//! Serving-path benchmarks: per-query latency of the sharded engine vs
//! the brute-force scan, snapshot codec throughput, closed-loop server
//! throughput at 1 vs 4 worker threads, the request-scheduler matrix
//! (condvar vs work-stealing with batched draining, p50/p99 at 1/4/8
//! workers under a bursty hotspot open loop), and the distributed tier —
//! routing-policy tail latency under the hotspot mix, hedged-request
//! p999 vs p2c-alone, router-tier cache hit rate vs fabric bytes
//! saved, a failover drill, and live ingestion (read p99 + hit rate
//! during delta publishes vs quiesced, plus the fresh-read propagation
//! cost) — all driven through the unified `QueryEngine` stack. A
//! windowed-collector pass over the p2c run splits the latency story
//! into steady-state p99 (median window) vs the worst single window.
//! A control-plane pass drives a placement-derived moving hotspot
//! through a static vs rebalancing-controlled router and records both
//! sides' load imbalance and p99 (the `control` section).
//! Results
//! are also written to `BENCH_serve.json` so the perf trajectory
//! accumulates across PRs.

use std::sync::Arc;

use celeste::benchkit::{bench, black_box, BenchResult};
use celeste::experiments::obj_pub;
use celeste::jsonlite::{self, Value};
use celeste::serve::dist::{CostModel, DistReport, FailureSchedule, Router, RouterConfig, Routing};
use celeste::serve::{
    self, drive_closed_loop, drive_open_loop, drive_open_loop_with, metric, Cached, Consistency,
    Consistent, DirectEngine, DriftConfig, DriftGen, DriveReport, Hedged, IngestDriver, Ingestor,
    LoadGen, LoadGenConfig, NetRouterEngine, Query, QueryEngine, Request, RouterEngine,
    SchedConfig, SchedKind, Server, ServerConfig, ServerEngine, ShardServer, SimClock,
    SourceFilter, Store, VersionedStore, WallClock,
};

const DIST_NODES: usize = 6;
const DIST_REPLICAS: usize = 3;
const DIST_QPS: f64 = 50_000.0;
const DIST_SECS: f64 = 0.3;
/// ingestion section: delta publishes per simulated second / batch size
const INGEST_RATE: f64 = 400.0;
const INGEST_BATCH: usize = 64;

fn dist_router(store: &Arc<Store>, routing: Routing) -> Router {
    Router::new(
        Arc::clone(store),
        DIST_NODES,
        DIST_REPLICAS,
        RouterConfig { routing, seed: 4242, ..Default::default() },
    )
}

/// Drive any engine open-loop on the hotspot mix in simulated time —
/// same seed, so every comparison below sees the identical query
/// stream at the identical offered load.
fn dist_drive<E: QueryEngine>(engine: &E, store: &Arc<Store>) -> DriveReport {
    let cfg = LoadGenConfig::scenario("hotspot", 4242).unwrap();
    let mut gen = LoadGen::new(cfg, store.width, store.height);
    let mut clock = SimClock::new();
    drive_open_loop(engine, &mut clock, &mut gen, DIST_QPS, DIST_SECS)
}

/// Drive the drift (mixed read/write) scenario: the identical read
/// stream every time, with `rate` delta publishes per simulated second
/// ingested through copy-on-write epochs and shipped to the replica
/// tier (`rate = 0`: quiesced baseline). Returns the drive plus the
/// publish/row counts.
fn drift_drive<E: QueryEngine>(
    engine: &E,
    store: &Arc<Store>,
    tier: &RouterEngine,
    rate: f64,
) -> (DriveReport, u64, u64) {
    let cfg = LoadGenConfig::scenario("drift", 4242).unwrap();
    let mut gen = LoadGen::new(cfg, store.width, store.height);
    let mut clock = SimClock::new();
    let mut driver = if rate > 0.0 {
        let versioned = Arc::new(VersionedStore::new(Arc::clone(store)));
        let drift = DriftGen::new(
            &store.all_sources(),
            store.width,
            store.height,
            DriftConfig { batch: INGEST_BATCH, seed: 777, ..Default::default() },
        );
        Some(IngestDriver::new(Ingestor::new(versioned), drift, rate, 777))
    } else {
        None
    };
    let drive = drive_open_loop_with(engine, &mut clock, &mut gen, DIST_QPS, DIST_SECS, |at| {
        if let Some(d) = driver.as_mut() {
            for rep in d.tick(at) {
                tier.publish(at, &rep);
            }
        }
    });
    let (publishes, rows) = driver.as_ref().map(|d| (d.publishes, d.rows)).unwrap_or((0, 0));
    (drive, publishes, rows)
}

/// Nearest-rank percentile over an ascending-sorted slice.
fn pctl(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let i = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[i]
}

fn main() {
    println!("== serve: sharded query engine + server ==");
    let snap = serve::snapshot::synthetic(5000, 42);
    let (w, h) = (snap.width, snap.height);
    let flat = snap.sources.clone();
    let store = Arc::new(Store::build(snap.sources, w, h, 8));
    println!("{}", store.summary());

    // --- single-query latency: index vs brute force ---
    let mut singles: Vec<BenchResult> = Vec::new();
    let cone = Query::Cone { center: (w * 0.5, h * 0.5), radius: 60.0, filter: SourceFilter::Any };
    singles.push(bench("cone r=60 sharded (5k)", 0.5, || {
        black_box(serve::execute(&store, &cone));
    }));
    singles.push(bench("cone r=60 brute-force scan", 0.5, || {
        black_box(serve::execute_scan(&flat, &cone));
    }));
    let boxq = Query::BoxSearch {
        x0: w * 0.3,
        y0: h * 0.3,
        x1: w * 0.45,
        y1: h * 0.45,
        filter: SourceFilter::GalaxiesOnly,
    };
    singles.push(bench("box 15% sharded", 0.5, || {
        black_box(serve::execute(&store, &boxq));
    }));
    let bright = Query::BrightestN { n: 100, filter: SourceFilter::Any };
    singles.push(bench("brightest-100 sharded", 0.5, || {
        black_box(serve::execute(&store, &bright));
    }));
    let xm = Query::CrossMatch { pos: (w * 0.6, h * 0.4), radius: 3.0 };
    singles.push(bench("cross-match sharded", 0.5, || {
        black_box(serve::execute(&store, &xm));
    }));

    // --- snapshot codec ---
    let text = serve::snapshot::to_json(&flat, w, h);
    println!("snapshot size: {} bytes for {} sources", text.len(), flat.len());
    singles.push(bench("snapshot encode 5k", 0.5, || {
        black_box(serve::snapshot::to_json(&flat, w, h));
    }));
    singles.push(bench("snapshot decode 5k", 0.5, || {
        black_box(serve::snapshot::from_json(&text).unwrap());
    }));

    // --- closed-loop server throughput: 1 vs 4 workers (bare engine:
    //     no cache layer, so this measures execution scaling) ---
    let mut closed: Vec<(usize, f64)> = Vec::new();
    for threads in [1usize, 4] {
        let server = Arc::new(Server::start(
            Arc::clone(&store),
            ServerConfig { threads, ..Default::default() },
        ));
        let engine = ServerEngine::new(Arc::clone(&server));
        let cfg = LoadGenConfig::scenario("uniform", 7).unwrap();
        let mut gen = LoadGen::new(cfg, w, h);
        let cl = drive_closed_loop(&engine, &mut gen, 8, 1.5);
        let _ = server.shutdown();
        let all = cl.latency_all();
        println!(
            "closed loop {threads} worker(s): {:>9.0} qps  p50={:.3}ms p99={:.3}ms",
            cl.qps(),
            all.p50() * 1e3,
            all.p99() * 1e3
        );
        closed.push((threads, cl.qps()));
    }
    let speedup = closed[1].1 / closed[0].1.max(1e-9);
    println!(
        "4-thread speedup over 1 thread: {speedup:.2}x {}",
        if closed[1].1 > closed[0].1 { "(scales)" } else { "(NOT scaling!)" }
    );

    // --- scheduler: condvar vs work-stealing (batch 16) under a bursty
    //     hotspot open loop at 1/4/8 workers. The offered rate is
    //     calibrated off the measured 4-worker closed-loop capacity so
    //     queues actually form and draining efficiency is what the tail
    //     measures; both schedulers see the identical arrival stream.
    //     Latency here is the server's own queue-entry -> reply
    //     accounting; steal/local/batch counters ride the same report.
    const SCHED_WORKERS: [usize; 3] = [1, 4, 8];
    const SCHED_BATCH: usize = 16;
    const SCHED_BURST: usize = 8;
    let sched_qps = (closed[1].1 * 1.1).max(2_000.0);
    let sched_secs = 0.6;
    println!(
        "== sched: condvar vs steal(batch {SCHED_BATCH}), hotspot burst {SCHED_BURST} @ {:.0} qps open-loop ==",
        sched_qps
    );
    let mut sched_rows: Vec<Value> = Vec::new();
    let mut sched_p99_8w = (0.0f64, 0.0f64); // (condvar, steal), seconds
    for &workers in &SCHED_WORKERS {
        let mut per: Vec<(f64, f64, serve::ServerReport)> = Vec::new();
        for kind in [SchedKind::Condvar, SchedKind::Steal] {
            let batch = if kind == SchedKind::Steal { SCHED_BATCH } else { 1 };
            let server = Arc::new(Server::start(
                Arc::clone(&store),
                ServerConfig {
                    threads: workers,
                    queue_depth: usize::MAX,
                    sched: SchedConfig { kind, batch },
                },
            ));
            let engine = ServerEngine::new(Arc::clone(&server));
            let cfg = LoadGenConfig {
                burst: SCHED_BURST,
                ..LoadGenConfig::scenario("hotspot", 4242).unwrap()
            };
            let mut gen = LoadGen::new(cfg, w, h);
            let mut clock = WallClock::start();
            let _ = drive_open_loop(&engine, &mut clock, &mut gen, sched_qps, sched_secs);
            let report = server.shutdown();
            let q = report.latency_all().quantiles(&[0.50, 0.99]);
            println!(
                "  {workers} worker(s) {:<7}: p50={:>8.3}ms p99={:>8.3}ms ({} local, {} stolen, mean batch {:.2})",
                kind.name(),
                q[0] * 1e3,
                q[1] * 1e3,
                report.local_hits,
                report.steals,
                report.batch_size.mean()
            );
            per.push((q[0], q[1], report));
        }
        if workers == 8 {
            sched_p99_8w = (per[0].1, per[1].1);
        }
        sched_rows.push(obj_pub(vec![
            ("workers", Value::Num(workers as f64)),
            ("condvar_p50_ms", Value::Num(per[0].0 * 1e3)),
            ("condvar_p99_ms", Value::Num(per[0].1 * 1e3)),
            ("steal_p50_ms", Value::Num(per[1].0 * 1e3)),
            ("steal_p99_ms", Value::Num(per[1].1 * 1e3)),
            ("steal_local_hits", Value::Num(per[1].2.local_hits as f64)),
            ("steal_steals", Value::Num(per[1].2.steals as f64)),
            ("steal_fraction", Value::Num(per[1].2.steal_fraction())),
            ("steal_mean_batch", Value::Num(per[1].2.batch_size.mean())),
        ]));
    }
    let steal_wins_8w = sched_p99_8w.1 <= sched_p99_8w.0;
    println!(
        "steal p99 <= condvar p99 at 8 workers: {} ({:.3}ms vs {:.3}ms)",
        if steal_wins_8w { "YES" } else { "NO" },
        sched_p99_8w.1 * 1e3,
        sched_p99_8w.0 * 1e3
    );

    // --- distributed tier: routing-policy tails under the hotspot mix,
    //     same placement and same deterministic query stream ---
    println!(
        "== dist: {DIST_NODES} nodes x{DIST_REPLICAS} replicas, hotspot @ {:.0}k qps (simulated) ==",
        DIST_QPS / 1e3
    );
    let mut dist_reports: Vec<(Routing, DistReport)> = Vec::new();
    for routing in [Routing::Random, Routing::RoundRobin, Routing::PowerOfTwo] {
        let engine = RouterEngine::new(dist_router(&store, routing));
        let drive = dist_drive(&engine, &store);
        let rep = engine.dist_report(&drive);
        let q = rep.latency_all().quantiles(&[0.50, 0.99]);
        println!(
            "  {:<6} p50={:.3}ms p99={:.3}ms imbalance={:.2} fabric={:.2}MB failed={}",
            routing.name(),
            q[0] * 1e3,
            q[1] * 1e3,
            rep.imbalance(),
            rep.bytes_moved / 1e6,
            rep.failed
        );
        dist_reports.push((routing, rep));
    }
    let random_p99 = dist_reports[0].1.latency_all().p99();
    let rr_p99 = dist_reports[1].1.latency_all().p99();
    let p2c_p99 = dist_reports[2].1.latency_all().p99();
    let p2c_wins = p2c_p99 < random_p99;
    println!(
        "p2c beats random on p99 at equal offered load: {} ({:.3}ms vs {:.3}ms)",
        if p2c_wins { "YES" } else { "NO" },
        p2c_p99 * 1e3,
        random_p99 * 1e3
    );

    // --- hedged requests: clip the p999 tail on top of p2c. Budgets
    //     are taken from the unhedged run's own latency quantiles (how
    //     a real deployment tunes a hedge), best budget wins ---
    let base_engine = RouterEngine::new(dist_router(&store, Routing::PowerOfTwo));
    let base_drive = dist_drive(&base_engine, &store);
    let base_p999 = base_drive.latency_all().quantile(0.999);

    // --- per-stage latency breakdown of the p2c run: every request's
    //     simulated time partitioned into queue wait (stalls + failure
    //     detection), shard execution, and the fabric residual by the
    //     router's span attribution; p50/p99 per stage land in the JSON
    //     (schema v6) and are gated by bench_check ---
    let stage_snap = base_engine.registry().snapshot();
    let mut stage_fields: Vec<(&str, Value)> = Vec::new();
    let mut stage_line = String::new();
    for stage in serve::obs::STAGES {
        // every stage lands in the JSON even when it never fired
        // (n = 0, zero quantiles): the gate reads fixed paths, and an
        // idle stage reporting 0.000 must not read as a missing metric
        let (n, p50, p99) = match stage_snap.histograms.get(&format!("stage_{}", stage.name())) {
            Some(s) if s.n > 0 => (s.n, s.p50(), s.p99()),
            _ => (0, 0.0, 0.0),
        };
        stage_fields.push((
            stage.name(),
            obj_pub(vec![
                ("n", Value::Num(n as f64)),
                ("p50_ms", Value::Num(p50 * 1e3)),
                ("p99_ms", Value::Num(p99 * 1e3)),
            ]),
        ));
        if n > 0 {
            stage_line.push_str(&format!(" {}={:.3}ms", stage.name(), p99 * 1e3));
        }
    }
    println!("stage p99 (p2c, simulated):{stage_line}");
    let budgets = base_drive.latency_all().quantiles(&[0.90, 0.95, 0.99]);
    let mut best: Option<(f64, f64, u64, u64)> = None;
    for &b in &budgets {
        if b <= 0.0 {
            continue;
        }
        let engine = Hedged::new(RouterEngine::new(dist_router(&store, Routing::PowerOfTwo)), b);
        let drive = dist_drive(&engine, &store);
        assert_eq!(drive.offered, base_drive.offered, "equal offered load");
        let p999 = drive.latency_all().quantile(0.999);
        let better = match best {
            None => true,
            Some((_, prev, _, _)) => p999 < prev,
        };
        if better {
            best = Some((b, p999, drive.hedges, drive.hedge_wins));
        }
    }
    let (hedge_budget, hedged_p999, hedges_fired, hedge_wins) =
        best.unwrap_or((0.0, base_p999, 0, 0));
    let hedged_improves = hedged_p999 < base_p999;
    println!(
        "hedged p2c (budget {:.3}ms): p999 {:.3}ms vs p2c-alone {:.3}ms ({}; {} hedges, {} wins)",
        hedge_budget * 1e3,
        hedged_p999 * 1e3,
        base_p999 * 1e3,
        if hedged_improves { "improves" } else { "no win" },
        hedges_fired,
        hedge_wins
    );

    // --- router-tier result cache: hit rate vs fabric bytes saved
    //     under the hotspot mix (hot queries repeat exactly) ---
    let cache_tier = RouterEngine::new(dist_router(&store, Routing::PowerOfTwo));
    let cached = Cached::new(cache_tier.clone(), 512);
    let cdrive = dist_drive(&cached, &store);
    let crep = cache_tier.dist_report(&cdrive);
    println!(
        "router cache (512/class): {:.1}% hit rate, {:.2}MB fabric saved vs {:.2}MB moved",
        cached.hit_rate() * 100.0,
        cached.bytes_saved() / 1e6,
        crep.bytes_moved / 1e6
    );

    // --- live ingestion: the same read stream quiesced vs with delta
    //     publishes flowing (copy-on-write epochs shipped to replicas),
    //     plus the fresh-read cost of waiting out propagation lag ---
    println!(
        "== ingest: drift mix @ {:.0}k qps reads + {INGEST_RATE:.0} publishes/s x {INGEST_BATCH} rows ==",
        DIST_QPS / 1e3
    );
    let q_tier = RouterEngine::new(dist_router(&store, Routing::PowerOfTwo));
    let q_cached = Cached::new(q_tier.clone(), 512);
    let (q_drive, _, _) = drift_drive(&q_cached, &store, &q_tier, 0.0);
    let quiesced_p99 = q_drive.latency_all().p99();
    let quiesced_hit = q_cached.hit_rate();
    println!(
        "  quiesced : p99={:.3}ms hit={:.1}%",
        quiesced_p99 * 1e3,
        quiesced_hit * 100.0
    );
    let i_tier = RouterEngine::new(dist_router(&store, Routing::PowerOfTwo));
    let i_cached = Cached::new(i_tier.clone(), 512);
    let (i_drive, publishes, rows) = drift_drive(&i_cached, &store, &i_tier, INGEST_RATE);
    let i_rep = i_tier.dist_report(&i_drive);
    let ingest_p99 = i_drive.latency_all().p99();
    let ingest_hit = i_cached.hit_rate();
    println!(
        "  ingesting: p99={:.3}ms hit={:.1}% invalidations={} ({} epochs, {:.2}MB delta)",
        ingest_p99 * 1e3,
        ingest_hit * 100.0,
        i_cached.invalidations(),
        publishes,
        i_rep.delta_bytes / 1e6
    );
    assert_eq!(
        i_drive.offered, q_drive.offered,
        "quiesced and ingesting phases must offer the identical read stream"
    );
    // fresh reads during the same ingestion schedule: every read is
    // served at the head, paying stale-replica refusals and catch-up
    // stalls instead of staleness
    let f_tier = RouterEngine::new(dist_router(&store, Routing::PowerOfTwo));
    let f_engine = Consistent::new(Cached::new(f_tier.clone(), 512), Consistency::Fresh);
    let (f_drive, _, _) = drift_drive(&f_engine, &store, &f_tier, INGEST_RATE);
    let f_rep = f_tier.dist_report(&f_drive);
    let fresh_p99 = f_drive.latency_all().p99();
    println!(
        "  fresh    : p99={:.3}ms stale refusals={} catch-up stalls={}",
        fresh_p99 * 1e3,
        f_rep.stale_refusals,
        f_rep.stale_waits.n
    );

    // --- failover drill: kill one replica of a 3-replica range mid-run
    //     (a non-origin host, read from the router's own placement) ---
    let router = dist_router(&store, Routing::PowerOfTwo);
    let victim = *router
        .placement
        .replicas_of(0)
        .iter()
        .find(|&&n| n != 0)
        .expect("3 distinct replicas include a non-origin node");
    let kill_spec = format!("{victim}@{}", DIST_SECS * 0.5);
    let router =
        router.with_schedule(FailureSchedule::parse(&kill_spec).expect("valid kill spec"));
    let kengine = RouterEngine::new(router);
    let kdrive = dist_drive(&kengine, &store);
    let rep_kill = kengine.dist_report(&kdrive);
    let fo_max_ms =
        if rep_kill.failover.n == 0 { 0.0 } else { rep_kill.failover.max * 1e3 };
    println!(
        "failover (kill node {victim} mid-run): failed={} events={} mean={:.3}ms max={:.3}ms",
        rep_kill.failed,
        rep_kill.failover.n,
        rep_kill.failover.mean() * 1e3,
        fo_max_ms
    );

    // --- continuous telemetry: the p2c tier driven with the windowed
    //     collector sampling the registry + every node each window.
    //     The full-run aggregate can hide a bad stretch; the gate reads
    //     steady-state p99 (median window) vs the worst single window,
    //     so a latency story that only holds on average fails here ---
    const TL_WINDOWS: f64 = 8.0;
    let tl_engine = RouterEngine::new(dist_router(&store, Routing::PowerOfTwo));
    let tl_names: Vec<String> = std::iter::once("local".to_string())
        .chain((0..DIST_NODES).map(|n| format!("node-{n}")))
        .collect();
    let mut tl = serve::Collector::new(
        serve::CollectorConfig { window_s: DIST_SECS / TL_WINDOWS, ..Default::default() },
        tl_names,
    );
    let tl_drive = {
        let cfg = LoadGenConfig::scenario("hotspot", 4242).unwrap();
        let mut gen = LoadGen::new(cfg, w, h);
        let mut clock = SimClock::new();
        let scraper = tl_engine.clone();
        drive_open_loop_with(&tl_engine, &mut clock, &mut gen, DIST_QPS, DIST_SECS, |at| {
            let mut src = |t: f64| {
                let mut v = vec![Some(scraper.registry().snapshot())];
                v.extend(scraper.node_samples(t));
                v
            };
            tl.tick(at, &mut src);
        })
    };
    tl_engine.registry().absorb_drive(&tl_drive);
    {
        let scraper = tl_engine.clone();
        let mut src = |t: f64| {
            let mut v = vec![Some(scraper.registry().snapshot())];
            v.extend(scraper.node_samples(t));
            v
        };
        tl.finish(DIST_SECS, &mut src);
    }
    let mut tl_p99: Vec<f64> = Vec::new();
    let mut tl_gapped = 0usize;
    for win in tl.cluster().windows() {
        if win.gapped {
            tl_gapped += 1;
            continue;
        }
        if let Some(h) = win.hists.get("request_latency") {
            if h.n > 0 {
                tl_p99.push(h.p99);
            }
        }
    }
    tl_p99.sort_by(|a, b| a.total_cmp(b));
    let steady_p99 = pctl(&tl_p99, 0.50);
    let worst_p99 = tl_p99.last().copied().unwrap_or(0.0);
    println!(
        "timeline (p2c, {} window(s)): steady p99={:.3}ms worst-window p99={:.3}ms ({} gapped)",
        tl.cluster().windows().count(),
        steady_p99 * 1e3,
        worst_p99 * 1e3,
        tl_gapped
    );

    // --- real-socket transport: the identical hotspot query stream
    //     through in-process planning (sim) vs framed TCP to local
    //     shard-server threads, at 1/4/8 servers, wall clock; parity
    //     is asserted per query, codec cost comes from the client's
    //     own encode/decode counters ---
    println!("== transport: sim vs tcp, localhost shard servers (wall clock) ==");
    const NET_QUERIES: usize = 600;
    let mut transport_rows: Vec<Value> = Vec::new();
    let mut transport_parity = true;
    for n_servers in [1usize, 4, 8] {
        let mut handles = Vec::new();
        let mut addrs = Vec::new();
        for _ in 0..n_servers {
            let s =
                ShardServer::bind(Arc::clone(&store), "127.0.0.1:0").expect("bind shard server");
            addrs.push(s.local_addr().to_string());
            handles.push(s.spawn());
        }
        let replicas = 2.min(n_servers);
        let net = NetRouterEngine::connect(Arc::clone(&store), &addrs, replicas)
            .expect("connect to shard servers");
        let direct = DirectEngine::new(Arc::clone(&store));
        let cfg = LoadGenConfig::scenario("hotspot", 4242).unwrap();
        let mut gen = LoadGen::new(cfg, w, h);
        let queries: Vec<Query> = (0..NET_QUERIES).map(|_| gen.next_query()).collect();
        let mut sim_lat = Vec::with_capacity(NET_QUERIES);
        let mut tcp_lat = Vec::with_capacity(NET_QUERIES);
        for q in &queries {
            let t = std::time::Instant::now();
            let sim = direct.call(Request::new(q.clone()));
            sim_lat.push(t.elapsed().as_secs_f64());
            let t = std::time::Instant::now();
            let tcp = net.call(Request::new(q.clone()));
            tcp_lat.push(t.elapsed().as_secs_f64());
            transport_parity &= tcp.result.is_some() && sim.result == tcp.result;
        }
        sim_lat.sort_by(|a, b| a.total_cmp(b));
        tcp_lat.sort_by(|a, b| a.total_cmp(b));
        let enc_us = metric(&net, "net_encode_us_per_frame").unwrap_or(0.0);
        let dec_us = metric(&net, "net_decode_us_per_frame").unwrap_or(0.0);
        println!(
            "  {n_servers} server(s) x{replicas}: sim p50={:>7.3}ms p99={:>7.3}ms | tcp p50={:>7.3}ms p99={:>7.3}ms | enc={:.1}us dec={:.1}us/frame",
            pctl(&sim_lat, 0.50) * 1e3,
            pctl(&sim_lat, 0.99) * 1e3,
            pctl(&tcp_lat, 0.50) * 1e3,
            pctl(&tcp_lat, 0.99) * 1e3,
            enc_us,
            dec_us
        );
        transport_rows.push(obj_pub(vec![
            ("servers", Value::Num(n_servers as f64)),
            ("replicas", Value::Num(replicas as f64)),
            ("sim_p50_ms", Value::Num(pctl(&sim_lat, 0.50) * 1e3)),
            ("sim_p99_ms", Value::Num(pctl(&sim_lat, 0.99) * 1e3)),
            ("tcp_p50_ms", Value::Num(pctl(&tcp_lat, 0.50) * 1e3)),
            ("tcp_p99_ms", Value::Num(pctl(&tcp_lat, 0.99) * 1e3)),
            ("encode_us_per_req", Value::Num(enc_us)),
            ("decode_us_per_req", Value::Num(dec_us)),
        ]));
    }
    println!(
        "tcp answers byte-identical to in-process execution: {}",
        if transport_parity { "YES" } else { "NO" }
    );

    // --- adaptive control plane: a moving hotspot at equal offered
    //     load, static placement vs the rebalancing controller. The
    //     workload is derived from the actual placement (every cone
    //     lands on a shard hosted by the initially most-crowded node,
    //     ~3.2x one node's service capacity), so the margin is
    //     structural, not statistical; bench_check requires the
    //     controller to beat static on BOTH load imbalance and p99 ---
    println!("== control: moving hotspot, static vs rebalanced placement ==");
    let ctl_store = {
        let snap = celeste::serve::snapshot::synthetic(3200, 77);
        Arc::new(Store::build(snap.sources, snap.width, snap.height, 32))
    };
    let ctl_rcfg = RouterConfig {
        cost: CostModel { base_service: 400e-6, ..Default::default() },
        ..Default::default()
    };
    let ctl_router = || Router::new(Arc::clone(&ctl_store), 8, 1, ctl_rcfg.clone());
    let ctl_placement0 = ctl_router().placement.clone();
    let ctl_counts = ctl_placement0.counts_per_node();
    let ctl_crowded = (0..8).max_by_key(|&n| ctl_counts[n]).expect("eight nodes");
    let ctl_hot: Vec<usize> = (0..32)
        .filter(|&s| {
            ctl_placement0.shard_nodes[s].contains(&ctl_crowded)
                && !ctl_store.shards[s].sources.is_empty()
        })
        .take(4)
        .collect();
    assert!(ctl_hot.len() >= 2, "the crowded node must host >= 2 populated shards");
    let ctl_pairs = [
        [ctl_hot[0], ctl_hot[1 % ctl_hot.len()]],
        [ctl_hot[2 % ctl_hot.len()], ctl_hot[3 % ctl_hot.len()]],
    ];
    let ctl_dt = 125e-6; // 8000 qps across a 0.5s run, hotspot moving at 0.25s
    let ctl_queries: Vec<Query> = (0..4000usize)
        .map(|i| {
            let phase = if (i as f64 * ctl_dt) < 0.25 { 0 } else { 1 };
            let shard = ctl_pairs[phase][i % 2];
            Query::Cone {
                center: ctl_store.shards[shard].sources[0].pos,
                radius: 2.0,
                filter: SourceFilter::Any,
            }
        })
        .collect();
    let ctl_run = |controlled: bool| {
        let mut router = ctl_router();
        let mut ctl = serve::Controller::new(
            serve::ControlConfig {
                period_s: 0.05,
                cooldown_periods: 0,
                min_window_subqueries: 16,
                ..Default::default()
            },
            8,
            &(0..8).collect::<Vec<usize>>(),
        );
        let mut lat = Vec::with_capacity(ctl_queries.len());
        for (i, q) in ctl_queries.iter().enumerate() {
            let at = i as f64 * ctl_dt;
            if controlled {
                let nodes: Vec<serve::NodeLoad> = (0..8)
                    .map(|n| serve::NodeLoad {
                        alive: router.node_alive(n),
                        served: router.served_per_node[n],
                        busy_s: router.busy_per_node[n],
                    })
                    .collect();
                let shard_served = router.served_per_shard.clone();
                if let Some(target) = ctl.tick(at, &nodes, &shard_served, &router.placement) {
                    router.rebalance_to(at, &target);
                }
            }
            let (res, done) = router.execute(at, q);
            assert!(res.is_some(), "control query {i} failed");
            lat.push(done - at);
        }
        lat.sort_by(|a, b| a.total_cmp(b));
        let max = router.served_per_node.iter().copied().max().unwrap_or(0) as f64;
        let mean = router.served_per_node.iter().sum::<u64>() as f64
            / router.served_per_node.len() as f64;
        let imb = max / mean.max(1e-9);
        (imb, pctl(&lat, 0.99), router.migrations, router.failed, ctl.log().clone())
    };
    let (static_imb, static_hot_p99, _, static_ctl_failed, _) = ctl_run(false);
    let (reb_imb, reb_p99, ctl_migrations, reb_failed, ctl_log) = ctl_run(true);
    println!(
        "  static:     imbalance={static_imb:.2} p99={:.3}ms failed={static_ctl_failed}",
        static_hot_p99 * 1e3
    );
    println!(
        "  rebalanced: imbalance={reb_imb:.2} p99={:.3}ms failed={reb_failed} \
         migrations={ctl_migrations} decisions={}",
        reb_p99 * 1e3,
        ctl_log.events.len()
    );

    // --- machine-readable results ---
    let single_fields: Vec<(&str, Value)> = singles
        .iter()
        .map(|r| (r.name.as_str(), Value::Num(r.ns_per_iter)))
        .collect();
    let json = obj_pub(vec![
        ("schema", Value::Str("celeste-bench-serve-v8".to_string())),
        ("single_query_ns", obj_pub(single_fields)),
        (
            "scheduler",
            obj_pub(vec![
                ("mix", Value::Str("hotspot".to_string())),
                ("burst", Value::Num(SCHED_BURST as f64)),
                ("batch", Value::Num(SCHED_BATCH as f64)),
                ("qps", Value::Num(sched_qps)),
                ("secs", Value::Num(sched_secs)),
                ("per_workers", Value::Arr(sched_rows)),
                ("steal_beats_condvar_p99_8w", Value::Bool(steal_wins_8w)),
            ]),
        ),
        (
            "closed_loop",
            Value::Arr(
                closed
                    .iter()
                    .map(|&(t, q)| {
                        obj_pub(vec![
                            ("threads", Value::Num(t as f64)),
                            ("qps", Value::Num(q)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "dist",
            obj_pub(vec![
                ("nodes", Value::Num(DIST_NODES as f64)),
                ("replicas", Value::Num(DIST_REPLICAS as f64)),
                ("qps", Value::Num(DIST_QPS)),
                ("sim_secs", Value::Num(DIST_SECS)),
                ("mix", Value::Str("hotspot".to_string())),
                ("random_p99_ms", Value::Num(random_p99 * 1e3)),
                ("rr_p99_ms", Value::Num(rr_p99 * 1e3)),
                ("p2c_p99_ms", Value::Num(p2c_p99 * 1e3)),
                ("p2c_beats_random", Value::Bool(p2c_wins)),
                (
                    "p2c_imbalance",
                    Value::Num(dist_reports[2].1.imbalance()),
                ),
                (
                    "bytes_moved_mb",
                    Value::Num(dist_reports[2].1.bytes_moved / 1e6),
                ),
            ]),
        ),
        (
            "stages",
            obj_pub(vec![
                ("tier", Value::Str("dist-sim-p2c".to_string())),
                ("per_stage", obj_pub(stage_fields)),
            ]),
        ),
        (
            "hedged",
            obj_pub(vec![
                ("budget_ms", Value::Num(hedge_budget * 1e3)),
                ("p2c_p999_ms", Value::Num(base_p999 * 1e3)),
                ("hedged_p999_ms", Value::Num(hedged_p999 * 1e3)),
                ("improves_p999", Value::Bool(hedged_improves)),
                ("hedges_fired", Value::Num(hedges_fired as f64)),
                ("hedge_wins", Value::Num(hedge_wins as f64)),
            ]),
        ),
        (
            "router_cache",
            obj_pub(vec![
                ("entries_per_class", Value::Num(512.0)),
                ("hit_rate", Value::Num(cached.hit_rate())),
                ("bytes_saved_mb", Value::Num(cached.bytes_saved() / 1e6)),
                ("bytes_moved_mb", Value::Num(crep.bytes_moved / 1e6)),
            ]),
        ),
        (
            "ingest",
            obj_pub(vec![
                ("mix", Value::Str("drift".to_string())),
                ("read_qps", Value::Num(DIST_QPS)),
                ("ingest_rate", Value::Num(INGEST_RATE)),
                ("ingest_batch", Value::Num(INGEST_BATCH as f64)),
                ("epochs_published", Value::Num(publishes as f64)),
                ("rows_ingested", Value::Num(rows as f64)),
                ("delta_mb", Value::Num(i_rep.delta_bytes / 1e6)),
                ("quiesced_p99_ms", Value::Num(quiesced_p99 * 1e3)),
                ("ingesting_p99_ms", Value::Num(ingest_p99 * 1e3)),
                ("quiesced_hit_rate", Value::Num(quiesced_hit)),
                ("ingesting_hit_rate", Value::Num(ingest_hit)),
                (
                    "cache_invalidations",
                    Value::Num(i_cached.invalidations() as f64),
                ),
                ("fresh_p99_ms", Value::Num(fresh_p99 * 1e3)),
                (
                    "fresh_stale_refusals",
                    Value::Num(f_rep.stale_refusals as f64),
                ),
                (
                    "fresh_catchup_stalls",
                    Value::Num(f_rep.stale_waits.n as f64),
                ),
            ]),
        ),
        (
            "timeline",
            obj_pub(vec![
                ("tier", Value::Str("dist-sim-p2c".to_string())),
                ("window_ms", Value::Num(DIST_SECS / TL_WINDOWS * 1e3)),
                ("windows", Value::Num(tl_p99.len() as f64)),
                ("gapped", Value::Num(tl_gapped as f64)),
                ("steady_p99_ms", Value::Num(steady_p99 * 1e3)),
                ("worst_p99_ms", Value::Num(worst_p99 * 1e3)),
                ("worst_over_steady", Value::Num(worst_p99 / steady_p99.max(1e-12))),
            ]),
        ),
        (
            "transport",
            obj_pub(vec![
                ("mix", Value::Str("hotspot".to_string())),
                ("queries_per_point", Value::Num(NET_QUERIES as f64)),
                ("per_servers", Value::Arr(transport_rows)),
                ("parity", Value::Bool(transport_parity)),
            ]),
        ),
        (
            "failover",
            obj_pub(vec![
                ("kill_spec", Value::Str(kill_spec.clone())),
                ("failed_queries", Value::Num(rep_kill.failed as f64)),
                ("zero_failed", Value::Bool(rep_kill.failed == 0)),
                ("events", Value::Num(rep_kill.failover.n as f64)),
                ("mean_ms", Value::Num(rep_kill.failover.mean() * 1e3)),
                ("max_ms", Value::Num(fo_max_ms)),
            ]),
        ),
        (
            "control",
            obj_pub(vec![
                ("mix", Value::Str("moving-hotspot".to_string())),
                ("nodes", Value::Num(8.0)),
                ("shards", Value::Num(32.0)),
                ("qps", Value::Num(8000.0)),
                ("static_imbalance", Value::Num(static_imb)),
                ("rebalanced_imbalance", Value::Num(reb_imb)),
                ("static_p99_ms", Value::Num(static_hot_p99 * 1e3)),
                ("rebalanced_p99_ms", Value::Num(reb_p99 * 1e3)),
                ("migrations", Value::Num(ctl_migrations as f64)),
                ("decisions", Value::Num(ctl_log.events.len() as f64)),
                (
                    "failed_queries",
                    Value::Num((static_ctl_failed + reb_failed) as f64),
                ),
                (
                    "rebalance_beats_static_imbalance",
                    Value::Bool(reb_imb < static_imb),
                ),
                (
                    "rebalance_beats_static_p99",
                    Value::Bool(reb_p99 < static_hot_p99),
                ),
            ]),
        ),
    ]);
    match std::fs::write("BENCH_serve.json", jsonlite::to_string(&json)) {
        Ok(()) => println!("wrote BENCH_serve.json"),
        Err(e) => println!("could not write BENCH_serve.json: {e}"),
    }
}
