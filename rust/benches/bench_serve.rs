//! Serving-path benchmarks: per-query latency of the sharded engine vs
//! the brute-force scan, snapshot codec throughput, and closed-loop
//! server throughput at 1 vs 4 worker threads (the acceptance check
//! that the worker pool actually scales).

use std::sync::Arc;

use celeste::benchkit::{bench, black_box};
use celeste::serve::{
    self, run_closed_loop, LoadGen, LoadGenConfig, Query, Server, ServerConfig, SourceFilter,
    Store,
};

fn main() {
    println!("== serve: sharded query engine + server ==");
    let snap = serve::snapshot::synthetic(5000, 42);
    let (w, h) = (snap.width, snap.height);
    let flat = snap.sources.clone();
    let store = Arc::new(Store::build(snap.sources, w, h, 8));
    println!("{}", store.summary());

    // --- single-query latency: index vs brute force ---
    let cone = Query::Cone { center: (w * 0.5, h * 0.5), radius: 60.0, filter: SourceFilter::Any };
    bench("cone r=60 sharded (5k)", 0.5, || {
        black_box(serve::execute(&store, &cone));
    });
    bench("cone r=60 brute-force scan", 0.5, || {
        black_box(serve::execute_scan(&flat, &cone));
    });
    let boxq = Query::BoxSearch {
        x0: w * 0.3,
        y0: h * 0.3,
        x1: w * 0.45,
        y1: h * 0.45,
        filter: SourceFilter::GalaxiesOnly,
    };
    bench("box 15% sharded", 0.5, || {
        black_box(serve::execute(&store, &boxq));
    });
    let bright = Query::BrightestN { n: 100, filter: SourceFilter::Any };
    bench("brightest-100 sharded", 0.5, || {
        black_box(serve::execute(&store, &bright));
    });
    let xm = Query::CrossMatch { pos: (w * 0.6, h * 0.4), radius: 3.0 };
    bench("cross-match sharded", 0.5, || {
        black_box(serve::execute(&store, &xm));
    });

    // --- snapshot codec ---
    let text = serve::snapshot::to_json(&flat, w, h);
    println!("snapshot size: {} bytes for {} sources", text.len(), flat.len());
    bench("snapshot encode 5k", 0.5, || {
        black_box(serve::snapshot::to_json(&flat, w, h));
    });
    bench("snapshot decode 5k", 0.5, || {
        black_box(serve::snapshot::from_json(&text).unwrap());
    });

    // --- closed-loop server throughput: 1 vs 4 workers ---
    // cache off so the comparison measures execution scaling
    let mut results = Vec::new();
    for threads in [1usize, 4] {
        let server = Server::start(
            Arc::clone(&store),
            ServerConfig { threads, cache_entries: 0, ..Default::default() },
        );
        let cfg = LoadGenConfig::scenario("uniform", 7).unwrap();
        let mut gen = LoadGen::new(cfg, w, h);
        let cl = run_closed_loop(&server, &mut gen, 8, 1.5);
        let report = server.shutdown();
        let all = report.latency_all();
        println!(
            "closed loop {threads} worker(s): {:>9.0} qps  p50={:.3}ms p99={:.3}ms",
            cl.qps(),
            all.p50() * 1e3,
            all.p99() * 1e3
        );
        results.push(cl.qps());
    }
    let speedup = results[1] / results[0].max(1e-9);
    println!(
        "4-thread speedup over 1 thread: {speedup:.2}x {}",
        if results[1] > results[0] { "(scales)" } else { "(NOT scaling!)" }
    );
}
