//! Hot-path microbenchmarks (L3 profile targets, DESIGN.md §Perf):
//! renderer, patch extraction, per-source linear algebra, scheduler,
//! cache, fabric model, and the event-driven simulator itself.

use celeste::benchkit::{bench, black_box};
use celeste::catalog::noisy_catalog;
use celeste::cluster::workload::{synthetic_workload, CostModel};
use celeste::cluster::{simulate, ClusterConfig};
use celeste::dtree::{Dtree, DtreeConfig};
use celeste::ga::{Fabric, FabricConfig, LruCache};
use celeste::imaging::{extract_patch, render_field, Survey, SurveyConfig};
use celeste::linalg::{solve_spd, solve_trust_region, sym_eig, Mat};
use celeste::model::{galaxy_comps, render_mixture, GalaxyShape, PixelRect, SourceParams};
use celeste::prng::Rng;
use celeste::sky::{generate, SkyConfig};

fn main() {
    println!("== L3 hot paths ==");

    // --- renderer: one galaxy over a 32x32 patch (the per-iteration cost
    // of neighbor-background construction) ---
    let psf = [
        [0.7, 0.0, 0.0, 1.1, 0.03, 1.0],
        [0.3, 0.1, -0.1, 2.6, -0.1, 2.4],
    ];
    let shape = GalaxyShape { p_dev: 0.4, axis_ratio: 0.6, angle: 0.8, scale: 2.0 };
    let comps = galaxy_comps((16.0, 16.0), &psf, &shape);
    let rect = PixelRect { x0: 0.0, y0: 0.0, rows: 32, cols: 32 };
    bench("render_mixture 16comp 32x32", 0.5, || {
        black_box(render_mixture(&rect, &comps, 1.0));
    });

    // --- patch extraction incl. neighbor rendering ---
    let survey = Survey::layout(SurveyConfig {
        sky_width: 256.0,
        sky_height: 256.0,
        field_w: 256,
        field_h: 256,
        n_epochs: 1,
        jitter: 0.0,
        ..Default::default()
    });
    let sky = generate(&SkyConfig {
        width: 256.0,
        height: 256.0,
        n_sources: 60,
        seed: 3,
        ..Default::default()
    });
    let mut rng = Rng::new(4);
    let field = render_field(&sky.sources, &survey.fields[0], &mut rng);
    let neighbors: Vec<SourceParams> = sky.sources[1..5].to_vec();
    bench("extract_patch +4 neighbors", 0.5, || {
        black_box(extract_patch(&field, sky.sources[0].pos, &neighbors));
    });

    // --- per-iteration linear algebra at dim 27 ---
    let mut rng = Rng::new(5);
    let n = 27;
    let mut b = Mat::zeros(n, n);
    for v in &mut b.data {
        *v = rng.normal();
    }
    let mut spd = b.matmul(&b.transpose());
    spd.add_diag(n as f64);
    let g: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    bench("cholesky+solve 27x27", 0.3, || {
        black_box(solve_spd(&spd, &g));
    });
    bench("sym_eig 27x27 (jacobi)", 0.3, || {
        black_box(sym_eig(&spd));
    });
    bench("trust_region subproblem 27", 0.3, || {
        black_box(solve_trust_region(&spd, &g, 1.0));
    });

    // --- scheduler / cache / fabric ---
    bench("dtree drain 10k tasks 64 procs", 0.3, || {
        let mut dt = Dtree::new(DtreeConfig::default(), 64, 10_000);
        let mut done = false;
        while !done {
            done = true;
            for p in 0..64 {
                if dt.request(p).is_some() {
                    done = false;
                }
            }
        }
    });
    bench("lru insert+probe 1k entries", 0.3, || {
        let mut c = LruCache::new(1e9);
        for i in 0..1000u64 {
            c.insert(i, 1e6);
            black_box(c.contains(i / 2));
        }
    });
    bench("fabric get x1000", 0.3, || {
        let mut f = Fabric::new(FabricConfig::default(), 64);
        for i in 0..1000 {
            black_box(f.get(i as f64 * 1e-3, 120e6, i % 64, (i + 7) % 64));
        }
    });

    // --- the simulator itself (events/sec; fig4-scale runs depend on it) ---
    let w = synthetic_workload(5000, 64, 3, &CostModel::default(), 120e6, 5);
    bench("simulate 5k tasks 16 nodes", 1.0, || {
        let cfg = ClusterConfig { nodes: 16, ..Default::default() };
        black_box(simulate(&cfg, &w));
    });

    // --- catalog spatial index ---
    let cat = {
        let u = generate(&SkyConfig { n_sources: 5000, ..Default::default() });
        let mut r = Rng::new(6);
        noisy_catalog(&u.sources, u.width, u.height, &mut r, 0.5, 0.2)
    };
    bench("neighbors_within r=20 (5k catalog)", 0.3, || {
        black_box(cat.neighbors_within((1000.0, 600.0), 20.0, 0));
    });
}
