//! L1/L2 artifact benchmarks: PJRT execution latency of the compiled
//! ELBO kernels — the per-Newton-iteration cost that dominates inference
//! (DESIGN.md §Perf). Skips cleanly when artifacts are absent.

use celeste::benchkit::{bench, black_box};
use celeste::imaging::{extract_patch, render_field, Survey, SurveyConfig};
use celeste::model::layout as L;
use celeste::model::{theta_init, GalaxyShape, Prior, SourceParams};
use celeste::prng::Rng;
use celeste::runtime::{ElboEngine, Runtime};

fn main() {
    let dir = celeste::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP bench_artifacts: run `make artifacts` first");
        return;
    }
    let rt = Runtime::load(&dir).expect("runtime");
    let engine = ElboEngine::new(&rt, &Prior::default());

    let truth = SourceParams {
        pos: (48.0, 48.0),
        is_galaxy: true,
        flux_r: 3000.0,
        colors: [0.5, 0.3, 0.2, 0.1],
        shape: GalaxyShape { p_dev: 0.4, axis_ratio: 0.6, angle: 0.5, scale: 2.0 },
    };
    let survey = Survey::layout(SurveyConfig {
        sky_width: 96.0,
        sky_height: 96.0,
        field_w: 96,
        field_h: 96,
        n_epochs: 1,
        jitter: 0.0,
        ..Default::default()
    });
    let mut rng = Rng::new(1);
    let field = render_field(std::slice::from_ref(&truth), &survey.fields[0], &mut rng);
    let patch = extract_patch(&field, truth.pos, &[]).unwrap();
    let theta = theta_init(&truth, 0.5);
    let prior = Prior::default().to_vec();
    let _ = prior;

    println!("== L1/L2 compiled artifacts (per-execute latency) ==");
    bench("kl value+grad+hess", 1.0, || {
        black_box(engine.kl_vgh(&theta).unwrap());
    });
    bench("like_ad value+grad+hess (5x32x32)", 2.0, || {
        black_box(engine.like_vgh(&theta, &patch).unwrap());
    });
    bench("like_pallas value+grad (manual)", 2.0, || {
        black_box(engine.like_vg_pallas(&theta, &patch).unwrap());
    });
    let comps = [0.05f64; L::K_GAL * L::COMP_PARAMS];
    bench("render_pallas 16comp 32x32", 1.0, || {
        black_box(engine.render_pallas(&comps).unwrap());
    });
    println!(
        "mean artifact exec: {:.1} us over {} executions",
        rt.mean_exec_us(),
        rt.exec_count.get()
    );
}
