//! Serving-layer invariants: the sharded query engine must be
//! byte-identical to a brute-force scan on a ~5k-source synthetic
//! catalog, snapshots must round-trip losslessly, the server must
//! return exactly what direct execution returns — plus property tests
//! for the Hilbert curve the sharding is keyed on.

use std::sync::Arc;

use celeste::catalog::{hilbert_d2xy, hilbert_sky_key, hilbert_xy2d, noisy_catalog};
use celeste::prng::Rng;
use celeste::quickcheck::forall_with;
use celeste::serve::dist::{FailureSchedule, Router, RouterConfig, Routing};
use celeste::serve::{
    self, cross_match_catalog, drive_open_loop, execute, execute_scan, LoadGen, LoadGenConfig,
    Query, QueryEngine, QueryResult, Request, RouterEngine, Server, ServerConfig, ServedSource,
    SimClock, SourceFilter, Store,
};
use celeste::sky::{generate, SkyConfig};

/// ~5k sources with realistic clustering (the sky generator's mixture
/// of uniform field + clusters), plus noisy per-source uncertainties —
/// the same ingestion path `celeste serve-bench` uses.
fn synthetic_snapshot(n: usize, seed: u64) -> serve::Snapshot {
    serve::snapshot::synthetic(n, seed)
}

#[test]
fn sharded_queries_match_bruteforce_on_5k_catalog() {
    let snap = synthetic_snapshot(5000, 21);
    let (w, h) = (snap.width, snap.height);
    let store = Store::build(snap.sources, w, h, 16);
    let flat = store.all_sources();
    assert_eq!(flat.len(), 5000);

    let mut rng = Rng::new(5);
    let filters = [SourceFilter::Any, SourceFilter::StarsOnly, SourceFilter::GalaxiesOnly];
    for i in 0..200usize {
        let filter = filters[i % 3];
        let q = match i % 4 {
            0 => Query::Cone {
                center: (rng.uniform_in(-60.0, w + 60.0), rng.uniform_in(-60.0, h + 60.0)),
                radius: rng.uniform_in(0.5, 300.0),
                filter,
            },
            1 => {
                let ax = rng.uniform_in(-20.0, w + 20.0);
                let ay = rng.uniform_in(-20.0, h + 20.0);
                let bx = rng.uniform_in(-20.0, w + 20.0);
                let by = rng.uniform_in(-20.0, h + 20.0);
                Query::BoxSearch {
                    x0: ax.min(bx),
                    y0: ay.min(by),
                    x1: ax.max(bx),
                    y1: ay.max(by),
                    filter,
                }
            }
            2 => Query::BrightestN { n: rng.below(200) as usize, filter },
            _ => Query::CrossMatch {
                pos: (rng.uniform_in(0.0, w), rng.uniform_in(0.0, h)),
                radius: rng.uniform_in(0.2, 8.0),
            },
        };
        let fast = execute(&store, &q);
        let slow = execute_scan(&flat, &q);
        assert_eq!(fast, slow, "divergence on query {i}: {q:?}");
    }
}

#[test]
fn shard_count_does_not_change_results() {
    let snap = synthetic_snapshot(1500, 3);
    let (w, h) = (snap.width, snap.height);
    let flat = {
        let s = Store::build(snap.sources.clone(), w, h, 1);
        s.all_sources()
    };
    let q = Query::Cone { center: (w / 2.0, h / 2.0), radius: 200.0, filter: SourceFilter::Any };
    let want = execute_scan(&flat, &q);
    for shards in [1usize, 2, 5, 16, 64] {
        let store = Store::build(snap.sources.clone(), w, h, shards);
        assert_eq!(execute(&store, &q), want, "{shards} shards");
    }
}

#[test]
fn cross_match_catalog_finds_most_truth_sources() {
    // serve the noisy catalog, cross-match the truth positions against
    // it: position noise is 0.5 px, so a 3 px base radius should match
    // nearly everything
    let sky = generate(&SkyConfig { n_sources: 800, seed: 13, ..Default::default() });
    let mut rng = Rng::new(77);
    let cat = noisy_catalog(&sky.sources, sky.width, sky.height, &mut rng, 0.5, 0.2);
    let sources: Vec<ServedSource> = cat
        .entries
        .iter()
        .map(|e| ServedSource::from_entry(e, 0.2))
        .collect();
    let store = Store::build(sources, sky.width, sky.height, 8);
    let truth: Vec<(f64, f64)> = sky.sources.iter().map(|s| s.pos).collect();
    let matches = cross_match_catalog(&store, &truth, 3.0);
    let hit = matches.iter().filter(|m| m.is_some()).count();
    assert!(hit as f64 > 0.95 * truth.len() as f64, "{hit}/{} matched", truth.len());
    for m in matches.into_iter().flatten() {
        assert!(m.dist <= 3.0 * 2.0 + 1e-12);
    }
}

#[test]
fn snapshot_roundtrips_through_disk_and_store() {
    let snap = synthetic_snapshot(600, 9);
    let dir = std::env::temp_dir().join("celeste-serve-test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("roundtrip.json");
    let store = Store::build(snap.sources.clone(), snap.width, snap.height, 4);
    serve::snapshot::save(&path, &store).unwrap();
    let loaded = serve::snapshot::load(&path).unwrap();
    assert_eq!(loaded.width, snap.width);
    assert_eq!(loaded.height, snap.height);
    let mut want = snap.sources;
    want.sort_by_key(|s| s.id);
    assert_eq!(loaded.sources, want, "snapshot must round-trip losslessly");
    // and the rebuilt store answers identically
    let store2 = loaded.into_store(9);
    let q = Query::BrightestN { n: 50, filter: SourceFilter::Any };
    assert_eq!(execute(&store2, &q), execute_scan(&want, &q));
    std::fs::remove_file(&path).ok();
}

#[test]
fn server_returns_exactly_direct_execution_results() {
    let snap = synthetic_snapshot(2000, 31);
    let (w, h) = (snap.width, snap.height);
    let store = Arc::new(Store::build(snap.sources, w, h, 8));
    let flat = store.all_sources();
    let server = Server::start(
        Arc::clone(&store),
        ServerConfig { threads: 4, queue_depth: 256, ..Default::default() },
    );
    let mut rng = Rng::new(2);
    let mut served = 0;
    for i in 0..150 {
        let q = if i % 2 == 0 {
            Query::Cone {
                center: (rng.uniform_in(0.0, w), rng.uniform_in(0.0, h)),
                radius: rng.uniform_in(2.0, 120.0),
                filter: SourceFilter::Any,
            }
        } else {
            Query::CrossMatch {
                pos: (rng.uniform_in(0.0, w), rng.uniform_in(0.0, h)),
                radius: 4.0,
            }
        };
        let got = server.call(q.clone()).expect("closed-loop call must not shed");
        assert_eq!(got, execute_scan(&flat, &q), "query {i}");
        served += 1;
    }
    let report = server.shutdown();
    assert_eq!(report.executed, served);
    assert_eq!(report.shed, 0);
    let all = report.latency_all();
    assert_eq!(all.n, served);
    assert!(all.p50() <= all.p99() + 1e-15);
    assert!(all.p99() <= all.max + 1e-15);
}

#[test]
fn hilbert_roundtrip_property() {
    forall_with(
        400,
        71,
        |rng: &mut Rng| {
            let order = 1 + rng.below(16) as u32;
            let n = 1u64 << order;
            (order, rng.below(n) as u32, rng.below(n) as u32)
        },
        |&(order, x, y)| {
            let d = hilbert_xy2d(order, x, y);
            d < (1u64 << (2 * order)) && hilbert_d2xy(order, d) == (x, y)
        },
    );
}

#[test]
fn hilbert_adjacency_property() {
    // consecutive curve positions are Manhattan-adjacent cells, at any
    // order and anywhere along the curve
    forall_with(
        300,
        73,
        |rng: &mut Rng| {
            let order = 2 + rng.below(12) as u32;
            let max_d = 1u64 << (2 * order);
            (order, rng.below(max_d - 1))
        },
        |&(order, d)| {
            let (x0, y0) = hilbert_d2xy(order, d);
            let (x1, y1) = hilbert_d2xy(order, d + 1);
            (x1 as i64 - x0 as i64).abs() + (y1 as i64 - y0 as i64).abs() == 1
        },
    );
}

#[test]
fn hilbert_sky_key_respects_extent() {
    forall_with(
        300,
        79,
        |rng: &mut Rng| {
            let w = rng.uniform_in(10.0, 5000.0);
            let h = rng.uniform_in(10.0, 5000.0);
            // include out-of-extent positions: keys must still clamp
            let x = rng.uniform_in(-100.0, w + 100.0);
            let y = rng.uniform_in(-100.0, h + 100.0);
            (w, h, x, y)
        },
        |&(w, h, x, y)| {
            let k = hilbert_sky_key((x, y), w, h);
            k < (1u64 << 32)
        },
    );
}

/// Every query class through the distributed router, over any
/// placement / replication / routing policy, must equal the single-host
/// `Store` answer byte-for-byte (the distributed tier is a deployment
/// choice, never a semantics change).
#[test]
fn dist_router_matches_single_host_store_over_any_placement() {
    let snap = synthetic_snapshot(2500, 41);
    let (w, h) = (snap.width, snap.height);
    let store = Arc::new(Store::build(snap.sources, w, h, 10));
    let filters = [SourceFilter::Any, SourceFilter::StarsOnly, SourceFilter::GalaxiesOnly];
    for (nodes, replicas, routing) in [
        (1usize, 1usize, Routing::Random),
        (2, 1, Routing::RoundRobin),
        (4, 2, Routing::PowerOfTwo),
        (6, 3, Routing::Random),
        (8, 3, Routing::RoundRobin),
        (5, 9, Routing::PowerOfTwo), // replication clamps to 5
    ] {
        let mut router = Router::new(
            Arc::clone(&store),
            nodes,
            replicas,
            RouterConfig { routing, seed: 1000 + nodes as u64, ..Default::default() },
        );
        let mut rng = Rng::new(nodes as u64 * 31 + replicas as u64);
        let mut now = 0.0f64;
        for i in 0..48usize {
            let filter = filters[i % 3];
            let q = match i % 4 {
                0 => Query::Cone {
                    center: (rng.uniform_in(-40.0, w + 40.0), rng.uniform_in(-40.0, h + 40.0)),
                    radius: rng.uniform_in(1.0, 260.0),
                    filter,
                },
                1 => {
                    let ax = rng.uniform_in(0.0, w);
                    let ay = rng.uniform_in(0.0, h);
                    let bx = rng.uniform_in(0.0, w);
                    let by = rng.uniform_in(0.0, h);
                    Query::BoxSearch {
                        x0: ax.min(bx),
                        y0: ay.min(by),
                        x1: ax.max(bx),
                        y1: ay.max(by),
                        filter,
                    }
                }
                2 => Query::BrightestN { n: rng.below(150) as usize, filter },
                _ => Query::CrossMatch {
                    pos: (rng.uniform_in(0.0, w), rng.uniform_in(0.0, h)),
                    radius: rng.uniform_in(0.3, 9.0),
                },
            };
            let (res, done) = router.execute(now, &q);
            assert!(done >= now);
            assert_eq!(
                res.expect("no failures scheduled"),
                execute(&store, &q),
                "nodes={nodes} replicas={replicas} {routing:?} query {i}: {q:?}"
            );
            now += 5e-5;
        }
        assert_eq!(router.failed, 0);
    }
}

/// Acceptance (a): power-of-two-choices routing beats random on p99
/// under the hotspot mix at equal offered load. Same catalog, same
/// placement, same deterministic query stream — only the replica
/// selection policy differs.
#[test]
fn p2c_beats_random_p99_under_hotspot_load() {
    fn run(routing: Routing) -> (f64, u64) {
        let snap = synthetic_snapshot(3000, 99);
        let (w, h) = (snap.width, snap.height);
        let store = Arc::new(Store::build(snap.sources, w, h, 12));
        let router = Router::new(
            store,
            6,
            3,
            RouterConfig { routing, seed: 4242, ..Default::default() },
        );
        let engine = RouterEngine::new(router);
        let cfg = LoadGenConfig::scenario("hotspot", 4242).unwrap();
        let mut gen = LoadGen::new(cfg, w, h);
        let mut clock = SimClock::new();
        let rep = drive_open_loop(&engine, &mut clock, &mut gen, 50_000.0, 0.3);
        assert_eq!(rep.failed, 0);
        (rep.latency_all().p99(), rep.completed)
    }
    let (random_p99, n_random) = run(Routing::Random);
    let (p2c_p99, n_p2c) = run(Routing::PowerOfTwo);
    assert_eq!(n_random, n_p2c, "equal offered load means equal query streams");
    assert!(n_random > 5_000, "load generator produced too few queries: {n_random}");
    assert!(
        p2c_p99 < random_p99,
        "p2c p99 {:.3}ms must beat random p99 {:.3}ms at equal load",
        p2c_p99 * 1e3,
        random_p99 * 1e3
    );
}

/// Acceptance (b): killing one replica of a 3-replica range mid-run
/// completes with zero failed queries, records failover latency, and
/// keeps answers byte-identical to the single-host store.
#[test]
fn killed_replica_of_three_fails_over_with_zero_failed_queries() {
    let snap = synthetic_snapshot(2000, 55);
    let (w, h) = (snap.width, snap.height);
    let store = Arc::new(Store::build(snap.sources, w, h, 12));
    let mut router = Router::new(
        Arc::clone(&store),
        6,
        3,
        RouterConfig { routing: Routing::PowerOfTwo, seed: 7, ..Default::default() },
    );
    // kill a node guaranteed to host replicas (and not the front-end's
    // own node), a third of the way in
    let victim = *router
        .placement
        .replicas_of(0)
        .iter()
        .find(|&&n| n != 0)
        .expect("3 distinct replicas include a non-origin node");
    router = router
        .with_schedule(FailureSchedule::parse(&format!("{victim}@0.1")).unwrap());
    let engine = RouterEngine::new(router);
    let cfg = LoadGenConfig::scenario("hotspot", 7).unwrap();
    let mut gen = LoadGen::new(cfg, w, h);
    let mut clock = SimClock::new();
    let drive = drive_open_loop(&engine, &mut clock, &mut gen, 10_000.0, 0.3);
    let rep = engine.dist_report(&drive);
    assert_eq!(rep.failed, 0, "3-way replication must absorb one node kill");
    assert_eq!(rep.completed, rep.offered);
    assert!(rep.failover.n >= 1, "the dead replica was never discovered");
    assert!(rep.failover.mean() > 0.0 && !rep.failover.mean().is_nan());
    assert!(rep.failover.max >= rep.failover.mean());
    // parity survives the kill (through the engine API)
    let q = Query::BrightestN { n: 25, filter: SourceFilter::Any };
    let resp = engine.call(Request::new(q.clone()).arriving_at(1.0));
    assert_eq!(resp.result.expect("survivors answer"), execute(&store, &q));
}

/// Golden stability of `Query::cache_key`: router-tier caching makes
/// these keys cross-node-visible, so silent algorithm drift would
/// invalidate (or worse, cross-wire) every warm cache in a
/// mixed-version fleet. Expected values were computed independently
/// (FNV-1a over the exact parameter bits).
#[test]
fn cache_key_golden_values_are_stable() {
    let cases: [(Query, u64); 4] = [
        (
            Query::Cone { center: (1.5, 2.5), radius: 3.25, filter: SourceFilter::Any },
            0x2e7f_6cae_a7dc_7eec,
        ),
        (
            Query::BoxSearch {
                x0: 0.0,
                y0: 0.25,
                x1: 100.5,
                y1: 200.75,
                filter: SourceFilter::StarsOnly,
            },
            0x0384_6c60_0580_fbfc,
        ),
        (
            Query::BrightestN { n: 17, filter: SourceFilter::GalaxiesOnly },
            0xe1c3_9518_70cb_e261,
        ),
        (
            Query::CrossMatch { pos: (7.5, 8.25), radius: 2.5 },
            0x5758_465e_44f7_21b1,
        ),
    ];
    for (q, want) in cases {
        assert_eq!(q.cache_key(), want, "cache_key drifted for {q:?}");
    }
}

/// Distinct queries must get distinct 64-bit keys across a structured
/// parameter sweep plus a generated hotspot stream (repeats are
/// expected there and must map to the repeated key, never a fresh one).
#[test]
fn cache_keys_distinct_across_a_query_sweep() {
    use std::collections::{HashMap, HashSet};
    let filters = [SourceFilter::Any, SourceFilter::StarsOnly, SourceFilter::GalaxiesOnly];
    let mut queries: Vec<Query> = Vec::new();
    for &filter in &filters {
        for i in 0..10 {
            for j in 0..10 {
                let (x, y) = (i as f64 * 37.5, j as f64 * 21.25);
                queries.push(Query::Cone {
                    center: (x, y),
                    radius: 1.0 + i as f64 + j as f64 * 0.5,
                    filter,
                });
                queries.push(Query::BoxSearch {
                    x0: x,
                    y0: y,
                    x1: x + 10.0 + i as f64,
                    y1: y + 5.0 + j as f64,
                    filter,
                });
                queries.push(Query::CrossMatch {
                    pos: (x, y),
                    radius: 0.5 + 0.25 * (i + 10 * j) as f64,
                });
            }
        }
        for n in 0..200 {
            queries.push(Query::BrightestN { n, filter });
        }
    }
    let mut gen =
        LoadGen::new(LoadGenConfig::scenario("hotspot", 12).unwrap(), 800.0, 600.0);
    for _ in 0..3000 {
        queries.push(gen.next_query());
    }
    let mut by_key: HashMap<u64, Query> = HashMap::new();
    let mut distinct: HashSet<String> = HashSet::new();
    for q in queries {
        distinct.insert(format!("{q:?}"));
        let key = q.cache_key();
        if let Some(prev) = by_key.get(&key) {
            assert_eq!(*prev, q, "64-bit key collision between distinct queries");
        } else {
            by_key.insert(key, q);
        }
    }
    assert_eq!(by_key.len(), distinct.len(), "distinct queries must get distinct keys");
    assert!(by_key.len() > 1000, "sweep too small: {}", by_key.len());
}

#[test]
fn query_results_are_canonically_ordered() {
    let snap = synthetic_snapshot(1000, 17);
    let store = Store::build(snap.sources, snap.width, snap.height, 8);
    match execute(
        &store,
        &Query::Cone {
            center: (snap_center(&store), snap_center2(&store)),
            radius: 500.0,
            filter: SourceFilter::Any,
        },
    ) {
        QueryResult::Sources(v) => {
            assert!(!v.is_empty());
            for w in v.windows(2) {
                assert!(w[0].id < w[1].id, "cone results must be id-ascending");
            }
        }
        _ => unreachable!(),
    }
    match execute(&store, &Query::BrightestN { n: 200, filter: SourceFilter::Any }) {
        QueryResult::Sources(v) => {
            assert_eq!(v.len(), 200);
            for w in v.windows(2) {
                assert!(
                    w[0].flux_r > w[1].flux_r
                        || (w[0].flux_r == w[1].flux_r && w[0].id < w[1].id),
                    "brightest results must be flux-desc, id-asc on ties"
                );
            }
        }
        _ => unreachable!(),
    }
}

fn snap_center(store: &Store) -> f64 {
    store.width / 2.0
}

fn snap_center2(store: &Store) -> f64 {
    store.height / 2.0
}
