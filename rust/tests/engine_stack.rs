//! Engine-API invariants: any tier behind any middleware stack, in any
//! order, returns byte-identical results to direct `query::execute`;
//! cached responses equal uncached ones; deadlines and admission behave
//! uniformly; and hedged requests measurably improve the p999 tail over
//! p2c-alone under the hotspot mix at equal offered load (the ROADMAP's
//! speculative-requests acceptance).

use std::sync::Arc;

use celeste::prng::Rng;
use celeste::serve::dist::{Router, RouterConfig, Routing};
use celeste::serve::{
    self, drive_open_loop, execute, fuzz_query, layered, metric, Admission, Cached, DirectEngine,
    Hedged, LayerSpec, LoadGen, LoadGenConfig, Outcome, Query, QueryEngine, Request,
    RouterEngine, ScanEngine, SchedConfig, SchedKind, Server, ServerConfig, ServerEngine,
    SimClock, SourceFilter, Store,
};

fn test_store(n: usize, shards: usize, seed: u64) -> Arc<Store> {
    let snap = serve::snapshot::synthetic(n, seed);
    Arc::new(Store::build(snap.sources, snap.width, snap.height, shards))
}

/// Acceptance: for any query, the layered engine stack — any tier, any
/// middleware order — returns byte-identical `QueryResult`s to direct
/// `query::execute`, and the repeated (cache-served) request returns
/// the identical result again.
#[test]
fn layered_stacks_match_direct_execution_across_tiers_and_orders() {
    let store = test_store(1500, 8, 61);
    let (w, h) = (store.width, store.height);
    let flat = store.all_sources();

    for tier_id in 0..4usize {
        for arrangement in 0..4usize {
            // arrangements alternate the server's request scheduler so
            // the middleware matrix also covers the work-stealing
            // batched pool behind the same engine seam
            let sched = if arrangement % 2 == 0 {
                SchedConfig::default()
            } else {
                SchedConfig { kind: SchedKind::Steal, batch: 4 }
            };
            let server = Arc::new(Server::start(
                Arc::clone(&store),
                ServerConfig { threads: 2, sched, ..Default::default() },
            ));
            let base: Box<dyn QueryEngine> = match tier_id {
                0 => Box::new(ScanEngine::new(flat.clone())),
                1 => Box::new(DirectEngine::new(Arc::clone(&store))),
                2 => Box::new(ServerEngine::new(Arc::clone(&server))),
                _ => Box::new(RouterEngine::new(Router::new(
                    Arc::clone(&store),
                    4,
                    2,
                    RouterConfig::default(),
                ))),
            };
            // a 1 us hedge budget fires constantly on the router tier,
            // so the hedge path itself is parity-tested
            let engine: Box<dyn QueryEngine> = match arrangement {
                0 => base,
                1 => Box::new(Cached::new(Hedged::new(base, 1e-6), 64)),
                2 => Box::new(Hedged::new(Cached::new(base, 64), 1e-6)),
                _ => Box::new(Admission::new(
                    Cached::new(Hedged::new(base, 1e-6), 64),
                    1 << 20,
                )),
            };
            let mut rng = Rng::new(7 + tier_id as u64 * 13 + arrangement as u64);
            let mut now = 0.0f64;
            for i in 0..40usize {
                let q = fuzz_query(&mut rng, w, h, i);
                let want = execute(&store, &q);
                for repeat in 0..2 {
                    let resp = engine.call(Request::new(q.clone()).arriving_at(now));
                    assert_eq!(
                        resp.trace.outcome,
                        Outcome::Served,
                        "tier {tier_id} arrangement {arrangement} query {i} repeat {repeat}"
                    );
                    assert_eq!(
                        resp.result.as_ref().expect("served"),
                        &want,
                        "tier {tier_id} arrangement {arrangement} query {i} repeat {repeat}: {q:?}"
                    );
                    now += 1e-4;
                }
            }
            let _ = server.shutdown();
        }
    }
}

#[test]
fn fresh_requests_bypass_the_cache_but_match() {
    let store = test_store(800, 6, 17);
    let engine = Cached::new(DirectEngine::new(Arc::clone(&store)), 32);
    let q = Query::BrightestN { n: 12, filter: SourceFilter::Any };
    let want = execute(&store, &q);
    let a = engine.call(Request::new(q.clone()));
    assert!(!a.trace.cache_hit);
    let b = engine.call(Request::new(q.clone()));
    assert!(b.trace.cache_hit, "second identical request must hit");
    let c = engine.call(Request::new(q.clone()).fresh());
    assert!(!c.trace.cache_hit, "fresh must bypass the cache probe");
    for r in [a, b, c] {
        assert_eq!(r.result.expect("served"), want, "cached == uncached == fresh");
    }
    assert_eq!(engine.hits(), 1);
    assert_eq!(engine.misses(), 2);
}

#[test]
fn deadlines_drop_late_results_uniformly() {
    let store = test_store(600, 4, 23);
    let engine =
        RouterEngine::new(Router::new(Arc::clone(&store), 2, 1, RouterConfig::default()));
    let q = Query::BrightestN { n: 5, filter: SourceFilter::Any };
    // shard service takes at least the cost model's base time, so a
    // 1 ns budget is always exceeded in simulated time
    let late = engine.call(Request::new(q.clone()).with_deadline(1e-9));
    assert_eq!(late.trace.outcome, Outcome::DeadlineExceeded);
    assert!(late.result.is_none(), "late results must be dropped");
    // a generous budget passes through untouched
    let ok = engine.call(Request::new(q.clone()).arriving_at(1.0).with_deadline(10.0));
    assert_eq!(ok.trace.outcome, Outcome::Served);
    assert_eq!(ok.result.unwrap(), execute(&store, &q));
}

#[test]
fn admission_sheds_on_simulated_backlog_and_drains() {
    let store = test_store(500, 4, 29);
    let tier =
        RouterEngine::new(Router::new(Arc::clone(&store), 2, 2, RouterConfig::default()));
    let engine = Admission::new(tier, 2);
    let q = Query::BrightestN { n: 3, filter: SourceFilter::Any };
    // two requests at t=0 fill the in-flight bound (their completions
    // lie in the simulated future); the third sheds
    let r1 = engine.call(Request::new(q.clone()));
    let r2 = engine.call(Request::new(q.clone()));
    assert_eq!(r1.trace.outcome, Outcome::Served);
    assert_eq!(r2.trace.outcome, Outcome::Served);
    let r3 = engine.call(Request::new(q.clone()));
    assert_eq!(r3.trace.outcome, Outcome::Shed);
    assert!(r3.result.is_none());
    assert_eq!(engine.shed(), 1);
    // far in the future the backlog has drained
    let r4 = engine.call(Request::new(q.clone()).arriving_at(1e6));
    assert_eq!(r4.trace.outcome, Outcome::Served);
    assert_eq!(r4.result.unwrap(), execute(&store, &q));
}

#[test]
fn describe_echoes_the_layer_stack_outermost_first() {
    let store = test_store(300, 4, 31);
    let spec = LayerSpec {
        admit_depth: 256,
        cache_entries: 128,
        hedge_budget: 2e-4,
        ..Default::default()
    };
    let engine = layered(Box::new(DirectEngine::new(Arc::clone(&store))), &spec);
    let desc = engine.describe();
    assert!(desc.starts_with("admit(256)"), "{desc}");
    let admit_pos = desc.find("admit").unwrap();
    let cache_pos = desc.find("cached").unwrap();
    let hedge_pos = desc.find("hedged").unwrap();
    let tier_pos = desc.find("direct").unwrap();
    assert!(
        admit_pos < cache_pos && cache_pos < hedge_pos && hedge_pos < tier_pos,
        "layer order wrong: {desc}"
    );
}

/// Satellite acceptance: the hedge-rate budget caps the fraction of
/// requests that may hedge. With a zero-latency budget every stamped
/// request hedges, so the stamped count is the hedged-request count:
/// uncapped stamps everything, a 5% cap stamps at most 5% (+1 for the
/// grant rounding) and counts every skip.
#[test]
fn hedge_budget_caps_the_hedged_fraction() {
    let store = test_store(2000, 10, 77);
    let (w, h) = (store.width, store.height);
    let run = |cap: f64| {
        let router = Router::new(
            Arc::clone(&store),
            6,
            3,
            RouterConfig { routing: Routing::PowerOfTwo, seed: 4242, ..Default::default() },
        );
        // zero budget: every stamped request fires hedges
        let engine = Hedged::with_cap(RouterEngine::new(router), 0.0, cap);
        let cfg = LoadGenConfig::scenario("hotspot", 4242).unwrap();
        let mut gen = LoadGen::new(cfg, w, h);
        let mut clock = SimClock::new();
        let drive = drive_open_loop(&engine, &mut clock, &mut gen, 20_000.0, 0.2);
        (drive, engine)
    };
    let (base_drive, base_engine) = run(0.0); // cap <= 0 disables the cap
    assert!(base_drive.offered > 1_000, "offered {}", base_drive.offered);
    assert_eq!(base_engine.budget_skipped(), 0, "uncapped must never skip");
    assert_eq!(base_engine.stamped_requests(), base_drive.offered);
    assert!(base_drive.hedges > 0);

    let (cap_drive, cap_engine) = run(0.05);
    assert_eq!(cap_drive.offered, base_drive.offered, "equal offered load");
    let stamped = cap_engine.stamped_requests();
    assert!(
        stamped as f64 <= 0.05 * cap_drive.offered as f64 + 1.0,
        "cap 5%: stamped {stamped} of {}",
        cap_drive.offered
    );
    assert!(stamped > 0, "the cap must still grant some hedges");
    assert_eq!(
        cap_engine.budget_skipped(),
        cap_drive.offered - stamped,
        "every unstamped request is a counted skip"
    );
    assert!(
        cap_drive.hedges < base_drive.hedges,
        "capped hedges {} must be fewer than uncapped {}",
        cap_drive.hedges,
        base_drive.hedges
    );
    assert_eq!(
        metric(&cap_engine, "hedge_budget_skipped"),
        Some(cap_engine.budget_skipped() as f64),
        "the skip count must surface through the metrics API"
    );
    assert!(cap_engine.describe().contains("cap 5%"), "{}", cap_engine.describe());
}

/// Acceptance: hedged requests measurably improve p999 over p2c-alone
/// under the hotspot mix at equal offered load. The budget is tuned
/// from the unhedged run's own latency quantiles, exactly how a real
/// deployment tunes a hedge; the best candidate must beat the unhedged
/// tail. (`bench_serve` runs the same comparison and records it in
/// `BENCH_serve.json`.)
#[test]
fn hedged_improves_p999_over_p2c_alone_under_hotspot() {
    let store = test_store(3000, 12, 99);
    let (w, h) = (store.width, store.height);
    let run = |budget: Option<f64>| {
        let router = Router::new(
            Arc::clone(&store),
            6,
            3,
            RouterConfig { routing: Routing::PowerOfTwo, seed: 4242, ..Default::default() },
        );
        let tier = RouterEngine::new(router);
        let cfg = LoadGenConfig::scenario("hotspot", 4242).unwrap();
        let mut gen = LoadGen::new(cfg, w, h);
        let mut clock = SimClock::new();
        match budget {
            Some(b) => {
                let engine = Hedged::new(tier, b);
                drive_open_loop(&engine, &mut clock, &mut gen, 50_000.0, 0.3)
            }
            None => drive_open_loop(&tier, &mut clock, &mut gen, 50_000.0, 0.3),
        }
    };
    let base = run(None);
    assert_eq!(base.failed, 0);
    assert_eq!(base.hedges, 0, "no hedge layer, no hedges");
    assert!(base.offered > 5_000, "too few queries: {}", base.offered);
    let base_p999 = base.latency_all().quantile(0.999);
    assert!(base_p999 > 0.0);
    let budgets = base.latency_all().quantiles(&[0.90, 0.95, 0.99]);
    let mut best = f64::INFINITY;
    let mut fired_total = 0u64;
    let mut wins_total = 0u64;
    for &b in &budgets {
        if b <= 0.0 {
            continue;
        }
        let hedged = run(Some(b));
        assert_eq!(hedged.offered, base.offered, "equal offered load means equal streams");
        assert_eq!(hedged.failed, 0);
        fired_total += hedged.hedges;
        wins_total += hedged.hedge_wins;
        best = best.min(hedged.latency_all().quantile(0.999));
    }
    assert!(fired_total > 0, "no hedges fired at any candidate budget");
    assert!(wins_total > 0, "hedges never beat the primary replica");
    assert!(
        best < base_p999,
        "hedging must clip the p999 tail: best hedged {:.3}ms vs p2c-alone {:.3}ms",
        best * 1e3,
        base_p999 * 1e3
    );
}
