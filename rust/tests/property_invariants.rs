//! Property-based tests over coordinator-layer invariants (routing,
//! batching, state) plus numeric substrates — quickcheck-lite in place of
//! proptest (offline registry).

use celeste::catalog::{Catalog, CatalogEntry};
use celeste::cluster::workload::{synthetic_workload, CostModel};
use celeste::cluster::{simulate, ClusterConfig};
use celeste::dtree::{Dtree, DtreeConfig};
use celeste::ga::LruCache;
use celeste::jsonlite;
use celeste::linalg::{norm2, solve_trust_region, Mat};
use celeste::model::GalaxyShape;
use celeste::prng::Rng;
use celeste::quickcheck::forall_with;

/// Dtree invariant: any request interleaving issues every task exactly
/// once, and every grant is non-empty until global exhaustion.
#[test]
fn dtree_any_interleaving_is_exact_cover() {
    forall_with(
        60,
        41,
        |rng: &mut Rng| {
            let nprocs = 1 + rng.below(64) as usize;
            let total = rng.below(3000) as usize;
            let order: Vec<usize> = (0..4 * total + 8)
                .map(|_| rng.below(nprocs as u64) as usize)
                .collect();
            (nprocs, total, order)
        },
        |(nprocs, total, order)| {
            let mut dt = Dtree::new(DtreeConfig::default(), *nprocs, *total);
            let mut seen = vec![false; *total];
            // random interleaving ...
            for &p in order {
                if let Some(g) = dt.request(p) {
                    if g.range.is_empty() {
                        return false;
                    }
                    for i in g.range.first..g.range.last {
                        if seen[i] {
                            return false; // double issue
                        }
                        seen[i] = true;
                    }
                }
            }
            // ... then drain deterministically
            loop {
                let mut any = false;
                for p in 0..*nprocs {
                    if let Some(g) = dt.request(p) {
                        any = true;
                        for i in g.range.first..g.range.last {
                            if seen[i] {
                                return false;
                            }
                            seen[i] = true;
                        }
                    }
                }
                if !any {
                    break;
                }
            }
            seen.iter().all(|&s| s) && dt.remaining() == 0
        },
    );
}

/// LRU invariant: used bytes never exceed capacity (given any op stream)
/// once more than one entry exists, and hits+misses == probes.
#[test]
fn lru_capacity_invariant() {
    forall_with(
        80,
        43,
        |rng: &mut Rng| {
            let cap = 10.0 + rng.uniform() * 500.0;
            let ops: Vec<(u64, f64)> = (0..rng.below(300))
                .map(|_| (rng.below(40), 1.0 + rng.uniform() * 80.0))
                .collect();
            (cap, ops)
        },
        |(cap, ops)| {
            let mut c = LruCache::new(*cap);
            let mut probes = 0;
            for (k, b) in ops {
                probes += 1;
                c.contains(*k);
                c.insert(*k, *b);
                if c.len() > 1 && c.used_bytes() > *cap + 1e-9 {
                    return false;
                }
            }
            c.hits + c.misses == probes
        },
    );
}

/// Trust-region invariant: the step never exceeds the radius and always
/// has non-negative predicted reduction, for arbitrary symmetric H.
#[test]
fn trust_region_step_invariants() {
    forall_with(
        150,
        47,
        |rng: &mut Rng| {
            let n = 1 + rng.below(12) as usize;
            let mut h = Mat::zeros(n, n);
            for i in 0..n {
                for j in 0..=i {
                    let v = rng.normal() * 10f64.powf(rng.uniform_in(-2.0, 2.0));
                    h[(i, j)] = v;
                    h[(j, i)] = v;
                }
            }
            let g: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let delta = 10f64.powf(rng.uniform_in(-3.0, 2.0));
            (h, g, delta)
        },
        |(h, g, delta)| {
            let sol = solve_trust_region(h, g, *delta);
            let within = norm2(&sol.step) <= delta * (1.0 + 1e-6);
            let descent = sol.predicted_reduction >= -1e-12;
            let finite = sol.step.iter().all(|s| s.is_finite());
            within && descent && finite
        },
    );
}

/// Catalog invariant: neighbor queries are symmetric (if a sees b within
/// r, b sees a) and exclude self.
#[test]
fn catalog_neighbor_symmetry() {
    forall_with(
        30,
        53,
        |rng: &mut Rng| {
            let n = 2 + rng.below(120) as usize;
            let entries: Vec<CatalogEntry> = (0..n)
                .map(|i| CatalogEntry {
                    id: i,
                    pos: (rng.uniform_in(0.0, 500.0), rng.uniform_in(0.0, 500.0)),
                    p_gal: 0.5,
                    flux_r: 100.0,
                    colors: [0.0; 4],
                    shape: GalaxyShape::point_like(),
                })
                .collect();
            (entries, 5.0 + rng.uniform() * 60.0)
        },
        |(entries, radius)| {
            let cat = Catalog::new(entries.clone(), 500.0, 500.0);
            for i in 0..cat.len().min(40) {
                let nb = cat.neighbors_within(cat.entries[i].pos, *radius, i);
                if nb.contains(&i) {
                    return false;
                }
                for &j in &nb {
                    let back = cat.neighbors_within(cat.entries[j].pos, *radius, j);
                    if !back.contains(&i) {
                        return false;
                    }
                }
            }
            true
        },
    );
}

/// Simulator invariant: every task is executed exactly once and the
/// makespan is at least the critical-path lower bound, for arbitrary
/// topologies.
#[test]
fn simulator_conservation_and_bounds() {
    forall_with(
        25,
        59,
        |rng: &mut Rng| {
            let nodes = 1 + rng.below(8) as usize;
            let ppn = 1 + rng.below(4) as usize;
            let tpp = 1 + rng.below(4) as usize;
            let tasks = 1 + rng.below(400) as usize;
            (nodes, ppn, tpp, tasks)
        },
        |&(nodes, ppn, tpp, tasks)| {
            let w = synthetic_workload(tasks, 8, 2, &CostModel::Fixed(1.0), 1e6, 9);
            let cfg = ClusterConfig {
                nodes,
                procs_per_node: ppn,
                threads_per_proc: tpp,
                gc: None,
                ..Default::default()
            };
            let r = simulate(&cfg, &w);
            let threads = (nodes * ppn * tpp) as f64;
            let lower = w.total_cost() / threads;
            r.task_stats.n == tasks as u64
                && r.makespan + 1e-9 >= lower
                && r.breakdown.get(celeste::metrics::Component::Optimize) - w.total_cost() < 1e-6
        },
    );
}

/// JSON round-trip: parse(to_string(v)) == v for arbitrary values built
/// from primitives.
#[test]
fn json_roundtrip_property() {
    forall_with(
        200,
        61,
        |rng: &mut Rng| {
            fn gen(rng: &mut Rng, depth: usize) -> jsonlite::Value {
                use jsonlite::Value::*;
                match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                    0 => Null,
                    1 => Bool(rng.uniform() < 0.5),
                    2 => Num((rng.normal() * 100.0 * 8.0).round() / 8.0),
                    3 => Str(format!("s{}-\"q\"\n", rng.below(1000))),
                    4 => Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
                    _ => {
                        let mut m = std::collections::BTreeMap::new();
                        for i in 0..rng.below(5) {
                            m.insert(format!("k{i}"), gen(rng, depth - 1));
                        }
                        Obj(m)
                    }
                }
            }
            gen(rng, 3)
        },
        |v| {
            let s = jsonlite::to_string(v);
            jsonlite::parse(&s).map(|w| w == *v).unwrap_or(false)
        },
    );
}
