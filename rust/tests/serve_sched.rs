//! Scheduler invariants, pinned across the whole matrix:
//!
//! * any scheduler (condvar | steal) × any batch size × any middleware
//!   order over `ServerEngine` returns byte-identical results to direct
//!   `query::execute` — including while ingestion publishes epochs
//!   under the pool (the `--mix drift` shape);
//! * shutdown in steal mode under concurrent load *drains*: every
//!   accepted request executes, no worker deadlocks (a watchdog aborts
//!   the process if shutdown wedges — the Condvar-era bug class this
//!   refactor must not reintroduce);
//! * batch-aware admission sheds identically across schedulers;
//! * the drive/server reports carry coherent scheduler counters.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use celeste::prng::Rng;
use celeste::serve::{
    self, execute, fuzz_query, plan_shards, Admission, Cached, DriftConfig, DriftGen, Hedged,
    Ingestor, LoadGen, LoadGenConfig, NetRouterEngine, Outcome, Query, QueryEngine, Request,
    SchedConfig, SchedKind, Server, ServerConfig, ServerEngine, ShardServer, SourceFilter, Store,
    VersionedStore,
};

fn test_store(n: usize, shards: usize, seed: u64) -> Arc<Store> {
    let snap = serve::snapshot::synthetic(n, seed);
    Arc::new(Store::build(snap.sources, snap.width, snap.height, shards))
}

/// Acceptance: scheduler × batch × middleware order is byte-identical
/// to `query::execute` (the serve-path contract the whole stack pins).
#[test]
fn sched_matrix_matches_direct_execution_across_middleware_orders() {
    let store = test_store(1500, 8, 71);
    let (w, h) = (store.width, store.height);
    let kinds = [SchedKind::Condvar, SchedKind::Steal];
    for (ki, &kind) in kinds.iter().enumerate() {
        for batch in [1usize, 7] {
            for arrangement in 0..3usize {
                let server = Arc::new(Server::start(
                    Arc::clone(&store),
                    ServerConfig {
                        threads: 3,
                        sched: SchedConfig { kind, batch },
                        ..Default::default()
                    },
                ));
                let base: Box<dyn QueryEngine> = Box::new(ServerEngine::new(Arc::clone(&server)));
                let engine: Box<dyn QueryEngine> = match arrangement {
                    0 => base,
                    1 => Box::new(Cached::new(Hedged::new(base, 1e-6), 64)),
                    _ => Box::new(Admission::new(
                        Hedged::new(Cached::new(base, 64), 1e-6),
                        1 << 20,
                    )),
                };
                let mut rng = Rng::new(5 + ki as u64 * 31 + batch as u64 + arrangement as u64);
                for i in 0..32usize {
                    let q = fuzz_query(&mut rng, w, h, i);
                    let want = execute(&store, &q);
                    for repeat in 0..2 {
                        let resp = engine.call(Request::new(q.clone()));
                        assert_eq!(
                            resp.trace.outcome,
                            Outcome::Served,
                            "{kind:?} batch {batch} arrangement {arrangement} query {i} repeat {repeat}"
                        );
                        assert_eq!(
                            resp.result.as_ref().expect("served"),
                            &want,
                            "{kind:?} batch {batch} arrangement {arrangement} query {i}: {q:?}"
                        );
                    }
                }
                let report = server.shutdown();
                assert_eq!(report.executed, report.accepted, "{kind:?}: drain on shutdown");
                assert_eq!(report.local_hits + report.steals, report.executed);
                if kind == SchedKind::Condvar {
                    assert_eq!(report.steals, 0, "condvar never steals");
                }
            }
        }
    }
}

/// Acceptance: steal-mode batched parity holds *during ingestion* — a
/// live versioned store publishing drift epochs between calls (the
/// `--mix drift` shape) still answers byte-identically to a direct
/// execute over the epoch current at submit time.
#[test]
fn steal_parity_holds_under_ingestion() {
    let store = test_store(1000, 6, 83);
    let (w, h) = (store.width, store.height);
    let vs = Arc::new(VersionedStore::new(Arc::clone(&store)));
    let server = Arc::new(Server::start_live(
        Arc::clone(&vs),
        ServerConfig {
            threads: 2,
            sched: SchedConfig { kind: SchedKind::Steal, batch: 5 },
            ..Default::default()
        },
    ));
    let engine = ServerEngine::new(Arc::clone(&server));
    let mut drift = DriftGen::new(
        &store.all_sources(),
        w,
        h,
        DriftConfig { batch: 24, seed: 99, ..Default::default() },
    );
    let mut ingestor = Ingestor::new(Arc::clone(&vs));
    let mut rng = Rng::new(17);
    for round in 0..12usize {
        // publish a drift epoch, then read against the new head
        let rep = ingestor.apply(&drift.next_batch());
        assert_eq!(rep.epoch, round as u64 + 1);
        let head = vs.load();
        for i in 0..6usize {
            let q = fuzz_query(&mut rng, w, h, round * 6 + i);
            let want = execute(&head.store, &q);
            let resp = engine.call(Request::new(q.clone()));
            assert_eq!(resp.trace.outcome, Outcome::Served, "round {round} query {i}");
            assert_eq!(resp.result.expect("served"), want, "round {round} query {i}: {q:?}");
        }
    }
    let report = server.shutdown();
    assert_eq!(report.executed, 72);
    assert_eq!(report.executed, report.accepted);
}

/// Satellite acceptance: dropping the server mid-load in steal mode
/// loses nothing — every accepted request is executed (drained, not
/// discarded) and every in-flight closed-loop caller gets an answer.
/// A watchdog aborts the process if shutdown wedges, so a deadlock is
/// a loud CI failure instead of a hung job.
#[test]
fn steal_shutdown_mid_load_drains_accepted_requests() {
    let store = test_store(2000, 8, 123);
    let (w, h) = (store.width, store.height);
    let server = Arc::new(Server::start(
        Arc::clone(&store),
        ServerConfig {
            threads: 4,
            // bounded: the post-shutdown drain is at most one queue's
            // worth of work, so the test stays fast on slow runners
            queue_depth: 1 << 16,
            sched: SchedConfig { kind: SchedKind::Steal, batch: 8 },
        },
    ));
    let done = Arc::new(AtomicBool::new(false));
    {
        let done = Arc::clone(&done);
        std::thread::spawn(move || {
            for _ in 0..600 {
                std::thread::sleep(Duration::from_millis(100));
                if done.load(Ordering::SeqCst) {
                    return;
                }
            }
            eprintln!("steal_shutdown_mid_load: shutdown deadlocked, aborting");
            std::process::abort();
        });
    }
    let stop = AtomicBool::new(false);
    let report = std::thread::scope(|scope| {
        // open-loop submitters hammering try_submit
        for c in 0..3u64 {
            let server = &server;
            let stop = &stop;
            scope.spawn(move || {
                let cfg = LoadGenConfig::scenario("hotspot", 1000 + c).unwrap();
                let mut gen = LoadGen::new(cfg, w, h);
                while !stop.load(Ordering::Relaxed) {
                    let _ = server.try_submit(gen.next_query());
                }
            });
        }
        // closed-loop callers that must never hang
        for c in 0..2u64 {
            let server = &server;
            let stop = &stop;
            scope.spawn(move || {
                let cfg = LoadGenConfig::scenario("uniform", 2000 + c).unwrap();
                let mut gen = LoadGen::new(cfg, w, h);
                while !stop.load(Ordering::Relaxed) {
                    // accepted => a result must arrive; shed => None
                    let _ = server.call(gen.next_query());
                }
            });
        }
        std::thread::sleep(Duration::from_millis(40));
        // shutdown races the submitters on purpose: mid-load drop
        let report = server.shutdown();
        stop.store(true, Ordering::SeqCst);
        report
    });
    done.store(true, Ordering::SeqCst);
    assert!(report.accepted > 0, "load never reached the server");
    assert_eq!(
        report.executed, report.accepted,
        "shutdown must drain every accepted request (shed {})",
        report.shed
    );
    assert_eq!(report.local_hits + report.steals, report.executed);
}

/// Satellite acceptance: admission accounting is scheduler-independent
/// — with no workers draining, both schedulers shed exactly the same
/// requests at the same depth, and batching cannot widen the bound.
#[test]
fn admission_sheds_identically_across_schedulers_and_batches() {
    for kind in [SchedKind::Condvar, SchedKind::Steal] {
        for batch in [1usize, 16] {
            let store = test_store(60, 3, 5);
            let cfg = ServerConfig {
                threads: 0,
                queue_depth: 6,
                sched: SchedConfig { kind, batch },
            };
            let server = Server::start(store, cfg);
            let q = Query::BrightestN { n: 2, filter: SourceFilter::Any };
            let mut ok = 0;
            for _ in 0..15 {
                if server.try_submit(q.clone()) {
                    ok += 1;
                }
            }
            assert_eq!(ok, 6, "{kind:?} batch {batch}");
            assert_eq!(server.queue_len(), 6, "{kind:?} batch {batch}");
            let report = server.shutdown();
            assert_eq!(report.accepted, 6, "{kind:?} batch {batch}");
            assert_eq!(report.shed, 9, "{kind:?} batch {batch}");
        }
    }
}

/// Satellite acceptance: the scheduler's shard grouping coalesces on
/// the wire — all same-shard (and, transitively, same-server)
/// sub-queries from one batch travel as ONE framed request per
/// contacted server, and the coalesced answers stay byte-identical to
/// direct execution.
#[test]
fn batched_subqueries_coalesce_into_one_frame_per_server() {
    let store = test_store(1100, 8, 57);
    let (w, h) = (store.width, store.height);

    // one server owning everything: any batch must cost exactly 1 frame
    let single = ShardServer::bind(Arc::clone(&store), "127.0.0.1:0").expect("bind");
    let addr = single.local_addr().to_string();
    let _h1 = single.spawn();
    let net = NetRouterEngine::connect(Arc::clone(&store), &[addr], 1).expect("connect");
    let mut rng = Rng::new(41);
    for round in 0..6usize {
        let batch: Vec<Query> = (0..5).map(|i| fuzz_query(&mut rng, w, h, round * 5 + i)).collect();
        let before = net.frames_sent();
        let got = net.call_batch(&batch);
        assert_eq!(
            net.frames_sent() - before,
            1,
            "round {round}: a whole batch to one server is one frame"
        );
        for (q, r) in batch.iter().zip(&got) {
            assert_eq!(r.as_ref().expect("served"), &execute(&store, q), "{q:?}");
        }
    }

    // three servers, replicas=1: frames == distinct servers the plan
    // touches, never the number of sub-queries
    let mut handles = Vec::new();
    let mut addrs = Vec::new();
    for _ in 0..3 {
        let s = ShardServer::bind(Arc::clone(&store), "127.0.0.1:0").expect("bind");
        addrs.push(s.local_addr().to_string());
        handles.push(s.spawn());
    }
    let net = NetRouterEngine::connect(Arc::clone(&store), &addrs, 1).expect("connect");
    for round in 0..6usize {
        let batch: Vec<Query> = (0..5).map(|i| fuzz_query(&mut rng, w, h, round * 5 + i)).collect();
        let mut servers = std::collections::BTreeSet::new();
        let mut subqueries = 0usize;
        for q in &batch {
            for shard in plan_shards(&store, q) {
                subqueries += 1;
                servers.insert(net.placement().replicas_of(shard)[0]);
            }
        }
        let before = net.frames_sent();
        let got = net.call_batch(&batch);
        let frames = (net.frames_sent() - before) as usize;
        assert_eq!(
            frames,
            servers.len(),
            "round {round}: one frame per contacted server ({subqueries} sub-queries planned)"
        );
        assert!(frames <= subqueries, "coalescing can only shrink the wire cost");
        for (q, r) in batch.iter().zip(&got) {
            assert_eq!(r.as_ref().expect("served"), &execute(&store, q), "{q:?}");
        }
    }
}

/// The drive report surfaces the scheduler counters after a driven run
/// (the same numbers `serve-bench` prints and `bench_serve` records).
#[test]
fn drive_report_carries_scheduler_counters() {
    let store = test_store(800, 6, 42);
    let (w, h) = (store.width, store.height);
    let server = Arc::new(Server::start(
        Arc::clone(&store),
        ServerConfig {
            threads: 2,
            sched: SchedConfig { kind: SchedKind::Steal, batch: 4 },
            ..Default::default()
        },
    ));
    let engine = ServerEngine::new(Arc::clone(&server));
    let cfg = LoadGenConfig { burst: 4, ..LoadGenConfig::scenario("hotspot", 7).unwrap() };
    let mut gen = LoadGen::new(cfg, w, h);
    let mut drive = serve::drive_closed_loop(&engine, &mut gen, 4, 0.3);
    let report = server.shutdown();
    drive.absorb_server(&report);
    assert!(drive.completed > 0);
    assert_eq!(drive.local_hits + drive.steals, report.executed);
    assert_eq!(drive.batches, report.batches);
    assert!(drive.batches > 0);
    assert_eq!(drive.batch_size.n, report.batches);
    let summary = drive.summary();
    assert!(summary.contains("sched:"), "{summary}");
}
