//! Acceptance tests for the observability layer (`serve::obs`):
//!
//! * the unified registry absorbs the stack's existing accounting —
//!   drive reports, worker-pool server reports — without changing a
//!   single reported value (counters equal the report's fields,
//!   histogram quantiles equal the report's distributions);
//! * on the simulated distributed tier the per-stage spans of every
//!   sampled request sum to its end-to-end latency within 5% (they
//!   partition it by construction), with shard service always
//!   individually attributed;
//! * the continuous collector on the same tier: fixed seed in, a
//!   byte-identical timeline out; every per-node row and the cluster
//!   fold conserve (evicted + Σ window deltas == final counters); a
//!   node killed mid-run gaps, flips unhealthy within two windows of
//!   its death, and no other node gains a gap.

use std::sync::Arc;

use celeste::jsonlite;
use celeste::prng::Rng;
use celeste::serve::dist::{FailureSchedule, Router, RouterConfig, Routing};
use celeste::serve::{
    self, drive_open_loop, drive_open_loop_with, fuzz_query, Collector, CollectorConfig, LoadGen,
    LoadGenConfig, Outcome, Registry, Request, RouterEngine, SchedConfig, SchedKind, Server,
    ServerConfig, SimClock, Stage, Store, Verdict,
};

fn test_store(n: usize, shards: usize, seed: u64) -> Arc<Store> {
    let snap = serve::snapshot::synthetic(n, seed);
    Arc::new(Store::build(snap.sources, snap.width, snap.height, shards))
}

/// Acceptance: absorbing the worker pool's server report and a drive
/// report into the registry changes no reported value.
#[test]
fn registry_absorbs_reports_without_changing_reported_values() {
    let store = test_store(800, 6, 53);
    let (w, h) = (store.width, store.height);

    // a real worker-pool run: 60 closed-loop requests through the
    // work-stealing batched scheduler, then shut down for the report
    let server = Server::start(
        Arc::clone(&store),
        ServerConfig {
            threads: 2,
            sched: SchedConfig { kind: SchedKind::Steal, batch: 4 },
            ..Default::default()
        },
    );
    let mut rng = Rng::new(9);
    for i in 0..60usize {
        let q = fuzz_query(&mut rng, w, h, i);
        assert!(server.call(q).is_some(), "query {i} must be served");
    }
    let report = server.shutdown();
    assert_eq!(report.executed, 60);

    // a real driven run on the simulated dist tier
    let rengine =
        RouterEngine::new(Router::new(Arc::clone(&store), 4, 2, RouterConfig::default()));
    let cfg = LoadGenConfig::scenario("uniform", 77).expect("known scenario");
    let mut gen = LoadGen::new(cfg, w, h);
    let mut clock = SimClock::new();
    let drive = drive_open_loop(&rengine, &mut clock, &mut gen, 5_000.0, 0.2);
    assert!(drive.completed > 100, "completed {}", drive.completed);

    let reg = Registry::new();
    reg.absorb_server(&report);
    reg.absorb_drive(&drive);
    let snap = reg.snapshot();

    // worker-pool values, unchanged
    assert_eq!(snap.counter("server_accepted"), report.accepted);
    assert_eq!(snap.counter("server_executed"), report.executed);
    assert_eq!(snap.counter("server_shed"), report.shed);
    assert_eq!(snap.counter("server_batches"), report.batches);
    let lat = &snap.histograms["server_latency"];
    assert_eq!(lat.n, report.latency_all().n);
    assert_eq!(lat.p50(), report.latency_all().p50());
    assert_eq!(lat.p99(), report.latency_all().p99());
    // the pool's own stage breakdown rides along: one queue wait per
    // job, one execute per drained batch
    assert_eq!(snap.histograms["stage_queue_wait"].n, 60);
    assert_eq!(snap.histograms["stage_shard_execute"].n, report.batches);

    // drive values, unchanged
    assert_eq!(snap.counter("drive_offered"), drive.offered);
    assert_eq!(snap.counter("drive_completed"), drive.completed);
    assert_eq!(snap.counter("drive_shed"), drive.shed);
    let dlat = &snap.histograms["drive_latency"];
    assert_eq!(dlat.n, drive.latency_all().n);
    assert_eq!(dlat.p50(), drive.latency_all().p50());
    assert_eq!(dlat.p99(), drive.latency_all().p99());
}

/// Acceptance: on the simulated dist tier the spans of every sampled
/// request sum to its end-to-end simulated latency within 5%.
#[test]
fn sim_tier_spans_partition_end_to_end_latency() {
    let store = test_store(600, 6, 31);
    let (w, h) = (store.width, store.height);
    let rengine =
        RouterEngine::new(Router::new(Arc::clone(&store), 4, 2, RouterConfig::default()));
    rengine.sampler().configure(1, 0.0); // keep every request
    let mut rng = Rng::new(19);
    let mut now = 0.0f64;
    for i in 0..30usize {
        let q = fuzz_query(&mut rng, w, h, i);
        let resp = rengine.call(Request::new(q).arriving_at(now));
        assert_eq!(resp.trace.outcome, Outcome::Served, "query {i}");
        assert_ne!(resp.trace.trace_id, 0);
        now += 1e-3;
    }
    let records = rengine.sampler().records();
    assert_eq!(records.len(), 30, "sampling every request keeps every request");
    for rec in &records {
        assert!(rec.total_s > 0.0);
        let sum = rec.spans.total();
        assert!(
            (sum - rec.total_s).abs() <= 0.05 * rec.total_s,
            "trace {}: spans sum to {:.9}s but e2e simulated latency is {:.9}s (>5% apart)",
            rec.trace_id,
            sum,
            rec.total_s
        );
        assert!(
            rec.spans.get(Stage::ShardExecute) > 0.0,
            "trace {} has no shard service attributed",
            rec.trace_id
        );
    }
    // the fabric transfer residual shows up on at least the remote
    // critical branches
    assert!(
        records.iter().any(|r| r.spans.get(Stage::NetRtt) > 0.0),
        "no request attributed any fabric time"
    );
    let snap = rengine.registry().snapshot();
    assert_eq!(snap.histograms["stage_shard_execute"].n, 30);
}

const COLLECT_NODES: usize = 4;
const COLLECT_SECS: f64 = 0.25;
const COLLECT_WINDOW_S: f64 = 0.025;

/// Drive the simulated p2c tier under the hotspot mix with the
/// continuous collector sampling the front-end registry plus every
/// node each window (the `serve-bench --collect-ms` wiring, inlined);
/// `kill` optionally schedules a mid-run node death (`"NODE@T"`).
fn collect_run(store: &Arc<Store>, kill: Option<&str>) -> Collector {
    let mut router = Router::new(
        Arc::clone(store),
        COLLECT_NODES,
        2,
        RouterConfig { routing: Routing::PowerOfTwo, seed: 4242, ..Default::default() },
    );
    if let Some(spec) = kill {
        router = router.with_schedule(FailureSchedule::parse(spec).expect("valid kill spec"));
    }
    let rengine = RouterEngine::new(router);
    let names: Vec<String> = std::iter::once("local".to_string())
        .chain((0..COLLECT_NODES).map(|n| format!("node-{n}")))
        .collect();
    let mut c =
        Collector::new(CollectorConfig { window_s: COLLECT_WINDOW_S, ..Default::default() }, names);
    let cfg = LoadGenConfig::scenario("hotspot", 4242).expect("known scenario");
    let mut gen = LoadGen::new(cfg, store.width, store.height);
    let mut clock = SimClock::new();
    let scraper = rengine.clone();
    let drive =
        drive_open_loop_with(&rengine, &mut clock, &mut gen, 20_000.0, COLLECT_SECS, |at| {
            let mut src = |t: f64| {
                let mut v = vec![Some(scraper.registry().snapshot())];
                v.extend(scraper.node_samples(t));
                v
            };
            c.tick(at, &mut src);
        });
    rengine.registry().absorb_drive(&drive);
    let mut src = |t: f64| {
        let mut v = vec![Some(rengine.registry().snapshot())];
        v.extend(rengine.node_samples(t));
        v
    };
    c.finish(COLLECT_SECS, &mut src);
    c
}

/// Acceptance: a fixed seed yields a byte-identical timeline — the
/// sim-tier collection path is fully deterministic, so any diff in the
/// rendered JSON across reruns is a code change, never noise.
#[test]
fn collected_timeline_is_byte_identical_across_fixed_seed_reruns() {
    let store = test_store(600, 6, 31);
    let a = jsonlite::to_string(&collect_run(&store, None).to_json());
    let b = jsonlite::to_string(&collect_run(&store, None).to_json());
    assert!(a.contains("\"window_ms\""), "rendered timeline missing its window_ms field");
    assert_eq!(a, b, "same seed, same store: the collected timeline must not drift");
}

/// Acceptance: every row conserves — evicted counter deltas plus the
/// per-window deltas reproduce the final cumulative counters exactly —
/// and the cluster fold carries windowed latency rollups, not just an
/// end-of-run aggregate.
#[test]
fn collected_windows_conserve_and_carry_latency_rollups() {
    let store = test_store(600, 6, 31);
    let c = collect_run(&store, None);
    for (i, name) in c.names().iter().enumerate() {
        let t = c.node_timeline(i);
        assert_eq!(t.delta_total(), t.final_counters(), "node {name:?} row must conserve");
        assert_eq!(t.gaps(), 0, "node {name:?} gapped with nothing killed");
    }
    let cl = c.cluster();
    assert_eq!(cl.delta_total(), cl.final_counters(), "cluster fold must conserve");
    let live = cl
        .windows()
        .filter(|w| !w.gapped && w.hists.get("request_latency").is_some_and(|h| h.n > 0))
        .count();
    assert!(live >= 4, "want >= 4 windows with request_latency rollups, got {live}");
}

/// Acceptance: a node killed mid-run becomes visible as gapped windows
/// on its own row only, its health verdict flips to unhealthy within
/// two windows of the death, and conservation survives the gap.
#[test]
fn killed_node_gaps_and_flips_unhealthy_within_two_windows() {
    let store = test_store(600, 6, 31);
    let kill_t = 0.1;
    let c = collect_run(&store, Some("1@0.1"));
    let victim = "node-1";
    let vi = c.names().iter().position(|n| n == victim).expect("victim row exists");
    let row = c.node_timeline(vi);
    assert!(row.gaps() > 0, "killed node shows no gapped windows");
    assert_eq!(row.delta_total(), row.final_counters(), "gapped row must still conserve");
    for (i, name) in c.names().iter().enumerate() {
        if i != vi {
            assert_eq!(
                c.node_timeline(i).gaps(),
                0,
                "node {name:?} gained a gap but only {victim:?} was killed"
            );
        }
    }
    let kill_window = (kill_t / COLLECT_WINDOW_S) as u64;
    let flip = c
        .transitions()
        .iter()
        .find(|t| t.node == victim && t.to == Verdict::Unhealthy)
        .expect("killed node must flip to unhealthy");
    assert!(
        flip.window <= kill_window + 2,
        "unhealthy flip at window {} but the kill landed in window {kill_window}",
        flip.window
    );
    assert_eq!(c.verdict(vi), Verdict::Unhealthy, "victim verdict at end of run");
}
