//! Acceptance tests for the observability layer (`serve::obs`):
//!
//! * the unified registry absorbs the stack's existing accounting —
//!   drive reports, worker-pool server reports — without changing a
//!   single reported value (counters equal the report's fields,
//!   histogram quantiles equal the report's distributions);
//! * on the simulated distributed tier the per-stage spans of every
//!   sampled request sum to its end-to-end latency within 5% (they
//!   partition it by construction), with shard service always
//!   individually attributed.

use std::sync::Arc;

use celeste::prng::Rng;
use celeste::serve::dist::{Router, RouterConfig};
use celeste::serve::{
    self, drive_open_loop, fuzz_query, LoadGen, LoadGenConfig, Outcome, Registry, Request,
    RouterEngine, SchedConfig, SchedKind, Server, ServerConfig, SimClock, Stage, Store,
};

fn test_store(n: usize, shards: usize, seed: u64) -> Arc<Store> {
    let snap = serve::snapshot::synthetic(n, seed);
    Arc::new(Store::build(snap.sources, snap.width, snap.height, shards))
}

/// Acceptance: absorbing the worker pool's server report and a drive
/// report into the registry changes no reported value.
#[test]
fn registry_absorbs_reports_without_changing_reported_values() {
    let store = test_store(800, 6, 53);
    let (w, h) = (store.width, store.height);

    // a real worker-pool run: 60 closed-loop requests through the
    // work-stealing batched scheduler, then shut down for the report
    let server = Server::start(
        Arc::clone(&store),
        ServerConfig {
            threads: 2,
            sched: SchedConfig { kind: SchedKind::Steal, batch: 4 },
            ..Default::default()
        },
    );
    let mut rng = Rng::new(9);
    for i in 0..60usize {
        let q = fuzz_query(&mut rng, w, h, i);
        assert!(server.call(q).is_some(), "query {i} must be served");
    }
    let report = server.shutdown();
    assert_eq!(report.executed, 60);

    // a real driven run on the simulated dist tier
    let rengine =
        RouterEngine::new(Router::new(Arc::clone(&store), 4, 2, RouterConfig::default()));
    let cfg = LoadGenConfig::scenario("uniform", 77).expect("known scenario");
    let mut gen = LoadGen::new(cfg, w, h);
    let mut clock = SimClock::new();
    let drive = drive_open_loop(&rengine, &mut clock, &mut gen, 5_000.0, 0.2);
    assert!(drive.completed > 100, "completed {}", drive.completed);

    let reg = Registry::new();
    reg.absorb_server(&report);
    reg.absorb_drive(&drive);
    let snap = reg.snapshot();

    // worker-pool values, unchanged
    assert_eq!(snap.counter("server_accepted"), report.accepted);
    assert_eq!(snap.counter("server_executed"), report.executed);
    assert_eq!(snap.counter("server_shed"), report.shed);
    assert_eq!(snap.counter("server_batches"), report.batches);
    let lat = &snap.histograms["server_latency"];
    assert_eq!(lat.n, report.latency_all().n);
    assert_eq!(lat.p50(), report.latency_all().p50());
    assert_eq!(lat.p99(), report.latency_all().p99());
    // the pool's own stage breakdown rides along: one queue wait per
    // job, one execute per drained batch
    assert_eq!(snap.histograms["stage_queue_wait"].n, 60);
    assert_eq!(snap.histograms["stage_shard_execute"].n, report.batches);

    // drive values, unchanged
    assert_eq!(snap.counter("drive_offered"), drive.offered);
    assert_eq!(snap.counter("drive_completed"), drive.completed);
    assert_eq!(snap.counter("drive_shed"), drive.shed);
    let dlat = &snap.histograms["drive_latency"];
    assert_eq!(dlat.n, drive.latency_all().n);
    assert_eq!(dlat.p50(), drive.latency_all().p50());
    assert_eq!(dlat.p99(), drive.latency_all().p99());
}

/// Acceptance: on the simulated dist tier the spans of every sampled
/// request sum to its end-to-end simulated latency within 5%.
#[test]
fn sim_tier_spans_partition_end_to_end_latency() {
    let store = test_store(600, 6, 31);
    let (w, h) = (store.width, store.height);
    let rengine =
        RouterEngine::new(Router::new(Arc::clone(&store), 4, 2, RouterConfig::default()));
    rengine.sampler().configure(1, 0.0); // keep every request
    let mut rng = Rng::new(19);
    let mut now = 0.0f64;
    for i in 0..30usize {
        let q = fuzz_query(&mut rng, w, h, i);
        let resp = rengine.call(Request::new(q).arriving_at(now));
        assert_eq!(resp.trace.outcome, Outcome::Served, "query {i}");
        assert_ne!(resp.trace.trace_id, 0);
        now += 1e-3;
    }
    let records = rengine.sampler().records();
    assert_eq!(records.len(), 30, "sampling every request keeps every request");
    for rec in &records {
        assert!(rec.total_s > 0.0);
        let sum = rec.spans.total();
        assert!(
            (sum - rec.total_s).abs() <= 0.05 * rec.total_s,
            "trace {}: spans sum to {:.9}s but e2e simulated latency is {:.9}s (>5% apart)",
            rec.trace_id,
            sum,
            rec.total_s
        );
        assert!(
            rec.spans.get(Stage::ShardExecute) > 0.0,
            "trace {} has no shard service attributed",
            rec.trace_id
        );
    }
    // the fabric transfer residual shows up on at least the remote
    // critical branches
    assert!(
        records.iter().any(|r| r.spans.get(Stage::NetRtt) > 0.0),
        "no request attributed any fabric time"
    );
    let snap = rengine.registry().snapshot();
    assert_eq!(snap.histograms["stage_shard_execute"].n, 30);
}
