//! Acceptance tests for the real RPC transport (`serve::net`):
//!
//! * byte parity with the in-process store across the middleware ×
//!   consistency matrix (`--transport tcp` must answer exactly what
//!   `query::execute` answers);
//! * live-ingestion parity: epoch publishes ship over the wire to
//!   every server before the front-end mirror advances, so `Fresh`
//!   reads hold cross-process;
//! * a shard-server *process* killed mid-run is absorbed by
//!   replication 2 with zero failed queries (the CI smoke's contract);
//! * hostile peers get typed errors and can only ever end their own
//!   connection, never the server;
//! * graceful termination flushes a final WAL checkpoint of the
//!   applied head and reports terminal stats, and the flushed
//!   directory recovers to that exact epoch;
//! * the continuous collector over live servers: per-window stats
//!   scrapes land in per-server timeline rows that conserve, with
//!   zero gaps while the fleet is healthy;
//! * the `ShardClient` trait adapter serves real replies through the
//!   simulated router's seam.

use std::io::Write;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use celeste::ga::{Fabric, FabricConfig};
use celeste::prng::Rng;
use celeste::serve::dist::ShardClient;
use celeste::serve::durable::DurableLog;
use celeste::serve::net::wire::{self, ErrorCode, Msg, WireError};
use celeste::serve::net::{NetConn, NetShardClient, ShardServerHandle};
use celeste::serve::{
    self, execute, execute_on_shard, fuzz_query, Admission, Cached, Collector, CollectorConfig,
    Consistency, Consistent, DriftConfig, DriftGen, Hedged, Ingestor, NetRouterEngine, Outcome,
    Query, QueryEngine, Request, ShardServer, SourceFilter, Stage, Store, VersionedStore,
};

fn test_store(n: usize, shards: usize, seed: u64) -> Arc<Store> {
    let snap = serve::snapshot::synthetic(n, seed);
    Arc::new(Store::build(snap.sources, snap.width, snap.height, shards))
}

fn spawn_servers(store: &Arc<Store>, n: usize) -> (Vec<ShardServerHandle>, Vec<String>) {
    let mut handles = Vec::new();
    let mut addrs = Vec::new();
    for _ in 0..n {
        let server = ShardServer::bind(Arc::clone(store), "127.0.0.1:0").expect("bind");
        let handle = server.spawn();
        addrs.push(handle.addr().to_string());
        handles.push(handle);
    }
    (handles, addrs)
}

/// Acceptance: `--transport tcp` is byte-identical to the in-process
/// store for the full tier × middleware × consistency matrix.
#[test]
fn tcp_parity_across_middleware_and_consistency() {
    let store = test_store(1200, 8, 311);
    let (w, h) = (store.width, store.height);
    let (_handles, addrs) = spawn_servers(&store, 2);
    let levels = [Consistency::CachedOk, Consistency::Fresh, Consistency::AtMost(1)];
    for arrangement in 0..3usize {
        for (ci, &level) in levels.iter().enumerate() {
            let net = NetRouterEngine::connect(Arc::clone(&store), &addrs, 2).expect("connect");
            let base: Box<dyn QueryEngine> = Box::new(net);
            let engine: Box<dyn QueryEngine> = match arrangement {
                0 => base,
                1 => Box::new(Cached::new(Hedged::new(base, 1e-6), 64)),
                _ => Box::new(Admission::new(
                    Hedged::new(Cached::new(base, 64), 1e-6),
                    1 << 20,
                )),
            };
            let engine = Consistent::new(engine, level);
            let mut rng = Rng::new(7 + arrangement as u64 * 13 + ci as u64);
            for i in 0..24usize {
                let q = fuzz_query(&mut rng, w, h, i);
                let want = execute(&store, &q);
                // the repeat probes the cache path on arrangement > 0
                for repeat in 0..2 {
                    let resp = engine.call(Request::new(q.clone()));
                    assert_eq!(
                        resp.trace.outcome,
                        Outcome::Served,
                        "arrangement {arrangement} level {level:?} query {i} repeat {repeat}"
                    );
                    assert_eq!(
                        resp.result.as_ref().expect("served"),
                        &want,
                        "arrangement {arrangement} level {level:?} query {i}: {q:?}"
                    );
                }
            }
        }
    }
}

/// Acceptance: parity holds under live ingestion with publishes
/// shipped over the wire — every server acks the epoch before the
/// front-end mirror advances, so a `Fresh` read planned against the
/// new head is answered from it on every server.
#[test]
fn tcp_fresh_reads_hold_under_live_ingestion_with_wire_publishes() {
    let store = test_store(900, 6, 47);
    let (w, h) = (store.width, store.height);
    let (_handles, addrs) = spawn_servers(&store, 3);
    let net = NetRouterEngine::connect(Arc::clone(&store), &addrs, 2).expect("connect");
    let vs = Arc::new(VersionedStore::new(Arc::clone(&store)));
    let mut ingestor = Ingestor::new(Arc::clone(&vs));
    let mut drift = DriftGen::new(
        &store.all_sources(),
        w,
        h,
        DriftConfig { batch: 16, seed: 5, ..Default::default() },
    );
    let mut rng = Rng::new(23);
    for round in 0..8u64 {
        let rep = ingestor.apply(&drift.next_batch());
        assert_eq!(rep.epoch, round + 1);
        net.publish(&rep);
        let head = net.epoch_view().expect("mirror");
        assert_eq!(head.epoch, round + 1, "mirror advances with the publish");
        for i in 0..5usize {
            let q = fuzz_query(&mut rng, w, h, round as usize * 5 + i);
            let want = execute(&head.store, &q);
            let resp = net.call(Request::new(q.clone()).fresh());
            assert_eq!(resp.trace.outcome, Outcome::Served, "round {round} query {i}");
            assert_eq!(
                resp.result.expect("served"),
                want,
                "round {round} query {i}: {q:?}"
            );
        }
    }
    assert_eq!(net.suspected(), vec![false; 3], "no server fell behind or failed");
}

/// A server that speaks just enough protocol to pass the connect-time
/// ping, then dies: handshake, one empty Execute, gone. The canonical
/// mid-run death as seen from the client side.
fn spawn_flaky_server() -> std::net::SocketAddr {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr");
    std::thread::spawn(move || {
        if let Ok((mut s, _)) = listener.accept() {
            let _ = wire::read_frame(&mut s); // Hello
            let _ = wire::write_frame(
                &mut s,
                &Msg::HelloAck { version: wire::VERSION, epoch: 0, n_shards: 6 },
            );
            if let Ok(Msg::Execute { req_id, trace_id, entries, .. }) = wire::read_frame(&mut s) {
                // the connect-time ping carries no entries; echo the shape
                let replies: Vec<Vec<celeste::serve::ShardReply>> =
                    entries.iter().map(|_| Vec::new()).collect();
                let _ = wire::write_frame(
                    &mut s,
                    &Msg::Reply { req_id, trace_id, server_spans: Vec::new(), entries: replies },
                );
            }
        }
        // listener and connection drop here: further dials are refused
    });
    addr
}

/// Acceptance: a server dying mid-run is failed over — every query is
/// still served byte-identically from surviving replicas, the dead
/// server is suspected, and nothing is recorded as failed.
#[test]
fn dead_server_fails_over_with_zero_failed_queries() {
    let store = test_store(700, 6, 99);
    let (w, h) = (store.width, store.height);
    let (_handles, mut addrs) = spawn_servers(&store, 2);
    addrs.push(spawn_flaky_server().to_string()); // server 2 dies after the ping
    let net = NetRouterEngine::connect(Arc::clone(&store), &addrs, 2).expect("connect");
    let owns: Vec<usize> = (0..store.shards.len())
        .filter(|&s| net.placement().replicas_of(s).contains(&2))
        .collect();
    assert!(!owns.is_empty(), "rendezvous gave the flaky server no replica slot");
    let mut rng = Rng::new(3);
    for i in 0..40usize {
        let q = fuzz_query(&mut rng, w, h, i);
        let want = execute(&store, &q);
        let resp = net.call(Request::new(q.clone()));
        assert_eq!(resp.trace.outcome, Outcome::Served, "query {i} must fail over, not fail");
        assert_eq!(resp.result.expect("served"), want, "query {i}: {q:?}");
    }
    let m: std::collections::BTreeMap<String, f64> = net.metrics().into_iter().collect();
    assert_eq!(m["net_failed"], 0.0, "replication must absorb the death");
    assert!(net.suspected()[2], "the dead server must be suspected");
    assert!(m["net_failovers"] >= 1.0, "the death must be recorded as a failover");
}

/// Kills children on drop so a failing test cannot leak shard-server
/// processes past the test run.
struct Reap(Vec<std::process::Child>);

impl Drop for Reap {
    fn drop(&mut self) {
        for c in &mut self.0 {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

/// Acceptance (the CI smoke's contract, in-tree): three real
/// shard-server *processes*, one killed mid-run, zero failed queries
/// at replication 2 and full byte parity throughout.
#[test]
fn child_process_kill_mid_run_is_absorbed_at_replication_two() {
    let store = test_store(800, 8, 2024);
    let (w, h) = (store.width, store.height);
    let snap_path =
        std::env::temp_dir().join(format!("celeste-net-test-{}.json", std::process::id()));
    serve::snapshot::save(&snap_path, &store).expect("write snapshot");
    let exe = env!("CARGO_BIN_EXE_celeste");
    let mut reap = Reap(Vec::new());
    let mut addrs = Vec::new();
    for _ in 0..3 {
        let mut child = std::process::Command::new(exe)
            .arg("shard-server")
            .arg("--snapshot")
            .arg(&snap_path)
            .args(["--shards", "8", "--listen", "127.0.0.1:0"])
            .stdout(std::process::Stdio::piped())
            .spawn()
            .expect("spawn shard-server");
        let stdout = child.stdout.take().expect("piped");
        reap.0.push(child);
        let mut line = String::new();
        std::io::BufRead::read_line(&mut std::io::BufReader::new(stdout), &mut line)
            .expect("read announce line");
        let addr = line.trim().rsplit(' ').next().unwrap_or_default().to_string();
        assert!(addr.contains(':'), "bad announce line: {line:?}");
        addrs.push(addr);
    }
    let net = NetRouterEngine::connect(Arc::clone(&store), &addrs, 2).expect("connect");
    let mut rng = Rng::new(8);
    for i in 0..30usize {
        let q = fuzz_query(&mut rng, w, h, i);
        let want = execute(&store, &q);
        let resp = net.call(Request::new(q.clone()));
        assert_eq!(resp.trace.outcome, Outcome::Served, "warm query {i}");
        assert_eq!(resp.result.expect("served"), want, "warm query {i}");
    }
    // kill one server process for real: its sockets die with it
    reap.0[1].kill().expect("kill shard-server 1");
    let _ = reap.0[1].wait();
    for i in 30..130usize {
        let q = fuzz_query(&mut rng, w, h, i);
        let want = execute(&store, &q);
        let resp = net.call(Request::new(q.clone()));
        assert_eq!(resp.trace.outcome, Outcome::Served, "post-kill query {i} must be served");
        assert_eq!(resp.result.expect("served"), want, "post-kill query {i}");
    }
    let m: std::collections::BTreeMap<String, f64> = net.metrics().into_iter().collect();
    assert_eq!(m["net_failed"], 0.0, "zero failed queries at replication 2");
    assert!(net.suspected()[1], "the killed process must be suspected");
    assert!(m["net_failovers"] >= 1.0);
    std::fs::remove_file(&snap_path).ok();
}

/// Satellite acceptance: a hostile peer gets a typed error and only
/// ever ends its own connection — a well-behaved client is served
/// normally after every kind of abuse.
#[test]
fn hostile_peers_get_typed_errors_and_cannot_kill_the_server() {
    let store = test_store(300, 4, 7);
    let server = ShardServer::bind(Arc::clone(&store), "127.0.0.1:0").expect("bind");
    let addr = server.local_addr();
    let _handle = server.spawn();

    // garbage bytes: answered with a typed Malformed error
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(b"GET / HTTP/1.1\r\n\r\n").expect("write");
    match wire::read_frame(&mut s) {
        Ok(Msg::Error { code, .. }) => assert_eq!(code, ErrorCode::Malformed),
        other => panic!("want a typed Malformed error, got {other:?}"),
    }

    // a partial frame followed by a disconnect: the handler exits quietly
    let mut s = TcpStream::connect(addr).expect("connect");
    let frame = wire::encode_frame(&Msg::Hello { version: wire::VERSION });
    s.write_all(&frame[..5]).expect("write partial");
    drop(s);

    // an unsupported version byte in the header: typed BadVersion
    let mut s = TcpStream::connect(addr).expect("connect");
    let mut bad = frame.clone();
    bad[2] = 9;
    s.write_all(&bad).expect("write");
    match wire::read_frame(&mut s) {
        Ok(Msg::Error { code, .. }) => assert_eq!(code, ErrorCode::BadVersion),
        other => panic!("want a typed BadVersion error, got {other:?}"),
    }

    // a Hello negotiating a version the server does not speak
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(&wire::encode_frame(&Msg::Hello { version: 99 })).expect("write");
    match wire::read_frame(&mut s) {
        Ok(Msg::Error { code, .. }) => assert_eq!(code, ErrorCode::BadVersion),
        other => panic!("want a typed BadVersion error, got {other:?}"),
    }

    // after all that abuse a well-behaved client is served normally
    let conn = NetConn::new(addr.to_string());
    let q = Query::BrightestN { n: 5, filter: SourceFilter::Any };
    let replies = conn
        .execute(vec![(0, vec![q.clone()])], 0, Some(Duration::from_secs(5)))
        .expect("server must survive hostile peers");
    assert_eq!(replies.len(), 1);
    assert_eq!(replies[0][0], execute_on_shard(&store.shards[0], &q));
}

/// Satellite acceptance: the epoch machinery refuses what it must —
/// unmet freshness bounds are `Stale`, skipped epochs are `EpochGap`,
/// duplicate publishes are acked idempotently — all without ending
/// the connection.
#[test]
fn epoch_bounds_and_gaps_are_typed_refusals() {
    let store = test_store(200, 4, 11);
    let server = ShardServer::bind(Arc::clone(&store), "127.0.0.1:0").expect("bind");
    let addr = server.local_addr();
    let _handle = server.spawn();
    let conn = NetConn::new(addr.to_string());
    let q = Query::BrightestN { n: 1, filter: SourceFilter::Any };

    // the server is at epoch 0: a freshness bound of 999 is Stale
    assert_eq!(
        conn.execute(vec![(0, vec![q.clone()])], 999, None),
        Err(WireError::Remote(ErrorCode::Stale))
    );
    // a shard index past the store is Malformed, not a crash
    assert_eq!(
        conn.execute(vec![(40, vec![q.clone()])], 0, None),
        Err(WireError::Remote(ErrorCode::Malformed))
    );
    // skipping epochs is refused: the replica would diverge
    let rows = store.all_sources()[..3].to_vec();
    assert_eq!(conn.publish(5, &rows, None), Err(WireError::Remote(ErrorCode::EpochGap)));
    // the next epoch applies; a duplicate is acked idempotently
    conn.publish(1, &rows, None).expect("epoch 1 applies");
    conn.publish(1, &rows, None).expect("duplicate publish acks idempotently");
    // the same connection survived every refusal and the bound now holds
    let replies = conn.execute(vec![(0, vec![q])], 1, None).expect("bound met");
    assert_eq!(replies.len(), 1);
}

/// Connecting to a dead address is a typed error after the backoff
/// budget, not a hang or a panic.
#[test]
fn connect_to_dead_address_errors_after_backoff() {
    let store = test_store(50, 2, 1);
    // bind-then-drop guarantees the port is closed
    let addr = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
        l.local_addr().expect("addr").to_string()
    };
    let err = NetRouterEngine::connect(store, &[addr], 1).expect_err("must refuse");
    assert!(matches!(err, WireError::Io(_)), "got {err:?}");
}

/// Tentpole acceptance: over tcp, every sampled request yields a
/// complete cross-process span tree — the client's encode/decode and
/// the server's shard execution individually attributed, joined by one
/// trace id — and the client spans sum to the end-to-end latency
/// within 5%.
#[test]
fn tcp_traces_join_client_and_server_spans_and_sum_to_latency() {
    let store = test_store(900, 6, 71);
    let (w, h) = (store.width, store.height);
    let (_handles, addrs) = spawn_servers(&store, 2);
    let net = NetRouterEngine::connect(Arc::clone(&store), &addrs, 2).expect("connect");
    net.configure_tracing(1, 0.0); // keep every request
    let mut rng = Rng::new(41);
    let mut ids = Vec::new();
    for i in 0..25usize {
        let q = fuzz_query(&mut rng, w, h, i);
        let resp = net.call(Request::new(q));
        assert_eq!(resp.trace.outcome, Outcome::Served, "query {i}");
        assert_ne!(resp.trace.trace_id, 0, "every request carries a trace id");
        assert!(!resp.trace.spans.is_empty(), "query {i} got no client spans");
        ids.push(resp.trace.trace_id);
    }
    let records = net.sampler().records();
    assert_eq!(records.len(), 25, "sampling every request keeps every request");
    for rec in &records {
        assert!(ids.contains(&rec.trace_id), "sampled id {} from no real request", rec.trace_id);
        assert!(rec.total_s > 0.0);
        let sum = rec.spans.total();
        assert!(
            (sum - rec.total_s).abs() <= 0.05 * rec.total_s,
            "trace {}: client spans sum to {:.6}s but e2e latency is {:.6}s (>5% apart)",
            rec.trace_id,
            sum,
            rec.total_s
        );
        // the cross-process join: wire codec cost attributed client-side,
        // shard execution attributed server-side, same trace id
        assert!(rec.spans.get(Stage::Encode) > 0.0, "trace {} missing encode", rec.trace_id);
        assert!(rec.spans.get(Stage::Decode) > 0.0, "trace {} missing decode", rec.trace_id);
        assert!(
            rec.server_spans.get(Stage::ShardExecute) > 0.0,
            "trace {} has no server-side shard_execute span",
            rec.trace_id
        );
    }
    // the registry's stage histograms saw the same 25 requests
    let snap = net.registry().snapshot();
    assert_eq!(snap.histograms["stage_batch_assembly"].n, 25);
    assert_eq!(snap.histograms["stage_merge"].n, 25);
}

/// Satellite acceptance: a peer speaking an older wire version
/// surfaces as the distinct, actionable version-mismatch error — not a
/// generic decode failure — and the client gives up immediately
/// instead of burning reconnect backoff on a mismatch that cannot
/// heal.
#[test]
fn old_version_peer_is_a_distinct_actionable_error() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr");
    std::thread::spawn(move || {
        if let Ok((mut s, _)) = listener.accept() {
            // a v1-era server: reads the client's Hello, answers with a
            // hand-rolled frame whose header carries version 1
            let _ = wire::read_frame(&mut s);
            let mut frame = Vec::new();
            frame.extend_from_slice(&wire::MAGIC.to_le_bytes());
            frame.push(1); // old protocol version
            frame.push(2); // HelloAck tag
            frame.extend_from_slice(&1u32.to_le_bytes());
            frame.push(1); // v1 payload: just the version byte
            let _ = s.write_all(&frame);
            // keep the socket open long enough for the client to read
            std::thread::sleep(Duration::from_millis(200));
        }
    });
    let conn = NetConn::new(addr.to_string());
    let err = conn.execute(Vec::new(), 0, None).expect_err("handshake must fail");
    assert_eq!(err, WireError::PeerVersion { ours: wire::VERSION, theirs: 1 });
    let msg = err.to_string();
    assert!(msg.contains("v1"), "mismatch names the peer's version: {msg}");
    assert!(msg.contains(&format!("v{}", wire::VERSION)), "and ours: {msg}");
    assert!(msg.contains("docs/WIRE.md"), "and points at the fix: {msg}");
}

/// Satellite acceptance: `StatsReq` scrapes a live server's own
/// registry — frame counts, per-stage timings, the applied-epoch gauge
/// — and a refused stale read is counted on both ends of the
/// connection.
#[test]
fn stats_scrape_reports_server_side_counters_and_stages() {
    let store = test_store(300, 4, 13);
    let server = ShardServer::bind(Arc::clone(&store), "127.0.0.1:0").expect("bind");
    let addr = server.local_addr();
    let _handle = server.spawn();
    let conn = NetConn::new(addr.to_string());
    let q = Query::BrightestN { n: 3, filter: SourceFilter::Any };
    for i in 0..4 {
        conn.execute(vec![(0, vec![q.clone()])], 0, None)
            .unwrap_or_else(|e| panic!("execute {i}: {e}"));
    }
    // a bound the epoch-0 server cannot meet: refused as Stale and
    // counted on both sides, without dropping the connection
    assert_eq!(
        conn.execute(vec![(1, vec![q.clone()])], 7, None),
        Err(WireError::Remote(ErrorCode::Stale))
    );
    assert_eq!(conn.stale_refusals.load(std::sync::atomic::Ordering::Relaxed), 1);
    let snap = conn.scrape(None).expect("scrape over the same connection");
    // one in-order connection makes the server's accounting exact:
    // 5 Execute frames + the StatsReq itself
    assert_eq!(snap.counter("net_frames"), 6);
    assert_eq!(snap.counter("stale_refusals"), 1);
    assert_eq!(snap.histograms["stage_decode"].n, 5, "every Execute decode is timed");
    let exec = &snap.histograms["stage_shard_execute"];
    assert_eq!(exec.n, 4, "only executed batches are timed");
    assert!(exec.max > 0.0);
    assert_eq!(snap.histograms["stage_encode"].n, 4, "every Reply encode is timed");
    assert_eq!(snap.gauges.get("applied_epoch"), Some(&0.0));
}

/// Satellite acceptance: with `--pipeline 2` replies are matched to
/// their callers by `req_id`, not by arrival order. The mock server
/// withholds its replies until BOTH in-flight Execute frames have
/// arrived — a lockstep (depth-1) client would deadlock here — then
/// answers them in reverse order. Each reply echoes its request's
/// outer entry count, so a caller that got the other caller's reply
/// fails the arity assertion immediately.
#[test]
fn pipelined_replies_are_matched_by_req_id_not_arrival_order() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr");
    std::thread::spawn(move || {
        let (mut s, _) = listener.accept().expect("accept");
        let _ = wire::read_frame(&mut s); // Hello
        wire::write_frame(
            &mut s,
            &Msg::HelloAck { version: wire::VERSION, epoch: 0, n_shards: 4 },
        )
        .expect("hello ack");
        // hold both pipelined requests before answering either
        let mut held = Vec::new();
        while held.len() < 2 {
            match wire::read_frame(&mut s).expect("read execute") {
                Msg::Execute { req_id, trace_id, entries, .. } => {
                    held.push((req_id, trace_id, entries));
                }
                other => panic!("want Execute, got {other:?}"),
            }
        }
        // answer in REVERSE arrival order: only req_id matching can
        // route these back to the right callers
        for (req_id, trace_id, entries) in held.into_iter().rev() {
            let replies: Vec<Vec<celeste::serve::ShardReply>> =
                entries.iter().map(|_| Vec::new()).collect();
            wire::write_frame(
                &mut s,
                &Msg::Reply { req_id, trace_id, server_spans: Vec::new(), entries: replies },
            )
            .expect("write reply");
        }
        std::thread::sleep(Duration::from_millis(200));
    });
    let conn = Arc::new(NetConn::with_pipeline(addr.to_string(), 2));
    assert_eq!(conn.pipeline_depth(), 2);
    let q = Query::BrightestN { n: 1, filter: SourceFilter::Any };
    let a = {
        let conn = Arc::clone(&conn);
        let q = q.clone();
        std::thread::spawn(move || {
            conn.execute(vec![(0, vec![q])], 0, Some(Duration::from_secs(5)))
        })
    };
    let b = {
        let conn = Arc::clone(&conn);
        std::thread::spawn(move || {
            conn.execute(
                vec![(0, vec![q.clone()]), (1, vec![q])],
                0,
                Some(Duration::from_secs(5)),
            )
        })
    };
    let ra = a.join().expect("caller A").expect("caller A served");
    let rb = b.join().expect("caller B").expect("caller B served");
    // the arity fingerprint: A sent 1 shard entry, B sent 2 — swapped
    // replies would invert these counts (or fail the client's own
    // shape check and surface as Malformed)
    assert_eq!(ra.len(), 1, "caller A must get the 1-entry reply");
    assert_eq!(rb.len(), 2, "caller B must get the 2-entry reply");
}

/// Satellite acceptance: graceful termination — a serving shard server
/// asked to exit flushes a final WAL checkpoint of its applied head
/// and reports its terminal stats, and the flushed directory recovers
/// to the exact epoch it was serving.
#[test]
fn graceful_term_flushes_a_final_checkpoint_and_reports() {
    let store = test_store(300, 4, 21);
    let dir = std::env::temp_dir().join(format!("celeste-term-test-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let versioned = Arc::new(VersionedStore::new(Arc::clone(&store)));
    let log = Arc::new(DurableLog::create(&dir, 0, &versioned.load()).expect("create log"));
    let server = ShardServer::bind_durable(Arc::clone(&versioned), Some(log), "127.0.0.1:0")
        .expect("bind");
    let addr = server.local_addr();
    // the in-process stand-in for the SIGTERM flag the real process
    // polls (`signal::term_requested`; the flag flip itself is pinned
    // by signal.rs's own unit test)
    let flag = Arc::new(AtomicBool::new(false));
    let term = Arc::clone(&flag);
    let join =
        std::thread::spawn(move || server.run_graceful(move || term.load(Ordering::Relaxed)));
    let conn = NetConn::new(addr.to_string());
    let rows = store.all_sources()[..3].to_vec();
    conn.publish(1, &rows, None).expect("epoch 1 applies");
    let q = Query::BrightestN { n: 2, filter: SourceFilter::Any };
    conn.execute(vec![(0, vec![q])], 1, None).expect("served at epoch 1");
    flag.store(true, Ordering::Relaxed);
    let rep = join
        .join()
        .expect("server thread")
        .expect("a termination request must yield a terminal report");
    assert_eq!(rep.epoch, 1, "the report carries the applied head");
    assert!(rep.frames >= 2, "publish + execute crossed the wire, got {}", rep.frames);
    assert_eq!(rep.stale_refusals, 0);
    assert!(rep.wal_synced, "the final WAL checkpoint must flush on the way out");
    // the flush is real: a cold recovery from the directory lands on
    // the epoch the server was serving when it was told to exit
    let rec = DurableLog::recover(&dir, 0).expect("recover from the flushed dir");
    assert_eq!(rec.versioned.load().epoch, 1, "recovered head matches the terminal report");
    std::fs::remove_dir_all(&dir).ok();
}

/// Tentpole acceptance: the continuous collector over live tcp servers
/// — per-window stats scrapes land in per-server timeline rows, every
/// row conserves, and a healthy fleet shows zero gaps.
#[test]
fn tcp_collector_scrapes_live_servers_and_conserves() {
    let store = test_store(400, 4, 61);
    let (w, h) = (store.width, store.height);
    let (_handles, addrs) = spawn_servers(&store, 2);
    let net = NetRouterEngine::connect(Arc::clone(&store), &addrs, 2).expect("connect");
    let names = vec!["local".to_string(), "server-0".to_string(), "server-1".to_string()];
    let mut c = Collector::new(CollectorConfig { window_s: 0.05, ..Default::default() }, names);
    let t0 = std::time::Instant::now();
    let mut rng = Rng::new(5);
    let mut i = 0usize;
    while t0.elapsed().as_secs_f64() < 0.28 {
        let q = fuzz_query(&mut rng, w, h, i);
        let resp = net.call(Request::new(q));
        assert_eq!(resp.trace.outcome, Outcome::Served, "query {i}");
        i += 1;
        let mut src = |_t: f64| {
            let mut v = vec![Some(net.obs_snapshot())];
            v.extend(net.scrape_nodes(Duration::from_millis(300)));
            v
        };
        c.tick(t0.elapsed().as_secs_f64(), &mut src);
    }
    let mut src = |_t: f64| {
        let mut v = vec![Some(net.obs_snapshot())];
        v.extend(net.scrape_nodes(Duration::from_millis(300)));
        v
    };
    c.finish(t0.elapsed().as_secs_f64(), &mut src);
    assert!(c.windows_closed() >= 4, "0.28s at 50ms windows, got {}", c.windows_closed());
    for (n, name) in c.names().iter().enumerate() {
        let t = c.node_timeline(n);
        assert_eq!(t.delta_total(), t.final_counters(), "row {name:?} must conserve");
        assert_eq!(t.gaps(), 0, "row {name:?} gapped with every server alive");
    }
    let cl = c.cluster();
    assert_eq!(cl.delta_total(), cl.final_counters(), "cluster fold must conserve");
    // the scrapes were real: both server rows counted wire frames
    for n in 1..=2usize {
        let frames = c.node_timeline(n).final_counters().get("net_frames").copied().unwrap_or(0);
        assert!(frames > 0, "server row {n} scraped no net_frames");
    }
}

/// Satellite acceptance (tcp tier): a cancelled hedge stops consuming
/// server-side work and is counted. The in-order frame pipe makes the
/// probe exact — the Cancel is written before the loser's Execute, so
/// the server drops the batch before any shard runs:
/// `stage_shard_execute` counts only the real executions and
/// `hedge_cancels` counts the drop.
#[test]
fn tcp_cancelled_hedge_consumes_no_server_work_and_is_counted() {
    let store = test_store(300, 4, 19);
    let server = ShardServer::bind(Arc::clone(&store), "127.0.0.1:0").expect("bind");
    let addr = server.local_addr();
    let _handle = server.spawn();
    let conn = NetConn::new(addr.to_string());
    let q = Query::BrightestN { n: 3, filter: SourceFilter::Any };
    // three real executions: the baseline server-side work
    for i in 0..3 {
        conn.execute(vec![(0, vec![q.clone()])], 0, None)
            .unwrap_or_else(|e| panic!("warm execute {i}: {e}"));
    }
    // the hedge race resolved: the winner's reply landed elsewhere, so
    // the loser (trace 42) is cancelled before its Execute is sent
    conn.cancel(42);
    let (replies, _, _) = conn
        .execute_traced(vec![(0, vec![q.clone()]), (2, vec![q.clone()])], 0, 42, None)
        .expect("a cancelled batch still answers — the reply is discarded, not errored");
    assert_eq!(replies.len(), 2, "the drop's reply mirrors the request shape");
    let snap = conn.scrape(None).expect("scrape");
    assert_eq!(snap.counter("hedge_cancels"), 1, "the drop is counted");
    assert_eq!(
        snap.histograms["stage_shard_execute"].n,
        3,
        "the cancelled batch consumed zero shard-execution work"
    );
    // cancellation is one-shot: the same trace id executes normally next
    let (replies, _, _) = conn
        .execute_traced(vec![(0, vec![q.clone()])], 0, 42, None)
        .expect("post-cancel execute");
    assert_eq!(replies[0][0], execute_on_shard(&store.shards[0], &q));
    let snap = conn.scrape(None).expect("scrape");
    assert_eq!(snap.counter("hedge_cancels"), 1, "no double count");
    assert_eq!(snap.histograms["stage_shard_execute"].n, 4, "the reused id ran for real");
}

/// Tentpole acceptance (tcp tier): the control plane swaps the routing
/// placement live. Every server loads the full catalog, so migration
/// is a pure routing change — instant, byte-parity preserved, counted
/// in `net_migrations` — and after the swap new work concentrates on
/// the target server while the drained server sees none.
#[test]
fn tcp_rebalance_swaps_routing_live_with_parity() {
    use celeste::serve::dist::Placement;
    let store = test_store(600, 16, 83);
    let (w, h) = (store.width, store.height);
    let (_handles, addrs) = spawn_servers(&store, 2);
    let net = NetRouterEngine::connect(Arc::clone(&store), &addrs, 1).expect("connect");
    let mut rng = Rng::new(9);
    for i in 0..20usize {
        let q = fuzz_query(&mut rng, w, h, i);
        let resp = net.call(Request::new(q));
        assert_eq!(resp.trace.outcome, Outcome::Served, "warm query {i}");
    }
    let loads0 = net.node_loads();
    assert!(loads0.iter().all(|l| l.alive), "both servers live");
    assert!(loads0.iter().map(|l| l.served).sum::<u64>() > 0, "warm traffic was counted");
    assert!(net.served_per_shard().iter().sum::<u64>() > 0, "per-shard demand was counted");
    // drain whichever server hosts fewer shards onto the other one
    let p0 = net.placement();
    let counts = p0.counts_per_node();
    let dst = if counts[0] >= counts[1] { 0usize } else { 1 };
    let src = 1 - dst;
    assert!(counts[src] > 0, "rendezvous left server {src} empty — pick different shards");
    let target = Placement {
        n_nodes: 2,
        replicas: 1,
        shard_nodes: vec![vec![dst]; store.shards.len()],
    };
    let moved = net.rebalance_to(target).expect("shape matches");
    assert_eq!(moved, counts[src] as u64, "exactly the drained server's shards moved");
    assert_eq!(net.migrations(), moved);
    // parity holds across the swap and the drained server goes quiet
    let src_before = net.node_loads()[src].served;
    for i in 20..60usize {
        let q = fuzz_query(&mut rng, w, h, i);
        let want = execute(&store, &q);
        let resp = net.call(Request::new(q.clone()));
        assert_eq!(resp.trace.outcome, Outcome::Served, "post-swap query {i}");
        assert_eq!(resp.result.expect("served"), want, "post-swap query {i}: {q:?}");
    }
    let loads1 = net.node_loads();
    assert_eq!(loads1[src].served, src_before, "all post-swap work routes to server {dst}");
    assert!(loads1[dst].served > loads0[dst].served, "the target server absorbed it");
    // a mis-shapen target is refused, not applied
    assert!(net.rebalance_to(Placement::rendezvous(store.shards.len(), 3, 1)).is_err());
    let m: std::collections::BTreeMap<String, f64> = net.metrics().into_iter().collect();
    assert_eq!(m["net_migrations"], moved as f64);
    assert_eq!(m["net_failed"], 0.0, "the swap failed nothing");
}

/// The `ShardClient` trait adapter: a real socket standing where the
/// simulated `LocalShard`/`FabricShard` replicas do, returning the
/// same replies `execute_on_shard` computes.
#[test]
fn net_shard_client_serves_through_the_trait_seam() {
    let store = test_store(400, 4, 17);
    let (w, h) = (store.width, store.height);
    let server = ShardServer::bind(Arc::clone(&store), "127.0.0.1:0").expect("bind");
    let addr = server.local_addr();
    let _handle = server.spawn();
    let conn = Arc::new(NetConn::new(addr.to_string()));
    let mut fabric = Fabric::new(FabricConfig::default(), 2);
    let mut node_free = vec![0.0f64; 2];
    let mut rng = Rng::new(29);
    for shard in 0..store.shards.len() {
        let client = NetShardClient::new(Arc::clone(&conn), 1, shard as u32);
        assert_eq!(client.node(), 1);
        for i in 0..4usize {
            let q = fuzz_query(&mut rng, w, h, shard * 4 + i);
            let want = execute_on_shard(&store.shards[shard], &q);
            let (reply, done) =
                client.call(1.0, 0, &q, &store.shards[shard], &mut fabric, &mut node_free);
            assert_eq!(reply, want, "shard {shard} query {i}: {q:?}");
            assert!(done >= 1.0, "completion time advances from now");
        }
    }
}
