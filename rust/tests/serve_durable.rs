//! Acceptance tests for the durable publish log (`serve::durable`):
//!
//! * a real shard-server *process* killed with SIGKILL mid-publish
//!   restarts from its WAL alone and answers queries byte-identically
//!   to the last-write-wins mirror at whatever epoch it durably acked;
//! * compaction's re-split moves only re-keyed ranges through the
//!   keyed rendezvous placement (the minimal-movement property), and
//!   shards the re-split never touched stay Arc-shared;
//! * snapshot edge cases — an empty store, a single-row shard, a shard
//!   whose key range was widened by ingestion — round-trip losslessly
//!   through both `snapshot.rs` and a WAL checkpoint.

use std::sync::Arc;

use celeste::prng::Rng;
use celeste::serve::dist::Placement;
use celeste::serve::durable::skew;
use celeste::serve::net::NetConn;
use celeste::serve::{
    self, catalog_checksum, execute_on_shard, fuzz_query, Compactor, DriftConfig, DriftGen,
    DurableLog, Ingestor, ServedSource, Store, VersionedStore,
};

fn test_store(n: usize, shards: usize, seed: u64) -> Arc<Store> {
    let snap = serve::snapshot::synthetic(n, seed);
    Arc::new(Store::build(snap.sources, snap.width, snap.height, shards))
}

/// Kills children on drop so a failing test cannot leak shard-server
/// processes past the test run.
struct Reap(Vec<std::process::Child>);

impl Drop for Reap {
    fn drop(&mut self) {
        for c in &mut self.0 {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

/// Read a shard-server child's announce lines: the optional
/// 'shard-server recovered ...' report, then the listening line.
fn read_announce(stdout: std::process::ChildStdout) -> (String, Option<String>) {
    use std::io::BufRead;
    let mut reader = std::io::BufReader::new(stdout);
    let mut recovered = None;
    for _ in 0..16 {
        let mut line = String::new();
        if reader.read_line(&mut line).expect("read announce") == 0 {
            break;
        }
        let line = line.trim();
        if line.contains("listening on") {
            let addr = line.rsplit(' ').next().expect("addr token").to_string();
            assert!(addr.contains(':'), "bad announce line: {line:?}");
            return (addr, recovered);
        }
        if line.starts_with("shard-server recovered") {
            recovered = Some(line.to_string());
        }
    }
    panic!("shard-server exited before announcing a listening address");
}

fn announce_field(line: &str, key: &str) -> String {
    line.split_whitespace()
        .find_map(|w| w.strip_prefix(&format!("{key}=")))
        .unwrap_or_else(|| panic!("recovery line missing {key}=: {line:?}"))
        .to_string()
}

/// Tentpole acceptance: kill -9 a durable shard-server mid-publish,
/// restart it with the same --wal-dir, and the recovered catalog is
/// byte-identical to the last-write-wins mirror at the recovered epoch
/// — proven twice, by checksum and by per-shard query parity.
#[test]
fn kill_nine_mid_publish_recovers_byte_identical_to_the_mirror() {
    let shards = 6usize;
    let store = test_store(600, shards, 4071);
    let (w, h) = (store.width, store.height);
    let tag = format!("celeste-durable-test-{}", std::process::id());
    let snap_path = std::env::temp_dir().join(format!("{tag}.json"));
    let wal_dir = std::env::temp_dir().join(format!("{tag}-wal"));
    std::fs::remove_dir_all(&wal_dir).ok();
    serve::snapshot::save(&snap_path, &store).expect("write snapshot");

    // the whole drift stream is generated up front so the mirror's
    // checksum at *every* epoch is known before the crash happens
    let mut drift = DriftGen::new(
        &store.all_sources(),
        w,
        h,
        DriftConfig { batch: 24, seed: 17, ..Default::default() },
    );
    let total_epochs = 14u64;
    let mut batches: Vec<Vec<ServedSource>> = Vec::new();
    let mut sums = vec![catalog_checksum(drift.mirror())]; // epoch 0
    for _ in 0..total_epochs {
        batches.push(drift.next_batch());
        sums.push(catalog_checksum(drift.mirror()));
    }

    let exe = env!("CARGO_BIN_EXE_celeste");
    let mut reap = Reap(Vec::new());
    let mut child = std::process::Command::new(exe)
        .arg("shard-server")
        .arg("--snapshot")
        .arg(&snap_path)
        .arg("--wal-dir")
        .arg(&wal_dir)
        .args(["--checkpoint-every", "4"])
        .args(["--shards", &shards.to_string(), "--listen", "127.0.0.1:0"])
        .stdout(std::process::Stdio::piped())
        .spawn()
        .expect("spawn durable shard-server");
    let stdout = child.stdout.take().expect("piped");
    reap.0.push(child);
    let (addr, recovered) = read_announce(stdout);
    assert!(recovered.is_none(), "a fresh WAL dir must not report a recovery");

    // phase 1: six epochs acked — each ack means fsynced, so all six
    // MUST survive the kill
    let conn = NetConn::new(addr);
    let acked = 6u64;
    for e in 1..=acked {
        conn.publish(e, &batches[(e - 1) as usize], None)
            .unwrap_or_else(|err| panic!("publish epoch {e}: {err}"));
    }
    // phase 2: keep publishing from another thread while the main
    // thread SIGKILLs the process — the canonical mid-publish crash.
    // Failures here are expected and ignored; acks past `acked` are
    // durable too, so any recovered epoch in [acked, total] is legal.
    let publisher = {
        let batches = batches.clone();
        let conn = NetConn::new(conn.addr().to_string());
        std::thread::spawn(move || {
            for e in (acked + 1)..=total_epochs {
                if conn
                    .publish(e, &batches[(e - 1) as usize], Some(std::time::Duration::from_secs(2)))
                    .is_err()
                {
                    break;
                }
            }
        })
    };
    std::thread::sleep(std::time::Duration::from_millis(25));
    reap.0[0].kill().expect("SIGKILL the shard-server");
    let _ = reap.0[0].wait();
    publisher.join().expect("publisher thread");

    // restart from the WAL alone: no --snapshot
    let mut child = std::process::Command::new(exe)
        .arg("shard-server")
        .arg("--wal-dir")
        .arg(&wal_dir)
        .args(["--checkpoint-every", "4"])
        .args(["--shards", &shards.to_string(), "--listen", "127.0.0.1:0"])
        .stdout(std::process::Stdio::piped())
        .spawn()
        .expect("restart shard-server");
    let stdout = child.stdout.take().expect("piped");
    reap.0.push(child);
    let (addr, recovered) = read_announce(stdout);
    let line = recovered.expect("restart must report a WAL recovery");
    let epoch: u64 = announce_field(&line, "epoch").parse().expect("epoch");
    let checksum = u64::from_str_radix(&announce_field(&line, "checksum"), 16).expect("checksum");
    assert!(
        epoch >= acked && epoch <= total_epochs,
        "recovered epoch {epoch} must cover every acked epoch (>= {acked})"
    );
    assert_eq!(
        checksum, sums[epoch as usize],
        "recovered catalog must hash exactly like the mirror at epoch {epoch}"
    );

    // byte parity the long way: rebuild the reference store by applying
    // the same deltas in-process, then compare per-shard query replies
    let versioned = Arc::new(VersionedStore::new(Arc::clone(&store)));
    let mut ing = Ingestor::new(Arc::clone(&versioned));
    for b in &batches[..epoch as usize] {
        ing.apply(b);
    }
    let want_head = versioned.load();
    assert_eq!(want_head.epoch, epoch);
    let conn = NetConn::new(addr);
    let mut rng = Rng::new(92);
    for i in 0..20usize {
        let q = fuzz_query(&mut rng, w, h, i);
        for shard in 0..shards {
            let want = execute_on_shard(&want_head.store.shards[shard], &q);
            let replies = conn
                .execute(vec![(shard as u32, vec![q.clone()])], epoch, None)
                .unwrap_or_else(|e| panic!("query {i} shard {shard}: {e}"));
            assert_eq!(replies[0][0], want, "query {i} shard {shard}: {q:?}");
        }
    }
    std::fs::remove_file(&snap_path).ok();
    std::fs::remove_dir_all(&wal_dir).ok();
}

/// Tentpole acceptance (minimal movement): under sustained hotspot
/// ingestion the compactor re-splits hot ranges, and the keyed
/// rendezvous placement moves ONLY ranges whose identifying key
/// changed — every surviving key keeps its exact replica set. Shards
/// the re-split never rebuilt stay Arc-shared with the prior epoch.
#[test]
fn compaction_moves_only_resplit_ranges_under_keyed_rendezvous() {
    let store = test_store(500, 8, 1213);
    let (w, h) = (store.width, store.height);
    let versioned = Arc::new(VersionedStore::new(Arc::clone(&store)));
    let mut ing = Ingestor::new(Arc::clone(&versioned));
    let mut drift = DriftGen::new(
        &store.all_sources(),
        w,
        h,
        DriftConfig {
            batch: 60,
            update_fraction: 0.1,
            hotspot: 0.95,
            seed: 31,
            ..Default::default()
        },
    );
    let threshold = 1.6;
    let mut compactor = Compactor::new(threshold, 3);
    let mut fired = false;
    for _ in 0..60 {
        ing.apply(&drift.next_batch());
        if compactor.observe(&versioned.load().store) {
            fired = true;
            break;
        }
    }
    assert!(fired, "hotspot ingestion must eventually trip the compactor");

    let before = versioned.load();
    let skew_before = skew(&before.store);
    assert!(skew_before > threshold, "trigger implies skew, got {skew_before:.2}");
    let keys_before: Vec<u64> = before.store.shards.iter().map(|s| s.key_lo).collect();

    let rep = ing.compact(threshold).expect("skewed store must produce a re-split");
    let after = versioned.load();
    assert_eq!(after.epoch, before.epoch + 1, "compaction publishes one epoch");
    assert!(rep.splits >= 1, "at least one hot range splits");
    assert!(rep.skew_after < rep.skew_before, "compaction must reduce skew");
    assert_eq!(
        after.store.all_sources(),
        before.store.all_sources(),
        "compaction moves rows between shards, never changes the catalog"
    );
    let keys_after: Vec<u64> = after.store.shards.iter().map(|s| s.key_lo).collect();
    assert_eq!(keys_after.len(), keys_before.len(), "shard count is conserved");

    // the minimal-movement property, across several cluster shapes:
    // a key present on both sides keeps its exact replica set
    for (n_nodes, replicas) in [(5usize, 2usize), (7, 3), (9, 2)] {
        let nodes: Vec<usize> = (0..n_nodes).collect();
        let p_before = Placement::rendezvous_keyed(&keys_before, n_nodes, &nodes, replicas);
        let p_after = Placement::rendezvous_keyed(&keys_after, n_nodes, &nodes, replicas);
        let mut moved = 0usize;
        for (i, k) in keys_after.iter().enumerate() {
            match keys_before.iter().position(|kb| kb == k) {
                Some(j) => assert_eq!(
                    p_after.replicas_of(i),
                    p_before.replicas_of(j),
                    "surviving key {k:#x} must keep its replica set ({n_nodes} nodes)"
                ),
                None => moved += 1,
            }
        }
        assert!(moved >= 1, "a re-split mints at least one new key");
        assert!(
            moved <= 2 * (rep.splits + rep.merges + rep.absorbed),
            "moved {moved} ranges, but only {} split(s) {} merge(s) {} absorb(s) happened",
            rep.splits,
            rep.merges,
            rep.absorbed
        );
    }

    // copy-on-write discipline: shards the re-split never rebuilt are
    // the same allocation in both epochs
    let shared = after
        .store
        .shards
        .iter()
        .filter(|sa| before.store.shards.iter().any(|sb| Arc::ptr_eq(sa, sb)))
        .count();
    assert!(
        shared >= 1,
        "a partial re-split must share untouched shards with the prior epoch"
    );
}

/// Round-trip one store through `snapshot.rs` (flat jsonlite) and
/// assert the reloaded catalog is byte-identical.
fn assert_snapshot_roundtrip(store: &Store, shards: usize, tag: &str) {
    let path = std::env::temp_dir().join(format!(
        "celeste-snap-edge-{}-{tag}.json",
        std::process::id()
    ));
    serve::snapshot::save(&path, store).expect("save snapshot");
    let back = serve::snapshot::load(&path).expect("load snapshot").into_store(shards);
    assert_eq!(back.all_sources(), store.all_sources(), "{tag}: snapshot must be lossless");
    assert_eq!(back.width, store.width, "{tag}");
    assert_eq!(back.height, store.height, "{tag}");
    std::fs::remove_file(&path).ok();
}

/// Round-trip a versioned head through a WAL checkpoint (create →
/// recover) and assert catalog bytes AND per-shard layout survive.
fn assert_checkpoint_roundtrip(versioned: &Arc<VersionedStore>, tag: &str) {
    let dir = std::env::temp_dir().join(format!(
        "celeste-ckpt-edge-{}-{tag}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    let head = versioned.load();
    {
        let _log = DurableLog::create(&dir, 0, &head).expect("create checkpoint");
    }
    let rec = DurableLog::recover(&dir, 0).expect("recover checkpoint");
    let back = rec.versioned.load();
    assert_eq!(back.epoch, head.epoch, "{tag}: checkpoint preserves the epoch");
    assert_eq!(
        back.store.all_sources(),
        head.store.all_sources(),
        "{tag}: checkpoint must be lossless"
    );
    let layout = |s: &Store| -> Vec<(u64, u64, usize)> {
        s.shards.iter().map(|sh| (sh.key_lo, sh.key_hi, sh.sources.len())).collect()
    };
    assert_eq!(
        layout(&back.store),
        layout(&head.store),
        "{tag}: checkpoint preserves the exact shard layout"
    );
    assert_eq!(rec.report.records_replayed, 0, "{tag}: a pure checkpoint replays nothing");
    std::fs::remove_dir_all(&dir).ok();
}

/// Satellite acceptance: the snapshot codec and the WAL checkpoint
/// both survive the shapes that break naive splitters — an empty
/// store, a single-row shard, and a shard whose key range ingestion
/// widened past its original bounds.
#[test]
fn snapshot_edge_cases_round_trip_through_snapshot_and_checkpoint() {
    // empty store: zero sources over several degenerate shards
    let empty = Store::build(Vec::new(), 64.0, 64.0, 4);
    assert_eq!(empty.len(), 0);
    assert_snapshot_roundtrip(&empty, 4, "empty");
    let v_empty = Arc::new(VersionedStore::new(Arc::new(empty)));
    assert_checkpoint_roundtrip(&v_empty, "empty");

    // single-row shard: one source, many shards — every shard but one
    // carries a degenerate key range
    let snap = serve::snapshot::synthetic(1, 77);
    let single = Store::build(snap.sources, snap.width, snap.height, 4);
    assert_eq!(single.len(), 1);
    assert!(single.shards.iter().any(|s| s.sources.len() == 1));
    assert_snapshot_roundtrip(&single, 4, "single");
    let v_single = Arc::new(VersionedStore::new(Arc::new(single)));
    assert_checkpoint_roundtrip(&v_single, "single");

    // widened key range: ingest fresh detections whose Hilbert keys
    // fall past the last shard's original key_hi — the edge shard must
    // absorb them by widening its range
    let store = test_store(300, 4, 909);
    let (w, h) = (store.width, store.height);
    let last = store.shards.len() - 1;
    let hi_before = store.shards[last].key_hi;
    let versioned = Arc::new(VersionedStore::new(Arc::clone(&store)));
    let mut ing = Ingestor::new(Arc::clone(&versioned));
    let mut drift = DriftGen::new(
        &store.all_sources(),
        w,
        h,
        DriftConfig { batch: 40, update_fraction: 0.0, seed: 5, ..Default::default() },
    );
    for _ in 0..6 {
        ing.apply(&drift.next_batch());
    }
    let head = versioned.load();
    assert!(
        head.store.shards[last].key_hi > hi_before
            || head.store.shards[0].key_lo < store.shards[0].key_lo,
        "uniform fresh detections must widen an edge shard's key range"
    );
    assert_eq!(head.store.all_sources(), drift.mirror_sorted());
    assert_snapshot_roundtrip(&head.store, 4, "widened");
    assert_checkpoint_roundtrip(&versioned, "widened");
}
