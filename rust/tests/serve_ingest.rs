//! Live-ingestion invariants (the acceptance criteria of the ingest
//! subsystem): after any ingestion schedule every tier and middleware
//! stack answers byte-identically to brute force over the final
//! epoch's catalog; a reader pinned to an old epoch sees that epoch's
//! answers exactly; fresh reads observe a publish immediately
//! (read-your-writes) while bounded reads tolerate exactly their lag
//! budget; and cache invalidation drops only entries covering mutated
//! shard ranges — untouched-range entries keep hitting.

use std::sync::Arc;

use celeste::prng::Rng;
use celeste::serve::dist::{Router, RouterConfig, Routing};
use celeste::serve::{
    self, execute, execute_scan, fuzz_query, plan_shards, Admission, Cached, DirectEngine,
    DriftConfig, DriftGen, Hedged, IngestDriver, Ingestor, Outcome, Query, QueryEngine, Request,
    RouterEngine, ScanEngine, ServedSource, Server, ServerConfig, ServerEngine, SourceFilter,
    Store, VersionedStore,
};

fn seed_store(n: usize, shards: usize, seed: u64) -> Arc<Store> {
    let snap = serve::snapshot::synthetic(n, seed);
    Arc::new(Store::build(snap.sources, snap.width, snap.height, shards))
}

/// Acceptance: run a drift ingestion schedule, then check that every
/// tier — live direct, live worker pool, and the replicated router
/// with all publishes shipped — behind several middleware stacks
/// answers byte-identically to a brute-force scan of the drift
/// generator's flat mirror (the independent reference for what the
/// final epoch's catalog must contain).
#[test]
fn every_tier_matches_bruteforce_over_the_final_epoch() {
    let store = seed_store(1200, 8, 71);
    let (w, h) = (store.width, store.height);
    let versioned = Arc::new(VersionedStore::new(Arc::clone(&store)));
    let drift = DriftGen::new(
        &store.all_sources(),
        w,
        h,
        DriftConfig { batch: 40, seed: 7, ..Default::default() },
    );
    let mut driver = IngestDriver::new(Ingestor::new(Arc::clone(&versioned)), drift, 100.0, 7);
    // the router is told about every publish so replicas converge
    let rengine = RouterEngine::new(Router::new(
        Arc::clone(&store),
        4,
        2,
        RouterConfig { routing: Routing::PowerOfTwo, ..Default::default() },
    ));
    let mut t = 0.0;
    while t < 0.2 {
        for rep in driver.tick(t) {
            rengine.publish(t, &rep);
        }
        t += 0.005;
    }
    let epochs = driver.publishes;
    assert!(epochs >= 5, "schedule too short: {epochs} publishes");
    let mirror = driver.mirror_sorted();
    let head = versioned.load();
    assert_eq!(head.epoch, epochs);
    assert_eq!(head.store.all_sources(), mirror, "store must track the mirror");

    let server = Arc::new(Server::start_live(
        Arc::clone(&versioned),
        ServerConfig { threads: 2, ..Default::default() },
    ));
    // query far past every delta shipment: all replicas caught up
    let t_query = 1000.0;
    for tier_id in 0..4usize {
        for arrangement in 0..3usize {
            let base: Box<dyn QueryEngine> = match tier_id {
                0 => Box::new(ScanEngine::new(mirror.clone())),
                1 => Box::new(DirectEngine::live(Arc::clone(&versioned))),
                2 => Box::new(ServerEngine::new(Arc::clone(&server))),
                _ => Box::new(rengine.clone()),
            };
            let engine: Box<dyn QueryEngine> = match arrangement {
                0 => base,
                1 => Box::new(Cached::new(Hedged::new(base, 1e-6), 64)),
                _ => Box::new(Admission::new(Cached::new(base, 64), 1 << 20)),
            };
            let mut rng = Rng::new(3 + tier_id as u64 * 11 + arrangement as u64);
            let mut now = t_query;
            for i in 0..30usize {
                let q = fuzz_query(&mut rng, w, h, i);
                let want = execute_scan(&mirror, &q);
                for repeat in 0..2 {
                    let resp = engine.call(Request::new(q.clone()).arriving_at(now));
                    assert_eq!(
                        resp.trace.outcome,
                        Outcome::Served,
                        "tier {tier_id} arrangement {arrangement} query {i} repeat {repeat}"
                    );
                    assert_eq!(
                        resp.result.as_ref().expect("served"),
                        &want,
                        "tier {tier_id} arrangement {arrangement} query {i} repeat {repeat}: {q:?}"
                    );
                    now += 1e-4;
                }
            }
        }
    }
    let _ = server.shutdown();
}

/// Acceptance: a reader pinned to an old epoch keeps seeing exactly
/// that epoch's answers, no matter how much is published after it.
#[test]
fn pinned_reader_sees_its_epoch_exactly() {
    let store = seed_store(800, 6, 23);
    let (w, h) = (store.width, store.height);
    let versioned = Arc::new(VersionedStore::new(Arc::clone(&store)));
    let mut ing = Ingestor::new(Arc::clone(&versioned));
    let drift_cfg = DriftConfig { batch: 30, seed: 19, ..Default::default() };
    let mut drift = DriftGen::new(&store.all_sources(), w, h, drift_cfg);
    // advance two epochs, pin, advance five more
    ing.apply(&drift.next_batch());
    ing.apply(&drift.next_batch());
    let pinned = versioned.load();
    let frozen = pinned.store.all_sources();
    assert_eq!(pinned.epoch, 2);
    for _ in 0..5 {
        ing.apply(&drift.next_batch());
    }
    assert_eq!(versioned.epoch(), 7);
    let mut rng = Rng::new(4);
    for i in 0..40usize {
        let q = fuzz_query(&mut rng, w, h, i);
        assert_eq!(
            execute(&pinned.store, &q),
            execute_scan(&frozen, &q),
            "pinned epoch drifted on query {i}: {q:?}"
        );
    }
    // and the head serves the drift mirror, not the pinned view
    let head = versioned.load();
    assert_eq!(head.store.all_sources(), drift.mirror_sorted());
}

/// Acceptance: invalidation is per mutated range. An entry whose plan
/// covers the mutated shard is dropped (and re-executes against the
/// new epoch); an entry over untouched ranges keeps hitting across the
/// publish. Bounded-staleness requests may still ride the old entry.
#[test]
fn cache_invalidation_drops_only_entries_covering_mutated_ranges() {
    let store = seed_store(1000, 8, 37);
    let versioned = Arc::new(VersionedStore::new(Arc::clone(&store)));
    let engine = Cached::new(DirectEngine::live(Arc::clone(&versioned)), 64);

    // q_a: a tight cone around a shard-0 source (plan = {0}); the delta
    // will re-estimate that very source in place. q_b: a tight cone in
    // some other shard whose plan avoids shard 0 entirely.
    let victim = store.shards[0].sources[0].clone();
    let q_a = Query::Cone { center: victim.pos, radius: 1.5, filter: SourceFilter::Any };
    let plan_a = plan_shards(&store, &q_a);
    assert!(plan_a.contains(&0), "probe around a shard-0 member must plan shard 0");
    let q_b = (1..store.shards.len())
        .rev()
        .filter(|&i| !store.shards[i].sources.is_empty())
        .find_map(|i| {
            let s = &store.shards[i].sources[0];
            let q = Query::Cone { center: s.pos, radius: 1.5, filter: SourceFilter::Any };
            let plan = plan_shards(&store, &q);
            if plan.iter().all(|p| !plan_a.contains(p)) {
                Some(q)
            } else {
                None
            }
        })
        .expect("some shard plans disjointly from q_a");

    // fill both entries
    let a0 = engine.call(Request::new(q_a.clone()));
    let b0 = engine.call(Request::new(q_b.clone()));
    assert!(!a0.trace.cache_hit && !b0.trace.cache_hit);
    assert!(engine.call(Request::new(q_a.clone())).trace.cache_hit);
    assert_eq!(engine.hits(), 1);

    // publish an in-place re-estimate of the victim (same position =>
    // same shard, shard 0 is the only touched range)
    let mut ing = Ingestor::new(Arc::clone(&versioned));
    let delta = ServedSource { flux_r: victim.flux_r * 3.0 + 1.0, ..victim.clone() };
    let rep = ing.apply(&[delta]);
    assert_eq!(rep.touched.iter().map(|&(s, _)| s).collect::<Vec<_>>(), vec![0]);

    // bounded staleness first: the old entry may still serve a reader
    // tolerating one epoch of lag
    let stale_ok = engine.call(Request::new(q_a.clone()).at_most(1));
    assert!(stale_ok.trace.cache_hit, "AtMost(1) must accept the 1-epoch-old entry");
    assert_eq!(stale_ok.result.as_ref().unwrap(), a0.result.as_ref().unwrap());

    // epoch-exact probe: the mutated-range entry is invalidated and the
    // re-execution reflects the new epoch
    let inv0 = engine.invalidations();
    let a1 = engine.call(Request::new(q_a.clone()));
    assert!(!a1.trace.cache_hit, "mutated-range entry must not hit");
    assert_eq!(engine.invalidations(), inv0 + 1, "exactly one entry invalidated");
    let head = versioned.load();
    assert_eq!(a1.result.as_ref().unwrap(), &execute(&head.store, &q_a));
    assert_ne!(
        a1.result.as_ref().unwrap(),
        a0.result.as_ref().unwrap(),
        "the re-estimate must be visible"
    );

    // the untouched-range entry still hits across the publish
    let b1 = engine.call(Request::new(q_b.clone()));
    assert!(b1.trace.cache_hit, "untouched-range entry must keep hitting");
    assert_eq!(b1.result.as_ref().unwrap(), b0.result.as_ref().unwrap());
    // and the refilled q_a entry hits again at the new epoch
    assert!(engine.call(Request::new(q_a)).trace.cache_hit);
}

/// Read-your-writes through the full engine stack: a Fresh request
/// issued immediately after a publish observes the delta even though
/// no replica has applied it yet, while the cache still serves the
/// bounded-staleness reader its (valid) old entry.
#[test]
fn fresh_reads_through_the_stack_observe_the_publish() {
    let store = seed_store(900, 6, 53);
    let versioned = Arc::new(VersionedStore::new(Arc::clone(&store)));
    let rengine = RouterEngine::new(Router::new(
        Arc::clone(&store),
        4,
        2,
        RouterConfig::default(),
    ));
    let engine = Cached::new(rengine.clone(), 64);
    let q = Query::BrightestN { n: 1, filter: SourceFilter::Any };
    let before = engine.call(Request::new(q.clone()).arriving_at(0.5));
    assert_eq!(before.trace.outcome, Outcome::Served);

    // a new all-sky-brightest source publishes at t = 1.0
    let mut ing = Ingestor::new(Arc::clone(&versioned));
    let delta = ServedSource {
        id: 555_555,
        pos: (store.width * 0.25, store.height * 0.25),
        p_gal: 0.0,
        flux_r: 1e12,
        flux_logsd: 0.02,
        colors: [0.0; 4],
        converged: true,
    };
    let rep = ing.apply(&[delta]);
    rengine.publish(1.0, &rep);

    let head = versioned.load();
    let want = execute(&head.store, &q);
    // fresh read just after the publish: must contain the new source
    let fresh = engine.call(Request::new(q.clone()).fresh().arriving_at(1.0 + 1e-9));
    assert!(!fresh.trace.cache_hit);
    assert_eq!(fresh.result.as_ref().expect("served"), &want);
    // brightest-N plans every shard, so the old entry covers the
    // mutated range: a default read right after re-executes (and a
    // replica may still lag) — but far in the future it must equal the
    // head exactly
    let late = engine.call(Request::new(q).arriving_at(100.0));
    assert_eq!(late.result.as_ref().expect("served"), &want);
}
