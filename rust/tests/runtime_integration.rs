//! End-to-end integration of the AOT bridge: artifacts → PJRT → ELBO →
//! trust-region Newton inference on synthetic data.
//!
//! Requires `make artifacts` (tests skip with a notice otherwise).
//! Compiling the autodiff artifact dominates wall time, so checks are
//! grouped into a few test functions that share one `Runtime`.

use celeste::imaging::{extract_patch, render_field, Patch, Survey, SurveyConfig};
use celeste::linalg::norm2;
use celeste::model::layout as L;
use celeste::model::{
    extract_estimate, galaxy_comps, render_mixture, theta_init, GalaxyShape, PixelRect, Prior,
    SourceParams,
};
use celeste::optim::{lbfgs, newton_tr, LbfgsConfig, NewtonConfig, NewtonObjective};
use celeste::prng::Rng;
use celeste::runtime::{ElboEngine, LikeEngine, Runtime, SourceObjective};

fn artifacts_ready() -> bool {
    let dir = celeste::runtime::default_artifact_dir();
    let ok = dir.join("manifest.json").exists();
    if !ok {
        eprintln!("SKIP: no artifacts at {dir:?}; run `make artifacts`");
    }
    ok
}

/// One bright source in the middle of a small two-epoch survey.
fn scene(truth: &SourceParams, seed: u64) -> Vec<Patch> {
    let survey = Survey::layout(SurveyConfig {
        sky_width: 96.0,
        sky_height: 96.0,
        field_w: 96,
        field_h: 96,
        n_epochs: 2,
        jitter: 0.0,
        ..Default::default()
    });
    let mut rng = Rng::new(seed);
    survey
        .fields
        .iter()
        .map(|g| {
            let f = render_field(std::slice::from_ref(truth), g, &mut rng);
            extract_patch(&f, truth.pos, &[]).expect("patch")
        })
        .collect()
}

fn star_truth() -> SourceParams {
    SourceParams {
        pos: (48.3, 47.6),
        is_galaxy: false,
        flux_r: 4000.0,
        colors: [0.4, 0.3, 0.15, 0.1],
        shape: GalaxyShape::point_like(),
    }
}

fn galaxy_truth() -> SourceParams {
    SourceParams {
        pos: (48.1, 48.4),
        is_galaxy: true,
        flux_r: 6000.0,
        colors: [0.8, 0.5, 0.3, 0.2],
        shape: GalaxyShape { p_dev: 0.3, axis_ratio: 0.5, angle: 0.9, scale: 2.5 },
    }
}

/// Fast checks that only need the small artifacts (kl, render).
#[test]
fn manifest_kl_and_render_parity() {
    if !artifacts_ready() {
        return;
    }
    let dir = celeste::runtime::default_artifact_dir();
    let rt = Runtime::load_subset(&dir, &[L::ART_KL, L::ART_RENDER]).expect("load subset");
    assert!(rt.has(L::ART_KL) && rt.has(L::ART_RENDER));
    assert!(!rt.has(L::ART_LIKE_AD));

    // --- manifest signatures ---
    let sig = rt.manifest.get(L::ART_LIKE_AD).unwrap();
    assert_eq!(sig.inputs[0].shape, vec![L::DIM]);
    assert_eq!(sig.outputs[2].shape, vec![L::DIM, L::DIM]);

    // --- KL is ~0 at the prior-matching θ, positive away from it ---
    let prior = Prior::default();
    let engine = ElboEngine::new(&rt, &prior);
    let mut t = [0.0f64; L::DIM];
    t[L::I_A] = (prior.p_gal / (1.0 - prior.p_gal)).ln();
    t[L::I_FLUX_STAR] = prior.flux_star.0;
    t[L::I_FLUX_STAR + 1] = prior.flux_star.1.ln();
    t[L::I_FLUX_GAL] = prior.flux_gal.0;
    t[L::I_FLUX_GAL + 1] = prior.flux_gal.1.ln();
    for i in 0..4 {
        t[L::I_COLOR_MEAN_STAR + i] = prior.color_mean_star[i];
        t[L::I_COLOR_MEAN_GAL + i] = prior.color_mean_gal[i];
        t[L::I_COLOR_VAR_STAR + i] = prior.color_var_star[i].ln();
        t[L::I_COLOR_VAR_GAL + i] = prior.color_var_gal[i].ln();
    }
    t[L::I_SHAPE] = L::SHAPE_PRIOR_PDEV.0;
    t[L::I_SHAPE + 1] = L::SHAPE_PRIOR_AXIS.0;
    t[L::I_SHAPE + 3] = L::SHAPE_PRIOR_SCALE.0;
    let (kl0, grad, hess) = engine.kl_vgh(&t).unwrap();
    assert!(kl0.abs() < 1e-4, "kl at prior = {kl0}");
    assert!(grad.iter().all(|g| g.is_finite()));
    assert!(hess.data.iter().all(|h| h.is_finite()));
    let mut t2 = t;
    t2[L::I_FLUX_STAR] += 1.5;
    let (kl2, _, _) = engine.kl_vgh(&t2).unwrap();
    assert!(kl2 > kl0 + 0.05, "kl must grow away from prior: {kl0} -> {kl2}");

    // --- Rust renderer vs the Pallas kernel artifact ---
    let psf = [
        [0.7, 0.0, 0.0, 1.1, 0.03, 1.0],
        [0.3, 0.1, -0.1, 2.6, -0.1, 2.4],
    ];
    let shape = GalaxyShape { p_dev: 0.35, axis_ratio: 0.55, angle: 0.8, scale: 2.2 };
    let comps = galaxy_comps((16.0, 16.0), &psf, &shape);
    let rect = PixelRect { x0: 0.0, y0: 0.0, rows: 32, cols: 32 };
    let rust_img = render_mixture(&rect, &comps, 1.0);
    let flat: Vec<f64> = comps.iter().flat_map(|c| c.iter().copied()).collect();
    let pallas_img = engine.render_pallas(&flat).unwrap();
    assert_eq!(pallas_img.len(), 32 * 32);
    let peak = rust_img.iter().cloned().fold(0.0f64, f64::max);
    for (i, (a, b)) in rust_img.iter().zip(&pallas_img).enumerate() {
        assert!(
            (a - *b as f64).abs() < 1e-4 * peak.max(1e-6),
            "pixel {i}: rust {a} pallas {b}"
        );
    }
}

/// Everything that needs the likelihood artifacts, sharing one Runtime.
#[test]
fn elbo_bridge_and_inference() {
    if !artifacts_ready() {
        return;
    }
    let rt = celeste::runtime::load_default().expect("runtime");
    let engine = ElboEngine::new(&rt, &Prior::default());

    // ---------------------------------------------------------------
    // 1. Gradient sanity: directional finite difference along g.
    //    (f32 artifact at |f| ~ 1e6: only the directional signal is
    //    above the rounding floor.)
    // ---------------------------------------------------------------
    let star = star_truth();
    let patches = scene(&star, 11);
    let t0 = theta_init(&star, 0.3);
    let p0 = &patches[0];
    let (_, g, _) = engine.like_vgh(&t0, p0).unwrap();
    let gn = norm2(&g);
    assert!(gn > 0.0 && gn.is_finite());
    let eps = (300.0 / gn).min(0.05);
    let dir: Vec<f64> = g.iter().map(|x| x / gn).collect();
    let tp: Vec<f64> = t0.iter().zip(&dir).map(|(a, d)| a + eps * d).collect();
    let tm: Vec<f64> = t0.iter().zip(&dir).map(|(a, d)| a - eps * d).collect();
    let mut tpa = [0.0; L::DIM];
    tpa.copy_from_slice(&tp);
    let mut tma = [0.0; L::DIM];
    tma.copy_from_slice(&tm);
    let (fp, _, _) = engine.like_vgh(&tpa, p0).unwrap();
    let (fm, _, _) = engine.like_vgh(&tma, p0).unwrap();
    let fd = (fp - fm) / (2.0 * eps);
    assert!(
        (fd - gn).abs() / gn < 0.05,
        "directional derivative {fd} vs ‖g‖ {gn}"
    );

    // ---------------------------------------------------------------
    // 2. Pallas manual-gradient artifact agrees with autodiff artifact.
    // ---------------------------------------------------------------
    for p in &patches {
        let (fa, ga, _) = engine.like_vgh(&t0, p).unwrap();
        let (fpl, gpl) = engine.like_vg_pallas(&t0, p).unwrap();
        assert!((fa - fpl).abs() / fa.abs().max(1.0) < 1e-4, "value {fa} vs {fpl}");
        let gmax = ga.iter().fold(0.0f64, |m, g| m.max(g.abs()));
        for (a, b) in ga.iter().zip(&gpl) {
            assert!((a - b).abs() < 5e-3 * gmax.max(1.0), "grad {a} vs {b}");
        }
    }

    // ---------------------------------------------------------------
    // 3. Newton recovers a star (params + classification), ≤ 50 iters.
    // ---------------------------------------------------------------
    let mut init = star.clone();
    init.flux_r *= 1.6;
    init.colors = [0.2, 0.2, 0.2, 0.2];
    let mut t_start = theta_init(&init, 0.5);
    t_start[L::I_LOC] = 0.8;
    t_start[L::I_LOC + 1] = -0.6;

    let fit = celeste::runtime::optimize_source(&engine, &patches, &t_start, &NewtonConfig::default());
    assert!(fit.result.converged(), "stop: {:?}", fit.result.stop);
    assert!(
        fit.result.iterations <= 50,
        "paper: Newton reaches tolerance within 50 iterations; took {}",
        fit.result.iterations
    );
    let est = extract_estimate(&fit.theta);
    assert!(est.p_gal < 0.5, "true star classified galaxy: p_gal {}", est.p_gal);
    // fitted absolute position = patch center + offset
    let pr = patches[0].rect;
    let fx = pr.x0 + 16.0 + est.d_pos.0;
    let fy = pr.y0 + 16.0 + est.d_pos.1;
    let d = ((fx - star.pos.0).powi(2) + (fy - star.pos.1).powi(2)).sqrt();
    assert!(d < 0.1, "position error {d} px");
    assert!(
        (est.flux_r - star.flux_r).abs() / star.flux_r < 0.10,
        "flux {} vs {}",
        est.flux_r,
        star.flux_r
    );
    for (a, b) in est.colors.iter().zip(&star.colors) {
        assert!((a - b).abs() < 0.12, "color {a} vs {b}");
    }

    // ---------------------------------------------------------------
    // 4. Newton recovers a galaxy (classification + shape).
    // ---------------------------------------------------------------
    let gal = galaxy_truth();
    let gpatches = scene(&gal, 29);
    let mut ginit = gal.clone();
    ginit.flux_r *= 0.7;
    ginit.shape.scale = 1.2;
    ginit.shape.axis_ratio = 0.8;
    let tg0 = theta_init(&ginit, 0.5);
    let gfit = celeste::runtime::optimize_source(&engine, &gpatches, &tg0, &NewtonConfig::default());
    assert!(gfit.result.converged(), "stop: {:?}", gfit.result.stop);
    let gest = extract_estimate(&gfit.theta);
    assert!(gest.p_gal > 0.5, "true galaxy classified star: p_gal {}", gest.p_gal);
    assert!(
        (gest.shape.scale - gal.shape.scale).abs() / gal.shape.scale < 0.3,
        "scale {} vs {}",
        gest.shape.scale,
        gal.shape.scale
    );
    assert!(
        (gest.shape.axis_ratio - gal.shape.axis_ratio).abs() < 0.2,
        "axis {} vs {}",
        gest.shape.axis_ratio,
        gal.shape.axis_ratio
    );

    // ---------------------------------------------------------------
    // 5. Newton uses far fewer objective evaluations than L-BFGS.
    // ---------------------------------------------------------------
    let mut t_cmp = theta_init(&init, 0.5);
    t_cmp[L::I_LOC] = 0.5;
    let mut obj_n = SourceObjective::new(&engine, &patches);
    let newton = newton_tr(&mut obj_n, &t_cmp, &NewtonConfig::default());
    let mut obj_l = SourceObjective::new(&engine, &patches).with_engine(LikeEngine::PallasManual);
    let lb = lbfgs(&mut obj_l, &t_cmp, &LbfgsConfig { max_iter: 3000, ..Default::default() });
    assert!(newton.converged());
    assert!(
        lb.f_evals > newton.f_evals,
        "lbfgs {} evals, newton {} evals",
        lb.f_evals,
        newton.f_evals
    );

    // ---------------------------------------------------------------
    // 6. Absurd θ values fail cleanly, never panic.
    // ---------------------------------------------------------------
    let mut t_bad = [0.0f64; L::DIM];
    t_bad[L::I_FLUX_STAR] = 200.0;
    let mut obj_b = SourceObjective::new(&engine, &patches);
    if let Some((f, _, _)) = obj_b.value_grad_hess(&t_bad) {
        assert!(f.is_finite());
    }
}
