//! Vendored minimal re-implementation of the `anyhow` error API.
//!
//! The container building this repo has no crates.io access, so instead
//! of the real crate we ship the small subset the codebase uses:
//! [`Error`], [`Result`], [`anyhow!`], [`bail!`], and the [`Context`]
//! extension trait. Semantics match `anyhow` for these paths: errors are
//! type-erased with a formatted message, `?` converts any
//! `std::error::Error`, and context is prepended `"{context}: {cause}"`.

use std::fmt;

/// A type-erased error: a rendered message plus the optional source it
/// was converted from (kept alive for completeness of the chain).
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Prepend context, anyhow-style: `"{context}: {cause}"`.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The root cause this error was converted from, if any.
    pub fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.source.as_deref().map(|e| e as &(dyn std::error::Error + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// `fn main() -> anyhow::Result<()>` prints the error via Debug, so Debug
// renders the human-readable message (as the real anyhow does).
impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`: that would conflict with this blanket conversion.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// `anyhow::Result<T>` — `Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert!(e.to_string().contains("gone"));
        assert!(e.source().is_some());
    }

    #[test]
    fn macros_format() {
        let x = 7;
        let e = anyhow!("value {x} bad: {:?}", "why");
        assert_eq!(e.to_string(), "value 7 bad: \"why\"");
        fn f() -> Result<()> {
            bail!("stop at {}", 3);
        }
        assert_eq!(f().unwrap_err().to_string(), "stop at 3");
    }

    #[test]
    fn context_prepends() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "loading manifest").unwrap_err();
        assert_eq!(e.to_string(), "loading manifest: gone");
        // context also chains on an already-anyhow Result
        let r2: Result<()> = Err(anyhow!("inner"));
        let e2 = r2.context("outer").unwrap_err();
        assert_eq!(e2.to_string(), "outer: inner");
        // and on Option
        let n: Option<u32> = None;
        assert_eq!(n.context("missing").unwrap_err().to_string(), "missing");
    }
}
