//! Offline stub of the `xla` PJRT bindings.
//!
//! The real `xla` crate wraps the XLA C API and needs its shared library,
//! which this container does not ship. This stub exposes the exact API
//! surface `celeste::runtime` uses so the crate builds and tests run
//! offline; anything that would actually execute a compiled artifact
//! returns a descriptive error instead. Code paths that depend on
//! artifacts already skip cleanly when `manifest.json` is absent, so the
//! stub is only ever exercised for type-checking and the smoke command.
//!
//! To use real PJRT execution, point the `xla` path dependency in
//! `rust/Cargo.toml` at the actual bindings; no `celeste` source changes
//! are required.

use std::path::Path;

/// Error type mirroring the real bindings' debug-printable errors.
#[derive(Clone)]
pub struct Error(pub String);

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_err(what: &str) -> Error {
    Error(format!(
        "xla stub: {what} unavailable (offline build; swap in the real xla bindings)"
    ))
}

/// Stub PJRT client. Creation succeeds so `celeste smoke` can report the
/// platform; compilation fails with a clear message.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient(()))
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_err("PJRT compilation"))
    }
}

/// Parsed HLO module text (held verbatim; never executed).
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let path = path.as_ref();
        match std::fs::read_to_string(path) {
            Ok(text) => Ok(HloModuleProto { text }),
            Err(e) => Err(Error(format!("{}: {e}", path.display()))),
        }
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Never constructed in the stub (`compile` always errors), but the type
/// must exist with the executable API for `celeste::runtime` to compile.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_err("PJRT execution"))
    }
}

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub_err("device-to-host transfer"))
    }
}

/// Host literal: flattened f64 payload plus dims.
pub struct Literal {
    data: Vec<f64>,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1(data: &[f64]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let numel: i64 = dims.iter().product();
        if numel as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements into dims {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(stub_err("tuple decomposition"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(stub_err("literal readback"))
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_and_literal_surface() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "stub-cpu");
        assert_eq!(c.device_count(), 1);
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.dims(), &[4]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert!(l.reshape(&[3, 3]).is_err());
        assert!(l.to_vec::<f64>().is_err());
    }

    #[test]
    fn compile_fails_with_stub_message() {
        let c = PjRtClient::cpu().unwrap();
        let comp = XlaComputation(());
        let e = c.compile(&comp).unwrap_err();
        assert!(format!("{e:?}").contains("xla stub"));
    }
}
