//! Multi-threaded request executor over an `Arc<Store>`.
//!
//! Architecture (the request path every later scaling PR builds on):
//!
//! ```text
//!   clients ──try_submit──▶ scheduler ──▶ worker pool ──▶ shards
//!                 │     (condvar: one FIFO │
//!                 ▼      steal: per-worker └─ per-worker latency Stats
//!               shed     deques + stealing)
//! ```
//!
//! The queue between admission and the workers is pluggable (see
//! [`crate::serve::sched`]): the original single mutex+condvar FIFO, or
//! a work-stealing pool of per-worker FIFO deques with randomized
//! stealing. Workers drain up to [`SchedConfig::batch`] jobs
//! per wake-up and execute them through
//! [`execute_batch`](crate::serve::sched::execute_batch), which answers
//! same-shard queries in one pass over the shard list and pins a live
//! store's epoch once per batch instead of once per request.
//!
//! Admission control sheds load once the count of accepted-but-
//! unexecuted jobs exceeds the depth bound, so overload degrades into
//! an explicit shed count rather than unbounded latency; the accounting
//! is batch-aware (a drained batch keeps its slots until it begins
//! executing). All per-request accounting is worker-local and merged
//! once at shutdown (same discipline as the inference coordinator's
//! per-worker stats); the merged quantiles are deterministic in the
//! worker fold order (see [`Stats::merge_all`]).
//!
//! Result caching used to live here too; it is now the engine API's
//! composable [`Cached`](crate::serve::engine::Cached) layer, shared by
//! every tier. Stack it as `Cached<ServerEngine>` to get the old
//! behavior (and the same layer caches the distributed router).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use crate::metrics::Stats;
use crate::prng::Rng;

use super::engine::Priority;
use super::ingest::{EpochStore, StoreSource, VersionedStore};
use super::query::{Query, QueryResult, N_QUERY_CLASSES, QUERY_CLASSES};
use super::sched::{execute_batch, Job, SchedConfig, SchedQueue};
use super::store::Store;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// worker threads (0 is allowed: nothing drains, useful for
    /// deterministic admission-control tests)
    pub threads: usize,
    /// bound on accepted-but-unexecuted jobs beyond which new requests
    /// are shed
    pub queue_depth: usize,
    /// request scheduler + batching knobs; the default (condvar,
    /// batch 1) is the original single-queue behavior
    pub sched: SchedConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { threads: 4, queue_depth: 1024, sched: SchedConfig::default() }
    }
}

struct Shared {
    source: StoreSource,
    cfg: ServerConfig,
    queue: SchedQueue,
    shed: AtomicU64,
}

/// Per-worker accounting, merged at shutdown.
#[derive(Default)]
struct WorkerLocal {
    latency: [Stats; N_QUERY_CLASSES],
    executed: u64,
    /// jobs popped from the worker's own deque (or the shared FIFO)
    local_hits: u64,
    /// jobs taken from another worker's deque
    steals: u64,
    /// wake-ups that found work (drained batches)
    batches: u64,
    /// jobs per drained batch
    batch_size: Stats,
    /// per-job enqueue -> drain wait, seconds
    queue_wait: Stats,
    /// per-batch shard-execution time, seconds
    execute: Stats,
}

/// Final report: throughput counters, scheduler counters, plus
/// per-class latency distributions (p50/p99 via `metrics::Stats`
/// quantiles).
#[derive(Clone, Debug, Default)]
pub struct ServerReport {
    pub accepted: u64,
    pub shed: u64,
    pub executed: u64,
    /// jobs executed from the owning worker's own queue
    pub local_hits: u64,
    /// jobs executed after being stolen from another worker's deque
    /// (always 0 on the condvar scheduler)
    pub steals: u64,
    /// drained batches across all workers
    pub batches: u64,
    /// jobs per drained batch across all workers
    pub batch_size: Stats,
    /// per-job enqueue → worker-drain wait (the `queue_wait` stage of
    /// the worker-pool tier; feeds `Registry::absorb_server`)
    pub queue_wait: Stats,
    /// per-batch shard-execution time (the `shard_execute` stage)
    pub execute: Stats,
    /// queue-entry → reply latency per query class
    pub latency: [Stats; N_QUERY_CLASSES],
}

impl ServerReport {
    /// All-classes latency distribution.
    pub fn latency_all(&self) -> Stats {
        Stats::merge_all(&self.latency)
    }

    /// Fraction of executed jobs that arrived by stealing.
    pub fn steal_fraction(&self) -> f64 {
        let total = self.local_hits + self.steals;
        if total == 0 {
            0.0
        } else {
            self.steals as f64 / total as f64
        }
    }

    /// Multi-line human summary with per-class quantiles.
    pub fn summary(&self) -> String {
        let all = self.latency_all();
        let aq = all.quantiles(&[0.50, 0.99]);
        let mut out = format!(
            "served {} (accepted {}, shed {})\n  all      p50={:.3}ms p99={:.3}ms max={:.3}ms",
            self.executed,
            self.accepted,
            self.shed,
            aq[0] * 1e3,
            aq[1] * 1e3,
            if all.n == 0 { 0.0 } else { all.max * 1e3 },
        );
        for c in QUERY_CLASSES {
            let s = &self.latency[c.index()];
            if s.n == 0 {
                continue;
            }
            let q = s.quantiles(&[0.50, 0.99]);
            out.push_str(&format!(
                "\n  {:<8} n={} p50={:.3}ms p99={:.3}ms",
                c.name(),
                s.n,
                q[0] * 1e3,
                q[1] * 1e3
            ));
        }
        if self.batches > 0 {
            out.push_str(&format!(
                "\n  sched: {} local, {} stolen ({:.1}%), mean batch {:.2} (max {:.0})",
                self.local_hits,
                self.steals,
                self.steal_fraction() * 100.0,
                self.batch_size.mean(),
                self.batch_size.max
            ));
        }
        if self.queue_wait.n > 0 {
            let wq = self.queue_wait.quantiles(&[0.50, 0.99]);
            let eq = self.execute.quantiles(&[0.50, 0.99]);
            out.push_str(&format!(
                "\n  stages: queue_wait p50={:.3}ms p99={:.3}ms, execute/batch p50={:.3}ms p99={:.3}ms",
                wq[0] * 1e3,
                wq[1] * 1e3,
                eq[0] * 1e3,
                eq[1] * 1e3
            ));
        }
        out
    }
}

/// The running server. Call [`Server::shutdown`] to stop the workers
/// and collect the report (shareable as `Arc<Server>`, so an engine
/// stack and the owner can hold it at once; the first `shutdown` wins,
/// later ones return an empty report).
pub struct Server {
    shared: Arc<Shared>,
    handles: Mutex<Vec<std::thread::JoinHandle<WorkerLocal>>>,
}

impl Server {
    /// Serve a fixed (pre-ingestion) store.
    pub fn start(store: Arc<Store>, cfg: ServerConfig) -> Server {
        Server::start_from(StoreSource::Fixed(store), cfg)
    }

    /// Serve the live head of a versioned store: each worker loads the
    /// current epoch per drained batch, so a publish is picked up by
    /// every in-flight worker at its next batch — no pause, no
    /// coordination.
    pub fn start_live(versioned: Arc<VersionedStore>, cfg: ServerConfig) -> Server {
        Server::start_from(StoreSource::Live(versioned), cfg)
    }

    fn start_from(source: StoreSource, cfg: ServerConfig) -> Server {
        let shared = Arc::new(Shared {
            source,
            queue: SchedQueue::new(cfg.sched.kind, cfg.threads, cfg.queue_depth),
            cfg: cfg.clone(),
            shed: AtomicU64::new(0),
        });
        let handles = (0..cfg.threads)
            .map(|w| {
                let sh = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&sh, w))
            })
            .collect();
        Server { shared, handles: Mutex::new(handles) }
    }

    /// Configured worker-thread count.
    pub fn threads(&self) -> usize {
        self.shared.cfg.threads
    }

    /// The scheduler + batching configuration this server runs on.
    pub fn sched(&self) -> SchedConfig {
        self.shared.cfg.sched
    }

    /// The catalog epoch currently served (`None` over a fixed store).
    pub fn epoch_view(&self) -> Option<Arc<EpochStore>> {
        self.shared.source.view()
    }

    fn submit(
        &self,
        query: Query,
        priority: Priority,
        reply: Option<mpsc::Sender<QueryResult>>,
    ) -> bool {
        let job = Job { query, priority, enqueued: Instant::now(), reply };
        // acceptance is counted by the queue itself, under the same
        // lock that makes the job visible to workers (so a racing
        // shutdown's report can never under-count accepted work)
        if self.shared.queue.try_push(job) {
            true
        } else {
            self.shared.shed.fetch_add(1, Ordering::Relaxed);
            false
        }
    }

    /// Open-loop submission (fire and forget). Returns false if shed.
    pub fn try_submit(&self, query: Query) -> bool {
        self.submit(query, Priority::Normal, None)
    }

    /// Open-loop submission at an explicit scheduling priority: the job
    /// lands in the matching queue band (see [`crate::serve::sched`]).
    pub fn try_submit_with(&self, query: Query, priority: Priority) -> bool {
        self.submit(query, priority, None)
    }

    /// Closed-loop call: submit and wait for the result. `None` = shed.
    pub fn call(&self, query: Query) -> Option<QueryResult> {
        self.call_with(query, Priority::Normal)
    }

    /// Closed-loop call at an explicit scheduling priority.
    pub fn call_with(&self, query: Query, priority: Priority) -> Option<QueryResult> {
        let (tx, rx) = mpsc::channel();
        if !self.submit(query, priority, Some(tx)) {
            return None;
        }
        rx.recv().ok()
    }

    /// Accepted jobs not yet executing (the admission bound's measure).
    pub fn queue_len(&self) -> usize {
        self.shared.queue.pending()
    }

    /// Drain remaining jobs, stop workers, merge per-worker accounting.
    pub fn shutdown(&self) -> ServerReport {
        self.shared.queue.shutdown();
        let handles: Vec<_> = self.handles.lock().unwrap().drain(..).collect();
        let mut locals = Vec::with_capacity(handles.len());
        for h in handles {
            locals.push(h.join().expect("server worker panicked"));
        }
        // counters read after the join: every accepted job has executed
        let mut report = ServerReport {
            accepted: self.shared.queue.accepted(),
            shed: self.shared.shed.load(Ordering::SeqCst),
            ..Default::default()
        };
        // worker-index fold order: together with the deterministic
        // `Stats::merge_all`, repeated runs over the same per-worker
        // sample multisets report identical quantiles
        for local in &locals {
            report.executed += local.executed;
            report.local_hits += local.local_hits;
            report.steals += local.steals;
            report.batches += local.batches;
        }
        report.batch_size = Stats::merge_all(locals.iter().map(|l| &l.batch_size));
        report.queue_wait = Stats::merge_all(locals.iter().map(|l| &l.queue_wait));
        report.execute = Stats::merge_all(locals.iter().map(|l| &l.execute));
        for c in 0..N_QUERY_CLASSES {
            report.latency[c] = Stats::merge_all(locals.iter().map(|l| &l.latency[c]));
        }
        report
    }
}

fn worker_loop(shared: &Shared, worker: usize) -> WorkerLocal {
    let mut local = WorkerLocal::default();
    // per-worker steal-victim stream, independent of the query streams
    let mut rng = Rng::new(0x57ea1 ^ (worker as u64).wrapping_mul(0x9E3779B97F4A7C15));
    let batch = shared.cfg.sched.batch.max(1);
    let mut jobs: Vec<Job> = Vec::with_capacity(batch);
    while let Some(stolen) = shared.queue.next_batch(worker, batch, &mut rng, &mut jobs) {
        if stolen {
            local.steals += jobs.len() as u64;
        } else {
            local.local_hits += jobs.len() as u64;
        }
        local.batches += 1;
        local.batch_size.push(jobs.len() as f64);
        // the queue_wait stage: enqueue -> this drain, per job
        for job in &jobs {
            local.queue_wait.push(job.enqueued.elapsed().as_secs_f64());
        }
        // live stores flip epochs between batches: one head load serves
        // the whole batch (amortized epoch pin)
        let store = shared.source.current();
        // batch-aware admission: slots free only once execution begins
        shared.queue.begin_execute(jobs.len());
        let queries: Vec<&Query> = jobs.iter().map(|j| &j.query).collect();
        let t_exec = Instant::now();
        let results = execute_batch(&store, &queries);
        local.execute.push(t_exec.elapsed().as_secs_f64());
        for (job, result) in jobs.drain(..).zip(results) {
            let class = job.query.class();
            local.latency[class.index()].push(job.enqueued.elapsed().as_secs_f64());
            local.executed += 1;
            if let Some(tx) = job.reply {
                // receiver may have given up; that is not a server error
                let _ = tx.send(result);
            }
        }
    }
    local
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;
    use crate::serve::query::{execute_scan, SourceFilter};
    use crate::serve::sched::SchedKind;
    use crate::serve::store::ServedSource;

    fn small_store(n: usize) -> (Arc<Store>, Vec<ServedSource>) {
        let mut rng = Rng::new(33);
        let src: Vec<ServedSource> = (0..n)
            .map(|id| ServedSource {
                id,
                pos: (rng.uniform_in(0.0, 300.0), rng.uniform_in(0.0, 300.0)),
                p_gal: rng.uniform(),
                flux_r: rng.lognormal(4.0, 1.0),
                flux_logsd: rng.uniform_in(0.01, 0.5),
                colors: [0.0; 4],
                converged: true,
            })
            .collect();
        let store = Store::build(src, 300.0, 300.0, 4);
        let flat = store.all_sources();
        (Arc::new(store), flat)
    }

    fn steal_cfg(threads: usize, batch: usize) -> ServerConfig {
        ServerConfig {
            threads,
            sched: SchedConfig { kind: SchedKind::Steal, batch },
            ..Default::default()
        }
    }

    #[test]
    fn served_results_match_bruteforce() {
        let (store, flat) = small_store(500);
        let server = Server::start(store, ServerConfig { threads: 2, ..Default::default() });
        let mut rng = Rng::new(9);
        for _ in 0..60 {
            let q = Query::Cone {
                center: (rng.uniform_in(0.0, 300.0), rng.uniform_in(0.0, 300.0)),
                radius: rng.uniform_in(5.0, 80.0),
                filter: SourceFilter::Any,
            };
            let got = server.call(q.clone()).expect("not shed");
            assert_eq!(got, execute_scan(&flat, &q));
        }
        let report = server.shutdown();
        assert_eq!(report.executed, 60);
        assert_eq!(report.accepted, 60);
        assert_eq!(report.shed, 0);
        assert_eq!(report.latency_all().n, 60);
        assert_eq!(report.steals, 0, "condvar scheduler never steals");
        assert_eq!(report.local_hits, 60);
    }

    #[test]
    fn steal_scheduler_matches_bruteforce_too() {
        let (store, flat) = small_store(500);
        let server = Server::start(store, steal_cfg(3, 4));
        let mut rng = Rng::new(10);
        for i in 0..60usize {
            let q = match i % 2 {
                0 => Query::Cone {
                    center: (rng.uniform_in(0.0, 300.0), rng.uniform_in(0.0, 300.0)),
                    radius: rng.uniform_in(5.0, 80.0),
                    filter: SourceFilter::Any,
                },
                _ => Query::BrightestN { n: 1 + i, filter: SourceFilter::GalaxiesOnly },
            };
            let got = server.call(q.clone()).expect("not shed");
            assert_eq!(got, execute_scan(&flat, &q));
        }
        let report = server.shutdown();
        assert_eq!(report.executed, 60);
        assert_eq!(report.local_hits + report.steals, 60);
        assert!(report.batches > 0);
        assert_eq!(report.batch_size.n, report.batches);
        // stage timings cover every job / every batch
        assert_eq!(report.queue_wait.n, 60);
        assert_eq!(report.execute.n, report.batches);
    }

    #[test]
    fn admission_control_sheds_beyond_depth() {
        for kind in [SchedKind::Condvar, SchedKind::Steal] {
            let (store, _) = small_store(50);
            // zero workers: the queue only fills, deterministically
            let cfg = ServerConfig {
                threads: 0,
                queue_depth: 4,
                sched: SchedConfig { kind, batch: 1 },
            };
            let server = Server::start(store, cfg);
            let q = Query::BrightestN { n: 3, filter: SourceFilter::Any };
            let mut ok = 0;
            for _ in 0..10 {
                if server.try_submit(q.clone()) {
                    ok += 1;
                }
            }
            assert_eq!(ok, 4, "{kind:?}");
            assert_eq!(server.queue_len(), 4, "{kind:?}");
            let report = server.shutdown();
            assert_eq!(report.accepted, 4, "{kind:?}");
            assert_eq!(report.shed, 6, "{kind:?}");
            assert_eq!(report.executed, 0, "{kind:?}");
        }
    }

    #[test]
    fn live_server_picks_up_published_epochs() {
        let (store, _) = small_store(200);
        let vs = Arc::new(VersionedStore::new(store));
        let server = Server::start_live(Arc::clone(&vs), steal_cfg(2, 4));
        assert_eq!(server.epoch_view().expect("live").epoch, 0);
        let q = Query::BrightestN { n: 1, filter: SourceFilter::Any };
        let before = server.call(q.clone()).expect("not shed");
        // publish an outshining detection; in-flight workers must see it
        let mut ing = crate::serve::ingest::Ingestor::new(Arc::clone(&vs));
        let delta = ServedSource {
            id: 999_999,
            pos: (10.0, 10.0),
            p_gal: 0.0,
            flux_r: 1e12,
            flux_logsd: 0.1,
            colors: [0.0; 4],
            converged: true,
        };
        ing.apply(&[delta]);
        let after = server.call(q).expect("not shed");
        assert_ne!(before, after, "publish must be visible to the worker pool");
        match after {
            QueryResult::Sources(v) => assert_eq!(v[0].id, 999_999),
            _ => unreachable!(),
        }
        assert_eq!(server.epoch_view().expect("live").epoch, 1);
        let _ = server.shutdown();
    }

    #[test]
    fn shutdown_is_shareable_and_idempotent() {
        let (store, _) = small_store(100);
        let server = Arc::new(Server::start(store, ServerConfig::default()));
        let q = Query::BrightestN { n: 2, filter: SourceFilter::Any };
        assert!(server.call(q).is_some());
        let first = server.shutdown();
        assert_eq!(first.executed, 1);
        // a second shutdown through another handle finds no workers
        let second = server.shutdown();
        assert_eq!(second.executed, 0);
    }
}
