//! Multi-threaded request executor over an `Arc<Store>`.
//!
//! Architecture (the request path every later scaling PR builds on):
//!
//! ```text
//!   clients ──try_submit──▶ bounded queue ──▶ worker pool ──▶ shards
//!                 │ (admission control:          │
//!                 ▼  shed beyond depth)          └─ per-worker latency Stats
//!               shed
//! ```
//!
//! Workers pull jobs from a single bounded FIFO guarded by a mutex +
//! condvar; admission control sheds load once the queue exceeds its
//! depth bound, so overload degrades into an explicit shed count rather
//! than unbounded latency. All per-request accounting is worker-local
//! and merged once at shutdown (same discipline as the inference
//! coordinator's per-worker stats).
//!
//! Result caching used to live here too; it is now the engine API's
//! composable [`Cached`](crate::serve::engine::Cached) layer, shared by
//! every tier. Stack it as `Cached<ServerEngine>` to get the old
//! behavior (and the same layer caches the distributed router).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Instant;

use crate::metrics::Stats;

use super::ingest::{EpochStore, StoreSource, VersionedStore};
use super::query::{execute, Query, QueryResult, N_QUERY_CLASSES, QUERY_CLASSES};
use super::store::Store;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// worker threads (0 is allowed: nothing drains, useful for
    /// deterministic admission-control tests)
    pub threads: usize,
    /// queue depth bound beyond which new requests are shed
    pub queue_depth: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { threads: 4, queue_depth: 1024 }
    }
}

struct Job {
    query: Query,
    enqueued: Instant,
    reply: Option<mpsc::Sender<QueryResult>>,
}

struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    source: StoreSource,
    cfg: ServerConfig,
    state: Mutex<QueueState>,
    not_empty: Condvar,
    accepted: AtomicU64,
    shed: AtomicU64,
}

/// Per-worker accounting, merged at shutdown.
#[derive(Default)]
struct WorkerLocal {
    latency: [Stats; N_QUERY_CLASSES],
    executed: u64,
}

/// Final report: throughput counters plus per-class latency
/// distributions (p50/p99 via `metrics::Stats` quantiles).
#[derive(Clone, Debug, Default)]
pub struct ServerReport {
    pub accepted: u64,
    pub shed: u64,
    pub executed: u64,
    /// queue-entry → reply latency per query class
    pub latency: [Stats; N_QUERY_CLASSES],
}

impl ServerReport {
    /// All-classes latency distribution.
    pub fn latency_all(&self) -> Stats {
        Stats::merge_all(&self.latency)
    }

    /// Multi-line human summary with per-class quantiles.
    pub fn summary(&self) -> String {
        let all = self.latency_all();
        let aq = all.quantiles(&[0.50, 0.99]);
        let mut out = format!(
            "served {} (accepted {}, shed {})\n  all      p50={:.3}ms p99={:.3}ms max={:.3}ms",
            self.executed,
            self.accepted,
            self.shed,
            aq[0] * 1e3,
            aq[1] * 1e3,
            if all.n == 0 { 0.0 } else { all.max * 1e3 },
        );
        for c in QUERY_CLASSES {
            let s = &self.latency[c.index()];
            if s.n == 0 {
                continue;
            }
            let q = s.quantiles(&[0.50, 0.99]);
            out.push_str(&format!(
                "\n  {:<8} n={} p50={:.3}ms p99={:.3}ms",
                c.name(),
                s.n,
                q[0] * 1e3,
                q[1] * 1e3
            ));
        }
        out
    }
}

/// The running server. Call [`Server::shutdown`] to stop the workers
/// and collect the report (shareable as `Arc<Server>`, so an engine
/// stack and the owner can hold it at once; the first `shutdown` wins,
/// later ones return an empty report).
pub struct Server {
    shared: Arc<Shared>,
    handles: Mutex<Vec<std::thread::JoinHandle<WorkerLocal>>>,
}

impl Server {
    /// Serve a fixed (pre-ingestion) store.
    pub fn start(store: Arc<Store>, cfg: ServerConfig) -> Server {
        Server::start_from(StoreSource::Fixed(store), cfg)
    }

    /// Serve the live head of a versioned store: each worker loads the
    /// current epoch per request, so a publish is picked up by every
    /// in-flight worker at its next job — no pause, no coordination.
    pub fn start_live(versioned: Arc<VersionedStore>, cfg: ServerConfig) -> Server {
        Server::start_from(StoreSource::Live(versioned), cfg)
    }

    fn start_from(source: StoreSource, cfg: ServerConfig) -> Server {
        let shared = Arc::new(Shared {
            source,
            cfg: cfg.clone(),
            state: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false }),
            not_empty: Condvar::new(),
            accepted: AtomicU64::new(0),
            shed: AtomicU64::new(0),
        });
        let handles = (0..cfg.threads)
            .map(|_| {
                let sh = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&sh))
            })
            .collect();
        Server { shared, handles: Mutex::new(handles) }
    }

    /// Configured worker-thread count.
    pub fn threads(&self) -> usize {
        self.shared.cfg.threads
    }

    /// The catalog epoch currently served (`None` over a fixed store).
    pub fn epoch_view(&self) -> Option<Arc<EpochStore>> {
        self.shared.source.view()
    }

    fn submit(&self, query: Query, reply: Option<mpsc::Sender<QueryResult>>) -> bool {
        {
            let mut st = self.shared.state.lock().unwrap();
            if st.shutdown || st.jobs.len() >= self.shared.cfg.queue_depth {
                drop(st);
                self.shared.shed.fetch_add(1, Ordering::Relaxed);
                return false;
            }
            st.jobs.push_back(Job { query, enqueued: Instant::now(), reply });
        }
        self.shared.accepted.fetch_add(1, Ordering::Relaxed);
        self.shared.not_empty.notify_one();
        true
    }

    /// Open-loop submission (fire and forget). Returns false if shed.
    pub fn try_submit(&self, query: Query) -> bool {
        self.submit(query, None)
    }

    /// Closed-loop call: submit and wait for the result. `None` = shed.
    pub fn call(&self, query: Query) -> Option<QueryResult> {
        let (tx, rx) = mpsc::channel();
        if !self.submit(query, Some(tx)) {
            return None;
        }
        rx.recv().ok()
    }

    pub fn queue_len(&self) -> usize {
        self.shared.state.lock().unwrap().jobs.len()
    }

    /// Drain remaining jobs, stop workers, merge per-worker accounting.
    pub fn shutdown(&self) -> ServerReport {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.not_empty.notify_all();
        let handles: Vec<_> = self.handles.lock().unwrap().drain(..).collect();
        let mut report = ServerReport {
            accepted: self.shared.accepted.load(Ordering::Relaxed),
            shed: self.shared.shed.load(Ordering::Relaxed),
            ..Default::default()
        };
        for h in handles {
            let local = h.join().expect("server worker panicked");
            report.executed += local.executed;
            for (dst, src) in report.latency.iter_mut().zip(&local.latency) {
                dst.merge(src);
            }
        }
        report
    }
}

fn worker_loop(shared: &Shared) -> WorkerLocal {
    let mut local = WorkerLocal::default();
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(j) = st.jobs.pop_front() {
                    break Some(j);
                }
                if st.shutdown {
                    break None;
                }
                st = shared.not_empty.wait(st).unwrap();
            }
        };
        let Some(job) = job else { break };
        let class = job.query.class();
        // live stores flip epochs between jobs: load the current one
        let result = execute(&shared.source.current(), &job.query);
        local.latency[class.index()].push(job.enqueued.elapsed().as_secs_f64());
        local.executed += 1;
        if let Some(tx) = job.reply {
            // receiver may have given up; that is not a server error
            let _ = tx.send(result);
        }
    }
    local
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;
    use crate::serve::query::{execute_scan, SourceFilter};
    use crate::serve::store::ServedSource;

    fn small_store(n: usize) -> (Arc<Store>, Vec<ServedSource>) {
        let mut rng = Rng::new(33);
        let src: Vec<ServedSource> = (0..n)
            .map(|id| ServedSource {
                id,
                pos: (rng.uniform_in(0.0, 300.0), rng.uniform_in(0.0, 300.0)),
                p_gal: rng.uniform(),
                flux_r: rng.lognormal(4.0, 1.0),
                flux_logsd: rng.uniform_in(0.01, 0.5),
                colors: [0.0; 4],
                converged: true,
            })
            .collect();
        let store = Store::build(src, 300.0, 300.0, 4);
        let flat = store.all_sources();
        (Arc::new(store), flat)
    }

    #[test]
    fn served_results_match_bruteforce() {
        let (store, flat) = small_store(500);
        let server = Server::start(store, ServerConfig { threads: 2, ..Default::default() });
        let mut rng = Rng::new(9);
        for _ in 0..60 {
            let q = Query::Cone {
                center: (rng.uniform_in(0.0, 300.0), rng.uniform_in(0.0, 300.0)),
                radius: rng.uniform_in(5.0, 80.0),
                filter: SourceFilter::Any,
            };
            let got = server.call(q.clone()).expect("not shed");
            assert_eq!(got, execute_scan(&flat, &q));
        }
        let report = server.shutdown();
        assert_eq!(report.executed, 60);
        assert_eq!(report.accepted, 60);
        assert_eq!(report.shed, 0);
        assert_eq!(report.latency_all().n, 60);
    }

    #[test]
    fn admission_control_sheds_beyond_depth() {
        let (store, _) = small_store(50);
        // zero workers: the queue only fills, deterministically
        let server = Server::start(store, ServerConfig { threads: 0, queue_depth: 4 });
        let q = Query::BrightestN { n: 3, filter: SourceFilter::Any };
        let mut ok = 0;
        for _ in 0..10 {
            if server.try_submit(q.clone()) {
                ok += 1;
            }
        }
        assert_eq!(ok, 4);
        assert_eq!(server.queue_len(), 4);
        let report = server.shutdown();
        assert_eq!(report.accepted, 4);
        assert_eq!(report.shed, 6);
        assert_eq!(report.executed, 0);
    }

    #[test]
    fn live_server_picks_up_published_epochs() {
        let (store, _) = small_store(200);
        let vs = Arc::new(VersionedStore::new(store));
        let server =
            Server::start_live(Arc::clone(&vs), ServerConfig { threads: 2, ..Default::default() });
        assert_eq!(server.epoch_view().expect("live").epoch, 0);
        let q = Query::BrightestN { n: 1, filter: SourceFilter::Any };
        let before = server.call(q.clone()).expect("not shed");
        // publish an outshining detection; in-flight workers must see it
        let mut ing = crate::serve::ingest::Ingestor::new(Arc::clone(&vs));
        let delta = ServedSource {
            id: 999_999,
            pos: (10.0, 10.0),
            p_gal: 0.0,
            flux_r: 1e12,
            flux_logsd: 0.1,
            colors: [0.0; 4],
            converged: true,
        };
        ing.apply(&[delta]);
        let after = server.call(q).expect("not shed");
        assert_ne!(before, after, "publish must be visible to the worker pool");
        match after {
            QueryResult::Sources(v) => assert_eq!(v[0].id, 999_999),
            _ => unreachable!(),
        }
        assert_eq!(server.epoch_view().expect("live").epoch, 1);
        let _ = server.shutdown();
    }

    #[test]
    fn shutdown_is_shareable_and_idempotent() {
        let (store, _) = small_store(100);
        let server = Arc::new(Server::start(store, ServerConfig::default()));
        let q = Query::BrightestN { n: 2, filter: SourceFilter::Any };
        assert!(server.call(q).is_some());
        let first = server.shutdown();
        assert_eq!(first.executed, 1);
        // a second shutdown through another handle finds no workers
        let second = server.shutdown();
        assert_eq!(second.executed, 0);
    }
}
