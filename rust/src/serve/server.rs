//! Multi-threaded request executor over an `Arc<Store>`.
//!
//! Architecture (the request path every later scaling PR builds on):
//!
//! ```text
//!   clients ──try_submit──▶ bounded queue ──▶ worker pool ──▶ shards
//!                 │ (admission control:          │
//!                 ▼  shed beyond depth)          ├─ per-class LRU result cache
//!               shed                             └─ per-worker latency Stats
//! ```
//!
//! Workers pull jobs from a single bounded FIFO guarded by a mutex +
//! condvar; admission control sheds load once the queue exceeds its
//! depth bound, so overload degrades into an explicit shed count rather
//! than unbounded latency. All per-request accounting is worker-local
//! and merged once at shutdown (same discipline as the inference
//! coordinator's per-worker stats).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Instant;

use crate::metrics::Stats;

use super::query::{execute, Query, QueryResult, N_QUERY_CLASSES, QUERY_CLASSES};
use super::store::Store;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// worker threads (0 is allowed: nothing drains, useful for
    /// deterministic admission-control tests)
    pub threads: usize,
    /// queue depth bound beyond which new requests are shed
    pub queue_depth: usize,
    /// per-query-class LRU result cache capacity, entries (0 disables)
    pub cache_entries: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { threads: 4, queue_depth: 1024, cache_entries: 512 }
    }
}

struct Job {
    query: Query,
    enqueued: Instant,
    reply: Option<mpsc::Sender<QueryResult>>,
}

struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

/// Entry-count LRU mapping query cache keys to cloned results. The
/// stored query is compared on probe so a 64-bit key collision returns
/// a miss instead of silently serving another query's result.
struct ResultCache {
    capacity: usize,
    map: HashMap<u64, (Query, QueryResult, u64)>,
    tick: u64,
}

impl ResultCache {
    fn new(capacity: usize) -> ResultCache {
        ResultCache { capacity, map: HashMap::new(), tick: 0 }
    }

    fn get(&mut self, key: u64, q: &Query) -> Option<QueryResult> {
        self.tick += 1;
        let tick = self.tick;
        match self.map.get_mut(&key) {
            Some(e) if e.0 == *q => {
                e.2 = tick;
                Some(e.1.clone())
            }
            _ => None,
        }
    }

    fn put(&mut self, key: u64, q: Query, v: QueryResult) {
        if self.capacity == 0 {
            return;
        }
        self.tick += 1;
        if self.map.len() >= self.capacity && !self.map.contains_key(&key) {
            // amortized eviction: drop the least-recent ~1/8 of entries
            // in one pass instead of an O(n) scan per insert (this runs
            // under the class mutex on the worker hot path)
            let mut ticks: Vec<u64> = self.map.values().map(|e| e.2).collect();
            ticks.sort_unstable();
            let cut = ticks[(ticks.len() / 8).min(ticks.len() - 1)];
            self.map.retain(|_, e| e.2 > cut);
            if self.map.len() >= self.capacity {
                // all survivors newer than cut (degenerate tie case)
                let victim = self.map.iter().min_by_key(|(_, e)| e.2).map(|(&k, _)| k);
                if let Some(k) = victim {
                    self.map.remove(&k);
                }
            }
        }
        self.map.insert(key, (q, v, self.tick));
    }
}

struct Shared {
    store: Arc<Store>,
    cfg: ServerConfig,
    state: Mutex<QueueState>,
    not_empty: Condvar,
    caches: Vec<Mutex<ResultCache>>,
    accepted: AtomicU64,
    shed: AtomicU64,
}

/// Per-worker accounting, merged at shutdown.
#[derive(Default)]
struct WorkerLocal {
    latency: [Stats; N_QUERY_CLASSES],
    executed: u64,
    cache_hits: u64,
}

/// Final report: throughput counters plus per-class latency
/// distributions (p50/p99 via `metrics::Stats` quantiles).
#[derive(Clone, Debug, Default)]
pub struct ServerReport {
    pub accepted: u64,
    pub shed: u64,
    pub executed: u64,
    pub cache_hits: u64,
    /// queue-entry → reply latency per query class
    pub latency: [Stats; N_QUERY_CLASSES],
}

impl ServerReport {
    /// All-classes latency distribution.
    pub fn latency_all(&self) -> Stats {
        let mut all = Stats::new();
        for s in &self.latency {
            all.merge(s);
        }
        all
    }

    pub fn cache_hit_rate(&self) -> f64 {
        if self.executed == 0 {
            0.0
        } else {
            self.cache_hits as f64 / self.executed as f64
        }
    }

    /// Multi-line human summary with per-class quantiles.
    pub fn summary(&self) -> String {
        let all = self.latency_all();
        let aq = all.quantiles(&[0.50, 0.99]);
        let mut out = format!(
            "served {} (accepted {}, shed {}), cache hit rate {:.1}%\n  all      p50={:.3}ms p99={:.3}ms max={:.3}ms",
            self.executed,
            self.accepted,
            self.shed,
            100.0 * self.cache_hit_rate(),
            aq[0] * 1e3,
            aq[1] * 1e3,
            if all.n == 0 { 0.0 } else { all.max * 1e3 },
        );
        for c in QUERY_CLASSES {
            let s = &self.latency[c.index()];
            if s.n == 0 {
                continue;
            }
            let q = s.quantiles(&[0.50, 0.99]);
            out.push_str(&format!(
                "\n  {:<8} n={} p50={:.3}ms p99={:.3}ms",
                c.name(),
                s.n,
                q[0] * 1e3,
                q[1] * 1e3
            ));
        }
        out
    }
}

/// The running server. Dropping without `shutdown()` leaks workers;
/// always call `shutdown()` to stop and collect the report.
pub struct Server {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<WorkerLocal>>,
}

impl Server {
    pub fn start(store: Arc<Store>, cfg: ServerConfig) -> Server {
        let caches = (0..N_QUERY_CLASSES)
            .map(|_| Mutex::new(ResultCache::new(cfg.cache_entries)))
            .collect();
        let shared = Arc::new(Shared {
            store,
            cfg: cfg.clone(),
            state: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false }),
            not_empty: Condvar::new(),
            caches,
            accepted: AtomicU64::new(0),
            shed: AtomicU64::new(0),
        });
        let handles = (0..cfg.threads)
            .map(|_| {
                let sh = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&sh))
            })
            .collect();
        Server { shared, handles }
    }

    fn submit(&self, query: Query, reply: Option<mpsc::Sender<QueryResult>>) -> bool {
        {
            let mut st = self.shared.state.lock().unwrap();
            if st.shutdown || st.jobs.len() >= self.shared.cfg.queue_depth {
                drop(st);
                self.shared.shed.fetch_add(1, Ordering::Relaxed);
                return false;
            }
            st.jobs.push_back(Job { query, enqueued: Instant::now(), reply });
        }
        self.shared.accepted.fetch_add(1, Ordering::Relaxed);
        self.shared.not_empty.notify_one();
        true
    }

    /// Open-loop submission (fire and forget). Returns false if shed.
    pub fn try_submit(&self, query: Query) -> bool {
        self.submit(query, None)
    }

    /// Closed-loop call: submit and wait for the result. `None` = shed.
    pub fn call(&self, query: Query) -> Option<QueryResult> {
        let (tx, rx) = mpsc::channel();
        if !self.submit(query, Some(tx)) {
            return None;
        }
        rx.recv().ok()
    }

    pub fn queue_len(&self) -> usize {
        self.shared.state.lock().unwrap().jobs.len()
    }

    /// Drain remaining jobs, stop workers, merge per-worker accounting.
    pub fn shutdown(self) -> ServerReport {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.not_empty.notify_all();
        let mut report = ServerReport {
            accepted: self.shared.accepted.load(Ordering::Relaxed),
            shed: self.shared.shed.load(Ordering::Relaxed),
            ..Default::default()
        };
        for h in self.handles {
            let local = h.join().expect("server worker panicked");
            report.executed += local.executed;
            report.cache_hits += local.cache_hits;
            for (dst, src) in report.latency.iter_mut().zip(&local.latency) {
                dst.merge(src);
            }
        }
        report
    }
}

fn worker_loop(shared: &Shared) -> WorkerLocal {
    let mut local = WorkerLocal::default();
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(j) = st.jobs.pop_front() {
                    break Some(j);
                }
                if st.shutdown {
                    break None;
                }
                st = shared.not_empty.wait(st).unwrap();
            }
        };
        let Some(job) = job else { break };
        let class = job.query.class();
        let key = job.query.cache_key();
        let cached = if shared.cfg.cache_entries > 0 {
            shared.caches[class.index()].lock().unwrap().get(key, &job.query)
        } else {
            None
        };
        let result = match cached {
            Some(r) => {
                local.cache_hits += 1;
                r
            }
            None => {
                let r = execute(&shared.store, &job.query);
                if shared.cfg.cache_entries > 0 {
                    shared.caches[class.index()]
                        .lock()
                        .unwrap()
                        .put(key, job.query.clone(), r.clone());
                }
                r
            }
        };
        local.latency[class.index()].push(job.enqueued.elapsed().as_secs_f64());
        local.executed += 1;
        if let Some(tx) = job.reply {
            // receiver may have given up; that is not a server error
            let _ = tx.send(result);
        }
    }
    local
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;
    use crate::serve::query::{execute_scan, SourceFilter};
    use crate::serve::store::ServedSource;

    fn small_store(n: usize) -> (Arc<Store>, Vec<ServedSource>) {
        let mut rng = Rng::new(33);
        let src: Vec<ServedSource> = (0..n)
            .map(|id| ServedSource {
                id,
                pos: (rng.uniform_in(0.0, 300.0), rng.uniform_in(0.0, 300.0)),
                p_gal: rng.uniform(),
                flux_r: rng.lognormal(4.0, 1.0),
                flux_logsd: rng.uniform_in(0.01, 0.5),
                colors: [0.0; 4],
                converged: true,
            })
            .collect();
        let store = Store::build(src, 300.0, 300.0, 4);
        let flat = store.all_sources();
        (Arc::new(store), flat)
    }

    #[test]
    fn served_results_match_bruteforce() {
        let (store, flat) = small_store(500);
        let server = Server::start(store, ServerConfig { threads: 2, ..Default::default() });
        let mut rng = Rng::new(9);
        for _ in 0..60 {
            let q = Query::Cone {
                center: (rng.uniform_in(0.0, 300.0), rng.uniform_in(0.0, 300.0)),
                radius: rng.uniform_in(5.0, 80.0),
                filter: SourceFilter::Any,
            };
            let got = server.call(q.clone()).expect("not shed");
            assert_eq!(got, execute_scan(&flat, &q));
        }
        let report = server.shutdown();
        assert_eq!(report.executed, 60);
        assert_eq!(report.accepted, 60);
        assert_eq!(report.shed, 0);
        assert_eq!(report.latency_all().n, 60);
    }

    #[test]
    fn admission_control_sheds_beyond_depth() {
        let (store, _) = small_store(50);
        // zero workers: the queue only fills, deterministically
        let server = Server::start(
            store,
            ServerConfig { threads: 0, queue_depth: 4, cache_entries: 0 },
        );
        let q = Query::BrightestN { n: 3, filter: SourceFilter::Any };
        let mut ok = 0;
        for _ in 0..10 {
            if server.try_submit(q.clone()) {
                ok += 1;
            }
        }
        assert_eq!(ok, 4);
        assert_eq!(server.queue_len(), 4);
        let report = server.shutdown();
        assert_eq!(report.accepted, 4);
        assert_eq!(report.shed, 6);
        assert_eq!(report.executed, 0);
    }

    #[test]
    fn identical_queries_hit_the_cache() {
        let (store, flat) = small_store(300);
        // one worker => strictly sequential service => deterministic hits
        let server = Server::start(
            store,
            ServerConfig { threads: 1, queue_depth: 64, cache_entries: 32 },
        );
        let q = Query::Cone { center: (150.0, 150.0), radius: 60.0, filter: SourceFilter::Any };
        let want = execute_scan(&flat, &q);
        for _ in 0..20 {
            assert_eq!(server.call(q.clone()).unwrap(), want);
        }
        let report = server.shutdown();
        assert_eq!(report.executed, 20);
        assert_eq!(report.cache_hits, 19);
        assert!(report.cache_hit_rate() > 0.9);
    }

    #[test]
    fn cache_evicts_lru_beyond_capacity() {
        let mut c = ResultCache::new(2);
        let r = QueryResult::Sources(Vec::new());
        let q = Query::BrightestN { n: 1, filter: SourceFilter::Any };
        c.put(1, q.clone(), r.clone());
        c.put(2, q.clone(), r.clone());
        assert!(c.get(1, &q).is_some()); // refresh 1 => 2 is LRU
        c.put(3, q.clone(), r.clone());
        assert!(c.get(2, &q).is_none(), "2 should be evicted");
        assert!(c.get(1, &q).is_some());
        assert!(c.get(3, &q).is_some());
    }

    #[test]
    fn cache_key_collision_is_a_miss_not_a_wrong_answer() {
        let mut c = ResultCache::new(4);
        let q1 = Query::BrightestN { n: 1, filter: SourceFilter::Any };
        let q2 = Query::BrightestN { n: 2, filter: SourceFilter::Any };
        // simulate a 64-bit key collision: same key, different query
        c.put(42, q1.clone(), QueryResult::Sources(Vec::new()));
        assert!(c.get(42, &q1).is_some());
        assert!(c.get(42, &q2).is_none(), "colliding key must not serve q1's result for q2");
    }
}
