//! Compact jsonlite snapshot of a served catalog.
//!
//! The process boundary between `celeste infer` and `celeste
//! serve-bench`: inference writes a snapshot, serving loads it and
//! builds a `Store` with whatever shard count the serving tier wants.
//! Numbers round-trip losslessly (Rust's shortest-round-trip f64
//! formatting on write, exact `str::parse::<f64>` on read).

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::jsonlite::{self, Value};

use super::store::{ServedSource, Store};

pub const SNAPSHOT_FORMAT: &str = "celeste-snapshot-v1";

/// A loaded snapshot: flat sources plus the sky extent the store's
/// Hilbert keys must be computed over.
#[derive(Clone, Debug, PartialEq)]
pub struct Snapshot {
    pub width: f64,
    pub height: f64,
    pub sources: Vec<ServedSource>,
}

impl Snapshot {
    pub fn into_store(self, n_shards: usize) -> Store {
        Store::build(self.sources, self.width, self.height, n_shards)
    }
}

fn source_to_value(s: &ServedSource) -> Value {
    let mut m = std::collections::BTreeMap::new();
    m.insert("id".to_string(), Value::Num(s.id as f64));
    m.insert("x".to_string(), Value::Num(s.pos.0));
    m.insert("y".to_string(), Value::Num(s.pos.1));
    m.insert("p_gal".to_string(), Value::Num(s.p_gal));
    m.insert("flux_r".to_string(), Value::Num(s.flux_r));
    m.insert("flux_logsd".to_string(), Value::Num(s.flux_logsd));
    m.insert(
        "colors".to_string(),
        Value::Arr(s.colors.iter().map(|&c| Value::Num(c)).collect()),
    );
    m.insert("converged".to_string(), Value::Bool(s.converged));
    Value::Obj(m)
}

fn f64_field(v: &Value, key: &str) -> Result<f64> {
    v.get(key)
        .and_then(Value::as_f64)
        .ok_or_else(|| anyhow!("snapshot source missing numeric field {key:?}"))
}

fn source_from_value(v: &Value) -> Result<ServedSource> {
    let colors_v = v
        .get("colors")
        .and_then(Value::as_arr)
        .ok_or_else(|| anyhow!("snapshot source missing colors array"))?;
    if colors_v.len() != 4 {
        bail!("snapshot colors must have 4 entries, got {}", colors_v.len());
    }
    let mut colors = [0.0f64; 4];
    for (slot, cv) in colors.iter_mut().zip(colors_v) {
        *slot = cv.as_f64().ok_or_else(|| anyhow!("non-numeric color"))?;
    }
    Ok(ServedSource {
        id: f64_field(v, "id")? as usize,
        pos: (f64_field(v, "x")?, f64_field(v, "y")?),
        p_gal: f64_field(v, "p_gal")?,
        flux_r: f64_field(v, "flux_r")?,
        flux_logsd: f64_field(v, "flux_logsd")?,
        colors,
        converged: v.get("converged").and_then(Value::as_bool).unwrap_or(true),
    })
}

/// Serialize sources + extent to the snapshot JSON text.
pub fn to_json(sources: &[ServedSource], width: f64, height: f64) -> String {
    let mut m = std::collections::BTreeMap::new();
    m.insert("format".to_string(), Value::Str(SNAPSHOT_FORMAT.to_string()));
    m.insert("width".to_string(), Value::Num(width));
    m.insert("height".to_string(), Value::Num(height));
    m.insert(
        "sources".to_string(),
        Value::Arr(sources.iter().map(source_to_value).collect()),
    );
    jsonlite::to_string(&Value::Obj(m))
}

/// Parse snapshot JSON text.
pub fn from_json(text: &str) -> Result<Snapshot> {
    let v = jsonlite::parse(text).map_err(|e| anyhow!("snapshot parse: {e}"))?;
    match v.get("format").and_then(Value::as_str) {
        Some(SNAPSHOT_FORMAT) => {}
        other => bail!("unsupported snapshot format {other:?} (want {SNAPSHOT_FORMAT})"),
    }
    let width = f64_field(&v, "width")?;
    let height = f64_field(&v, "height")?;
    let sources = v
        .get("sources")
        .and_then(Value::as_arr)
        .ok_or_else(|| anyhow!("snapshot missing sources"))?
        .iter()
        .map(source_from_value)
        .collect::<Result<Vec<_>>>()?;
    Ok(Snapshot { width, height, sources })
}

/// Save a flat source list (e.g. fresh `infer` output).
pub fn save_sources(path: &Path, sources: &[ServedSource], width: f64, height: f64) -> Result<()> {
    std::fs::write(path, to_json(sources, width, height))?;
    Ok(())
}

/// Save a built store (canonical id-ordered flat view).
pub fn save(path: &Path, store: &Store) -> Result<()> {
    save_sources(path, &store.all_sources(), store.width, store.height)
}

/// Load a snapshot from disk.
pub fn load(path: &Path) -> Result<Snapshot> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("reading snapshot {path:?}: {e}"))?;
    from_json(&text)
}

/// Serve the heuristic baseline: photo-pipeline detections become
/// catalog entries and then served rows through
/// [`ServedSource::from_entry`]. Photo measures no posterior, so the
/// star/galaxy label is hard (`p_gal` in {0, 1}) and `flux_logsd` is 0
/// — the tightest cross-match acceptance radius, exactly the gap §II
/// attributes to heuristic pipelines.
pub fn from_photo(
    detections: &[crate::photo::PhotoSource],
    width: f64,
    height: f64,
) -> Snapshot {
    let sources = detections
        .iter()
        .enumerate()
        .map(|(id, d)| {
            let entry = crate::catalog::CatalogEntry {
                id,
                pos: d.pos,
                p_gal: if d.is_galaxy { 1.0 } else { 0.0 },
                flux_r: d.flux_r,
                colors: d.colors,
                shape: crate::model::GalaxyShape {
                    p_dev: d.p_dev,
                    axis_ratio: d.axis_ratio,
                    angle: d.angle,
                    scale: d.scale,
                },
            };
            ServedSource::from_entry(&entry, 0.0)
        })
        .collect();
    Snapshot { width, height, sources }
}

/// Synthesize a serveable catalog without compiled artifacts: truth sky
/// -> noisy "previous survey" estimates -> served rows (with synthetic
/// posterior SDs). The one ingestion path shared by the CLI, benches,
/// and tests, so they all serve the same catalog shape.
pub fn synthetic(n_sources: usize, seed: u64) -> Snapshot {
    let sky = crate::sky::generate(&crate::sky::SkyConfig {
        n_sources,
        seed,
        ..Default::default()
    });
    let mut rng = crate::prng::Rng::new(seed ^ 0x11);
    let cat =
        crate::catalog::noisy_catalog(&sky.sources, sky.width, sky.height, &mut rng, 0.5, 0.2);
    let sources = cat
        .entries
        .iter()
        .map(|e| ServedSource::from_entry(e, rng.uniform_in(0.05, 0.5)))
        .collect();
    Snapshot { width: sky.width, height: sky.height, sources }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn awkward_sources(n: usize) -> Vec<ServedSource> {
        // deliberately non-round values to stress lossless round-trip
        let mut rng = Rng::new(99);
        (0..n)
            .map(|id| ServedSource {
                id,
                pos: (rng.uniform() * 1234.567, rng.uniform() * 987.654),
                p_gal: rng.uniform(),
                flux_r: rng.lognormal(4.0, 1.5),
                flux_logsd: rng.uniform() * 0.3 + 1e-9,
                colors: [rng.normal(), rng.normal() * 1e-7, rng.normal() * 1e7, 0.0],
                converged: rng.uniform() < 0.5,
            })
            .collect()
    }

    #[test]
    fn json_roundtrip_is_lossless() {
        let src = awkward_sources(64);
        let text = to_json(&src, 1234.567, 987.654);
        let snap = from_json(&text).unwrap();
        assert_eq!(snap.width, 1234.567);
        assert_eq!(snap.height, 987.654);
        assert_eq!(snap.sources, src);
        // a second round-trip is byte-stable
        let text2 = to_json(&snap.sources, snap.width, snap.height);
        assert_eq!(text, text2);
    }

    #[test]
    fn file_roundtrip_through_store() {
        let dir = std::env::temp_dir().join("celeste-snapshot-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snap.json");
        let src = awkward_sources(200);
        let store = Store::build(src.clone(), 1300.0, 1000.0, 6);
        save(&path, &store).unwrap();
        let snap = load(&path).unwrap();
        let mut want = src;
        want.sort_by_key(|s| s.id);
        assert_eq!(snap.sources, want);
        let store2 = snap.into_store(3);
        assert_eq!(store2.all_sources(), want);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn photo_detections_become_a_servable_snapshot() {
        use crate::model::layout as L;
        use crate::photo::PhotoSource;
        let dets = vec![
            PhotoSource {
                pos: (10.0, 20.0),
                fluxes: [100.0; L::N_BANDS],
                flux_r: 100.0,
                colors: [0.1, 0.2, 0.3, 0.4],
                is_galaxy: false,
                p_dev: 0.0,
                axis_ratio: 1.0,
                angle: 0.0,
                scale: 0.0,
                significance: 25.0,
            },
            PhotoSource {
                pos: (40.0, 50.0),
                fluxes: [900.0; L::N_BANDS],
                flux_r: 900.0,
                colors: [0.4, 0.3, 0.2, 0.1],
                is_galaxy: true,
                p_dev: 0.5,
                axis_ratio: 0.6,
                angle: 1.0,
                scale: 2.5,
                significance: 80.0,
            },
        ];
        let snap = from_photo(&dets, 64.0, 64.0);
        assert_eq!(snap.sources.len(), 2);
        assert_eq!(snap.sources[0].id, 0);
        assert!(!snap.sources[0].is_galaxy(), "hard star label must serve as star");
        assert!(snap.sources[1].is_galaxy(), "hard galaxy label must serve as galaxy");
        assert_eq!(snap.sources[1].flux_r, 900.0);
        assert_eq!(snap.sources[0].flux_logsd, 0.0, "photo has no posterior SD");
        // round-trips through the snapshot codec and store like any catalog
        let text = to_json(&snap.sources, snap.width, snap.height);
        let back = from_json(&text).unwrap();
        assert_eq!(back.sources, snap.sources);
        let store = back.into_store(2);
        assert_eq!(store.len(), 2);
    }

    #[test]
    fn bad_snapshots_are_rejected() {
        assert!(from_json("{}").is_err());
        assert!(from_json("not json").is_err());
        assert!(from_json(r#"{"format":"celeste-snapshot-v1","width":1}"#).is_err());
        assert!(from_json(r#"{"format":"other","width":1,"height":1,"sources":[]}"#).is_err());
    }
}
