//! The length-prefixed binary wire format of the real RPC transport.
//!
//! Every frame is an 8-byte header followed by a message payload:
//!
//! ```text
//! offset  size  field
//!      0     2  magic 0xCE57, little-endian
//!      2     1  protocol version (currently 3)
//!      3     1  message tag (see below)
//!      4     4  payload length, little-endian u32
//! ```
//!
//! Version 2 extended version 1 with request telemetry: `Execute`
//! carries the originating trace id, `Reply` echoes it back alongside
//! the server-side per-stage span timings, and the
//! `StatsReq`/`StatsReply` pair (tags 8/9) lets a front end scrape a
//! shard server's metrics-registry snapshot. Version 3 (this build)
//! adds cooperative cancellation: the fire-and-forget [`Msg::Cancel`]
//! frame (tag 10) marks a trace id whose not-yet-executed work the
//! server drops before any shard runs — how a resolved hedge race
//! stops its loser from consuming server-side work. Mixed-version
//! peers do not interoperate; the mismatch surfaces as the actionable
//! [`WireError::PeerVersion`] rather than a generic decode failure.
//!
//! The header is validated *before* the payload is touched: a bad
//! magic, unknown version, unknown tag, or a length past
//! [`MAX_PAYLOAD`] is rejected without allocating a payload buffer, so
//! a hostile or corrupted peer cannot make the server reserve gigabytes
//! off a four-byte length field. Element counts inside a payload are
//! bounded the same way (a count must fit in the bytes that remain).
//!
//! Numbers are little-endian; `f64` travels as its IEEE-754 bit
//! pattern (`to_bits`/`from_bits`), so catalog rows round-trip
//! bit-exactly — the byte-parity contract the whole serving stack pins
//! extends across the process boundary unchanged.
//!
//! Decoding never panics: every failure is a typed [`WireError`], and a
//! clean peer close at a frame boundary ([`WireError::Closed`]) is
//! distinguished from a disconnect mid-frame ([`WireError::Truncated`]).

use std::io::{Read, Write};

use crate::metrics::Stats;
use crate::serve::query::{MatchResult, Query, ShardReply, SourceFilter};
use crate::serve::store::ServedSource;

/// Frame magic (little-endian on the wire).
pub const MAGIC: u16 = 0xCE57;
/// Protocol version spoken by this build.
pub const VERSION: u8 = 3;
/// Header size in bytes.
pub const HEADER_LEN: usize = 8;
/// Largest payload a peer may announce (checked before allocation).
pub const MAX_PAYLOAD: u32 = 64 << 20;

/// Error codes carried by [`Msg::Error`] frames.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// the peer speaks an unsupported protocol version
    BadVersion,
    /// the request could not be decoded or referenced an unknown shard
    Malformed,
    /// the server's applied epoch is older than the request's bound
    Stale,
    /// a publish skipped an epoch (the server would diverge)
    EpochGap,
    /// the server failed internally
    Internal,
}

impl ErrorCode {
    pub fn to_u8(self) -> u8 {
        match self {
            ErrorCode::BadVersion => 1,
            ErrorCode::Malformed => 2,
            ErrorCode::Stale => 3,
            ErrorCode::EpochGap => 4,
            ErrorCode::Internal => 5,
        }
    }

    pub fn from_u8(v: u8) -> Option<ErrorCode> {
        match v {
            1 => Some(ErrorCode::BadVersion),
            2 => Some(ErrorCode::Malformed),
            3 => Some(ErrorCode::Stale),
            4 => Some(ErrorCode::EpochGap),
            5 => Some(ErrorCode::Internal),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ErrorCode::BadVersion => "bad-version",
            ErrorCode::Malformed => "malformed",
            ErrorCode::Stale => "stale",
            ErrorCode::EpochGap => "epoch-gap",
            ErrorCode::Internal => "internal",
        }
    }
}

/// Everything that can go wrong on the wire, typed. Decoding and
/// framing never panic; they return one of these.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// OS-level I/O failure (connect refused, reset, timeout, ...)
    Io(std::io::ErrorKind),
    /// the peer closed cleanly at a frame boundary
    Closed,
    /// the peer disconnected mid-frame
    Truncated,
    /// the frame header's magic bytes are wrong
    BadMagic(u16),
    /// the frame announces an unsupported protocol version
    Version(u8),
    /// the handshake found a peer speaking a different protocol
    /// version (`theirs == 0` when the peer reported the mismatch
    /// without revealing its own version)
    PeerVersion {
        /// the version this build speaks
        ours: u8,
        /// the version the peer speaks (0 = unknown)
        theirs: u8,
    },
    /// the frame announces an unknown message tag
    BadTag(u8),
    /// the frame announces a payload larger than [`MAX_PAYLOAD`]
    Oversized(u32),
    /// the payload does not decode as its tag's message
    Malformed,
    /// the peer answered with an [`Msg::Error`] frame
    Remote(ErrorCode),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(kind) => write!(f, "wire i/o error: {kind:?}"),
            WireError::Closed => write!(f, "peer closed the connection"),
            WireError::Truncated => write!(f, "peer disconnected mid-frame"),
            WireError::BadMagic(m) => write!(f, "bad frame magic {m:#06x}"),
            WireError::Version(v) => write!(f, "unsupported wire version {v}"),
            WireError::PeerVersion { ours, theirs } => {
                if *theirs == 0 {
                    write!(
                        f,
                        "wire version mismatch: this build speaks v{ours} but the peer \
                         rejected the handshake as bad-version; upgrade the older side \
                         so both speak the same protocol (see docs/WIRE.md)"
                    )
                } else {
                    write!(
                        f,
                        "wire version mismatch: this build speaks v{ours}, the peer \
                         speaks v{theirs}; upgrade the older side so both speak the \
                         same protocol (see docs/WIRE.md)"
                    )
                }
            }
            WireError::BadTag(t) => write!(f, "unknown message tag {t}"),
            WireError::Oversized(n) => {
                write!(f, "payload length {n} exceeds the {MAX_PAYLOAD}-byte cap")
            }
            WireError::Malformed => write!(f, "malformed payload"),
            WireError::Remote(c) => write!(f, "remote error: {}", c.name()),
        }
    }
}

impl std::error::Error for WireError {}

/// True when the error is an OS read timeout (the deadline-derived
/// read timeout firing, not the peer misbehaving).
pub fn is_timeout(e: &WireError) -> bool {
    matches!(
        e,
        WireError::Io(std::io::ErrorKind::WouldBlock) | WireError::Io(std::io::ErrorKind::TimedOut)
    )
}

/// The messages of the shard-serving protocol. One frame carries one
/// message; request/response pairs are correlated by `req_id`.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// client -> server, first frame on a connection
    Hello { version: u8 },
    /// server -> client: negotiated version plus the served snapshot's
    /// current epoch and shard count
    HelloAck { version: u8, epoch: u64, n_shards: u32 },
    /// one framed request: every sub-query this client owes this
    /// server, grouped per shard (a whole scheduler batch coalesces
    /// into one of these). `min_epoch` is the consistency bound: the
    /// server refuses to answer from an older applied epoch.
    /// `trace_id` identifies the originating request's trace (0 =
    /// untraced) and is echoed back in the matching [`Msg::Reply`].
    Execute { req_id: u64, min_epoch: u64, trace_id: u64, entries: Vec<(u32, Vec<Query>)> },
    /// the per-shard replies, parallel to the request's entries.
    /// `server_spans` is the server-side per-stage timing breakdown as
    /// `(stage tag, seconds)` pairs (see [`crate::serve::obs::Stage`]),
    /// so the front end can join client and server spans into one
    /// cross-process trace.
    Reply {
        /// echoes the [`Msg::Execute`]
        req_id: u64,
        /// echoes the request's trace id (0 = untraced)
        trace_id: u64,
        /// server-side per-stage timings as `(stage tag, secs)` pairs
        server_spans: Vec<(u8, f64)>,
        /// per-shard replies, parallel to the request's entries
        entries: Vec<Vec<ShardReply>>,
    },
    /// an epoch publish: the deduped delta rows of exactly the next
    /// epoch, shipped so `Fresh`/`AtMost(k)` reads hold cross-process
    Publish { req_id: u64, epoch: u64, rows: Vec<ServedSource> },
    PublishAck { req_id: u64, epoch: u64 },
    /// typed failure; `req_id` echoes the offending request (0 when
    /// the failure is not attributable to one)
    Error { req_id: u64, code: ErrorCode, detail: String },
    /// client -> server: request a snapshot of the server's metrics
    /// registry (wire v2)
    StatsReq { req_id: u64 },
    /// server -> client: the registry snapshot. Histograms travel as
    /// their full [`Stats`] state (moments + bounded reservoir) so the
    /// scraper's merged quantiles stay deterministic.
    StatsReply {
        /// echoes the [`Msg::StatsReq`]
        req_id: u64,
        /// named counters
        counters: Vec<(String, u64)>,
        /// named gauges
        gauges: Vec<(String, f64)>,
        /// named histograms as full reservoir state
        histograms: Vec<(String, Stats)>,
    },
    /// client -> server, fire-and-forget (wire v3): drop any
    /// not-yet-executed work of this trace before a shard runs it.
    /// The server sends no reply; the dropped `Execute` (if one
    /// arrives) is still answered — with empty replies and zero shard
    /// work — so request/response correlation is undisturbed.
    Cancel { trace_id: u64 },
}

impl Msg {
    fn tag(&self) -> u8 {
        match self {
            Msg::Hello { .. } => 1,
            Msg::HelloAck { .. } => 2,
            Msg::Execute { .. } => 3,
            Msg::Reply { .. } => 4,
            Msg::Publish { .. } => 5,
            Msg::PublishAck { .. } => 6,
            Msg::Error { .. } => 7,
            Msg::StatsReq { .. } => 8,
            Msg::StatsReply { .. } => 9,
            Msg::Cancel { .. } => 10,
        }
    }
}

// ---------------------------------------------------------------- codec

/// Append-only payload writer (little-endian).
struct W(Vec<u8>);

impl W {
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.0.extend_from_slice(&v.to_bits().to_le_bytes());
    }
}

/// Cursor-based payload reader; every overrun is [`WireError::Malformed`].
struct R<'a> {
    b: &'a [u8],
    p: usize,
}

impl<'a> R<'a> {
    fn new(b: &'a [u8]) -> R<'a> {
        R { b, p: 0 }
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.p
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Malformed);
        }
        let s = &self.b[self.p..self.p + n];
        self.p += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Read an element count and bound it by the bytes that remain
    /// (`min_elem` = smallest possible element encoding), so a hostile
    /// count cannot drive a huge `Vec` allocation.
    fn count(&mut self, min_elem: usize) -> Result<usize, WireError> {
        let n = self.u32()? as usize;
        if min_elem > 0 && n > self.remaining() / min_elem {
            return Err(WireError::Malformed);
        }
        Ok(n)
    }

    /// Every payload byte must be consumed; trailing garbage means the
    /// peer and we disagree on the encoding.
    fn done(&self) -> Result<(), WireError> {
        if self.remaining() == 0 {
            Ok(())
        } else {
            Err(WireError::Malformed)
        }
    }
}

// smallest possible encodings, used to bound counts before allocation
const MIN_SOURCE: usize = 8 + 9 * 8 + 1; // 81
const MIN_QUERY: usize = 10; // BrightestN: tag + u64 + filter
const MIN_REPLY: usize = 2; // Match(None): tag + present byte
const MIN_ENTRY: usize = 8; // shard u32 + query count u32

fn put_str(w: &mut W, s: &str) {
    let bytes = s.as_bytes();
    w.u32(bytes.len() as u32);
    w.0.extend_from_slice(bytes);
}

fn get_str(r: &mut R) -> Result<String, WireError> {
    let n = r.count(1)?;
    String::from_utf8(r.take(n)?.to_vec()).map_err(|_| WireError::Malformed)
}

/// Encode a histogram as its full `Stats` state: moments, extremes,
/// and the bounded sample reservoir (so merged quantiles on the
/// scraping side stay deterministic).
fn put_stats(w: &mut W, s: &Stats) {
    w.u64(s.n);
    w.f64(s.sum);
    w.f64(s.sum2);
    w.f64(s.min);
    w.f64(s.max);
    let samples = s.samples();
    w.u32(samples.len() as u32);
    for x in samples {
        w.f64(*x);
    }
}

fn get_stats(r: &mut R) -> Result<Stats, WireError> {
    let n = r.u64()?;
    let sum = r.f64()?;
    let sum2 = r.f64()?;
    let min = r.f64()?;
    let max = r.f64()?;
    let ns = r.count(8)?;
    let mut samples = Vec::with_capacity(ns);
    for _ in 0..ns {
        samples.push(r.f64()?);
    }
    Ok(Stats::from_parts(n, sum, sum2, min, max, samples))
}

fn put_filter(w: &mut W, f: SourceFilter) {
    w.u8(match f {
        SourceFilter::Any => 0,
        SourceFilter::StarsOnly => 1,
        SourceFilter::GalaxiesOnly => 2,
    });
}

fn get_filter(r: &mut R) -> Result<SourceFilter, WireError> {
    match r.u8()? {
        0 => Ok(SourceFilter::Any),
        1 => Ok(SourceFilter::StarsOnly),
        2 => Ok(SourceFilter::GalaxiesOnly),
        _ => Err(WireError::Malformed),
    }
}

fn put_query(w: &mut W, q: &Query) {
    match q {
        Query::Cone { center, radius, filter } => {
            w.u8(1);
            w.f64(center.0);
            w.f64(center.1);
            w.f64(*radius);
            put_filter(w, *filter);
        }
        Query::BoxSearch { x0, y0, x1, y1, filter } => {
            w.u8(2);
            w.f64(*x0);
            w.f64(*y0);
            w.f64(*x1);
            w.f64(*y1);
            put_filter(w, *filter);
        }
        Query::BrightestN { n, filter } => {
            w.u8(3);
            w.u64(*n as u64);
            put_filter(w, *filter);
        }
        Query::CrossMatch { pos, radius } => {
            w.u8(4);
            w.f64(pos.0);
            w.f64(pos.1);
            w.f64(*radius);
        }
    }
}

fn get_query(r: &mut R) -> Result<Query, WireError> {
    match r.u8()? {
        1 => Ok(Query::Cone {
            center: (r.f64()?, r.f64()?),
            radius: r.f64()?,
            filter: get_filter(r)?,
        }),
        2 => Ok(Query::BoxSearch {
            x0: r.f64()?,
            y0: r.f64()?,
            x1: r.f64()?,
            y1: r.f64()?,
            filter: get_filter(r)?,
        }),
        3 => Ok(Query::BrightestN { n: r.u64()? as usize, filter: get_filter(r)? }),
        4 => Ok(Query::CrossMatch { pos: (r.f64()?, r.f64()?), radius: r.f64()? }),
        _ => Err(WireError::Malformed),
    }
}

fn put_source(w: &mut W, s: &ServedSource) {
    w.u64(s.id as u64);
    w.f64(s.pos.0);
    w.f64(s.pos.1);
    w.f64(s.p_gal);
    w.f64(s.flux_r);
    w.f64(s.flux_logsd);
    for c in &s.colors {
        w.f64(*c);
    }
    w.u8(s.converged as u8);
}

fn get_source(r: &mut R) -> Result<ServedSource, WireError> {
    let id = r.u64()? as usize;
    let pos = (r.f64()?, r.f64()?);
    let p_gal = r.f64()?;
    let flux_r = r.f64()?;
    let flux_logsd = r.f64()?;
    let mut colors = [0.0f64; 4];
    for c in &mut colors {
        *c = r.f64()?;
    }
    let converged = match r.u8()? {
        0 => false,
        1 => true,
        _ => return Err(WireError::Malformed),
    };
    Ok(ServedSource { id, pos, p_gal, flux_r, flux_logsd, colors, converged })
}

fn put_sources(w: &mut W, v: &[ServedSource]) {
    w.u32(v.len() as u32);
    for s in v {
        put_source(w, s);
    }
}

fn get_sources(r: &mut R) -> Result<Vec<ServedSource>, WireError> {
    let n = r.count(MIN_SOURCE)?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(get_source(r)?);
    }
    Ok(out)
}

/// Encode a row batch with the wire codec (count-prefixed rows, every
/// f64 as its IEEE-754 bits). Shared with the durable WAL
/// ([`crate::serve::durable`]) so a logged publish payload is
/// byte-identical to the `Publish` frame that carried it.
pub(crate) fn encode_sources(rows: &[ServedSource]) -> Vec<u8> {
    let mut w = W(Vec::with_capacity(4 + rows.len() * MIN_SOURCE));
    put_sources(&mut w, rows);
    w.0
}

/// Decode a batch produced by [`encode_sources`]; every payload byte
/// must be consumed (trailing garbage is [`WireError::Malformed`]).
pub(crate) fn decode_sources(bytes: &[u8]) -> Result<Vec<ServedSource>, WireError> {
    let mut r = R::new(bytes);
    let rows = get_sources(&mut r)?;
    r.done()?;
    Ok(rows)
}

fn put_reply(w: &mut W, reply: &ShardReply) {
    match reply {
        ShardReply::Sources(v) => {
            w.u8(1);
            put_sources(w, v);
        }
        ShardReply::Match(m) => {
            w.u8(2);
            match m {
                None => w.u8(0),
                Some(mr) => {
                    w.u8(1);
                    put_source(w, &mr.source);
                    w.f64(mr.dist);
                }
            }
        }
    }
}

fn get_reply(r: &mut R) -> Result<ShardReply, WireError> {
    match r.u8()? {
        1 => Ok(ShardReply::Sources(get_sources(r)?)),
        2 => match r.u8()? {
            0 => Ok(ShardReply::Match(None)),
            1 => {
                let source = get_source(r)?;
                let dist = r.f64()?;
                Ok(ShardReply::Match(Some(MatchResult { source, dist })))
            }
            _ => Err(WireError::Malformed),
        },
        _ => Err(WireError::Malformed),
    }
}

fn encode_payload(msg: &Msg) -> Vec<u8> {
    let mut w = W(Vec::new());
    match msg {
        Msg::Hello { version } => w.u8(*version),
        Msg::HelloAck { version, epoch, n_shards } => {
            w.u8(*version);
            w.u64(*epoch);
            w.u32(*n_shards);
        }
        Msg::Execute { req_id, min_epoch, trace_id, entries } => {
            w.u64(*req_id);
            w.u64(*min_epoch);
            w.u64(*trace_id);
            w.u32(entries.len() as u32);
            for (shard, queries) in entries {
                w.u32(*shard);
                w.u32(queries.len() as u32);
                for q in queries {
                    put_query(&mut w, q);
                }
            }
        }
        Msg::Reply { req_id, trace_id, server_spans, entries } => {
            w.u64(*req_id);
            w.u64(*trace_id);
            w.u32(server_spans.len() as u32);
            for (stage, secs) in server_spans {
                w.u8(*stage);
                w.f64(*secs);
            }
            w.u32(entries.len() as u32);
            for replies in entries {
                w.u32(replies.len() as u32);
                for rep in replies {
                    put_reply(&mut w, rep);
                }
            }
        }
        Msg::Publish { req_id, epoch, rows } => {
            w.u64(*req_id);
            w.u64(*epoch);
            put_sources(&mut w, rows);
        }
        Msg::PublishAck { req_id, epoch } => {
            w.u64(*req_id);
            w.u64(*epoch);
        }
        Msg::Error { req_id, code, detail } => {
            w.u64(*req_id);
            w.u8(code.to_u8());
            put_str(&mut w, detail);
        }
        Msg::StatsReq { req_id } => w.u64(*req_id),
        Msg::StatsReply { req_id, counters, gauges, histograms } => {
            w.u64(*req_id);
            w.u32(counters.len() as u32);
            for (name, v) in counters {
                put_str(&mut w, name);
                w.u64(*v);
            }
            w.u32(gauges.len() as u32);
            for (name, v) in gauges {
                put_str(&mut w, name);
                w.f64(*v);
            }
            w.u32(histograms.len() as u32);
            for (name, s) in histograms {
                put_str(&mut w, name);
                put_stats(&mut w, s);
            }
        }
        Msg::Cancel { trace_id } => w.u64(*trace_id),
    }
    w.0
}

fn decode_payload(tag: u8, payload: &[u8]) -> Result<Msg, WireError> {
    let mut r = R::new(payload);
    let msg = match tag {
        1 => Msg::Hello { version: r.u8()? },
        2 => Msg::HelloAck { version: r.u8()?, epoch: r.u64()?, n_shards: r.u32()? },
        3 => {
            let req_id = r.u64()?;
            let min_epoch = r.u64()?;
            let trace_id = r.u64()?;
            let n = r.count(MIN_ENTRY)?;
            let mut entries = Vec::with_capacity(n);
            for _ in 0..n {
                let shard = r.u32()?;
                let nq = r.count(MIN_QUERY)?;
                let mut queries = Vec::with_capacity(nq);
                for _ in 0..nq {
                    queries.push(get_query(&mut r)?);
                }
                entries.push((shard, queries));
            }
            Msg::Execute { req_id, min_epoch, trace_id, entries }
        }
        4 => {
            let req_id = r.u64()?;
            let trace_id = r.u64()?;
            let ns = r.count(9)?; // stage u8 + f64
            let mut server_spans = Vec::with_capacity(ns);
            for _ in 0..ns {
                let stage = r.u8()?;
                let secs = r.f64()?;
                server_spans.push((stage, secs));
            }
            let n = r.count(4)?;
            let mut entries = Vec::with_capacity(n);
            for _ in 0..n {
                let nr = r.count(MIN_REPLY)?;
                let mut replies = Vec::with_capacity(nr);
                for _ in 0..nr {
                    replies.push(get_reply(&mut r)?);
                }
                entries.push(replies);
            }
            Msg::Reply { req_id, trace_id, server_spans, entries }
        }
        5 => Msg::Publish { req_id: r.u64()?, epoch: r.u64()?, rows: get_sources(&mut r)? },
        6 => Msg::PublishAck { req_id: r.u64()?, epoch: r.u64()? },
        7 => {
            let req_id = r.u64()?;
            let code = ErrorCode::from_u8(r.u8()?).ok_or(WireError::Malformed)?;
            let detail = get_str(&mut r)?;
            Msg::Error { req_id, code, detail }
        }
        8 => Msg::StatsReq { req_id: r.u64()? },
        9 => {
            let req_id = r.u64()?;
            let nc = r.count(12)?; // name len + at least u64
            let mut counters = Vec::with_capacity(nc);
            for _ in 0..nc {
                let name = get_str(&mut r)?;
                counters.push((name, r.u64()?));
            }
            let ng = r.count(12)?;
            let mut gauges = Vec::with_capacity(ng);
            for _ in 0..ng {
                let name = get_str(&mut r)?;
                gauges.push((name, r.f64()?));
            }
            let nh = r.count(44)?; // name len + moments + sample count
            let mut histograms = Vec::with_capacity(nh);
            for _ in 0..nh {
                let name = get_str(&mut r)?;
                histograms.push((name, get_stats(&mut r)?));
            }
            Msg::StatsReply { req_id, counters, gauges, histograms }
        }
        10 => Msg::Cancel { trace_id: r.u64()? },
        t => return Err(WireError::BadTag(t)),
    };
    r.done()?;
    Ok(msg)
}

// -------------------------------------------------------------- framing

/// Encode `msg` as one complete frame (header + payload).
pub fn encode_frame(msg: &Msg) -> Vec<u8> {
    let payload = encode_payload(msg);
    let mut frame = Vec::with_capacity(HEADER_LEN + payload.len());
    frame.extend_from_slice(&MAGIC.to_le_bytes());
    frame.push(VERSION);
    frame.push(msg.tag());
    frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    frame.extend_from_slice(&payload);
    frame
}

/// Write one frame; returns the bytes written.
pub fn write_frame(w: &mut impl Write, msg: &Msg) -> Result<usize, WireError> {
    let frame = encode_frame(msg);
    w.write_all(&frame).map_err(|e| WireError::Io(e.kind()))?;
    w.flush().map_err(|e| WireError::Io(e.kind()))?;
    Ok(frame.len())
}

/// Read one frame. A clean close before any header byte is
/// [`WireError::Closed`]; a close anywhere after the first byte is
/// [`WireError::Truncated`]. The header is fully validated (magic,
/// version, tag, length cap) before any payload buffer is allocated.
pub fn read_frame(r: &mut impl Read) -> Result<Msg, WireError> {
    Ok(read_frame_timed(r)?.0)
}

/// [`read_frame`] plus the time spent *decoding* the payload (header
/// validation and socket reads excluded), in seconds — the codec cost
/// attributed to the `decode` trace stage.
pub fn read_frame_timed(r: &mut impl Read) -> Result<(Msg, f64), WireError> {
    let mut header = [0u8; HEADER_LEN];
    let mut got = 0;
    while got < HEADER_LEN {
        match r.read(&mut header[got..]) {
            Ok(0) => {
                return Err(if got == 0 { WireError::Closed } else { WireError::Truncated })
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e.kind())),
        }
    }
    let magic = u16::from_le_bytes([header[0], header[1]]);
    if magic != MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    let version = header[2];
    if version != VERSION {
        return Err(WireError::Version(version));
    }
    let tag = header[3];
    if !(1..=10).contains(&tag) {
        return Err(WireError::BadTag(tag));
    }
    let len = u32::from_le_bytes([header[4], header[5], header[6], header[7]]);
    if len > MAX_PAYLOAD {
        return Err(WireError::Oversized(len));
    }
    let mut payload = vec![0u8; len as usize];
    let mut got = 0;
    while got < payload.len() {
        match r.read(&mut payload[got..]) {
            Ok(0) => return Err(WireError::Truncated),
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e.kind())),
        }
    }
    let t0 = std::time::Instant::now();
    let msg = decode_payload(tag, &payload)?;
    Ok((msg, t0.elapsed().as_secs_f64()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn awkward_source(rng: &mut Rng, id: usize) -> ServedSource {
        ServedSource {
            id,
            pos: (rng.uniform() * 1e4, rng.uniform() * -1e-7),
            p_gal: rng.uniform(),
            flux_r: rng.lognormal(4.0, 2.0),
            flux_logsd: rng.uniform() * 0.7 + 1e-12,
            colors: [rng.normal(), rng.normal() * 1e9, rng.normal() * 1e-9, -0.0],
            converged: rng.uniform() < 0.5,
        }
    }

    fn sample_msgs() -> Vec<Msg> {
        let mut rng = Rng::new(404);
        let rows: Vec<ServedSource> = (0..17).map(|i| awkward_source(&mut rng, i)).collect();
        vec![
            Msg::Hello { version: VERSION },
            Msg::HelloAck { version: VERSION, epoch: 42, n_shards: 8 },
            Msg::Execute {
                req_id: 7,
                min_epoch: 3,
                trace_id: 0xDEAD_BEEF,
                entries: vec![
                    (
                        0,
                        vec![
                            Query::Cone {
                                center: (1.5, -2.25),
                                radius: 1e-3,
                                filter: SourceFilter::GalaxiesOnly,
                            },
                            Query::BrightestN { n: 0, filter: SourceFilter::StarsOnly },
                        ],
                    ),
                    (
                        5,
                        vec![Query::BoxSearch {
                            x0: -1.0,
                            y0: 0.0,
                            x1: f64::MAX,
                            y1: 1e300,
                            filter: SourceFilter::Any,
                        }],
                    ),
                    (9, vec![Query::CrossMatch { pos: (0.0, -0.0), radius: 2.5 }]),
                ],
            },
            Msg::Reply {
                req_id: 7,
                trace_id: 0xDEAD_BEEF,
                server_spans: vec![(3, 1.25e-4), (4, 0.0), (5, 7.5e-7)],
                entries: vec![
                    vec![ShardReply::Sources(rows[..5].to_vec()), ShardReply::Sources(vec![])],
                    vec![ShardReply::Match(None)],
                    vec![ShardReply::Match(Some(MatchResult {
                        source: rows[6].clone(),
                        dist: 0.125,
                    }))],
                ],
            },
            Msg::Publish { req_id: 9, epoch: 11, rows },
            Msg::PublishAck { req_id: 9, epoch: 11 },
            Msg::Error {
                req_id: 3,
                code: ErrorCode::Stale,
                detail: "applied epoch 2 < bound 5".to_string(),
            },
            Msg::StatsReq { req_id: 21 },
            Msg::StatsReply {
                req_id: 21,
                counters: vec![
                    ("net_frames".to_string(), 1234),
                    ("stale_refusals".to_string(), 0),
                ],
                gauges: vec![("applied_epoch".to_string(), 42.0)],
                histograms: vec![("stage_shard_execute".to_string(), {
                    let mut s = Stats::new();
                    for i in 0..9 {
                        s.push(1e-4 * (i as f64 + 0.5));
                    }
                    s
                })],
            },
            Msg::Cancel { trace_id: 0xFEED },
        ]
    }

    #[test]
    fn every_message_roundtrips_bit_exactly() {
        for msg in sample_msgs() {
            let frame = encode_frame(&msg);
            let mut cursor = &frame[..];
            let back = read_frame(&mut cursor).unwrap();
            assert_eq!(back, msg);
            assert!(cursor.is_empty(), "frame must consume exactly its bytes");
            // a second encode is byte-stable
            assert_eq!(encode_frame(&back), frame);
        }
    }

    #[test]
    fn frames_concatenate_on_one_stream() {
        let msgs = sample_msgs();
        let mut stream = Vec::new();
        for m in &msgs {
            stream.extend_from_slice(&encode_frame(m));
        }
        let mut cursor = &stream[..];
        for m in &msgs {
            assert_eq!(&read_frame(&mut cursor).unwrap(), m);
        }
        assert_eq!(read_frame(&mut cursor), Err(WireError::Closed));
    }

    #[test]
    fn empty_stream_is_a_clean_close_partial_header_is_truncated() {
        assert_eq!(read_frame(&mut &[][..]), Err(WireError::Closed));
        let frame = encode_frame(&Msg::Hello { version: VERSION });
        for cut in 1..HEADER_LEN {
            assert_eq!(
                read_frame(&mut &frame[..cut]),
                Err(WireError::Truncated),
                "cut at {cut}"
            );
        }
    }

    #[test]
    fn mid_payload_disconnect_is_truncated() {
        let frame = encode_frame(&Msg::PublishAck { req_id: 1, epoch: 2 });
        for cut in HEADER_LEN..frame.len() {
            assert_eq!(
                read_frame(&mut &frame[..cut]),
                Err(WireError::Truncated),
                "cut at {cut}"
            );
        }
    }

    #[test]
    fn bad_magic_version_and_tag_are_typed_errors() {
        let good = encode_frame(&Msg::Hello { version: VERSION });
        let mut bad = good.clone();
        bad[0] = 0x00;
        assert!(matches!(read_frame(&mut &bad[..]), Err(WireError::BadMagic(_))));
        let mut bad = good.clone();
        bad[2] = 99;
        assert_eq!(read_frame(&mut &bad[..]), Err(WireError::Version(99)));
        let mut bad = good.clone();
        bad[3] = 0;
        assert_eq!(read_frame(&mut &bad[..]), Err(WireError::BadTag(0)));
        let mut bad = good;
        bad[3] = 200;
        assert_eq!(read_frame(&mut &bad[..]), Err(WireError::BadTag(200)));
    }

    #[test]
    fn oversized_length_prefix_is_rejected_before_allocation() {
        // a header announcing a u32::MAX payload with no payload behind
        // it: the reject must come from the length check, not from an
        // allocation or a read failure
        let mut frame = Vec::new();
        frame.extend_from_slice(&MAGIC.to_le_bytes());
        frame.push(VERSION);
        frame.push(1);
        frame.extend_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(read_frame(&mut &frame[..]), Err(WireError::Oversized(u32::MAX)));
        // just over the cap is equally rejected...
        let mut frame2 = frame.clone();
        frame2[4..8].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        assert_eq!(
            read_frame(&mut &frame2[..]),
            Err(WireError::Oversized(MAX_PAYLOAD + 1))
        );
        // ...while a frame at the cap fails only on the missing payload
        let mut frame3 = frame;
        frame3[4..8].copy_from_slice(&MAX_PAYLOAD.to_le_bytes());
        assert_eq!(read_frame(&mut &frame3[..]), Err(WireError::Truncated));
    }

    #[test]
    fn hostile_element_counts_inside_a_payload_are_malformed() {
        // a Publish frame whose row count claims far more rows than the
        // payload holds: the count bound rejects it without allocating
        let mut w = W(Vec::new());
        w.u64(1); // req_id
        w.u64(1); // epoch
        w.u32(u32::MAX); // row count with no rows behind it
        let payload = w.0;
        let mut frame = Vec::new();
        frame.extend_from_slice(&MAGIC.to_le_bytes());
        frame.push(VERSION);
        frame.push(5);
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&payload);
        assert_eq!(read_frame(&mut &frame[..]), Err(WireError::Malformed));
    }

    #[test]
    fn trailing_garbage_and_bad_enums_are_malformed() {
        let mut frame = encode_frame(&Msg::Hello { version: VERSION });
        // grow the payload by one byte and fix up the length prefix
        frame.push(0xAB);
        let len = (frame.len() - HEADER_LEN) as u32;
        frame[4..8].copy_from_slice(&len.to_le_bytes());
        assert_eq!(read_frame(&mut &frame[..]), Err(WireError::Malformed));
        // an Execute whose query tag is unknown
        let mut w = W(Vec::new());
        w.u64(1);
        w.u64(0);
        w.u32(1); // one entry
        w.u32(0); // shard
        w.u32(1); // one query
        w.u8(9); // unknown query tag
        w.u64(0);
        w.u8(0);
        let payload = w.0;
        let mut frame = Vec::new();
        frame.extend_from_slice(&MAGIC.to_le_bytes());
        frame.push(VERSION);
        frame.push(3);
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&payload);
        assert_eq!(read_frame(&mut &frame[..]), Err(WireError::Malformed));
    }

    #[test]
    fn peer_version_error_is_actionable() {
        let e = WireError::PeerVersion { ours: VERSION, theirs: 1 };
        let msg = e.to_string();
        assert!(msg.contains("version mismatch"), "{msg}");
        assert!(msg.contains(&format!("v{VERSION}")), "{msg}");
        assert!(msg.contains("v1"), "{msg}");
        assert!(msg.contains("docs/WIRE.md"), "{msg}");
        let e = WireError::PeerVersion { ours: VERSION, theirs: 0 };
        let msg = e.to_string();
        assert!(msg.contains("bad-version"), "{msg}");
        assert!(msg.contains("docs/WIRE.md"), "{msg}");
    }

    #[test]
    fn decode_timing_is_reported() {
        let frame = encode_frame(&Msg::StatsReq { req_id: 1 });
        let (msg, decode_s) = read_frame_timed(&mut &frame[..]).unwrap();
        assert_eq!(msg, Msg::StatsReq { req_id: 1 });
        assert!(decode_s >= 0.0 && decode_s.is_finite());
    }

    #[test]
    fn error_codes_roundtrip() {
        for code in [
            ErrorCode::BadVersion,
            ErrorCode::Malformed,
            ErrorCode::Stale,
            ErrorCode::EpochGap,
            ErrorCode::Internal,
        ] {
            assert_eq!(ErrorCode::from_u8(code.to_u8()), Some(code));
        }
        assert_eq!(ErrorCode::from_u8(0), None);
        assert_eq!(ErrorCode::from_u8(6), None);
    }
}
