//! The real RPC transport: multi-process shard serving over a
//! length-prefixed binary wire protocol.
//!
//! Everything "distributed" below `serve/dist/` runs in simulated time
//! inside one process — [`FabricShard`](crate::serve::dist::FabricShard)
//! charges bytes to the fabric model but never serializes a byte. This
//! module is the same tier over real sockets:
//!
//! * [`wire`] — the framed binary codec (versioned header, typed
//!   errors, bit-exact `f64`s, allocation-bounded decoding);
//! * [`ShardServer`] — a process (or thread) owning a
//!   [`VersionedStore`](crate::serve::ingest::VersionedStore) replica,
//!   answering shard sub-queries and applying epoch publishes over TCP;
//! * [`NetConn`] / [`NetShardClient`] — the pipelined per-server
//!   connection and the [`ShardClient`](crate::serve::dist::ShardClient)
//!   trait adapter over it;
//! * [`NetRouterEngine`] — the front-end
//!   [`QueryEngine`](crate::serve::engine::QueryEngine) tier that plans
//!   on a local mirror, coalesces same-shard sub-queries into one frame
//!   per server, fails over on server death, and ships ingest epochs to
//!   every replica before its mirror advances.
//!
//! `serve-bench --transport tcp` spawns local `celeste shard-server`
//! child processes and drives this tier wall-clock; `--transport sim`
//! (the default) keeps the simulated fabric. See `docs/WIRE.md` for the
//! wire layout and `README.md` for the flag matrix.
//!
//! The control plane reaches this tier too (wire v3): a fire-and-forget
//! `Cancel` frame drops a resolved hedge race's loser before any shard
//! work runs (counted in the server's `hedge_cancels`), and
//! [`NetRouterEngine::rebalance_to`] swaps the routing placement live —
//! every server loads the full catalog, so a tcp-tier "migration" is an
//! instant routing change with parity preserved throughout.
//!
//! Shutdown is graceful: [`signal`] flips a flag on SIGTERM and
//! [`ShardServer::run_graceful`] flushes a final checkpoint + terminal
//! stats line before the process exits, so the last acked epoch is on
//! disk even when the parent tears the fleet down.

pub mod client;
pub mod server;
pub mod signal;
pub mod wire;

mod router;

pub use client::{NetConn, NetShardClient, WireTimes};
pub use router::NetRouterEngine;
pub use server::{ShardServer, ShardServerHandle, TermReport};
pub use wire::{ErrorCode, Msg, WireError};

use std::time::Duration;

use crate::serve::obs;

/// One-shot stats scrape of a shard server at `addr`: fresh
/// connection, `StatsReq`, snapshot back. The collector uses this to
/// fold a restarted server (whose long-lived [`NetConn`] died with the
/// old process) back into its timeline.
pub fn scrape_addr(addr: &str, timeout: Duration) -> Result<obs::Snapshot, WireError> {
    NetConn::new(addr.to_string()).scrape(Some(timeout))
}
