//! `NetRouterEngine`: the front-end tier that plans queries over its
//! own catalog mirror and scatters the per-shard sub-queries to real
//! shard-server processes over TCP.
//!
//! Placement is the same rendezvous hash the simulated dist tier uses
//! ([`Placement::rendezvous`]), planning is the same
//! [`plan_shards`]/[`plan_batch`], execution on the far side is the
//! same `execute_on_shard`, and the fold is the same
//! [`merge_replies`] — so byte-parity with the in-process store is by
//! construction, not by luck. What this tier adds is everything the
//! fabric model abstracted away: one framed request per contacted
//! server (a whole scheduler batch's same-shard sub-queries coalesce
//! into a single frame), real encode/decode cost, real kernel round
//! trips, reconnect-with-backoff, and failover to the next replica
//! when a server dies mid-run.
//!
//! Epoch publishes are shipped to **every** server and acked before
//! the front-end mirror advances, so a query planned against the new
//! head can never reach a server that has not applied it — that
//! in-order pipe is what makes `Fresh`/`AtMost(k)` hold across the
//! process boundary at full byte parity, live ingestion included.
//!
//! A server that fails a round trip is marked suspected and never
//! retried (kill-style failure injection; revival is not modeled over
//! TCP). With replication R, up to R-1 server deaths are absorbed
//! with zero failed queries.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::serve::control::NodeLoad;
use crate::serve::dist::Placement;
use crate::serve::engine::{enforce_deadline, Consistency, QueryEngine, Request, Response};
use crate::serve::ingest::{EpochStore, IngestReport, VersionedStore};
use crate::serve::obs::{self, Registry, SpanSet, Stage, TraceRecord, TraceSampler};
use crate::serve::query::{merge_replies, plan_shards, Query, QueryResult, ShardReply};
use crate::serve::sched::plan_batch;
use crate::serve::store::Store;

use super::client::{NetConn, WireTimes};
use super::wire::WireError;

struct Inner {
    /// front-end planning mirror; advanced only after every server acks
    mirror: Arc<VersionedStore>,
    /// routing placement — mutable because the control plane swaps it
    /// live ([`NetRouterEngine::rebalance_to`]); every server loads the
    /// full catalog, so a swap is purely a routing change
    placement: Mutex<Placement>,
    conns: Vec<Arc<NetConn>>,
    /// replica rotation cursor (round-robin over live replicas)
    rr: AtomicUsize,
    /// sticky per-server death marks fed by failed round trips
    suspected: Vec<AtomicBool>,
    /// cumulative sub-queries dispatched per shard — the controller's
    /// per-shard demand signal
    served_per_shard: Vec<AtomicU64>,
    /// cumulative sub-queries answered per server
    served_per_server: Vec<AtomicU64>,
    /// wall-clock nanoseconds this front end spent waiting on each
    /// server's round trips (the tcp tier's busy proxy)
    busy_ns_per_server: Vec<AtomicU64>,
    /// shards whose replica set changed across every placement swap
    migrations: AtomicU64,
    failovers: AtomicU64,
    failed: AtomicU64,
    epochs_published: AtomicU64,
    /// serializes publishes (the mirror asserts strictly advancing epochs)
    publish_lock: Mutex<()>,
    /// the front end's metrics registry (`stage_*` histograms)
    registry: Arc<Registry>,
    /// `--trace-sample` / `--slow-ms` sampler
    sampler: Arc<TraceSampler>,
}

/// The TCP serving tier as one more [`QueryEngine`]: admission,
/// caching, hedging, consistency stamping, and both drivers compose
/// over it unchanged. Clones share the connections and counters —
/// keep one to publish ingest epochs and read wire metrics after the
/// engine is boxed into a middleware stack.
#[derive(Clone)]
pub struct NetRouterEngine {
    inner: Arc<Inner>,
    desc: String,
}

impl NetRouterEngine {
    /// Connect to one shard server per address and verify each with an
    /// empty round trip. `store` must be built from the same snapshot
    /// (and shard count) the servers loaded — shard indices must agree.
    pub fn connect(
        store: Arc<Store>,
        addrs: &[String],
        replicas: usize,
    ) -> Result<NetRouterEngine, WireError> {
        NetRouterEngine::connect_pipelined(store, addrs, replicas, 1)
    }

    /// [`NetRouterEngine::connect`] with per-connection pipelining:
    /// each server connection keeps up to `pipeline` Execute frames in
    /// flight, replies matched by req_id (1 = strict lockstep).
    pub fn connect_pipelined(
        store: Arc<Store>,
        addrs: &[String],
        replicas: usize,
        pipeline: usize,
    ) -> Result<NetRouterEngine, WireError> {
        let pipeline = pipeline.max(1);
        let n_servers = addrs.len().max(1);
        let placement = Placement::rendezvous(store.shards.len(), n_servers, replicas);
        let conns: Vec<Arc<NetConn>> = addrs
            .iter()
            .map(|a| Arc::new(NetConn::with_pipeline(a.clone(), pipeline)))
            .collect();
        for conn in &conns {
            // handshake + empty execute: fail fast if a server is down
            conn.execute(Vec::new(), 0, Some(Duration::from_secs(5)))?;
        }
        let desc = format!(
            "net-router(tcp, {} server(s) x{} replicas, {} shards, pipeline {})",
            n_servers,
            placement.replicas,
            store.shards.len(),
            pipeline
        );
        let n_shards = store.shards.len();
        let mirror = Arc::new(VersionedStore::new(store));
        Ok(NetRouterEngine {
            inner: Arc::new(Inner {
                mirror,
                placement: Mutex::new(placement),
                conns,
                rr: AtomicUsize::new(0),
                suspected: (0..n_servers).map(|_| AtomicBool::new(false)).collect(),
                served_per_shard: (0..n_shards).map(|_| AtomicU64::new(0)).collect(),
                served_per_server: (0..n_servers).map(|_| AtomicU64::new(0)).collect(),
                busy_ns_per_server: (0..n_servers).map(|_| AtomicU64::new(0)).collect(),
                migrations: AtomicU64::new(0),
                failovers: AtomicU64::new(0),
                failed: AtomicU64::new(0),
                epochs_published: AtomicU64::new(0),
                publish_lock: Mutex::new(()),
                registry: Arc::new(Registry::new()),
                sampler: Arc::new(TraceSampler::new()),
            }),
            desc,
        })
    }

    /// The front end's metrics registry (per-stage `stage_*` wall-clock
    /// histograms; counters folded in by [`NetRouterEngine::obs_snapshot`]).
    pub fn registry(&self) -> &Arc<Registry> {
        &self.inner.registry
    }

    /// The front end's trace sampler.
    pub fn sampler(&self) -> &Arc<TraceSampler> {
        &self.inner.sampler
    }

    /// Arm trace sampling: keep every `every`th request (0 = off) and
    /// everything slower than `slow_s` seconds (<= 0 = off).
    pub fn configure_tracing(&self, every: u64, slow_s: f64) {
        self.inner.sampler.configure(every, slow_s);
    }

    /// The front end's registry snapshot with the per-connection wire
    /// counters folded in (same names and values as
    /// [`QueryEngine::metrics`], plus `net_stale_refusals`).
    pub fn obs_snapshot(&self) -> obs::Snapshot {
        let inner = &*self.inner;
        self.inner.registry.absorb_metrics(&self.metrics());
        let stale: u64 =
            inner.conns.iter().map(|c| c.stale_refusals.load(Ordering::Relaxed)).sum();
        let mut snap = self.inner.registry.snapshot();
        snap.counters.insert("net_stale_refusals".to_string(), stale);
        snap.counters
            .insert("net_frames".to_string(), self.frames_sent());
        snap
    }

    /// Scrape each live shard server's registry snapshot (`StatsReq`).
    /// Dead servers are skipped.
    pub fn scrape(&self) -> Vec<obs::Snapshot> {
        let inner = &*self.inner;
        inner
            .conns
            .iter()
            .enumerate()
            .filter(|(i, _)| !inner.suspected[*i].load(Ordering::SeqCst))
            .filter_map(|(_, c)| c.scrape(Some(Duration::from_secs(5))).ok())
            .collect()
    }

    /// Per-node scrape for the continuous collector: one entry per
    /// server, in node order, `None` for a server that is suspected or
    /// fails the scrape (a failed scrape is a failed round trip, so it
    /// marks the server suspected like any other). Successful samples
    /// are augmented with this side's per-connection wire counters
    /// (`conn_io_errors` / `conn_timeouts` / `conn_reconnects`) — the
    /// health model's error and reconnect signals — and the bytes this
    /// front end moved to that server.
    pub fn scrape_nodes(&self, deadline: Duration) -> Vec<Option<obs::Snapshot>> {
        let inner = &*self.inner;
        inner
            .conns
            .iter()
            .enumerate()
            .map(|(i, c)| {
                if inner.suspected[i].load(Ordering::SeqCst) {
                    return None;
                }
                match c.scrape(Some(deadline)) {
                    Ok(mut snap) => {
                        snap.counters.insert(
                            "conn_io_errors".to_string(),
                            c.io_errors.load(Ordering::Relaxed),
                        );
                        snap.counters.insert(
                            "conn_timeouts".to_string(),
                            c.timeouts.load(Ordering::Relaxed),
                        );
                        snap.counters.insert(
                            "conn_reconnects".to_string(),
                            c.reconnects.load(Ordering::Relaxed),
                        );
                        snap.counters.insert(
                            "conn_bytes_sent".to_string(),
                            c.bytes_sent.load(Ordering::Relaxed),
                        );
                        Some(snap)
                    }
                    Err(_) => {
                        inner.suspected[i].store(true, Ordering::SeqCst);
                        None
                    }
                }
            })
            .collect()
    }

    /// Send one deliberately-too-fresh execute (consistency bound one
    /// past the mirror's head) to the first live server. The server
    /// must refuse it as `Stale`, which increments both its
    /// `stale_refusals` counter and this side's `net_stale_refusals` —
    /// the CI probe that proves the refusal path is live end to end.
    /// Returns true when the refusal round-tripped as expected.
    pub fn probe_stale(&self) -> bool {
        let inner = &*self.inner;
        let too_fresh = inner.mirror.load().epoch + 1;
        for (i, conn) in inner.conns.iter().enumerate() {
            if inner.suspected[i].load(Ordering::SeqCst) {
                continue;
            }
            let got = conn.execute(Vec::new(), too_fresh, Some(Duration::from_secs(5)));
            return matches!(got, Err(WireError::Remote(super::wire::ErrorCode::Stale)));
        }
        false
    }

    /// A clone of the current routing placement (the control plane
    /// swaps the live one via [`NetRouterEngine::rebalance_to`]).
    pub fn placement(&self) -> Placement {
        self.inner.placement.lock().expect("placement lock").clone()
    }

    /// Swap the routing placement for `target`. Every shard server
    /// loads the full catalog, so a shard "migration" on the tcp tier
    /// is purely a routing change — the swap is instant, nothing
    /// ships, and scatters already planned finish against the
    /// placement they picked replicas under. Shards whose replica set
    /// changed are counted as migrations (`net_migrations`). Returns
    /// the number of shards moved; errors when the target's shape does
    /// not match this tier.
    pub fn rebalance_to(&self, target: Placement) -> Result<u64, String> {
        let inner = &*self.inner;
        if target.n_nodes != inner.conns.len() {
            return Err(format!(
                "target places over {} nodes but this tier has {} servers",
                target.n_nodes,
                inner.conns.len()
            ));
        }
        let mut p = inner.placement.lock().expect("placement lock");
        if target.n_shards() != p.n_shards() {
            return Err(format!(
                "target has {} shards but the store has {}",
                target.n_shards(),
                p.n_shards()
            ));
        }
        let mut moved = 0u64;
        for s in 0..p.n_shards() {
            let mut a = p.shard_nodes[s].clone();
            let mut b = target.shard_nodes[s].clone();
            a.sort_unstable();
            b.sort_unstable();
            if a != b {
                moved += 1;
            }
        }
        *p = target;
        inner.migrations.fetch_add(moved, Ordering::Relaxed);
        Ok(moved)
    }

    /// One [`NodeLoad`] per server for the controller: liveness from
    /// the suspicion marks, cumulative sub-queries served, and the
    /// wall-clock seconds spent waiting on that server's round trips.
    pub fn node_loads(&self) -> Vec<NodeLoad> {
        let inner = &*self.inner;
        (0..inner.conns.len())
            .map(|i| NodeLoad {
                alive: !inner.suspected[i].load(Ordering::SeqCst),
                served: inner.served_per_server[i].load(Ordering::Relaxed),
                busy_s: inner.busy_ns_per_server[i].load(Ordering::Relaxed) as f64 * 1e-9,
            })
            .collect()
    }

    /// Cumulative sub-query dispatches per shard — the controller's
    /// per-shard demand signal.
    pub fn served_per_shard(&self) -> Vec<u64> {
        self.inner.served_per_shard.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    /// Shards whose replica set changed across every placement swap.
    pub fn migrations(&self) -> u64 {
        self.inner.migrations.load(Ordering::Relaxed)
    }

    /// Propagate a cancellation to every live server (fire-and-forget
    /// `Cancel` frames, wire v3): any not-yet-executed sub-query of
    /// this trace is dropped server-side before a shard runs it and
    /// counted in that server's `hedge_cancels`.
    pub fn cancel(&self, trace_id: u64) {
        let inner = &*self.inner;
        for (i, conn) in inner.conns.iter().enumerate() {
            if !inner.suspected[i].load(Ordering::SeqCst) {
                conn.cancel(trace_id);
            }
        }
    }

    pub fn n_servers(&self) -> usize {
        self.inner.conns.len()
    }

    /// Total request frames sent across every server connection — the
    /// coalescing contract's observable (one frame per contacted
    /// server per batch).
    pub fn frames_sent(&self) -> u64 {
        self.inner.conns.iter().map(|c| c.frames.load(Ordering::Relaxed)).sum()
    }

    /// Servers currently marked dead by failed round trips.
    pub fn suspected(&self) -> Vec<bool> {
        self.inner.suspected.iter().map(|s| s.load(Ordering::SeqCst)).collect()
    }

    /// Ship one ingest epoch to every shard server (acked before the
    /// planning mirror advances). Mirrors `RouterEngine::publish`.
    pub fn publish(&self, report: &IngestReport) {
        let inner = &*self.inner;
        let _g = inner.publish_lock.lock().expect("publish lock");
        let epoch = report.epoch;
        let rows = &report.deltas;
        std::thread::scope(|s| {
            for (i, conn) in inner.conns.iter().enumerate() {
                s.spawn(move || {
                    if inner.suspected[i].load(Ordering::SeqCst) {
                        return;
                    }
                    // one retry: the first failure drops the socket, the
                    // second attempt redials with backoff (covers a
                    // server restartless blip); then give up and mark
                    let ok = conn.publish(epoch, rows, None).is_ok()
                        || conn.publish(epoch, rows, None).is_ok();
                    if !ok {
                        inner.suspected[i].store(true, Ordering::SeqCst);
                    }
                });
            }
        });
        inner.mirror.publish(Arc::clone(&report.published));
        inner.epochs_published.fetch_add(1, Ordering::Relaxed);
    }

    /// Execute a whole batch with per-server coalescing: all
    /// sub-queries of one batch bound for one server travel in one
    /// frame. Results are in input order, byte-identical to per-query
    /// [`crate::serve::query::execute`]; `None` marks a query whose
    /// shards lost every replica.
    pub fn call_batch(&self, queries: &[Query]) -> Vec<Option<QueryResult>> {
        if queries.is_empty() {
            return Vec::new();
        }
        let head = self.inner.mirror.load();
        let by_shard = plan_batch(&head.store, queries);
        let groups: Vec<(u32, Vec<Query>)> = by_shard
            .iter()
            .enumerate()
            .filter(|(_, qis)| !qis.is_empty())
            .map(|(s, qis)| (s as u32, qis.iter().map(|&qi| queries[qi].clone()).collect()))
            .collect();
        match self.execute_grouped(groups, 0, 0, None) {
            Ok((mut by_shard_replies, _, _)) => {
                let mut replies: Vec<Vec<ShardReply>> =
                    (0..queries.len()).map(|_| Vec::new()).collect();
                // ascending shard order — the canonical merge order the
                // in-process batch path uses
                for (s, qis) in by_shard.iter().enumerate() {
                    if qis.is_empty() {
                        continue;
                    }
                    let reps = by_shard_replies.remove(&(s as u32)).expect("every shard answered");
                    debug_assert_eq!(reps.len(), qis.len());
                    for (&qi, rep) in qis.iter().zip(reps) {
                        replies[qi].push(rep);
                    }
                }
                queries
                    .iter()
                    .zip(replies)
                    .map(|(q, r)| Some(merge_replies(q, r)))
                    .collect()
            }
            Err(()) => {
                self.inner.failed.fetch_add(queries.len() as u64, Ordering::Relaxed);
                queries.iter().map(|_| None).collect()
            }
        }
    }

    /// Core scatter: assign each shard group to a live replica, send
    /// one frame per contacted server, fail servers over on error.
    /// Returns shard -> replies (parallel to that shard's queries) plus
    /// the critical round trip's stage timing and server-side spans
    /// (the slowest call — the one that explains the scatter's wall
    /// time), or `Err(())` once some shard has no live replica left.
    fn execute_grouped(
        &self,
        groups: Vec<(u32, Vec<Query>)>,
        min_epoch: u64,
        trace_id: u64,
        deadline: Option<Duration>,
    ) -> Result<(BTreeMap<u32, Vec<ShardReply>>, WireTimes, SpanSet), ()> {
        let inner = &*self.inner;
        let mut results: BTreeMap<u32, Vec<ShardReply>> = BTreeMap::new();
        let mut crit = WireTimes::default();
        let mut crit_spans = SpanSet::new();
        let mut remaining = groups;
        while !remaining.is_empty() {
            // pick a live replica per shard, rotating the start slot;
            // the placement is read under its lock so a concurrent
            // control-plane swap is seen atomically per shard
            let mut per_server: BTreeMap<usize, Vec<(u32, Vec<Query>)>> = BTreeMap::new();
            for (shard, queries) in remaining.drain(..) {
                let reps: Vec<usize> = {
                    let p = inner.placement.lock().expect("placement lock");
                    p.replicas_of(shard as usize).to_vec()
                };
                let offset = inner.rr.fetch_add(1, Ordering::Relaxed);
                let pick = (0..reps.len())
                    .map(|i| reps[(offset + i) % reps.len()])
                    .find(|&n| !inner.suspected[n].load(Ordering::SeqCst));
                match pick {
                    Some(server) => per_server.entry(server).or_default().push((shard, queries)),
                    None => return Err(()),
                }
            }
            // one frame per server; scatter concurrently when >1
            let plan: Vec<(usize, Vec<(u32, Vec<Query>)>)> = per_server.into_iter().collect();
            type TracedOutcome = Result<(Vec<Vec<ShardReply>>, WireTimes, SpanSet), WireError>;
            let outcomes: Vec<TracedOutcome> = if plan.len() == 1 {
                vec![inner.conns[plan[0].0].execute_traced(
                    plan[0].1.clone(),
                    min_epoch,
                    trace_id,
                    deadline,
                )]
            } else {
                std::thread::scope(|s| {
                    let handles: Vec<_> = plan
                        .iter()
                        .map(|(server, entries)| {
                            let conn = Arc::clone(&inner.conns[*server]);
                            let entries = entries.clone();
                            s.spawn(move || {
                                conn.execute_traced(entries, min_epoch, trace_id, deadline)
                            })
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| h.join().unwrap_or(Err(WireError::Malformed)))
                        .collect()
                })
            };
            for ((server, entries), outcome) in plan.into_iter().zip(outcomes) {
                match outcome {
                    Ok((replies, times, server_spans)) => {
                        if times.total_s >= crit.total_s {
                            crit = times;
                            crit_spans = server_spans;
                        }
                        let mut subs = 0u64;
                        for (shard, queries) in &entries {
                            inner.served_per_shard[*shard as usize]
                                .fetch_add(queries.len() as u64, Ordering::Relaxed);
                            subs += queries.len() as u64;
                        }
                        inner.served_per_server[server].fetch_add(subs, Ordering::Relaxed);
                        inner.busy_ns_per_server[server]
                            .fetch_add((times.total_s * 1e9) as u64, Ordering::Relaxed);
                        for ((shard, _), reps) in entries.into_iter().zip(replies) {
                            results.insert(shard, reps);
                        }
                    }
                    Err(_) => {
                        // the conn already counted the error and dropped
                        // the socket; mark the server and re-queue its
                        // shard groups for the next replica
                        inner.suspected[server].store(true, Ordering::SeqCst);
                        inner.failovers.fetch_add(1, Ordering::Relaxed);
                        remaining.extend(entries);
                    }
                }
            }
        }
        Ok((results, crit, crit_spans))
    }
}

impl QueryEngine for NetRouterEngine {
    fn call(&self, req: Request) -> Response {
        let t = Instant::now();
        let head = self.inner.mirror.load();
        // publishes are acked by every live server before the mirror
        // advances, so the head epoch is a bound every server meets;
        // min_epoch makes the server enforce it rather than trust it
        let min_epoch = match req.consistency {
            Consistency::Fresh => head.epoch,
            Consistency::AtMost(k) => head.epoch.saturating_sub(k as u64),
            Consistency::CachedOk => 0,
        };
        let deadline = req.deadline.map(Duration::from_secs_f64);
        let plan = plan_shards(&head.store, &req.query);
        let groups: Vec<(u32, Vec<Query>)> =
            plan.iter().map(|&s| (s as u32, vec![req.query.clone()])).collect();
        let frames0 = self.frames_sent();
        let assemble_s = t.elapsed().as_secs_f64();
        match self.execute_grouped(groups, min_epoch, req.trace_id, deadline) {
            Ok((mut by_shard, times, server_spans)) => {
                let scatter_end_s = t.elapsed().as_secs_f64();
                let replies: Vec<ShardReply> = plan
                    .iter()
                    .map(|&s| {
                        let mut reps = by_shard.remove(&(s as u32)).expect("every shard answered");
                        reps.pop().expect("one query per shard")
                    })
                    .collect();
                let result = merge_replies(&req.query, replies);
                let total_s = t.elapsed().as_secs_f64();
                // the stages partition [0, total_s]: plan+group, then
                // the scatter segment split into the critical round
                // trip's encode/decode and the residual wire wait, then
                // the merge — so the spans sum to the measured
                // end-to-end latency by construction
                let seg = scatter_end_s - assemble_s;
                let mut spans = SpanSet::new();
                spans.add(Stage::BatchAssembly, assemble_s);
                spans.add(Stage::Encode, times.encode_s.min(seg));
                spans.add(Stage::Decode, times.decode_s.min(seg - times.encode_s));
                spans.add(Stage::NetRtt, seg - spans.get(Stage::Encode) - spans.get(Stage::Decode));
                spans.add(Stage::Merge, total_s - scatter_end_s);
                self.inner.registry.record_spans(&spans);
                self.inner.registry.histogram("request_latency").record(total_s);
                self.inner
                    .registry
                    .histogram(&format!("request_latency_{}", req.query.class().name()))
                    .record(total_s);
                if self.inner.sampler.enabled() {
                    self.inner.sampler.observe(TraceRecord {
                        trace_id: req.trace_id,
                        total_s,
                        spans,
                        server_spans,
                        slow: false,
                    });
                }
                let mut resp = Response::served(result, req.at + total_s);
                resp.trace.replicas_contacted = (self.frames_sent() - frames0) as u32;
                resp.trace.trace_id = req.trace_id;
                resp.trace.spans = spans;
                resp.trace.server_spans = server_spans;
                enforce_deadline(req.at, req.deadline, resp)
            }
            Err(()) => {
                self.inner.failed.fetch_add(1, Ordering::Relaxed);
                Response::failed(req.at + t.elapsed().as_secs_f64())
            }
        }
    }

    fn describe(&self) -> String {
        self.desc.clone()
    }

    fn metrics(&self) -> Vec<(String, f64)> {
        let inner = &*self.inner;
        let sum = |f: fn(&NetConn) -> &AtomicU64| -> f64 {
            inner.conns.iter().map(|c| f(c.as_ref()).load(Ordering::Relaxed)).sum::<u64>() as f64
        };
        let frames = sum(|c| &c.frames).max(1.0);
        vec![
            ("net_frames".to_string(), sum(|c| &c.frames)),
            ("net_bytes_sent".to_string(), sum(|c| &c.bytes_sent)),
            ("net_bytes_recv".to_string(), sum(|c| &c.bytes_recv)),
            ("net_reconnects".to_string(), sum(|c| &c.reconnects)),
            ("net_io_errors".to_string(), sum(|c| &c.io_errors)),
            ("net_timeouts".to_string(), sum(|c| &c.timeouts)),
            ("net_stale_refusals".to_string(), sum(|c| &c.stale_refusals)),
            ("net_encode_us_per_frame".to_string(), sum(|c| &c.encode_ns) * 1e-3 / frames),
            ("net_decode_us_per_frame".to_string(), sum(|c| &c.decode_ns) * 1e-3 / frames),
            (
                "net_migrations".to_string(),
                inner.migrations.load(Ordering::Relaxed) as f64,
            ),
            (
                "net_failovers".to_string(),
                inner.failovers.load(Ordering::Relaxed) as f64,
            ),
            ("net_failed".to_string(), inner.failed.load(Ordering::Relaxed) as f64),
            (
                "net_epochs_published".to_string(),
                inner.epochs_published.load(Ordering::Relaxed) as f64,
            ),
        ]
    }

    fn epoch_view(&self) -> Option<Arc<EpochStore>> {
        Some(self.inner.mirror.load())
    }
}
