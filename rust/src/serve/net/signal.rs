//! SIGTERM plumbing for shard-server processes — graceful shutdown
//! without a libc dependency.
//!
//! The handler is the only async-signal-safe thing a handler can be: a
//! relaxed store to a process-global atomic flag. The graceful accept
//! loop ([`super::ShardServer::run_graceful`]) polls the flag between
//! accepts and, once set, flushes a final checkpoint + stats frame
//! before the process exits. The parent sends the signal through
//! [`send_term`], so the whole drill works on a stock container: no
//! external crates, just the three POSIX calls declared here.
//!
//! On non-unix targets everything degrades to a no-op: [`send_term`]
//! reports failure and the caller falls back to a hard kill.

use std::sync::atomic::{AtomicBool, Ordering};

/// POSIX `SIGTERM` — the polite "finish up and exit" signal.
pub const SIGTERM: i32 = 15;

static TERM_REQUESTED: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
extern "C" {
    fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    fn kill(pid: i32, sig: i32) -> i32;
    fn raise(sig: i32) -> i32;
}

#[cfg(unix)]
extern "C" fn on_term(_sig: i32) {
    // async-signal-safe: nothing but an atomic store
    TERM_REQUESTED.store(true, Ordering::Relaxed);
}

/// Install the process-global SIGTERM handler. Call once, early, from
/// the shard-server entry point; later calls are harmless (they
/// re-install the same handler).
pub fn install_term_handler() {
    #[cfg(unix)]
    unsafe {
        signal(SIGTERM, on_term);
    }
}

/// Has a SIGTERM arrived since [`install_term_handler`]? Sticky until
/// [`reset_term`].
pub fn term_requested() -> bool {
    TERM_REQUESTED.load(Ordering::Relaxed)
}

/// Clear the termination flag (tests share one process, so each
/// graceful-shutdown test resets before raising).
pub fn reset_term() {
    TERM_REQUESTED.store(false, Ordering::Relaxed);
}

/// Send SIGTERM to another process. Returns `false` if the signal
/// could not be delivered (dead pid, or a non-unix host) — callers
/// fall back to a hard kill.
pub fn send_term(pid: u32) -> bool {
    #[cfg(unix)]
    {
        unsafe { kill(pid as i32, SIGTERM) == 0 }
    }
    #[cfg(not(unix))]
    {
        let _ = pid;
        false
    }
}

/// Deliver SIGTERM to this process (exercises the installed handler
/// in-process; used by the graceful-shutdown tests).
pub fn raise_term() -> bool {
    #[cfg(unix)]
    {
        unsafe { raise(SIGTERM) == 0 }
    }
    #[cfg(not(unix))]
    {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handler_flips_the_flag_on_raise() {
        install_term_handler();
        reset_term();
        assert!(!term_requested());
        assert!(raise_term(), "raise(SIGTERM) should succeed on unix");
        assert!(term_requested(), "handler must set the flag");
        reset_term();
        assert!(!term_requested());
    }
}
