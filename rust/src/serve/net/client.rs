//! Client side of the shard-serving protocol: one framed, pipelined
//! TCP connection per shard server, plus the [`ShardClient`]-trait
//! adapter that lets a real socket stand where the simulated
//! `LocalShard`/`FabricShard` replicas do.
//!
//! [`NetConn`] owns the socket and everything per-connection: the
//! Hello/HelloAck handshake, request-id allocation, deadline-derived
//! read timeouts, reconnect-with-backoff, and the counters the bench
//! and failure-injection paths read (reconnects, I/O errors, timeouts,
//! frames, bytes, encode/decode nanoseconds). All requests to one
//! server share the connection — that is what turns a whole scheduler
//! batch into a single framed request.

use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::ga::Fabric;
use crate::serve::obs::{self, SpanSet};
use crate::serve::query::{Query, ShardReply};
use crate::serve::store::{ServedSource, Shard};

use super::super::dist::ShardClient;
use super::wire::{self, read_frame, read_frame_timed, ErrorCode, Msg, WireError, VERSION};

/// Read timeout when a request carries no deadline.
const DEFAULT_TIMEOUT: Duration = Duration::from_secs(5);
/// Reconnect backoff: `BACKOFF_BASE << attempt`, capped at
/// [`BACKOFF_CAP`], for [`CONNECT_ATTEMPTS`] attempts.
const BACKOFF_BASE: Duration = Duration::from_millis(10);
const BACKOFF_CAP: Duration = Duration::from_millis(200);
const CONNECT_ATTEMPTS: u32 = 5;

/// One framed connection to one shard server. Cheap to share
/// (`Arc<NetConn>`): the socket is behind a mutex, the counters are
/// atomics.
pub struct NetConn {
    addr: String,
    stream: Mutex<Option<TcpStream>>,
    next_req: AtomicU64,
    had_session: AtomicU64,
    /// first successful connects (0 or 1)
    pub connects: AtomicU64,
    /// successful re-establishments after a drop
    pub reconnects: AtomicU64,
    /// round trips that died on an I/O or protocol error
    pub io_errors: AtomicU64,
    /// round trips that died on the deadline-derived read timeout
    pub timeouts: AtomicU64,
    /// request frames sent (the coalescing assertion counts these)
    pub frames: AtomicU64,
    pub bytes_sent: AtomicU64,
    pub bytes_recv: AtomicU64,
    pub encode_ns: AtomicU64,
    pub decode_ns: AtomicU64,
    /// typed `Stale` refusals from the server (the consistency bound
    /// was not met by its applied epoch)
    pub stale_refusals: AtomicU64,
}

/// Wall-clock stage timing of one traced round trip, measured on the
/// client: encode and decode are direct measurements, `rtt_s` is the
/// residual (write syscall + network + server time + read syscalls),
/// so the three sum to the call's wall time by construction.
#[derive(Clone, Copy, Debug, Default)]
pub struct WireTimes {
    /// request-frame encode time, seconds
    pub encode_s: f64,
    /// reply-frame decode time, seconds
    pub decode_s: f64,
    /// residual wire wait (everything between encode and decode)
    pub rtt_s: f64,
    /// whole round trip (`encode_s + rtt_s + decode_s`)
    pub total_s: f64,
}

impl NetConn {
    pub fn new(addr: String) -> NetConn {
        NetConn {
            addr,
            stream: Mutex::new(None),
            next_req: AtomicU64::new(1),
            had_session: AtomicU64::new(0),
            connects: AtomicU64::new(0),
            reconnects: AtomicU64::new(0),
            io_errors: AtomicU64::new(0),
            timeouts: AtomicU64::new(0),
            frames: AtomicU64::new(0),
            bytes_sent: AtomicU64::new(0),
            bytes_recv: AtomicU64::new(0),
            encode_ns: AtomicU64::new(0),
            decode_ns: AtomicU64::new(0),
            stale_refusals: AtomicU64::new(0),
        }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Connect + handshake with exponential backoff. Called with the
    /// stream lock held (via `ensure`).
    fn dial(&self) -> Result<TcpStream, WireError> {
        let mut last = WireError::Io(std::io::ErrorKind::NotConnected);
        for attempt in 0..CONNECT_ATTEMPTS {
            if attempt > 0 {
                let backoff = BACKOFF_BASE
                    .checked_mul(1 << (attempt - 1))
                    .unwrap_or(BACKOFF_CAP)
                    .min(BACKOFF_CAP);
                std::thread::sleep(backoff);
            }
            let mut stream = match TcpStream::connect(&self.addr) {
                Ok(s) => s,
                Err(e) => {
                    last = WireError::Io(e.kind());
                    continue;
                }
            };
            stream.set_nodelay(true).ok();
            stream.set_read_timeout(Some(DEFAULT_TIMEOUT)).ok();
            match handshake(&mut stream) {
                Ok(()) => {
                    if self.had_session.swap(1, Ordering::SeqCst) == 0 {
                        self.connects.fetch_add(1, Ordering::Relaxed);
                    } else {
                        self.reconnects.fetch_add(1, Ordering::Relaxed);
                    }
                    return Ok(stream);
                }
                // a version mismatch will not heal with backoff
                Err(e @ WireError::PeerVersion { .. }) => return Err(e),
                Err(e) => last = e,
            }
        }
        Err(last)
    }

    /// One framed round trip: encode, send, read the correlated reply.
    /// On any failure the connection is dropped so the next round trip
    /// redials (reconnect-with-backoff); the caller decides whether to
    /// fail over.
    fn round_trip(
        &self,
        msg: &Msg,
        deadline: Option<Duration>,
    ) -> Result<(Msg, WireTimes), WireError> {
        let mut guard = self.stream.lock().expect("conn lock");
        if guard.is_none() {
            *guard = Some(self.dial()?);
        }
        let stream = guard.as_mut().expect("just ensured");
        let timeout = deadline.unwrap_or(DEFAULT_TIMEOUT).max(Duration::from_millis(1));
        stream.set_read_timeout(Some(timeout)).ok();
        let result = (|| {
            let t_start = Instant::now();
            let frame = wire::encode_frame(msg);
            let encode_s = t_start.elapsed().as_secs_f64();
            self.encode_ns.fetch_add((encode_s * 1e9) as u64, Ordering::Relaxed);
            use std::io::Write;
            stream.write_all(&frame).map_err(|e| WireError::Io(e.kind()))?;
            self.frames.fetch_add(1, Ordering::Relaxed);
            self.bytes_sent.fetch_add(frame.len() as u64, Ordering::Relaxed);
            let t1 = Instant::now();
            let (reply, decode_s) = read_frame_timed(stream)?;
            self.decode_ns.fetch_add(t1.elapsed().as_nanos() as u64, Ordering::Relaxed);
            let recv = (wire::HEADER_LEN + frame_payload_hint(&reply)) as u64;
            self.bytes_recv.fetch_add(recv, Ordering::Relaxed);
            let total_s = t_start.elapsed().as_secs_f64();
            let rtt_s = (total_s - encode_s - decode_s).max(0.0);
            Ok((reply, WireTimes { encode_s, decode_s, rtt_s, total_s }))
        })();
        match result {
            Ok((Msg::Error { code, .. }, _)) => {
                // typed remote refusal: the connection itself is fine
                if code == ErrorCode::Stale {
                    self.stale_refusals.fetch_add(1, Ordering::Relaxed);
                }
                Err(WireError::Remote(code))
            }
            Ok(reply) => Ok(reply),
            Err(e) => {
                if wire::is_timeout(&e) {
                    self.timeouts.fetch_add(1, Ordering::Relaxed);
                } else {
                    self.io_errors.fetch_add(1, Ordering::Relaxed);
                }
                *guard = None;
                Err(e)
            }
        }
    }

    /// Execute a coalesced per-shard batch on this server. Returns the
    /// per-entry replies, parallel to `entries`.
    pub fn execute(
        &self,
        entries: Vec<(u32, Vec<Query>)>,
        min_epoch: u64,
        deadline: Option<Duration>,
    ) -> Result<Vec<Vec<ShardReply>>, WireError> {
        Ok(self.execute_traced(entries, min_epoch, 0, deadline)?.0)
    }

    /// [`NetConn::execute`] carrying a trace id, returning the replies
    /// plus the round trip's stage timing and the server-side spans the
    /// `Reply` frame carried back.
    pub fn execute_traced(
        &self,
        entries: Vec<(u32, Vec<Query>)>,
        min_epoch: u64,
        trace_id: u64,
        deadline: Option<Duration>,
    ) -> Result<(Vec<Vec<ShardReply>>, WireTimes, SpanSet), WireError> {
        let req_id = self.next_req.fetch_add(1, Ordering::Relaxed);
        let n = entries.len();
        let (reply, times) =
            self.round_trip(&Msg::Execute { req_id, min_epoch, trace_id, entries }, deadline)?;
        match reply {
            Msg::Reply { req_id: rid, trace_id: tid, server_spans, entries }
                if rid == req_id && tid == trace_id && entries.len() == n =>
            {
                Ok((entries, times, SpanSet::from_entries(&server_spans)))
            }
            _ => {
                self.io_errors.fetch_add(1, Ordering::Relaxed);
                *self.stream.lock().expect("conn lock") = None;
                Err(WireError::Malformed)
            }
        }
    }

    /// Scrape the server's metrics-registry snapshot (`StatsReq`).
    pub fn scrape(&self, deadline: Option<Duration>) -> Result<obs::Snapshot, WireError> {
        let req_id = self.next_req.fetch_add(1, Ordering::Relaxed);
        let (reply, _) = self.round_trip(&Msg::StatsReq { req_id }, deadline)?;
        match reply {
            Msg::StatsReply { req_id: rid, counters, gauges, histograms } if rid == req_id => {
                let mut snap = obs::Snapshot::default();
                snap.counters.extend(counters);
                snap.gauges.extend(gauges);
                snap.histograms.extend(histograms);
                Ok(snap)
            }
            _ => {
                self.io_errors.fetch_add(1, Ordering::Relaxed);
                *self.stream.lock().expect("conn lock") = None;
                Err(WireError::Malformed)
            }
        }
    }

    /// Ship one epoch publish and await its ack.
    pub fn publish(
        &self,
        epoch: u64,
        rows: &[ServedSource],
        deadline: Option<Duration>,
    ) -> Result<(), WireError> {
        let req_id = self.next_req.fetch_add(1, Ordering::Relaxed);
        let msg = Msg::Publish { req_id, epoch, rows: rows.to_vec() };
        match self.round_trip(&msg, deadline)?.0 {
            Msg::PublishAck { req_id: rid, epoch: e } if rid == req_id && e == epoch => Ok(()),
            _ => {
                self.io_errors.fetch_add(1, Ordering::Relaxed);
                *self.stream.lock().expect("conn lock") = None;
                Err(WireError::Malformed)
            }
        }
    }
}

/// Rough payload size of a decoded reply, for the bytes_recv counter
/// (exact sizes would mean re-encoding; the header is exact, the body
/// is the dominant sources term).
fn frame_payload_hint(msg: &Msg) -> usize {
    match msg {
        Msg::Reply { entries, .. } => {
            12 + entries
                .iter()
                .flat_map(|v| v.iter())
                .map(|r| 5 + r.rows() * 81)
                .sum::<usize>()
        }
        Msg::Publish { rows, .. } => 20 + rows.len() * 81,
        Msg::Error { detail, .. } => 13 + detail.len(),
        _ => 16,
    }
}

fn handshake(stream: &mut TcpStream) -> Result<(), WireError> {
    wire::write_frame(stream, &Msg::Hello { version: VERSION })?;
    match read_frame(stream) {
        Ok(Msg::HelloAck { version: v, .. }) if v == VERSION => Ok(()),
        Ok(Msg::HelloAck { version: v, .. }) => {
            Err(WireError::PeerVersion { ours: VERSION, theirs: v })
        }
        Ok(Msg::Error { code: ErrorCode::BadVersion, .. }) => {
            // the server rejected our version without revealing its own
            Err(WireError::PeerVersion { ours: VERSION, theirs: 0 })
        }
        Ok(_) => Err(WireError::Malformed),
        // an old server answers with an old-version header: surface the
        // mismatch as the actionable error, not a generic decode failure
        Err(WireError::Version(v)) => Err(WireError::PeerVersion { ours: VERSION, theirs: v }),
        Err(e) => Err(e),
    }
}

/// [`ShardClient`] over a real socket: one replica slot (a fixed shard
/// on a fixed node) backed by a shared [`NetConn`] to that node's
/// server. The simulated-time parameters are ignored — the returned
/// completion time is `now` plus the measured wall-clock round trip,
/// so the dist router's accounting keeps working with real latencies
/// in place of modeled ones.
pub struct NetShardClient {
    conn: std::sync::Arc<NetConn>,
    node: usize,
    shard: u32,
}

impl NetShardClient {
    pub fn new(conn: std::sync::Arc<NetConn>, node: usize, shard: u32) -> NetShardClient {
        NetShardClient { conn, node, shard }
    }

    pub fn conn(&self) -> &NetConn {
        &self.conn
    }
}

impl ShardClient for NetShardClient {
    fn node(&self) -> usize {
        self.node
    }

    fn call(
        &self,
        now: f64,
        _origin: usize,
        q: &Query,
        shard: &Shard,
        _fabric: &mut Fabric,
        _node_free: &mut [f64],
    ) -> (ShardReply, f64) {
        let t0 = Instant::now();
        match self.conn.execute(vec![(self.shard, vec![q.clone()])], 0, None) {
            Ok(mut entries) if entries.len() == 1 && entries[0].len() == 1 => {
                let reply = entries.pop().expect("checked").pop().expect("checked");
                (reply, now + t0.elapsed().as_secs_f64())
            }
            // the trait has no failure channel: answer from the
            // front-end's own copy of the shard so correctness holds,
            // with the error already counted on the conn
            _ => (
                crate::serve::query::execute_on_shard(shard, q),
                now + t0.elapsed().as_secs_f64(),
            ),
        }
    }
}
