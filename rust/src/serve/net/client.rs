//! Client side of the shard-serving protocol: one framed, pipelined
//! TCP connection per shard server, plus the [`ShardClient`]-trait
//! adapter that lets a real socket stand where the simulated
//! `LocalShard`/`FabricShard` replicas do.
//!
//! [`NetConn`] owns the socket and everything per-connection: the
//! Hello/HelloAck handshake, request-id allocation, deadline-derived
//! read timeouts, reconnect-with-backoff, and the counters the bench
//! and failure-injection paths read (reconnects, I/O errors, timeouts,
//! frames, bytes, encode/decode nanoseconds). All requests to one
//! server share the connection — that is what turns a whole scheduler
//! batch into a single framed request.
//!
//! ## Pipelining
//!
//! A connection admits up to `depth` concurrent requests
//! ([`NetConn::with_pipeline`]; the default depth is 1, which degrades
//! to the classic strict request/reply lockstep). Writers push their
//! frame as soon as a flight slot frees up, then park on a condvar;
//! replies are matched back to their writer by `req_id`, so the server
//! may answer out of order. Exactly one parked waiter at a time holds
//! the read half of the socket (a `try_clone`), reads one frame off
//! the lock, and routes it: its own reply, or another waiter's, or a
//! typed `Error` frame (which only fails the request it names — the
//! connection survives, preserving the refusal semantics of depth 1).
//! Any I/O error, timeout, or protocol violation tears the whole
//! session down: every in-flight request errors out and the next
//! round trip redials.

use std::collections::{HashMap, HashSet};
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::ga::Fabric;
use crate::serve::obs::{self, SpanSet};
use crate::serve::query::{Query, ShardReply};
use crate::serve::store::{ServedSource, Shard};

use super::super::dist::ShardClient;
use super::wire::{self, read_frame, read_frame_timed, ErrorCode, Msg, WireError, VERSION};

/// Read timeout when a request carries no deadline.
const DEFAULT_TIMEOUT: Duration = Duration::from_secs(5);
/// Reconnect backoff: `BACKOFF_BASE << attempt`, capped at
/// [`BACKOFF_CAP`], for [`CONNECT_ATTEMPTS`] attempts.
const BACKOFF_BASE: Duration = Duration::from_millis(10);
const BACKOFF_CAP: Duration = Duration::from_millis(200);
const CONNECT_ATTEMPTS: u32 = 5;

/// Everything guarded by the connection lock. `stream` is the write
/// half; `reader` is a `try_clone` of the same socket, *taken out* of
/// the state by whichever waiter is currently reading (so at most one
/// thread blocks in `read` while the lock stays free for writers).
struct PipeState {
    stream: Option<TcpStream>,
    reader: Option<TcpStream>,
    /// decoded replies parked for their waiter, keyed by req_id,
    /// carrying the reader-measured decode seconds
    ready: HashMap<u64, (Msg, f64)>,
    /// req_ids sent and not yet answered (a reply outside this set is
    /// a protocol violation)
    pending: HashSet<u64>,
    in_flight: usize,
    /// bumped on every teardown; a waiter whose generation is stale
    /// knows its request died with the session
    generation: u64,
    /// why the last teardown happened (what stale waiters report)
    last_error: WireError,
}

/// One framed connection to one shard server. Cheap to share
/// (`Arc<NetConn>`): the socket is behind a mutex, the counters are
/// atomics.
pub struct NetConn {
    addr: String,
    /// max requests in flight on this connection (>= 1)
    depth: usize,
    state: Mutex<PipeState>,
    wakeup: Condvar,
    next_req: AtomicU64,
    had_session: AtomicU64,
    /// first successful connects (0 or 1)
    pub connects: AtomicU64,
    /// successful re-establishments after a drop
    pub reconnects: AtomicU64,
    /// round trips that died on an I/O or protocol error
    pub io_errors: AtomicU64,
    /// round trips that died on the deadline-derived read timeout
    pub timeouts: AtomicU64,
    /// request frames sent (the coalescing assertion counts these)
    pub frames: AtomicU64,
    pub bytes_sent: AtomicU64,
    pub bytes_recv: AtomicU64,
    pub encode_ns: AtomicU64,
    pub decode_ns: AtomicU64,
    /// typed `Stale` refusals from the server (the consistency bound
    /// was not met by its applied epoch)
    pub stale_refusals: AtomicU64,
}

/// Wall-clock stage timing of one traced round trip, measured on the
/// client: encode and decode are direct measurements, `rtt_s` is the
/// residual (write syscall + network + server time + read syscalls —
/// and, pipelined, any wait behind other in-flight replies), so the
/// three sum to the call's wall time by construction.
#[derive(Clone, Copy, Debug, Default)]
pub struct WireTimes {
    /// request-frame encode time, seconds
    pub encode_s: f64,
    /// reply-frame decode time, seconds
    pub decode_s: f64,
    /// residual wire wait (everything between encode and decode)
    pub rtt_s: f64,
    /// whole round trip (`encode_s + rtt_s + decode_s`)
    pub total_s: f64,
}

/// The req_id a reply frame answers, if it is a reply at all.
fn msg_req_id(msg: &Msg) -> Option<u64> {
    match msg {
        Msg::Reply { req_id, .. }
        | Msg::PublishAck { req_id, .. }
        | Msg::StatsReply { req_id, .. }
        | Msg::Error { req_id, .. } => Some(*req_id),
        _ => None,
    }
}

impl NetConn {
    pub fn new(addr: String) -> NetConn {
        NetConn::with_pipeline(addr, 1)
    }

    /// A connection admitting up to `depth` concurrent requests
    /// (clamped to at least 1; 1 = strict request/reply lockstep).
    pub fn with_pipeline(addr: String, depth: usize) -> NetConn {
        NetConn {
            addr,
            depth: depth.max(1),
            state: Mutex::new(PipeState {
                stream: None,
                reader: None,
                ready: HashMap::new(),
                pending: HashSet::new(),
                in_flight: 0,
                generation: 0,
                last_error: WireError::Io(std::io::ErrorKind::NotConnected),
            }),
            wakeup: Condvar::new(),
            next_req: AtomicU64::new(1),
            had_session: AtomicU64::new(0),
            connects: AtomicU64::new(0),
            reconnects: AtomicU64::new(0),
            io_errors: AtomicU64::new(0),
            timeouts: AtomicU64::new(0),
            frames: AtomicU64::new(0),
            bytes_sent: AtomicU64::new(0),
            bytes_recv: AtomicU64::new(0),
            encode_ns: AtomicU64::new(0),
            decode_ns: AtomicU64::new(0),
            stale_refusals: AtomicU64::new(0),
        }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// The configured pipelining depth.
    pub fn pipeline_depth(&self) -> usize {
        self.depth
    }

    /// Connect + handshake with exponential backoff. Called with the
    /// state lock held (no reader can be active without a stream).
    fn dial(&self) -> Result<TcpStream, WireError> {
        let mut last = WireError::Io(std::io::ErrorKind::NotConnected);
        for attempt in 0..CONNECT_ATTEMPTS {
            if attempt > 0 {
                let backoff = BACKOFF_BASE
                    .checked_mul(1 << (attempt - 1))
                    .unwrap_or(BACKOFF_CAP)
                    .min(BACKOFF_CAP);
                std::thread::sleep(backoff);
            }
            let mut stream = match TcpStream::connect(&self.addr) {
                Ok(s) => s,
                Err(e) => {
                    last = WireError::Io(e.kind());
                    continue;
                }
            };
            stream.set_nodelay(true).ok();
            stream.set_read_timeout(Some(DEFAULT_TIMEOUT)).ok();
            match handshake(&mut stream) {
                Ok(()) => {
                    if self.had_session.swap(1, Ordering::SeqCst) == 0 {
                        self.connects.fetch_add(1, Ordering::Relaxed);
                    } else {
                        self.reconnects.fetch_add(1, Ordering::Relaxed);
                    }
                    return Ok(stream);
                }
                // a version mismatch will not heal with backoff
                Err(e @ WireError::PeerVersion { .. }) => return Err(e),
                Err(e) => last = e,
            }
        }
        Err(last)
    }

    /// Count a failed round trip on the right counter (typed remote
    /// refusals are not connection failures and are not counted here).
    fn count_err(&self, e: &WireError) {
        match e {
            WireError::Remote(_) => {}
            e if wire::is_timeout(e) => {
                self.timeouts.fetch_add(1, Ordering::Relaxed);
            }
            _ => {
                self.io_errors.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Tear the session down: every in-flight request errors out with
    /// `err`, the next round trip redials. Shutting the socket down
    /// (not just dropping our handle) also wakes a reader blocked on
    /// the cloned read half.
    fn fail_conn(&self, st: &mut PipeState, err: WireError) {
        if let Some(s) = st.stream.take() {
            s.shutdown(Shutdown::Both).ok();
        }
        st.reader = None;
        st.ready.clear();
        st.pending.clear();
        st.in_flight = 0;
        st.generation += 1;
        st.last_error = err;
        self.wakeup.notify_all();
    }

    /// Kill the connection from outside the round-trip path (a caller
    /// saw a structurally wrong reply).
    fn drop_conn(&self) {
        let mut st = self.state.lock().expect("conn lock");
        self.fail_conn(&mut st, WireError::Malformed);
    }

    /// Read one frame off the lock and route it. Takes the guard,
    /// returns it re-acquired. `gen` is the session generation the
    /// caller observed; if it moved while we were reading, the frame
    /// (or error) belongs to a dead session and is discarded.
    fn read_one<'a>(
        &self,
        st: MutexGuard<'a, PipeState>,
        mut reader: TcpStream,
        gen: u64,
        budget: Duration,
    ) -> MutexGuard<'a, PipeState> {
        drop(st);
        reader.set_read_timeout(Some(budget.max(Duration::from_millis(1)))).ok();
        let t_read = Instant::now();
        let result = read_frame_timed(&mut reader);
        let mut st = self.state.lock().expect("conn lock");
        if st.generation != gen {
            return st;
        }
        match result {
            Ok((reply, decode_s)) => {
                self.decode_ns.fetch_add(t_read.elapsed().as_nanos() as u64, Ordering::Relaxed);
                let recv = (wire::HEADER_LEN + frame_payload_hint(&reply)) as u64;
                self.bytes_recv.fetch_add(recv, Ordering::Relaxed);
                match msg_req_id(&reply) {
                    Some(rid) if st.pending.contains(&rid) => {
                        st.reader = Some(reader);
                        st.ready.insert(rid, (reply, decode_s));
                        self.wakeup.notify_all();
                    }
                    // a reply nobody asked for: the stream is
                    // desynchronized. A typed Error that names no live
                    // request still reports its code to the waiters.
                    _ => {
                        let err = match &reply {
                            Msg::Error { code, .. } => WireError::Remote(*code),
                            _ => WireError::Malformed,
                        };
                        self.fail_conn(&mut st, err);
                    }
                }
            }
            Err(e) => self.fail_conn(&mut st, e),
        }
        st
    }

    /// One framed round trip: send the frame as soon as a flight slot
    /// is free, then wait for the reply correlated by `req_id` (which
    /// must be the id inside `msg`). On any session failure the
    /// connection is dropped so the next round trip redials; a typed
    /// `Error` reply fails only this request.
    fn round_trip(
        &self,
        req_id: u64,
        msg: &Msg,
        deadline: Option<Duration>,
    ) -> Result<(Msg, WireTimes), WireError> {
        let timeout = deadline.unwrap_or(DEFAULT_TIMEOUT).max(Duration::from_millis(1));
        let expires = Instant::now() + timeout;
        let t_start = Instant::now();
        let frame = wire::encode_frame(msg);
        let encode_s = t_start.elapsed().as_secs_f64();
        self.encode_ns.fetch_add((encode_s * 1e9) as u64, Ordering::Relaxed);

        let mut st = self.state.lock().expect("conn lock");
        // admission: at most `depth` requests in flight per connection
        while st.stream.is_some() && st.in_flight >= self.depth {
            let left = expires.saturating_duration_since(Instant::now());
            if left.is_zero() {
                let e = WireError::Io(std::io::ErrorKind::TimedOut);
                self.count_err(&e);
                self.fail_conn(&mut st, e.clone());
                return Err(e);
            }
            st = self.wakeup.wait_timeout(st, left).expect("conn lock").0;
        }
        if st.stream.is_none() {
            let s = match self.dial() {
                Ok(s) => s,
                Err(e) => {
                    self.count_err(&e);
                    return Err(e);
                }
            };
            let r = match s.try_clone() {
                Ok(r) => r,
                Err(e) => {
                    let e = WireError::Io(e.kind());
                    self.count_err(&e);
                    return Err(e);
                }
            };
            st.stream = Some(s);
            st.reader = Some(r);
            st.ready.clear();
            st.pending.clear();
            st.in_flight = 0;
        }
        let gen = st.generation;
        {
            use std::io::Write;
            let stream = st.stream.as_mut().expect("just ensured");
            if let Err(e) = stream.write_all(&frame) {
                let e = WireError::Io(e.kind());
                self.count_err(&e);
                self.fail_conn(&mut st, e.clone());
                return Err(e);
            }
        }
        self.frames.fetch_add(1, Ordering::Relaxed);
        self.bytes_sent.fetch_add(frame.len() as u64, Ordering::Relaxed);
        st.in_flight += 1;
        st.pending.insert(req_id);

        loop {
            if let Some((reply, decode_s)) = st.ready.remove(&req_id) {
                st.pending.remove(&req_id);
                st.in_flight -= 1;
                self.wakeup.notify_all();
                drop(st);
                if let Msg::Error { code, .. } = &reply {
                    // typed remote refusal: the connection itself is
                    // fine, only this request is refused
                    if *code == ErrorCode::Stale {
                        self.stale_refusals.fetch_add(1, Ordering::Relaxed);
                    }
                    return Err(WireError::Remote(*code));
                }
                let total_s = t_start.elapsed().as_secs_f64();
                let rtt_s = (total_s - encode_s - decode_s).max(0.0);
                return Ok((reply, WireTimes { encode_s, decode_s, rtt_s, total_s }));
            }
            if st.generation != gen {
                // the session died under us (reader error or a peer's
                // expired deadline): our request went with it
                let e = st.last_error.clone();
                self.count_err(&e);
                return Err(e);
            }
            let left = expires.saturating_duration_since(Instant::now());
            if left.is_zero() {
                let e = WireError::Io(std::io::ErrorKind::TimedOut);
                self.count_err(&e);
                self.fail_conn(&mut st, e.clone());
                return Err(e);
            }
            if let Some(reader) = st.reader.take() {
                st = self.read_one(st, reader, gen, left);
            } else {
                st = self.wakeup.wait_timeout(st, left).expect("conn lock").0;
            }
        }
    }

    /// Execute a coalesced per-shard batch on this server. Returns the
    /// per-entry replies, parallel to `entries`.
    pub fn execute(
        &self,
        entries: Vec<(u32, Vec<Query>)>,
        min_epoch: u64,
        deadline: Option<Duration>,
    ) -> Result<Vec<Vec<ShardReply>>, WireError> {
        Ok(self.execute_traced(entries, min_epoch, 0, deadline)?.0)
    }

    /// [`NetConn::execute`] carrying a trace id, returning the replies
    /// plus the round trip's stage timing and the server-side spans the
    /// `Reply` frame carried back.
    pub fn execute_traced(
        &self,
        entries: Vec<(u32, Vec<Query>)>,
        min_epoch: u64,
        trace_id: u64,
        deadline: Option<Duration>,
    ) -> Result<(Vec<Vec<ShardReply>>, WireTimes, SpanSet), WireError> {
        let req_id = self.next_req.fetch_add(1, Ordering::Relaxed);
        let n = entries.len();
        let (reply, times) = self.round_trip(
            req_id,
            &Msg::Execute { req_id, min_epoch, trace_id, entries },
            deadline,
        )?;
        match reply {
            Msg::Reply { req_id: rid, trace_id: tid, server_spans, entries }
                if rid == req_id && tid == trace_id && entries.len() == n =>
            {
                Ok((entries, times, SpanSet::from_entries(&server_spans)))
            }
            _ => {
                self.io_errors.fetch_add(1, Ordering::Relaxed);
                self.drop_conn();
                Err(WireError::Malformed)
            }
        }
    }

    /// Scrape the server's metrics-registry snapshot (`StatsReq`).
    pub fn scrape(&self, deadline: Option<Duration>) -> Result<obs::Snapshot, WireError> {
        let req_id = self.next_req.fetch_add(1, Ordering::Relaxed);
        let (reply, _) = self.round_trip(req_id, &Msg::StatsReq { req_id }, deadline)?;
        match reply {
            Msg::StatsReply { req_id: rid, counters, gauges, histograms } if rid == req_id => {
                let mut snap = obs::Snapshot::default();
                snap.counters.extend(counters);
                snap.gauges.extend(gauges);
                snap.histograms.extend(histograms);
                Ok(snap)
            }
            _ => {
                self.io_errors.fetch_add(1, Ordering::Relaxed);
                self.drop_conn();
                Err(WireError::Malformed)
            }
        }
    }

    /// Fire-and-forget cancellation (wire v3): tell the server to drop
    /// any not-yet-executed work of `trace_id` before a shard runs it.
    /// No reply is expected and no flight slot is consumed, so a
    /// hedge's winner path can cancel the loser without waiting behind
    /// it. Best-effort by design: a dead session is redialed once so a
    /// cancel racing ahead of its execute still lands, but a server
    /// that stays unreachable just misses the hint — the cancelled
    /// request's own round trip will fail on its usual path.
    pub fn cancel(&self, trace_id: u64) {
        if trace_id == 0 {
            return;
        }
        let frame = wire::encode_frame(&Msg::Cancel { trace_id });
        let mut st = self.state.lock().expect("conn lock");
        if st.stream.is_none() {
            let Ok(s) = self.dial() else { return };
            let Ok(r) = s.try_clone() else { return };
            st.stream = Some(s);
            st.reader = Some(r);
            st.ready.clear();
            st.pending.clear();
            st.in_flight = 0;
        }
        use std::io::Write;
        let stream = st.stream.as_mut().expect("just ensured");
        match stream.write_all(&frame) {
            Ok(()) => {
                // not counted in `frames`: that counter is the
                // coalescing contract's request-frame observable
                self.bytes_sent.fetch_add(frame.len() as u64, Ordering::Relaxed);
            }
            Err(e) => {
                let e = WireError::Io(e.kind());
                self.count_err(&e);
                self.fail_conn(&mut st, e);
            }
        }
    }

    /// Ship one epoch publish and await its ack. With a durable
    /// server, the ack means the epoch is fsynced in that server's WAL.
    pub fn publish(
        &self,
        epoch: u64,
        rows: &[ServedSource],
        deadline: Option<Duration>,
    ) -> Result<(), WireError> {
        let req_id = self.next_req.fetch_add(1, Ordering::Relaxed);
        let msg = Msg::Publish { req_id, epoch, rows: rows.to_vec() };
        match self.round_trip(req_id, &msg, deadline)?.0 {
            Msg::PublishAck { req_id: rid, epoch: e } if rid == req_id && e == epoch => Ok(()),
            _ => {
                self.io_errors.fetch_add(1, Ordering::Relaxed);
                self.drop_conn();
                Err(WireError::Malformed)
            }
        }
    }
}

/// Rough payload size of a decoded reply, for the bytes_recv counter
/// (exact sizes would mean re-encoding; the header is exact, the body
/// is the dominant sources term).
fn frame_payload_hint(msg: &Msg) -> usize {
    match msg {
        Msg::Reply { entries, .. } => {
            12 + entries
                .iter()
                .flat_map(|v| v.iter())
                .map(|r| 5 + r.rows() * 81)
                .sum::<usize>()
        }
        Msg::Publish { rows, .. } => 20 + rows.len() * 81,
        Msg::Error { detail, .. } => 13 + detail.len(),
        _ => 16,
    }
}

fn handshake(stream: &mut TcpStream) -> Result<(), WireError> {
    wire::write_frame(stream, &Msg::Hello { version: VERSION })?;
    match read_frame(stream) {
        Ok(Msg::HelloAck { version: v, .. }) if v == VERSION => Ok(()),
        Ok(Msg::HelloAck { version: v, .. }) => {
            Err(WireError::PeerVersion { ours: VERSION, theirs: v })
        }
        Ok(Msg::Error { code: ErrorCode::BadVersion, .. }) => {
            // the server rejected our version without revealing its own
            Err(WireError::PeerVersion { ours: VERSION, theirs: 0 })
        }
        Ok(_) => Err(WireError::Malformed),
        // an old server answers with an old-version header: surface the
        // mismatch as the actionable error, not a generic decode failure
        Err(WireError::Version(v)) => Err(WireError::PeerVersion { ours: VERSION, theirs: v }),
        Err(e) => Err(e),
    }
}

/// [`ShardClient`] over a real socket: one replica slot (a fixed shard
/// on a fixed node) backed by a shared [`NetConn`] to that node's
/// server. The simulated-time parameters are ignored — the returned
/// completion time is `now` plus the measured wall-clock round trip,
/// so the dist router's accounting keeps working with real latencies
/// in place of modeled ones.
pub struct NetShardClient {
    conn: std::sync::Arc<NetConn>,
    node: usize,
    shard: u32,
}

impl NetShardClient {
    pub fn new(conn: std::sync::Arc<NetConn>, node: usize, shard: u32) -> NetShardClient {
        NetShardClient { conn, node, shard }
    }

    pub fn conn(&self) -> &NetConn {
        &self.conn
    }
}

impl ShardClient for NetShardClient {
    fn node(&self) -> usize {
        self.node
    }

    fn call(
        &self,
        now: f64,
        _origin: usize,
        q: &Query,
        shard: &Shard,
        _fabric: &mut Fabric,
        _node_free: &mut [f64],
    ) -> (ShardReply, f64) {
        let t0 = Instant::now();
        match self.conn.execute(vec![(self.shard, vec![q.clone()])], 0, None) {
            Ok(mut entries) if entries.len() == 1 && entries[0].len() == 1 => {
                let reply = entries.pop().expect("checked").pop().expect("checked");
                (reply, now + t0.elapsed().as_secs_f64())
            }
            // the trait has no failure channel: answer from the
            // front-end's own copy of the shard so correctness holds,
            // with the error already counted on the conn
            _ => (
                crate::serve::query::execute_on_shard(shard, q),
                now + t0.elapsed().as_secs_f64(),
            ),
        }
    }
}
