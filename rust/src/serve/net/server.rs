//! `ShardServer`: one process (or thread) owning a versioned catalog
//! partition and answering shard sub-queries over TCP.
//!
//! Each server loads the same snapshot the front-end planned over and
//! builds an identical [`Store`], so shard indices agree across the
//! process boundary by construction. Epoch publishes arrive as
//! [`Msg::Publish`] frames carrying the deduped delta rows of exactly
//! the next epoch; the server applies them through its own
//! [`Ingestor`], whose rebuild is deterministic — every replica (and
//! the front-end mirror) converges on byte-identical shards, which is
//! what lets `Fresh`/`AtMost(k)` consistency and byte-parity hold
//! cross-process.
//!
//! A connection is a strict in-order frame pipe: the client's publishes
//! and queries are processed in arrival order, so a query sent after a
//! publish ack can never observe the older epoch. Decode failures are
//! answered with a typed [`Msg::Error`] and a close — a hostile peer
//! can end its own connection, never the server.
//!
//! Cancellation (wire v3): a fire-and-forget [`Msg::Cancel`] marks a
//! trace id in a set shared across every connection; the next
//! `Execute` carrying that id is answered with empty replies and
//! *zero* shard work, counted in the `hedge_cancels` counter. The
//! in-order pipe makes the race well-defined per connection: a cancel
//! written before the loser's execute always lands first.

use std::collections::HashSet;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::serve::durable::DurableLog;
use crate::serve::ingest::{Ingestor, VersionedStore};
use crate::serve::obs::{self, Registry, SpanSet, Stage};
use crate::serve::query::{execute_on_shard, ShardReply};
use crate::serve::store::Store;

use super::wire::{read_frame, read_frame_timed, write_frame, ErrorCode, Msg, WireError, VERSION};

/// Idle-connection read timeout: a peer that goes silent this long is
/// dropped so its handler thread can exit.
const IDLE_TIMEOUT: Duration = Duration::from_secs(120);

/// Bound on the cancelled-trace set: cancels that never meet their
/// execute (the common race resolution — the work already finished)
/// must not accumulate forever, so the set is cleared when it grows
/// past this many stale ids.
const CANCEL_SET_CAP: usize = 1024;

pub struct ShardServer {
    listener: TcpListener,
    versioned: Arc<VersionedStore>,
    ingest: Arc<Mutex<Ingestor>>,
    registry: Arc<Registry>,
    /// trace ids cancelled by `Msg::Cancel`, shared across connections
    /// (a hedge's cancel and its execute may ride different sockets)
    cancelled: Arc<Mutex<HashSet<u64>>>,
    /// attached durable log, if this server fsyncs publishes; its own
    /// registry (wal_appends, fsync latency, recovery gauges) is merged
    /// into every `StatsReq` scrape
    log: Option<Arc<DurableLog>>,
    stop: Arc<AtomicBool>,
}

/// Test/bench handle for an in-process server: lets the owner stop the
/// accept loop and join it.
pub struct ShardServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

/// What a gracefully terminated server flushed on the way out: the
/// shard-server entry point prints these fields as its terminal
/// status line so the parent (and CI) can verify the flush happened.
#[derive(Clone, Debug)]
pub struct TermReport {
    /// applied epoch at shutdown — the freshness the flushed
    /// checkpoint pins
    pub epoch: u64,
    /// total wire frames this server processed
    pub frames: u64,
    /// queries refused for staleness over the server's lifetime
    pub stale_refusals: u64,
    /// whether an attached durable log took a final fsynced checkpoint
    pub wal_synced: bool,
}

impl ShardServer {
    /// Bind a listener and wrap `store` in a fresh epoch-0
    /// [`VersionedStore`]. `addr` is usually `127.0.0.1:0` (kernel
    /// picks the port; read it back with [`local_addr`]).
    ///
    /// [`local_addr`]: ShardServer::local_addr
    pub fn bind(store: Arc<Store>, addr: &str) -> std::io::Result<ShardServer> {
        ShardServer::bind_durable(Arc::new(VersionedStore::new(store)), None, addr)
    }

    /// Bind over an existing versioned head (crash recovery hands the
    /// recovered store in here) with an optional durable log. When the
    /// log is attached to `versioned`, every `Publish` is appended and
    /// fsynced *before* its ack leaves this process — an acked epoch
    /// survives kill -9.
    pub fn bind_durable(
        versioned: Arc<VersionedStore>,
        log: Option<Arc<DurableLog>>,
        addr: &str,
    ) -> std::io::Result<ShardServer> {
        let listener = TcpListener::bind(addr)?;
        let ingest = Arc::new(Mutex::new(Ingestor::new(Arc::clone(&versioned))));
        Ok(ShardServer {
            listener,
            versioned,
            ingest,
            registry: Arc::new(Registry::new()),
            cancelled: Arc::new(Mutex::new(HashSet::new())),
            log,
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("bound listener has an address")
    }

    /// The server's metrics registry: per-stage `stage_*` histograms,
    /// frame/refusal counters, the applied-epoch gauge. Scraped over
    /// the wire via `StatsReq`.
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// Accept loop; runs until the process exits (the child-process
    /// entry point) or [`ShardServerHandle::stop`] fires.
    pub fn run(self) {
        self.run_graceful(|| false);
    }

    /// Accept loop with graceful termination: `term` is polled between
    /// accepts (e.g. [`signal::term_requested`] wired to SIGTERM), and
    /// when it fires the server flushes before returning — a final
    /// fsynced checkpoint of the applied head when a durable log is
    /// attached — and hands back a [`TermReport`] for the terminal
    /// status line. Returns `None` when stopped through the handle
    /// instead (tests/benches, no flush semantics implied).
    ///
    /// The listener runs non-blocking with a short poll sleep so a
    /// SIGTERM lands within milliseconds even on an idle server;
    /// accepted connections are switched back to blocking before
    /// their handler threads take over.
    ///
    /// [`signal::term_requested`]: super::signal::term_requested
    pub fn run_graceful(self, term: impl Fn() -> bool) -> Option<TermReport> {
        self.listener.set_nonblocking(true).expect("listener supports non-blocking accept");
        loop {
            if self.stop.load(Ordering::SeqCst) {
                return None;
            }
            if term() {
                return Some(self.flush_for_exit());
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    // the listener's non-blocking flag is inherited by
                    // accepted sockets on some platforms: undo it so
                    // the frame reader blocks normally
                    stream.set_nonblocking(false).ok();
                    let versioned = Arc::clone(&self.versioned);
                    let ingest = Arc::clone(&self.ingest);
                    let registry = Arc::clone(&self.registry);
                    let cancelled = Arc::clone(&self.cancelled);
                    let log = self.log.clone();
                    std::thread::spawn(move || {
                        // per-connection failures only ever end that
                        // connection
                        let _ = serve_conn(
                            stream,
                            &versioned,
                            &ingest,
                            &registry,
                            &cancelled,
                            log.as_ref(),
                        );
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(_) => continue,
            }
        }
    }

    /// The graceful-exit flush: checkpoint the applied head through the
    /// attached durable log (fsynced — an acked epoch survives even a
    /// kill that races the WAL tail) and snapshot the lifetime stats
    /// for the terminal report.
    fn flush_for_exit(&self) -> TermReport {
        let head = self.versioned.load();
        let wal_synced = match &self.log {
            Some(l) => l.checkpoint_now(&head).is_ok(),
            None => false,
        };
        let snap = self.registry.snapshot();
        TermReport {
            epoch: head.epoch,
            frames: snap.counters.get("net_frames").copied().unwrap_or(0),
            stale_refusals: snap.counters.get("stale_refusals").copied().unwrap_or(0),
            wal_synced,
        }
    }

    /// Run the accept loop on a background thread (tests, benches).
    pub fn spawn(self) -> ShardServerHandle {
        let addr = self.local_addr();
        let stop = Arc::clone(&self.stop);
        let join = std::thread::spawn(move || self.run());
        ShardServerHandle { addr, stop, join: Some(join) }
    }
}

impl ShardServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the accept loop and join it. Already-open connections keep
    /// draining on their own threads until their peers hang up.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // unblock the blocking accept with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

impl Drop for ShardServerHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

fn send_error(stream: &mut TcpStream, req_id: u64, code: ErrorCode, detail: String) {
    let _ = write_frame(stream, &Msg::Error { req_id, code, detail });
}

/// Drive one connection to completion. Returns `Ok(())` on a clean
/// peer close; any other exit closed the connection deliberately.
fn serve_conn(
    mut stream: TcpStream,
    versioned: &Arc<VersionedStore>,
    ingest: &Arc<Mutex<Ingestor>>,
    registry: &Arc<Registry>,
    cancelled: &Arc<Mutex<HashSet<u64>>>,
    log: Option<&Arc<DurableLog>>,
) -> Result<(), WireError> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(IDLE_TIMEOUT)).ok();

    // version negotiation: first frame must be a Hello we can speak
    match read_frame(&mut stream) {
        Ok(Msg::Hello { version }) if version == VERSION => {
            let head = versioned.load();
            write_frame(
                &mut stream,
                &Msg::HelloAck {
                    version: VERSION,
                    epoch: head.epoch,
                    n_shards: head.store.shards.len() as u32,
                },
            )?;
        }
        Ok(Msg::Hello { version }) => {
            send_error(
                &mut stream,
                0,
                ErrorCode::BadVersion,
                format!("server speaks version {VERSION}, client sent {version}"),
            );
            return Err(WireError::Version(version));
        }
        Ok(_) => {
            send_error(&mut stream, 0, ErrorCode::Malformed, "expected Hello".to_string());
            return Err(WireError::Malformed);
        }
        Err(e) => {
            // a frame-level decode error still gets a typed answer if
            // the socket survives (e.g. bad magic on a live peer)
            if !matches!(e, WireError::Closed | WireError::Truncated | WireError::Io(_)) {
                let code = match e {
                    WireError::Version(_) => ErrorCode::BadVersion,
                    _ => ErrorCode::Malformed,
                };
                send_error(&mut stream, 0, code, e.to_string());
            }
            return Err(e);
        }
    }

    let frames = registry.counter("net_frames");
    let stale = registry.counter("stale_refusals");
    let cancels = registry.counter("hedge_cancels");
    let h_decode = registry.histogram("stage_decode");
    let h_execute = registry.histogram("stage_shard_execute");
    let h_encode = registry.histogram("stage_encode");

    loop {
        let (msg, decode_s) = match read_frame_timed(&mut stream) {
            Ok(m) => m,
            Err(WireError::Closed) => return Ok(()),
            Err(e @ (WireError::Truncated | WireError::Io(_))) => return Err(e),
            Err(e) => {
                send_error(&mut stream, 0, ErrorCode::Malformed, e.to_string());
                return Err(e);
            }
        };
        frames.inc();
        match msg {
            Msg::Execute { req_id, min_epoch, trace_id, entries } => {
                h_decode.record(decode_s);
                // a cancelled trace is dropped before any shard runs:
                // the reply mirrors the request's shape (correlation is
                // undisturbed) but carries empty replies and consumed
                // zero execution work. One-shot: the id is removed, so
                // a later request reusing it executes normally.
                let drop_work = trace_id != 0
                    && cancelled.lock().expect("cancel set").remove(&trace_id);
                if drop_work {
                    cancels.inc();
                    let out: Vec<Vec<ShardReply>> = entries
                        .iter()
                        .map(|(_, qs)| {
                            qs.iter().map(|_| ShardReply::Sources(Vec::new())).collect()
                        })
                        .collect();
                    let mut spans = SpanSet::new();
                    spans.add(Stage::Decode, decode_s);
                    write_frame(
                        &mut stream,
                        &Msg::Reply {
                            req_id,
                            trace_id,
                            server_spans: spans.entries(),
                            entries: out,
                        },
                    )?;
                    continue;
                }
                let head = versioned.load();
                registry.gauge_set("applied_epoch", head.epoch as f64);
                if head.epoch < min_epoch {
                    stale.inc();
                    send_error(
                        &mut stream,
                        req_id,
                        ErrorCode::Stale,
                        format!("applied epoch {} < bound {min_epoch}", head.epoch),
                    );
                    continue;
                }
                let n_shards = head.store.shards.len();
                let t_exec = Instant::now();
                let mut out = Vec::with_capacity(entries.len());
                let mut bad_shard = None;
                for (shard, queries) in &entries {
                    let Some(shard_ref) = head.store.shards.get(*shard as usize) else {
                        bad_shard = Some(*shard);
                        break;
                    };
                    out.push(
                        queries.iter().map(|q| execute_on_shard(shard_ref, q)).collect::<Vec<_>>(),
                    );
                }
                let execute_s = t_exec.elapsed().as_secs_f64();
                match bad_shard {
                    Some(shard) => send_error(
                        &mut stream,
                        req_id,
                        ErrorCode::Malformed,
                        format!("shard {shard} out of range ({n_shards} shards)"),
                    ),
                    None => {
                        h_execute.record(execute_s);
                        // the server-side breakdown of this request:
                        // request decode + shard execute (the reply's
                        // own encode cannot time itself; it lands in
                        // the stage_encode histogram one reply late)
                        let mut spans = SpanSet::new();
                        spans.add(Stage::Decode, decode_s);
                        spans.add(Stage::ShardExecute, execute_s);
                        let t_enc = Instant::now();
                        write_frame(
                            &mut stream,
                            &Msg::Reply {
                                req_id,
                                trace_id,
                                server_spans: spans.entries(),
                                entries: out,
                            },
                        )?;
                        h_encode.record(t_enc.elapsed().as_secs_f64());
                    }
                }
            }
            Msg::StatsReq { req_id } => {
                // a durable server's scrape carries its WAL accounting
                // (wal_appends, wal_fsync_s, recovery gauges) merged in
                let snap = match log {
                    Some(l) => {
                        obs::Snapshot::merge_all([&registry.snapshot(), &l.obs().snapshot()])
                    }
                    None => registry.snapshot(),
                };
                write_frame(
                    &mut stream,
                    &Msg::StatsReply {
                        req_id,
                        counters: snap.counters.into_iter().collect(),
                        gauges: snap.gauges.into_iter().collect(),
                        histograms: snap.histograms.into_iter().collect(),
                    },
                )?;
            }
            Msg::Publish { req_id, epoch, rows } => {
                // the ingest lock spans the epoch check so two racing
                // publishes cannot both see "current + 1"
                let mut ing = ingest.lock().expect("ingest lock");
                let cur = versioned.epoch();
                if epoch <= cur {
                    // duplicate delivery (e.g. after a reconnect): the
                    // epoch is already applied, ack idempotently
                    drop(ing);
                    write_frame(&mut stream, &Msg::PublishAck { req_id, epoch })?;
                } else if epoch == cur + 1 {
                    let rep = ing.apply(&rows);
                    debug_assert_eq!(rep.epoch, epoch);
                    drop(ing);
                    write_frame(&mut stream, &Msg::PublishAck { req_id, epoch })?;
                } else {
                    drop(ing);
                    send_error(
                        &mut stream,
                        req_id,
                        ErrorCode::EpochGap,
                        format!("publish skips from epoch {cur} to {epoch}"),
                    );
                }
            }
            Msg::Cancel { trace_id } => {
                // fire-and-forget: mark the trace so its next Execute
                // is dropped before any shard work. The set is bounded
                // — ids whose work already finished never get matched,
                // so past the cap the stale ones are discarded.
                if trace_id != 0 {
                    let mut c = cancelled.lock().expect("cancel set");
                    if c.len() >= CANCEL_SET_CAP {
                        c.clear();
                    }
                    c.insert(trace_id);
                }
            }
            Msg::Hello { .. } => {
                send_error(&mut stream, 0, ErrorCode::Malformed, "duplicate Hello".to_string());
                return Err(WireError::Malformed);
            }
            _ => {
                send_error(
                    &mut stream,
                    0,
                    ErrorCode::Malformed,
                    "unexpected client frame (server-only message)".to_string(),
                );
                return Err(WireError::Malformed);
            }
        }
    }
}
