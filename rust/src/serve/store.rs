//! The immutable catalog store: Hilbert-range shards with per-shard grid
//! indexes.
//!
//! The coordinator orders sources along a Hilbert curve for inference
//! locality (paper §III-D); the store reuses the *same* key to cut the
//! catalog into `n_shards` contiguous, equal-count key ranges. Spatially
//! compact shards mean (a) most queries touch one or two shards, and
//! (b) a future distributed deployment can place shards on different
//! hosts without re-keying anything.

use std::sync::Arc;

use crate::catalog::{hilbert_sky_key, CatalogEntry};
use crate::coordinator::InferredSource;
use crate::model::layout as L;

/// One catalog row as served: posterior point estimate + the
/// uncertainties that distinguish Celeste output from heuristic
/// catalogs. `PartialEq` is exact (bitwise on floats): query results are
/// required to be byte-identical to a brute-force scan.
#[derive(Clone, Debug, PartialEq)]
pub struct ServedSource {
    pub id: usize,
    /// absolute sky position, pixels
    pub pos: (f64, f64),
    /// probability the source is a galaxy
    pub p_gal: f64,
    /// posterior mean reference-band flux
    pub flux_r: f64,
    /// posterior SD of log flux (drives uncertainty-aware cross-match)
    pub flux_logsd: f64,
    pub colors: [f64; L::N_COLORS],
    pub converged: bool,
}

impl ServedSource {
    pub fn is_galaxy(&self) -> bool {
        self.p_gal > 0.5
    }

    pub fn from_inferred(s: &InferredSource) -> ServedSource {
        ServedSource {
            id: s.id,
            pos: s.pos,
            p_gal: s.est.p_gal,
            flux_r: s.est.flux_r,
            flux_logsd: s.flux_logsd,
            colors: s.est.colors,
            converged: s.converged,
        }
    }

    /// Build from a plain catalog entry (synthetic benches / photo
    /// baseline ingestion, where no posterior SD exists).
    pub fn from_entry(e: &CatalogEntry, flux_logsd: f64) -> ServedSource {
        ServedSource {
            id: e.id,
            pos: e.pos,
            p_gal: e.p_gal,
            flux_r: e.flux_r,
            flux_logsd,
            colors: e.colors,
            converged: true,
        }
    }
}

/// Uniform-cell grid index over a shard's bounding box.
#[derive(Clone, Debug)]
struct ShardGrid {
    x0: f64,
    y0: f64,
    cell: f64,
    nx: usize,
    ny: usize,
    /// indices into the shard's `sources`
    cells: Vec<Vec<usize>>,
}

impl ShardGrid {
    fn build(sources: &[ServedSource], bbox: (f64, f64, f64, f64)) -> ShardGrid {
        let (x0, y0, x1, y1) = bbox;
        let w = (x1 - x0).max(1e-9);
        let h = (y1 - y0).max(1e-9);
        // target a handful of sources per cell
        let cell = ((w * h / sources.len().max(1) as f64).sqrt() * 2.0).clamp(8.0, 512.0);
        let nx = (w / cell).ceil().max(1.0) as usize;
        let ny = (h / cell).ceil().max(1.0) as usize;
        let mut cells = vec![Vec::new(); nx * ny];
        for (i, s) in sources.iter().enumerate() {
            let cx = (((s.pos.0 - x0) / cell) as usize).min(nx - 1);
            let cy = (((s.pos.1 - y0) / cell) as usize).min(ny - 1);
            cells[cy * nx + cx].push(i);
        }
        ShardGrid { x0, y0, cell, nx, ny, cells }
    }

    /// Visit every source index whose cell intersects the axis-aligned
    /// box `(bx0, by0, bx1, by1)`.
    fn visit_box(&self, bx0: f64, by0: f64, bx1: f64, by1: f64, mut f: impl FnMut(usize)) {
        let cx0 = (((bx0 - self.x0) / self.cell).floor().max(0.0) as usize).min(self.nx - 1);
        let cy0 = (((by0 - self.y0) / self.cell).floor().max(0.0) as usize).min(self.ny - 1);
        let cx1 = (((bx1 - self.x0) / self.cell).floor().max(0.0) as usize).min(self.nx - 1);
        let cy1 = (((by1 - self.y0) / self.cell).floor().max(0.0) as usize).min(self.ny - 1);
        for cy in cy0..=cy1 {
            for cx in cx0..=cx1 {
                for &i in &self.cells[cy * self.nx + cx] {
                    f(i);
                }
            }
        }
    }
}

/// One immutable shard: a contiguous Hilbert key range of the catalog,
/// independently searchable via its own grid index.
#[derive(Clone, Debug)]
pub struct Shard {
    /// Hilbert keys covered (inclusive bounds, diagnostics / routing)
    pub key_lo: u64,
    pub key_hi: u64,
    pub sources: Vec<ServedSource>,
    /// tight bounding box (x0, y0, x1, y1) of member positions
    pub bbox: (f64, f64, f64, f64),
    grid: ShardGrid,
}

impl Shard {
    /// Build a shard from its member rows and key range. `pub(crate)` so
    /// the ingest path can rebuild individual shards copy-on-write.
    pub(crate) fn build(sources: Vec<ServedSource>, key_lo: u64, key_hi: u64) -> Shard {
        let mut bbox = (f64::INFINITY, f64::INFINITY, f64::NEG_INFINITY, f64::NEG_INFINITY);
        for s in &sources {
            bbox.0 = bbox.0.min(s.pos.0);
            bbox.1 = bbox.1.min(s.pos.1);
            bbox.2 = bbox.2.max(s.pos.0);
            bbox.3 = bbox.3.max(s.pos.1);
        }
        if sources.is_empty() {
            bbox = (0.0, 0.0, 0.0, 0.0);
        }
        let grid = ShardGrid::build(&sources, bbox);
        Shard { key_lo, key_hi, sources, bbox, grid }
    }

    /// Does this shard's bounding box intersect the given box?
    pub fn intersects_box(&self, x0: f64, y0: f64, x1: f64, y1: f64) -> bool {
        !self.sources.is_empty()
            && self.bbox.0 <= x1
            && self.bbox.2 >= x0
            && self.bbox.1 <= y1
            && self.bbox.3 >= y0
    }

    /// Indices of members within `radius` of `center`.
    pub fn cone(&self, center: (f64, f64), radius: f64, out: &mut Vec<usize>) {
        if self.sources.is_empty() {
            return;
        }
        let r2 = radius * radius;
        self.grid.visit_box(
            center.0 - radius,
            center.1 - radius,
            center.0 + radius,
            center.1 + radius,
            |i| {
                let s = &self.sources[i];
                let d2 = (s.pos.0 - center.0).powi(2) + (s.pos.1 - center.1).powi(2);
                if d2 <= r2 {
                    out.push(i);
                }
            },
        );
    }

    /// Indices of members inside the closed box `[x0, x1] x [y0, y1]`.
    pub fn box_search(&self, x0: f64, y0: f64, x1: f64, y1: f64, out: &mut Vec<usize>) {
        if self.sources.is_empty() {
            return;
        }
        self.grid.visit_box(x0, y0, x1, y1, |i| {
            let s = &self.sources[i];
            if s.pos.0 >= x0 && s.pos.0 <= x1 && s.pos.1 >= y0 && s.pos.1 <= y1 {
                out.push(i);
            }
        });
    }
}

/// The sharded, immutable catalog store. Shards are held behind `Arc`
/// so a copy-on-write publish (see [`crate::serve::ingest`]) rebuilds
/// only the touched shards and shares the rest with the prior epoch.
#[derive(Clone, Debug)]
pub struct Store {
    pub shards: Vec<Arc<Shard>>,
    /// sky extent the Hilbert keys were computed over
    pub width: f64,
    pub height: f64,
}

impl Store {
    /// Build a store: keys sources along the Hilbert curve, splits the
    /// sorted order into `n_shards` contiguous ~equal-count ranges, and
    /// indexes each shard. Chunks are only ever cut at key boundaries,
    /// so every Hilbert key maps to exactly one non-empty shard — the
    /// invariant a future key-range router relies on. Empty trailing
    /// shards (more shards than sources) carry a degenerate
    /// `[prev_hi, prev_hi]` range and own no keys.
    pub fn build(sources: Vec<ServedSource>, width: f64, height: f64, n_shards: usize) -> Store {
        let n_shards = n_shards.max(1);
        let mut keyed: Vec<(u64, ServedSource)> = sources
            .into_iter()
            .map(|s| (hilbert_sky_key(s.pos, width, height), s))
            .collect();
        keyed.sort_by_key(|(k, _)| *k);
        let n = keyed.len();
        let per = ((n + n_shards - 1) / n_shards).max(1);
        let mut shards = Vec::with_capacity(n_shards);
        let mut start = 0usize;
        let mut prev_hi = 0u64;
        for _ in 0..n_shards {
            let mut end = (start + per).min(n);
            // never split a run of identical keys across two shards
            while end > start && end < n && keyed[end - 1].0 == keyed[end].0 {
                end += 1;
            }
            let (key_lo, key_hi) = if end > start {
                (keyed[start].0, keyed[end - 1].0)
            } else {
                (prev_hi, prev_hi)
            };
            prev_hi = key_hi;
            let chunk: Vec<ServedSource> =
                keyed[start..end].iter().map(|(_, s)| s.clone()).collect();
            shards.push(Arc::new(Shard::build(chunk, key_lo, key_hi)));
            start = end;
        }
        Store { shards, width, height }
    }

    /// Ingest coordinator output directly.
    pub fn from_inferred(
        rows: &[InferredSource],
        width: f64,
        height: f64,
        n_shards: usize,
    ) -> Store {
        let sources = rows.iter().map(ServedSource::from_inferred).collect();
        Store::build(sources, width, height, n_shards)
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.sources.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The Hilbert key of a sky position under this store's extent.
    pub fn sky_key(&self, pos: (f64, f64)) -> u64 {
        hilbert_sky_key(pos, self.width, self.height)
    }

    /// The shard a Hilbert key is (or would be) stored in: the first
    /// non-empty shard whose range reaches `key`, else the last
    /// non-empty shard (keys past every range extend it). Empty shards
    /// own no keys and are skipped, so delta ingestion only ever widens
    /// a shard's range into the gap left by its lower neighbor — ranges
    /// of non-empty shards stay disjoint across epochs. `None` only for
    /// a fully empty store.
    pub fn shard_for_key(&self, key: u64) -> Option<usize> {
        let mut last_nonempty = None;
        for (i, sh) in self.shards.iter().enumerate() {
            if sh.sources.is_empty() {
                continue;
            }
            if key <= sh.key_hi {
                return Some(i);
            }
            last_nonempty = Some(i);
        }
        last_nonempty
    }

    /// All sources, sorted by id — the canonical flat view used by
    /// snapshots and brute-force reference scans.
    pub fn all_sources(&self) -> Vec<ServedSource> {
        let mut out: Vec<ServedSource> = self
            .shards
            .iter()
            .flat_map(|sh| sh.sources.iter().cloned())
            .collect();
        out.sort_by_key(|s| s.id);
        out
    }

    /// One-line description for logs.
    pub fn summary(&self) -> String {
        let sizes: Vec<usize> = self.shards.iter().map(|s| s.sources.len()).collect();
        format!(
            "store: {} sources over {} shard(s) (sizes {:?}), extent {:.0}x{:.0}",
            self.len(),
            self.shards.len(),
            sizes,
            self.width,
            self.height
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    pub fn synthetic_sources(n: usize, width: f64, height: f64, seed: u64) -> Vec<ServedSource> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|id| ServedSource {
                id,
                pos: (rng.uniform_in(0.0, width), rng.uniform_in(0.0, height)),
                p_gal: rng.uniform(),
                flux_r: rng.lognormal(4.0, 1.2),
                flux_logsd: rng.uniform_in(0.01, 0.8),
                colors: [rng.normal(), rng.normal(), rng.normal(), rng.normal()],
                converged: rng.uniform() < 0.9,
            })
            .collect()
    }

    #[test]
    fn shards_partition_the_catalog() {
        let src = synthetic_sources(1000, 800.0, 600.0, 1);
        let store = Store::build(src.clone(), 800.0, 600.0, 8);
        assert_eq!(store.shards.len(), 8);
        assert_eq!(store.len(), 1000);
        // every shard within one of another in size (equal-count split)
        let sizes: Vec<usize> = store.shards.iter().map(|s| s.sources.len()).collect();
        let max = *sizes.iter().max().unwrap();
        let min = *sizes.iter().min().unwrap();
        assert!(max - min <= 1, "sizes {sizes:?}");
        // flat view recovers exactly the input set
        let mut want = src;
        want.sort_by_key(|s| s.id);
        assert_eq!(store.all_sources(), want);
    }

    #[test]
    fn shard_key_ranges_are_ordered_and_disjoint() {
        let src = synthetic_sources(500, 640.0, 480.0, 2);
        let store = Store::build(src, 640.0, 480.0, 5);
        let nonempty: Vec<&Arc<Shard>> =
            store.shards.iter().filter(|s| !s.sources.is_empty()).collect();
        for w in nonempty.windows(2) {
            // strictly disjoint: a key belongs to exactly one shard
            assert!(w[0].key_hi < w[1].key_lo, "{} >= {}", w[0].key_hi, w[1].key_lo);
        }
        for sh in &store.shards {
            assert!(sh.key_lo <= sh.key_hi);
            for s in &sh.sources {
                let k = hilbert_sky_key(s.pos, store.width, store.height);
                assert!(k >= sh.key_lo && k <= sh.key_hi);
            }
        }
    }

    #[test]
    fn duplicate_keys_never_straddle_shards() {
        // many sources on the same handful of positions => heavy key ties
        let mut src = Vec::new();
        for id in 0..90usize {
            let p = (id % 3) as f64;
            src.push(ServedSource {
                id,
                pos: (10.0 + p, 20.0 + p),
                p_gal: 0.2,
                flux_r: 100.0,
                flux_logsd: 0.1,
                colors: [0.0; 4],
                converged: true,
            });
        }
        let store = Store::build(src, 100.0, 100.0, 4);
        assert_eq!(store.len(), 90);
        // each of the 3 distinct keys must live in exactly one shard
        for sh in &store.shards {
            for other in &store.shards {
                if std::ptr::eq(sh, other) || sh.sources.is_empty() || other.sources.is_empty() {
                    continue;
                }
                assert!(
                    sh.key_hi < other.key_lo || other.key_hi < sh.key_lo,
                    "overlapping non-empty shards: [{},{}] vs [{},{}]",
                    sh.key_lo,
                    sh.key_hi,
                    other.key_lo,
                    other.key_hi
                );
            }
        }
    }

    #[test]
    fn shard_for_key_covers_every_key() {
        let src = synthetic_sources(300, 400.0, 400.0, 8);
        let store = Store::build(src, 400.0, 400.0, 6);
        // every member's key maps back to the shard holding it
        for (i, sh) in store.shards.iter().enumerate() {
            for s in &sh.sources {
                assert_eq!(store.shard_for_key(store.sky_key(s.pos)), Some(i));
            }
        }
        // keys past every range extend the last non-empty shard
        assert_eq!(store.shard_for_key(u64::MAX), Some(5));
        // an empty store owns nothing
        let empty = Store::build(Vec::new(), 100.0, 100.0, 4);
        assert_eq!(empty.shard_for_key(0), None);
    }

    #[test]
    fn more_shards_than_sources_is_fine() {
        let src = synthetic_sources(3, 100.0, 100.0, 3);
        let store = Store::build(src, 100.0, 100.0, 8);
        assert_eq!(store.len(), 3);
        assert_eq!(store.shards.len(), 8);
        // empty shards never match a box probe
        let mut hits = 0;
        for sh in &store.shards {
            if sh.intersects_box(0.0, 0.0, 100.0, 100.0) {
                hits += sh.sources.len();
            }
        }
        assert_eq!(hits, 3);
    }

    #[test]
    fn shard_cone_matches_scan() {
        let src = synthetic_sources(400, 500.0, 500.0, 4);
        let store = Store::build(src, 500.0, 500.0, 4);
        for sh in &store.shards {
            let mut got = Vec::new();
            sh.cone((250.0, 250.0), 120.0, &mut got);
            let want: Vec<usize> = (0..sh.sources.len())
                .filter(|&i| {
                    let p = sh.sources[i].pos;
                    (p.0 - 250.0).powi(2) + (p.1 - 250.0).powi(2) <= 120.0 * 120.0
                })
                .collect();
            let mut got_sorted = got;
            got_sorted.sort_unstable();
            assert_eq!(got_sorted, want);
        }
    }
}
