//! Catalog serving: the system the inference pipeline feeds.
//!
//! The paper stops where the catalog's life begins: posterior point
//! estimates and uncertainties for every light source. This subsystem
//! turns that output into a sharded, queryable, benchmarked store —
//! the ROADMAP's "serve heavy traffic from millions of users" path:
//!
//! * [`store`] — immutable shard-per-Hilbert-range store with per-shard
//!   grid indexes (same spatial key as the inference task ordering).
//! * [`query`] — typed queries (cone, box, brightest-N, star/galaxy
//!   filters, uncertainty-aware cross-match), answered per-shard and
//!   merged; a brute-force reference executor pins the semantics.
//! * [`server`] — multi-threaded executor over `Arc<Store>`: bounded
//!   queue, worker pool, per-class LRU result cache, admission control,
//!   per-class latency quantiles.
//! * [`loadgen`] — open-loop (Poisson) and closed-loop load generators
//!   with configurable query mix and Zipf-skewed sky hotspots.
//! * [`snapshot`] — jsonlite snapshot format bridging `infer` output to
//!   serving across process boundaries.
//! * [`dist`] — the multi-node tier: replicated shard placement, fabric-
//!   backed remote shard clients, a load-balanced scatter-gather router,
//!   and failure injection — all in simulated time.
//!
//! Entry points: `celeste serve-bench` (CLI) and `benches/bench_serve`.

pub mod dist;
pub mod loadgen;
pub mod query;
pub mod server;
pub mod snapshot;
pub mod store;

pub use loadgen::{
    run_closed_loop, run_open_loop, ClosedLoopReport, LoadGen, LoadGenConfig, OpenLoopReport,
    QueryMix,
};
pub use query::{
    cross_match_catalog, execute, execute_on_shard, execute_scan, merge_replies, MatchResult,
    Query, QueryClass, QueryResult, ShardReply, SourceFilter, N_QUERY_CLASSES,
};
pub use server::{Server, ServerConfig, ServerReport};
pub use snapshot::Snapshot;
pub use store::{ServedSource, Shard, Store};
