//! Catalog serving: the system the inference pipeline feeds.
//!
//! The paper stops where the catalog's life begins: posterior point
//! estimates and uncertainties for every light source. This subsystem
//! turns that output into a sharded, queryable, benchmarked store —
//! the ROADMAP's "serve heavy traffic from millions of users" path:
//!
//! * [`store`] — immutable shard-per-Hilbert-range store with per-shard
//!   grid indexes (same spatial key as the inference task ordering).
//! * [`ingest`] — the write path: epoch-stamped shard-level
//!   copy-on-write publishes ([`VersionedStore`]), batch delta
//!   ingestion rebuilding only touched ranges ([`Ingestor`]), and the
//!   synthetic drift generator feeding the mixed read/write scenarios.
//! * [`query`] — typed queries (cone, box, brightest-N, star/galaxy
//!   filters, uncertainty-aware cross-match), answered per-shard and
//!   merged; a brute-force reference executor pins the semantics.
//! * [`engine`] — the unified serving API: a `Request`/`Response`
//!   envelope, the [`QueryEngine`] trait every tier implements, the
//!   composable `Admission`/`Cached`/`Hedged` middleware layers, and
//!   one open/closed-loop driver over a wall or simulated clock.
//! * [`server`] — the wall-clock tier: worker pool over `Arc<Store>`
//!   with a bounded queue and per-class latency quantiles.
//! * [`sched`] — the request schedulers under that pool: the original
//!   mutex+condvar FIFO or a work-stealing pool of per-worker deques,
//!   both with batched draining and same-shard batched execution.
//! * [`loadgen`] — deterministic query streams with configurable query
//!   mix and Zipf-skewed sky hotspots.
//! * [`snapshot`] — jsonlite snapshot format bridging `infer` output to
//!   serving across process boundaries.
//! * [`durable`] — the durability layer: a CRC-framed write-ahead log
//!   fsynced before every publish becomes visible, incremental
//!   per-shard checkpoints, checkpoint-load + tail-replay crash
//!   recovery with a measured RTO (`celeste recover-bench`), and
//!   skew-triggered Hilbert-range compaction with minimal-movement
//!   rendezvous rebalancing.
//! * [`config`] — `serve-bench`'s typed configuration: every flag
//!   parsed and cross-validated in one place ([`ServeConfig`]), with
//!   the conflict matrix pinned by unit tests.
//! * [`control`] — the adaptive control plane: a mechanism-free
//!   controller over windowed per-node/per-shard load that decides
//!   hot-shard relief migrations and membership scaling, recording
//!   every decision in a dump-able log.
//! * [`dist`] — the multi-node tier: replicated shard placement, fabric-
//!   backed remote shard clients, a load-balanced scatter-gather router
//!   with replica hedging, and failure injection — in simulated time.
//! * [`net`] — the same tier over real sockets: a length-prefixed
//!   binary wire protocol, multi-process shard servers, a pipelined
//!   framed client with reconnect/backoff, and a front-end router
//!   engine with cross-process epoch publishes (`--transport tcp`).
//! * [`obs`] — unified observability: the metrics registry every tier's
//!   counters fold into, per-stage request spans propagated across the
//!   wire by trace id, and the sampled trace/slow-query log behind
//!   `serve-bench --obs-dump`.
//!
//! Entry points: `celeste serve-bench` (CLI) and `benches/bench_serve`.

pub mod config;
pub mod control;
pub mod dist;
pub mod durable;
pub mod engine;
pub mod ingest;
pub mod loadgen;
pub mod net;
pub mod obs;
pub mod query;
pub mod sched;
pub mod server;
pub mod snapshot;
pub mod store;

pub use config::ServeConfig;
pub use control::{ControlConfig, ControlEvent, Controller, DecisionLog, NodeLoad};
pub use engine::{
    admit_fraction, drive_closed_loop, drive_open_loop, drive_open_loop_with, layered, metric,
    Admission, Cached, Clock, Consistency, Consistent, DirectEngine, DriveReport, Hedged,
    LayerSpec, Outcome, Priority, QueryEngine, Request, Response, ResultCache, RouterEngine,
    ScanEngine, ServerEngine, SimClock, Submitted, Trace, WallClock, N_PRIORITIES, PRIORITIES,
};
pub use durable::{
    catalog_checksum, store_checksum, CompactionReport, Compactor, DurableLog, Recovered,
    RecoveryReport, WalOp,
};
pub use ingest::{
    DriftConfig, DriftGen, EpochStore, IngestDriver, IngestReport, Ingestor, StoreSource,
    VersionedStore,
};
pub use loadgen::{fuzz_query, LoadGen, LoadGenConfig, QueryMix};
pub use net::{NetRouterEngine, NetShardClient, ShardServer};
pub use obs::{
    Collector, CollectorConfig, GaugeKind, HealthConfig, Registry, SloTarget, SpanSet, Stage,
    Timeline, TraceRecord, TraceSampler, Verdict,
};
pub use query::{
    cross_match_catalog, execute, execute_on_shard, execute_scan, merge_replies, plan_shards,
    MatchResult, Query, QueryClass, QueryResult, ShardReply, SourceFilter, N_QUERY_CLASSES,
};
pub use sched::{execute_batch, plan_batch, SchedConfig, SchedKind};
pub use server::{Server, ServerConfig, ServerReport};
pub use snapshot::Snapshot;
pub use store::{ServedSource, Shard, Store};
