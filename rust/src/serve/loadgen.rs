//! Load generation for the serving path: deterministic query streams
//! with configurable class mixes and Zipf-skewed sky hotspots.
//!
//! The drivers that consume these streams live in
//! [`crate::serve::engine::drive`] — one open-loop and one closed-loop
//! driver, generic over every engine tier (they used to be duplicated
//! here and in the distributed router).
//!
//! Spatial skew: a configurable fraction of spatial queries target
//! Zipf-weighted hotspot centers (quantized so hot queries repeat and
//! result caches are exercised); the rest are uniform over the sky.
//! Mix presets cover the scenario axes: uniform scan, hotspot, and
//! cross-match-heavy.
//!
//! Three time-varying axes exercise the adaptive control plane:
//!
//! * **Moving hotspots** ([`LoadGenConfig::hotspot_move_s`]): the hot
//!   sky regions are re-derived every interval, so demand migrates
//!   between shard ranges mid-run — the workload a rebalancer earns its
//!   keep under. Phase 0 is byte-identical to the static derivation.
//! * **Rate curve** ([`LoadGenConfig::rate_curve`]): a raised-cosine
//!   diurnal swell multiplies the offered rate between 1x and the peak
//!   factor — what autoscaling reacts to.
//! * **Priority mix** ([`LoadGenConfig::priority_mix`]): each request
//!   draws a [`Priority`] from the configured weights, off a dedicated
//!   rng stream so the query sequence itself is unperturbed.
//!
//! The open-loop drivers feed generator time via [`LoadGen::advance_to`]
//! as arrivals are placed; a generator that is never advanced behaves
//! exactly as before these axes existed.

use crate::prng::Rng;

use super::engine::Priority;
use super::query::{Query, SourceFilter};

/// Relative weights of the four query classes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QueryMix {
    pub cone: f64,
    pub box_search: f64,
    pub brightest: f64,
    pub cross_match: f64,
}

impl QueryMix {
    /// Mostly small spatial reads, a sprinkle of heavy scans — the
    /// "millions of users browsing the sky" default.
    pub fn uniform() -> QueryMix {
        QueryMix { cone: 6.0, box_search: 3.0, brightest: 0.5, cross_match: 0.5 }
    }

    /// Same shape as `uniform`; pair with a high hotspot fraction.
    pub fn hotspot() -> QueryMix {
        QueryMix { cone: 7.0, box_search: 2.0, brightest: 0.5, cross_match: 0.5 }
    }

    /// Cross-match dominated (catalog-validation traffic, §VII).
    pub fn cross_match_heavy() -> QueryMix {
        QueryMix { cone: 1.0, box_search: 0.5, brightest: 0.25, cross_match: 8.0 }
    }

    /// Parse either a preset name (`uniform` | `hotspot` | `xmatch`) or
    /// explicit weights `cone=6,box=3,brightest=1,xmatch=1`.
    pub fn parse(s: &str) -> Option<QueryMix> {
        match s {
            "uniform" => return Some(QueryMix::uniform()),
            "hotspot" => return Some(QueryMix::hotspot()),
            "xmatch" => return Some(QueryMix::cross_match_heavy()),
            _ => {}
        }
        let mut mix = QueryMix { cone: 0.0, box_search: 0.0, brightest: 0.0, cross_match: 0.0 };
        for part in s.split(',') {
            let (k, v) = part.split_once('=')?;
            let w: f64 = v.trim().parse().ok()?;
            match k.trim() {
                "cone" => mix.cone = w,
                "box" => mix.box_search = w,
                "brightest" => mix.brightest = w,
                "xmatch" => mix.cross_match = w,
                _ => return None,
            }
        }
        let total = mix.cone + mix.box_search + mix.brightest + mix.cross_match;
        if total > 0.0 {
            Some(mix)
        } else {
            None
        }
    }
}

/// Scenario knobs for one generator stream.
#[derive(Clone, Debug)]
pub struct LoadGenConfig {
    pub mix: QueryMix,
    /// fraction of spatial queries aimed at a hotspot (vs uniform sky)
    pub hotspot_fraction: f64,
    pub n_hotspots: usize,
    /// Zipf exponent over hotspot ranks (s=0 => uniform over hotspots)
    pub zipf_s: f64,
    /// cone radius range, px
    pub radius: (f64, f64),
    /// box edge length range, px
    pub box_edge: (f64, f64),
    /// brightest-N upper bound
    pub brightest_max: usize,
    /// arrivals per burst for the open-loop drivers (1 = plain Poisson).
    /// With `burst > 1`, arrivals come in back-to-back groups of
    /// `burst` separated by exponential gaps scaled to keep the offered
    /// rate unchanged — the arrival shape under which batched request
    /// scheduling earns its keep.
    pub burst: usize,
    /// re-derive the hotspot centers every this many seconds of
    /// generator time (0 = static hotspots, the historical behavior)
    pub hotspot_move_s: f64,
    /// `Some((period_s, peak))`: modulate the offered rate by a
    /// raised-cosine curve from 1x (trough) to `peak`x over each period
    /// — the diurnal swell an autoscaler reacts to
    pub rate_curve: Option<(f64, f64)>,
    /// `Some([low, normal, high])` draws each request's priority from
    /// these weights; `None` leaves every request at `Normal`
    pub priority_mix: Option<[f64; 3]>,
    pub seed: u64,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        LoadGenConfig {
            mix: QueryMix::uniform(),
            hotspot_fraction: 0.3,
            n_hotspots: 16,
            zipf_s: 1.1,
            radius: (4.0, 60.0),
            box_edge: (8.0, 120.0),
            brightest_max: 100,
            burst: 1,
            hotspot_move_s: 0.0,
            rate_curve: None,
            priority_mix: None,
            seed: 42,
        }
    }
}

impl LoadGenConfig {
    /// Preset for a named scenario
    /// (`uniform` | `hotspot` | `xmatch` | `drift` | `moving`).
    pub fn scenario(name: &str, seed: u64) -> Option<LoadGenConfig> {
        let base = LoadGenConfig { seed, ..Default::default() };
        match name {
            "uniform" => Some(LoadGenConfig {
                mix: QueryMix::uniform(),
                hotspot_fraction: 0.0,
                ..base
            }),
            "hotspot" => Some(LoadGenConfig {
                mix: QueryMix::hotspot(),
                hotspot_fraction: 0.9,
                ..base
            }),
            "xmatch" => Some(LoadGenConfig {
                mix: QueryMix::cross_match_heavy(),
                hotspot_fraction: 0.2,
                ..base
            }),
            // the read side of the mixed read/write scenario: hot
            // enough that result caches fill (so ingestion-driven
            // invalidation is visible), with a uniform tail that keeps
            // touching freshly mutated ranges. Pair with --ingest-qps.
            "drift" => Some(LoadGenConfig {
                mix: QueryMix::uniform(),
                hotspot_fraction: 0.7,
                ..base
            }),
            // a few intense hotspots that jump to fresh sky every
            // second: sustained per-range skew whose location keeps
            // moving — the rebalancing controller's scenario
            "moving" => Some(LoadGenConfig {
                mix: QueryMix::hotspot(),
                hotspot_fraction: 0.95,
                n_hotspots: 4,
                zipf_s: 1.5,
                hotspot_move_s: 1.0,
                ..base
            }),
            _ => None,
        }
    }
}

/// One deterministic query stream over a given sky extent.
pub struct LoadGen {
    cfg: LoadGenConfig,
    rng: Rng,
    width: f64,
    height: f64,
    hotspots: Vec<(f64, f64)>,
    /// cumulative Zipf weights over hotspot ranks, normalized to 1
    zipf_cdf: Vec<f64>,
    /// cumulative class weights: cone, box, brightest, xmatch
    mix_cdf: [f64; 4],
    /// arrivals remaining in the current burst (see `LoadGenConfig::burst`)
    burst_left: usize,
    /// generator time (advanced by the open-loop drivers); drives the
    /// hotspot phase and the rate curve
    now: f64,
    /// current hotspot phase (`floor(now / hotspot_move_s)`)
    phase: u64,
    /// dedicated stream for priority draws, so enabling a priority mix
    /// never perturbs the query sequence
    pri_rng: Rng,
}

impl LoadGen {
    pub fn new(cfg: LoadGenConfig, width: f64, height: f64) -> LoadGen {
        let hotspots = LoadGen::derive_hotspots(&cfg, width, height, 0);
        let mut zipf_cdf = Vec::with_capacity(hotspots.len());
        let mut acc = 0.0;
        for rank in 1..=hotspots.len() {
            acc += 1.0 / (rank as f64).powf(cfg.zipf_s);
            zipf_cdf.push(acc);
        }
        for v in &mut zipf_cdf {
            *v /= acc;
        }
        let m = cfg.mix;
        let total = (m.cone + m.box_search + m.brightest + m.cross_match).max(1e-12);
        let mix_cdf = [
            m.cone / total,
            (m.cone + m.box_search) / total,
            (m.cone + m.box_search + m.brightest) / total,
            1.0,
        ];
        let rng = Rng::new(cfg.seed);
        let pri_rng = Rng::new(cfg.seed ^ 0x70f1);
        LoadGen {
            cfg,
            rng,
            width,
            height,
            hotspots,
            zipf_cdf,
            mix_cdf,
            burst_left: 0,
            now: 0.0,
            phase: 0,
            pri_rng,
        }
    }

    /// Hotspot placement is seed-stable but independent of the
    /// per-query stream, so differently-seeded generators share the
    /// same hot sky regions (as real traffic would). Phase 0 is the
    /// historical static derivation; each later phase re-rolls the
    /// centers, modelling interest moving across the sky.
    fn derive_hotspots(
        cfg: &LoadGenConfig,
        width: f64,
        height: f64,
        phase: u64,
    ) -> Vec<(f64, f64)> {
        let seed =
            0x5eed ^ cfg.n_hotspots as u64 ^ phase.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let mut hot_rng = Rng::new(seed);
        (0..cfg.n_hotspots.max(1))
            .map(|_| (hot_rng.uniform_in(0.0, width), hot_rng.uniform_in(0.0, height)))
            .collect()
    }

    /// Advance generator time to `now` (monotone). The open-loop
    /// drivers call this as each arrival is placed; moving hotspots
    /// and the rate curve key off it. Never advancing keeps the stream
    /// identical to the pre-time-varying generator.
    pub fn advance_to(&mut self, now: f64) {
        self.now = self.now.max(now);
        if self.cfg.hotspot_move_s > 0.0 {
            let phase = (self.now / self.cfg.hotspot_move_s) as u64;
            if phase != self.phase {
                self.phase = phase;
                self.hotspots =
                    LoadGen::derive_hotspots(&self.cfg, self.width, self.height, phase);
            }
        }
    }

    /// The rate curve's multiplier at the current generator time
    /// (1.0 without a curve; peaks mid-period with one).
    pub fn rate_factor(&self) -> f64 {
        match self.cfg.rate_curve {
            Some((period, peak)) if period > 0.0 => {
                let swell = 0.5 * (1.0 - (std::f64::consts::TAU * self.now / period).cos());
                1.0 + (peak - 1.0) * swell
            }
            _ => 1.0,
        }
    }

    /// The next request's priority: `Normal` unless a priority mix is
    /// configured, in which case it is drawn from the mix weights on a
    /// stream independent of the query sequence.
    pub fn next_priority(&mut self) -> Priority {
        let Some(w) = self.cfg.priority_mix else {
            return Priority::Normal;
        };
        let total = (w[0] + w[1] + w[2]).max(1e-12);
        let u = self.pri_rng.uniform() * total;
        if u < w[0] {
            Priority::Low
        } else if u < w[0] + w[1] {
            Priority::Normal
        } else {
            Priority::High
        }
    }

    /// A derived stream for another client thread.
    pub fn fork(&mut self, stream: u64) -> LoadGen {
        let mut cfg = self.cfg.clone();
        cfg.seed = self.rng.split(stream).next_u64();
        LoadGen::new(cfg, self.width, self.height)
    }

    fn zipf_hotspot(&mut self) -> (f64, f64) {
        let u = self.rng.uniform();
        let i = self
            .zipf_cdf
            .iter()
            .position(|&c| u <= c)
            .unwrap_or(self.zipf_cdf.len() - 1);
        self.hotspots[i]
    }

    /// A query center plus whether it targeted a hotspot. Hot centers
    /// are quantized to a 2 px lattice so hot queries repeat exactly and
    /// can cache-hit; cold centers are continuous.
    fn sample_center(&mut self) -> ((f64, f64), bool) {
        if self.rng.uniform() < self.cfg.hotspot_fraction {
            let (hx, hy) = self.zipf_hotspot();
            let x = hx + self.rng.normal() * 8.0;
            let y = hy + self.rng.normal() * 8.0;
            (((x * 0.5).round() * 2.0, (y * 0.5).round() * 2.0), true)
        } else {
            (
                (
                    self.rng.uniform_in(0.0, self.width),
                    self.rng.uniform_in(0.0, self.height),
                ),
                false,
            )
        }
    }

    fn sample_filter(&mut self) -> SourceFilter {
        match self.rng.below(4) {
            0 => SourceFilter::StarsOnly,
            1 => SourceFilter::GalaxiesOnly,
            _ => SourceFilter::Any,
        }
    }

    /// One inter-arrival gap (seconds) at offered rate `qps` — shared
    /// by the wall-clock open-loop driver and the distributed tier's
    /// simulated-time driver, so both offer the same arrival process.
    /// With the default `burst == 1` this is a plain Poisson process
    /// (one exponential gap per arrival, draw-for-draw identical to the
    /// pre-burst generator); with `burst > 1`, `burst` arrivals land
    /// back to back and the gap between bursts is scaled by `burst` so
    /// the offered rate is unchanged. A configured rate curve
    /// multiplies the instantaneous rate by [`LoadGen::rate_factor`].
    pub fn next_interarrival(&mut self, qps: f64) -> f64 {
        let burst = self.cfg.burst.max(1);
        if burst > 1 {
            if self.burst_left > 0 {
                self.burst_left -= 1;
                return 0.0;
            }
            self.burst_left = burst - 1;
        }
        let u = self.rng.uniform().max(1e-12);
        -u.ln() * burst as f64 / (qps.max(1e-3) * self.rate_factor())
    }

    /// Draw the next query from the configured mix.
    pub fn next_query(&mut self) -> Query {
        let u = self.rng.uniform();
        if u < self.mix_cdf[0] {
            let (center, hot) = self.sample_center();
            let radius = if hot {
                // quantized radius => repeatable hot cone queries
                (self.rng.uniform_in(self.cfg.radius.0, self.cfg.radius.1) / 8.0).round() * 8.0
            } else {
                self.rng.uniform_in(self.cfg.radius.0, self.cfg.radius.1)
            };
            Query::Cone { center, radius: radius.max(1.0), filter: self.sample_filter() }
        } else if u < self.mix_cdf[1] {
            let ((cx, cy), hot) = self.sample_center();
            let (he, hf) = if hot {
                let e = 0.5
                    * ((self.rng.uniform_in(self.cfg.box_edge.0, self.cfg.box_edge.1) / 8.0)
                        .round()
                        * 8.0)
                        .max(self.cfg.box_edge.0);
                (e, e)
            } else {
                (
                    0.5 * self.rng.uniform_in(self.cfg.box_edge.0, self.cfg.box_edge.1),
                    0.5 * self.rng.uniform_in(self.cfg.box_edge.0, self.cfg.box_edge.1),
                )
            };
            Query::BoxSearch {
                x0: cx - he,
                y0: cy - hf,
                x1: cx + he,
                y1: cy + hf,
                filter: self.sample_filter(),
            }
        } else if u < self.mix_cdf[2] {
            Query::BrightestN {
                n: 1 + self.rng.below(self.cfg.brightest_max.max(1) as u64) as usize,
                filter: self.sample_filter(),
            }
        } else {
            Query::CrossMatch {
                pos: (
                    self.rng.uniform_in(0.0, self.width),
                    self.rng.uniform_in(0.0, self.height),
                ),
                radius: self.rng.uniform_in(0.5, 4.0),
            }
        }
    }
}

/// Deterministic "any corner of the query space" generator for parity
/// tests and benches: cycles class by `i` (cone, box, brightest,
/// cross-match) and filter by `i % 3`, with off-sky centers, near-
/// degenerate radii, and whole-sky boxes included. One copy shared by
/// every suite, so a new query variant gets fuzz coverage everywhere
/// by being added here once.
pub fn fuzz_query(rng: &mut Rng, width: f64, height: f64, i: usize) -> Query {
    let filters = [SourceFilter::Any, SourceFilter::StarsOnly, SourceFilter::GalaxiesOnly];
    let filter = filters[i % 3];
    match i % 4 {
        0 => Query::Cone {
            center: (rng.uniform_in(-40.0, width + 40.0), rng.uniform_in(-40.0, height + 40.0)),
            radius: rng.uniform_in(1.0, 220.0),
            filter,
        },
        1 => {
            let ax = rng.uniform_in(0.0, width);
            let ay = rng.uniform_in(0.0, height);
            let bx = rng.uniform_in(0.0, width);
            let by = rng.uniform_in(0.0, height);
            Query::BoxSearch {
                x0: ax.min(bx),
                y0: ay.min(by),
                x1: ax.max(bx),
                y1: ay.max(by),
                filter,
            }
        }
        2 => Query::BrightestN { n: rng.below(120) as usize, filter },
        _ => Query::CrossMatch {
            pos: (rng.uniform_in(0.0, width), rng.uniform_in(0.0, height)),
            radius: rng.uniform_in(0.3, 8.0),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_parse_presets_and_weights() {
        assert_eq!(QueryMix::parse("uniform"), Some(QueryMix::uniform()));
        assert_eq!(QueryMix::parse("hotspot"), Some(QueryMix::hotspot()));
        assert_eq!(QueryMix::parse("xmatch"), Some(QueryMix::cross_match_heavy()));
        let m = QueryMix::parse("cone=4,box=2,brightest=1,xmatch=3").unwrap();
        assert_eq!(m.cone, 4.0);
        assert_eq!(m.box_search, 2.0);
        assert_eq!(m.brightest, 1.0);
        assert_eq!(m.cross_match, 3.0);
        assert!(QueryMix::parse("nope").is_none());
        assert!(QueryMix::parse("cone=0,box=0").is_none());
    }

    #[test]
    fn generator_is_deterministic_and_mix_respected() {
        let cfg = LoadGenConfig { seed: 7, ..Default::default() };
        let mut a = LoadGen::new(cfg.clone(), 1000.0, 800.0);
        let mut b = LoadGen::new(cfg, 1000.0, 800.0);
        let mut counts = [0usize; 4];
        for _ in 0..2000 {
            let qa = a.next_query();
            let qb = b.next_query();
            assert_eq!(qa, qb);
            counts[qa.class().index()] += 1;
        }
        // uniform mix: cone 60%, box 30%, brightest 5%, xmatch 5%
        assert!(counts[0] > counts[1], "cone {} box {}", counts[0], counts[1]);
        assert!(counts[1] > counts[2] && counts[1] > counts[3], "{counts:?}");
        assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
    }

    #[test]
    fn zipf_hotspots_are_skewed() {
        let cfg = LoadGenConfig {
            hotspot_fraction: 1.0,
            n_hotspots: 8,
            zipf_s: 1.2,
            seed: 3,
            ..Default::default()
        };
        let mut g = LoadGen::new(cfg, 1000.0, 1000.0);
        let hotspots = g.hotspots.clone();
        let mut counts = vec![0usize; hotspots.len()];
        for _ in 0..4000 {
            let ((x, y), _) = g.sample_center();
            // nearest hotspot wins (scatter is small vs spacing, mostly)
            let mut best = 0;
            let mut bd = f64::INFINITY;
            for (i, h) in hotspots.iter().enumerate() {
                let d = (h.0 - x).powi(2) + (h.1 - y).powi(2);
                if d < bd {
                    bd = d;
                    best = i;
                }
            }
            counts[best] += 1;
        }
        // heavy skew: the hottest spot dwarfs the coldest (nearest-spot
        // attribution blurs exact ranks, so compare extremes)
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        assert!(max > 3 * min.max(1), "zipf skew missing: {counts:?}");
    }

    #[test]
    fn interarrival_gaps_are_positive_with_the_right_mean() {
        let mut g = LoadGen::new(LoadGenConfig::default(), 100.0, 100.0);
        let qps = 500.0;
        let n = 20_000;
        let mut total = 0.0;
        for _ in 0..n {
            let gap = g.next_interarrival(qps);
            assert!(gap > 0.0);
            total += gap;
        }
        let mean = total / n as f64;
        assert!(
            (mean - 1.0 / qps).abs() < 0.2 / qps,
            "mean gap {mean} vs expected {}",
            1.0 / qps
        );
    }

    #[test]
    fn bursty_arrivals_keep_the_offered_rate() {
        let cfg = LoadGenConfig { burst: 8, ..Default::default() };
        let mut g = LoadGen::new(cfg, 100.0, 100.0);
        let qps = 400.0;
        let n = 16_000;
        let (mut total, mut zeros) = (0.0, 0usize);
        for _ in 0..n {
            let gap = g.next_interarrival(qps);
            assert!(gap >= 0.0);
            if gap == 0.0 {
                zeros += 1;
            }
            total += gap;
        }
        // 7 of every 8 gaps are zero (inside a burst)...
        assert_eq!(zeros, n * 7 / 8, "burst shape wrong: {zeros} zero gaps");
        // ...and the mean gap still matches the offered rate
        let mean = total / n as f64;
        assert!(
            (mean - 1.0 / qps).abs() < 0.25 / qps,
            "mean gap {mean} vs expected {}",
            1.0 / qps
        );
    }

    #[test]
    fn moving_hotspots_relocate_per_phase_and_phase_zero_is_static() {
        let moving = LoadGenConfig {
            hotspot_fraction: 1.0,
            n_hotspots: 4,
            hotspot_move_s: 1.0,
            seed: 11,
            ..Default::default()
        };
        let static_cfg = LoadGenConfig { hotspot_move_s: 0.0, ..moving.clone() };
        let mut m = LoadGen::new(moving, 1000.0, 1000.0);
        let s = LoadGen::new(static_cfg, 1000.0, 1000.0);
        // before any time passes, the moving generator IS the static one
        assert_eq!(m.hotspots, s.hotspots);
        m.advance_to(0.5); // same phase
        assert_eq!(m.hotspots, s.hotspots);
        let phase0 = m.hotspots.clone();
        m.advance_to(1.25); // phase 1: fresh sky
        assert_ne!(m.hotspots, phase0, "hotspots did not move");
        let phase1 = m.hotspots.clone();
        m.advance_to(2.0); // phase 2 differs from both
        assert_ne!(m.hotspots, phase0);
        assert_ne!(m.hotspots, phase1);
        // time is monotone: a stale timestamp cannot rewind the phase
        let phase2 = m.hotspots.clone();
        m.advance_to(1.0);
        assert_eq!(m.hotspots, phase2);
    }

    #[test]
    fn rate_curve_swells_the_offered_rate_mid_period() {
        let cfg = LoadGenConfig { rate_curve: Some((10.0, 3.0)), ..Default::default() };
        let mut g = LoadGen::new(cfg, 100.0, 100.0);
        let qps = 200.0;
        // trough: factor 1
        assert!((g.rate_factor() - 1.0).abs() < 1e-12);
        let n = 8000;
        let mut trough = 0.0;
        for _ in 0..n {
            trough += g.next_interarrival(qps);
        }
        // peak: factor = the full configured swell
        g.advance_to(5.0);
        assert!((g.rate_factor() - 3.0).abs() < 1e-9);
        let mut peak = 0.0;
        for _ in 0..n {
            peak += g.next_interarrival(qps);
        }
        let (trough_mean, peak_mean) = (trough / n as f64, peak / n as f64);
        assert!(
            (trough_mean - 1.0 / qps).abs() < 0.2 / qps,
            "trough mean {trough_mean}"
        );
        assert!(
            (peak_mean - 1.0 / (3.0 * qps)).abs() < 0.2 / (3.0 * qps),
            "peak mean {peak_mean}"
        );
    }

    #[test]
    fn priority_mix_is_deterministic_and_leaves_the_query_stream_alone() {
        let base = LoadGenConfig { seed: 23, ..Default::default() };
        let mixed = LoadGenConfig {
            priority_mix: Some([6.0, 3.0, 1.0]),
            ..base.clone()
        };
        let mut plain = LoadGen::new(base, 800.0, 800.0);
        let mut a = LoadGen::new(mixed.clone(), 800.0, 800.0);
        let mut b = LoadGen::new(mixed, 800.0, 800.0);
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            // the priority draw rides its own rng stream: the query
            // sequence with a mix is identical to the one without
            let q = a.next_query();
            assert_eq!(q, plain.next_query());
            assert_eq!(plain.next_priority(), Priority::Normal);
            let pa = a.next_priority();
            assert_eq!(pa, b.next_priority());
            b.next_query();
            counts[pa.index()] += 1;
        }
        // 60/30/10 weights show up as ordered frequencies
        assert!(counts[0] > counts[1] && counts[1] > counts[2], "{counts:?}");
        assert!(counts[2] > 100, "high priority starved: {counts:?}");
    }

    #[test]
    fn moving_scenario_preset_moves_and_skews() {
        let cfg = LoadGenConfig::scenario("moving", 9).unwrap();
        assert!(cfg.hotspot_move_s > 0.0);
        assert!(cfg.hotspot_fraction > 0.9);
        assert!(LoadGenConfig::scenario("nope", 9).is_none());
    }

    #[test]
    fn forked_streams_differ() {
        let mut g = LoadGen::new(LoadGenConfig::default(), 500.0, 500.0);
        let mut f1 = g.fork(1);
        let mut f2 = g.fork(2);
        let a: Vec<Query> = (0..10).map(|_| f1.next_query()).collect();
        let b: Vec<Query> = (0..10).map(|_| f2.next_query()).collect();
        assert_ne!(a, b);
    }
}
