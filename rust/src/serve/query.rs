//! Typed catalog queries, answered per-shard and merged.
//!
//! Every query has two executors: [`execute`] (sharded, index-backed)
//! and [`execute_scan`] (brute-force over a flat slice). The engine's
//! contract, enforced by tests, is that the two are *byte-identical* on
//! the same data: results are returned in a canonical order (id order
//! for sets, flux-descending for brightest-N) so merging is
//! deterministic.

use super::store::{ServedSource, Shard, Store};

/// Star/galaxy predicate applied to set-returning queries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SourceFilter {
    Any,
    StarsOnly,
    GalaxiesOnly,
}

impl SourceFilter {
    pub fn accepts(&self, s: &ServedSource) -> bool {
        match self {
            SourceFilter::Any => true,
            SourceFilter::StarsOnly => !s.is_galaxy(),
            SourceFilter::GalaxiesOnly => s.is_galaxy(),
        }
    }

    fn tag(&self) -> u64 {
        match self {
            SourceFilter::Any => 0,
            SourceFilter::StarsOnly => 1,
            SourceFilter::GalaxiesOnly => 2,
        }
    }
}

/// The query language.
#[derive(Clone, Debug, PartialEq)]
pub enum Query {
    /// All sources within `radius` of `center`.
    Cone {
        center: (f64, f64),
        radius: f64,
        filter: SourceFilter,
    },
    /// All sources inside the closed box `[x0, x1] x [y0, y1]`.
    BoxSearch {
        x0: f64,
        y0: f64,
        x1: f64,
        y1: f64,
        filter: SourceFilter,
    },
    /// The `n` brightest sources (reference band), whole catalog.
    BrightestN { n: usize, filter: SourceFilter },
    /// Best uncertainty-aware match for an external (truth) position:
    /// a source at distance `d` matches if `d <= radius * (1 + min(1,
    /// flux_logsd))` — poorly constrained sources get a wider
    /// acceptance radius, mirroring how Celeste's posterior SDs are
    /// meant to be consumed downstream.
    CrossMatch { pos: (f64, f64), radius: f64 },
}

/// Query classes — the unit of result caching and latency accounting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueryClass {
    Cone,
    Box,
    Brightest,
    CrossMatch,
}

pub const N_QUERY_CLASSES: usize = 4;

pub const QUERY_CLASSES: [QueryClass; N_QUERY_CLASSES] = [
    QueryClass::Cone,
    QueryClass::Box,
    QueryClass::Brightest,
    QueryClass::CrossMatch,
];

impl QueryClass {
    pub fn index(self) -> usize {
        match self {
            QueryClass::Cone => 0,
            QueryClass::Box => 1,
            QueryClass::Brightest => 2,
            QueryClass::CrossMatch => 3,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            QueryClass::Cone => "cone",
            QueryClass::Box => "box",
            QueryClass::Brightest => "brightest",
            QueryClass::CrossMatch => "xmatch",
        }
    }

    /// Relative execution cost of this class, 0 = cheapest. The shed
    /// order under overload keys off this (see
    /// [`crate::serve::engine::admit_fraction`]): a cone probe touches
    /// one grid neighborhood, a box scans a bounded region, brightest-N
    /// walks every shard for its top-k, and a cross-match runs the
    /// uncertainty-weighted candidate search — the most expensive.
    pub fn cost_rank(self) -> usize {
        match self {
            QueryClass::Cone => 0,
            QueryClass::Box => 1,
            QueryClass::Brightest => 2,
            QueryClass::CrossMatch => 3,
        }
    }
}

impl Query {
    pub fn class(&self) -> QueryClass {
        match self {
            Query::Cone { .. } => QueryClass::Cone,
            Query::BoxSearch { .. } => QueryClass::Box,
            Query::BrightestN { .. } => QueryClass::Brightest,
            Query::CrossMatch { .. } => QueryClass::CrossMatch,
        }
    }

    /// FNV-1a hash over the exact parameter bits — equal queries (bitwise
    /// equal parameters) get equal cache keys.
    pub fn cache_key(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        let mut mix = |x: u64| {
            h ^= x;
            h = h.wrapping_mul(0x100000001b3);
        };
        match self {
            Query::Cone { center, radius, filter } => {
                mix(1);
                mix(center.0.to_bits());
                mix(center.1.to_bits());
                mix(radius.to_bits());
                mix(filter.tag());
            }
            Query::BoxSearch { x0, y0, x1, y1, filter } => {
                mix(2);
                mix(x0.to_bits());
                mix(y0.to_bits());
                mix(x1.to_bits());
                mix(y1.to_bits());
                mix(filter.tag());
            }
            Query::BrightestN { n, filter } => {
                mix(3);
                mix(*n as u64);
                mix(filter.tag());
            }
            Query::CrossMatch { pos, radius } => {
                mix(4);
                mix(pos.0.to_bits());
                mix(pos.1.to_bits());
                mix(radius.to_bits());
            }
        }
        h
    }
}

/// A cross-match hit: the matched source and its distance.
#[derive(Clone, Debug, PartialEq)]
pub struct MatchResult {
    pub source: ServedSource,
    pub dist: f64,
}

/// Result of any query, in canonical order.
#[derive(Clone, Debug, PartialEq)]
pub enum QueryResult {
    /// id-ascending for cone/box, flux-descending (tie: id) for brightest
    Sources(Vec<ServedSource>),
    Match(Option<MatchResult>),
}

impl QueryResult {
    pub fn count(&self) -> usize {
        match self {
            QueryResult::Sources(v) => v.len(),
            QueryResult::Match(m) => m.is_some() as usize,
        }
    }
}

/// Brightest-N canonical order: flux descending, ties by id ascending.
fn brightness_order(a: &ServedSource, b: &ServedSource) -> std::cmp::Ordering {
    b.flux_r
        .partial_cmp(&a.flux_r)
        .unwrap_or(std::cmp::Ordering::Equal)
        .then(a.id.cmp(&b.id))
}

/// The widest acceptance radius any source can have under
/// uncertainty-aware matching (used to bound the index probe; the
/// distributed router uses it to plan which shards a probe touches).
pub(crate) fn max_match_radius(radius: f64) -> f64 {
    radius * 2.0
}

fn match_radius(radius: f64, s: &ServedSource) -> f64 {
    radius * (1.0 + s.flux_logsd.min(1.0))
}

/// Pick the better of two cross-match candidates: smaller distance,
/// ties by lower id.
fn better_match(a: Option<MatchResult>, b: Option<MatchResult>) -> Option<MatchResult> {
    match (a, b) {
        (None, x) => x,
        (x, None) => x,
        (Some(x), Some(y)) => {
            let pick_y = y.dist < x.dist || (y.dist == x.dist && y.source.id < x.source.id);
            Some(if pick_y { y } else { x })
        }
    }
}

/// One shard's partial answer to a query — what a remote replica ships
/// back over the wire, and what [`merge_replies`] combines into the
/// final result.
#[derive(Clone, Debug, PartialEq)]
pub enum ShardReply {
    Sources(Vec<ServedSource>),
    Match(Option<MatchResult>),
}

impl ShardReply {
    /// Result rows carried by the reply (drives the distributed tier's
    /// response-size and service-time cost model).
    pub fn rows(&self) -> usize {
        match self {
            ShardReply::Sources(v) => v.len(),
            ShardReply::Match(m) => m.is_some() as usize,
        }
    }
}

/// Execute the per-shard part of a query against one shard's grid
/// index: cone/box prune on the shard bbox and filter shard-side (only
/// matching rows travel), brightest-N returns the shard's top-k,
/// cross-match returns the shard's best candidate.
pub fn execute_on_shard(shard: &Shard, q: &Query) -> ShardReply {
    match q {
        Query::Cone { center, radius, filter } => {
            let (bx0, by0) = (center.0 - radius, center.1 - radius);
            let (bx1, by1) = (center.0 + radius, center.1 + radius);
            let mut out = Vec::new();
            if shard.intersects_box(bx0, by0, bx1, by1) {
                let mut idx = Vec::new();
                shard.cone(*center, *radius, &mut idx);
                out.extend(idx.into_iter().map(|i| shard.sources[i].clone()));
                out.retain(|s| filter.accepts(s));
            }
            ShardReply::Sources(out)
        }
        Query::BoxSearch { x0, y0, x1, y1, filter } => {
            let mut out = Vec::new();
            if shard.intersects_box(*x0, *y0, *x1, *y1) {
                let mut idx = Vec::new();
                shard.box_search(*x0, *y0, *x1, *y1, &mut idx);
                out.extend(idx.into_iter().map(|i| shard.sources[i].clone()));
                out.retain(|s| filter.accepts(s));
            }
            ShardReply::Sources(out)
        }
        Query::BrightestN { n, filter } => {
            // top-n on indices, clone only the winners
            let mut idx: Vec<usize> = (0..shard.sources.len())
                .filter(|&i| filter.accepts(&shard.sources[i]))
                .collect();
            idx.sort_by(|&a, &b| brightness_order(&shard.sources[a], &shard.sources[b]));
            idx.truncate(*n);
            ShardReply::Sources(idx.into_iter().map(|i| shard.sources[i].clone()).collect())
        }
        Query::CrossMatch { pos, radius } => {
            let probe = max_match_radius(*radius);
            let (bx0, by0) = (pos.0 - probe, pos.1 - probe);
            let (bx1, by1) = (pos.0 + probe, pos.1 + probe);
            let mut best: Option<MatchResult> = None;
            if shard.intersects_box(bx0, by0, bx1, by1) {
                let mut idx = Vec::new();
                shard.cone(*pos, probe, &mut idx);
                for i in idx {
                    let s = &shard.sources[i];
                    let d = ((s.pos.0 - pos.0).powi(2) + (s.pos.1 - pos.1).powi(2)).sqrt();
                    if d <= match_radius(*radius, s) {
                        best = better_match(
                            best,
                            Some(MatchResult { source: s.clone(), dist: d }),
                        );
                    }
                }
            }
            ShardReply::Match(best)
        }
    }
}

/// Merge per-shard replies into the final result in canonical order
/// (id-ascending for sets, flux-descending + global re-truncate for
/// brightest-N, best-candidate fold for cross-match).
pub fn merge_replies(q: &Query, replies: Vec<ShardReply>) -> QueryResult {
    match q {
        Query::Cone { .. } | Query::BoxSearch { .. } => {
            let mut out = Vec::new();
            for r in replies {
                match r {
                    ShardReply::Sources(v) => out.extend(v),
                    ShardReply::Match(_) => unreachable!("spatial query got match reply"),
                }
            }
            out.sort_by_key(|s| s.id);
            QueryResult::Sources(out)
        }
        Query::BrightestN { n, .. } => {
            let mut cand = Vec::new();
            for r in replies {
                match r {
                    ShardReply::Sources(v) => cand.extend(v),
                    ShardReply::Match(_) => unreachable!("brightest query got match reply"),
                }
            }
            cand.sort_by(brightness_order);
            cand.truncate(*n);
            QueryResult::Sources(cand)
        }
        Query::CrossMatch { .. } => {
            let mut best = None;
            for r in replies {
                match r {
                    ShardReply::Match(m) => best = better_match(best, m),
                    ShardReply::Sources(_) => unreachable!("cross-match got sources reply"),
                }
            }
            QueryResult::Match(best)
        }
    }
}

/// Indices of the shards a query must touch: bbox pruning for spatial
/// probes (cone/box/cross-match), every non-empty shard for
/// brightest-N. One copy of the planning semantics shared by the
/// distributed router's scatter planner and the epoch-aware result
/// cache's coverage stamps — the two must agree on what a query
/// covers, or invalidation would miss mutated ranges.
pub fn plan_shards(store: &Store, q: &Query) -> Vec<usize> {
    let shards = &store.shards;
    match q {
        Query::Cone { center, radius, .. } => {
            let (bx0, by0) = (center.0 - radius, center.1 - radius);
            let (bx1, by1) = (center.0 + radius, center.1 + radius);
            (0..shards.len())
                .filter(|&i| shards[i].intersects_box(bx0, by0, bx1, by1))
                .collect()
        }
        Query::BoxSearch { x0, y0, x1, y1, .. } => (0..shards.len())
            .filter(|&i| shards[i].intersects_box(*x0, *y0, *x1, *y1))
            .collect(),
        Query::BrightestN { .. } => {
            (0..shards.len()).filter(|&i| !shards[i].sources.is_empty()).collect()
        }
        Query::CrossMatch { pos, radius } => {
            let probe = max_match_radius(*radius);
            let (bx0, by0) = (pos.0 - probe, pos.1 - probe);
            let (bx1, by1) = (pos.0 + probe, pos.1 + probe);
            (0..shards.len())
                .filter(|&i| shards[i].intersects_box(bx0, by0, bx1, by1))
                .collect()
        }
    }
}

/// Execute a query against the sharded store. Built as the literal
/// merge of per-shard partials, so the single-host answer and the
/// distributed router's scatter-gather answer are byte-identical *by
/// construction* — there is exactly one copy of the per-shard and
/// merge semantics.
pub fn execute(store: &Store, q: &Query) -> QueryResult {
    merge_replies(q, store.shards.iter().map(|sh| execute_on_shard(sh, q)).collect())
}

/// Brute-force reference executor over a flat slice (id order assumed
/// irrelevant; results are canonically ordered the same way `execute`
/// orders them). Used by tests to pin the sharded engine's semantics and
/// by callers that have no store built.
pub fn execute_scan(sources: &[ServedSource], q: &Query) -> QueryResult {
    match q {
        Query::Cone { center, radius, filter } => {
            let r2 = radius * radius;
            let mut out: Vec<ServedSource> = sources
                .iter()
                .filter(|s| {
                    filter.accepts(s)
                        && (s.pos.0 - center.0).powi(2) + (s.pos.1 - center.1).powi(2) <= r2
                })
                .cloned()
                .collect();
            out.sort_by_key(|s| s.id);
            QueryResult::Sources(out)
        }
        Query::BoxSearch { x0, y0, x1, y1, filter } => {
            let mut out: Vec<ServedSource> = sources
                .iter()
                .filter(|s| {
                    filter.accepts(s)
                        && s.pos.0 >= *x0
                        && s.pos.0 <= *x1
                        && s.pos.1 >= *y0
                        && s.pos.1 <= *y1
                })
                .cloned()
                .collect();
            out.sort_by_key(|s| s.id);
            QueryResult::Sources(out)
        }
        Query::BrightestN { n, filter } => {
            let mut out: Vec<ServedSource> =
                sources.iter().filter(|s| filter.accepts(s)).cloned().collect();
            out.sort_by(brightness_order);
            out.truncate(*n);
            QueryResult::Sources(out)
        }
        Query::CrossMatch { pos, radius } => {
            let mut best: Option<MatchResult> = None;
            for s in sources {
                let d = ((s.pos.0 - pos.0).powi(2) + (s.pos.1 - pos.1).powi(2)).sqrt();
                if d <= match_radius(*radius, s) {
                    best = better_match(best, Some(MatchResult { source: s.clone(), dist: d }));
                }
            }
            QueryResult::Match(best)
        }
    }
}

/// Batch cross-match of a truth catalog against the store: one
/// uncertainty-aware match per truth entry (None where nothing is within
/// the acceptance radius). The validation workload of §VII, as a query.
pub fn cross_match_catalog(
    store: &Store,
    truth_positions: &[(f64, f64)],
    radius: f64,
) -> Vec<Option<MatchResult>> {
    truth_positions
        .iter()
        .map(|&pos| match execute(store, &Query::CrossMatch { pos, radius }) {
            QueryResult::Match(m) => m,
            _ => unreachable!("CrossMatch returns Match"),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn synthetic(n: usize, w: f64, h: f64, seed: u64) -> Vec<ServedSource> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|id| ServedSource {
                id,
                pos: (rng.uniform_in(0.0, w), rng.uniform_in(0.0, h)),
                p_gal: rng.uniform(),
                flux_r: rng.lognormal(4.0, 1.2),
                flux_logsd: rng.uniform_in(0.01, 0.8),
                colors: [0.1, 0.2, 0.3, 0.4],
                converged: true,
            })
            .collect()
    }

    #[test]
    fn sharded_equals_scan_on_random_queries() {
        let (w, h) = (900.0, 700.0);
        let src = synthetic(1200, w, h, 10);
        let store = Store::build(src.clone(), w, h, 7);
        let flat = store.all_sources();
        let mut rng = Rng::new(77);
        let filters = [SourceFilter::Any, SourceFilter::StarsOnly, SourceFilter::GalaxiesOnly];
        for i in 0..120 {
            let filter = filters[(i % 3) as usize];
            let q = match i % 4 {
                0 => Query::Cone {
                    center: (rng.uniform_in(-50.0, w + 50.0), rng.uniform_in(-50.0, h + 50.0)),
                    radius: rng.uniform_in(1.0, 250.0),
                    filter,
                },
                1 => {
                    let ax = rng.uniform_in(0.0, w);
                    let ay = rng.uniform_in(0.0, h);
                    let bx = rng.uniform_in(0.0, w);
                    let by = rng.uniform_in(0.0, h);
                    Query::BoxSearch {
                        x0: ax.min(bx),
                        y0: ay.min(by),
                        x1: ax.max(bx),
                        y1: ay.max(by),
                        filter,
                    }
                }
                2 => Query::BrightestN { n: rng.below(40) as usize, filter },
                _ => Query::CrossMatch {
                    pos: (rng.uniform_in(0.0, w), rng.uniform_in(0.0, h)),
                    radius: rng.uniform_in(0.5, 10.0),
                },
            };
            assert_eq!(execute(&store, &q), execute_scan(&flat, &q), "query {q:?}");
        }
    }

    #[test]
    fn cache_keys_distinguish_queries() {
        let a = Query::Cone { center: (1.0, 2.0), radius: 3.0, filter: SourceFilter::Any };
        let b = Query::Cone { center: (1.0, 2.0), radius: 3.0, filter: SourceFilter::Any };
        let c = Query::Cone { center: (1.0, 2.0), radius: 3.5, filter: SourceFilter::Any };
        let d = Query::Cone { center: (1.0, 2.0), radius: 3.0, filter: SourceFilter::StarsOnly };
        assert_eq!(a.cache_key(), b.cache_key());
        assert_ne!(a.cache_key(), c.cache_key());
        assert_ne!(a.cache_key(), d.cache_key());
        let e = Query::BrightestN { n: 5, filter: SourceFilter::Any };
        let f = Query::BrightestN { n: 6, filter: SourceFilter::Any };
        assert_ne!(e.cache_key(), f.cache_key());
    }

    #[test]
    fn uncertainty_widens_match_radius() {
        let tight = ServedSource {
            id: 0,
            pos: (10.0, 0.0),
            p_gal: 0.1,
            flux_r: 100.0,
            flux_logsd: 0.0,
            colors: [0.0; 4],
            converged: true,
        };
        let loose = ServedSource { id: 1, flux_logsd: 1.0, ..tight.clone() };
        // at distance 10 with base radius 6: only the uncertain source
        // (acceptance 12) matches; the certain one (acceptance 6) does not
        let q = Query::CrossMatch { pos: (0.0, 0.0), radius: 6.0 };
        match execute_scan(&[tight.clone()], &q) {
            QueryResult::Match(m) => assert!(m.is_none()),
            _ => unreachable!(),
        }
        match execute_scan(&[tight, loose], &q) {
            QueryResult::Match(m) => assert_eq!(m.unwrap().source.id, 1),
            _ => unreachable!(),
        }
    }

    #[test]
    fn brightest_order_is_flux_descending() {
        let src = synthetic(50, 100.0, 100.0, 5);
        let store = Store::build(src, 100.0, 100.0, 3);
        match execute(&store, &Query::BrightestN { n: 10, filter: SourceFilter::Any }) {
            QueryResult::Sources(v) => {
                assert_eq!(v.len(), 10);
                for w in v.windows(2) {
                    assert!(w[0].flux_r >= w[1].flux_r);
                }
            }
            _ => unreachable!(),
        }
    }
}
