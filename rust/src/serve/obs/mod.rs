//! Unified observability: a metrics registry, per-stage request spans,
//! and a sampled trace log — the measurement foundation the serving
//! stack's perf work stands on.
//!
//! Three pieces:
//!
//! * **[`Registry`]** — named counters, gauges, and histograms behind
//!   cheap cloneable handles. It absorbs the stack's formerly scattered
//!   accounting (drive/server reports, engine-stack `metrics()` pairs,
//!   net counters) into one snapshottable view. Histograms are
//!   [`metrics::Stats`](crate::metrics::Stats), so quantiles stay
//!   deterministic under [`Snapshot::merge_all`] — the same sorted-
//!   union guarantee `Stats::merge_all` gives per-worker latency folds.
//! * **[`Stage`] / [`SpanSet`]** — the per-request stage vocabulary
//!   (queue wait, batch assembly, shard execute, encode, decode,
//!   network RTT, merge). Each request's `Trace` carries a client-side
//!   `SpanSet` plus the server-side `SpanSet` returned in `Reply`
//!   frames, joined by the request's trace id, so a tcp request yields
//!   a complete cross-process span tree.
//! * **[`TraceSampler`]** — keeps every `N`th request's spans plus
//!   every request slower than a threshold (the slow-query log), bounded
//!   in memory; [`write_dump`] exports registry + samples as jsonlite
//!   (`serve-bench --obs-dump FILE`).
//!
//! Stage-attribution semantics (also in `docs/OBSERVABILITY.md`): on
//! every tier the stages of one request partition its end-to-end
//! latency — the residual interval not covered by a directly measured
//! stage is attributed to `NetRtt` (tcp: the wire wait between encode
//! and decode; sim: fabric transfer plus remote node queueing). That
//! makes "stage sums equal end-to-end latency" hold by construction,
//! which the acceptance tests pin to within 5%.
//!
//! On top of the point-in-time registry sit the continuous-telemetry
//! submodules: [`timeseries`] (fixed-width windowed rollups with an
//! exact counter-conservation invariant), [`collector`] (the per-node
//! + cluster collection loop, gap-tolerant across node death),
//! [`health`] (hysteresis health verdicts), and [`slo`] (multi-window
//! burn-rate gates). `serve-bench --collect-ms N` drives them on every
//! tier and exports the `timeline` section of the dump-v2 schema.

pub mod collector;
pub mod health;
pub mod slo;
pub mod timeseries;

pub use collector::{Collector, CollectorConfig, HealthTransition, StatsSource};
pub use health::{HealthConfig, HealthInputs, HealthTracker, Verdict};
pub use slo::{SloEvaluator, SloEvent, SloKind, SloTarget};
pub use timeseries::{fold_gauges, gauge_kind, GaugeKind, Timeline, Window, WindowHist};

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::jsonlite::Value;
use crate::metrics::Stats;
use crate::serve::query::QUERY_CLASSES;

use super::engine::drive::DriveReport;
use super::server::ServerReport;

/// The per-request pipeline stages a span can be attributed to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// time between enqueue and a worker draining the job (worker-pool
    /// tier), or consistency catch-up stalls + dead-replica detection
    /// delay (distributed tiers)
    QueueWait,
    /// shard planning and per-server request grouping
    BatchAssembly,
    /// executing sub-queries against shard content
    ShardExecute,
    /// wire encoding (client request frames; server reply frames)
    Encode,
    /// wire decoding (client reply frames; server request frames)
    Decode,
    /// the residual wire/fabric wait: everything between a request
    /// leaving the encoder and its reply reaching the decoder that is
    /// not attributed to a server-side stage
    NetRtt,
    /// canonical reply merge + response assembly
    Merge,
}

/// Number of [`Stage`] variants (the fixed width of a [`SpanSet`]).
pub const N_STAGES: usize = 7;

/// Every stage, in wire/display order.
pub const STAGES: [Stage; N_STAGES] = [
    Stage::QueueWait,
    Stage::BatchAssembly,
    Stage::ShardExecute,
    Stage::Encode,
    Stage::Decode,
    Stage::NetRtt,
    Stage::Merge,
];

impl Stage {
    /// Stable metric/JSON name.
    pub fn name(self) -> &'static str {
        match self {
            Stage::QueueWait => "queue_wait",
            Stage::BatchAssembly => "batch_assembly",
            Stage::ShardExecute => "shard_execute",
            Stage::Encode => "encode",
            Stage::Decode => "decode",
            Stage::NetRtt => "net_rtt",
            Stage::Merge => "merge",
        }
    }

    /// Wire tag (index into [`STAGES`]).
    pub fn as_u8(self) -> u8 {
        STAGES.iter().position(|s| *s == self).unwrap() as u8
    }

    /// Inverse of [`Stage::as_u8`]; `None` for unknown tags (a newer
    /// peer may speak stages this build does not know — skip them).
    pub fn from_u8(b: u8) -> Option<Stage> {
        STAGES.get(b as usize).copied()
    }
}

/// Seconds attributed to each [`Stage`] for one request. Additive:
/// repeated `add`s and `merge`s accumulate.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SpanSet {
    secs: [f64; N_STAGES],
}

impl SpanSet {
    pub fn new() -> SpanSet {
        SpanSet::default()
    }

    /// Attribute `secs` (clamped at 0) to `stage`.
    pub fn add(&mut self, stage: Stage, secs: f64) {
        self.secs[stage.as_u8() as usize] += secs.max(0.0);
    }

    pub fn get(&self, stage: Stage) -> f64 {
        self.secs[stage.as_u8() as usize]
    }

    /// Sum over all stages.
    pub fn total(&self) -> f64 {
        self.secs.iter().sum()
    }

    /// True if no stage has any time attributed.
    pub fn is_empty(&self) -> bool {
        self.secs.iter().all(|&s| s == 0.0)
    }

    /// Accumulate another span set stage-wise.
    pub fn merge(&mut self, o: &SpanSet) {
        for (dst, src) in self.secs.iter_mut().zip(&o.secs) {
            *dst += src;
        }
    }

    /// The non-zero `(stage, secs)` pairs, wire order (what `Reply`
    /// frames carry).
    pub fn entries(&self) -> Vec<(u8, f64)> {
        STAGES
            .iter()
            .filter(|s| self.get(**s) > 0.0)
            .map(|s| (s.as_u8(), self.get(*s)))
            .collect()
    }

    /// Rebuild from wire `(stage, secs)` pairs; unknown stages are
    /// skipped, negative times clamped (hostile peers).
    pub fn from_entries(entries: &[(u8, f64)]) -> SpanSet {
        let mut out = SpanSet::new();
        for &(tag, secs) in entries {
            if let Some(stage) = Stage::from_u8(tag) {
                if secs.is_finite() {
                    out.add(stage, secs);
                }
            }
        }
        out
    }
}

/// Process-global trace-id source: unique, monotone, never 0 (0 on the
/// wire means "untraced").
static NEXT_TRACE_ID: AtomicU64 = AtomicU64::new(1);

/// Allocate a fresh trace id (stamped on every `Request`).
pub fn next_trace_id() -> u64 {
    NEXT_TRACE_ID.fetch_add(1, Ordering::Relaxed)
}

/// A cloneable counter handle: one atomic, no lock on the hot path.
#[derive(Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A cloneable histogram handle over a shared [`Stats`] reservoir.
#[derive(Clone, Default)]
pub struct Histogram(Arc<Mutex<Stats>>);

impl Histogram {
    /// Record one observation (seconds, bytes, whatever the metric is).
    pub fn record(&self, x: f64) {
        self.0.lock().unwrap().push(x);
    }

    /// A copy of the underlying distribution.
    pub fn stats(&self) -> Stats {
        self.0.lock().unwrap().clone()
    }
}

#[derive(Default)]
struct RegistryInner {
    counters: BTreeMap<String, Counter>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

/// The unified metrics registry: named counters/gauges/histograms.
/// Handle lookup takes the registry lock once; the returned handles are
/// lock-free (counters) or per-metric locked (histograms), so hot paths
/// hold handles instead of names. Shareable as `Arc<Registry>`.
#[derive(Default)]
pub struct Registry {
    inner: Mutex<RegistryInner>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get or create the named counter.
    pub fn counter(&self, name: &str) -> Counter {
        let mut g = self.inner.lock().unwrap();
        g.counters.entry(name.to_string()).or_default().clone()
    }

    /// Get or create the named histogram.
    pub fn histogram(&self, name: &str) -> Histogram {
        let mut g = self.inner.lock().unwrap();
        g.histograms.entry(name.to_string()).or_default().clone()
    }

    /// Set the named gauge to its latest value.
    pub fn gauge_set(&self, name: &str, v: f64) {
        let mut g = self.inner.lock().unwrap();
        g.gauges.insert(name.to_string(), v);
    }

    /// Per-stage histogram handles (`stage_<name>` seconds), so engines
    /// record a whole [`SpanSet`] with one registry lock acquisition.
    pub fn stage_histograms(&self) -> Vec<(Stage, Histogram)> {
        let mut g = self.inner.lock().unwrap();
        STAGES
            .iter()
            .map(|s| {
                let h = g
                    .histograms
                    .entry(format!("stage_{}", s.name()))
                    .or_default()
                    .clone();
                (*s, h)
            })
            .collect()
    }

    /// Record every non-zero stage of one request's spans into the
    /// `stage_*` histograms.
    pub fn record_spans(&self, spans: &SpanSet) {
        for (stage, h) in self.stage_histograms() {
            let s = spans.get(stage);
            if s > 0.0 {
                h.record(s);
            }
        }
    }

    /// Absorb an engine stack's `metrics()` pairs as gauges, names
    /// unchanged — the reported values are exactly the stack's own.
    pub fn absorb_metrics(&self, pairs: &[(String, f64)]) {
        let mut g = self.inner.lock().unwrap();
        for (name, v) in pairs {
            g.gauges.insert(name.clone(), *v);
        }
    }

    /// Absorb a drive report's disposition counters and latency
    /// distributions, values unchanged (`drive_*` metrics; per-class
    /// latency histograms `drive_latency_<class>` plus the merged
    /// `drive_latency`).
    pub fn absorb_drive(&self, rep: &DriveReport) {
        for (name, v) in [
            ("drive_offered", rep.offered),
            ("drive_completed", rep.completed),
            ("drive_queued", rep.queued),
            ("drive_shed", rep.shed),
            ("drive_failed", rep.failed),
            ("drive_deadline_exceeded", rep.deadline_exceeded),
            ("drive_cache_hits", rep.cache_hits),
            ("drive_hedges", rep.hedges),
            ("drive_hedge_wins", rep.hedge_wins),
            ("drive_local_hits", rep.local_hits),
            ("drive_steals", rep.steals),
            ("drive_batches", rep.batches),
        ] {
            self.counter(name).add(v);
        }
        let mut g = self.inner.lock().unwrap();
        for c in QUERY_CLASSES {
            let h = g
                .histograms
                .entry(format!("drive_latency_{}", c.name()))
                .or_default()
                .clone();
            let mut s = h.0.lock().unwrap();
            s.merge(&rep.latency[c.index()]);
        }
        let all = g.histograms.entry("drive_latency".to_string()).or_default().clone();
        drop(g);
        all.0.lock().unwrap().merge(&rep.latency_all());
    }

    /// Absorb a worker-pool server report (`server_*` metrics), values
    /// unchanged.
    pub fn absorb_server(&self, rep: &ServerReport) {
        for (name, v) in [
            ("server_accepted", rep.accepted),
            ("server_shed", rep.shed),
            ("server_executed", rep.executed),
            ("server_local_hits", rep.local_hits),
            ("server_steals", rep.steals),
            ("server_batches", rep.batches),
        ] {
            self.counter(name).add(v);
        }
        let batch = self.histogram("server_batch_size");
        batch.0.lock().unwrap().merge(&rep.batch_size);
        let lat = self.histogram("server_latency");
        lat.0.lock().unwrap().merge(&rep.latency_all());
        // the worker-pool tier's stage breakdown, measured inside the
        // pool itself (enqueue -> drain; per-batch shard execution)
        let qw = self.histogram(&format!("stage_{}", Stage::QueueWait.name()));
        qw.0.lock().unwrap().merge(&rep.queue_wait);
        let ex = self.histogram(&format!("stage_{}", Stage::ShardExecute.name()));
        ex.0.lock().unwrap().merge(&rep.execute);
    }

    /// A point-in-time copy of every metric.
    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap();
        Snapshot {
            counters: g.counters.iter().map(|(k, c)| (k.clone(), c.get())).collect(),
            gauges: g.gauges.clone(),
            histograms: g
                .histograms
                .iter()
                .map(|(k, h)| (k.clone(), h.stats()))
                .collect(),
        }
    }
}

/// An immutable point-in-time view of a [`Registry`] — what travels in
/// `StatsReply` frames and what [`write_dump`] serializes.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
    pub histograms: BTreeMap<String, Stats>,
}

impl Snapshot {
    /// Counter value by name (0 if absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Merge snapshots from several processes/registries into one view:
    /// counters sum, gauges **sum**, histograms fold through the
    /// deterministic [`Stats::merge_all`] — so the merged quantiles do
    /// not depend on the order snapshots arrive in.
    ///
    /// The gauge rule is deliberate and pinned by test: `merge_all`
    /// joins the *disjoint* registries of one logical process (drive +
    /// server + WAL), where each gauge has exactly one writer and
    /// summing is the identity on the only non-zero value. Folding the
    /// *same* gauge across many nodes is a different operation with a
    /// per-name rule — use
    /// [`fold_gauges`](timeseries::fold_gauges) /
    /// [`GaugeKind`](timeseries::GaugeKind) for cluster rollups
    /// (applied epochs take the min, queue depths the sum).
    pub fn merge_all<'a, I>(parts: I) -> Snapshot
    where
        I: IntoIterator<Item = &'a Snapshot>,
    {
        let parts: Vec<&Snapshot> = parts.into_iter().collect();
        let mut out = Snapshot::default();
        for p in &parts {
            for (k, v) in &p.counters {
                *out.counters.entry(k.clone()).or_insert(0) += v;
            }
            for (k, v) in &p.gauges {
                *out.gauges.entry(k.clone()).or_insert(0.0) += v;
            }
        }
        let mut names: Vec<&String> = Vec::new();
        for p in &parts {
            for k in p.histograms.keys() {
                if !names.contains(&k) {
                    names.push(k);
                }
            }
        }
        for name in names {
            let hs: Vec<&Stats> =
                parts.iter().filter_map(|p| p.histograms.get(name)).collect();
            out.histograms.insert(name.clone(), Stats::merge_all(hs));
        }
        out
    }

    /// Render as a jsonlite object: counters and gauges verbatim,
    /// histograms summarized (n/mean/p50/p99/max in milliseconds-free
    /// raw units).
    pub fn to_json(&self) -> Value {
        let mut counters = BTreeMap::new();
        for (k, v) in &self.counters {
            counters.insert(k.clone(), Value::Num(*v as f64));
        }
        let mut gauges = BTreeMap::new();
        for (k, v) in &self.gauges {
            gauges.insert(k.clone(), Value::Num(*v));
        }
        let mut hists = BTreeMap::new();
        for (k, s) in &self.histograms {
            let q = s.quantiles(&[0.50, 0.99]);
            let mut h = BTreeMap::new();
            h.insert("n".to_string(), Value::Num(s.n as f64));
            h.insert("mean".to_string(), Value::Num(s.mean()));
            h.insert("p50".to_string(), Value::Num(q[0]));
            h.insert("p99".to_string(), Value::Num(q[1]));
            h.insert("max".to_string(), Value::Num(if s.n == 0 { 0.0 } else { s.max }));
            hists.insert(k.clone(), Value::Obj(h));
        }
        let mut obj = BTreeMap::new();
        obj.insert("counters".to_string(), Value::Obj(counters));
        obj.insert("gauges".to_string(), Value::Obj(gauges));
        obj.insert("histograms".to_string(), Value::Obj(hists));
        Value::Obj(obj)
    }
}

/// One sampled request: its trace id, end-to-end latency, and the
/// client/server span sets joined by that id.
#[derive(Clone, Debug)]
pub struct TraceRecord {
    pub trace_id: u64,
    /// end-to-end latency, seconds on the engine's clock
    pub total_s: f64,
    /// client-side (front-end) stage spans
    pub spans: SpanSet,
    /// server-side stage spans returned in `Reply` frames (empty on
    /// single-process tiers)
    pub server_spans: SpanSet,
    /// admitted because it exceeded the slow threshold (the slow-query
    /// log), not (only) by the 1-in-N sampler
    pub slow: bool,
}

impl TraceRecord {
    fn to_json(&self) -> Value {
        let spans_obj = |s: &SpanSet| {
            let mut m = BTreeMap::new();
            for stage in STAGES {
                let v = s.get(stage);
                if v > 0.0 {
                    m.insert(stage.name().to_string(), Value::Num(v * 1e3));
                }
            }
            Value::Obj(m)
        };
        let mut m = BTreeMap::new();
        m.insert("trace_id".to_string(), Value::Num(self.trace_id as f64));
        m.insert("total_ms".to_string(), Value::Num(self.total_s * 1e3));
        m.insert("slow".to_string(), Value::Bool(self.slow));
        m.insert("client_spans_ms".to_string(), spans_obj(&self.spans));
        m.insert("server_spans_ms".to_string(), spans_obj(&self.server_spans));
        Value::Obj(m)
    }
}

/// Retained trace records are bounded so a long run cannot grow the
/// sampler without limit (oldest non-slow records are evicted first).
const TRACE_CAP: usize = 4096;

/// 1-in-N request sampler plus slow-query log. Disabled until
/// [`TraceSampler::configure`] sets a sampling period or threshold.
#[derive(Default)]
pub struct TraceSampler {
    /// keep every Nth request (0 = sampling off)
    every: AtomicU64,
    /// slow threshold in nanoseconds-free f64 bits (0-bits = off)
    slow_bits: AtomicU64,
    seen: AtomicU64,
    records: Mutex<Vec<TraceRecord>>,
}

impl TraceSampler {
    pub fn new() -> TraceSampler {
        TraceSampler::default()
    }

    /// Enable sampling: keep every `every`th request (0 = off) and all
    /// requests at least `slow_s` seconds slow (<= 0 = off; the
    /// threshold is inclusive, so a latency exactly at it is logged).
    pub fn configure(&self, every: u64, slow_s: f64) {
        self.every.store(every, Ordering::Relaxed);
        self.slow_bits
            .store(if slow_s > 0.0 { slow_s.to_bits() } else { 0 }, Ordering::Relaxed);
    }

    /// True if either the sampler or the slow log is armed.
    pub fn enabled(&self) -> bool {
        self.every.load(Ordering::Relaxed) > 0 || self.slow_bits.load(Ordering::Relaxed) != 0
    }

    fn slow_threshold(&self) -> Option<f64> {
        match self.slow_bits.load(Ordering::Relaxed) {
            0 => None,
            bits => Some(f64::from_bits(bits)),
        }
    }

    /// Offer one completed request; the sampler decides whether to keep
    /// it. Cheap when disabled (two relaxed loads).
    pub fn observe(&self, mut rec: TraceRecord) {
        let every = self.every.load(Ordering::Relaxed);
        let slow = self.slow_threshold().is_some_and(|t| rec.total_s >= t);
        let seen = self.seen.fetch_add(1, Ordering::Relaxed) + 1;
        let sampled = every > 0 && seen % every == 0;
        if !sampled && !slow {
            return;
        }
        rec.slow = slow;
        let mut recs = self.records.lock().unwrap();
        if recs.len() >= TRACE_CAP {
            // evict the oldest non-slow record; if everything retained
            // is slow, drop the oldest outright
            let victim = recs.iter().position(|r| !r.slow).unwrap_or(0);
            recs.remove(victim);
        }
        recs.push(rec);
    }

    /// The retained records, oldest first.
    pub fn records(&self) -> Vec<TraceRecord> {
        self.records.lock().unwrap().clone()
    }

    /// Requests offered to the sampler so far.
    pub fn seen(&self) -> u64 {
        self.seen.load(Ordering::Relaxed)
    }

    /// Human lines for the slow-query log (empty when nothing crossed
    /// the threshold).
    pub fn slow_log(&self) -> Vec<String> {
        self.records
            .lock()
            .unwrap()
            .iter()
            .filter(|r| r.slow)
            .map(|r| {
                let mut stages: Vec<String> = STAGES
                    .iter()
                    .filter(|s| r.spans.get(**s) > 0.0)
                    .map(|s| format!("{}={:.3}ms", s.name(), r.spans.get(*s) * 1e3))
                    .collect();
                for s in STAGES {
                    let v = r.server_spans.get(s);
                    if v > 0.0 {
                        stages.push(format!("srv_{}={:.3}ms", s.name(), v * 1e3));
                    }
                }
                format!(
                    "slow: trace={} total={:.3}ms {}",
                    r.trace_id,
                    r.total_s * 1e3,
                    stages.join(" ")
                )
            })
            .collect()
    }
}

/// The `--obs-dump` schema tag. v2 added the optional `timeline`
/// section (windowed rollups + health transitions + SLO burn events,
/// present when the run collected with `--collect-ms`). v3 added the
/// optional `control` section: the control plane's decision log
/// (every rebalance and scale event with its trigger measurement),
/// present when the run passed `--rebalance`.
pub const DUMP_SCHEMA: &str = "celeste-obs-dump-v3";

/// Write the observability dump `serve-bench --obs-dump` produces: the
/// front end's merged metrics snapshot, each shard server's scraped
/// snapshot, the sampled trace records, and — when a collector or a
/// controller ran — the `timeline` and `control` sections.
pub fn write_dump(
    path: &str,
    metrics: &Snapshot,
    servers: &[Snapshot],
    traces: &[TraceRecord],
    timeline: Option<&Collector>,
    control: Option<&crate::serve::control::DecisionLog>,
) -> std::io::Result<()> {
    let mut obj = BTreeMap::new();
    obj.insert("schema".to_string(), Value::Str(DUMP_SCHEMA.to_string()));
    obj.insert("metrics".to_string(), metrics.to_json());
    if let Some(c) = timeline {
        obj.insert("timeline".to_string(), c.to_json());
    }
    if let Some(log) = control {
        let mut c = BTreeMap::new();
        let decisions = crate::jsonlite::parse(&log.to_json())
            .unwrap_or(Value::Arr(Vec::new()));
        c.insert("decisions".to_string(), decisions);
        c.insert("rebalances".to_string(), Value::Num(log.rebalances() as f64));
        c.insert("scale_events".to_string(), Value::Num(log.scale_events() as f64));
        obj.insert("control".to_string(), Value::Obj(c));
    }
    obj.insert(
        "servers".to_string(),
        Value::Arr(servers.iter().map(|s| s.to_json()).collect()),
    );
    obj.insert(
        "traces".to_string(),
        Value::Arr(traces.iter().map(|t| t.to_json()).collect()),
    );
    std::fs::write(path, crate::jsonlite::to_string(&Value::Obj(obj)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_tags_roundtrip() {
        for s in STAGES {
            assert_eq!(Stage::from_u8(s.as_u8()), Some(s));
        }
        assert_eq!(Stage::from_u8(N_STAGES as u8), None);
        assert_eq!(Stage::from_u8(255), None);
    }

    #[test]
    fn span_set_accumulates_and_roundtrips_entries() {
        let mut s = SpanSet::new();
        assert!(s.is_empty());
        s.add(Stage::Encode, 1e-3);
        s.add(Stage::Encode, 2e-3);
        s.add(Stage::NetRtt, 5e-3);
        s.add(Stage::Merge, -1.0); // clamped
        assert!((s.get(Stage::Encode) - 3e-3).abs() < 1e-15);
        assert_eq!(s.get(Stage::Merge), 0.0);
        assert!((s.total() - 8e-3).abs() < 1e-15);
        let back = SpanSet::from_entries(&s.entries());
        assert_eq!(back, s);
        // unknown stages and non-finite times from a hostile peer are
        // dropped, never panicking
        let hostile = SpanSet::from_entries(&[(200, 1.0), (0, f64::NAN), (1, 2.0)]);
        assert_eq!(hostile.get(Stage::BatchAssembly), 2.0);
        assert_eq!(hostile.total(), 2.0);
    }

    #[test]
    fn trace_ids_are_unique_and_nonzero() {
        let a = next_trace_id();
        let b = next_trace_id();
        assert_ne!(a, 0);
        assert_ne!(b, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn counter_handles_share_state() {
        let reg = Registry::new();
        let a = reg.counter("x");
        let b = reg.counter("x");
        a.inc();
        b.add(2);
        assert_eq!(reg.snapshot().counter("x"), 3);
        assert_eq!(a.get(), 3);
    }

    #[test]
    fn snapshot_is_deterministic_across_interleavings() {
        // the same multiset of events recorded in two different
        // interleavings must produce identical snapshots, including
        // histogram quantiles (the registry extension of the
        // `Stats::merge_all` guarantee)
        let events: Vec<f64> = (0..3000u64)
            .map(|i| ((i.wrapping_mul(2654435761) % 10_000) as f64) * 1e-5)
            .collect();
        let build = |order: &[usize]| {
            let reg = Registry::new();
            let c = reg.counter("events");
            let h = reg.histogram("lat");
            for &i in order {
                c.inc();
                h.record(events[i]);
            }
            reg.gauge_set("g", 4.5);
            reg.snapshot()
        };
        let fwd: Vec<usize> = (0..events.len()).collect();
        let mut rev = fwd.clone();
        rev.reverse();
        let a = build(&fwd);
        let b = build(&rev);
        assert_eq!(a.counter("events"), b.counter("events"));
        assert_eq!(a.gauges, b.gauges);
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(
                a.histograms["lat"].quantile(q),
                b.histograms["lat"].quantile(q),
                "q{q} differs across interleavings"
            );
        }
    }

    #[test]
    fn snapshot_merge_is_order_independent() {
        let mk = |lo: u64, hi: u64| {
            let reg = Registry::new();
            reg.counter("n").add(hi - lo);
            let h = reg.histogram("lat");
            for x in lo..hi {
                h.record(x as f64);
            }
            reg.gauge_set("g", 1.0);
            reg.snapshot()
        };
        let a = mk(0, 500);
        let b = mk(500, 900);
        let ab = Snapshot::merge_all([&a, &b]);
        let ba = Snapshot::merge_all([&b, &a]);
        assert_eq!(ab.counter("n"), 900);
        assert_eq!(ab.counter("n"), ba.counter("n"));
        assert_eq!(ab.gauges["g"], 2.0);
        for q in [0.5, 0.99] {
            assert_eq!(ab.histograms["lat"].quantile(q), ba.histograms["lat"].quantile(q));
        }
        assert_eq!(ab.histograms["lat"].n, 900);
    }

    #[test]
    fn sampler_keeps_every_nth_and_slow_requests() {
        let s = TraceSampler::new();
        assert!(!s.enabled());
        s.configure(10, 1e-3);
        assert!(s.enabled());
        for i in 0..100u64 {
            s.observe(TraceRecord {
                trace_id: i + 1,
                total_s: if i == 3 { 5e-3 } else { 1e-5 },
                spans: SpanSet::new(),
                server_spans: SpanSet::new(),
                slow: false,
            });
        }
        let recs = s.records();
        // 10 sampled + 1 slow (trace 4 is not a 10th request)
        assert_eq!(recs.len(), 11);
        assert_eq!(recs.iter().filter(|r| r.slow).count(), 1);
        assert_eq!(recs.iter().find(|r| r.slow).unwrap().trace_id, 4);
        assert_eq!(s.seen(), 100);
        assert_eq!(s.slow_log().len(), 1);
        assert!(s.slow_log()[0].contains("trace=4"));
    }

    #[test]
    fn sampler_memory_is_bounded() {
        let s = TraceSampler::new();
        s.configure(1, 0.0);
        for i in 0..(TRACE_CAP as u64 + 500) {
            s.observe(TraceRecord {
                trace_id: i + 1,
                total_s: 1e-5,
                spans: SpanSet::new(),
                server_spans: SpanSet::new(),
                slow: false,
            });
        }
        let recs = s.records();
        assert_eq!(recs.len(), TRACE_CAP);
        // oldest evicted first
        assert_eq!(recs[0].trace_id, 501);
    }

    fn rec(trace_id: u64, total_s: f64) -> TraceRecord {
        TraceRecord {
            trace_id,
            total_s,
            spans: SpanSet::new(),
            server_spans: SpanSet::new(),
            slow: false,
        }
    }

    #[test]
    fn sampler_cap_eviction_spares_slow_records_deterministically() {
        let s = TraceSampler::new();
        s.configure(1, 1e-3);
        // fill the cap with alternating slow / fast records
        for i in 0..TRACE_CAP as u64 {
            s.observe(rec(i + 1, if i % 2 == 0 { 5e-3 } else { 1e-5 }));
        }
        // each overflow evicts the oldest *non-slow* record, so after
        // N more fast records the retained set is exactly: all original
        // slow records, the original fast tail shifted, the new tail —
        // byte-for-byte reproducible
        for i in 0..100u64 {
            s.observe(rec(TRACE_CAP as u64 + i + 1, 1e-5));
        }
        let recs = s.records();
        assert_eq!(recs.len(), TRACE_CAP);
        let slow_ids: Vec<u64> = recs.iter().filter(|r| r.slow).map(|r| r.trace_id).collect();
        let want: Vec<u64> = (0..TRACE_CAP as u64 / 2).map(|k| 2 * k + 1).collect();
        assert_eq!(slow_ids, want, "every slow record survives eviction, in order");
        let first_fast = recs.iter().find(|r| !r.slow).unwrap().trace_id;
        assert_eq!(first_fast, 202, "the 100 oldest fast records (2,4,..,200) were evicted");
        // when everything retained is slow, eviction degrades to
        // oldest-first instead of scanning forever
        let s2 = TraceSampler::new();
        s2.configure(0, 1e-9);
        for i in 0..(TRACE_CAP as u64 + 3) {
            s2.observe(rec(i + 1, 1.0));
        }
        let recs2 = s2.records();
        assert_eq!(recs2.len(), TRACE_CAP);
        assert_eq!(recs2[0].trace_id, 4, "all-slow cap drops the oldest slow records");
    }

    #[test]
    fn slow_threshold_fires_on_exactly_at_threshold_latency() {
        let s = TraceSampler::new();
        s.configure(0, 2e-3);
        s.observe(rec(1, 2e-3)); // exactly at the threshold
        s.observe(rec(2, 2e-3 - 1e-9)); // just under
        let recs = s.records();
        assert_eq!(recs.len(), 1, "the inclusive threshold keeps the boundary latency");
        assert_eq!(recs[0].trace_id, 1);
        assert!(recs[0].slow);
    }

    #[test]
    fn sample_every_request_with_slow_log_off_records_once() {
        // `--trace-sample 1` + slow log disarmed (`configure(1, 0.0)`):
        // every request must appear exactly once — the sample path and
        // the slow path must not double-record
        let s = TraceSampler::new();
        s.configure(1, 0.0);
        for i in 0..50u64 {
            s.observe(rec(i + 1, 10.0)); // huge latency, but slow log is off
        }
        let recs = s.records();
        assert_eq!(recs.len(), 50);
        let mut ids: Vec<u64> = recs.iter().map(|r| r.trace_id).collect();
        ids.dedup();
        assert_eq!(ids.len(), 50, "no trace id recorded twice");
        assert!(recs.iter().all(|r| !r.slow), "slow log off: nothing marked slow");
        // and both armed: a record that is sampled *and* slow is still
        // recorded once (marked slow)
        let s2 = TraceSampler::new();
        s2.configure(1, 1e-3);
        s2.observe(rec(7, 5e-3));
        let recs2 = s2.records();
        assert_eq!(recs2.len(), 1);
        assert!(recs2[0].slow);
    }

    #[test]
    fn gauge_merge_is_sum_across_disjoint_registries() {
        // the pinned rule (see `Snapshot::merge_all` docs): gauges SUM
        // under merge_all — each gauge has one writer per registry, so
        // the sum is the identity on the only non-zero value. Cluster
        // folds of the *same* gauge use `timeseries::fold_gauges`.
        let mut a = Snapshot::default();
        a.gauges.insert("applied_epoch".to_string(), 9.0);
        let mut b = Snapshot::default();
        b.gauges.insert("recovered_epoch".to_string(), 4.0);
        let merged = Snapshot::merge_all([&a, &b]);
        assert_eq!(merged.gauges["applied_epoch"], 9.0);
        assert_eq!(merged.gauges["recovered_epoch"], 4.0);
        // same-name gauges from two registries do sum — the documented
        // sharp edge that fold_gauges exists to avoid
        let merged2 = Snapshot::merge_all([&a, &a]);
        assert_eq!(merged2.gauges["applied_epoch"], 18.0);
    }

    #[test]
    fn absorb_preserves_reported_values() {
        let mut rep = DriveReport {
            offered: 10,
            completed: 8,
            shed: 1,
            failed: 1,
            cache_hits: 3,
            ..Default::default()
        };
        rep.latency[0].push(0.5);
        rep.latency[0].push(1.5);
        let reg = Registry::new();
        reg.absorb_drive(&rep);
        let snap = reg.snapshot();
        assert_eq!(snap.counter("drive_offered"), 10);
        assert_eq!(snap.counter("drive_completed"), 8);
        assert_eq!(snap.counter("drive_shed"), 1);
        assert_eq!(snap.counter("drive_cache_hits"), 3);
        assert_eq!(snap.histograms["drive_latency"].n, 2);
        assert_eq!(
            snap.histograms["drive_latency"].p50(),
            rep.latency_all().p50()
        );
    }
}
