//! Declarative SLO targets evaluated as multi-window burn rates.
//!
//! A target states an objective as a *good fraction* (e.g. "99% of
//! requests under 50 ms", "99.9% of requests error-free"). Each closed
//! window contributes a bad/total pair per tracked series; the
//! evaluator reports the **burn rate** — the window's bad fraction
//! divided by the objective's error budget `(1 - objective)`:
//!
//! ```text
//! burn = (bad / total) / (1 - objective)
//! ```
//!
//! Burn 1.0 spends the budget exactly as fast as the objective allows;
//! burn 2.0 spends it twice as fast. One noisy window is not an
//! incident, and a long slow bleed should not need a full compliance
//! period to surface — so, following the standard multi-window
//! pattern, an event fires only when **both** the fast burn (the
//! current window) and the slow burn (the trailing
//! [`SloEvaluator::slow_windows`] windows, pooled) clear the target's
//! threshold. The fast window gates on "is it still happening", the
//! slow window on "has it been happening long enough to matter".
//!
//! Latency targets are counted against exact per-window reservoir
//! tails when available (see
//! [`timeseries`](super::timeseries::WindowHist)); once a reservoir
//! saturates the collector falls back to a p99-vs-threshold estimate
//! and says so in the event.

use std::collections::{BTreeMap, VecDeque};

use crate::jsonlite::Value;

/// What a window's bad/total pair measures.
#[derive(Clone, Debug)]
pub enum SloKind {
    /// Bad = requests whose latency exceeded `threshold_s` (seconds —
    /// registry histograms are in seconds).
    LatencyOver { threshold_s: f64 },
    /// Bad = errored requests (the collector decides which counters
    /// count as errors).
    ErrorRate,
}

/// One declarative objective.
#[derive(Clone, Debug)]
pub struct SloTarget {
    /// Report name, e.g. `"latency_p99"`.
    pub name: String,
    /// Histogram the SLI reads (latency kinds) and whose per-window
    /// `n` is the request total (both kinds). Series whose histogram
    /// name extends this with a `_<class>` suffix are tracked per
    /// class automatically.
    pub hist: String,
    pub kind: SloKind,
    /// Target good fraction, e.g. `0.99`.
    pub objective: f64,
    /// Fire when both fast and slow burn reach this, e.g. `1.0`.
    pub burn_threshold: f64,
}

impl SloTarget {
    /// The default target set: p99-style latency at 50 ms / 99%, and
    /// an error-rate objective at 99.9%, both over the engines'
    /// end-to-end `request_latency` histogram (per class via the
    /// `request_latency_<class>` series).
    pub fn defaults() -> Vec<SloTarget> {
        vec![
            SloTarget {
                name: "latency".to_string(),
                hist: "request_latency".to_string(),
                kind: SloKind::LatencyOver { threshold_s: 0.050 },
                objective: 0.99,
                burn_threshold: 1.0,
            },
            SloTarget {
                name: "errors".to_string(),
                hist: "request_latency".to_string(),
                kind: SloKind::ErrorRate,
                objective: 0.999,
                burn_threshold: 1.0,
            },
        ]
    }
}

/// One window's SLI measurement for one series.
#[derive(Clone, Debug)]
pub struct SliSample {
    /// Target index into the evaluator's target list.
    pub target: usize,
    /// Series label: the target name, suffixed per class
    /// (`latency:cone`) when measured from a per-class histogram.
    pub series: String,
    pub bad: u64,
    pub total: u64,
    /// False when `bad` came from a saturated-reservoir estimate.
    pub exact: bool,
}

/// A fired burn-rate gate.
#[derive(Clone, Debug)]
pub struct SloEvent {
    pub target: String,
    pub series: String,
    pub window: u64,
    pub fast_burn: f64,
    pub slow_burn: f64,
    pub exact: bool,
}

impl SloEvent {
    pub fn to_json(&self) -> Value {
        let mut o = BTreeMap::new();
        o.insert("target".to_string(), Value::Str(self.target.clone()));
        o.insert("series".to_string(), Value::Str(self.series.clone()));
        o.insert("window".to_string(), Value::Num(self.window as f64));
        o.insert("fast_burn".to_string(), Value::Num(self.fast_burn));
        o.insert("slow_burn".to_string(), Value::Num(self.slow_burn));
        o.insert("exact".to_string(), Value::Bool(self.exact));
        Value::Obj(o)
    }
}

/// Per-series trailing bad/total ring + event log.
pub struct SloEvaluator {
    targets: Vec<SloTarget>,
    slow_windows: usize,
    rings: BTreeMap<String, VecDeque<(u64, u64)>>,
    events: Vec<SloEvent>,
}

impl SloEvaluator {
    pub fn new(targets: Vec<SloTarget>, slow_windows: usize) -> SloEvaluator {
        SloEvaluator {
            targets,
            slow_windows: slow_windows.max(1),
            rings: BTreeMap::new(),
            events: Vec::new(),
        }
    }

    pub fn targets(&self) -> &[SloTarget] {
        &self.targets
    }

    pub fn events(&self) -> &[SloEvent] {
        &self.events
    }

    /// Feed one closed window's measurements; appends any events that
    /// fire on this window to the log and returns how many did.
    pub fn observe(&mut self, window: u64, samples: &[SliSample]) -> usize {
        let mut fired = 0;
        for s in samples {
            let Some(target) = self.targets.get(s.target) else { continue };
            let budget = (1.0 - target.objective).max(1e-9);
            let ring = self.rings.entry(s.series.clone()).or_default();
            ring.push_back((s.bad, s.total));
            while ring.len() > self.slow_windows {
                ring.pop_front();
            }
            let frac = |bad: u64, total: u64| {
                if total == 0 {
                    0.0
                } else {
                    bad as f64 / total as f64
                }
            };
            let fast_burn = frac(s.bad, s.total) / budget;
            let (slow_bad, slow_total) =
                ring.iter().fold((0u64, 0u64), |(b, t), &(wb, wt)| (b + wb, t + wt));
            let slow_burn = frac(slow_bad, slow_total) / budget;
            if fast_burn >= target.burn_threshold && slow_burn >= target.burn_threshold {
                self.events.push(SloEvent {
                    target: target.name.clone(),
                    series: s.series.clone(),
                    window,
                    fast_burn,
                    slow_burn,
                    exact: s.exact,
                });
                fired += 1;
            }
        }
        fired
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_target() -> Vec<SloTarget> {
        vec![SloTarget {
            name: "latency".to_string(),
            hist: "request_latency".to_string(),
            kind: SloKind::LatencyOver { threshold_s: 0.050 },
            objective: 0.99,
            burn_threshold: 2.0,
        }]
    }

    fn sli(bad: u64, total: u64) -> SliSample {
        SliSample { target: 0, series: "latency".to_string(), bad, total, exact: true }
    }

    #[test]
    fn single_breach_window_does_not_fire_sustained_does() {
        let mut ev = SloEvaluator::new(one_target(), 4);
        // budget = 1%, threshold = 2x burn → needs >= 2% bad fast AND slow.
        // one hot window pooled against three clean ones stays under
        // the slow gate:
        for w in 0..3 {
            assert_eq!(ev.observe(w, &[sli(0, 100)]), 0);
        }
        // fast burn is 5x but the slow pool (5/400 = 1.25x) dilutes it
        assert_eq!(ev.observe(3, &[sli(5, 100)]), 0, "slow burn still diluted");
        // second hot window: slow pool is now 10/400 = 2.5x — sustained
        let fired = ev.observe(4, &[sli(5, 100)]);
        assert_eq!(fired, 1, "two hot windows of 5% must burn a 1% budget at 2x");
        assert_eq!(ev.events().len(), 1);
        let e = &ev.events()[0];
        assert_eq!(e.window, 4);
        assert!(e.fast_burn >= 2.0 && e.slow_burn >= 2.0);
    }

    #[test]
    fn empty_windows_are_compliant() {
        let mut ev = SloEvaluator::new(one_target(), 4);
        assert_eq!(ev.observe(0, &[sli(0, 0)]), 0);
        assert!(ev.events().is_empty());
    }

    #[test]
    fn series_are_tracked_independently() {
        let mut ev = SloEvaluator::new(one_target(), 2);
        let hot = |series: &str, bad| SliSample {
            target: 0,
            series: series.to_string(),
            bad,
            total: 100,
            exact: true,
        };
        // only the cone series burns; the box series must not fire
        for w in 0..3 {
            ev.observe(w, &[hot("latency:cone", 10), hot("latency:box", 0)]);
        }
        assert!(!ev.events().is_empty());
        assert!(ev.events().iter().all(|e| e.series == "latency:cone"));
    }
}
