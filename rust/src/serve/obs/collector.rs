//! The continuous collection loop: per-node cumulative snapshots in,
//! per-node + cluster [`Timeline`]s, health transitions, and SLO burn
//! events out.
//!
//! A [`Collector`] owns one [`Timeline`] per node plus a cluster fold.
//! The *driver* ticks it — from the open-loop driver's
//! `before_arrival` hook on both tiers, so collection runs on the same
//! [`Clock`](crate::serve::engine::Clock) as the load: simulated time
//! on the sim tier (byte-identical timelines across fixed-seed runs),
//! wall time over real sockets. Each tick closes every window the
//! clock has passed; a window close pulls one sample per node from the
//! [`StatsSource`] — the local registry snapshot, a wire `StatsReq`
//! scrape per shard server, or the sim router's per-node view. A node
//! that fails to sample (dead, restarting, suspected) yields `None`
//! and its window is marked **gapped** — the collection loop never
//! fails because a node did.
//!
//! The cluster fold sums counters and merges histograms over each
//! node's *last known* cumulative snapshot (a dead node's contribution
//! is frozen, not dropped — cluster counters stay monotone through a
//! kill), and folds gauges under the explicit per-name
//! [`GaugeKind`](super::timeseries::GaugeKind) rule: applied epochs
//! take the min, queue depths the sum.

use std::collections::BTreeMap;

use crate::jsonlite::Value;
use crate::metrics::Stats;

use super::health::{score, HealthConfig, HealthInputs, HealthTracker, Verdict};
use super::slo::{SliSample, SloEvaluator, SloEvent, SloKind, SloTarget};
use super::timeseries::{fold_gauges, Timeline, Window};
use super::Snapshot;

/// Counters that count as request failures for the error-rate SLO.
const ERROR_COUNTERS: [&str; 5] =
    ["conn_io_errors", "conn_timeouts", "net_failed", "router_failed", "drive_failed"];

/// One sample per node per window close. `None` = the node could not
/// be sampled (dead / restarting / suspected) → gapped window.
pub trait StatsSource {
    fn sample(&mut self, now: f64) -> Vec<Option<Snapshot>>;
}

impl<F: FnMut(f64) -> Vec<Option<Snapshot>>> StatsSource for F {
    fn sample(&mut self, now: f64) -> Vec<Option<Snapshot>> {
        self(now)
    }
}

#[derive(Clone, Debug)]
pub struct CollectorConfig {
    /// Window width, in the driving clock's seconds.
    pub window_s: f64,
    /// Ring bound per timeline (evicted counter deltas are folded into
    /// the conservation total, never lost).
    pub max_windows: usize,
    pub health: HealthConfig,
    pub targets: Vec<SloTarget>,
    /// Trailing windows pooled into the slow burn rate.
    pub slow_windows: usize,
}

impl Default for CollectorConfig {
    fn default() -> CollectorConfig {
        CollectorConfig {
            window_s: 0.25,
            max_windows: 512,
            health: HealthConfig::default(),
            targets: SloTarget::defaults(),
            slow_windows: 6,
        }
    }
}

/// A recorded verdict flip.
#[derive(Clone, Debug)]
pub struct HealthTransition {
    pub node: String,
    pub window: u64,
    pub from: Verdict,
    pub to: Verdict,
    pub score: f64,
}

impl HealthTransition {
    pub fn to_json(&self) -> Value {
        let mut o = BTreeMap::new();
        o.insert("node".to_string(), Value::Str(self.node.clone()));
        o.insert("window".to_string(), Value::Num(self.window as f64));
        o.insert("from".to_string(), Value::Str(self.from.name().to_string()));
        o.insert("to".to_string(), Value::Str(self.to.name().to_string()));
        o.insert("score".to_string(), Value::Num(self.score));
        Value::Obj(o)
    }
}

pub struct Collector {
    cfg: CollectorConfig,
    names: Vec<String>,
    nodes: Vec<Timeline>,
    cluster: Timeline,
    /// Last known cumulative snapshot per node — the cluster fold's
    /// input, frozen (not dropped) while a node is down.
    carried: Vec<Option<Snapshot>>,
    prev_busy: Vec<Option<f64>>,
    trackers: Vec<HealthTracker>,
    transitions: Vec<HealthTransition>,
    slo: SloEvaluator,
    next_window: u64,
}

impl Collector {
    pub fn new(cfg: CollectorConfig, names: Vec<String>) -> Collector {
        let n = names.len();
        let slo = SloEvaluator::new(cfg.targets.clone(), cfg.slow_windows);
        Collector {
            nodes: (0..n).map(|_| Timeline::new(cfg.max_windows)).collect(),
            cluster: Timeline::new(cfg.max_windows),
            carried: vec![None; n],
            prev_busy: vec![None; n],
            trackers: vec![HealthTracker::new(); n],
            transitions: Vec::new(),
            slo,
            next_window: 0,
            cfg,
            names,
        }
    }

    pub fn window_s(&self) -> f64 {
        self.cfg.window_s
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn windows_closed(&self) -> u64 {
        self.next_window
    }

    pub fn node_timeline(&self, i: usize) -> &Timeline {
        &self.nodes[i]
    }

    pub fn cluster(&self) -> &Timeline {
        &self.cluster
    }

    pub fn transitions(&self) -> &[HealthTransition] {
        &self.transitions
    }

    pub fn slo_events(&self) -> &[SloEvent] {
        self.slo.events()
    }

    pub fn verdict(&self, node: usize) -> Verdict {
        self.trackers[node].verdict()
    }

    /// Close every window the clock has fully passed. Call from the
    /// driver's `before_arrival` hook (or any periodic point on the
    /// driving clock).
    pub fn tick(&mut self, now: f64, source: &mut dyn StatsSource) {
        while ((self.next_window + 1) as f64) * self.cfg.window_s <= now {
            let samples = source.sample(now);
            self.close_window(samples);
        }
    }

    /// Close any remaining due windows plus one final (possibly
    /// partial) window, so counters absorbed right up to the end of
    /// the run land in the timeline and conservation against the final
    /// registry totals is exact.
    pub fn finish(&mut self, now: f64, source: &mut dyn StatsSource) {
        self.tick(now, source);
        let samples = source.sample(now);
        self.close_window(samples);
    }

    /// A killed node answered a scrape after being restarted: append a
    /// `recovered` window from its fresh registry (its previous
    /// incarnation's totals are retired into the conservation base)
    /// and flip its verdict back to healthy, bypassing hysteresis.
    pub fn record_recovery(&mut self, node: usize, snap: Snapshot) {
        let index = self.next_window;
        self.nodes[node].observe_recovered(index, snap);
        let win = self.nodes[node].latest().cloned().unwrap_or_default();
        let inputs = self.health_inputs(node, &win, f64::NEG_INFINITY);
        let s = score(&self.cfg.health, &inputs);
        if let Some((from, to)) = self.trackers[node].recover() {
            self.transitions.push(HealthTransition {
                node: self.names[node].clone(),
                window: index,
                from,
                to,
                score: s,
            });
        }
    }

    fn close_window(&mut self, samples: Vec<Option<Snapshot>>) {
        assert_eq!(samples.len(), self.names.len(), "source must sample every node");
        let index = self.next_window;
        self.next_window += 1;

        // freshest applied epoch this tick — per-node lag is measured
        // against it, not against an absolute the collector can't know
        let max_applied = samples
            .iter()
            .flatten()
            .filter_map(|s| s.gauges.get("applied_epoch"))
            .fold(f64::NEG_INFINITY, |a, &b| a.max(b));

        for (n, sample) in samples.into_iter().enumerate() {
            if let Some(s) = &sample {
                self.carried[n] = Some(s.clone());
            }
            self.nodes[n].observe(index, sample);
            let win = self.nodes[n].latest().cloned().unwrap_or_default();
            let inputs = self.health_inputs(n, &win, max_applied);
            let s = score(&self.cfg.health, &inputs);
            if let Some((from, to)) = self.trackers[n].observe(&self.cfg.health, s) {
                self.transitions.push(HealthTransition {
                    node: self.names[n].clone(),
                    window: index,
                    from,
                    to,
                    score: s,
                });
            }
        }

        // cluster fold over last-known cumulative snapshots
        let parts: Vec<&Snapshot> = self.carried.iter().flatten().collect();
        if parts.is_empty() {
            self.cluster.observe(index, None);
            return;
        }
        let mut cum = Snapshot::merge_all(parts.iter().copied());
        cum.gauges = fold_gauges(parts.iter().copied());
        // SLI measurement needs the previous cluster cumulative —
        // compute before the fold is committed to the timeline
        let slis = self.measure_slis(&cum);
        self.cluster.observe(index, Some(cum));
        self.slo.observe(index, &slis);
    }

    fn health_inputs(&mut self, n: usize, win: &Window, max_applied: f64) -> HealthInputs {
        if win.gapped {
            return HealthInputs { gapped: true, ..Default::default() };
        }
        let g = |k: &str| win.gauges.get(k).copied();
        let c = |k: &str| win.counters.get(k).copied().unwrap_or(0) as f64;
        let busy_now = g("node_busy_s");
        let busy_frac = match (busy_now, self.prev_busy[n]) {
            (Some(b), Some(p)) => ((b - p) / self.cfg.window_s).clamp(0.0, 1.0),
            _ => 0.0,
        };
        if busy_now.is_some() {
            self.prev_busy[n] = busy_now;
        }
        let epoch_lag = match g("applied_epoch") {
            Some(a) if max_applied.is_finite() => (max_applied - a).max(0.0),
            _ => 0.0,
        };
        let total = c("net_frames").max(c("node_served")).max(1.0);
        HealthInputs {
            gapped: false,
            queue_depth: g("queue_depth").unwrap_or(0.0),
            busy_frac,
            epoch_lag,
            error_rate: (c("conn_io_errors") + c("conn_timeouts")) / total,
            stale_rate: c("stale_refusals") / total,
            reconnects: c("conn_reconnects"),
        }
    }

    fn measure_slis(&self, cum: &Snapshot) -> Vec<SliSample> {
        let prev = self.cluster.last_snapshot();
        let mut out = Vec::new();
        for (ti, t) in self.slo.targets().iter().enumerate() {
            match &t.kind {
                SloKind::LatencyOver { threshold_s } => {
                    let class_prefix = format!("{}_", t.hist);
                    for (h, st) in &cum.histograms {
                        let series = if *h == t.hist {
                            t.name.clone()
                        } else if let Some(cls) = h.strip_prefix(&class_prefix) {
                            format!("{}:{}", t.name, cls)
                        } else {
                            continue;
                        };
                        let prev_st = prev.and_then(|p| p.histograms.get(h));
                        let (bad, total, exact) = count_over(st, prev_st, *threshold_s);
                        out.push(SliSample { target: ti, series, bad, total, exact });
                    }
                }
                SloKind::ErrorRate => {
                    let prev_c =
                        |k: &str| prev.and_then(|p| p.counters.get(k)).copied().unwrap_or(0);
                    let bad: u64 = ERROR_COUNTERS
                        .iter()
                        .map(|k| cum.counter(k).saturating_sub(prev_c(k)))
                        .sum();
                    let prev_n = prev.and_then(|p| p.histograms.get(&t.hist)).map_or(0, |st| st.n);
                    let total =
                        cum.histograms.get(&t.hist).map_or(0, |st| st.n).saturating_sub(prev_n);
                    out.push(SliSample {
                        target: ti,
                        series: t.name.clone(),
                        bad,
                        total,
                        exact: true,
                    });
                }
            }
        }
        out
    }

    /// The dump-v2 `timeline` section.
    pub fn to_json(&self) -> Value {
        let mut o = BTreeMap::new();
        o.insert("window_ms".to_string(), Value::Num(self.cfg.window_s * 1e3));
        o.insert("windows_closed".to_string(), Value::Num(self.next_window as f64));
        let nodes = self
            .names
            .iter()
            .zip(&self.nodes)
            .map(|(name, t)| t.to_json(name))
            .collect::<Vec<_>>();
        o.insert("nodes".to_string(), Value::Arr(nodes));
        o.insert("cluster".to_string(), self.cluster.to_json("cluster"));
        o.insert(
            "health".to_string(),
            Value::Arr(self.transitions.iter().map(|t| t.to_json()).collect()),
        );
        o.insert(
            "slo".to_string(),
            Value::Arr(self.slo.events().iter().map(|e| e.to_json()).collect()),
        );
        Value::Obj(o)
    }
}

/// Count the window's samples over `thr` in `cur`'s new reservoir
/// tail. Exact while both snapshots' reservoirs held every sample;
/// past saturation the count degrades to a flagged p99-vs-threshold
/// estimate (`~1%` of the window when the cumulative p99 is over).
fn count_over(cur: &Stats, prev: Option<&Stats>, thr: f64) -> (u64, u64, bool) {
    let prev_n = prev.map_or(0, |p| p.n);
    let dn = cur.n.saturating_sub(prev_n);
    if dn == 0 {
        return (0, 0, true);
    }
    let cur_exact = cur.samples().len() as u64 == cur.n;
    let prev_exact = prev.is_none_or(|p| p.samples().len() as u64 == p.n);
    if cur_exact && prev_exact && (prev_n as usize) <= cur.samples().len() {
        let tail = &cur.samples()[prev_n as usize..];
        (tail.iter().filter(|&&x| x > thr).count() as u64, dn, true)
    } else {
        let bad = if cur.quantile(0.99) > thr { (dn / 100).max(1) } else { 0 };
        (bad, dn, false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(served: u64, applied: f64, lat: &[f64]) -> Snapshot {
        let mut s = Snapshot::default();
        s.counters.insert("node_served".to_string(), served);
        s.gauges.insert("applied_epoch".to_string(), applied);
        if !lat.is_empty() {
            let mut st = Stats::new();
            for &x in lat {
                st.push(x);
            }
            s.histograms.insert("request_latency".to_string(), st);
        }
        s
    }

    fn cfg() -> CollectorConfig {
        CollectorConfig { window_s: 0.25, ..Default::default() }
    }

    #[test]
    fn ticks_close_only_fully_passed_windows() {
        let mut c = Collector::new(cfg(), vec!["a".to_string()]);
        let mut calls = 0u64;
        let mut src = |_now: f64| {
            calls += 1;
            vec![Some(snap(calls * 10, 1.0, &[]))]
        };
        c.tick(0.1, &mut src);
        assert_eq!(c.windows_closed(), 0, "window 0 not past yet");
        c.tick(0.26, &mut src);
        assert_eq!(c.windows_closed(), 1);
        c.tick(1.01, &mut src);
        assert_eq!(c.windows_closed(), 4, "catches up one window per due boundary");
        c.finish(1.1, &mut src);
        assert_eq!(c.windows_closed(), 5, "finish closes the partial window");
        // conservation: node and cluster
        let t = c.node_timeline(0);
        assert_eq!(t.delta_total(), t.final_counters());
        assert_eq!(c.cluster().delta_total(), c.cluster().final_counters());
    }

    #[test]
    fn dead_node_gaps_and_goes_unhealthy_within_two_windows() {
        let mut c = Collector::new(cfg(), vec!["a".to_string(), "b".to_string()]);
        let mut tick_n = 0u64;
        let mut src = |_now: f64| {
            tick_n += 1;
            let a = Some(snap(tick_n * 10, tick_n as f64, &[]));
            // node b dies after the second sample
            let b = if tick_n <= 2 { Some(snap(tick_n * 7, tick_n as f64, &[])) } else { None };
            vec![a, b]
        };
        for w in 1..=6 {
            c.tick(0.25 * w as f64 + 0.01, &mut src);
        }
        assert_eq!(c.verdict(0), Verdict::Healthy);
        assert_eq!(c.verdict(1), Verdict::Unhealthy);
        let flips = c.transitions();
        assert_eq!(flips.len(), 1);
        assert_eq!(flips[0].node, "b");
        assert_eq!(
            flips[0].window, 3,
            "gaps start at window 2; two consecutive flip the verdict at window 3"
        );
        assert_eq!(c.node_timeline(0).gaps(), 0, "the healthy node gains no gap");
        assert!(c.node_timeline(1).gaps() >= 2);
        // cluster counters froze node b's contribution, never regressed
        assert_eq!(c.cluster().delta_total(), c.cluster().final_counters());
        // cluster applied epoch folds as min over *sampled* nodes: b's
        // carried gauge keeps the min at its last applied epoch
        let last = c.cluster().latest().unwrap();
        assert_eq!(last.gauges.get("applied_epoch"), Some(&2.0));
    }

    #[test]
    fn recovery_appends_recovered_window_and_flips_back() {
        let mut c = Collector::new(cfg(), vec!["a".to_string()]);
        let mut alive = |_now: f64| vec![Some(snap(10, 1.0, &[]))];
        c.tick(0.26, &mut alive);
        let mut dead = |_now: f64| -> Vec<Option<Snapshot>> { vec![None] };
        c.tick(0.80, &mut dead);
        c.finish(1.0, &mut dead);
        assert_eq!(c.verdict(0), Verdict::Unhealthy);
        c.record_recovery(0, snap(3, 2.0, &[]));
        assert_eq!(c.verdict(0), Verdict::Healthy);
        let t = c.node_timeline(0);
        assert_eq!(t.restarts(), 1);
        let last = t.latest().unwrap();
        assert!(last.recovered && !last.gapped);
        // conservation across the restart: 10 from the first life + 3 after
        assert_eq!(t.delta_total(), t.final_counters());
        assert_eq!(t.final_counters().get("node_served"), Some(&13));
        let recov = c.transitions().iter().find(|t| t.to == Verdict::Healthy);
        assert!(recov.is_some(), "recovery must be recorded as a transition");
    }

    #[test]
    fn latency_slis_are_measured_per_class_series() {
        let mut cfg = cfg();
        cfg.targets = vec![SloTarget {
            name: "latency".to_string(),
            hist: "request_latency".to_string(),
            kind: SloKind::LatencyOver { threshold_s: 0.010 },
            objective: 0.99,
            burn_threshold: 1.0,
        }];
        cfg.slow_windows = 1;
        let mut c = Collector::new(cfg, vec!["a".to_string()]);
        let mut src = |_now: f64| {
            let mut s = snap(100, 1.0, &[0.001; 1]);
            let mut slow = Stats::new();
            for _ in 0..10 {
                slow.push(0.5); // every cone request blows the threshold
            }
            s.histograms.insert("request_latency_cone".to_string(), slow);
            vec![Some(s)]
        };
        c.tick(0.26, &mut src);
        let events = c.slo_events();
        assert!(
            events.iter().any(|e| e.series == "latency:cone"),
            "per-class breach must fire under its class series, got {events:?}"
        );
        assert!(events.iter().all(|e| e.series != "latency"), "base series stayed compliant");
    }
}
