//! Fixed-width windowed rollups over registry snapshots.
//!
//! A [`Timeline`] turns a sequence of *cumulative* [`Snapshot`]s into
//! per-window rows: counter **deltas** (what happened in the window),
//! gauge **last-values** (state at the window close), and per-window
//! p50/p99 computed from the reservoir sample deltas of each histogram
//! (exact while the reservoir is below its cap, flagged approximate
//! once it saturates). Rows live in a bounded ring — memory is
//! O(`max_windows`) — and counter deltas evicted off the ring are
//! folded into a running `evicted` total so the conservation invariant
//! survives eviction:
//!
//! ```text
//! evicted + Σ window counter deltas  ==  final cumulative counters
//! ```
//!
//! (`final` accumulates across process restarts via `base`, so the
//! invariant also holds for a node that was killed and recovered —
//! see [`Timeline::observe_recovered`].)
//!
//! Windows are indexed, not timestamped: the collector closes window
//! `i` when its driving [`Clock`](crate::serve::engine::Clock) passes
//! `(i + 1) * width`, which is what makes sim-tier timelines byte-
//! identical across runs — no wall time enters the row.

use std::collections::{BTreeMap, VecDeque};

use crate::jsonlite::Value;
use crate::metrics::Stats;

use super::Snapshot;

/// How per-node gauges fold into a cluster rollup. Counters always
/// sum; gauges do not have one right answer — an applied epoch wants
/// the *minimum* over nodes (the cluster is only as fresh as its
/// stalest replica), a queue depth wants the *sum*, a lag wants the
/// *max* — so the fold is explicit per gauge name ([`gauge_kind`])
/// instead of an implicit convention.
///
/// Note this is deliberately different from [`Snapshot::merge_all`],
/// which **sums** gauges: `merge_all` joins disjoint registries of one
/// logical process (drive + server + WAL), where each gauge has one
/// writer and summing is the identity; the cluster fold joins the
/// *same* gauge from many nodes, where summing an epoch number would
/// be nonsense.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GaugeKind {
    /// Last writer wins (node order; deterministic). The default for
    /// gauges with no meaningful cross-node fold.
    Last,
    /// Sum over nodes: capacities and depths (queue depth, busy time).
    Sum,
    /// Minimum over nodes: progress watermarks (applied/recovered
    /// epoch — the cluster has applied an epoch only when every node
    /// has).
    Min,
    /// Maximum over nodes: lags and worst-cases.
    Max,
}

/// The cluster-fold kind for a gauge name. Names not listed fold as
/// [`GaugeKind::Last`].
pub fn gauge_kind(name: &str) -> GaugeKind {
    match name {
        "applied_epoch" | "recovered_epoch" => GaugeKind::Min,
        "queue_depth" | "node_busy_s" => GaugeKind::Sum,
        "epoch_lag" => GaugeKind::Max,
        _ => GaugeKind::Last,
    }
}

/// Fold per-node gauge maps into one cluster gauge map under
/// [`gauge_kind`]. Nodes are visited in slice order, so `Last` is
/// deterministic.
pub fn fold_gauges<'a>(parts: impl IntoIterator<Item = &'a Snapshot>) -> BTreeMap<String, f64> {
    let mut out: BTreeMap<String, f64> = BTreeMap::new();
    for part in parts {
        for (name, &v) in &part.gauges {
            match out.entry(name.clone()) {
                std::collections::btree_map::Entry::Vacant(e) => {
                    e.insert(v);
                }
                std::collections::btree_map::Entry::Occupied(mut e) => {
                    let cur = *e.get();
                    *e.get_mut() = match gauge_kind(name) {
                        GaugeKind::Last => v,
                        GaugeKind::Sum => cur + v,
                        GaugeKind::Min => cur.min(v),
                        GaugeKind::Max => cur.max(v),
                    };
                }
            }
        }
    }
    out
}

/// Per-window view of one histogram: sample count in the window and
/// window-local quantiles. `exact` is true while the underlying
/// reservoir held every sample (below its cap) for both the opening
/// and closing snapshot, i.e. the window's samples are literally the
/// cumulative sample vector's new tail; past saturation the quantiles
/// fall back to the *cumulative* distribution and are flagged.
#[derive(Clone, Debug, PartialEq)]
pub struct WindowHist {
    pub n: u64,
    pub p50: f64,
    pub p99: f64,
    pub exact: bool,
}

/// One closed window of a [`Timeline`].
#[derive(Clone, Debug, Default)]
pub struct Window {
    pub index: u64,
    /// The sample for this window failed (dead node / scrape error):
    /// no deltas, gauges carry nothing. Gaps never contribute to the
    /// conservation sum.
    pub gapped: bool,
    /// First successful sample after a process restart
    /// ([`Timeline::observe_recovered`]).
    pub recovered: bool,
    /// Counter deltas vs the previous successful sample (zero deltas
    /// omitted).
    pub counters: BTreeMap<String, u64>,
    /// Gauge values at the window close.
    pub gauges: BTreeMap<String, f64>,
    /// Per-window histogram rollups (histograms with no new samples
    /// omitted).
    pub hists: BTreeMap<String, WindowHist>,
}

impl Window {
    /// A window that carries no signal at all (not even a gap marker).
    pub fn is_empty(&self) -> bool {
        !self.gapped
            && !self.recovered
            && self.counters.is_empty()
            && self.gauges.is_empty()
            && self.hists.is_empty()
    }
}

/// Index-based quantile over an already-sorted slice.
fn sorted_quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let i = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[i.min(sorted.len() - 1)]
}

/// A bounded ring of [`Window`]s for one node (or the cluster fold),
/// plus the bookkeeping that keeps the conservation invariant exact:
/// the last successful cumulative snapshot, counters retired across
/// restarts (`base`), and counter deltas evicted off the ring.
#[derive(Clone, Debug)]
pub struct Timeline {
    max_windows: usize,
    windows: VecDeque<Window>,
    /// Cumulative snapshot at the last successful observation.
    last: Option<Snapshot>,
    /// Counters accumulated by incarnations that have since restarted.
    base: BTreeMap<String, u64>,
    /// Counter deltas of windows evicted off the ring.
    evicted: BTreeMap<String, u64>,
    evicted_windows: u64,
    gaps: u64,
    restarts: u64,
}

impl Timeline {
    pub fn new(max_windows: usize) -> Timeline {
        Timeline {
            max_windows: max_windows.max(1),
            windows: VecDeque::new(),
            last: None,
            base: BTreeMap::new(),
            evicted: BTreeMap::new(),
            evicted_windows: 0,
            gaps: 0,
            restarts: 0,
        }
    }

    /// Close window `index` against `sample` (the node's *cumulative*
    /// snapshot at the close, or `None` for a failed scrape → gap).
    pub fn observe(&mut self, index: u64, sample: Option<Snapshot>) {
        match sample {
            None => {
                self.gaps += 1;
                self.push(Window { index, gapped: true, ..Window::default() });
            }
            Some(snap) => {
                let win = self.delta_window(index, &snap, false);
                self.last = Some(snap);
                self.push(win);
            }
        }
    }

    /// Close window `index` against the first successful sample of a
    /// *restarted* process: the previous incarnation's cumulative
    /// counters are retired into `base` (its registry is gone — its
    /// totals are not), and deltas restart from zero, so conservation
    /// (`delta_total == final_counters`) holds across the restart.
    pub fn observe_recovered(&mut self, index: u64, sample: Snapshot) {
        if let Some(prev) = self.last.take() {
            for (k, v) in &prev.counters {
                *self.base.entry(k.clone()).or_insert(0) += v;
            }
        }
        self.restarts += 1;
        let win = self.delta_window(index, &sample, true);
        self.last = Some(sample);
        self.push(win);
    }

    fn delta_window(&self, index: u64, snap: &Snapshot, recovered: bool) -> Window {
        // `recovered` retires `last` before calling, so prev is None
        let prev = if recovered { None } else { self.last.as_ref() };
        let mut counters = BTreeMap::new();
        for (k, &v) in &snap.counters {
            let p = prev.and_then(|s| s.counters.get(k)).copied().unwrap_or(0);
            let d = v.saturating_sub(p);
            if d > 0 {
                counters.insert(k.clone(), d);
            }
        }
        let mut hists = BTreeMap::new();
        for (k, s) in &snap.histograms {
            let prev_s = prev.and_then(|p| p.histograms.get(k));
            let prev_n = prev_s.map_or(0, |p| p.n);
            let dn = s.n.saturating_sub(prev_n);
            if dn == 0 {
                continue;
            }
            hists.insert(k.clone(), Self::window_hist(s, prev_s, dn));
        }
        Window { index, gapped: false, recovered, counters, gauges: snap.gauges.clone(), hists }
    }

    fn window_hist(cur: &Stats, prev: Option<&Stats>, dn: u64) -> WindowHist {
        let prev_n = prev.map_or(0, |p| p.n);
        let cur_exact = cur.samples().len() as u64 == cur.n;
        let prev_exact = prev.is_none_or(|p| p.samples().len() as u64 == p.n);
        if cur_exact && prev_exact && prev_n as usize <= cur.samples().len() {
            // below the reservoir cap the sample vector is the whole
            // insertion-ordered population: the window's samples are
            // its new tail, and the quantiles are exact
            let mut tail: Vec<f64> = cur.samples()[prev_n as usize..].to_vec();
            tail.sort_by(f64::total_cmp);
            WindowHist {
                n: dn,
                p50: sorted_quantile(&tail, 0.50),
                p99: sorted_quantile(&tail, 0.99),
                exact: true,
            }
        } else {
            // reservoir saturated: window-local samples are no longer
            // recoverable — report the cumulative distribution, flagged
            WindowHist { n: dn, p50: cur.quantile(0.50), p99: cur.quantile(0.99), exact: false }
        }
    }

    fn push(&mut self, win: Window) {
        if self.windows.len() == self.max_windows {
            if let Some(old) = self.windows.pop_front() {
                self.evicted_windows += 1;
                for (k, v) in old.counters {
                    *self.evicted.entry(k).or_insert(0) += v;
                }
            }
        }
        self.windows.push_back(win);
    }

    pub fn windows(&self) -> impl Iterator<Item = &Window> {
        self.windows.iter()
    }

    /// The most recently closed window.
    pub fn latest(&self) -> Option<&Window> {
        self.windows.back()
    }

    pub fn len(&self) -> usize {
        self.windows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }

    pub fn gaps(&self) -> u64 {
        self.gaps
    }

    pub fn restarts(&self) -> u64 {
        self.restarts
    }

    /// Cumulative snapshot at the last successful observation.
    pub fn last_snapshot(&self) -> Option<&Snapshot> {
        self.last.as_ref()
    }

    /// Final cumulative counters: the last successful snapshot plus
    /// counters retired by restarts. The right-hand side of the
    /// conservation invariant.
    pub fn final_counters(&self) -> BTreeMap<String, u64> {
        let mut out = self.base.clone();
        if let Some(last) = &self.last {
            for (k, &v) in &last.counters {
                *out.entry(k.clone()).or_insert(0) += v;
            }
        }
        out.retain(|_, v| *v > 0);
        out
    }

    /// Evicted counter deltas plus the deltas of every retained
    /// window. The left-hand side of the conservation invariant:
    /// equals [`Timeline::final_counters`] exactly, always.
    pub fn delta_total(&self) -> BTreeMap<String, u64> {
        let mut out = self.evicted.clone();
        for w in &self.windows {
            for (k, &v) in &w.counters {
                *out.entry(k.clone()).or_insert(0) += v;
            }
        }
        out.retain(|_, v| *v > 0);
        out
    }

    /// Render as the dump-v2 per-node timeline object.
    pub fn to_json(&self, node: &str) -> Value {
        let mut o = BTreeMap::new();
        o.insert("node".to_string(), Value::Str(node.to_string()));
        o.insert("gaps".to_string(), Value::Num(self.gaps as f64));
        o.insert("restarts".to_string(), Value::Num(self.restarts as f64));
        o.insert("evicted_windows".to_string(), Value::Num(self.evicted_windows as f64));
        let windows = self
            .windows
            .iter()
            .map(|w| {
                let mut wo = BTreeMap::new();
                wo.insert("index".to_string(), Value::Num(w.index as f64));
                wo.insert("gapped".to_string(), Value::Bool(w.gapped));
                wo.insert("recovered".to_string(), Value::Bool(w.recovered));
                wo.insert(
                    "counters".to_string(),
                    Value::Obj(
                        w.counters
                            .iter()
                            .map(|(k, &v)| (k.clone(), Value::Num(v as f64)))
                            .collect(),
                    ),
                );
                wo.insert(
                    "gauges".to_string(),
                    Value::Obj(
                        w.gauges.iter().map(|(k, &v)| (k.clone(), Value::Num(v))).collect(),
                    ),
                );
                wo.insert(
                    "hists".to_string(),
                    Value::Obj(
                        w.hists
                            .iter()
                            .map(|(k, h)| {
                                let mut ho = BTreeMap::new();
                                ho.insert("n".to_string(), Value::Num(h.n as f64));
                                ho.insert("p50".to_string(), Value::Num(h.p50));
                                ho.insert("p99".to_string(), Value::Num(h.p99));
                                ho.insert("exact".to_string(), Value::Bool(h.exact));
                                (k.clone(), Value::Obj(ho))
                            })
                            .collect(),
                    ),
                );
                Value::Obj(wo)
            })
            .collect();
        o.insert("windows".to_string(), Value::Arr(windows));
        o.insert(
            "final".to_string(),
            Value::Obj(
                self.final_counters()
                    .iter()
                    .map(|(k, &v)| (k.clone(), Value::Num(v as f64)))
                    .collect(),
            ),
        );
        o.insert(
            "evicted".to_string(),
            Value::Obj(
                self.evicted.iter().map(|(k, &v)| (k.clone(), Value::Num(v as f64))).collect(),
            ),
        );
        Value::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(counters: &[(&str, u64)], lat: &[f64]) -> Snapshot {
        let mut s = Snapshot::default();
        for (k, v) in counters {
            s.counters.insert(k.to_string(), *v);
        }
        if !lat.is_empty() {
            let mut st = Stats::new();
            for &x in lat {
                st.push(x);
            }
            s.histograms.insert("lat".to_string(), st);
        }
        s
    }

    #[test]
    fn window_deltas_conserve_counters() {
        let mut t = Timeline::new(64);
        t.observe(0, Some(snap(&[("served", 10)], &[])));
        t.observe(1, Some(snap(&[("served", 25), ("failed", 1)], &[])));
        t.observe(2, None); // gap
        t.observe(3, Some(snap(&[("served", 40), ("failed", 1)], &[])));
        assert_eq!(t.delta_total(), t.final_counters());
        assert_eq!(t.final_counters().get("served"), Some(&40));
        assert_eq!(t.gaps(), 1);
        let deltas: Vec<u64> =
            t.windows().map(|w| w.counters.get("served").copied().unwrap_or(0)).collect();
        assert_eq!(deltas, vec![10, 15, 0, 15]);
    }

    #[test]
    fn conservation_survives_ring_eviction() {
        let mut t = Timeline::new(4);
        for i in 0..32u64 {
            t.observe(i, Some(snap(&[("served", (i + 1) * 3)], &[])));
        }
        assert_eq!(t.len(), 4);
        assert_eq!(t.delta_total(), t.final_counters());
        assert_eq!(t.final_counters().get("served"), Some(&96));
    }

    #[test]
    fn conservation_survives_restart() {
        let mut t = Timeline::new(64);
        t.observe(0, Some(snap(&[("served", 100)], &[])));
        t.observe(1, None); // killed
        t.observe_recovered(2, snap(&[("served", 7)], &[])); // fresh registry
        assert_eq!(t.restarts(), 1);
        assert_eq!(t.delta_total(), t.final_counters());
        assert_eq!(t.final_counters().get("served"), Some(&107));
        let last = t.windows().last().unwrap();
        assert!(last.recovered);
        assert_eq!(last.counters.get("served"), Some(&7));
    }

    #[test]
    fn window_quantiles_are_exact_below_the_cap() {
        let mut t = Timeline::new(8);
        t.observe(0, Some(snap(&[], &[1.0, 2.0, 3.0])));
        // window 1 adds a clearly separated batch; its quantiles must
        // come from the new tail only, not the cumulative distribution
        t.observe(1, Some(snap(&[], &[1.0, 2.0, 3.0, 100.0, 101.0, 102.0, 103.0])));
        let w1 = t.windows().nth(1).unwrap();
        let h = &w1.hists["lat"];
        assert_eq!(h.n, 4);
        assert!(h.exact);
        assert!(h.p50 >= 100.0, "window p50 {} must ignore older samples", h.p50);
        assert_eq!(h.p99, 103.0);
    }

    #[test]
    fn gauges_fold_by_explicit_kind() {
        let mut a = Snapshot::default();
        a.gauges.insert("applied_epoch".to_string(), 7.0);
        a.gauges.insert("queue_depth".to_string(), 4.0);
        a.gauges.insert("epoch_lag".to_string(), 1.0);
        a.gauges.insert("whatever".to_string(), 1.0);
        let mut b = Snapshot::default();
        b.gauges.insert("applied_epoch".to_string(), 5.0);
        b.gauges.insert("queue_depth".to_string(), 9.0);
        b.gauges.insert("epoch_lag".to_string(), 3.0);
        b.gauges.insert("whatever".to_string(), 2.0);
        let folded = fold_gauges([&a, &b]);
        assert_eq!(folded["applied_epoch"], 5.0); // min: stalest replica
        assert_eq!(folded["queue_depth"], 13.0); // sum
        assert_eq!(folded["epoch_lag"], 3.0); // max
        assert_eq!(folded["whatever"], 2.0); // last writer (node order)
    }
}
