//! Per-node health scoring with hysteresis.
//!
//! Each closed window yields a [`HealthInputs`] for every node —
//! queue depth, busy fraction, applied-epoch lag, error / stale-refusal
//! rate, reconnect count, all *window-local* — which [`score`] folds
//! into one number in `[0, 1]`. A [`HealthTracker`] then turns the
//! score stream into a [`Verdict`] with hysteresis: the verdict flips
//! only after [`HealthConfig::flip_windows`] *consecutive* windows on
//! the far side of the threshold band, so a single bad (or good)
//! window cannot flap it. A gapped window (node unreachable) scores
//! 0.0 — two consecutive gaps take a healthy node to unhealthy, which
//! is what the kill-node acceptance bound ("unhealthy within 2 windows
//! of the kill") pins.
//!
//! Scoring is a pure function of its inputs: no clocks, no atomics —
//! the sim-tier timeline stays byte-identical across runs.

/// Normalization + thresholds for [`score`] and [`HealthTracker`].
#[derive(Clone, Debug)]
pub struct HealthConfig {
    /// Scores strictly below this count toward an unhealthy flip.
    pub unhealthy_below: f64,
    /// Scores strictly above this count toward a healthy flip. The
    /// band between the two thresholds counts toward neither — that
    /// dead zone is the hysteresis.
    pub healthy_above: f64,
    /// Consecutive qualifying windows required to flip the verdict.
    pub flip_windows: u32,
    /// Queue depth at which the queue term saturates.
    pub queue_capacity: f64,
    /// Applied-epoch lag (epochs behind the freshest node) at which
    /// the lag term saturates.
    pub epoch_lag_tolerance: f64,
}

impl Default for HealthConfig {
    fn default() -> HealthConfig {
        HealthConfig {
            unhealthy_below: 0.45,
            healthy_above: 0.70,
            flip_windows: 2,
            queue_capacity: 512.0,
            epoch_lag_tolerance: 4.0,
        }
    }
}

/// Window-local signals for one node. All rates are per-window
/// fractions (errors / requests in the window), not cumulative.
#[derive(Clone, Debug, Default)]
pub struct HealthInputs {
    /// The window's scrape failed: the node is unreachable.
    pub gapped: bool,
    /// Request queue depth at the window close.
    pub queue_depth: f64,
    /// Fraction of the window the node spent busy.
    pub busy_frac: f64,
    /// Epochs behind the freshest node at the window close.
    pub epoch_lag: f64,
    /// Errors (io + timeout) per request in the window.
    pub error_rate: f64,
    /// Stale-consistency refusals per request in the window.
    pub stale_rate: f64,
    /// Transport reconnects in the window.
    pub reconnects: f64,
}

/// Fold one window's signals into a health score in `[0, 1]`.
/// An unreachable node scores 0.0 outright; otherwise each signal
/// subtracts a weighted, saturating penalty from 1.0. Weights sum
/// past 1.0 on purpose: several moderately bad signals should be able
/// to take a reachable node below [`HealthConfig::unhealthy_below`].
pub fn score(cfg: &HealthConfig, inp: &HealthInputs) -> f64 {
    if inp.gapped {
        return 0.0;
    }
    let sat = |x: f64, scale: f64| (x / scale.max(1e-9)).clamp(0.0, 1.0);
    let s = 1.0
        - 0.25 * sat(inp.queue_depth, cfg.queue_capacity)
        - 0.10 * inp.busy_frac.clamp(0.0, 1.0)
        - 0.25 * sat(inp.epoch_lag, cfg.epoch_lag_tolerance)
        - 0.50 * inp.error_rate.clamp(0.0, 1.0)
        - 0.25 * inp.stale_rate.clamp(0.0, 1.0)
        - 0.25 * sat(inp.reconnects, 4.0);
    s.max(0.0)
}

/// The hysteresis verdict.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    Healthy,
    Unhealthy,
}

impl Verdict {
    pub fn name(self) -> &'static str {
        match self {
            Verdict::Healthy => "healthy",
            Verdict::Unhealthy => "unhealthy",
        }
    }
}

/// Per-node verdict state machine. Starts `Healthy` (a node that has
/// never produced a bad window has given no evidence against itself).
#[derive(Clone, Debug)]
pub struct HealthTracker {
    verdict: Verdict,
    bad_streak: u32,
    good_streak: u32,
}

impl Default for HealthTracker {
    fn default() -> HealthTracker {
        HealthTracker::new()
    }
}

impl HealthTracker {
    pub fn new() -> HealthTracker {
        HealthTracker { verdict: Verdict::Healthy, bad_streak: 0, good_streak: 0 }
    }

    pub fn verdict(&self) -> Verdict {
        self.verdict
    }

    /// Feed one window's score; returns `Some((from, to))` when the
    /// verdict flips on this window.
    pub fn observe(&mut self, cfg: &HealthConfig, score: f64) -> Option<(Verdict, Verdict)> {
        if score < cfg.unhealthy_below {
            self.bad_streak += 1;
            self.good_streak = 0;
        } else if score > cfg.healthy_above {
            self.good_streak += 1;
            self.bad_streak = 0;
        } else {
            // hysteresis band: evidence for neither side
            self.bad_streak = 0;
            self.good_streak = 0;
        }
        let flip = match self.verdict {
            Verdict::Healthy if self.bad_streak >= cfg.flip_windows => Verdict::Unhealthy,
            Verdict::Unhealthy if self.good_streak >= cfg.flip_windows => Verdict::Healthy,
            _ => return None,
        };
        let from = self.verdict;
        self.verdict = flip;
        self.bad_streak = 0;
        self.good_streak = 0;
        Some((from, flip))
    }

    /// An out-of-band recovery signal (the process was restarted and
    /// answered a scrape): flips an unhealthy verdict back to healthy
    /// immediately, bypassing hysteresis — a successful restart is
    /// explicit evidence, not one ambiguous window.
    pub fn recover(&mut self) -> Option<(Verdict, Verdict)> {
        if self.verdict == Verdict::Unhealthy {
            self.verdict = Verdict::Healthy;
            self.bad_streak = 0;
            self.good_streak = 0;
            Some((Verdict::Unhealthy, Verdict::Healthy))
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gap_scores_zero_and_clean_node_scores_one() {
        let cfg = HealthConfig::default();
        assert_eq!(score(&cfg, &HealthInputs { gapped: true, ..Default::default() }), 0.0);
        assert_eq!(score(&cfg, &HealthInputs::default()), 1.0);
    }

    #[test]
    fn score_penalizes_each_signal_monotonically() {
        let cfg = HealthConfig::default();
        let base = score(&cfg, &HealthInputs::default());
        let worse = [
            HealthInputs { queue_depth: 600.0, ..Default::default() },
            HealthInputs { busy_frac: 0.9, ..Default::default() },
            HealthInputs { epoch_lag: 8.0, ..Default::default() },
            HealthInputs { error_rate: 0.5, ..Default::default() },
            HealthInputs { stale_rate: 0.5, ..Default::default() },
            HealthInputs { reconnects: 3.0, ..Default::default() },
        ];
        for inp in &worse {
            assert!(score(&cfg, inp) < base, "{inp:?} must lower the score");
        }
        // a saturated everything still floors at 0
        let awful = HealthInputs {
            queue_depth: 1e9,
            busy_frac: 1.0,
            epoch_lag: 1e9,
            error_rate: 1.0,
            stale_rate: 1.0,
            reconnects: 1e9,
            ..Default::default()
        };
        assert_eq!(score(&cfg, &awful), 0.0);
    }

    #[test]
    fn one_bad_window_does_not_flap_two_do() {
        let cfg = HealthConfig::default();
        let mut t = HealthTracker::new();
        assert_eq!(t.observe(&cfg, 0.0), None, "first bad window must not flip");
        assert_eq!(t.verdict(), Verdict::Healthy);
        assert_eq!(t.observe(&cfg, 1.0), None, "recovery resets the streak");
        assert_eq!(t.observe(&cfg, 0.0), None);
        let flipped = t.observe(&cfg, 0.0);
        assert_eq!(flipped, Some((Verdict::Healthy, Verdict::Unhealthy)));
        assert_eq!(t.verdict(), Verdict::Unhealthy);
        // and back: two good windows required
        assert_eq!(t.observe(&cfg, 1.0), None);
        assert_eq!(t.observe(&cfg, 1.0), Some((Verdict::Unhealthy, Verdict::Healthy)));
    }

    #[test]
    fn band_scores_count_for_neither_side() {
        let cfg = HealthConfig::default();
        let mut t = HealthTracker::new();
        t.observe(&cfg, 0.1);
        // a band score breaks the bad streak: no flip on the next bad
        t.observe(&cfg, 0.55);
        assert_eq!(t.observe(&cfg, 0.1), None);
        assert_eq!(t.verdict(), Verdict::Healthy);
    }

    #[test]
    fn explicit_recovery_bypasses_hysteresis() {
        let cfg = HealthConfig::default();
        let mut t = HealthTracker::new();
        t.observe(&cfg, 0.0);
        t.observe(&cfg, 0.0);
        assert_eq!(t.verdict(), Verdict::Unhealthy);
        assert_eq!(t.recover(), Some((Verdict::Unhealthy, Verdict::Healthy)));
        assert_eq!(t.verdict(), Verdict::Healthy);
        assert_eq!(t.recover(), None, "recovering a healthy node is a no-op");
    }
}
