//! Request scheduling for the worker-pool server: the queue between
//! admission and execution.
//!
//! Two schedulers share one interface:
//!
//! * **condvar** — the original single bounded FIFO guarded by a mutex
//!   + condvar. Every submit and every pop crosses the same lock, which
//!   makes it the contention ceiling of the whole single-host tier once
//!   worker counts grow (the paper's petascale follow-up attributes its
//!   8k-core scaling to moving off exactly this shape of queue).
//! * **steal** — per-worker deques. Submissions are sprayed round-robin
//!   across the deques; each worker drains its own deque oldest-first
//!   and, when empty, steals the oldest jobs from a randomized victim,
//!   so no worker idles while any deque holds work and stragglers'
//!   backlogs are drained by the fleet. Service is oldest-first on
//!   every path — under sustained overload no request is starved the
//!   way a newest-first (LIFO) pop would starve the queue head.
//!
//! Both queues are **priority-banded** ([`Bands`]): one FIFO per
//! [`Priority`], drained highest band first and oldest-first within a
//! band — on local drains *and* steals, so a stolen batch preserves the
//! same service order the owner would have used. Pre-priority callers
//! land in the `Normal` band and see exactly the old FIFO behavior.
//! Under sustained high-priority load lower bands wait; bounding how
//! much total work queues at all is admission's job (the graded
//! [`Admission`](crate::serve::engine::Admission) sheds low-priority
//! work first, so the bands drain, not starve).
//!
//! On top of either queue, workers drain up to `batch` jobs per wake-up
//! and execute them through [`execute_batch`], which answers same-shard
//! queries in one pass over the shard list (one store/epoch load and one
//! shard dispatch per batch instead of per request).
//!
//! **Batch-aware admission**: the shed bound counts every accepted job
//! until the moment its batch *begins executing* — drained-but-unrun
//! jobs still occupy admission slots, so turning batching on cannot
//! quietly widen the effective queue depth. With `batch == 1` the
//! accounting is the original pop-time accounting.
//!
//! **Shutdown drains**: both schedulers guarantee that every accepted
//! job is executed before the workers exit — shutdown stops *intake*,
//! never work in flight. The steal scheduler re-confirms emptiness
//! under every deque lock before a worker may exit, which closes the
//! race with a submitter that passed the shutdown check just before the
//! flag was set.

pub mod batch;

pub use batch::{execute_batch, plan_batch};

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::prng::Rng;

use super::engine::{Priority, N_PRIORITIES};
use super::query::{Query, QueryResult};

/// Which request scheduler the worker pool runs on.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SchedKind {
    /// single mutex+condvar FIFO (the original queue)
    #[default]
    Condvar,
    /// per-worker FIFO deques + randomized oldest-first stealing
    Steal,
}

impl SchedKind {
    /// Parse a `--sched` flag value (`condvar` | `steal`).
    pub fn parse(s: &str) -> Option<SchedKind> {
        match s {
            "condvar" => Some(SchedKind::Condvar),
            "steal" => Some(SchedKind::Steal),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SchedKind::Condvar => "condvar",
            SchedKind::Steal => "steal",
        }
    }
}

/// Scheduler + batching knobs. The default (`condvar`, batch 1) is the
/// original single-queue behavior, bit for bit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SchedConfig {
    pub kind: SchedKind,
    /// max jobs a worker drains (and executes) per wake-up
    pub batch: usize,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig { kind: SchedKind::Condvar, batch: 1 }
    }
}

impl SchedConfig {
    /// Short human label, e.g. `steal x16` (echoed by engine describes).
    pub fn describe(&self) -> String {
        if self.batch.max(1) > 1 {
            format!("{} x{}", self.kind.name(), self.batch)
        } else {
            self.kind.name().to_string()
        }
    }
}

/// One queued request: the query, its scheduling priority (picks the
/// band), its enqueue time (queue-entry → reply latency accounting),
/// and the optional closed-loop reply channel.
pub(crate) struct Job {
    pub query: Query,
    pub priority: Priority,
    pub enqueued: Instant,
    pub reply: Option<mpsc::Sender<QueryResult>>,
}

/// Priority-banded job queue: one FIFO per [`Priority`], drained
/// highest band first, oldest-first within a band. Shared by both
/// schedulers so the drain order is a property of the queue, not of
/// which scheduler happens to hold it.
pub(crate) struct Bands {
    bands: [VecDeque<Job>; N_PRIORITIES],
    len: usize,
}

impl Bands {
    fn new() -> Bands {
        Bands { bands: std::array::from_fn(|_| VecDeque::new()), len: 0 }
    }

    fn len(&self) -> usize {
        self.len
    }

    fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn push_back(&mut self, job: Job) {
        self.bands[job.priority.index()].push_back(job);
        self.len += 1;
    }

    /// Move up to `k` jobs into `out`: highest band first, FIFO within.
    fn drain_into(&mut self, k: usize, out: &mut Vec<Job>) -> usize {
        let mut moved = 0;
        for band in self.bands.iter_mut().rev() {
            while moved < k {
                match band.pop_front() {
                    Some(job) => {
                        out.push(job);
                        moved += 1;
                    }
                    None => break,
                }
            }
        }
        self.len -= moved;
        moved
    }
}

/// The queue between admission and the worker pool, in either flavor.
pub(crate) enum SchedQueue {
    Condvar(CondvarQueue),
    Steal(StealQueue),
}

impl SchedQueue {
    /// Build the queue for `workers` worker threads with an admission
    /// bound of `depth` accepted-but-unexecuted jobs.
    pub fn new(kind: SchedKind, workers: usize, depth: usize) -> SchedQueue {
        match kind {
            SchedKind::Condvar => SchedQueue::Condvar(CondvarQueue {
                state: Mutex::new(CondvarState { jobs: Bands::new(), shutdown: false }),
                not_empty: Condvar::new(),
                pending: AtomicUsize::new(0),
                accepted: AtomicU64::new(0),
                depth,
            }),
            SchedKind::Steal => SchedQueue::Steal(StealQueue {
                queues: (0..workers.max(1)).map(|_| Mutex::new(Bands::new())).collect(),
                pending: AtomicUsize::new(0),
                queued: AtomicUsize::new(0),
                accepted: AtomicU64::new(0),
                depth,
                shutdown: AtomicBool::new(false),
                sleepers: AtomicUsize::new(0),
                sleep: Mutex::new(()),
                wake: Condvar::new(),
                next: AtomicUsize::new(0),
            }),
        }
    }

    /// Admit one job, or refuse it (shutdown, or the pending bound is
    /// reached). Acceptance is counted here, under the queue lock; the
    /// caller counts sheds.
    pub fn try_push(&self, job: Job) -> bool {
        match self {
            SchedQueue::Condvar(q) => q.try_push(job),
            SchedQueue::Steal(q) => q.try_push(job),
        }
    }

    /// Accepted jobs that have not yet begun executing — the admission
    /// bound's measure, and what `QueryEngine::in_flight` reports.
    pub fn pending(&self) -> usize {
        match self {
            SchedQueue::Condvar(q) => q.pending.load(Ordering::SeqCst),
            SchedQueue::Steal(q) => q.pending.load(Ordering::SeqCst),
        }
    }

    /// Total jobs ever accepted. Counted under the same lock that makes
    /// the job visible to workers, so after the workers have joined,
    /// `accepted` and the executed total agree exactly even when
    /// shutdown raced concurrent submitters (the drain guarantee is
    /// checkable, not just true).
    pub fn accepted(&self) -> u64 {
        match self {
            SchedQueue::Condvar(q) => q.accepted.load(Ordering::SeqCst),
            SchedQueue::Steal(q) => q.accepted.load(Ordering::SeqCst),
        }
    }

    /// Release `k` admission slots: the drained batch is now executing.
    pub fn begin_execute(&self, k: usize) {
        let pending = match self {
            SchedQueue::Condvar(q) => &q.pending,
            SchedQueue::Steal(q) => &q.pending,
        };
        pending.fetch_sub(k, Ordering::SeqCst);
    }

    /// Stop intake and wake every worker; queued jobs still drain.
    pub fn shutdown(&self) {
        match self {
            SchedQueue::Condvar(q) => {
                q.state.lock().unwrap().shutdown = true;
                q.not_empty.notify_all();
            }
            SchedQueue::Steal(q) => {
                q.shutdown.store(true, Ordering::SeqCst);
                let _g = q.sleep.lock().unwrap();
                q.wake.notify_all();
            }
        }
    }

    /// Block until up to `batch` jobs are available and move them into
    /// `out` (which must arrive empty). Returns whether the jobs were
    /// stolen from another worker's deque, or `None` once shutdown is
    /// flagged and every queue has drained (the worker exits).
    pub fn next_batch(
        &self,
        worker: usize,
        batch: usize,
        rng: &mut Rng,
        out: &mut Vec<Job>,
    ) -> Option<bool> {
        match self {
            SchedQueue::Condvar(q) => q.next_batch(batch, out),
            SchedQueue::Steal(q) => q.next_batch(worker, batch, rng, out),
        }
    }
}

struct CondvarState {
    jobs: Bands,
    shutdown: bool,
}

/// The original scheduler: one bounded FIFO, one lock, one condvar.
pub(crate) struct CondvarQueue {
    state: Mutex<CondvarState>,
    not_empty: Condvar,
    /// accepted jobs not yet executing (== queue length while batch=1)
    pending: AtomicUsize,
    /// total ever accepted (incremented under the state lock)
    accepted: AtomicU64,
    depth: usize,
}

impl CondvarQueue {
    fn try_push(&self, job: Job) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.shutdown || self.pending.load(Ordering::SeqCst) >= self.depth {
            return false;
        }
        st.jobs.push_back(job);
        self.pending.fetch_add(1, Ordering::SeqCst);
        self.accepted.fetch_add(1, Ordering::SeqCst);
        drop(st);
        self.not_empty.notify_one();
        true
    }

    fn next_batch(&self, batch: usize, out: &mut Vec<Job>) -> Option<bool> {
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.jobs.is_empty() {
                let k = st.jobs.len().min(batch.max(1));
                st.jobs.drain_into(k, out);
                return Some(false);
            }
            if st.shutdown {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }
}

/// The work-stealing scheduler: one deque per worker.
pub(crate) struct StealQueue {
    queues: Vec<Mutex<Bands>>,
    /// accepted jobs not yet executing (admission bound)
    pending: AtomicUsize,
    /// jobs physically sitting in deques (park / exit decisions only;
    /// the authoritative exit check re-reads the deques under lock)
    queued: AtomicUsize,
    /// total ever accepted (incremented under the target deque's lock)
    accepted: AtomicU64,
    depth: usize,
    shutdown: AtomicBool,
    /// workers currently parked (or about to park) — submitters skip
    /// the parking lot entirely while this is zero, keeping the global
    /// `sleep` lock off the submit fast path
    sleepers: AtomicUsize,
    /// parking lot: notifies are sent while holding `sleep`, so a
    /// worker that just observed `queued == 0` cannot miss its wakeup
    sleep: Mutex<()>,
    wake: Condvar,
    /// round-robin spray counter for submissions
    next: AtomicUsize,
}

impl StealQueue {
    fn try_push(&self, job: Job) -> bool {
        if self.shutdown.load(Ordering::SeqCst) {
            return false;
        }
        // reserve an admission slot without overshoot
        let mut cur = self.pending.load(Ordering::SeqCst);
        loop {
            if cur >= self.depth {
                return false;
            }
            match self.pending.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => break,
                Err(now) => cur = now,
            }
        }
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.queues.len();
        let mut q = self.queues[i].lock().unwrap();
        // re-check under the deque lock: a shutdown that lands after
        // this check cannot sneak past the workers' final locked sweep
        if self.shutdown.load(Ordering::SeqCst) {
            drop(q);
            self.pending.fetch_sub(1, Ordering::SeqCst);
            return false;
        }
        q.push_back(job);
        self.queued.fetch_add(1, Ordering::SeqCst);
        self.accepted.fetch_add(1, Ordering::SeqCst);
        drop(q);
        // wake a parked worker only if one advertised itself: the
        // common saturated case never touches the global sleep lock.
        // (SeqCst pairing with park(): if this load misses a worker's
        // sleepers increment, that worker's post-increment re-check of
        // `queued` is ordered after our push and sees the job.)
        if self.sleepers.load(Ordering::SeqCst) > 0 {
            let _g = self.sleep.lock().unwrap();
            self.wake.notify_one();
        }
        true
    }

    /// Pop up to `batch` jobs from this worker's own deque: highest
    /// band first, oldest-first within a band, so a continuously-
    /// refilled deque still serves each band's head and no same-band
    /// request waits behind a newer one.
    fn drain_local(&self, worker: usize, batch: usize, out: &mut Vec<Job>) -> usize {
        let mut q = self.queues[worker].lock().unwrap();
        let k = q.len().min(batch);
        q.drain_into(k, out);
        drop(q);
        if k > 0 {
            self.queued.fetch_sub(k, Ordering::SeqCst);
        }
        k
    }

    /// Steal from a randomized victim: up to half the victim's backlog
    /// (capped at `batch`), in the victim's own drain order (highest
    /// band first, oldest within), so a straggler's queue head is
    /// exactly what the fleet drains for it.
    fn steal(&self, worker: usize, batch: usize, rng: &mut Rng, out: &mut Vec<Job>) -> usize {
        let n = self.queues.len();
        if n <= 1 {
            return 0;
        }
        let start = rng.below(n as u64) as usize;
        for off in 0..n {
            let v = (start + off) % n;
            if v == worker {
                continue;
            }
            let mut q = self.queues[v].lock().unwrap();
            let k = q.len().div_ceil(2).min(batch);
            q.drain_into(k, out);
            drop(q);
            if k > 0 {
                self.queued.fetch_sub(k, Ordering::SeqCst);
                return k;
            }
        }
        0
    }

    /// Sleep unless work arrived (or shutdown) since the caller's last
    /// scan. Lost-wakeup safety: the worker advertises itself in
    /// `sleepers` and *then* re-checks `queued` — a submitter that read
    /// `sleepers == 0` (and so skipped the notify) must have pushed
    /// before the advertisement, so the re-check sees its job. The
    /// timeout is belt and braces only.
    fn park(&self) {
        let g = self.sleep.lock().unwrap();
        if self.queued.load(Ordering::SeqCst) > 0 || self.shutdown.load(Ordering::SeqCst) {
            return;
        }
        self.sleepers.fetch_add(1, Ordering::SeqCst);
        if self.queued.load(Ordering::SeqCst) == 0 && !self.shutdown.load(Ordering::SeqCst) {
            // the long timeout is belt and braces only — wakeups are
            // already reliable — and keeps an idle pool nearly silent
            let _ = self.wake.wait_timeout(g, Duration::from_millis(100)).unwrap();
        }
        self.sleepers.fetch_sub(1, Ordering::SeqCst);
    }

    fn next_batch(
        &self,
        worker: usize,
        batch: usize,
        rng: &mut Rng,
        out: &mut Vec<Job>,
    ) -> Option<bool> {
        let batch = batch.max(1);
        loop {
            if self.drain_local(worker, batch, out) > 0 {
                return Some(false);
            }
            if self.steal(worker, batch, rng, out) > 0 {
                return Some(true);
            }
            if self.shutdown.load(Ordering::SeqCst) {
                // authoritative drain check: confirm emptiness under
                // every deque lock. An in-flight submit that passed the
                // shutdown check holds one of these locks until its job
                // is visible, so "all empty here" means "all drained".
                if self.queues.iter().all(|q| q.lock().unwrap().is_empty()) {
                    return None;
                }
                continue;
            }
            self.park();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::query::SourceFilter;

    fn job(n: usize) -> Job {
        job_at(n, Priority::Normal)
    }

    fn job_at(n: usize, priority: Priority) -> Job {
        Job {
            query: Query::BrightestN { n, filter: SourceFilter::Any },
            priority,
            enqueued: Instant::now(),
            reply: None,
        }
    }

    fn drained_ns(out: &[Job]) -> Vec<usize> {
        out.iter()
            .map(|j| match j.query {
                Query::BrightestN { n, .. } => n,
                _ => unreachable!(),
            })
            .collect()
    }

    #[test]
    fn sched_kind_parses() {
        assert_eq!(SchedKind::parse("condvar"), Some(SchedKind::Condvar));
        assert_eq!(SchedKind::parse("steal"), Some(SchedKind::Steal));
        assert_eq!(SchedKind::parse("lifo"), None);
        assert_eq!(SchedKind::default(), SchedKind::Condvar);
        assert_eq!(SchedConfig::default().describe(), "condvar");
        assert_eq!(SchedConfig { kind: SchedKind::Steal, batch: 16 }.describe(), "steal x16");
    }

    #[test]
    fn both_queues_enforce_the_admission_bound_identically() {
        for kind in [SchedKind::Condvar, SchedKind::Steal] {
            let q = SchedQueue::new(kind, 3, 4);
            let mut ok = 0;
            for i in 0..10 {
                if q.try_push(job(i)) {
                    ok += 1;
                }
            }
            assert_eq!(ok, 4, "{kind:?}");
            assert_eq!(q.pending(), 4, "{kind:?}");
        }
    }

    #[test]
    fn shutdown_refuses_new_jobs_but_drains_old_ones() {
        for kind in [SchedKind::Condvar, SchedKind::Steal] {
            let q = SchedQueue::new(kind, 2, 1024);
            assert!(q.try_push(job(1)));
            assert!(q.try_push(job(2)));
            q.shutdown();
            assert!(!q.try_push(job(3)), "{kind:?}: intake must stop");
            // both queued jobs drain before workers are told to exit
            let mut rng = Rng::new(1);
            let mut out = Vec::new();
            let mut drained = 0;
            for w in 0..2 {
                while let Some(_stolen) = q.next_batch(w, 8, &mut rng, &mut out) {
                    drained += out.len();
                    q.begin_execute(out.len());
                    out.clear();
                    if drained >= 2 {
                        break;
                    }
                }
            }
            assert_eq!(drained, 2, "{kind:?}");
            assert_eq!(q.pending(), 0, "{kind:?}");
            // and the drained queue reports exit to every worker
            assert!(q.next_batch(0, 8, &mut rng, &mut out).is_none());
        }
    }

    #[test]
    fn local_drain_and_steal_are_both_oldest_first() {
        let q = SchedQueue::new(SchedKind::Steal, 2, 1024);
        // round-robin spray: jobs 0, 2 land on deque 0; 1, 3 on deque 1
        for i in 0..4 {
            assert!(q.try_push(job(i)));
        }
        let mut rng = Rng::new(7);
        let mut out = Vec::new();
        // worker 0 drains its own deque oldest-first (per-deque FIFO)
        let stolen = q.next_batch(0, 8, &mut rng, &mut out).unwrap();
        assert!(!stolen);
        let ns: Vec<usize> = out
            .iter()
            .map(|j| match j.query {
                Query::BrightestN { n, .. } => n,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(ns, vec![0, 2], "local drain is FIFO");
        q.begin_execute(out.len());
        out.clear();
        // worker 0 again: own deque empty, steals oldest from deque 1
        let stolen = q.next_batch(0, 1, &mut rng, &mut out).unwrap();
        assert!(stolen);
        match out[0].query {
            Query::BrightestN { n, .. } => assert_eq!(n, 1, "steal is FIFO"),
            _ => unreachable!(),
        }
        q.begin_execute(out.len());
    }

    #[test]
    fn batch_caps_the_drain() {
        let q = SchedQueue::new(SchedKind::Condvar, 1, 1024);
        for i in 0..10 {
            assert!(q.try_push(job(i)));
        }
        let mut rng = Rng::new(3);
        let mut out = Vec::new();
        q.next_batch(0, 4, &mut rng, &mut out).unwrap();
        assert_eq!(out.len(), 4);
        // batch-aware accounting: drained-but-unexecuted jobs still
        // hold their admission slots until begin_execute
        assert_eq!(q.pending(), 10);
        q.begin_execute(out.len());
        assert_eq!(q.pending(), 6);
    }

    /// Both schedulers drain highest priority band first, FIFO within a
    /// band — the drain-order half of the priority-class contract (the
    /// shed-order half lives in the graded `Admission` tests).
    #[test]
    fn drain_order_is_priority_banded_fifo() {
        for kind in [SchedKind::Condvar, SchedKind::Steal] {
            // single worker so the steal spray lands on one deque
            let q = SchedQueue::new(kind, 1, 1024);
            let arrivals = [
                (0, Priority::Low),
                (1, Priority::Normal),
                (2, Priority::High),
                (3, Priority::Normal),
                (4, Priority::High),
                (5, Priority::Low),
            ];
            for (n, p) in arrivals {
                assert!(q.try_push(job_at(n, p)));
            }
            let mut rng = Rng::new(5);
            let mut out = Vec::new();
            q.next_batch(0, 16, &mut rng, &mut out).unwrap();
            assert_eq!(
                drained_ns(&out),
                vec![2, 4, 1, 3, 0, 5],
                "{kind:?}: high first, then normal, then low; FIFO within each"
            );
            q.begin_execute(out.len());
        }
    }

    /// A stolen batch preserves the victim's drain order: the thief
    /// takes the high-priority head, not the low-priority tail.
    #[test]
    fn steals_respect_priority_order() {
        let q = SchedQueue::new(SchedKind::Steal, 2, 1024);
        // round-robin spray: jobs 0, 2 land on deque 0; 1, 3 on deque 1
        for (n, p) in [
            (0, Priority::Low),
            (1, Priority::Low),
            (2, Priority::High),
            (3, Priority::High),
        ] {
            assert!(q.try_push(job_at(n, p)));
        }
        let mut rng = Rng::new(11);
        let mut out = Vec::new();
        // drain worker 0's own deque first so its next call must steal
        let stolen = q.next_batch(0, 8, &mut rng, &mut out).unwrap();
        assert!(!stolen);
        assert_eq!(drained_ns(&out), vec![2, 0], "own deque: high before low");
        q.begin_execute(out.len());
        out.clear();
        // steal-half from deque 1 takes its high-priority head
        let stolen = q.next_batch(0, 1, &mut rng, &mut out).unwrap();
        assert!(stolen);
        assert_eq!(drained_ns(&out), vec![3], "steal takes the high-priority head");
        q.begin_execute(out.len());
    }
}
