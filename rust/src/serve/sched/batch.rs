//! Batched query execution: answer a whole drained batch in one pass
//! over the shard list.
//!
//! Per-query execution visits the shard list once per request; a batch
//! of B requests visits it B times and, on a live store, pins the
//! current epoch B times. [`execute_batch`] turns that inside out:
//! plan every query's shard set up front, then walk the shard list
//! *once*, answering every query that touches each shard while it is
//! hot, and merge per query at the end. Same-shard queries (the common
//! case under a hotspot mix) thus share one shard dispatch.
//!
//! Byte parity with [`execute`] is by construction: each query's
//! replies are produced by the same [`execute_on_shard`] in the same
//! ascending-shard order and folded by the same [`merge_replies`];
//! shards outside a query's plan contribute exactly the empty replies
//! the unbatched path would have produced and discarded.

use std::borrow::Borrow;

use crate::serve::query::{
    execute, execute_on_shard, merge_replies, plan_shards, Query, QueryResult, ShardReply,
};
use crate::serve::store::Store;

/// Plan a whole batch at once: for each shard, the input indices of
/// the queries whose plan includes it (input order within a shard,
/// ascending shards by position). This is the single copy of batch
/// planning — the in-process [`execute_batch`] below and the net
/// tier's request coalescing (same-shard sub-queries from one batch
/// become one framed request) both group work through it, which is
/// what makes their answer order, and therefore their bytes, agree.
pub fn plan_batch<Q: Borrow<Query>>(store: &Store, queries: &[Q]) -> Vec<Vec<usize>> {
    let mut by_shard: Vec<Vec<usize>> = vec![Vec::new(); store.shards.len()];
    for (qi, q) in queries.iter().enumerate() {
        for s in plan_shards(store, q.borrow()) {
            by_shard[s].push(qi);
        }
    }
    by_shard
}

/// Execute `queries` against the store, grouping per-shard work so the
/// shard list is walked once per batch. Results are returned in input
/// order and are byte-identical to per-query [`execute`]. Generic over
/// `Borrow<Query>` so the worker loop can pass borrowed queries
/// (`&[&Query]`) without cloning on the hot path.
pub fn execute_batch<Q: Borrow<Query>>(store: &Store, queries: &[Q]) -> Vec<QueryResult> {
    if queries.len() <= 1 {
        return queries.iter().map(|q| execute(store, q.borrow())).collect();
    }
    let by_shard = plan_batch(store, queries);
    let mut replies: Vec<Vec<ShardReply>> =
        (0..queries.len()).map(|_| Vec::new()).collect();
    // one pass over the shards: each shard answers every query that
    // planned it, in ascending shard order (the merge's canonical order)
    for (s, qis) in by_shard.iter().enumerate() {
        if qis.is_empty() {
            continue;
        }
        let shard = &store.shards[s];
        for &qi in qis {
            let reply = execute_on_shard(shard, queries[qi].borrow());
            replies[qi].push(reply);
        }
    }
    queries
        .iter()
        .zip(replies)
        .map(|(q, r)| merge_replies(q.borrow(), r))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;
    use crate::serve::loadgen::fuzz_query;
    use crate::serve::query::SourceFilter;

    fn test_store(n: usize, shards: usize, seed: u64) -> Store {
        let snap = crate::serve::snapshot::synthetic(n, seed);
        Store::build(snap.sources, snap.width, snap.height, shards)
    }

    #[test]
    fn batched_execution_matches_per_query_execution() {
        let store = test_store(1200, 9, 51);
        let (w, h) = (store.width, store.height);
        let mut rng = Rng::new(23);
        for batch_size in [2usize, 3, 16, 40] {
            let queries: Vec<Query> =
                (0..batch_size).map(|i| fuzz_query(&mut rng, w, h, i)).collect();
            let got = execute_batch(&store, &queries);
            assert_eq!(got.len(), queries.len());
            for (q, g) in queries.iter().zip(&got) {
                assert_eq!(g, &execute(&store, q), "batch {batch_size}: {q:?}");
            }
        }
    }

    #[test]
    fn duplicate_queries_in_one_batch_agree() {
        let store = test_store(400, 4, 9);
        let q = Query::Cone {
            center: (store.width * 0.4, store.height * 0.6),
            radius: 55.0,
            filter: SourceFilter::Any,
        };
        let queries = [q.clone(), q.clone(), q.clone()];
        let got = execute_batch(&store, &queries);
        let want = execute(&store, &q);
        for g in &got {
            assert_eq!(g, &want);
        }
    }

    #[test]
    fn empty_and_singleton_batches() {
        let store = test_store(100, 3, 2);
        let empty: [Query; 0] = [];
        assert!(execute_batch(&store, &empty).is_empty());
        let q = Query::BrightestN { n: 5, filter: SourceFilter::Any };
        let got = execute_batch(&store, std::slice::from_ref(&q));
        assert_eq!(got, vec![execute(&store, &q)]);
        // borrowed-query form answers identically (the worker's path)
        let refs = [&q];
        assert_eq!(execute_batch(&store, &refs), got);
    }
}
