//! Synthetic catalog drift: the write-side load generator.
//!
//! As a survey keeps imaging, the catalog drifts — fresh detections
//! appear and known sources get re-estimated (position/flux posterior
//! updates). [`DriftGen`] produces deterministic delta batches with
//! that shape, and maintains a flat last-write-wins mirror of every
//! row it ever emitted: the brute-force reference the parity tests
//! compare the ingested store against. [`IngestDriver`] turns the
//! stream into Poisson-timed publishes through an [`Ingestor`], for
//! the mixed read/write scenarios of `serve-bench --ingest-qps` and
//! `bench_serve`.

use std::collections::HashMap;

use crate::prng::Rng;
use crate::serve::store::ServedSource;

use super::ingestor::{IngestReport, Ingestor};

/// Shape of one drift stream.
#[derive(Clone, Debug)]
pub struct DriftConfig {
    /// upserts per batch
    pub batch: usize,
    /// fraction of upserts that re-estimate an existing source (the
    /// rest are fresh detections)
    pub update_fraction: f64,
    /// position jitter SD applied by a re-estimate, px
    pub pos_jitter: f64,
    /// relative flux jitter SD applied by a re-estimate
    pub flux_jitter: f64,
    /// fraction of fresh detections drawn from a tight hotspot blob
    /// instead of uniformly (0.0 = uniform sky). Sustained values near
    /// 1.0 skew per-shard row counts — the compaction trigger's diet.
    pub hotspot: f64,
    pub seed: u64,
}

impl Default for DriftConfig {
    fn default() -> Self {
        DriftConfig {
            batch: 32,
            update_fraction: 0.5,
            pos_jitter: 1.5,
            flux_jitter: 0.05,
            hotspot: 0.0,
            seed: 42,
        }
    }
}

/// Deterministic delta-batch stream over a sky extent.
pub struct DriftGen {
    cfg: DriftConfig,
    rng: Rng,
    width: f64,
    height: f64,
    /// flat last-write-wins view of the catalog (seed + every delta)
    mirror: Vec<ServedSource>,
    index: HashMap<usize, usize>,
    next_id: usize,
}

impl DriftGen {
    /// Start drifting from a seed catalog (the flat view of the store
    /// being served). Fresh detections get ids above every seed id.
    pub fn new(
        seed_sources: &[ServedSource],
        width: f64,
        height: f64,
        cfg: DriftConfig,
    ) -> DriftGen {
        let mirror: Vec<ServedSource> = seed_sources.to_vec();
        let index = mirror.iter().enumerate().map(|(i, s)| (s.id, i)).collect();
        let next_id = mirror.iter().map(|s| s.id + 1).max().unwrap_or(0);
        let rng = Rng::new(cfg.seed ^ 0xd21f7);
        DriftGen { cfg, rng, width, height, mirror, index, next_id }
    }

    /// The flat catalog after every batch emitted so far — the
    /// brute-force reference for ingestion parity tests.
    pub fn mirror(&self) -> &[ServedSource] {
        &self.mirror
    }

    /// The mirror in canonical id order.
    pub fn mirror_sorted(&self) -> Vec<ServedSource> {
        let mut out = self.mirror.clone();
        out.sort_by_key(|s| s.id);
        out
    }

    fn fresh_detection(&mut self) -> ServedSource {
        let id = self.next_id;
        self.next_id += 1;
        // a transient alert region: a fixed blob at quarter-sky whose
        // spread is ~2% of the extent, hit by `hotspot` of detections
        let pos = if self.cfg.hotspot > 0.0 && self.rng.uniform() < self.cfg.hotspot {
            (
                (self.width * 0.25 + self.rng.normal() * self.width * 0.02)
                    .clamp(0.0, self.width),
                (self.height * 0.25 + self.rng.normal() * self.height * 0.02)
                    .clamp(0.0, self.height),
            )
        } else {
            (
                self.rng.uniform_in(0.0, self.width),
                self.rng.uniform_in(0.0, self.height),
            )
        };
        ServedSource {
            id,
            pos,
            p_gal: self.rng.uniform(),
            flux_r: self.rng.lognormal(4.0, 1.2),
            flux_logsd: self.rng.uniform_in(0.05, 0.5),
            colors: [
                self.rng.normal(),
                self.rng.normal(),
                self.rng.normal(),
                self.rng.normal(),
            ],
            converged: self.rng.uniform() < 0.9,
        }
    }

    fn re_estimate(&mut self) -> ServedSource {
        let k = self.rng.below(self.mirror.len() as u64) as usize;
        let mut s = self.mirror[k].clone();
        s.pos.0 = (s.pos.0 + self.rng.normal() * self.cfg.pos_jitter).clamp(0.0, self.width);
        s.pos.1 = (s.pos.1 + self.rng.normal() * self.cfg.pos_jitter).clamp(0.0, self.height);
        s.flux_r = (s.flux_r * (1.0 + self.rng.normal() * self.cfg.flux_jitter)).max(1e-6);
        // later epochs tighten the posterior, as more exposures would
        s.flux_logsd = (s.flux_logsd * 0.98).max(1e-3);
        s
    }

    /// Emit the next delta batch and fold it into the mirror.
    pub fn next_batch(&mut self) -> Vec<ServedSource> {
        let mut out = Vec::with_capacity(self.cfg.batch);
        for _ in 0..self.cfg.batch.max(1) {
            let update = !self.mirror.is_empty()
                && self.rng.uniform() < self.cfg.update_fraction;
            let s = if update { self.re_estimate() } else { self.fresh_detection() };
            match self.index.get(&s.id) {
                Some(&i) => self.mirror[i] = s.clone(),
                None => {
                    self.index.insert(s.id, self.mirror.len());
                    self.mirror.push(s.clone());
                }
            }
            out.push(s);
        }
        out
    }
}

/// Poisson-timed ingestion: drift batches applied through an
/// [`Ingestor`] at an offered publish rate, consumed by the mixed
/// read/write drivers (`drive_open_loop_with` ticks it with every
/// arrival time).
pub struct IngestDriver {
    ingestor: Ingestor,
    drift: DriftGen,
    rng: Rng,
    rate: f64,
    next_at: f64,
    /// publishes applied so far
    pub publishes: u64,
    /// upsert rows applied so far
    pub rows: u64,
    /// when tracking: (epoch, catalog checksum of the mirror at that
    /// epoch) — what a crashed replica must hash to after recovery
    epoch_checksums: Option<Vec<(u64, u64)>>,
}

impl IngestDriver {
    /// `rate` is publishes per second on the driving clock (simulated
    /// or wall); the first publish arrives after one exponential gap.
    pub fn new(ingestor: Ingestor, drift: DriftGen, rate: f64, seed: u64) -> IngestDriver {
        let mut rng = Rng::new(seed ^ 0x1276e57);
        let rate = rate.max(1e-9);
        let first = -rng.uniform().max(1e-12).ln() / rate;
        IngestDriver {
            ingestor,
            drift,
            rng,
            rate,
            next_at: first,
            publishes: 0,
            rows: 0,
            epoch_checksums: None,
        }
    }

    /// Record the mirror's [`catalog_checksum`] after every publish
    /// (and for the seed epoch now), so crash recovery can verify
    /// byte parity at *whatever* epoch a replica recovered to.
    ///
    /// [`catalog_checksum`]: crate::serve::durable::catalog_checksum
    pub fn track_checksums(&mut self) {
        let seed_sum = crate::serve::durable::catalog_checksum(self.drift.mirror());
        let start = self.ingestor.versioned().epoch();
        self.epoch_checksums = Some(vec![(start, seed_sum)]);
    }

    /// The mirror's checksum at `epoch`, when tracked.
    pub fn checksum_at(&self, epoch: u64) -> Option<u64> {
        self.epoch_checksums
            .as_ref()?
            .iter()
            .find(|(e, _)| *e == epoch)
            .map(|(_, sum)| *sum)
    }

    /// Apply every publish due at or before `now`; returns their
    /// reports (callers forward them to replicated tiers).
    pub fn tick(&mut self, now: f64) -> Vec<IngestReport> {
        let mut out = Vec::new();
        while self.next_at <= now {
            let batch = self.drift.next_batch();
            let rep = self.ingestor.apply(&batch);
            self.publishes += 1;
            self.rows += rep.upserts as u64;
            if let Some(sums) = self.epoch_checksums.as_mut() {
                sums.push((
                    rep.epoch,
                    crate::serve::durable::catalog_checksum(self.drift.mirror()),
                ));
            }
            out.push(rep);
            self.next_at += -self.rng.uniform().max(1e-12).ln() / self.rate;
        }
        out
    }

    /// The drift stream's flat reference catalog, id-ordered.
    pub fn mirror_sorted(&self) -> Vec<ServedSource> {
        self.drift.mirror_sorted()
    }

    pub fn ingestor(&self) -> &Ingestor {
        &self.ingestor
    }

    /// Mutable access for maintenance operations that publish through
    /// the same single-writer seam (compaction).
    pub fn ingestor_mut(&mut self) -> &mut Ingestor {
        &mut self.ingestor
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    use crate::serve::ingest::VersionedStore;
    use crate::serve::query::{execute, execute_scan, Query, SourceFilter};
    use crate::serve::store::Store;

    #[test]
    fn drift_batches_are_deterministic_and_mix_updates_with_inserts() {
        let snap = crate::serve::snapshot::synthetic(300, 5);
        let mk = || {
            DriftGen::new(
                &snap.sources,
                snap.width,
                snap.height,
                DriftConfig { batch: 50, seed: 9, ..Default::default() },
            )
        };
        let (mut a, mut b) = (mk(), mk());
        let (mut updates, mut inserts) = (0usize, 0usize);
        for _ in 0..10 {
            let ba = a.next_batch();
            assert_eq!(ba, b.next_batch(), "same seed, same stream");
            for s in &ba {
                if s.id < 300 {
                    updates += 1;
                } else {
                    inserts += 1;
                }
            }
        }
        assert!(updates > 50, "updates {updates}");
        assert!(inserts > 50, "inserts {inserts}");
        assert_eq!(a.mirror().len(), 300 + inserts);
    }

    #[test]
    fn driver_applies_due_batches_and_store_tracks_mirror() {
        let snap = crate::serve::snapshot::synthetic(400, 11);
        let (w, h) = (snap.width, snap.height);
        let store = Arc::new(Store::build(snap.sources.clone(), w, h, 6));
        let vs = Arc::new(VersionedStore::new(store));
        let drift_cfg = DriftConfig { batch: 25, seed: 3, ..Default::default() };
        let drift = DriftGen::new(&snap.sources, w, h, drift_cfg);
        let mut driver = IngestDriver::new(Ingestor::new(Arc::clone(&vs)), drift, 100.0, 3);
        assert!(driver.tick(0.0).is_empty() || driver.publishes > 0);
        let mut t = 0.0;
        while t < 0.5 {
            driver.tick(t);
            t += 0.01;
        }
        assert!(driver.publishes > 20, "publishes {}", driver.publishes);
        assert_eq!(driver.rows, driver.publishes * 25);
        let mirror = driver.mirror_sorted();
        let fin = vs.load();
        assert_eq!(fin.epoch, driver.publishes);
        assert_eq!(fin.store.all_sources(), mirror);
        let q = Query::BrightestN { n: 30, filter: SourceFilter::Any };
        assert_eq!(execute(&fin.store, &q), execute_scan(&mirror, &q));
    }
}
