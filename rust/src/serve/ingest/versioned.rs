//! Epoch-stamped store versions behind an arc-swap-style pointer flip.
//!
//! The write path never mutates a published [`Store`]: each publish
//! installs a fresh [`EpochStore`] whose untouched shards are shared
//! (`Arc`) with the prior epoch. Readers pin an epoch by cloning the
//! current `Arc` — a lock held only for the pointer copy, never across
//! a query — and an old epoch stays fully valid until its last reader
//! drops the `Arc` (no reader ever observes a half-applied batch).

use std::sync::{Arc, Mutex};

use super::super::durable::{DurableLog, WalOp};
use super::super::store::Store;

/// One immutable published version of the catalog.
#[derive(Clone, Debug)]
pub struct EpochStore {
    /// global publication number (0 = the seed store)
    pub epoch: u64,
    /// per shard: the epoch that last mutated it (0 = seed content).
    /// The result cache and the replica router compare these stamps to
    /// decide which cached entries / lagging replicas are still exact.
    pub shard_epochs: Vec<u64>,
    pub store: Arc<Store>,
}

impl EpochStore {
    /// Wrap a freshly built store as epoch 0.
    pub fn initial(store: Arc<Store>) -> EpochStore {
        let n = store.shards.len();
        EpochStore { epoch: 0, shard_epochs: vec![0; n], store }
    }

    /// Epoch stamps of a subset of shards, ascending by shard index —
    /// the coverage vector cache entries are keyed by.
    pub fn coverage_of(&self, shards: &[usize]) -> Vec<(u32, u64)> {
        shards.iter().map(|&s| (s as u32, self.shard_epochs[s])).collect()
    }
}

/// The mutable head pointer over immutable [`EpochStore`] versions.
///
/// `load` is the whole read-side protocol: clone the current `Arc` and
/// query it for as long as you like. `publish` is the whole write-side
/// protocol: flip the pointer to a strictly newer epoch.
///
/// With a [`DurableLog`] attached, the publish protocol tightens: the
/// WAL record is appended and fsynced *under the head lock, before the
/// pointer flips* — no reader (and no Publish ack) ever observes an
/// epoch that is not already durable, and the log order is exactly the
/// publish order.
pub struct VersionedStore {
    current: Mutex<Arc<EpochStore>>,
    wal: Mutex<Option<Arc<DurableLog>>>,
}

impl VersionedStore {
    pub fn new(store: Arc<Store>) -> VersionedStore {
        Self::from_head(Arc::new(EpochStore::initial(store)))
    }

    /// Resume from an already-built head (crash recovery installs the
    /// checkpoint-plus-replay result here, at its recovered epoch).
    pub fn from_head(head: Arc<EpochStore>) -> VersionedStore {
        VersionedStore { current: Mutex::new(head), wal: Mutex::new(None) }
    }

    /// Make every subsequent publish durable: appended to `log` and
    /// fsynced before it becomes visible. Publishers must then use
    /// [`VersionedStore::publish_logged`] (the ingest path does).
    pub fn attach_wal(&self, log: Arc<DurableLog>) {
        *self.wal.lock().unwrap() = Some(log);
    }

    /// The attached durable log, if any.
    pub fn wal(&self) -> Option<Arc<DurableLog>> {
        self.wal.lock().unwrap().clone()
    }

    /// Pin the current epoch (cheap: one lock for one pointer clone).
    pub fn load(&self) -> Arc<EpochStore> {
        Arc::clone(&self.current.lock().unwrap())
    }

    /// Atomically install a newer epoch. Concurrent readers keep the
    /// epochs they already pinned; new loads see `next`.
    ///
    /// Only for stores without a WAL (mirrors, replicas, tests): a
    /// durable store must describe what it publishes, so the log can
    /// replay it — use [`VersionedStore::publish_logged`].
    pub fn publish(&self, next: Arc<EpochStore>) {
        self.publish_inner(next, None);
    }

    /// Install a newer epoch durably: append `op` to the attached WAL
    /// and fsync before the flip. Without an attached log this is
    /// exactly [`VersionedStore::publish`].
    pub fn publish_logged(&self, next: Arc<EpochStore>, op: WalOp<'_>) {
        self.publish_inner(next, Some(op));
    }

    fn publish_inner(&self, next: Arc<EpochStore>, op: Option<WalOp<'_>>) {
        let mut cur = self.current.lock().unwrap();
        assert!(
            next.epoch > cur.epoch,
            "publish must advance the epoch ({} -> {})",
            cur.epoch,
            next.epoch
        );
        if let Some(log) = self.wal.lock().unwrap().as_ref() {
            let op = op.expect(
                "a WAL-attached store must publish through publish_logged \
                 so the epoch can be replayed",
            );
            // a WAL the store cannot append to is a store that must not
            // accept publishes: fail loudly rather than diverge from
            // what recovery will reconstruct
            log.append(&next, &op).expect("WAL append+fsync failed");
        }
        *cur = next;
    }

    /// The current global epoch.
    pub fn epoch(&self) -> u64 {
        self.current.lock().unwrap().epoch
    }
}

/// Where an engine tier reads its catalog from: a fixed store (the
/// pre-ingestion world, still the default everywhere) or the live head
/// of a [`VersionedStore`] — loaded per request, so concurrent readers
/// pick up a publish at their next query without coordination.
#[derive(Clone)]
pub enum StoreSource {
    Fixed(Arc<Store>),
    Live(Arc<VersionedStore>),
}

impl StoreSource {
    /// The store to execute the next query against.
    pub fn current(&self) -> Arc<Store> {
        match self {
            StoreSource::Fixed(s) => Arc::clone(s),
            StoreSource::Live(v) => Arc::clone(&v.load().store),
        }
    }

    /// The current epoch view (`None` for a fixed store: static tiers
    /// have no version to be stale against).
    pub fn view(&self) -> Option<Arc<EpochStore>> {
        match self {
            StoreSource::Fixed(_) => None,
            StoreSource::Live(v) => Some(v.load()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_store() -> Arc<Store> {
        let snap = crate::serve::snapshot::synthetic(50, 7);
        Arc::new(Store::build(snap.sources, snap.width, snap.height, 4))
    }

    #[test]
    fn load_pins_and_publish_flips() {
        let vs = VersionedStore::new(tiny_store());
        let pinned = vs.load();
        assert_eq!(pinned.epoch, 0);
        let mut next = (*pinned).clone();
        next.epoch = 1;
        next.shard_epochs[2] = 1;
        vs.publish(Arc::new(next));
        assert_eq!(vs.epoch(), 1);
        assert_eq!(vs.load().shard_epochs[2], 1);
        // the pinned reader still sees epoch 0 exactly
        assert_eq!(pinned.epoch, 0);
        assert_eq!(pinned.shard_epochs[2], 0);
    }

    #[test]
    #[should_panic(expected = "advance the epoch")]
    fn publish_must_be_monotonic() {
        let vs = VersionedStore::new(tiny_store());
        let same = vs.load();
        vs.publish(same);
    }

    #[test]
    fn coverage_reads_the_requested_shards() {
        let vs = VersionedStore::new(tiny_store());
        let mut e = (*vs.load()).clone();
        e.epoch = 3;
        e.shard_epochs = vec![0, 3, 1, 0];
        assert_eq!(e.coverage_of(&[1, 3]), vec![(1, 3), (3, 0)]);
    }
}
