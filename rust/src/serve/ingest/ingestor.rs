//! The write path: delta batches become copy-on-write epoch publishes.
//!
//! An [`Ingestor`] accepts batches of [`ServedSource`] upserts (fresh
//! detections as imaging proceeds, or re-estimates of known sources —
//! last write wins within a batch), routes each row to the shard owning
//! its Hilbert key, rebuilds *only* the touched shards (sources plus
//! grid index), and publishes the result as the next epoch through the
//! [`VersionedStore`]. Untouched shards are shared with the prior epoch
//! by `Arc`, so publish cost scales with the delta, not the catalog.

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

use crate::serve::durable::{compact, CompactionReport, WalOp};
use crate::serve::store::{ServedSource, Shard, Store};

use super::versioned::{EpochStore, VersionedStore};

/// What one [`Ingestor::apply`] publish did — the router's delta
/// shipping and the bench's accounting both read it.
#[derive(Clone, Debug)]
pub struct IngestReport {
    /// the epoch this batch published
    pub epoch: u64,
    /// touched shards with the delta rows each must ship to its
    /// replicas (upserts landing in the shard + tombstones leaving it)
    pub touched: Vec<(usize, usize)>,
    /// rows in the batch after intra-batch dedup
    pub upserts: usize,
    pub inserted: usize,
    pub updated: usize,
    /// updates whose new position moved them to a different shard
    pub moved: usize,
    /// the published version (hand this to `RouterEngine::publish` to
    /// ship the delta to a replicated tier)
    pub published: Arc<EpochStore>,
    /// the batch after intra-batch dedup, id-ascending — exactly the
    /// rows a remote replica must `apply` to reproduce this epoch
    /// byte-identically (the net tier ships these over the wire)
    pub deltas: Vec<ServedSource>,
}

/// The single-writer ingestion front-end over a [`VersionedStore`].
pub struct Ingestor {
    versioned: Arc<VersionedStore>,
    /// id -> owning shard at the current epoch (kept incrementally so
    /// moves know which shard to tombstone)
    id_to_shard: HashMap<usize, usize>,
}

impl Ingestor {
    pub fn new(versioned: Arc<VersionedStore>) -> Ingestor {
        let cur = versioned.load();
        let mut id_to_shard = HashMap::new();
        for (i, sh) in cur.store.shards.iter().enumerate() {
            for s in &sh.sources {
                id_to_shard.insert(s.id, i);
            }
        }
        Ingestor { versioned, id_to_shard }
    }

    /// Shared access to the store this ingestor publishes into.
    pub fn versioned(&self) -> &Arc<VersionedStore> {
        &self.versioned
    }

    /// Apply one delta batch and publish it as the next epoch. Returns
    /// the report; readers pick the new epoch up on their next load.
    pub fn apply(&mut self, deltas: &[ServedSource]) -> IngestReport {
        let cur = self.versioned.load();
        let store = &cur.store;
        // last write wins within a batch
        let mut batch: BTreeMap<usize, ServedSource> = BTreeMap::new();
        for d in deltas {
            batch.insert(d.id, d.clone());
        }
        let mut inserts: BTreeMap<usize, Vec<ServedSource>> = BTreeMap::new();
        let mut tombstones: BTreeMap<usize, usize> = BTreeMap::new();
        let (mut inserted, mut updated, mut moved) = (0usize, 0usize, 0usize);
        for (id, d) in &batch {
            let key = store.sky_key(d.pos);
            // an all-empty seed store owns no keys yet: open shard 0
            let target = store.shard_for_key(key).unwrap_or(0);
            match self.id_to_shard.get(id).copied() {
                Some(old) if old == target => updated += 1,
                Some(old) => {
                    moved += 1;
                    *tombstones.entry(old).or_insert(0) += 1;
                }
                None => inserted += 1,
            }
            inserts.entry(target).or_default().push(d.clone());
            self.id_to_shard.insert(*id, target);
        }
        let mut touched: BTreeMap<usize, usize> = BTreeMap::new();
        for (&s, rows) in &inserts {
            *touched.entry(s).or_insert(0) += rows.len();
        }
        for (&s, &rows) in &tombstones {
            *touched.entry(s).or_insert(0) += rows;
        }

        let epoch = cur.epoch + 1;
        let shards: Vec<Arc<Shard>> = store
            .shards
            .iter()
            .enumerate()
            .map(|(i, sh)| {
                if !touched.contains_key(&i) {
                    // copy-on-write: the untouched shard (sources and
                    // grid index) is shared with the prior epoch
                    return Arc::clone(sh);
                }
                // drop every old row the batch re-wrote or moved away,
                // then append the rows that land here
                let mut sources: Vec<ServedSource> = sh
                    .sources
                    .iter()
                    .filter(|s| !batch.contains_key(&s.id))
                    .cloned()
                    .collect();
                if let Some(rows) = inserts.get(&i) {
                    sources.extend(rows.iter().cloned());
                }
                sources.sort_by_cached_key(|s| (store.sky_key(s.pos), s.id));
                let (key_lo, key_hi) = if sources.is_empty() {
                    // emptied shard: keep its old (now unowned) range
                    (sh.key_lo, sh.key_hi)
                } else {
                    (
                        store.sky_key(sources[0].pos),
                        store.sky_key(sources[sources.len() - 1].pos),
                    )
                };
                Arc::new(Shard::build(sources, key_lo, key_hi))
            })
            .collect();
        let mut shard_epochs = cur.shard_epochs.clone();
        for &s in touched.keys() {
            shard_epochs[s] = epoch;
        }
        let published = Arc::new(EpochStore {
            epoch,
            shard_epochs,
            store: Arc::new(Store { shards, width: store.width, height: store.height }),
        });
        // the deduped delta rows are both the report's replication
        // payload and the WAL record: one definition of "what this
        // epoch changed", byte-identical on disk and on the wire
        let deltas: Vec<ServedSource> = batch.into_values().collect();
        self.versioned
            .publish_logged(Arc::clone(&published), WalOp::Publish { rows: &deltas });
        IngestReport {
            epoch,
            touched: touched.into_iter().collect(),
            upserts: deltas.len(),
            inserted,
            updated,
            moved,
            published,
            deltas,
        }
    }

    /// Re-split hot Hilbert ranges when row counts have skewed (see
    /// [`crate::serve::durable::compact`]) and publish the new layout
    /// as the next epoch. Returns `None` when nothing qualifies.
    ///
    /// The WAL records only `(epoch, threshold)`: the re-split is a
    /// deterministic function of the prior epoch's store, so replay
    /// re-derives the identical layout.
    pub fn compact(&mut self, threshold: f64) -> Option<CompactionReport> {
        let cur = self.versioned.load();
        let store = &cur.store;
        let skew_before = compact::skew(store);
        let re = compact::resplit_hot(store, threshold)?;
        let epoch = cur.epoch + 1;
        // stamp conservatively: a shard keeps its cache stamp only if
        // the same index still holds the same (Arc-shared) content —
        // an index shift would otherwise let stale cache entries match
        let shard_epochs: Vec<u64> = re
            .shards
            .iter()
            .enumerate()
            .map(|(i, sh)| {
                if Arc::ptr_eq(sh, &store.shards[i]) {
                    cur.shard_epochs[i]
                } else {
                    epoch
                }
            })
            .collect();
        let next_store =
            Arc::new(Store { shards: re.shards, width: store.width, height: store.height });
        let skew_after = compact::skew(&next_store);
        let published = Arc::new(EpochStore { epoch, shard_epochs, store: next_store });
        self.versioned
            .publish_logged(Arc::clone(&published), WalOp::Compact { threshold });
        // ranges moved wholesale: rebuild the id routing table
        self.id_to_shard.clear();
        for (idx, sh) in published.store.shards.iter().enumerate() {
            for s in &sh.sources {
                self.id_to_shard.insert(s.id, idx);
            }
        }
        Some(CompactionReport {
            epoch,
            splits: re.splits,
            merges: re.merges,
            absorbed: re.absorbed,
            rows_resharded: re.rows_resharded,
            skew_before,
            skew_after,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::query::{execute, execute_scan, Query, SourceFilter};

    fn seed(n: usize, shards: usize) -> (Arc<VersionedStore>, Vec<ServedSource>) {
        let snap = crate::serve::snapshot::synthetic(n, 31);
        let flat = snap.sources.clone();
        let store = Arc::new(Store::build(snap.sources, snap.width, snap.height, shards));
        (Arc::new(VersionedStore::new(store)), flat)
    }

    #[test]
    fn publish_rebuilds_only_touched_shards() {
        let (vs, flat) = seed(800, 8);
        let before = vs.load();
        let mut ing = Ingestor::new(Arc::clone(&vs));
        // update one existing source in place (same position => same shard)
        let delta = vec![ServedSource { flux_r: flat[0].flux_r * 2.0, ..flat[0].clone() }];
        let rep = ing.apply(&delta);
        assert_eq!(rep.epoch, 1);
        assert_eq!(rep.upserts, 1);
        assert_eq!(rep.updated, 1);
        assert_eq!(rep.touched.len(), 1);
        let after = vs.load();
        let touched = rep.touched[0].0;
        for i in 0..8 {
            let shared = Arc::ptr_eq(&before.store.shards[i], &after.store.shards[i]);
            assert_eq!(shared, i != touched, "shard {i}");
            assert_eq!(after.shard_epochs[i], if i == touched { 1 } else { 0 });
        }
        assert_eq!(after.store.len(), 800, "an update must not change the count");
    }

    #[test]
    fn inserts_updates_and_moves_match_a_flat_mirror() {
        let (vs, mut mirror) = seed(500, 6);
        let (w, h) = {
            let s = vs.load();
            (s.store.width, s.store.height)
        };
        let mut ing = Ingestor::new(Arc::clone(&vs));
        let mut rng = crate::prng::Rng::new(91);
        for round in 0..10 {
            let mut deltas = Vec::new();
            for j in 0..40 {
                if j % 3 == 0 || mirror.is_empty() {
                    // fresh detection
                    deltas.push(ServedSource {
                        id: 100_000 + round * 100 + j,
                        pos: (rng.uniform_in(0.0, w), rng.uniform_in(0.0, h)),
                        p_gal: rng.uniform(),
                        flux_r: rng.lognormal(4.0, 1.0),
                        flux_logsd: rng.uniform_in(0.01, 0.6),
                        colors: [0.1, 0.2, 0.3, 0.4],
                        converged: true,
                    });
                } else {
                    // re-estimate of a known source, possibly moving it
                    let k = rng.below(mirror.len() as u64) as usize;
                    let mut s = mirror[k].clone();
                    s.pos = (rng.uniform_in(0.0, w), rng.uniform_in(0.0, h));
                    s.flux_r *= 1.0 + 0.1 * rng.normal();
                    deltas.push(s);
                }
            }
            // mirror applies the same last-write-wins upserts
            for d in &deltas {
                match mirror.iter_mut().find(|s| s.id == d.id) {
                    Some(slot) => *slot = d.clone(),
                    None => mirror.push(d.clone()),
                }
            }
            let rep = ing.apply(&deltas);
            assert_eq!(rep.epoch, round as u64 + 1);
            assert!(rep.inserted + rep.updated + rep.moved >= 1);
        }
        mirror.sort_by_key(|s| s.id);
        let fin = vs.load();
        assert_eq!(fin.store.all_sources(), mirror, "store must equal the mirror");
        // and queries over the ingested store equal brute force
        let q =
            Query::Cone { center: (w * 0.5, h * 0.5), radius: 150.0, filter: SourceFilter::Any };
        assert_eq!(execute(&fin.store, &q), execute_scan(&mirror, &q));
        let q2 = Query::BrightestN { n: 40, filter: SourceFilter::Any };
        assert_eq!(execute(&fin.store, &q2), execute_scan(&mirror, &q2));
    }

    #[test]
    fn shard_ranges_stay_disjoint_across_epochs() {
        let (vs, _) = seed(400, 5);
        let (w, h) = {
            let s = vs.load();
            (s.store.width, s.store.height)
        };
        let mut ing = Ingestor::new(Arc::clone(&vs));
        let mut rng = crate::prng::Rng::new(13);
        for round in 0..6 {
            let deltas: Vec<ServedSource> = (0..30)
                .map(|j| ServedSource {
                    id: 50_000 + round * 50 + j,
                    pos: (rng.uniform_in(0.0, w), rng.uniform_in(0.0, h)),
                    p_gal: 0.3,
                    flux_r: 50.0,
                    flux_logsd: 0.1,
                    colors: [0.0; 4],
                    converged: true,
                })
                .collect();
            ing.apply(&deltas);
            let store = vs.load().store.clone();
            let nonempty: Vec<usize> = (0..store.shards.len())
                .filter(|&i| !store.shards[i].sources.is_empty())
                .collect();
            for w2 in nonempty.windows(2) {
                let (a, b) = (&store.shards[w2[0]], &store.shards[w2[1]]);
                assert!(a.key_hi < b.key_lo, "ranges overlap after round {round}");
            }
            for &i in &nonempty {
                let sh = &store.shards[i];
                for s in &sh.sources {
                    let k = store.sky_key(s.pos);
                    assert!(k >= sh.key_lo && k <= sh.key_hi);
                }
            }
        }
    }
}
