//! Live catalog ingestion: the write path for the serving stack.
//!
//! The paper's pipeline ends at a static catalog, but the survey it
//! serves keeps producing detections as imaging proceeds — a
//! production tier must absorb deltas while queries are in flight.
//! This module makes the read-only store writable without ever making
//! it mutable:
//!
//! * [`versioned`] — [`EpochStore`] (an epoch-stamped immutable store
//!   version with per-shard mutation stamps) behind a [`VersionedStore`]
//!   pointer flip: readers pin an epoch with one `Arc` clone, writers
//!   publish strictly newer epochs, old epochs stay valid until their
//!   last reader drains.
//! * [`ingestor`] — [`Ingestor`] turns delta batches into copy-on-write
//!   publishes: only the shards owning touched Hilbert ranges are
//!   rebuilt (rows + grid index); everything else is shared by `Arc`.
//! * [`drift`] — [`DriftGen`] synthesizes survey drift (fresh
//!   detections + posterior re-estimates) and keeps the flat
//!   last-write-wins mirror the parity tests compare against;
//!   [`IngestDriver`] paces publishes Poisson-style for the mixed
//!   read/write bench scenarios.
//!
//! Version awareness threads through the rest of the serving stack:
//! `Cached` keys entries by shard-epoch coverage and invalidates only
//! mutated ranges, `Consistency::AtMost(k)` bounds staleness, and the
//! distributed router ships deltas over the fabric and refuses
//! replicas that lag a fresh/bounded read (see `serve::dist`).

pub mod drift;
pub mod ingestor;
pub mod versioned;

pub use drift::{DriftConfig, DriftGen, IngestDriver};
pub use ingestor::{IngestReport, Ingestor};
pub use versioned::{EpochStore, StoreSource, VersionedStore};
