//! One load driver for every tier.
//!
//! Before the engine API there were three drivers: a wall-clock
//! open-loop, a wall-clock closed-loop (both in `loadgen`), and a
//! simulated-time open-loop welded to the distributed router. The only
//! real difference between the wall and simulated variants was the
//! clock, so the clock is now a trait: [`WallClock`] sleeps to the next
//! arrival, [`SimClock`] jumps to it. Both drivers are generic over
//! [`QueryEngine`], so a layered stack measures the same way at every
//! tier.
//!
//! * [`drive_open_loop`] — Poisson arrivals at a fixed offered rate,
//!   independent of service progress. The right shape for latency-
//!   under-load and admission control: a slow engine does not slow the
//!   arrivals down, it sheds (or queues).
//! * [`drive_closed_loop`] — `clients` synchronous loops, each waiting
//!   for its previous response. The right shape for peak-throughput
//!   comparisons (always wall-clock: callers block for real).

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::metrics::Stats;
use crate::serve::loadgen::LoadGen;
use crate::serve::query::{N_QUERY_CLASSES, QUERY_CLASSES};
use crate::serve::server::ServerReport;

use super::{Outcome, QueryEngine, Request, Submitted, N_PRIORITIES, PRIORITIES};

/// The driver's notion of time, seconds since the run began.
pub trait Clock {
    fn now(&mut self) -> f64;

    /// Advance to (at least) time `t`: sleep on a wall clock, jump on a
    /// simulated one. Never moves backward.
    fn advance_to(&mut self, t: f64);
}

/// Real time since an epoch; `advance_to` sleeps.
pub struct WallClock {
    epoch: Instant,
}

impl WallClock {
    pub fn start() -> WallClock {
        WallClock { epoch: Instant::now() }
    }
}

impl Clock for WallClock {
    fn now(&mut self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    fn advance_to(&mut self, t: f64) {
        let now = self.epoch.elapsed().as_secs_f64();
        if t > now {
            std::thread::sleep(Duration::from_secs_f64(t - now));
        }
    }
}

/// Simulated time; `advance_to` jumps instantly.
#[derive(Default)]
pub struct SimClock {
    now: f64,
}

impl SimClock {
    pub fn new() -> SimClock {
        SimClock::default()
    }
}

impl Clock for SimClock {
    fn now(&mut self) -> f64 {
        self.now
    }

    fn advance_to(&mut self, t: f64) {
        if t > self.now {
            self.now = t;
        }
    }
}

/// Outcome of one driven run: disposition counters, trace aggregates,
/// and per-class latency for synchronously completed requests.
#[derive(Clone, Debug, Default)]
pub struct DriveReport {
    pub offered: u64,
    /// served synchronously (includes cache hits)
    pub completed: u64,
    /// accepted into an asynchronous queue (latency is accounted by the
    /// engine itself, e.g. the worker-pool server's report)
    pub queued: u64,
    pub shed: u64,
    pub failed: u64,
    pub deadline_exceeded: u64,
    pub cache_hits: u64,
    pub hedges: u64,
    pub hedge_wins: u64,
    /// length of the arrival window (offered rate = offered / this)
    pub arrival_secs: f64,
    /// last arrival or completion, whichever is later
    pub horizon: f64,
    /// arrival -> completion latency per query class (synchronous
    /// completions only)
    pub latency: [Stats; N_QUERY_CLASSES],
    /// the same latencies split by request priority — the lane view the
    /// graded-admission acceptance is judged on (all `Normal` unless
    /// the generator draws a priority mix)
    pub latency_pri: [Stats; N_PRIORITIES],
    /// sheds attributed per request priority (both the engine's typed
    /// shed responses and queue-refusal sheds)
    pub shed_pri: [u64; N_PRIORITIES],
    /// scheduler accounting folded in from the worker-pool server's
    /// report (see [`DriveReport::absorb_server`]): jobs executed from
    /// the owning worker's queue vs stolen from another worker's deque,
    /// and the drained-batch size distribution. All zero for
    /// synchronous tiers.
    pub local_hits: u64,
    pub steals: u64,
    pub batches: u64,
    pub batch_size: Stats,
}

impl DriveReport {
    /// All-classes latency distribution.
    pub fn latency_all(&self) -> Stats {
        Stats::merge_all(&self.latency)
    }

    pub fn offered_qps(&self) -> f64 {
        self.offered as f64 / self.arrival_secs.max(1e-9)
    }

    /// Completed throughput over the full horizon.
    pub fn qps(&self) -> f64 {
        self.completed as f64 / self.horizon.max(1e-9)
    }

    /// Fold another report in (closed-loop per-client partials).
    pub fn merge(&mut self, o: &DriveReport) {
        self.offered += o.offered;
        self.completed += o.completed;
        self.queued += o.queued;
        self.shed += o.shed;
        self.failed += o.failed;
        self.deadline_exceeded += o.deadline_exceeded;
        self.cache_hits += o.cache_hits;
        self.hedges += o.hedges;
        self.hedge_wins += o.hedge_wins;
        self.arrival_secs = self.arrival_secs.max(o.arrival_secs);
        self.horizon = self.horizon.max(o.horizon);
        self.local_hits += o.local_hits;
        self.steals += o.steals;
        self.batches += o.batches;
        self.batch_size.merge(&o.batch_size);
        for (dst, src) in self.latency.iter_mut().zip(&o.latency) {
            dst.merge(src);
        }
        for (dst, src) in self.latency_pri.iter_mut().zip(&o.latency_pri) {
            dst.merge(src);
        }
        for (dst, src) in self.shed_pri.iter_mut().zip(&o.shed_pri) {
            *dst += src;
        }
    }

    /// Fold the worker-pool server's scheduler accounting (local hits,
    /// steals, batch sizes) into this report, so one artifact carries
    /// both the driver's disposition counters and the scheduler's view
    /// of the same run. Call it with `Server::shutdown`'s report after
    /// a driven run over a `ServerEngine`.
    pub fn absorb_server(&mut self, s: &ServerReport) {
        self.local_hits += s.local_hits;
        self.steals += s.steals;
        self.batches += s.batches;
        self.batch_size.merge(&s.batch_size);
    }

    /// Account one synchronously completed response.
    fn absorb(&mut self, class: usize, prio: usize, at: f64, resp: &super::Response) {
        self.horizon = self.horizon.max(resp.done);
        self.cache_hits += resp.trace.cache_hit as u64;
        self.hedges += resp.trace.hedges as u64;
        self.hedge_wins += resp.trace.hedge_wins as u64;
        match resp.trace.outcome {
            Outcome::Served => {
                self.completed += 1;
                self.latency[class].push(resp.done - at);
                self.latency_pri[prio].push(resp.done - at);
            }
            Outcome::Shed => {
                self.shed += 1;
                self.shed_pri[prio] += 1;
            }
            Outcome::Failed => self.failed += 1,
            Outcome::DeadlineExceeded => self.deadline_exceeded += 1,
        }
    }

    /// Did any request run outside the default `Normal` lane? (If not,
    /// the per-priority breakdown is just a copy of the totals and the
    /// summary omits it.)
    fn priorities_in_play(&self) -> bool {
        let normal = super::Priority::Normal.index();
        PRIORITIES.iter().any(|p| {
            p.index() != normal
                && (self.latency_pri[p.index()].n > 0 || self.shed_pri[p.index()] > 0)
        })
    }

    /// Multi-line human summary with per-class quantiles.
    pub fn summary(&self) -> String {
        let mut out = format!(
            "drive: {} offered over {:.2}s -> {} completed, {} queued, {} shed, {} failed, {} past deadline",
            self.offered,
            self.arrival_secs,
            self.completed,
            self.queued,
            self.shed,
            self.failed,
            self.deadline_exceeded,
        );
        let all = self.latency_all();
        if all.n > 0 {
            let aq = all.quantiles(&[0.50, 0.99]);
            out.push_str(&format!(
                "\n  all      n={} p50={:.3}ms p99={:.3}ms",
                all.n,
                aq[0] * 1e3,
                aq[1] * 1e3
            ));
        }
        for c in QUERY_CLASSES {
            let s = &self.latency[c.index()];
            if s.n == 0 {
                continue;
            }
            let q = s.quantiles(&[0.50, 0.99]);
            out.push_str(&format!(
                "\n  {:<8} n={} p50={:.3}ms p99={:.3}ms",
                c.name(),
                s.n,
                q[0] * 1e3,
                q[1] * 1e3
            ));
        }
        if self.priorities_in_play() {
            for p in PRIORITIES {
                let s = &self.latency_pri[p.index()];
                let shed = self.shed_pri[p.index()];
                if s.n == 0 && shed == 0 {
                    continue;
                }
                if s.n > 0 {
                    let q = s.quantiles(&[0.50, 0.99]);
                    out.push_str(&format!(
                        "\n  pri {:<6} n={} p50={:.3}ms p99={:.3}ms shed={}",
                        p.name(),
                        s.n,
                        q[0] * 1e3,
                        q[1] * 1e3,
                        shed
                    ));
                } else {
                    out.push_str(&format!("\n  pri {:<6} n=0 shed={}", p.name(), shed));
                }
            }
        }
        if self.cache_hits > 0 {
            out.push_str(&format!("\n  cache hits: {}", self.cache_hits));
        }
        if self.hedges > 0 {
            out.push_str(&format!(
                "\n  hedges: {} fired, {} won",
                self.hedges, self.hedge_wins
            ));
        }
        if self.batches > 0 {
            let total = (self.local_hits + self.steals).max(1);
            out.push_str(&format!(
                "\n  sched: {} local, {} stolen ({:.1}%), mean batch {:.2}",
                self.local_hits,
                self.steals,
                100.0 * self.steals as f64 / total as f64,
                self.batch_size.mean()
            ));
        }
        out
    }
}

/// Drive an engine open-loop: Poisson arrivals at `qps` for `secs`
/// clock seconds. Arrivals never wait on service — a slow engine shows
/// up as latency (synchronous tiers), queue depth (async tiers), or
/// sheds, exactly as an overloaded service would.
pub fn drive_open_loop<E: QueryEngine + ?Sized>(
    engine: &E,
    clock: &mut dyn Clock,
    gen: &mut LoadGen,
    qps: f64,
    secs: f64,
) -> DriveReport {
    drive_open_loop_with(engine, clock, gen, qps, secs, |_| {})
}

/// [`drive_open_loop`] with a per-arrival hook: `before_arrival(at)` is
/// called with each arrival time before the request is submitted. This
/// is how the mixed read/write scenarios interleave ingestion with the
/// query stream — the hook applies every delta publish due at or
/// before `at` (e.g. `IngestDriver::tick`), so reads race writes at
/// well-defined points on the shared clock, wall or simulated.
pub fn drive_open_loop_with<E: QueryEngine + ?Sized>(
    engine: &E,
    clock: &mut dyn Clock,
    gen: &mut LoadGen,
    qps: f64,
    secs: f64,
    mut before_arrival: impl FnMut(f64),
) -> DriveReport {
    let mut report = DriveReport::default();
    let mut next_at = 0.0f64;
    while next_at < secs {
        clock.advance_to(next_at);
        // a wall clock may wake late; arrivals burst to catch up, as a
        // true open-loop source does
        let at = clock.now().max(next_at);
        // generator time follows the clock: moving hotspots and the
        // rate curve react to where the run actually is
        gen.advance_to(at);
        before_arrival(at);
        let q = gen.next_query();
        let class = q.class().index();
        let prio = gen.next_priority();
        report.offered += 1;
        match engine.submit(Request::new(q).with_priority(prio).arriving_at(at)) {
            Submitted::Queued => report.queued += 1,
            Submitted::Shed => {
                report.shed += 1;
                report.shed_pri[prio.index()] += 1;
            }
            Submitted::Done(resp) => report.absorb(class, prio.index(), at, &resp),
        }
        next_at += gen.next_interarrival(qps);
    }
    report.arrival_secs = next_at.min(secs);
    report.horizon = report.horizon.max(report.arrival_secs);
    report
}

/// Drive an engine with `clients` synchronous loops for `secs` wall
/// seconds. Shed responses back off briefly so a closed loop cannot
/// spin on an admission-controlled engine.
pub fn drive_closed_loop<E: QueryEngine + ?Sized>(
    engine: &E,
    gen: &mut LoadGen,
    clients: usize,
    secs: f64,
) -> DriveReport {
    let epoch = Instant::now();
    let deadline = Duration::from_secs_f64(secs);
    let partials: Mutex<Vec<DriveReport>> = Mutex::new(Vec::new());
    std::thread::scope(|scope| {
        for c in 0..clients.max(1) {
            let mut cgen = gen.fork(c as u64 + 1);
            let partials = &partials;
            scope.spawn(move || {
                let mut local = DriveReport::default();
                while epoch.elapsed() < deadline {
                    let q = cgen.next_query();
                    let class = q.class().index();
                    let prio = cgen.next_priority();
                    let at = epoch.elapsed().as_secs_f64();
                    local.offered += 1;
                    let resp =
                        engine.call(Request::new(q).with_priority(prio).arriving_at(at));
                    let was_shed = resp.trace.outcome == Outcome::Shed;
                    local.absorb(class, prio.index(), at, &resp);
                    if was_shed {
                        std::thread::sleep(Duration::from_micros(200));
                    }
                }
                partials.lock().unwrap().push(local);
            });
        }
    });
    let mut report = DriveReport::default();
    for p in partials.lock().unwrap().iter() {
        report.merge(p);
    }
    let wall = epoch.elapsed().as_secs_f64();
    report.arrival_secs = wall;
    report.horizon = wall;
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::{Response, Trace};
    use crate::serve::loadgen::LoadGenConfig;
    use crate::serve::query::QueryResult;

    /// Synchronous stub: serves everything after a fixed service time.
    struct FixedEngine {
        svc: f64,
    }

    impl QueryEngine for FixedEngine {
        fn call(&self, req: Request) -> Response {
            Response::served(QueryResult::Sources(Vec::new()), req.at + self.svc)
        }

        fn describe(&self) -> String {
            "fixed".to_string()
        }
    }

    #[test]
    fn sim_clock_only_moves_forward() {
        let mut c = SimClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance_to(2.5);
        assert_eq!(c.now(), 2.5);
        c.advance_to(1.0);
        assert_eq!(c.now(), 2.5, "clock must never move backward");
    }

    #[test]
    fn open_loop_on_sim_clock_is_deterministic() {
        let cfg = LoadGenConfig { seed: 11, ..Default::default() };
        let engine = FixedEngine { svc: 1e-4 };
        let run = || {
            let mut gen = LoadGen::new(cfg.clone(), 500.0, 500.0);
            let mut clock = SimClock::new();
            drive_open_loop(&engine, &mut clock, &mut gen, 1000.0, 0.5)
        };
        let a = run();
        let b = run();
        assert_eq!(a.offered, b.offered);
        assert_eq!(a.completed, b.completed);
        assert!(a.offered > 300, "offered {}", a.offered);
        assert_eq!(a.completed, a.offered);
        assert_eq!(a.shed + a.failed + a.queued, 0);
        assert_eq!(a.latency_all().n, a.completed);
        // every latency is exactly the fixed service time
        assert!((a.latency_all().min - 1e-4).abs() < 1e-12);
        assert!((a.latency_all().max - 1e-4).abs() < 1e-12);
        assert!(a.horizon >= a.arrival_secs);
    }

    #[test]
    fn report_merge_sums_counters() {
        let mut a = DriveReport { offered: 3, completed: 2, shed: 1, ..Default::default() };
        a.latency[0].push(0.5);
        let mut b = DriveReport { offered: 4, completed: 4, horizon: 9.0, ..Default::default() };
        b.latency[0].push(1.5);
        a.merge(&b);
        assert_eq!(a.offered, 7);
        assert_eq!(a.completed, 6);
        assert_eq!(a.shed, 1);
        assert_eq!(a.horizon, 9.0);
        assert_eq!(a.latency[0].n, 2);
    }

    #[test]
    fn absorb_routes_outcomes() {
        let mut r = DriveReport::default();
        let served = Response::served(QueryResult::Sources(Vec::new()), 1.0);
        r.absorb(0, 2, 0.25, &served);
        assert_eq!(r.completed, 1);
        assert!((r.latency[0].max - 0.75).abs() < 1e-12);
        assert_eq!(r.latency_pri[2].n, 1, "served latency lands in its priority lane");
        let mut hit = served.clone();
        hit.trace = Trace { cache_hit: true, ..Trace::default() };
        r.absorb(1, 1, 1.0, &hit);
        assert_eq!(r.cache_hits, 1);
        r.absorb(0, 0, 0.0, &Response::shed(0.0));
        assert_eq!(r.shed, 1);
        assert_eq!(r.shed_pri, [1, 0, 0], "sheds attribute to the request's lane");
        r.absorb(0, 0, 0.0, &Response::failed(0.0));
        assert_eq!(r.failed, 1);
    }

    /// The control plane's overload acceptance, at the drive level:
    /// a mixed-priority stream at 2x an engine's sustainable rate must
    /// shed the low lane hardest while every admitted high-priority
    /// request completes at the bare service budget.
    #[test]
    fn two_x_overload_with_priority_mix_sheds_low_lane_first() {
        use crate::serve::engine::{Admission, Priority};
        let svc = 5e-3;
        // sustainable ~ depth / svc = 2000 qps; offer 4000
        let engine = Admission::graded(FixedEngine { svc }, 10);
        let cfg = LoadGenConfig {
            priority_mix: Some([1.0, 1.0, 1.0]),
            seed: 31,
            ..Default::default()
        };
        let mut gen = LoadGen::new(cfg, 500.0, 500.0);
        let mut clock = SimClock::new();
        let r = drive_open_loop(&engine, &mut clock, &mut gen, 4000.0, 0.5);
        assert!(r.offered > 1000, "offered {}", r.offered);
        assert!(r.shed > 0, "2x overload must shed");
        assert_eq!(
            r.shed,
            r.shed_pri.iter().sum::<u64>(),
            "every shed is attributed to a priority lane"
        );
        assert_eq!(r.completed, r.latency_pri.iter().map(|s| s.n).sum::<u64>());
        let (low, high) = (Priority::Low.index(), Priority::High.index());
        assert!(
            r.shed_pri[low] > r.shed_pri[high],
            "sheds must concentrate on the low lane: {:?}",
            r.shed_pri
        );
        let high_lane = &r.latency_pri[high];
        assert!(high_lane.n > 100, "high lane starved: n={}", high_lane.n);
        // FixedEngine is queueless, so every admitted request finishes
        // in exactly `svc` — the high lane's p99 sits at the budget
        assert!(
            high_lane.quantiles(&[0.99])[0] <= svc + 1e-9,
            "high-priority p99 {} blew the service budget",
            high_lane.quantiles(&[0.99])[0]
        );
        let s = r.summary();
        assert!(s.contains("pri low"), "summary must break out lanes:\n{s}");
        assert!(s.contains("pri high"), "summary must break out lanes:\n{s}");
    }
}
