//! Result caching as a middleware layer, epoch-aware.
//!
//! [`ResultCache`] is the per-class LRU that used to live inside the
//! worker-pool `Server`; hoisting it into a [`Cached`] layer makes the
//! same cache available to *every* tier — in particular the distributed
//! router, where a hit also avoids fabric traffic. The layer records
//! hit rate and the fabric bytes saved (each entry remembers what its
//! original miss moved).
//!
//! With live ingestion (see [`crate::serve::ingest`]) the cache must
//! also not serve yesterday's sky: every entry filled over a versioned
//! tier is stamped with its *coverage* — the `(shard, epoch)` pairs of
//! the ranges the query planned over, read from the tier's
//! [`epoch_view`](super::QueryEngine::epoch_view). A probe recomputes
//! the plan against the current epoch and the entry hits only if the
//! coverage matches exactly; a mismatch means some covered range
//! mutated (or the plan itself changed because a shard's extent moved),
//! so the entry is dropped and counted as an invalidation. Entries over
//! *untouched* ranges keep hitting through any number of publishes —
//! invalidation is per mutated range, not per epoch. Requests with
//! [`Consistency::AtMost`] additionally accept entries filled at most
//! `k` epochs ago even if their ranges mutated since.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::serve::ingest::EpochStore;
use crate::serve::query::{plan_shards, Query, QueryResult, N_QUERY_CLASSES};

use super::{Consistency, Outcome, QueryEngine, Request, Response, Submitted, Trace};

/// What a cached result was computed over: the global epoch at fill
/// time plus the `(shard, shard-epoch)` pairs of the planned ranges.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Coverage {
    pub fill_epoch: u64,
    /// ascending by shard index (plans are generated in order)
    pub plan: Vec<(u32, u64)>,
}

/// Outcome of a cache probe.
pub enum CacheProbe {
    /// entry valid for this request: result + fabric bytes its miss moved
    Hit(QueryResult, f64),
    /// entry existed but covered mutated ranges: dropped
    Invalidated,
    Miss,
}

struct Entry {
    query: Query,
    result: QueryResult,
    /// fabric bytes the original miss moved (0 on local tiers)
    bytes: f64,
    tick: u64,
    /// `None` = filled over a static (unversioned) tier
    coverage: Option<Coverage>,
}

/// Entry-count LRU mapping query cache keys to cloned results. The
/// stored query is compared on probe so a 64-bit key collision returns
/// a miss instead of silently serving another query's result.
pub struct ResultCache {
    capacity: usize,
    map: HashMap<u64, Entry>,
    tick: u64,
}

impl ResultCache {
    pub fn new(capacity: usize) -> ResultCache {
        ResultCache { capacity, map: HashMap::new(), tick: 0 }
    }

    /// Probe for `q`. `want` carries the query's current shard-epoch
    /// coverage when the inner tier is versioned (`None` = static tier,
    /// any stored entry is valid); `max_lag` is the request's tolerated
    /// staleness in epochs (`None` = epoch-exact).
    pub fn get(
        &mut self,
        key: u64,
        q: &Query,
        want: Option<&Coverage>,
        max_lag: Option<u64>,
    ) -> CacheProbe {
        self.tick += 1;
        let tick = self.tick;
        let valid = match self.map.get_mut(&key) {
            Some(e) if e.query == *q => match (want, &e.coverage) {
                // static tier: entries never go stale
                (None, _) => true,
                // epoch-exact: every covered range (and only those
                // ranges) still at the epoch the entry was filled over
                (Some(w), Some(c)) if c.plan == w.plan => true,
                // bounded staleness: the entry is recent enough even
                // though some covered range mutated
                (Some(w), Some(cov)) => match max_lag {
                    Some(k) => w.fill_epoch.saturating_sub(cov.fill_epoch) <= k,
                    None => false,
                },
                // filled before the tier became versioned: treat stale
                (Some(_), None) => false,
            },
            _ => return CacheProbe::Miss,
        };
        if valid {
            let e = self.map.get_mut(&key).unwrap();
            e.tick = tick;
            CacheProbe::Hit(e.result.clone(), e.bytes)
        } else {
            self.map.remove(&key);
            CacheProbe::Invalidated
        }
    }

    pub fn put(
        &mut self,
        key: u64,
        query: Query,
        result: QueryResult,
        bytes: f64,
        coverage: Option<Coverage>,
    ) {
        if self.capacity == 0 {
            return;
        }
        self.tick += 1;
        if self.map.len() >= self.capacity && !self.map.contains_key(&key) {
            // amortized eviction: drop the least-recent ~1/8 of entries
            // in one pass instead of an O(n) scan per insert (this runs
            // under the class mutex on the request hot path)
            let mut ticks: Vec<u64> = self.map.values().map(|e| e.tick).collect();
            ticks.sort_unstable();
            let cut = ticks[(ticks.len() / 8).min(ticks.len() - 1)];
            self.map.retain(|_, e| e.tick > cut);
            if self.map.len() >= self.capacity {
                // all survivors newer than cut (degenerate tie case)
                let victim = self.map.iter().min_by_key(|(_, e)| e.tick).map(|(&k, _)| k);
                if let Some(k) = victim {
                    self.map.remove(&k);
                }
            }
        }
        self.map.insert(key, Entry { query, result, bytes, tick: self.tick, coverage });
    }
}

/// Middleware: per-query-class LRU result cache over any engine.
///
/// Hits answer instantly (completion = arrival on the engine's clock)
/// and never reach the inner engine; misses pass through and fill the
/// cache on the way back. Requests with [`Consistency::Fresh`] bypass
/// the probe but still refresh the cache. Over a versioned tier,
/// entries carry shard-epoch coverage and only entries whose covered
/// ranges mutated are invalidated (reported next to the hit rate).
pub struct Cached<E> {
    inner: E,
    entries_per_class: usize,
    caches: Vec<Mutex<ResultCache>>,
    hits: AtomicU64,
    misses: AtomicU64,
    /// entries dropped because a covered range mutated
    invalidations: AtomicU64,
    /// fabric bytes avoided by hits
    saved: Mutex<f64>,
}

impl<E: QueryEngine> Cached<E> {
    pub fn new(inner: E, entries_per_class: usize) -> Cached<E> {
        let caches = (0..N_QUERY_CLASSES)
            .map(|_| Mutex::new(ResultCache::new(entries_per_class)))
            .collect();
        Cached {
            inner,
            entries_per_class,
            caches,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            invalidations: AtomicU64::new(0),
            saved: Mutex::new(0.0),
        }
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Entries dropped because a range they covered was mutated by an
    /// ingestion publish (a subset of [`Cached::misses`]).
    pub fn invalidations(&self) -> u64 {
        self.invalidations.load(Ordering::Relaxed)
    }

    /// Fraction of probed requests served from the cache.
    pub fn hit_rate(&self) -> f64 {
        let (h, m) = (self.hits(), self.misses());
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    /// Fraction of probed requests that found a stale entry (dropped).
    pub fn invalidation_rate(&self) -> f64 {
        let probes = self.hits() + self.misses();
        if probes == 0 {
            0.0
        } else {
            self.invalidations() as f64 / probes as f64
        }
    }

    /// Fabric bytes hits avoided moving (per-entry record of what the
    /// original miss cost).
    pub fn bytes_saved(&self) -> f64 {
        *self.saved.lock().unwrap()
    }

    /// The query's current coverage under `view` (the epoch the inner
    /// tier serves right now).
    fn coverage(view: &EpochStore, q: &Query) -> Coverage {
        let plan = plan_shards(&view.store, q);
        Coverage { fill_epoch: view.epoch, plan: view.coverage_of(&plan) }
    }

    fn probe(&self, req: &Request, coverage: &Option<Coverage>) -> Option<Response> {
        if req.consistency == Consistency::Fresh {
            return None;
        }
        // key off the typed envelope field (stamped once at
        // construction), not a per-layer re-derivation from the query
        let class = req.class.index();
        let key = req.query.cache_key();
        let probe = self.caches[class].lock().unwrap().get(
            key,
            &req.query,
            coverage.as_ref(),
            req.consistency.max_cache_lag(),
        );
        match probe {
            CacheProbe::Hit(result, bytes) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                *self.saved.lock().unwrap() += bytes;
                Some(Response {
                    result: Some(result),
                    done: req.at,
                    trace: Trace { cache_hit: true, ..Trace::default() },
                })
            }
            CacheProbe::Invalidated => {
                self.invalidations.fetch_add(1, Ordering::Relaxed);
                None
            }
            CacheProbe::Miss => None,
        }
    }

    fn fill(&self, query: &Query, resp: &Response, coverage: Option<Coverage>) {
        if resp.trace.outcome != Outcome::Served {
            return;
        }
        // a lag-tolerant read served from pre-head replica content must
        // not be memoized: stamped with head coverage it would look
        // epoch-exact forever, long after the replicas caught up
        if resp.trace.stale_content {
            return;
        }
        if let Some(result) = &resp.result {
            let class = query.class().index();
            let key = query.cache_key();
            // coverage was computed from the view captured *before* the
            // inner call: if a publish raced the execution, the entry's
            // stamps are at worst older than the data, so a later probe
            // invalidates it — never the other way around
            self.caches[class].lock().unwrap().put(
                key,
                query.clone(),
                result.clone(),
                resp.trace.fabric_bytes,
                coverage,
            );
        }
    }
}

impl<E: QueryEngine> QueryEngine for Cached<E> {
    fn call(&self, req: Request) -> Response {
        // one coverage computation serves both the probe and the fill
        let view = self.inner.epoch_view();
        let coverage = view.as_ref().map(|v| Self::coverage(v, &req.query));
        if let Some(resp) = self.probe(&req, &coverage) {
            return resp;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let query = req.query.clone();
        let resp = self.inner.call(req);
        self.fill(&query, &resp, coverage);
        resp
    }

    fn submit(&self, req: Request) -> Submitted {
        let view = self.inner.epoch_view();
        let coverage = view.as_ref().map(|v| Self::coverage(v, &req.query));
        if let Some(resp) = self.probe(&req, &coverage) {
            return Submitted::Done(resp);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let query = req.query.clone();
        match self.inner.submit(req) {
            // synchronous completion (simulated tiers): fill on the way
            // back, exactly like the call path
            Submitted::Done(resp) => {
                self.fill(&query, &resp, coverage);
                Submitted::Done(resp)
            }
            // queued into an async engine: the result never flows back
            // through this layer, so the miss cannot fill the cache —
            // wall-clock open-loop runs only hit via the call path
            other => other,
        }
    }

    fn describe(&self) -> String {
        format!("cached({}/class) -> {}", self.entries_per_class, self.inner.describe())
    }

    fn in_flight(&self) -> Option<usize> {
        self.inner.in_flight()
    }

    fn metrics(&self) -> Vec<(String, f64)> {
        let mut m = vec![
            ("cache_hits".to_string(), self.hits() as f64),
            ("cache_misses".to_string(), self.misses() as f64),
            ("cache_invalidations".to_string(), self.invalidations() as f64),
            ("cache_bytes_saved".to_string(), self.bytes_saved()),
        ];
        m.extend(self.inner.metrics());
        m
    }

    fn epoch_view(&self) -> Option<Arc<EpochStore>> {
        self.inner.epoch_view()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::query::SourceFilter;

    fn hit(probe: CacheProbe) -> Option<(QueryResult, f64)> {
        match probe {
            CacheProbe::Hit(r, b) => Some((r, b)),
            _ => None,
        }
    }

    #[test]
    fn cache_evicts_lru_beyond_capacity() {
        let mut c = ResultCache::new(2);
        let r = QueryResult::Sources(Vec::new());
        let q = Query::BrightestN { n: 1, filter: SourceFilter::Any };
        c.put(1, q.clone(), r.clone(), 0.0, None);
        c.put(2, q.clone(), r.clone(), 0.0, None);
        assert!(hit(c.get(1, &q, None, None)).is_some()); // refresh 1 => 2 is LRU
        c.put(3, q.clone(), r.clone(), 0.0, None);
        assert!(hit(c.get(2, &q, None, None)).is_none(), "2 should be evicted");
        assert!(hit(c.get(1, &q, None, None)).is_some());
        assert!(hit(c.get(3, &q, None, None)).is_some());
    }

    #[test]
    fn cache_key_collision_is_a_miss_not_a_wrong_answer() {
        let mut c = ResultCache::new(4);
        let q1 = Query::BrightestN { n: 1, filter: SourceFilter::Any };
        let q2 = Query::BrightestN { n: 2, filter: SourceFilter::Any };
        // simulate a 64-bit key collision: same key, different query
        c.put(42, q1.clone(), QueryResult::Sources(Vec::new()), 0.0, None);
        assert!(hit(c.get(42, &q1, None, None)).is_some());
        assert!(
            hit(c.get(42, &q2, None, None)).is_none(),
            "colliding key must not serve q1's result for q2"
        );
    }

    #[test]
    fn hits_record_bytes_saved() {
        let mut c = ResultCache::new(4);
        let q = Query::BrightestN { n: 3, filter: SourceFilter::Any };
        c.put(7, q.clone(), QueryResult::Sources(Vec::new()), 1234.0, None);
        let (_, bytes) = hit(c.get(7, &q, None, None)).unwrap();
        assert_eq!(bytes, 1234.0);
    }

    #[test]
    fn coverage_mismatch_invalidates_and_match_hits() {
        let mut c = ResultCache::new(4);
        let q = Query::BrightestN { n: 3, filter: SourceFilter::Any };
        let filled = Coverage { fill_epoch: 2, plan: vec![(0, 1), (1, 2)] };
        c.put(9, q.clone(), QueryResult::Sources(Vec::new()), 0.0, Some(filled.clone()));
        // same coverage: hit
        assert!(hit(c.get(9, &q, Some(&filled), None)).is_some());
        // shard 1 mutated at epoch 3: epoch-exact probe invalidates
        let moved = Coverage { fill_epoch: 3, plan: vec![(0, 1), (1, 3)] };
        assert!(matches!(c.get(9, &q, Some(&moved), None), CacheProbe::Invalidated));
        // entry is gone afterwards
        assert!(matches!(c.get(9, &q, Some(&filled), None), CacheProbe::Miss));
    }

    #[test]
    fn bounded_staleness_tolerates_recent_mutations() {
        let mut c = ResultCache::new(4);
        let q = Query::BrightestN { n: 3, filter: SourceFilter::Any };
        let filled = Coverage { fill_epoch: 5, plan: vec![(2, 5)] };
        c.put(11, q.clone(), QueryResult::Sources(Vec::new()), 0.0, Some(filled));
        // shard 2 mutated at epoch 6; entry is 1 epoch old
        let current = Coverage { fill_epoch: 6, plan: vec![(2, 6)] };
        assert!(
            hit(c.get(11, &q, Some(&current), Some(1))).is_some(),
            "lag 1 <= k 1 must hit"
        );
        assert!(matches!(c.get(11, &q, Some(&current), Some(0)), CacheProbe::Invalidated));
    }
}
