//! Result caching as a middleware layer.
//!
//! [`ResultCache`] is the per-class LRU that used to live inside the
//! worker-pool `Server`; hoisting it into a [`Cached`] layer makes the
//! same cache available to *every* tier — in particular the distributed
//! router, where a hit also avoids fabric traffic. The layer records
//! hit rate and the fabric bytes saved (each entry remembers what its
//! original miss moved), the ROADMAP's "hot-range cache hit rates vs
//! fabric bytes saved" measurement.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::serve::query::{Query, QueryResult, N_QUERY_CLASSES};

use super::{Consistency, Outcome, QueryEngine, Request, Response, Submitted, Trace};

struct Entry {
    query: Query,
    result: QueryResult,
    /// fabric bytes the original miss moved (0 on local tiers)
    bytes: f64,
    tick: u64,
}

/// Entry-count LRU mapping query cache keys to cloned results. The
/// stored query is compared on probe so a 64-bit key collision returns
/// a miss instead of silently serving another query's result.
pub struct ResultCache {
    capacity: usize,
    map: HashMap<u64, Entry>,
    tick: u64,
}

impl ResultCache {
    pub fn new(capacity: usize) -> ResultCache {
        ResultCache { capacity, map: HashMap::new(), tick: 0 }
    }

    /// Probe for `q`; a hit returns the result and the fabric bytes its
    /// original miss moved.
    pub fn get(&mut self, key: u64, q: &Query) -> Option<(QueryResult, f64)> {
        self.tick += 1;
        let tick = self.tick;
        match self.map.get_mut(&key) {
            Some(e) if e.query == *q => {
                e.tick = tick;
                Some((e.result.clone(), e.bytes))
            }
            _ => None,
        }
    }

    pub fn put(&mut self, key: u64, query: Query, result: QueryResult, bytes: f64) {
        if self.capacity == 0 {
            return;
        }
        self.tick += 1;
        if self.map.len() >= self.capacity && !self.map.contains_key(&key) {
            // amortized eviction: drop the least-recent ~1/8 of entries
            // in one pass instead of an O(n) scan per insert (this runs
            // under the class mutex on the request hot path)
            let mut ticks: Vec<u64> = self.map.values().map(|e| e.tick).collect();
            ticks.sort_unstable();
            let cut = ticks[(ticks.len() / 8).min(ticks.len() - 1)];
            self.map.retain(|_, e| e.tick > cut);
            if self.map.len() >= self.capacity {
                // all survivors newer than cut (degenerate tie case)
                let victim = self.map.iter().min_by_key(|(_, e)| e.tick).map(|(&k, _)| k);
                if let Some(k) = victim {
                    self.map.remove(&k);
                }
            }
        }
        self.map.insert(key, Entry { query, result, bytes, tick: self.tick });
    }
}

/// Middleware: per-query-class LRU result cache over any engine.
///
/// Hits answer instantly (completion = arrival on the engine's clock)
/// and never reach the inner engine; misses pass through and fill the
/// cache on the way back. Requests with [`Consistency::Fresh`] bypass
/// the probe but still refresh the cache.
pub struct Cached<E> {
    inner: E,
    entries_per_class: usize,
    caches: Vec<Mutex<ResultCache>>,
    hits: AtomicU64,
    misses: AtomicU64,
    /// fabric bytes avoided by hits
    saved: Mutex<f64>,
}

impl<E: QueryEngine> Cached<E> {
    pub fn new(inner: E, entries_per_class: usize) -> Cached<E> {
        let caches = (0..N_QUERY_CLASSES)
            .map(|_| Mutex::new(ResultCache::new(entries_per_class)))
            .collect();
        Cached {
            inner,
            entries_per_class,
            caches,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            saved: Mutex::new(0.0),
        }
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Fraction of probed requests served from the cache.
    pub fn hit_rate(&self) -> f64 {
        let (h, m) = (self.hits(), self.misses());
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    /// Fabric bytes hits avoided moving (per-entry record of what the
    /// original miss cost).
    pub fn bytes_saved(&self) -> f64 {
        *self.saved.lock().unwrap()
    }

    fn probe(&self, req: &Request) -> Option<Response> {
        if req.consistency != Consistency::CachedOk {
            return None;
        }
        let class = req.query.class().index();
        let key = req.query.cache_key();
        let hit = self.caches[class].lock().unwrap().get(key, &req.query);
        hit.map(|(result, bytes)| {
            self.hits.fetch_add(1, Ordering::Relaxed);
            *self.saved.lock().unwrap() += bytes;
            Response {
                result: Some(result),
                done: req.at,
                trace: Trace { cache_hit: true, ..Trace::default() },
            }
        })
    }

    fn fill(&self, query: &Query, resp: &Response) {
        if resp.trace.outcome != Outcome::Served {
            return;
        }
        if let Some(result) = &resp.result {
            let class = query.class().index();
            let key = query.cache_key();
            self.caches[class].lock().unwrap().put(
                key,
                query.clone(),
                result.clone(),
                resp.trace.fabric_bytes,
            );
        }
    }
}

impl<E: QueryEngine> QueryEngine for Cached<E> {
    fn call(&self, req: Request) -> Response {
        if let Some(resp) = self.probe(&req) {
            return resp;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let query = req.query.clone();
        let resp = self.inner.call(req);
        self.fill(&query, &resp);
        resp
    }

    fn submit(&self, req: Request) -> Submitted {
        if let Some(resp) = self.probe(&req) {
            return Submitted::Done(resp);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let query = req.query.clone();
        match self.inner.submit(req) {
            // synchronous completion (simulated tiers): fill on the way
            // back, exactly like the call path
            Submitted::Done(resp) => {
                self.fill(&query, &resp);
                Submitted::Done(resp)
            }
            // queued into an async engine: the result never flows back
            // through this layer, so the miss cannot fill the cache —
            // wall-clock open-loop runs only hit via the call path
            other => other,
        }
    }

    fn describe(&self) -> String {
        format!("cached({}/class) -> {}", self.entries_per_class, self.inner.describe())
    }

    fn in_flight(&self) -> Option<usize> {
        self.inner.in_flight()
    }

    fn metrics(&self) -> Vec<(String, f64)> {
        let mut m = vec![
            ("cache_hits".to_string(), self.hits() as f64),
            ("cache_misses".to_string(), self.misses() as f64),
            ("cache_bytes_saved".to_string(), self.bytes_saved()),
        ];
        m.extend(self.inner.metrics());
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::query::SourceFilter;

    #[test]
    fn cache_evicts_lru_beyond_capacity() {
        let mut c = ResultCache::new(2);
        let r = QueryResult::Sources(Vec::new());
        let q = Query::BrightestN { n: 1, filter: SourceFilter::Any };
        c.put(1, q.clone(), r.clone(), 0.0);
        c.put(2, q.clone(), r.clone(), 0.0);
        assert!(c.get(1, &q).is_some()); // refresh 1 => 2 is LRU
        c.put(3, q.clone(), r.clone(), 0.0);
        assert!(c.get(2, &q).is_none(), "2 should be evicted");
        assert!(c.get(1, &q).is_some());
        assert!(c.get(3, &q).is_some());
    }

    #[test]
    fn cache_key_collision_is_a_miss_not_a_wrong_answer() {
        let mut c = ResultCache::new(4);
        let q1 = Query::BrightestN { n: 1, filter: SourceFilter::Any };
        let q2 = Query::BrightestN { n: 2, filter: SourceFilter::Any };
        // simulate a 64-bit key collision: same key, different query
        c.put(42, q1.clone(), QueryResult::Sources(Vec::new()), 0.0);
        assert!(c.get(42, &q1).is_some());
        assert!(c.get(42, &q2).is_none(), "colliding key must not serve q1's result for q2");
    }

    #[test]
    fn hits_record_bytes_saved() {
        let mut c = ResultCache::new(4);
        let q = Query::BrightestN { n: 3, filter: SourceFilter::Any };
        c.put(7, q.clone(), QueryResult::Sources(Vec::new()), 1234.0);
        let (_, bytes) = c.get(7, &q).unwrap();
        assert_eq!(bytes, 1234.0);
    }
}
