//! Speculative (hedged) requests as a middleware layer.
//!
//! "The Tail at Scale" recipe: when a replica sub-query exceeds a
//! latency budget, issue the same sub-query to a second replica and
//! take whichever reply lands first. Replies are byte-identical by
//! construction (every replica of a range holds the same shard), so
//! hedging trades extra replica load and fabric bytes for a shorter
//! tail — the p999 comparison against p2c-alone lives in the serve
//! bench and tests.
//!
//! The layer is policy, the tier is mechanism: [`Hedged`] stamps the
//! budget onto the request envelope ([`Request::hedge`]) and aggregates
//! the fired/won counters from response traces; replicated tiers (the
//! distributed router) honor the stamp per sub-query, single-replica
//! tiers ignore it.

use std::sync::atomic::{AtomicU64, Ordering};

use super::{QueryEngine, Request, Response, Submitted};

/// Middleware: stamp a replica hedge budget on every request.
pub struct Hedged<E> {
    inner: E,
    /// hedge budget, seconds
    budget: f64,
    fired: AtomicU64,
    wins: AtomicU64,
}

impl<E: QueryEngine> Hedged<E> {
    pub fn new(inner: E, budget: f64) -> Hedged<E> {
        Hedged {
            inner,
            budget: budget.max(0.0),
            fired: AtomicU64::new(0),
            wins: AtomicU64::new(0),
        }
    }

    /// Hedge sub-queries issued.
    pub fn fired(&self) -> u64 {
        self.fired.load(Ordering::Relaxed)
    }

    /// Hedges whose reply beat the primary replica's.
    pub fn wins(&self) -> u64 {
        self.wins.load(Ordering::Relaxed)
    }

    fn stamp(&self, mut req: Request) -> Request {
        req.hedge = Some(match req.hedge {
            // an outer layer already set a tighter budget: keep the min
            Some(existing) => existing.min(self.budget),
            None => self.budget,
        });
        req
    }

    fn account(&self, resp: &Response) {
        self.fired.fetch_add(resp.trace.hedges as u64, Ordering::Relaxed);
        self.wins.fetch_add(resp.trace.hedge_wins as u64, Ordering::Relaxed);
    }
}

impl<E: QueryEngine> QueryEngine for Hedged<E> {
    fn call(&self, req: Request) -> Response {
        let resp = self.inner.call(self.stamp(req));
        self.account(&resp);
        resp
    }

    fn submit(&self, req: Request) -> Submitted {
        match self.inner.submit(self.stamp(req)) {
            Submitted::Done(resp) => {
                self.account(&resp);
                Submitted::Done(resp)
            }
            other => other,
        }
    }

    fn describe(&self) -> String {
        format!("hedged({:.3}ms) -> {}", self.budget * 1e3, self.inner.describe())
    }

    fn in_flight(&self) -> Option<usize> {
        self.inner.in_flight()
    }

    fn metrics(&self) -> Vec<(String, f64)> {
        let mut m = vec![
            ("hedges_fired".to_string(), self.fired() as f64),
            ("hedge_wins".to_string(), self.wins() as f64),
        ];
        m.extend(self.inner.metrics());
        m
    }
}
