//! Speculative (hedged) requests as a middleware layer.
//!
//! "The Tail at Scale" recipe: when a replica sub-query exceeds a
//! latency budget, issue the same sub-query to a second replica and
//! take whichever reply lands first. Replies are byte-identical by
//! construction (the router only hedges to replicas serving the same
//! shard content epoch), so hedging trades extra replica load and
//! fabric bytes for a shorter tail — the p999 comparison against
//! p2c-alone lives in the serve bench and tests.
//!
//! The layer is policy, the tier is mechanism: [`Hedged`] stamps the
//! budget onto the request envelope ([`Request::hedge`]) and aggregates
//! the fired/won counters from response traces; replicated tiers (the
//! distributed router) honor the stamp per sub-query, single-replica
//! tiers ignore it.
//!
//! Hedging doubles replica load for the requests it touches, so the
//! layer also enforces a *hedge-rate budget*: at most `cap` of all
//! requests may be hedged (default uncapped; `serve-bench` passes
//! `--hedge-budget`, default 0.05). Requests past the budget are not
//! stamped — skipped and counted — so a latency regression cannot
//! snowball into a self-inflicted load doubling.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::serve::ingest::EpochStore;

use super::{QueryEngine, Request, Response, Submitted};

/// Middleware: stamp a replica hedge budget on every request (subject
/// to the hedge-rate cap).
pub struct Hedged<E> {
    inner: E,
    /// hedge budget, seconds
    budget: f64,
    /// max fraction of requests that may be stamped (None = uncapped)
    cap: Option<f64>,
    /// requests seen / stamped / skipped by the rate budget
    seen: AtomicU64,
    stamped: AtomicU64,
    skipped: AtomicU64,
    fired: AtomicU64,
    wins: AtomicU64,
}

impl<E: QueryEngine> Hedged<E> {
    /// Uncapped hedging: every request carries the budget.
    pub fn new(inner: E, budget: f64) -> Hedged<E> {
        Hedged::with_cap(inner, budget, 0.0)
    }

    /// Hedging with a rate budget: at most `cap` of requests are
    /// stamped (`cap <= 0` or `>= 1` disables the cap).
    pub fn with_cap(inner: E, budget: f64, cap: f64) -> Hedged<E> {
        Hedged {
            inner,
            budget: budget.max(0.0),
            cap: if cap > 0.0 && cap < 1.0 { Some(cap) } else { None },
            seen: AtomicU64::new(0),
            stamped: AtomicU64::new(0),
            skipped: AtomicU64::new(0),
            fired: AtomicU64::new(0),
            wins: AtomicU64::new(0),
        }
    }

    /// Hedge sub-queries issued.
    pub fn fired(&self) -> u64 {
        self.fired.load(Ordering::Relaxed)
    }

    /// Hedges whose reply beat the primary replica's.
    pub fn wins(&self) -> u64 {
        self.wins.load(Ordering::Relaxed)
    }

    /// Requests left unstamped because the hedge-rate budget was spent.
    pub fn budget_skipped(&self) -> u64 {
        self.skipped.load(Ordering::Relaxed)
    }

    /// Requests stamped with a hedge budget.
    pub fn stamped_requests(&self) -> u64 {
        self.stamped.load(Ordering::Relaxed)
    }

    fn stamp(&self, mut req: Request) -> Request {
        let seen = self.seen.fetch_add(1, Ordering::Relaxed) + 1;
        if let Some(cap) = self.cap {
            // grant the n-th stamp only once enough requests have been
            // seen to keep stamped/seen <= cap (deterministic under a
            // single submitter; approximate under racing clients)
            let stamped = self.stamped.load(Ordering::Relaxed);
            if (stamped + 1) as f64 > cap * seen as f64 {
                self.skipped.fetch_add(1, Ordering::Relaxed);
                return req;
            }
        }
        self.stamped.fetch_add(1, Ordering::Relaxed);
        req.hedge = Some(match req.hedge {
            // an outer layer already set a tighter budget: keep the min
            Some(existing) => existing.min(self.budget),
            None => self.budget,
        });
        req
    }

    fn account(&self, resp: &Response) {
        self.fired.fetch_add(resp.trace.hedges as u64, Ordering::Relaxed);
        self.wins.fetch_add(resp.trace.hedge_wins as u64, Ordering::Relaxed);
    }
}

impl<E: QueryEngine> QueryEngine for Hedged<E> {
    fn call(&self, req: Request) -> Response {
        let resp = self.inner.call(self.stamp(req));
        self.account(&resp);
        resp
    }

    fn submit(&self, req: Request) -> Submitted {
        match self.inner.submit(self.stamp(req)) {
            Submitted::Done(resp) => {
                self.account(&resp);
                Submitted::Done(resp)
            }
            other => other,
        }
    }

    fn describe(&self) -> String {
        match self.cap {
            Some(cap) => format!(
                "hedged({:.3}ms, cap {:.0}%) -> {}",
                self.budget * 1e3,
                cap * 100.0,
                self.inner.describe()
            ),
            None => format!("hedged({:.3}ms) -> {}", self.budget * 1e3, self.inner.describe()),
        }
    }

    fn in_flight(&self) -> Option<usize> {
        self.inner.in_flight()
    }

    fn metrics(&self) -> Vec<(String, f64)> {
        let mut m = vec![
            ("hedges_fired".to_string(), self.fired() as f64),
            ("hedge_wins".to_string(), self.wins() as f64),
            ("hedge_budget_skipped".to_string(), self.budget_skipped() as f64),
        ];
        m.extend(self.inner.metrics());
        m
    }

    fn epoch_view(&self) -> Option<Arc<EpochStore>> {
        self.inner.epoch_view()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::query::{Query, QueryResult, SourceFilter};

    /// Stub that reports whether the envelope carried a hedge stamp.
    struct Probe;

    impl QueryEngine for Probe {
        fn call(&self, req: Request) -> Response {
            let mut resp = Response::served(QueryResult::Sources(Vec::new()), req.at);
            // reuse the hedges counter to observe the stamp downstream
            resp.trace.hedges = req.hedge.is_some() as u32;
            resp
        }

        fn describe(&self) -> String {
            "probe".to_string()
        }
    }

    #[test]
    fn cap_limits_the_stamped_fraction() {
        let engine = Hedged::with_cap(Probe, 1e-3, 0.05);
        let q = Query::BrightestN { n: 1, filter: SourceFilter::Any };
        let mut stamped = 0u64;
        for _ in 0..1000 {
            let resp = engine.call(Request::new(q.clone()));
            stamped += resp.trace.hedges as u64;
        }
        assert_eq!(stamped, engine.stamped_requests());
        assert!(stamped <= 50, "cap 5% of 1000 must stamp <= 50, got {stamped}");
        assert!(stamped >= 40, "cap must still allow ~5%: {stamped}");
        assert_eq!(engine.budget_skipped(), 1000 - stamped);
    }

    #[test]
    fn uncapped_stamps_everything() {
        let engine = Hedged::new(Probe, 1e-3);
        let q = Query::BrightestN { n: 1, filter: SourceFilter::Any };
        for _ in 0..20 {
            engine.call(Request::new(q.clone()));
        }
        assert_eq!(engine.stamped_requests(), 20);
        assert_eq!(engine.budget_skipped(), 0);
    }
}
