//! Admission control as a middleware layer.
//!
//! The bounded-queue shed logic that used to be welded into the
//! worker-pool `Server`, extracted so every tier degrades the same way
//! under overload: an explicit shed count instead of unbounded latency.
//!
//! For engines with a real queue the layer probes
//! [`QueryEngine::in_flight`]; for synchronous (simulated-time) engines
//! it models the backlog itself as the set of already-issued responses
//! whose completion time is still in the future at the new request's
//! arrival time. The worker-pool server's probe is batch-aware: a
//! drained-but-unexecuted batch still counts against the bound (see
//! [`crate::serve::sched`]), so switching schedulers or batch sizes
//! does not quietly widen the effective admission depth.
//!
//! Two shed policies share the backlog probe:
//!
//! * **Uniform** ([`Admission::new`], the historical behavior): every
//!   request sheds once the backlog reaches the depth, regardless of
//!   priority or class.
//! * **Graded** ([`Admission::graded`]): each `(priority, class)` pair
//!   may only use [`admit_fraction`] of the depth, so as the backlog
//!   climbs, low-priority expensive requests (cross-matches) are
//!   refused first and high-priority cheap ones (cone lookups) last —
//!   the overload response the control plane's priority classes exist
//!   for. The fraction ordering itself is pinned by
//!   `admit_fractions_pin_the_shed_order` in [`super`]; this module's
//!   tests pin that the *layer* actually sheds in that order.
//!
//! The bound is exact under a single submitting thread (both drivers'
//! open loops). Under concurrent submitters the probe and the submit
//! are separate steps, so the depth can transiently overshoot by up to
//! the number of racing clients — a shed signal, not a hard capacity
//! guarantee (the worker-pool `Server` additionally enforces its own
//! in-lock `queue_depth` when one is configured).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::serve::ingest::EpochStore;
use crate::serve::query::{QueryClass, N_QUERY_CLASSES, QUERY_CLASSES};

use super::{admit_fraction, Priority, QueryEngine, Request, Response, Submitted, PRIORITIES};

/// Middleware: shed requests beyond an in-flight bound.
pub struct Admission<E> {
    inner: E,
    depth: usize,
    /// grade the bound by `(priority, class)` instead of uniformly
    graded: bool,
    /// completion times of synchronous responses still pending at the
    /// engine clock (unused when the inner engine exposes a real queue)
    outstanding: Mutex<Vec<f64>>,
    admitted: AtomicU64,
    shed: AtomicU64,
    /// sheds by `[priority][class]` — the attribution the graded
    /// policy's acceptance is judged on (counted in uniform mode too)
    shed_by: [[AtomicU64; N_QUERY_CLASSES]; 3],
}

impl<E: QueryEngine> Admission<E> {
    /// Uniform admission: every request sheds at the same depth.
    pub fn new(inner: E, depth: usize) -> Admission<E> {
        Admission {
            inner,
            depth: depth.max(1),
            graded: false,
            outstanding: Mutex::new(Vec::new()),
            admitted: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            shed_by: Default::default(),
        }
    }

    /// Graded admission: each `(priority, class)` pair keeps only
    /// [`admit_fraction`] of the depth, so overload sheds cheap-last.
    pub fn graded(inner: E, depth: usize) -> Admission<E> {
        Admission {
            graded: true,
            ..Admission::new(inner, depth)
        }
    }

    pub fn admitted(&self) -> u64 {
        self.admitted.load(Ordering::Relaxed)
    }

    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// Sheds attributed to one `(priority, class)` pair.
    pub fn shed_for(&self, priority: Priority, class: QueryClass) -> u64 {
        self.shed_by[priority.index()][class.index()].load(Ordering::Relaxed)
    }

    fn backlog(&self, now: f64) -> usize {
        if let Some(queued) = self.inner.in_flight() {
            return queued;
        }
        let mut out = self.outstanding.lock().unwrap();
        out.retain(|&done| done > now);
        out.len()
    }

    fn over_limit(&self, req: &Request) -> bool {
        let bound = if self.graded {
            // ceil keeps small depths from rounding a fraction to zero
            let b = (self.depth as f64 * admit_fraction(req.priority, req.class)).ceil();
            (b as usize).max(1)
        } else {
            self.depth
        };
        self.backlog(req.at) >= bound
    }

    fn count_shed(&self, req: &Request) {
        self.shed.fetch_add(1, Ordering::Relaxed);
        self.shed_by[req.priority.index()][req.class.index()].fetch_add(1, Ordering::Relaxed);
    }

    fn record(&self, at: f64, resp: &Response) {
        if self.inner.in_flight().is_none() && resp.done > at {
            self.outstanding.lock().unwrap().push(resp.done);
        }
    }
}

impl<E: QueryEngine> QueryEngine for Admission<E> {
    fn call(&self, req: Request) -> Response {
        let at = req.at;
        if self.over_limit(&req) {
            self.count_shed(&req);
            return Response::shed(at);
        }
        self.admitted.fetch_add(1, Ordering::Relaxed);
        let resp = self.inner.call(req);
        self.record(at, &resp);
        resp
    }

    fn submit(&self, req: Request) -> Submitted {
        let at = req.at;
        if self.over_limit(&req) {
            self.count_shed(&req);
            return Submitted::Shed;
        }
        self.admitted.fetch_add(1, Ordering::Relaxed);
        match self.inner.submit(req) {
            Submitted::Done(resp) => {
                self.record(at, &resp);
                Submitted::Done(resp)
            }
            other => other,
        }
    }

    fn describe(&self) -> String {
        if self.graded {
            format!("admit({}, graded) -> {}", self.depth, self.inner.describe())
        } else {
            format!("admit({}) -> {}", self.depth, self.inner.describe())
        }
    }

    fn in_flight(&self) -> Option<usize> {
        self.inner.in_flight()
    }

    fn metrics(&self) -> Vec<(String, f64)> {
        let mut m = vec![
            ("admitted".to_string(), self.admitted() as f64),
            ("admission_shed".to_string(), self.shed() as f64),
        ];
        for p in PRIORITIES {
            for c in QUERY_CLASSES {
                let n = self.shed_by[p.index()][c.index()].load(Ordering::Relaxed);
                if n > 0 {
                    m.push((format!("admission_shed_{}_{}", p.name(), c.name()), n as f64));
                }
            }
        }
        m.extend(self.inner.metrics());
        m
    }

    fn epoch_view(&self) -> Option<Arc<EpochStore>> {
        self.inner.epoch_view()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::query::{Query, QueryResult, SourceFilter};

    /// Synchronous stub: every request takes `svc` seconds.
    struct Slow {
        svc: f64,
    }

    impl QueryEngine for Slow {
        fn call(&self, req: Request) -> Response {
            Response::served(QueryResult::Sources(Vec::new()), req.at + self.svc)
        }

        fn describe(&self) -> String {
            "slow".to_string()
        }
    }

    fn cone() -> Query {
        Query::Cone {
            center: (1.0, 1.0),
            radius: 2.0,
            filter: SourceFilter::Any,
        }
    }

    fn xmatch() -> Query {
        Query::CrossMatch {
            pos: (1.0, 1.0),
            radius: 1.0,
        }
    }

    /// Fill the backlog to exactly 70% of depth, then probe one request
    /// per (priority, class) pair: the graded layer must shed exactly
    /// the pairs whose admit fraction is at or below the fill level.
    #[test]
    fn graded_admission_sheds_in_fraction_order() {
        let depth = 100usize;
        let engine = Admission::graded(Slow { svc: 1.0 }, depth);
        for i in 0..70 {
            let r = Request::new(cone())
                .with_priority(Priority::High)
                .arriving_at(i as f64 * 1e-6);
            assert!(matches!(engine.submit(r), Submitted::Done(_)), "warm-up shed at {i}");
        }
        let probe = |q: Query, p: Priority| {
            let req = Request::new(q).with_priority(p).arriving_at(1e-4);
            matches!(engine.submit(req), Submitted::Shed)
        };
        // low priority sheds everything (its best fraction is 0.50)
        assert!(probe(xmatch(), Priority::Low));
        assert!(probe(cone(), Priority::Low));
        // normal spans 0.60..0.75: the cross-match (0.60) sheds, the
        // cone (0.75) still gets through at a 0.70 fill
        assert!(probe(xmatch(), Priority::Normal));
        assert!(!probe(cone(), Priority::Normal));
        // high priority (0.85..1.0) is untouched
        assert!(!probe(xmatch(), Priority::High));
        assert!(!probe(cone(), Priority::High));
        // attribution lands on the refused pairs, nowhere else
        assert_eq!(engine.shed_for(Priority::Low, QueryClass::CrossMatch), 1);
        assert_eq!(engine.shed_for(Priority::Low, QueryClass::Cone), 1);
        assert_eq!(engine.shed_for(Priority::Normal, QueryClass::CrossMatch), 1);
        assert_eq!(engine.shed_for(Priority::Normal, QueryClass::Cone), 0);
        assert_eq!(engine.shed_for(Priority::High, QueryClass::Cone), 0);
        assert_eq!(engine.shed(), 3);
        let m = engine.metrics();
        assert!(m.iter().any(|(n, v)| n == "admission_shed_low_xmatch" && *v == 1.0));
        assert!(
            !m.iter().any(|(n, _)| n == "admission_shed_high_cone"),
            "zero counters stay out of the metric list"
        );
    }

    /// Under sustained 2x overload with a mixed-priority stream, sheds
    /// must concentrate on low-priority cross-matches while admitted
    /// high-priority cones complete within the service budget — the
    /// acceptance shape for the control plane's priority classes.
    #[test]
    fn two_x_overload_sheds_cheap_last() {
        let svc = 10e-3;
        let depth = 10usize; // capacity ~ depth / svc = 1000 qps
        let engine = Admission::graded(Slow { svc }, depth);
        let mut shed = [[0u64; 2]; 3]; // [priority][cone=0 | xmatch=1]
        let mut served = [[0u64; 2]; 3];
        let mut rng = crate::prng::Rng::new(0xca11);
        let qps = 2000.0; // 2x overload
        let mut at = 0.0;
        let mut high_cone_worst = 0.0f64;
        for _ in 0..4000 {
            let (q, ci) = if rng.uniform() < 0.5 { (cone(), 0) } else { (xmatch(), 1) };
            let p = match rng.below(3) {
                0 => Priority::Low,
                1 => Priority::Normal,
                _ => Priority::High,
            };
            let req = Request::new(q).with_priority(p).arriving_at(at);
            match engine.submit(req) {
                Submitted::Shed => shed[p.index()][ci] += 1,
                Submitted::Done(resp) => {
                    served[p.index()][ci] += 1;
                    if p == Priority::High && ci == 0 {
                        high_cone_worst = high_cone_worst.max(resp.done - at);
                    }
                }
                Submitted::Queued => unreachable!("synchronous stub"),
            }
            at += rng.uniform().max(1e-9).ln() * (-1.0 / qps);
        }
        let shed_rate = |p: Priority, ci: usize| {
            let (s, v) = (shed[p.index()][ci], served[p.index()][ci]);
            s as f64 / (s + v).max(1) as f64
        };
        // sheds concentrate on low-priority cross-matches...
        assert!(
            shed_rate(Priority::Low, 1) > 0.9,
            "low/xmatch shed rate {:.2} should be near 1 under 2x overload",
            shed_rate(Priority::Low, 1)
        );
        // ...the ordering holds pairwise...
        assert!(shed_rate(Priority::Low, 1) >= shed_rate(Priority::Low, 0));
        assert!(shed_rate(Priority::Low, 0) > shed_rate(Priority::Normal, 0));
        assert!(shed_rate(Priority::Normal, 1) > shed_rate(Priority::High, 1));
        assert!(shed_rate(Priority::High, 1) >= shed_rate(Priority::High, 0));
        // ...and high-priority cones barely shed and stay in budget
        assert!(
            shed_rate(Priority::High, 0) < 0.35,
            "high/cone shed rate {:.2} must stay lowest",
            shed_rate(Priority::High, 0)
        );
        assert!(served[Priority::High.index()][0] > 100);
        assert!(
            high_cone_worst <= svc + 1e-9,
            "admitted high/cone latency {high_cone_worst} must stay at the service budget"
        );
    }

    #[test]
    fn uniform_admission_ignores_priorities() {
        let engine = Admission::new(Slow { svc: 1.0 }, 4);
        for i in 0..4 {
            let r = Request::new(xmatch())
                .with_priority(Priority::Low)
                .arriving_at(i as f64 * 1e-6);
            assert!(matches!(engine.submit(r), Submitted::Done(_)));
        }
        let r = Request::new(cone()).with_priority(Priority::High).arriving_at(1e-5);
        assert!(
            matches!(engine.submit(r), Submitted::Shed),
            "the legacy uniform bound is priority-blind"
        );
        assert_eq!(engine.shed_for(Priority::High, QueryClass::Cone), 1);
    }
}
