//! Admission control as a middleware layer.
//!
//! The bounded-queue shed logic that used to be welded into the
//! worker-pool `Server`, extracted so every tier degrades the same way
//! under overload: an explicit shed count instead of unbounded latency.
//!
//! For engines with a real queue the layer probes
//! [`QueryEngine::in_flight`]; for synchronous (simulated-time) engines
//! it models the backlog itself as the set of already-issued responses
//! whose completion time is still in the future at the new request's
//! arrival time. The worker-pool server's probe is batch-aware: a
//! drained-but-unexecuted batch still counts against the bound (see
//! [`crate::serve::sched`]), so switching schedulers or batch sizes
//! does not quietly widen the effective admission depth.
//!
//! The bound is exact under a single submitting thread (both drivers'
//! open loops). Under concurrent submitters the probe and the submit
//! are separate steps, so the depth can transiently overshoot by up to
//! the number of racing clients — a shed signal, not a hard capacity
//! guarantee (the worker-pool `Server` additionally enforces its own
//! in-lock `queue_depth` when one is configured).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::serve::ingest::EpochStore;

use super::{QueryEngine, Request, Response, Submitted};

/// Middleware: shed requests beyond an in-flight bound.
pub struct Admission<E> {
    inner: E,
    depth: usize,
    /// completion times of synchronous responses still pending at the
    /// engine clock (unused when the inner engine exposes a real queue)
    outstanding: Mutex<Vec<f64>>,
    admitted: AtomicU64,
    shed: AtomicU64,
}

impl<E: QueryEngine> Admission<E> {
    pub fn new(inner: E, depth: usize) -> Admission<E> {
        Admission {
            inner,
            depth: depth.max(1),
            outstanding: Mutex::new(Vec::new()),
            admitted: AtomicU64::new(0),
            shed: AtomicU64::new(0),
        }
    }

    pub fn admitted(&self) -> u64 {
        self.admitted.load(Ordering::Relaxed)
    }

    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    fn over_limit(&self, now: f64) -> bool {
        if let Some(queued) = self.inner.in_flight() {
            return queued >= self.depth;
        }
        let mut out = self.outstanding.lock().unwrap();
        out.retain(|&done| done > now);
        out.len() >= self.depth
    }

    fn record(&self, at: f64, resp: &Response) {
        if self.inner.in_flight().is_none() && resp.done > at {
            self.outstanding.lock().unwrap().push(resp.done);
        }
    }
}

impl<E: QueryEngine> QueryEngine for Admission<E> {
    fn call(&self, req: Request) -> Response {
        let at = req.at;
        if self.over_limit(at) {
            self.shed.fetch_add(1, Ordering::Relaxed);
            return Response::shed(at);
        }
        self.admitted.fetch_add(1, Ordering::Relaxed);
        let resp = self.inner.call(req);
        self.record(at, &resp);
        resp
    }

    fn submit(&self, req: Request) -> Submitted {
        let at = req.at;
        if self.over_limit(at) {
            self.shed.fetch_add(1, Ordering::Relaxed);
            return Submitted::Shed;
        }
        self.admitted.fetch_add(1, Ordering::Relaxed);
        match self.inner.submit(req) {
            Submitted::Done(resp) => {
                self.record(at, &resp);
                Submitted::Done(resp)
            }
            other => other,
        }
    }

    fn describe(&self) -> String {
        format!("admit({}) -> {}", self.depth, self.inner.describe())
    }

    fn in_flight(&self) -> Option<usize> {
        self.inner.in_flight()
    }

    fn metrics(&self) -> Vec<(String, f64)> {
        let mut m = vec![
            ("admitted".to_string(), self.admitted() as f64),
            ("admission_shed".to_string(), self.shed() as f64),
        ];
        m.extend(self.inner.metrics());
        m
    }

    fn epoch_view(&self) -> Option<Arc<EpochStore>> {
        self.inner.epoch_view()
    }
}
