//! The serving tiers, ported onto [`QueryEngine`]: brute-force scan,
//! direct sharded execution, the wall-clock worker-pool server, and the
//! simulated-time distributed router. The promise this trait made —
//! that a real RPC transport behind `ShardClient` would be just another
//! impl rather than a fifth bespoke entry point — is now kept by
//! [`crate::serve::net::NetRouterEngine`], the TCP tier living in
//! `serve/net/` and selected with `serve-bench --transport tcp`.
//!
//! Tiers over a [`VersionedStore`] expose their current epoch through
//! [`QueryEngine::epoch_view`], which is what lets the `Cached` layer
//! invalidate precisely and the drivers measure reads during ingestion.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::serve::dist::{DistReport, Router};
use crate::serve::ingest::{EpochStore, IngestReport, StoreSource, VersionedStore};
use crate::serve::obs::{self, Histogram, Registry, TraceRecord, TraceSampler};
use crate::serve::query::{execute, execute_scan, N_QUERY_CLASSES, QUERY_CLASSES};
use crate::serve::server::Server;
use crate::serve::store::{ServedSource, Store};

use super::drive::DriveReport;
use super::{enforce_deadline, Outcome, QueryEngine, Request, Response, Submitted, Trace};

/// The brute-force reference tier: a linear scan over a flat catalog.
/// Slow by design; parity tests pin every other tier against it.
pub struct ScanEngine {
    sources: Vec<ServedSource>,
}

impl ScanEngine {
    pub fn new(sources: Vec<ServedSource>) -> ScanEngine {
        ScanEngine { sources }
    }
}

impl QueryEngine for ScanEngine {
    fn call(&self, req: Request) -> Response {
        let t = Instant::now();
        let result = execute_scan(&self.sources, &req.query);
        let resp = Response::served(result, req.at + t.elapsed().as_secs_f64());
        enforce_deadline(req.at, req.deadline, resp)
    }

    fn describe(&self) -> String {
        format!("scan({} sources)", self.sources.len())
    }
}

/// The single-host sharded tier, executed inline on the caller's
/// thread (no worker pool): `query::execute` behind the envelope.
/// Serves either a fixed store or the live head of a versioned one
/// (loaded per request, so publishes are picked up immediately).
#[derive(Clone)]
pub struct DirectEngine {
    source: StoreSource,
}

impl DirectEngine {
    pub fn new(store: Arc<Store>) -> DirectEngine {
        DirectEngine { source: StoreSource::Fixed(store) }
    }

    /// Serve the live head of a versioned store.
    pub fn live(versioned: Arc<VersionedStore>) -> DirectEngine {
        DirectEngine { source: StoreSource::Live(versioned) }
    }
}

impl QueryEngine for DirectEngine {
    fn call(&self, req: Request) -> Response {
        let t = Instant::now();
        let result = execute(&self.source.current(), &req.query);
        let resp = Response::served(result, req.at + t.elapsed().as_secs_f64());
        enforce_deadline(req.at, req.deadline, resp)
    }

    fn describe(&self) -> String {
        match &self.source {
            StoreSource::Fixed(s) => format!("direct({} shards)", s.shards.len()),
            StoreSource::Live(v) => {
                let view = v.load();
                format!(
                    "direct(live, {} shards @ epoch {})",
                    view.store.shards.len(),
                    view.epoch
                )
            }
        }
    }

    fn epoch_view(&self) -> Option<Arc<EpochStore>> {
        self.source.view()
    }
}

/// The wall-clock worker-pool tier: `call` blocks for the reply,
/// `submit` is the fire-and-forget queue path. Clones share one
/// server; keep a clone (or the `Arc<Server>`) to collect the server's
/// own queue-latency + scheduler report via `Server::shutdown` after a
/// run (fold it into the drive via `DriveReport::absorb_server`). The
/// scheduler underneath (condvar FIFO or work-stealing deques, batched
/// or not) is invisible at this seam: any middleware stack above and
/// both drivers inherit it unchanged.
#[derive(Clone)]
pub struct ServerEngine {
    server: Arc<Server>,
}

impl ServerEngine {
    pub fn new(server: Arc<Server>) -> ServerEngine {
        ServerEngine { server }
    }

    pub fn server(&self) -> &Arc<Server> {
        &self.server
    }
}

impl QueryEngine for ServerEngine {
    fn call(&self, req: Request) -> Response {
        let t = Instant::now();
        match self.server.call_with(req.query.clone(), req.priority) {
            Some(result) => {
                let resp = Response::served(result, req.at + t.elapsed().as_secs_f64());
                enforce_deadline(req.at, req.deadline, resp)
            }
            None => Response::shed(req.at),
        }
    }

    fn submit(&self, req: Request) -> Submitted {
        if self.server.try_submit_with(req.query, req.priority) {
            Submitted::Queued
        } else {
            Submitted::Shed
        }
    }

    fn describe(&self) -> String {
        format!("server({} workers, {})", self.server.threads(), self.server.sched().describe())
    }

    fn in_flight(&self) -> Option<usize> {
        Some(self.server.queue_len())
    }

    fn epoch_view(&self) -> Option<Arc<EpochStore>> {
        self.server.epoch_view()
    }
}

/// The distributed tier: the scatter-gather router in simulated time.
/// Clones share one router; keep a clone to read the distributed
/// report ([`RouterEngine::dist_report`]) after a driven run, and to
/// ship ingestion publishes into the tier ([`RouterEngine::publish`]).
#[derive(Clone)]
pub struct RouterEngine {
    router: Arc<Mutex<Router>>,
    registry: Arc<Registry>,
    sampler: Arc<TraceSampler>,
    /// End-to-end latency histograms fed per request (merged + per
    /// class) — the continuous collector's windowed p50/p99 source.
    lat_all: Histogram,
    lat_class: [Histogram; N_QUERY_CLASSES],
    desc: String,
}

impl RouterEngine {
    pub fn new(router: Router) -> RouterEngine {
        let desc = format!(
            "router({}, {} nodes x{} replicas, {} shards)",
            router.routing().name(),
            router.n_nodes(),
            router.placement.replicas,
            router.placement.n_shards()
        );
        let registry = Arc::new(Registry::new());
        let lat_all = registry.histogram("request_latency");
        let lat_class = QUERY_CLASSES
            .map(|c| registry.histogram(&format!("request_latency_{}", c.name())));
        RouterEngine {
            router: Arc::new(Mutex::new(router)),
            registry,
            sampler: Arc::new(TraceSampler::new()),
            lat_all,
            lat_class,
            desc,
        }
    }

    /// The tier's metrics registry (per-stage `stage_*` histograms in
    /// simulated seconds, counters folded in by the bench harness).
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// The tier's trace sampler (`--trace-sample` / `--slow-ms`).
    pub fn sampler(&self) -> &Arc<TraceSampler> {
        &self.sampler
    }

    /// Read-only access to the shared router (placement, counters).
    pub fn with_router<T>(&self, f: impl FnOnce(&Router) -> T) -> T {
        f(&self.router.lock().unwrap())
    }

    /// Mutable access to the shared router — the control plane's seam:
    /// a controller ticking between arrivals reads per-node/per-shard
    /// load through it and initiates live migration
    /// ([`Router::rebalance_to`]) against the same router the drive is
    /// executing on.
    pub fn with_router_mut<T>(&self, f: impl FnOnce(&mut Router) -> T) -> T {
        f(&mut self.router.lock().unwrap())
    }

    /// Ship an ingestion publish to the replica tier at simulated time
    /// `now`: delta rows ride the fabric to every touched replica and
    /// each node applies the epoch when its transfer lands.
    pub fn publish(&self, now: f64, report: &IngestReport) {
        self.router.lock().unwrap().publish(
            now,
            Arc::clone(&report.published),
            &report.touched,
        );
    }

    /// Assemble the distributed-tier report: the drive's latency and
    /// disposition counters joined with the router's per-node load,
    /// fabric traffic, failover and replication-lag records.
    pub fn dist_report(&self, drive: &DriveReport) -> DistReport {
        self.router.lock().unwrap().report(drive)
    }

    /// One telemetry snapshot per simulated node at simulated time
    /// `now`, for the continuous collector: cumulative served count,
    /// busy seconds, and the applied epoch. A node the router knows to
    /// be dead samples `None` (→ gapped window); liveness advances
    /// with traffic, so a scheduled kill becomes visible at the first
    /// request after it.
    pub fn node_samples(&self, now: f64) -> Vec<Option<obs::Snapshot>> {
        self.with_router(|r| {
            (0..r.n_nodes())
                .map(|n| {
                    if !r.node_alive(n) {
                        return None;
                    }
                    let mut s = obs::Snapshot::default();
                    s.counters.insert("node_served".to_string(), r.served_per_node[n]);
                    s.gauges.insert("node_busy_s".to_string(), r.busy_per_node[n]);
                    s.gauges
                        .insert("applied_epoch".to_string(), r.node_applied_epoch(n, now) as f64);
                    Some(s)
                })
                .collect()
        })
    }
}

impl QueryEngine for RouterEngine {
    fn call(&self, req: Request) -> Response {
        let mut r = self.router.lock().unwrap();
        let subs0: u64 = r.served_per_node.iter().sum();
        let bytes0 = r.fabric.bytes_moved;
        let hedges0 = r.hedges;
        let wins0 = r.hedge_wins;
        let lagged0 = r.lagged_subqueries;
        let (result, done, spans) =
            r.execute_traced(req.at, &req.query, req.hedge, req.consistency);
        let subs1: u64 = r.served_per_node.iter().sum();
        let trace = Trace {
            outcome: if result.is_some() { Outcome::Served } else { Outcome::Failed },
            cache_hit: false,
            replicas_contacted: (subs1 - subs0) as u32,
            hedges: (r.hedges - hedges0) as u32,
            hedge_wins: (r.hedge_wins - wins0) as u32,
            fabric_bytes: r.fabric.bytes_moved - bytes0,
            stale_content: r.lagged_subqueries > lagged0,
            trace_id: req.trace_id,
            spans,
            server_spans: Default::default(),
        };
        drop(r);
        self.registry.record_spans(&spans);
        let total = done - req.at;
        self.lat_all.record(total);
        self.lat_class[req.class.index()].record(total);
        if self.sampler.enabled() {
            self.sampler.observe(TraceRecord {
                trace_id: req.trace_id,
                total_s: done - req.at,
                spans,
                server_spans: Default::default(),
                slow: false,
            });
        }
        enforce_deadline(req.at, req.deadline, Response { result, done, trace })
    }

    fn describe(&self) -> String {
        self.desc.clone()
    }

    fn metrics(&self) -> Vec<(String, f64)> {
        let r = self.router.lock().unwrap();
        vec![
            ("router_failed".to_string(), r.failed as f64),
            ("router_failovers".to_string(), r.failover.n as f64),
            ("router_hedges".to_string(), r.hedges as f64),
            ("router_hedge_wins".to_string(), r.hedge_wins as f64),
            ("router_hedge_cancels".to_string(), r.hedge_cancels as f64),
            ("router_hedge_cancel_saved_s".to_string(), r.hedge_cancel_saved_s),
            ("router_migrations".to_string(), r.migrations as f64),
            ("router_migrated_bytes".to_string(), r.migrated_bytes),
            ("router_fabric_bytes".to_string(), r.fabric.bytes_moved),
            ("router_epochs_published".to_string(), r.epochs_published as f64),
            ("router_delta_bytes".to_string(), r.delta_bytes),
            ("router_stale_refusals".to_string(), r.stale_refusals as f64),
            ("router_stale_waits".to_string(), r.stale_waits.n as f64),
            ("router_lagged_subqueries".to_string(), r.lagged_subqueries as f64),
        ]
    }

    fn epoch_view(&self) -> Option<Arc<EpochStore>> {
        // the router's head is its version truth: replicas lag it, the
        // cache invalidates against it
        Some(self.router.lock().unwrap().head())
    }
}
