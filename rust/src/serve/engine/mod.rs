//! The unified serving engine API: one request envelope, one trait,
//! composable middleware.
//!
//! Before this module the serving stack had three incompatible entry
//! points — `query::execute(store, q)`, `Server::call(q)`, and
//! `Router::execute(now, q)` — so load generation, caching, and fault
//! handling were reimplemented (or missing) per tier. Now every tier is
//! a [`QueryEngine`]:
//!
//! ```text
//!             Request { query, at, deadline, consistency, hedge }
//!                               │
//!   Admission ── shed beyond an in-flight bound
//!       │
//!    Cached ──── per-class LRU (hit rate, fabric bytes saved)
//!       │
//!    Hedged ──── stamps a replica hedge budget on the envelope
//!       │
//!   tier: ScanEngine | DirectEngine | ServerEngine | RouterEngine
//!                               │
//!             Response { result, done, trace }
//! ```
//!
//! The clock abstraction in [`drive`] lets the wall-clock worker-pool
//! tier and the simulated-time distributed tier share one open-loop /
//! closed-loop driver. Results are byte-identical across tiers and
//! middleware stacks by construction: every tier bottoms out in the
//! same per-shard execute + canonical merge.

pub mod admission;
pub mod cache;
pub mod drive;
pub mod hedge;
pub mod tiers;

pub use admission::Admission;
pub use cache::{Cached, Coverage, ResultCache};
pub use drive::{
    drive_closed_loop, drive_open_loop, drive_open_loop_with, Clock, DriveReport, SimClock,
    WallClock,
};
pub use hedge::Hedged;
pub use tiers::{DirectEngine, RouterEngine, ScanEngine, ServerEngine};

use std::sync::Arc;

use super::ingest::EpochStore;
use super::query::{Query, QueryClass, QueryResult};

/// Request priority — the admission-control tier of a request, distinct
/// from its [`QueryClass`] (what the query *costs*). Under overload the
/// graded [`Admission`] layer sheds low-priority expensive requests
/// first and high-priority cheap ones last (see [`admit_fraction`]);
/// the worker-pool scheduler drains higher priorities first.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    /// best-effort: bulk validation scans, backfills
    Low,
    /// the envelope default; every pre-priority constructor maps here
    #[default]
    Normal,
    /// interactive / latency-budgeted traffic
    High,
}

pub const N_PRIORITIES: usize = 3;

pub const PRIORITIES: [Priority; N_PRIORITIES] =
    [Priority::Low, Priority::Normal, Priority::High];

impl Priority {
    pub fn index(self) -> usize {
        match self {
            Priority::Low => 0,
            Priority::Normal => 1,
            Priority::High => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }

    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "low" => Some(Priority::Low),
            "normal" => Some(Priority::Normal),
            "high" => Some(Priority::High),
            _ => None,
        }
    }
}

/// The fraction of the admission depth available to a `(priority,
/// class)` combination — the class-ordering contract the graded
/// [`Admission`] layer enforces under overload. The total order is
/// pinned by tests, not assumed: for a fixed priority the fraction
/// strictly falls with [`QueryClass::cost_rank`] (expensive sheds
/// first), for a fixed class it strictly rises with priority, high-
/// priority cones keep the full depth, and low-priority cross-matches
/// are globally first to shed. Priorities dominate: every `High`
/// fraction exceeds every `Normal` one, which exceeds every `Low` one.
pub fn admit_fraction(priority: Priority, class: QueryClass) -> f64 {
    let base = match priority {
        Priority::Low => 0.35,
        Priority::Normal => 0.60,
        Priority::High => 0.85,
    };
    // class span (0.15 across the four cost ranks) stays inside one
    // priority band (0.25 between bases), so priority strictly
    // dominates; high-priority cones land exactly at the full depth
    base + 0.05 * (3 - class.cost_rank()) as f64
}

/// How stale a response the caller tolerates, in catalog epochs (see
/// [`crate::serve::ingest`]): live ingestion publishes new epochs while
/// queries are in flight, and this hint decides what each layer may
/// serve — which cache entries still count and which lagging replicas
/// the distributed router may route to.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Consistency {
    /// A cached result (if any layer holds one) is acceptable, and any
    /// replica may serve regardless of how far it lags the latest
    /// published epoch. Epoch-invalid cache entries are still dropped —
    /// `CachedOk` tolerates replica lag, not known-stale cache data.
    #[default]
    CachedOk,
    /// Bounded staleness: accept cache entries filled at most `k`
    /// epochs ago and replicas lagging at most `k` epochs behind the
    /// latest publish. `AtMost(0)` is equivalent to
    /// [`Consistency::Fresh`] replica selection (but still probes
    /// caches for epoch-exact entries).
    AtMost(u32),
    /// Bypass result caches and execute against the latest epoch; the
    /// distributed router refuses replicas that have not applied every
    /// mutation of the shards the query touches (read-your-writes).
    /// The fresh result still refills caches on the way back.
    Fresh,
}

impl Consistency {
    /// Cache-entry lag tolerance in epochs: `None` = only epoch-exact
    /// entries may serve (the entry's covered ranges are unmutated).
    pub fn max_cache_lag(self) -> Option<u64> {
        match self {
            Consistency::AtMost(k) => Some(k as u64),
            _ => None,
        }
    }
}

/// The request envelope every tier and middleware layer speaks.
#[derive(Clone, Debug)]
pub struct Request {
    /// the typed query to answer
    pub query: Query,
    /// the query's class, stamped at construction from the query shape.
    /// First-class on the envelope so middleware ([`Admission`]'s
    /// graded shed, [`Cached`]'s per-class maps) and the scheduler key
    /// off a typed field instead of re-deriving it per layer.
    pub class: QueryClass,
    /// admission/scheduling priority (default [`Priority::Normal`], so
    /// pre-priority constructors behave unchanged)
    pub priority: Priority,
    /// arrival time on the engine's clock, seconds (simulated or wall)
    pub at: f64,
    /// latency budget, seconds; responses completing later are marked
    /// [`Outcome::DeadlineExceeded`] and their result is dropped
    pub deadline: Option<f64>,
    /// cache tolerance hint, honored by [`Cached`] layers
    pub consistency: Consistency,
    /// replica hedge budget, seconds: replicated tiers issue a second
    /// sub-query when the first exceeds it (stamped by [`Hedged`])
    pub hedge: Option<f64>,
    /// process-unique trace id, stamped at construction and carried
    /// across the wire in `Execute`/`Reply` frames so client- and
    /// server-side spans of one request join into one span tree
    pub trace_id: u64,
}

impl Request {
    /// A plain request: no deadline, cached results acceptable, normal
    /// priority. The typed class is stamped from the query here, once.
    pub fn new(query: Query) -> Request {
        let class = query.class();
        Request {
            query,
            class,
            priority: Priority::Normal,
            at: 0.0,
            deadline: None,
            consistency: Consistency::CachedOk,
            hedge: None,
            trace_id: super::obs::next_trace_id(),
        }
    }

    /// Set the arrival time on the engine's clock.
    pub fn arriving_at(mut self, at: f64) -> Request {
        self.at = at;
        self
    }

    /// Set a latency budget in seconds.
    pub fn with_deadline(mut self, deadline: f64) -> Request {
        self.deadline = Some(deadline);
        self
    }

    /// Require a freshly executed (uncached) result.
    pub fn fresh(mut self) -> Request {
        self.consistency = Consistency::Fresh;
        self
    }

    /// Tolerate at most `k` epochs of staleness (cache and replicas).
    pub fn at_most(mut self, epochs: u32) -> Request {
        self.consistency = Consistency::AtMost(epochs);
        self
    }

    /// Set the admission/scheduling priority.
    pub fn with_priority(mut self, priority: Priority) -> Request {
        self.priority = priority;
        self
    }
}

/// How the engine disposed of a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// answered; `result` is `Some`
    Served,
    /// rejected at admission (queue/backlog bound)
    Shed,
    /// unanswerable (e.g. every replica of a needed range is dead)
    Failed,
    /// answered too late for the request's deadline; result dropped
    DeadlineExceeded,
}

/// Per-request accounting, filled in by whichever layers touched the
/// request on its way down the stack.
#[derive(Clone, Debug)]
pub struct Trace {
    pub outcome: Outcome,
    /// served from a [`Cached`] layer without reaching the tier
    pub cache_hit: bool,
    /// replica sub-queries dispatched (including failover + hedges)
    pub replicas_contacted: u32,
    /// speculative second sub-queries issued past the hedge budget
    pub hedges: u32,
    /// hedges whose reply beat the primary replica's
    pub hedge_wins: u32,
    /// fabric bytes this request moved (0 on local tiers / cache hits)
    pub fabric_bytes: f64,
    /// some sub-query was served from replica content older than the
    /// latest published epoch (lag-tolerant reads only). [`Cached`]
    /// refuses to fill from such responses: a stale result stamped
    /// with head coverage would otherwise look epoch-exact forever.
    pub stale_content: bool,
    /// the request's trace id, echoed back so asynchronous observers
    /// can join this response to its request (0 = untraced path)
    pub trace_id: u64,
    /// per-stage client-side (front-end) span timings; the stages
    /// partition `done - at` for tiers that fill them (see
    /// [`crate::serve::obs`])
    pub spans: super::obs::SpanSet,
    /// server-side stage timings returned in tcp `Reply` frames,
    /// summed over contacted servers (empty on single-process tiers)
    pub server_spans: super::obs::SpanSet,
}

impl Default for Trace {
    fn default() -> Trace {
        Trace {
            outcome: Outcome::Served,
            cache_hit: false,
            replicas_contacted: 0,
            hedges: 0,
            hedge_wins: 0,
            fabric_bytes: 0.0,
            stale_content: false,
            trace_id: 0,
            spans: super::obs::SpanSet::new(),
            server_spans: super::obs::SpanSet::new(),
        }
    }
}

/// What comes back: the result (if served), the completion time on the
/// engine's clock, and the per-request trace.
#[derive(Clone, Debug)]
pub struct Response {
    pub result: Option<QueryResult>,
    /// completion time, seconds on the same clock as `Request::at`
    pub done: f64,
    pub trace: Trace,
}

impl Response {
    /// A successful response completing at `done`.
    pub fn served(result: QueryResult, done: f64) -> Response {
        Response { result: Some(result), done, trace: Trace::default() }
    }

    /// A response shed at admission time `at`.
    pub fn shed(at: f64) -> Response {
        Response {
            result: None,
            done: at,
            trace: Trace { outcome: Outcome::Shed, ..Trace::default() },
        }
    }

    /// A failed response (no surviving replica for a needed range).
    pub fn failed(done: f64) -> Response {
        Response {
            result: None,
            done,
            trace: Trace { outcome: Outcome::Failed, ..Trace::default() },
        }
    }
}

/// Apply a request's deadline to a tier response: served results that
/// completed past `at + deadline` are dropped and re-marked. Tiers call
/// this on their way out so every engine enforces deadlines uniformly.
pub fn enforce_deadline(at: f64, deadline: Option<f64>, mut resp: Response) -> Response {
    if let Some(d) = deadline {
        if resp.trace.outcome == Outcome::Served && resp.done - at > d {
            resp.trace.outcome = Outcome::DeadlineExceeded;
            resp.result = None;
        }
    }
    resp
}

/// Outcome of an open-loop (fire-and-forget) submission.
#[derive(Clone, Debug)]
pub enum Submitted {
    /// accepted into an asynchronous queue; the engine accounts for the
    /// completion internally (wall-clock worker pools)
    Queued,
    /// rejected at admission
    Shed,
    /// completed synchronously (simulated-time tiers, cache hits)
    Done(Response),
}

/// One serving engine: a tier (scan, direct, worker-pool server,
/// distributed router) or a middleware layer wrapping another engine.
///
/// Engines are shared-reference callable (`&self`) so one stack can
/// serve many client threads; layers that keep state use interior
/// mutability.
pub trait QueryEngine: Send + Sync {
    /// Answer a request synchronously (closed-loop shape).
    fn call(&self, req: Request) -> Response;

    /// Open-loop submission. Engines with an internal queue return
    /// [`Submitted::Queued`]/[`Submitted::Shed`]; synchronous engines
    /// default to completing the call inline.
    fn submit(&self, req: Request) -> Submitted {
        Submitted::Done(self.call(req))
    }

    /// Human-readable description of this engine and everything below
    /// it, outermost layer first (echoed by `serve-bench` before a run).
    fn describe(&self) -> String;

    /// Queued-but-unserved request count for engines with a real queue
    /// (`None` for synchronous engines). [`Admission`] layers probe this
    /// before falling back to their own completion-time backlog model.
    fn in_flight(&self) -> Option<usize> {
        None
    }

    /// Cumulative counters of this engine plus every layer below it,
    /// as `(name, value)` pairs.
    fn metrics(&self) -> Vec<(String, f64)> {
        Vec::new()
    }

    /// The catalog epoch this engine currently serves (`None` for
    /// engines over a fixed store). Middleware forwards it; the
    /// [`Cached`] layer reads it to stamp entries with the shard-epoch
    /// coverage they were computed over and to invalidate entries whose
    /// covered ranges have since mutated.
    fn epoch_view(&self) -> Option<Arc<EpochStore>> {
        None
    }
}

impl QueryEngine for Box<dyn QueryEngine> {
    fn call(&self, req: Request) -> Response {
        self.as_ref().call(req)
    }

    fn submit(&self, req: Request) -> Submitted {
        self.as_ref().submit(req)
    }

    fn describe(&self) -> String {
        self.as_ref().describe()
    }

    fn in_flight(&self) -> Option<usize> {
        self.as_ref().in_flight()
    }

    fn metrics(&self) -> Vec<(String, f64)> {
        self.as_ref().metrics()
    }

    fn epoch_view(&self) -> Option<Arc<EpochStore>> {
        self.as_ref().epoch_view()
    }
}

/// Middleware: stamp a default consistency on requests that carry the
/// envelope default. Lets a driver or bench run a whole query stream
/// at `Fresh` or `AtMost(k)` without touching the load generator;
/// explicitly non-default requests pass through untouched.
pub struct Consistent<E> {
    inner: E,
    level: Consistency,
}

impl<E: QueryEngine> Consistent<E> {
    pub fn new(inner: E, level: Consistency) -> Consistent<E> {
        Consistent { inner, level }
    }

    fn stamp(&self, mut req: Request) -> Request {
        if req.consistency == Consistency::default() {
            req.consistency = self.level;
        }
        req
    }
}

impl<E: QueryEngine> QueryEngine for Consistent<E> {
    fn call(&self, req: Request) -> Response {
        self.inner.call(self.stamp(req))
    }

    fn submit(&self, req: Request) -> Submitted {
        self.inner.submit(self.stamp(req))
    }

    fn describe(&self) -> String {
        format!("consistency({:?}) -> {}", self.level, self.inner.describe())
    }

    fn in_flight(&self) -> Option<usize> {
        self.inner.in_flight()
    }

    fn metrics(&self) -> Vec<(String, f64)> {
        self.inner.metrics()
    }

    fn epoch_view(&self) -> Option<Arc<EpochStore>> {
        self.inner.epoch_view()
    }
}

/// Which middleware layers to stack on a tier (0 / 0.0 disables a
/// layer). Order, outermost first: admission, cache, hedge.
#[derive(Clone, Debug, Default)]
pub struct LayerSpec {
    /// [`Admission`] in-flight bound (0 = no admission layer)
    pub admit_depth: usize,
    /// grade the admission bound by `(priority, class)` (see
    /// [`admit_fraction`]) instead of shedding uniformly at the depth.
    /// Off by default: the plain bound is the historical behavior.
    pub graded_admission: bool,
    /// [`Cached`] entries per query class (0 = no cache layer)
    pub cache_entries: usize,
    /// [`Hedged`] replica budget, seconds (<= 0 = no hedge layer)
    pub hedge_budget: f64,
    /// max fraction of requests the hedge layer may hedge (<= 0 =
    /// uncapped): hedges past the budget are skipped and counted
    pub hedge_cap: f64,
}

/// Build the standard layered stack over a boxed tier.
pub fn layered(base: Box<dyn QueryEngine>, spec: &LayerSpec) -> Box<dyn QueryEngine> {
    let mut engine = base;
    if spec.hedge_budget > 0.0 {
        engine = Box::new(Hedged::with_cap(engine, spec.hedge_budget, spec.hedge_cap));
    }
    if spec.cache_entries > 0 {
        engine = Box::new(Cached::new(engine, spec.cache_entries));
    }
    if spec.admit_depth > 0 {
        engine = Box::new(if spec.graded_admission {
            Admission::graded(engine, spec.admit_depth)
        } else {
            Admission::new(engine, spec.admit_depth)
        });
    }
    engine
}

/// Look up one cumulative counter from an engine stack by name.
pub fn metric(engine: &dyn QueryEngine, name: &str) -> Option<f64> {
    engine.metrics().into_iter().find(|(n, _)| n == name).map(|(_, v)| v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::query::{SourceFilter, QUERY_CLASSES};

    #[test]
    fn request_stamps_typed_class_and_default_priority() {
        let q = Query::CrossMatch { pos: (1.0, 2.0), radius: 0.5 };
        let req = Request::new(q);
        assert_eq!(req.class, QueryClass::CrossMatch);
        assert_eq!(req.class, req.query.class(), "envelope class mirrors the query");
        assert_eq!(req.priority, Priority::Normal, "old constructors stay Normal");
        let req = req.with_priority(Priority::High);
        assert_eq!(req.priority, Priority::High);
    }

    /// The class-ordering contract, asserted rather than assumed: shed
    /// order under overload is exactly the `admit_fraction` total order.
    #[test]
    fn admit_fractions_pin_the_shed_order() {
        // (a) for a fixed priority, fractions strictly fall with cost:
        // expensive classes shed before cheap ones
        for p in PRIORITIES {
            for w in QUERY_CLASSES.windows(2) {
                assert!(
                    admit_fraction(p, w[0]) > admit_fraction(p, w[1]),
                    "{:?}: {:?} must outlast {:?}",
                    p,
                    w[0],
                    w[1]
                );
            }
        }
        // (b) for a fixed class, fractions strictly rise with priority
        for c in QUERY_CLASSES {
            assert!(admit_fraction(Priority::Low, c) < admit_fraction(Priority::Normal, c));
            assert!(admit_fraction(Priority::Normal, c) < admit_fraction(Priority::High, c));
        }
        // (c) priority dominates class: the cheapest low-priority query
        // still sheds before the costliest normal-priority one, etc.
        assert!(
            admit_fraction(Priority::Low, QueryClass::Cone)
                < admit_fraction(Priority::Normal, QueryClass::CrossMatch)
        );
        assert!(
            admit_fraction(Priority::Normal, QueryClass::Cone)
                < admit_fraction(Priority::High, QueryClass::CrossMatch)
        );
        // (d) the extremes: high-priority cones keep the full depth,
        // low-priority cross-matches are globally first to shed
        assert_eq!(admit_fraction(Priority::High, QueryClass::Cone), 1.0);
        let min = admit_fraction(Priority::Low, QueryClass::CrossMatch);
        for p in PRIORITIES {
            for c in QUERY_CLASSES {
                let f = admit_fraction(p, c);
                assert!(f >= min && f <= 1.0, "{p:?}/{c:?} fraction {f} out of range");
            }
        }
    }

    #[test]
    fn priority_parse_and_order() {
        assert_eq!(Priority::parse("low"), Some(Priority::Low));
        assert_eq!(Priority::parse("normal"), Some(Priority::Normal));
        assert_eq!(Priority::parse("high"), Some(Priority::High));
        assert_eq!(Priority::parse("urgent"), None);
        assert!(Priority::Low < Priority::Normal && Priority::Normal < Priority::High);
        for (i, p) in PRIORITIES.iter().enumerate() {
            assert_eq!(p.index(), i);
        }
        let q = Query::BrightestN { n: 1, filter: SourceFilter::Any };
        assert_eq!(Request::new(q).priority, Priority::default());
    }
}
