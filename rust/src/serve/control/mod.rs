//! The adaptive control plane: a periodic controller that watches the
//! distributed tier's own telemetry counters and answers with placement
//! *decisions* — live range migration off hot nodes, and growing or
//! shrinking the serving membership under an autoscale band.
//!
//! The controller is deliberately mechanism-free: it never touches a
//! router. Each [`Controller::tick`] receives cumulative per-node and
//! per-shard counters (exactly what [`crate::serve::dist::Router`]
//! already exposes, and what the TCP tier reports over the wire), diffs
//! them into a window, and returns a **target placement** when it wants
//! the world to change. The caller applies the target through the
//! tier's own migration seam ([`crate::serve::dist::Router::rebalance_to`]),
//! which moves only the replica-set difference and keeps the outgoing
//! copies serving until each snapshot transfer lands — so a decision
//! here never fails an in-flight query.
//!
//! Two policies share the windowed view:
//!
//! * **Hot-range relief**: when one node's sub-query share exceeds
//!   [`ControlConfig::hot_ratio`] times the per-member mean, its hosted
//!   shards are re-homed in descending window-demand order — each to
//!   the rendezvous choice among the *other* members — until the
//!   expected relief covers the excess or
//!   [`ControlConfig::max_moves`] is hit. Quiet shards never move.
//! * **Autoscale** (opt-in via [`ControlConfig::autoscale`]): when the
//!   members' mean busy fraction over the window crosses
//!   [`ControlConfig::scale_up_busy`], the smallest idle node joins and
//!   the placement is re-derived over the grown membership (rendezvous
//!   minimal-move: only replicas re-homing onto the newcomer travel).
//!   Below [`ControlConfig::scale_down_busy`] the least-loaded member
//!   retires the same way. Membership stays inside the configured
//!   `min..max` band and node capacity is fixed at construction — an
//!   autoscaled tier starts with its headroom allocated and the
//!   placement confined to the floor members (see
//!   [`crate::serve::dist::Router::new_among`]).
//!
//! Every decision is appended to a [`DecisionLog`] — the audit trail
//! the observability dump publishes (`serve-bench --obs-dump`), so a
//! migration or scale event is attributable after the fact.

use crate::serve::dist::Placement;

/// One node's cumulative load counters, sampled at a tick. `served`
/// and `busy_s` are lifetime totals (the controller diffs consecutive
/// samples itself); a dead node still reports its last totals.
#[derive(Clone, Copy, Debug, Default)]
pub struct NodeLoad {
    pub alive: bool,
    /// sub-queries served, cumulative
    pub served: u64,
    /// service seconds consumed, cumulative
    pub busy_s: f64,
}

/// Controller policy knobs. Defaults are conservative: tick every
/// 250ms of tier time, relieve at 1.5x mean, autoscale off.
#[derive(Clone, Debug)]
pub struct ControlConfig {
    /// seconds of tier time between decision windows
    pub period_s: f64,
    /// `Some((min, max))` enables membership scaling inside the band
    pub autoscale: Option<(usize, usize)>,
    /// relieve a node once its window share exceeds this multiple of
    /// the per-member mean
    pub hot_ratio: f64,
    /// members' mean busy fraction above which a node is added
    pub scale_up_busy: f64,
    /// members' mean busy fraction below which a member retires
    pub scale_down_busy: f64,
    /// windows to sit out after any decision (lets the tier absorb the
    /// change before it is judged again)
    pub cooldown_periods: u32,
    /// most shard moves per rebalance decision
    pub max_moves: usize,
    /// windows with fewer sub-queries than this are too quiet to judge
    pub min_window_subqueries: u64,
}

impl Default for ControlConfig {
    fn default() -> Self {
        ControlConfig {
            period_s: 0.25,
            autoscale: None,
            hot_ratio: 1.5,
            scale_up_busy: 0.75,
            scale_down_busy: 0.25,
            cooldown_periods: 1,
            max_moves: 8,
            min_window_subqueries: 32,
        }
    }
}

/// One logged control decision.
#[derive(Clone, Debug)]
pub enum ControlEvent {
    /// Hot-range relief: `shards_moved` replicas re-homed off
    /// `hot_node`, which held `imbalance`x the per-member mean.
    Rebalance { at: f64, hot_node: usize, imbalance: f64, shards_moved: usize },
    /// `node` joined the membership (now `members` strong) because the
    /// members' mean busy fraction reached `busy_frac`.
    ScaleUp { at: f64, node: usize, busy_frac: f64, members: usize },
    /// `node` retired from the membership (now `members` strong).
    ScaleDown { at: f64, node: usize, busy_frac: f64, members: usize },
}

impl ControlEvent {
    /// Tier time the decision was taken at.
    pub fn at(&self) -> f64 {
        match *self {
            ControlEvent::Rebalance { at, .. }
            | ControlEvent::ScaleUp { at, .. }
            | ControlEvent::ScaleDown { at, .. } => at,
        }
    }

    /// One JSON object (manual formatting, same idiom as the obs dump).
    pub fn to_json(&self) -> String {
        match *self {
            ControlEvent::Rebalance { at, hot_node, imbalance, shards_moved } => format!(
                "{{\"event\":\"rebalance\",\"at\":{at:.6},\"hot_node\":{hot_node},\
                 \"imbalance\":{imbalance:.3},\"shards_moved\":{shards_moved}}}"
            ),
            ControlEvent::ScaleUp { at, node, busy_frac, members } => format!(
                "{{\"event\":\"scale_up\",\"at\":{at:.6},\"node\":{node},\
                 \"busy_frac\":{busy_frac:.3},\"members\":{members}}}"
            ),
            ControlEvent::ScaleDown { at, node, busy_frac, members } => format!(
                "{{\"event\":\"scale_down\",\"at\":{at:.6},\"node\":{node},\
                 \"busy_frac\":{busy_frac:.3},\"members\":{members}}}"
            ),
        }
    }

    pub fn describe(&self) -> String {
        match *self {
            ControlEvent::Rebalance { at, hot_node, imbalance, shards_moved } => format!(
                "t={at:.3}s rebalance: node {hot_node} at {imbalance:.2}x mean, \
                 {shards_moved} shard(s) re-homed"
            ),
            ControlEvent::ScaleUp { at, node, busy_frac, members } => format!(
                "t={at:.3}s scale-up: node {node} joins ({members} member(s), \
                 busy {:.0}%)",
                busy_frac * 100.0
            ),
            ControlEvent::ScaleDown { at, node, busy_frac, members } => format!(
                "t={at:.3}s scale-down: node {node} retires ({members} member(s), \
                 busy {:.0}%)",
                busy_frac * 100.0
            ),
        }
    }
}

/// The controller's audit trail: every decision, in tier-time order.
#[derive(Clone, Debug, Default)]
pub struct DecisionLog {
    pub events: Vec<ControlEvent>,
}

impl DecisionLog {
    /// JSON array of decision objects (for the observability dump).
    pub fn to_json(&self) -> String {
        let items: Vec<String> = self.events.iter().map(|e| e.to_json()).collect();
        format!("[{}]", items.join(","))
    }

    pub fn rebalances(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e, ControlEvent::Rebalance { .. }))
            .count()
    }

    pub fn scale_events(&self) -> usize {
        self.events.len() - self.rebalances()
    }

    /// Multi-line human summary: the counts line, then one line per
    /// decision.
    pub fn summary(&self) -> String {
        let mut out = format!(
            "control: {} decision(s) ({} rebalance, {} scale)",
            self.events.len(),
            self.rebalances(),
            self.scale_events()
        );
        for e in &self.events {
            out.push_str("\n  ");
            out.push_str(&e.describe());
        }
        out
    }
}

/// The periodic decision loop. Construct over the tier's node capacity
/// and initial placement membership, then [`Controller::tick`] it with
/// fresh counters as tier time advances (the drivers do this between
/// arrivals); apply any returned target through the tier's migration
/// seam.
pub struct Controller {
    cfg: ControlConfig,
    /// fixed node capacity of the tier (fabric + accounting size)
    capacity: usize,
    /// current placement membership, ascending
    members: Vec<usize>,
    /// tier time the next window closes at
    next_at: f64,
    /// tier time the last window closed at
    last_at: f64,
    /// windows left to sit out after a decision
    cooldown: u32,
    /// cumulative (served, busy_s) per node at the last window close
    prev_node: Vec<(u64, f64)>,
    /// cumulative served per shard at the last window close
    prev_shard: Vec<u64>,
    log: DecisionLog,
}

impl Controller {
    pub fn new(cfg: ControlConfig, capacity: usize, members: &[usize]) -> Controller {
        let capacity = capacity.max(1);
        let mut members: Vec<usize> =
            members.iter().copied().filter(|&m| m < capacity).collect();
        members.sort_unstable();
        members.dedup();
        if members.is_empty() {
            members.push(0);
        }
        Controller {
            next_at: cfg.period_s,
            cfg,
            capacity,
            members,
            last_at: 0.0,
            cooldown: 0,
            prev_node: Vec::new(),
            prev_shard: Vec::new(),
            log: DecisionLog::default(),
        }
    }

    /// The membership the controller currently intends (the tier's
    /// placement converges to it as migrations land).
    pub fn members(&self) -> &[usize] {
        &self.members
    }

    pub fn log(&self) -> &DecisionLog {
        &self.log
    }

    /// Close a decision window at tier time `now` if one is due.
    /// `nodes` and `served_per_shard` are the tier's *cumulative*
    /// counters; `placement` is its live placement. Returns the target
    /// placement to migrate toward, or `None` when nothing should
    /// change. Cheap when no window is due — callers tick on every
    /// arrival.
    pub fn tick(
        &mut self,
        now: f64,
        nodes: &[NodeLoad],
        served_per_shard: &[u64],
        placement: &Placement,
    ) -> Option<Placement> {
        if now < self.next_at {
            return None;
        }
        let dt = (now - self.last_at).max(1e-12);
        self.last_at = now;
        self.next_at = now + self.cfg.period_s;
        if self.prev_node.len() != nodes.len() {
            self.prev_node = vec![(0, 0.0); nodes.len()];
        }
        if self.prev_shard.len() != served_per_shard.len() {
            self.prev_shard = vec![0; served_per_shard.len()];
        }
        // diff the cumulative counters into this window's deltas
        let node_delta: Vec<(u64, f64)> = nodes
            .iter()
            .zip(&self.prev_node)
            .map(|(n, p)| (n.served.saturating_sub(p.0), (n.busy_s - p.1).max(0.0)))
            .collect();
        for (p, n) in self.prev_node.iter_mut().zip(nodes) {
            *p = (n.served, n.busy_s);
        }
        let shard_delta: Vec<u64> = served_per_shard
            .iter()
            .zip(&self.prev_shard)
            .map(|(s, p)| s.saturating_sub(*p))
            .collect();
        self.prev_shard.copy_from_slice(served_per_shard);
        if self.cooldown > 0 {
            self.cooldown -= 1;
            return None;
        }
        let window_subs: u64 = node_delta.iter().map(|d| d.0).sum();
        if window_subs < self.cfg.min_window_subqueries {
            return None;
        }
        if let Some(t) = self.autoscale(now, dt, nodes, &node_delta, placement) {
            return Some(t);
        }
        self.relieve_hot_node(now, nodes, &node_delta, &shard_delta, window_subs, placement)
    }

    /// Grow or shrink the membership on the members' mean busy
    /// fraction over the window.
    fn autoscale(
        &mut self,
        now: f64,
        dt: f64,
        nodes: &[NodeLoad],
        node_delta: &[(u64, f64)],
        placement: &Placement,
    ) -> Option<Placement> {
        let (lo, hi) = self.cfg.autoscale?;
        let live: Vec<usize> =
            self.members.iter().copied().filter(|&m| nodes[m].alive).collect();
        if live.is_empty() {
            return None;
        }
        let busy_frac =
            live.iter().map(|&m| node_delta[m].1).sum::<f64>() / (live.len() as f64 * dt);
        if busy_frac >= self.cfg.scale_up_busy && self.members.len() < hi {
            // the smallest idle node joins — ids stay dense and stable
            let add =
                (0..self.capacity).find(|n| !self.members.contains(n) && nodes[*n].alive)?;
            self.members.push(add);
            self.members.sort_unstable();
            self.cooldown = self.cfg.cooldown_periods;
            self.log.events.push(ControlEvent::ScaleUp {
                at: now,
                node: add,
                busy_frac,
                members: self.members.len(),
            });
            return Some(self.target_for_members(placement));
        }
        if busy_frac <= self.cfg.scale_down_busy && self.members.len() > lo {
            // retire the member with the least window demand, ties to
            // the highest id (early nodes — the origin — stay)
            let mut victim = self.members[0];
            for &m in &self.members {
                let (vs, ms) = (node_delta[victim].0, node_delta[m].0);
                if ms < vs || (ms == vs && m > victim) {
                    victim = m;
                }
            }
            self.members.retain(|&m| m != victim);
            self.cooldown = self.cfg.cooldown_periods;
            self.log.events.push(ControlEvent::ScaleDown {
                at: now,
                node: victim,
                busy_frac,
                members: self.members.len(),
            });
            return Some(self.target_for_members(placement));
        }
        None
    }

    /// Re-home the hottest node's most-demanded shards onto the other
    /// members until the expected relief covers its excess over the
    /// mean.
    fn relieve_hot_node(
        &mut self,
        now: f64,
        nodes: &[NodeLoad],
        node_delta: &[(u64, f64)],
        shard_delta: &[u64],
        window_subs: u64,
        placement: &Placement,
    ) -> Option<Placement> {
        let hot = (0..nodes.len())
            .filter(|&n| nodes[n].alive)
            .max_by_key(|&n| node_delta[n].0)?;
        let hot_served = node_delta[hot].0 as f64;
        let live_members =
            self.members.iter().filter(|&&m| nodes[m].alive).count().max(1);
        let mean = window_subs as f64 / live_members as f64;
        if mean <= 0.0 || hot_served / mean < self.cfg.hot_ratio {
            return None;
        }
        let others: Vec<usize> = self
            .members
            .iter()
            .copied()
            .filter(|&m| m != hot && nodes[m].alive)
            .collect();
        if others.is_empty() {
            return None;
        }
        // where each shard would live if the hot node were not a
        // choice — the per-shard rendezvous answer among the others
        let relief = Placement::rendezvous_among(
            placement.n_shards(),
            self.capacity,
            &others,
            placement.replicas,
        );
        let mut hosted: Vec<usize> = (0..placement.n_shards())
            .filter(|&s| placement.shard_nodes[s].contains(&hot))
            .collect();
        hosted.sort_by(|&a, &b| shard_delta[b].cmp(&shard_delta[a]));
        let need = hot_served - mean;
        let mut target = placement.clone();
        let mut relieved = 0.0;
        let mut moved = 0usize;
        for s in hosted {
            if moved >= self.cfg.max_moves || relieved >= need {
                break;
            }
            if shard_delta[s] == 0 {
                // demand-descending order: everything left is quiet,
                // and quiet shards never move
                break;
            }
            let set = &mut target.shard_nodes[s];
            let Some(slot) = set.iter().position(|&n| n == hot) else { continue };
            let Some(&dst) = relief.shard_nodes[s].iter().find(|n| !set.contains(n))
            else {
                continue;
            };
            set[slot] = dst;
            // a shard's demand is split across its replicas; moving
            // one replica relieves the hot node of its share
            relieved += shard_delta[s] as f64 / set.len() as f64;
            moved += 1;
        }
        if moved == 0 {
            return None;
        }
        self.cooldown = self.cfg.cooldown_periods;
        self.log.events.push(ControlEvent::Rebalance {
            at: now,
            hot_node: hot,
            imbalance: hot_served / mean,
            shards_moved: moved,
        });
        Some(target)
    }

    /// The rendezvous placement over the current membership (minimal
    /// moves from any prior rendezvous placement over an overlapping
    /// membership).
    fn target_for_members(&self, placement: &Placement) -> Placement {
        Placement::rendezvous_among(
            placement.n_shards(),
            self.capacity,
            &self.members,
            placement.replicas,
        )
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::metrics::Stats;
    use crate::serve::dist::{CostModel, Router, RouterConfig};
    use crate::serve::query::{execute, Query, SourceFilter};
    use crate::serve::snapshot;
    use crate::serve::store::Store;

    fn loads(served: &[u64], busy: &[f64]) -> Vec<NodeLoad> {
        served
            .iter()
            .zip(busy)
            .map(|(&s, &b)| NodeLoad { alive: true, served: s, busy_s: b })
            .collect()
    }

    /// Synthetic cumulative counters walk the membership from the
    /// floor to the ceiling under sustained busy nodes, then back down
    /// when the tier goes idle — with every decision logged.
    #[test]
    fn autoscale_grows_to_max_then_shrinks_to_min() {
        let cfg = ControlConfig {
            period_s: 1.0,
            autoscale: Some((2, 4)),
            cooldown_periods: 0,
            min_window_subqueries: 1,
            hot_ratio: f64::INFINITY, // isolate the autoscale policy
            ..Default::default()
        };
        let mut ctl = Controller::new(cfg, 6, &[0, 1]);
        let placement = Placement::rendezvous_among(8, 6, &[0, 1], 2);
        let mut served = [0u64; 6];
        let mut busy = [0.0f64; 6];
        let shards = [0u64; 8];
        let mut grow_targets = 0;
        for t in 1..=4 {
            served[0] += 100;
            for b in busy.iter_mut() {
                *b += 0.9; // busy fraction 0.9 >= 0.75
            }
            if let Some(target) =
                ctl.tick(t as f64, &loads(&served, &busy), &shards, &placement)
            {
                grow_targets += 1;
                for nodes in &target.shard_nodes {
                    for n in nodes {
                        assert!(ctl.members().contains(n), "replica off-membership");
                    }
                }
            }
        }
        assert_eq!(ctl.members(), &[0, 1, 2, 3], "grown to the ceiling, in id order");
        assert_eq!(grow_targets, 2, "two scale-ups: 2 -> 3 -> 4 members");
        // idle: busy stops accumulating, so the fraction drops to zero
        let mut shrink_targets = 0;
        for t in 5..=8 {
            served[0] += 100; // still enough traffic to judge the window
            if ctl.tick(t as f64, &loads(&served, &busy), &shards, &placement).is_some() {
                shrink_targets += 1;
            }
        }
        assert_eq!(ctl.members(), &[0, 1], "shrunk back to the floor");
        assert_eq!(shrink_targets, 2);
        // the least-served members retired first (ids 3 then 2), and
        // the log kept the full story in order
        let log = ctl.log();
        assert_eq!(log.events.len(), 4);
        assert_eq!(log.scale_events(), 4);
        assert_eq!(log.rebalances(), 0);
        assert!(matches!(
            log.events[0],
            ControlEvent::ScaleUp { node: 2, members: 3, .. }
        ));
        assert!(matches!(
            log.events[1],
            ControlEvent::ScaleUp { node: 3, members: 4, .. }
        ));
        assert!(matches!(
            log.events[2],
            ControlEvent::ScaleDown { node: 3, members: 3, .. }
        ));
        assert!(matches!(
            log.events[3],
            ControlEvent::ScaleDown { node: 2, members: 2, .. }
        ));
        let json = log.to_json();
        assert!(json.starts_with('[') && json.ends_with(']'));
        assert!(json.contains("\"event\":\"scale_up\""));
        assert!(json.contains("\"event\":\"scale_down\""));
        assert!(ctl.log().summary().contains("4 decision(s)"));
    }

    /// A quiet window, a cooldown window, and a balanced window must
    /// all decide nothing; a hot window must move exactly the demanded
    /// shards off the hot node and nothing else.
    #[test]
    fn relief_moves_only_the_demanded_shards() {
        let cfg = ControlConfig {
            period_s: 1.0,
            cooldown_periods: 1,
            min_window_subqueries: 32,
            ..Default::default()
        };
        let mut ctl = Controller::new(cfg, 4, &[0, 1, 2, 3]);
        let placement = Placement::rendezvous_among(8, 4, &[0, 1, 2, 3], 1);
        // heat the node hosting the most shards (>= 2 by pigeonhole)
        let counts = placement.counts_per_node();
        let hot = (0..4).max_by_key(|&n| counts[n]).unwrap();
        let hosted: Vec<usize> = (0..8)
            .filter(|&s| placement.shard_nodes[s].contains(&hot))
            .collect();
        assert!(!hosted.is_empty(), "the most-crowded node hosts nothing");
        // window 1: too quiet to judge
        let mut served = [1u64; 4];
        let busy = [0.0f64; 4];
        let mut shards = [0u64; 8];
        assert!(ctl.tick(1.0, &loads(&served, &busy), &shards, &placement).is_none());
        // window 2: all demand on one hosted shard of the hot node
        served[hot] += 300;
        for (m, s) in served.iter_mut().enumerate() {
            if m != hot {
                *s += 10;
            }
        }
        shards[hosted[0]] += 300;
        let target = ctl
            .tick(2.0, &loads(&served, &busy), &shards, &placement)
            .expect("a 300-vs-10 window is hot");
        assert_eq!(ctl.log().rebalances(), 1);
        let mut diffs = Vec::new();
        for s in 0..8 {
            if target.shard_nodes[s] != placement.shard_nodes[s] {
                diffs.push(s);
            }
        }
        assert_eq!(diffs, vec![hosted[0]], "exactly the demanded shard moves");
        assert!(!target.shard_nodes[hosted[0]].contains(&hot));
        match ctl.log().events[0] {
            ControlEvent::Rebalance { hot_node, imbalance, shards_moved, .. } => {
                assert_eq!(hot_node, hot);
                assert!(imbalance > 3.0, "imbalance {imbalance}");
                assert_eq!(shards_moved, 1);
            }
            ref e => panic!("expected a rebalance, got {e:?}"),
        }
        // window 3: cooldown eats it even if still hot
        served[hot] += 300;
        shards[hosted[0]] += 300;
        assert!(ctl.tick(3.0, &loads(&served, &busy), &shards, &placement).is_none());
        // window 4: balanced traffic decides nothing
        for s in served.iter_mut() {
            *s += 100;
        }
        assert!(ctl.tick(4.0, &loads(&served, &busy), &shards, &placement).is_none());
    }

    fn imbalance(served_per_node: &[u64]) -> f64 {
        let max = served_per_node.iter().copied().max().unwrap_or(0) as f64;
        let mean =
            served_per_node.iter().sum::<u64>() as f64 / served_per_node.len() as f64;
        max / mean.max(1e-9)
    }

    /// The ISSUE's acceptance shape, in-tree: under a moving hotspot at
    /// equal offered load, the controlled tier must beat the static one
    /// on BOTH per-node load imbalance (max/mean) and request p99 —
    /// with migrations recorded and zero failed queries.
    ///
    /// The workload is derived from the actual placement so the margin
    /// is structural, not statistical: every query cones into a shard
    /// hosted by the initially most-crowded node, at an offered rate
    /// that supersaturates any single node (~3x one node's service
    /// capacity) while staying far below the tier's aggregate capacity.
    /// Static: every sub-query queues on that one node and the backlog
    /// ramps for the whole run. Controlled: the first decision window
    /// re-homes the demanded shards and the load spreads.
    #[test]
    fn rebalancing_beats_static_under_a_moving_hotspot() {
        let snap = snapshot::synthetic(3200, 77);
        let store = Arc::new(Store::build(snap.sources, snap.width, snap.height, 32));
        let cost = CostModel { base_service: 400e-6, ..Default::default() };
        let rcfg = RouterConfig { cost, ..Default::default() };
        let make_router = || Router::new(Arc::clone(&store), 8, 1, rcfg.clone());
        // the node hosting the most shards (>= 4 by pigeonhole), and
        // four of its populated shards to aim the two hotspot phases at
        let placement0 = make_router().placement.clone();
        let counts = placement0.counts_per_node();
        let crowded =
            (0..8).max_by_key(|&n| counts[n]).expect("eight candidate nodes");
        let hot_shards: Vec<usize> = (0..32)
            .filter(|&s| {
                placement0.shard_nodes[s].contains(&crowded)
                    && !store.shards[s].sources.is_empty()
            })
            .take(4)
            .collect();
        assert!(
            hot_shards.len() >= 2,
            "crowded node hosts {} populated shard(s)",
            hot_shards.len()
        );
        // two phases; each phase alternates cones into a pair of the
        // crowded node's shards (falling back to the first pair when
        // fewer than four are populated)
        let phase_pairs = [
            [hot_shards[0], hot_shards[1 % hot_shards.len()]],
            [
                hot_shards[2 % hot_shards.len()],
                hot_shards[3 % hot_shards.len()],
            ],
        ];
        let dt = 125e-6; // 8000 qps: ~3.2x one node, ~0.4x the tier
        let n_queries = 4000usize; // 0.5s of arrivals
        let queries: Vec<Query> = (0..n_queries)
            .map(|i| {
                let phase = if (i as f64 * dt) < 0.25 { 0 } else { 1 };
                let shard = phase_pairs[phase][i % 2];
                Query::Cone {
                    center: store.shards[shard].sources[0].pos,
                    radius: 2.0,
                    filter: SourceFilter::Any,
                }
            })
            .collect();
        let run = |controlled: bool| {
            let mut router = make_router();
            let mut ctl = Controller::new(
                ControlConfig {
                    period_s: 0.05,
                    cooldown_periods: 0,
                    min_window_subqueries: 16,
                    ..Default::default()
                },
                8,
                &(0..8).collect::<Vec<_>>(),
            );
            let mut lat = Stats::new();
            for (i, q) in queries.iter().enumerate() {
                let at = i as f64 * dt;
                if controlled {
                    let nodes: Vec<NodeLoad> = (0..8)
                        .map(|n| NodeLoad {
                            alive: router.node_alive(n),
                            served: router.served_per_node[n],
                            busy_s: router.busy_per_node[n],
                        })
                        .collect();
                    let shard_served = router.served_per_shard.clone();
                    if let Some(target) =
                        ctl.tick(at, &nodes, &shard_served, &router.placement)
                    {
                        router.rebalance_to(at, &target);
                    }
                }
                let (res, done) = router.execute(at, q);
                assert!(res.is_some(), "query {i} failed");
                lat.push(done - at);
            }
            let imb = imbalance(&router.served_per_node);
            (imb, lat.quantiles(&[0.99])[0], router.migrations, router.failed, ctl)
        };
        let (static_imb, static_p99, static_migrations, static_failed, _) = run(false);
        let (ctl_imb, ctl_p99, migrations, ctl_failed, ctl) = run(true);
        assert_eq!(static_failed, 0);
        assert_eq!(ctl_failed, 0, "a migration failed an in-flight query");
        assert_eq!(static_migrations, 0);
        assert!(migrations > 0, "the controller never moved a range");
        assert!(ctl.log().rebalances() > 0, "decisions must be logged");
        assert!(
            ctl_imb < static_imb * 0.85,
            "imbalance did not improve: controlled {ctl_imb:.2} vs static {static_imb:.2}"
        );
        assert!(
            ctl_p99 < static_p99 * 0.7,
            "p99 did not improve: controlled {:.1}ms vs static {:.1}ms",
            ctl_p99 * 1e3,
            static_p99 * 1e3
        );
        // and correctness held throughout: a post-run probe against the
        // migrated placement still matches brute force
        let mut router = make_router();
        let ctl_target = Placement::rendezvous_among(32, 8, &[1, 3, 5], 1);
        router.rebalance_to(0.0, &ctl_target);
        let q = Query::BrightestN { n: 10, filter: SourceFilter::Any };
        let (res, _) = router.execute(10.0, &q);
        assert_eq!(res.expect("served"), execute(&store, &q));
    }
}
