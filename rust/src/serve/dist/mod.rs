//! Distributed serving: the single-host store, placed across simulated
//! nodes and served through a front-end router.
//!
//! PR 1 made the catalog placement-ready — contiguous Hilbert-key
//! shards with range metadata — and this module turns that into a
//! multi-node serving story *modeled before it is built*, the same way
//! `cluster::sim` modeled the paper's inference scaling (§III-F) before
//! any real interconnect existed:
//!
//! * [`placement`] — rendezvous-hashed range-to-node assignment with a
//!   configurable replication factor (adding a node moves only the
//!   ranges the new node wins).
//! * [`remote`] — the `ShardClient` boundary: `LocalShard` for replicas
//!   colocated with the front-end, `FabricShard` for remote ones whose
//!   request/response bytes ride the `ga::Fabric` NIC/bisection model.
//! * [`router`] — scatter-gather planning per query class with
//!   random / round-robin / power-of-two-choices replica selection,
//!   per-request replica hedging, and — with live ingestion — delta
//!   shipping to replicas, per-node applied-epoch tracking, and
//!   consistency-bound replica selection (`Fresh` refuses lagging
//!   replicas, `AtMost(k)` bounds the lag, `CachedOk` tolerates it).
//! * [`failure`] — kill/revive schedules; the router times out on dead
//!   replicas, reroutes to survivors, and records failover latency.
//!
//! The tier is served through the engine API: wrap a [`Router`] in
//! [`crate::serve::engine::RouterEngine`], stack middleware on it, and
//! drive it with [`crate::serve::engine::drive_open_loop`] on a
//! simulated clock.
//!
//! The model now has a measured counterpart: `crate::serve::net`
//! implements the same placement/scatter/failover shape over real
//! sockets (its `NetShardClient` implements [`ShardClient`], and
//! `serve-bench --transport tcp` swaps the tiers), so every cost the
//! fabric model assumes — serialization, kernel round trips, reconnect
//! — is benchmarked against the simulation that predicted it.
//!
//! Entry point: `celeste serve-bench --dist-nodes N --replicas R
//! --routing {random,rr,p2c} [--kill-node K@T]`.

pub mod failure;
pub mod placement;
pub mod remote;
pub mod router;

pub use failure::{FailureEvent, FailureSchedule};
pub use placement::Placement;
pub use remote::{execute_on_shard, CostModel, FabricShard, LocalShard, ShardClient, ShardReply};
pub use router::{DistReport, Router, RouterConfig, Routing};
