//! The front-end router: scatter-gather query planning over placed
//! shard replicas, load-balanced replica selection, failover, and
//! replica update propagation.
//!
//! Per query class the router plans the minimal shard set — cone/box
//! probes hit only ranges whose bounding boxes intersect, brightest-N
//! does per-replica top-k then a canonical merge, cross-match probes the
//! widened acceptance box — and dispatches each sub-query to one replica
//! chosen by the configured policy:
//!
//! * `random`  — uniform over surviving replicas,
//! * `rr`      — per-shard round-robin,
//! * `p2c`     — power-of-two-choices on per-replica in-flight counts
//!               (the classic "two random choices" result: sampling two
//!               and picking the less loaded collapses queue-length
//!               variance, which is exactly what the p99 tail is).
//!
//! On top of the balanced choice the router honors a per-request
//! *hedge budget* ([`Router::execute_with`], stamped by the engine
//! API's `Hedged` layer): when a replica's reply would land more than
//! the budget past its dispatch, the same sub-query is speculatively
//! issued to the best alternate replica and the earlier reply wins —
//! extra replica load and fabric bytes traded for a shorter p999 tail.
//! The *loser* of a hedge race is cancelled the moment the winning
//! reply lands at the front-end: its remaining service is reclaimed
//! from the replica's serial queue (bounded by the full service cost —
//! cancel-signal propagation is folded into the reply time), its
//! in-flight entry is truncated to the winner's completion, and the
//! cancellation is counted (`hedge_cancels`, seconds reclaimed in
//! `hedge_cancel_saved_s`) — speculation buys tail latency without
//! doubling steady-state replica work.
//!
//! The router is also the control plane's mechanism for *live range
//! migration* ([`Router::rebalance_to`]): diffing the current placement
//! against a target, it moves only the replica-set difference per shard
//! (the minimal-move property rendezvous placements are chosen for),
//! ships each moving replica's shard snapshot over the fabric, and
//! swaps the slot to its destination only when the transfer lands —
//! the outgoing replica keeps serving until then, so queries issued
//! during a move succeed against the old copy.
//!
//! With live ingestion ([`crate::serve::ingest`]) the router is also
//! the tier's replication protocol: [`Router::publish`] ships each
//! epoch's delta rows over the fabric to every node hosting a touched
//! replica, and each node *applies* the epoch when its transfer lands —
//! so replicas lag the head by real (simulated) propagation time. A
//! sub-query executes against the shard content its chosen node has
//! applied, and the consistency hint decides who may serve:
//! `Fresh` reads refuse replicas that have not applied every mutation
//! of the touched shard (read-your-writes — each refusal is a recorded
//! violation avoided, and if no live replica qualifies the read stalls
//! until the earliest catch-up), `AtMost(k)` additionally accepts
//! replicas at most `k` epochs behind the head, and `CachedOk` serves
//! from any live replica.
//!
//! Everything advances *simulated* time: service queues per node, and
//! remote request/response bytes ride the `ga::Fabric` NIC/bisection
//! model, so a 64-node serving tier runs on one host.

use std::sync::Arc;

use crate::ga::{Fabric, FabricConfig};
use crate::metrics::Stats;
use crate::prng::Rng;
use crate::serve::engine::drive::DriveReport;
use crate::serve::engine::Consistency;
use crate::serve::ingest::EpochStore;
use crate::serve::obs::{SpanSet, Stage};

use super::super::query::{
    merge_replies, plan_shards, Query, QueryResult, N_QUERY_CLASSES, QUERY_CLASSES,
};
use super::super::store::Store;
use super::failure::FailureSchedule;
use super::placement::Placement;
use super::remote::{CostModel, FabricShard, LocalShard, ShardClient, ShardReply};

/// Replica-selection policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Routing {
    Random,
    RoundRobin,
    PowerOfTwo,
}

impl Routing {
    pub fn parse(s: &str) -> Option<Routing> {
        match s {
            "random" => Some(Routing::Random),
            "rr" | "round-robin" => Some(Routing::RoundRobin),
            "p2c" | "power-of-two" => Some(Routing::PowerOfTwo),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Routing::Random => "random",
            Routing::RoundRobin => "rr",
            Routing::PowerOfTwo => "p2c",
        }
    }
}

#[derive(Clone, Debug)]
pub struct RouterConfig {
    pub routing: Routing,
    pub fabric: FabricConfig,
    pub cost: CostModel,
    /// time to conclude a replica is dead before retrying elsewhere, s
    pub timeout_detect: f64,
    pub seed: u64,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            routing: Routing::PowerOfTwo,
            fabric: FabricConfig::default(),
            cost: CostModel::default(),
            timeout_detect: 2e-3,
            seed: 42,
        }
    }
}

/// One boxed replica client per (shard, replica) slot.
type ShardClients = Vec<Vec<Box<dyn ShardClient>>>;

/// One in-flight replica move: `(shard, slot)` re-homes to node `to`
/// once the snapshot transfer lands at `ready_at`. `epoch` is the head
/// epoch the shipped snapshot carries — the destination's applied
/// watermark once the move completes.
struct PendingMove {
    shard: usize,
    slot: usize,
    to: usize,
    ready_at: f64,
    epoch: u64,
}

/// The distributed serving front-end (simulated time). Node 0 hosts the
/// router itself, so replicas placed there are served by [`LocalShard`]
/// and everything else by [`FabricShard`]. Killing node 0 models the
/// *shard-server process* on that host dying — the colocated front-end
/// process survives and reroutes, exactly like killing any other node.
pub struct Router {
    pub placement: Placement,
    cfg: RouterConfig,
    /// [shard][replica] — parallel to `placement.shard_nodes`
    clients: ShardClients,
    pub fabric: Fabric,
    rng: Rng,
    /// per-shard round-robin cursor
    rr: Vec<usize>,
    /// per-node serial-service availability, simulated seconds
    node_free: Vec<f64>,
    /// per-node completion times of outstanding sub-requests
    inflight: Vec<Vec<f64>>,
    /// ground truth liveness (written by the failure schedule)
    alive: Vec<bool>,
    /// the router's possibly-stale knowledge of dead nodes
    suspected: Vec<bool>,
    schedule: FailureSchedule,
    origin: usize,
    /// epoch the router was constructed at (before any publish)
    base_epoch: u64,
    /// published versions still servable by a lagging replica,
    /// ascending and epoch-contiguous (last = the head)
    history: Vec<Arc<EpochStore>>,
    /// per node: (apply time, epoch) of each shipped publish, ascending
    node_applied: Vec<Vec<(f64, u64)>>,
    // accounting
    pub served_per_node: Vec<u64>,
    pub busy_per_node: Vec<f64>,
    /// extra latency of each failed-over sub-query (n = failover count)
    pub failover: Stats,
    /// queries lost because no replica of a needed range survived
    pub failed: u64,
    /// primary sub-queries dispatched per shard — the control plane's
    /// hot-range demand signal (hedges excluded: they are replica load,
    /// not shard demand)
    pub served_per_shard: Vec<u64>,
    /// speculative second sub-queries issued past a hedge budget
    pub hedges: u64,
    /// hedges whose reply beat the primary replica's
    pub hedge_wins: u64,
    /// hedge losers cancelled when the winning reply landed
    pub hedge_cancels: u64,
    /// replica service seconds reclaimed by those cancellations
    pub hedge_cancel_saved_s: f64,
    /// replica moves initiated by [`Router::rebalance_to`]
    pub migrations: u64,
    /// shard snapshot bytes shipped by migrations (also on the fabric)
    pub migrated_bytes: f64,
    /// moves whose snapshot is still in flight: each slot swaps to its
    /// destination when `ready_at` passes (the old replica serves on)
    pending: Vec<PendingMove>,
    /// epochs shipped to the tier via [`Router::publish`]
    pub epochs_published: u64,
    /// delta bytes shipped to replicas (also charged to the fabric)
    pub delta_bytes: f64,
    /// lagging replicas refused for fresh/bounded reads — each one a
    /// read-your-writes violation avoided
    pub stale_refusals: u64,
    /// sub-queries served from content older than the head's (lag-
    /// tolerant reads; the engine layer refuses to cache such results)
    pub lagged_subqueries: u64,
    /// stalls where *no* live replica met the consistency bound and the
    /// sub-query waited for the earliest catch-up (n = stall count)
    pub stale_waits: Stats,
    /// queries executed over this router's lifetime ([`Router::report`]
    /// uses it to reject reports over a reused router)
    pub queries: u64,
}

impl Router {
    pub fn new(store: Arc<Store>, n_nodes: usize, replicas: usize, cfg: RouterConfig) -> Router {
        let n_nodes = n_nodes.max(1);
        let members: Vec<usize> = (0..n_nodes).collect();
        Router::new_among(store, n_nodes, &members, replicas, cfg)
    }

    /// [`Router::new`] with `capacity` nodes allocated (fabric and
    /// per-node accounting) but the initial placement restricted to
    /// `members`. This is how an autoscaled tier starts at its floor:
    /// the idle headroom nodes exist from construction, and the control
    /// plane grows into them by rebalancing replicas onto them (node
    /// count in use = distinct nodes in the placement, not capacity).
    pub fn new_among(
        store: Arc<Store>,
        capacity: usize,
        members: &[usize],
        replicas: usize,
        cfg: RouterConfig,
    ) -> Router {
        let n_nodes = capacity.max(1);
        let members: Vec<usize> =
            members.iter().copied().filter(|&n| n < n_nodes).collect();
        let members = if members.is_empty() { vec![0usize] } else { members };
        let placement =
            Placement::rendezvous_among(store.shards.len(), n_nodes, &members, replicas);
        let origin = 0usize;
        let clients: ShardClients = placement
            .shard_nodes
            .iter()
            .map(|nodes| {
                nodes
                    .iter()
                    .map(|&node| -> Box<dyn ShardClient> {
                        if node == origin {
                            Box::new(LocalShard::new(node, cfg.cost.clone()))
                        } else {
                            Box::new(FabricShard::new(node, cfg.cost.clone()))
                        }
                    })
                    .collect()
            })
            .collect();
        let fabric = Fabric::new(cfg.fabric.clone(), n_nodes);
        let rng = Rng::new(cfg.seed ^ 0xd157);
        let n_shards = placement.n_shards();
        let head = Arc::new(EpochStore::initial(store));
        Router {
            placement,
            cfg,
            clients,
            fabric,
            rng,
            rr: vec![0; n_shards],
            node_free: vec![0.0; n_nodes],
            inflight: vec![Vec::new(); n_nodes],
            alive: vec![true; n_nodes],
            suspected: vec![false; n_nodes],
            schedule: FailureSchedule::default(),
            origin,
            base_epoch: head.epoch,
            history: vec![head],
            node_applied: vec![Vec::new(); n_nodes],
            served_per_node: vec![0; n_nodes],
            busy_per_node: vec![0.0; n_nodes],
            served_per_shard: vec![0; n_shards],
            failover: Stats::new(),
            failed: 0,
            hedges: 0,
            hedge_wins: 0,
            hedge_cancels: 0,
            hedge_cancel_saved_s: 0.0,
            migrations: 0,
            migrated_bytes: 0.0,
            pending: Vec::new(),
            epochs_published: 0,
            delta_bytes: 0.0,
            stale_refusals: 0,
            lagged_subqueries: 0,
            stale_waits: Stats::new(),
            queries: 0,
        }
    }

    /// Attach a kill/revive schedule (applied as simulated time passes).
    pub fn with_schedule(mut self, schedule: FailureSchedule) -> Router {
        self.schedule = schedule;
        self
    }

    pub fn routing(&self) -> Routing {
        self.cfg.routing
    }

    /// Simulated node count (including the front-end's node 0).
    pub fn n_nodes(&self) -> usize {
        self.node_free.len()
    }

    /// The newest published version (what `Fresh` reads observe).
    pub fn head(&self) -> Arc<EpochStore> {
        Arc::clone(self.history.last().expect("history is never empty"))
    }

    /// Is `node` alive as of the last applied failure-schedule step?
    /// (The schedule advances with traffic — a scheduled kill is
    /// reflected here from the first request at or after its time.)
    pub fn node_alive(&self, node: usize) -> bool {
        self.alive[node]
    }

    /// Telemetry view of the replication watermark: the newest epoch
    /// `node` has applied by simulated time `t`.
    pub fn node_applied_epoch(&self, node: usize, t: f64) -> u64 {
        self.applied_epoch(node, t)
    }

    /// Ship a freshly published epoch to the replica tier at simulated
    /// time `now`. `touched` is the ingest report's (shard, delta rows)
    /// list: every node hosting a touched replica receives that shard's
    /// delta over the fabric and applies the epoch when its last
    /// transfer lands; nodes with no touched replica apply immediately
    /// (the epoch announcement itself is metadata-sized).
    pub fn publish(&mut self, now: f64, next: Arc<EpochStore>, touched: &[(usize, usize)]) {
        self.complete_moves(now);
        let head_epoch = self.history.last().unwrap().epoch;
        assert_eq!(
            next.epoch,
            head_epoch + 1,
            "epochs must be published to the router in order"
        );
        assert_eq!(
            next.store.shards.len(),
            self.placement.n_shards(),
            "a publish must keep the shard count the placement was built over"
        );
        let epoch = next.epoch;
        let mut apply_at = vec![now; self.n_nodes()];
        for &(shard, rows) in touched {
            let bytes = self.cfg.cost.delta_bytes(rows);
            for &node in &self.placement.shard_nodes[shard] {
                let t = self.fabric.get(now, bytes, self.origin, node);
                self.delta_bytes += bytes;
                apply_at[node] = apply_at[node].max(t);
            }
        }
        for (node, log) in self.node_applied.iter_mut().enumerate() {
            // a node applies epochs in publication order
            let t = match log.last() {
                Some(&(prev, _)) => apply_at[node].max(prev),
                None => apply_at[node],
            };
            log.push((t, epoch));
        }
        self.history.push(next);
        self.epochs_published += 1;
        // prune versions every node has already superseded at `now`
        // (readers that pinned one via `head()` keep it alive anyway)
        let min_applied = (0..self.n_nodes())
            .map(|n| self.applied_epoch(n, now))
            .min()
            .unwrap_or(epoch);
        let base = self.history[0].epoch;
        let n_drop = (min_applied.saturating_sub(base) as usize).min(self.history.len() - 1);
        if n_drop > 0 {
            self.history.drain(..n_drop);
        }
    }

    /// Initiate live migration toward `target` at simulated time `now`:
    /// per shard, diff the current replica *set* against the target's
    /// and move only the difference — slots whose node keeps hosting
    /// the shard stay put, so a rendezvous-derived target moves the
    /// minimum. Each move ships the shard's snapshot over the fabric
    /// from a live current replica to the destination; the slot keeps
    /// serving from the outgoing node until the transfer lands, so
    /// queries issued during the move succeed against the old copy.
    /// Shards with a move already in flight are skipped (the mechanism-
    /// level backstop under the control plane's cooldown). Returns the
    /// number of moves initiated.
    pub fn rebalance_to(&mut self, now: f64, target: &Placement) -> usize {
        assert_eq!(
            target.n_shards(),
            self.placement.n_shards(),
            "a rebalance target must keep the shard count"
        );
        let head = self.head();
        let mut started = 0usize;
        for shard in 0..self.placement.n_shards() {
            if self.pending.iter().any(|m| m.shard == shard) {
                continue;
            }
            let cur = self.placement.shard_nodes[shard].clone();
            let tgt = &target.shard_nodes[shard];
            let adds: Vec<usize> =
                tgt.iter().copied().filter(|n| !cur.contains(n)).collect();
            let slots: Vec<usize> =
                (0..cur.len()).filter(|&s| !tgt.contains(&cur[s])).collect();
            let rows = head.store.shards[shard].sources.len();
            let bytes = self.cfg.cost.delta_bytes(rows);
            for (&to, &slot) in adds.iter().zip(slots.iter()) {
                if to >= self.n_nodes() {
                    continue;
                }
                // ship from the outgoing replica if it is alive, else
                // any live replica, else the head at the origin
                let from = if self.alive[cur[slot]] {
                    cur[slot]
                } else {
                    cur.iter().copied().find(|&n| self.alive[n]).unwrap_or(self.origin)
                };
                let ready_at = self.fabric.get(now, bytes, from, to);
                self.migrated_bytes += bytes;
                self.migrations += 1;
                self.pending.push(PendingMove {
                    shard,
                    slot,
                    to,
                    ready_at,
                    epoch: head.epoch,
                });
                started += 1;
            }
        }
        started
    }

    /// Complete every move whose snapshot transfer has landed by `now`:
    /// swap the slot's client and placement entry to the destination
    /// and record the destination's applied watermark (the shipped
    /// snapshot carries the head as of initiation; co-hosted replicas
    /// on the destination are deemed caught up to that epoch — the
    /// snapshot transfer dominates any delta they still owed). The
    /// node's apply log stays monotone in both time and epoch.
    fn complete_moves(&mut self, now: f64) {
        if self.pending.is_empty() {
            return;
        }
        let mut i = 0;
        while i < self.pending.len() {
            if self.pending[i].ready_at > now {
                i += 1;
                continue;
            }
            let m = self.pending.swap_remove(i);
            self.placement.shard_nodes[m.shard][m.slot] = m.to;
            self.clients[m.shard][m.slot] = if m.to == self.origin {
                Box::new(LocalShard::new(m.to, self.cfg.cost.clone()))
            } else {
                Box::new(FabricShard::new(m.to, self.cfg.cost.clone()))
            };
            let log = &mut self.node_applied[m.to];
            match log.last() {
                Some(&(t_last, e_last)) => {
                    if m.epoch > e_last {
                        log.push((m.ready_at.max(t_last), m.epoch));
                    }
                }
                None => {
                    if m.epoch > self.base_epoch {
                        log.push((m.ready_at, m.epoch));
                    }
                }
            }
        }
    }

    /// The newest epoch `node` has applied by simulated time `t`.
    fn applied_epoch(&self, node: usize, t: f64) -> u64 {
        let log = &self.node_applied[node];
        let i = log.partition_point(|&(ta, _)| ta <= t);
        if i == 0 {
            self.base_epoch
        } else {
            log[i - 1].1
        }
    }

    /// The published version at `epoch` (clamped to the retained
    /// window: pruned epochs resolve to the oldest kept version).
    fn store_at(&self, epoch: u64) -> &Arc<EpochStore> {
        let base = self.history[0].epoch;
        let idx = (epoch.saturating_sub(base) as usize).min(self.history.len() - 1);
        &self.history[idx]
    }

    /// May `node`'s replica of `shard` serve a read at time `t` under
    /// `consistency`? `Fresh` requires the shard's last mutation to
    /// have reached the node (its content *is* the head's content);
    /// `AtMost(k)` also accepts a node at most `k` epochs behind.
    fn replica_acceptable(
        &self,
        shard: usize,
        node: usize,
        t: f64,
        consistency: Consistency,
    ) -> bool {
        match consistency {
            Consistency::CachedOk => true,
            Consistency::Fresh | Consistency::AtMost(_) => {
                let head = self.history.last().unwrap();
                let applied = self.applied_epoch(node, t);
                if applied >= head.shard_epochs[shard] {
                    return true;
                }
                match consistency {
                    Consistency::AtMost(k) => head.epoch - applied <= k as u64,
                    _ => false,
                }
            }
        }
    }

    /// Earliest time an unsuspected replica of `shard` meets the
    /// consistency bound (`None`: never, or nothing to wait for).
    fn earliest_catch_up(&self, shard: usize, t: f64, consistency: Consistency) -> Option<f64> {
        let head = self.history.last().unwrap();
        let needed = head.shard_epochs[shard];
        let target = match consistency {
            Consistency::CachedOk => return None,
            Consistency::Fresh => needed,
            // acceptable once applied >= needed OR lag <= k, whichever
            // epoch is reached first
            Consistency::AtMost(k) => needed.min(head.epoch.saturating_sub(k as u64)),
        };
        let mut best: Option<f64> = None;
        for &node in &self.placement.shard_nodes[shard] {
            if self.suspected[node] {
                continue;
            }
            let log = &self.node_applied[node];
            let i = log.partition_point(|&(_, e)| e < target);
            if i < log.len() {
                let ready = log[i].0.max(t);
                best = Some(match best {
                    None => ready,
                    Some(b) => b.min(ready),
                });
            }
        }
        best
    }

    /// Pick a replica index for `shard` among unsuspected replicas that
    /// meet the read's consistency bound at time `t`. Lagging replicas
    /// are counted as read-your-writes violations avoided only when
    /// `count_refusals` is set (the first attempt of a dispatch), so
    /// stall and dead-node retries do not recount the same replica.
    fn pick_replica(
        &mut self,
        shard: usize,
        t: f64,
        consistency: Consistency,
        count_refusals: bool,
    ) -> Option<usize> {
        let mut refused = 0u64;
        let cand: Vec<usize> = {
            let nodes = &self.placement.shard_nodes[shard];
            (0..nodes.len())
                .filter(|&r| {
                    if self.suspected[nodes[r]] {
                        return false;
                    }
                    if self.replica_acceptable(shard, nodes[r], t, consistency) {
                        true
                    } else {
                        refused += 1;
                        false
                    }
                })
                .collect()
        };
        if count_refusals {
            self.stale_refusals += refused;
        }
        let nodes = &self.placement.shard_nodes[shard];
        match cand.len() {
            0 => None,
            1 => Some(cand[0]),
            k => match self.cfg.routing {
                Routing::Random => Some(cand[self.rng.below(k as u64) as usize]),
                Routing::RoundRobin => {
                    let r = cand[self.rr[shard] % k];
                    self.rr[shard] = self.rr[shard].wrapping_add(1);
                    Some(r)
                }
                Routing::PowerOfTwo => {
                    let i = self.rng.below(k as u64) as usize;
                    let mut j = self.rng.below(k as u64 - 1) as usize;
                    if j >= i {
                        j += 1;
                    }
                    let (a, b) = (cand[i], cand[j]);
                    let (na, nb) = (nodes[a], nodes[b]);
                    let (la, lb) = (self.inflight[na].len(), self.inflight[nb].len());
                    let pick_b = lb < la
                        || (lb == la && self.node_free[nb] < self.node_free[na]);
                    Some(if pick_b { b } else { a })
                }
            },
        }
    }

    /// Best alternate replica for a hedge: the unsuspected replica (not
    /// on `exclude_node`) with the fewest in-flight sub-requests, ties
    /// by earliest availability. Deliberately rng-free so hedging never
    /// perturbs the router's rng stream — random/rr primary choices
    /// replay exactly; p2c primaries can still drift because hedge
    /// dispatches feed the in-flight counts p2c reads. Only replicas
    /// serving the *same shard content epoch* as the primary qualify,
    /// so the race stays outcome-neutral under replication lag.
    fn pick_hedge_replica(
        &self,
        shard: usize,
        exclude_node: usize,
        t: f64,
        consistency: Consistency,
        content_epoch: u64,
    ) -> Option<usize> {
        let nodes = &self.placement.shard_nodes[shard];
        let mut best: Option<usize> = None;
        for (r, &n) in nodes.iter().enumerate() {
            if n == exclude_node || self.suspected[n] {
                continue;
            }
            if !self.replica_acceptable(shard, n, t, consistency) {
                continue;
            }
            let applied = self.applied_epoch(n, t);
            if self.store_at(applied).shard_epochs[shard] != content_epoch {
                continue;
            }
            best = match best {
                None => Some(r),
                Some(b) => {
                    let nb = nodes[b];
                    let better = self.inflight[n].len() < self.inflight[nb].len()
                        || (self.inflight[n].len() == self.inflight[nb].len()
                            && self.node_free[n] < self.node_free[nb]);
                    if better {
                        Some(r)
                    } else {
                        Some(b)
                    }
                }
            };
        }
        best
    }

    /// Speculatively re-issue `shard`'s sub-query to an alternate
    /// replica at `t_hedge` (the moment the budget expired). Candidates
    /// serve the same shard content epoch as the primary, so the
    /// replies are identical; the router keeps whichever lands first.
    /// Returns the observed reply time: `min(t_primary, hedge)`.
    #[allow(clippy::too_many_arguments)]
    fn hedge(
        &mut self,
        shard: usize,
        primary_node: usize,
        t_hedge: f64,
        q: &Query,
        t_primary: f64,
        rows: usize,
        consistency: Consistency,
        content_epoch: u64,
    ) -> f64 {
        let mut t_send = t_hedge;
        loop {
            let Some(r2) = self.pick_hedge_replica(
                shard,
                primary_node,
                t_send,
                consistency,
                content_epoch,
            ) else {
                return t_primary;
            };
            let node2 = self.clients[shard][r2].node();
            if !self.alive[node2] {
                // the hedge times out instead of replying: pay the
                // detection delay, remember the death, and retry on the
                // next-best alternate (each pass suspects one more dead
                // node, so this terminates)
                self.suspected[node2] = true;
                t_send += self.cfg.timeout_detect;
                continue;
            }
            let applied2 = self.applied_epoch(node2, t_send);
            let content2 = Arc::clone(self.store_at(applied2));
            let (reply2, t2) = self.clients[shard][r2].call(
                t_send,
                self.origin,
                q,
                &content2.store.shards[shard],
                &mut self.fabric,
                &mut self.node_free,
            );
            debug_assert_eq!(reply2.rows(), rows, "content-matched replicas must agree");
            self.inflight[node2].push(t2);
            self.served_per_node[node2] += 1;
            let service = self.cfg.cost.service_secs(reply2.rows());
            self.busy_per_node[node2] += service;
            self.hedges += 1;
            // the race resolves when the earlier reply lands at the
            // front-end; the loser is cancelled then, reclaiming the
            // service it would still have run (bounded by the full
            // service cost — content-matched replicas charge the same)
            let (t_win, loser, t_lose) = if t2 < t_primary {
                (t2, primary_node, t_primary)
            } else {
                (t_primary, node2, t2)
            };
            let saved = (t_lose - t_win).min(service).max(0.0);
            self.busy_per_node[loser] -= saved;
            self.node_free[loser] -= saved;
            if let Some(e) = self.inflight[loser].iter_mut().rfind(|e| **e == t_lose) {
                *e = t_win;
            }
            self.hedge_cancels += 1;
            self.hedge_cancel_saved_s += saved;
            return if t2 < t_primary {
                self.hedge_wins += 1;
                t2
            } else {
                t_primary
            };
        }
    }

    /// Execute one query arriving at simulated time `now`. Returns the
    /// merged result (`None` if some needed range lost all replicas) and
    /// the simulated completion time at the front-end.
    pub fn execute(&mut self, now: f64, q: &Query) -> (Option<QueryResult>, f64) {
        self.execute_with(now, q, None, Consistency::CachedOk)
    }

    /// [`Router::execute`] with an optional per-request hedge budget in
    /// seconds (sub-queries whose primary reply would land more than
    /// the budget past dispatch are speculatively re-issued to an
    /// alternate replica; the engine API's `Hedged` layer stamps this)
    /// and the request's consistency bound (which replicas may serve —
    /// see the module docs).
    pub fn execute_with(
        &mut self,
        now: f64,
        q: &Query,
        hedge: Option<f64>,
        consistency: Consistency,
    ) -> (Option<QueryResult>, f64) {
        let (res, done, _) = self.execute_traced(now, q, hedge, consistency);
        (res, done)
    }

    /// [`Router::execute_with`] plus the per-stage span breakdown of
    /// the *critical branch* — the sub-query whose reply lands last and
    /// therefore defines the front-end completion time. Its stall/
    /// detection delay is `QueueWait`, its replica service time is
    /// `ShardExecute`, and the remaining fabric transfer time is
    /// `NetRtt`, so the spans sum to exactly `done - now` (simulated
    /// seconds).
    pub fn execute_traced(
        &mut self,
        now: f64,
        q: &Query,
        hedge: Option<f64>,
        consistency: Consistency,
    ) -> (Option<QueryResult>, f64, SpanSet) {
        self.queries += 1;
        self.complete_moves(now);
        self.schedule.apply(now, &mut self.alive, &mut self.suspected);
        for fl in &mut self.inflight {
            fl.retain(|&t| t > now);
        }
        // plan against the head: Fresh reads execute exactly this
        // version; lag-tolerant reads may see older content per shard
        let head = self.head();
        let planned = plan_shards(&head.store, q);
        let mut replies: Vec<ShardReply> = Vec::with_capacity(planned.len());
        let mut done = now;
        // (reply time, stall+detect wait, replica service) of the
        // slowest branch — the one whose timings explain `done`
        let mut crit = (now, 0.0f64, 0.0f64);
        for shard in planned {
            // scatter: dispatch this range's sub-query, failing over past
            // replicas the router discovers to be dead and stalling past
            // replicas too stale for the read's consistency bound
            let mut t_send = now;
            let mut detect_delay = 0.0;
            let mut first_attempt = true;
            let dispatched = loop {
                let picked = self.pick_replica(shard, t_send, consistency, first_attempt);
                first_attempt = false;
                let Some(r) = picked else {
                    // every live replica lags the bound: wait for the
                    // earliest catch-up (replica propagation stall)
                    match self.earliest_catch_up(shard, t_send, consistency) {
                        Some(ready) => {
                            let ready = ready.max(t_send + 1e-12);
                            self.stale_waits.push(ready - t_send);
                            t_send = ready;
                            continue;
                        }
                        None => break None,
                    }
                };
                // the client is authoritative for its own node id
                let node = self.clients[shard][r].node();
                if !self.alive[node] {
                    // timeout-based discovery: pay the detection delay,
                    // remember the death, retry on a surviving replica
                    self.suspected[node] = true;
                    t_send += self.cfg.timeout_detect;
                    detect_delay += self.cfg.timeout_detect;
                    continue;
                }
                // execute against the shard content this node has applied
                let applied = self.applied_epoch(node, t_send);
                let content = Arc::clone(self.store_at(applied));
                if content.shard_epochs[shard] != head.shard_epochs[shard] {
                    // a lag-tolerant read served from pre-head content:
                    // flagged so the cache layer will not memoize it
                    self.lagged_subqueries += 1;
                }
                let (reply, t) = self.clients[shard][r].call(
                    t_send,
                    self.origin,
                    q,
                    &content.store.shards[shard],
                    &mut self.fabric,
                    &mut self.node_free,
                );
                self.inflight[node].push(t);
                self.served_per_node[node] += 1;
                self.served_per_shard[shard] += 1;
                let service = self.cfg.cost.service_secs(reply.rows());
                self.busy_per_node[node] += service;
                let t_reply = match hedge {
                    Some(budget) if t - t_send > budget => self.hedge(
                        shard,
                        node,
                        t_send + budget,
                        q,
                        t,
                        reply.rows(),
                        consistency,
                        content.shard_epochs[shard],
                    ),
                    _ => t,
                };
                break Some((reply, t_reply, t_send - now, service));
            };
            match dispatched {
                Some((reply, t, wait, service)) => {
                    if detect_delay > 0.0 {
                        self.failover.push(detect_delay);
                    }
                    if t >= done {
                        crit = (t, wait, service);
                    }
                    done = done.max(t);
                    replies.push(reply);
                }
                None => {
                    self.failed += 1;
                    let end = t_send.max(done);
                    // a lost query spent its whole life waiting for a
                    // replica that never qualified
                    let mut spans = SpanSet::new();
                    spans.add(Stage::QueueWait, end - now);
                    return (None, end, spans);
                }
            }
        }
        let mut spans = SpanSet::new();
        if done > now {
            let (t, wait, service) = crit;
            let total = t - now;
            let wait = wait.min(total);
            let service = service.min(total - wait);
            spans.add(Stage::QueueWait, wait);
            spans.add(Stage::ShardExecute, service);
            spans.add(Stage::NetRtt, total - wait - service);
        }
        // the same merge the single-host engine is built from: the
        // distributed answer is byte-identical by construction
        (Some(merge_replies(q, replies)), done, spans)
    }
}

/// Outcome of one simulated open-loop run against a [`Router`].
#[derive(Clone, Debug, Default)]
pub struct DistReport {
    pub offered: u64,
    pub completed: u64,
    pub failed: u64,
    /// length of the arrival window (offered rate = offered / this)
    pub arrival_secs: f64,
    /// simulated horizon: last arrival or completion, whichever is later
    pub sim_secs: f64,
    /// front-end latency (arrival -> merged result) per query class
    pub latency: [Stats; N_QUERY_CLASSES],
    pub served_per_node: Vec<u64>,
    pub busy_per_node: Vec<f64>,
    /// fabric traffic (remote request/response + delta shipping bytes)
    pub bytes_moved: f64,
    pub transfers: u64,
    pub bytes_per_node: Vec<f64>,
    pub failover: Stats,
    /// ingestion epochs shipped during the run
    pub epochs_published: u64,
    /// delta bytes shipped to replicas
    pub delta_bytes: f64,
    /// lagging replicas refused for fresh/bounded reads
    pub stale_refusals: u64,
    /// catch-up stalls of fresh/bounded sub-queries
    pub stale_waits: Stats,
    /// hedge losers cancelled when the winning reply landed
    pub hedge_cancels: u64,
    /// replica service seconds reclaimed by those cancellations
    pub hedge_cancel_saved_s: f64,
    /// live replica moves initiated by the control plane
    pub migrations: u64,
    /// shard snapshot bytes shipped by migrations
    pub migrated_bytes: f64,
}

impl DistReport {
    pub fn latency_all(&self) -> Stats {
        Stats::merge_all(&self.latency)
    }

    /// Per-node load imbalance: max over mean of sub-requests served
    /// (1.0 = perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        let max = self.served_per_node.iter().copied().max().unwrap_or(0) as f64;
        let mean = self.served_per_node.iter().sum::<u64>() as f64
            / self.served_per_node.len().max(1) as f64;
        if mean <= 0.0 {
            0.0
        } else {
            max / mean
        }
    }

    /// Multi-line human summary: per-class quantiles, per-node load,
    /// fabric traffic, failover and ingestion records.
    pub fn summary(&self) -> String {
        let all = self.latency_all();
        let aq = all.quantiles(&[0.50, 0.99]);
        let mut out = format!(
            "dist: {} completed / {} offered ({} failed) at {:.0} qps over {:.2}s (drained by {:.2} sim-s)\n  all      p50={:.3}ms p99={:.3}ms",
            self.completed,
            self.offered,
            self.failed,
            self.offered as f64 / self.arrival_secs.max(1e-9),
            self.arrival_secs,
            self.sim_secs,
            aq[0] * 1e3,
            aq[1] * 1e3,
        );
        for c in QUERY_CLASSES {
            let s = &self.latency[c.index()];
            if s.n == 0 {
                continue;
            }
            let q = s.quantiles(&[0.50, 0.99]);
            out.push_str(&format!(
                "\n  {:<8} n={} p50={:.3}ms p99={:.3}ms",
                c.name(),
                s.n,
                q[0] * 1e3,
                q[1] * 1e3
            ));
        }
        out.push_str(&format!(
            "\n  per-node sub-requests {:?} (imbalance {:.2})",
            self.served_per_node,
            self.imbalance()
        ));
        out.push_str(&format!(
            "\n  fabric: {:.2} MB in {} transfers",
            self.bytes_moved / 1e6,
            self.transfers
        ));
        if self.failover.n > 0 {
            out.push_str(&format!(
                "\n  failover: {} event(s), mean {:.3}ms, max {:.3}ms",
                self.failover.n,
                self.failover.mean() * 1e3,
                self.failover.max * 1e3
            ));
        }
        if self.hedge_cancels > 0 {
            out.push_str(&format!(
                "\n  hedges: {} loser(s) cancelled, {:.3}ms service reclaimed",
                self.hedge_cancels,
                self.hedge_cancel_saved_s * 1e3
            ));
        }
        if self.migrations > 0 {
            out.push_str(&format!(
                "\n  control: {} migration(s), {:.2} MB shipped",
                self.migrations,
                self.migrated_bytes / 1e6
            ));
        }
        if self.epochs_published > 0 {
            out.push_str(&format!(
                "\n  ingest: {} epoch(s) shipped ({:.2} MB delta), {} stale replica(s) refused",
                self.epochs_published,
                self.delta_bytes / 1e6,
                self.stale_refusals
            ));
            if self.stale_waits.n > 0 {
                out.push_str(&format!(
                    ", {} catch-up stall(s) mean {:.3}ms",
                    self.stale_waits.n,
                    self.stale_waits.mean() * 1e3
                ));
            }
        }
        out
    }
}

impl Router {
    /// Assemble the distributed-tier report for a run driven through
    /// the engine API (`drive_open_loop` over a `RouterEngine`): the
    /// drive's disposition counters and latency joined with this
    /// router's cumulative per-node load, fabric traffic, failover and
    /// replication-lag records.
    ///
    /// The router's counters are cumulative, so the report is only
    /// meaningful for a router that served exactly this drive; a reused
    /// router panics here instead of silently merging two runs.
    pub fn report(&self, drive: &DriveReport) -> DistReport {
        let reached_router =
            drive.offered.saturating_sub(drive.cache_hits + drive.shed + drive.queued);
        assert_eq!(
            self.queries, reached_router,
            "Router::report requires a freshly constructed router that served exactly this \
             drive ({} queries executed vs {} in the drive)",
            self.queries, reached_router
        );
        DistReport {
            offered: drive.offered,
            completed: drive.completed,
            failed: drive.failed,
            arrival_secs: drive.arrival_secs,
            sim_secs: drive.horizon.max(drive.arrival_secs),
            latency: drive.latency.clone(),
            served_per_node: self.served_per_node.clone(),
            busy_per_node: self.busy_per_node.clone(),
            bytes_moved: self.fabric.bytes_moved,
            transfers: self.fabric.transfers,
            bytes_per_node: self.fabric.node_bytes.clone(),
            failover: self.failover.clone(),
            epochs_published: self.epochs_published,
            delta_bytes: self.delta_bytes,
            stale_refusals: self.stale_refusals,
            stale_waits: self.stale_waits.clone(),
            hedge_cancels: self.hedge_cancels,
            hedge_cancel_saved_s: self.hedge_cancel_saved_s,
            migrations: self.migrations,
            migrated_bytes: self.migrated_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::{drive_open_loop, RouterEngine, SimClock};
    use crate::serve::ingest::{Ingestor, VersionedStore};
    use crate::serve::loadgen::{LoadGen, LoadGenConfig};
    use crate::serve::query::{execute, SourceFilter};
    use crate::serve::snapshot;
    use crate::serve::store::ServedSource;

    fn test_store(n: usize, shards: usize, seed: u64) -> Arc<Store> {
        let snap = snapshot::synthetic(n, seed);
        Arc::new(Store::build(snap.sources, snap.width, snap.height, shards))
    }

    #[test]
    fn router_matches_store_across_policies_and_placements() {
        let store = test_store(1500, 10, 5);
        let (w, h) = (store.width, store.height);
        for (nodes, replicas, routing) in [
            (1usize, 1usize, Routing::Random),
            (4, 2, Routing::RoundRobin),
            (6, 3, Routing::PowerOfTwo),
            (3, 9, Routing::PowerOfTwo), // replicas clamp to 3
        ] {
            let mut router = Router::new(
                Arc::clone(&store),
                nodes,
                replicas,
                RouterConfig { routing, ..Default::default() },
            );
            let mut rng = Rng::new(17);
            let mut now = 0.0;
            for i in 0..60 {
                let q = match i % 4 {
                    0 => Query::Cone {
                        center: (rng.uniform_in(0.0, w), rng.uniform_in(0.0, h)),
                        radius: rng.uniform_in(2.0, 200.0),
                        filter: SourceFilter::GalaxiesOnly,
                    },
                    1 => Query::BoxSearch {
                        x0: rng.uniform_in(0.0, w * 0.5),
                        y0: rng.uniform_in(0.0, h * 0.5),
                        x1: rng.uniform_in(w * 0.5, w),
                        y1: rng.uniform_in(h * 0.5, h),
                        filter: SourceFilter::Any,
                    },
                    2 => Query::BrightestN {
                        n: rng.below(80) as usize,
                        filter: SourceFilter::StarsOnly,
                    },
                    _ => Query::CrossMatch {
                        pos: (rng.uniform_in(0.0, w), rng.uniform_in(0.0, h)),
                        radius: rng.uniform_in(0.5, 6.0),
                    },
                };
                let (res, done) = router.execute(now, &q);
                assert!(done >= now);
                assert_eq!(
                    res.expect("no failures scheduled"),
                    execute(&store, &q),
                    "{routing:?} nodes={nodes} replicas={replicas} query {i}: {q:?}"
                );
                now += 1e-4;
            }
            assert_eq!(router.failed, 0);
            assert_eq!(router.failover.n, 0);
            assert_eq!(router.stale_refusals, 0, "no ingestion, no staleness");
        }
    }

    #[test]
    fn remote_queries_move_bytes_local_single_node_does_not() {
        let store = test_store(800, 8, 9);
        let q = Query::BrightestN { n: 20, filter: SourceFilter::Any };
        // one node: everything is colocated with the front-end
        let mut local = Router::new(Arc::clone(&store), 1, 1, RouterConfig::default());
        let (r, _) = local.execute(0.0, &q);
        assert!(r.is_some());
        assert_eq!(local.fabric.bytes_moved, 0.0);
        // many nodes: most replicas are remote
        let mut dist = Router::new(Arc::clone(&store), 8, 2, RouterConfig::default());
        let (r2, _) = dist.execute(0.0, &q);
        assert_eq!(r2, r);
        assert!(dist.fabric.bytes_moved > 0.0);
        assert!(dist.fabric.transfers > 0);
    }

    #[test]
    fn failover_reroutes_and_records_latency() {
        let store = test_store(1000, 12, 7);
        let cfg = RouterConfig { routing: Routing::Random, ..Default::default() };
        let mut router = Router::new(Arc::clone(&store), 6, 3, cfg);
        // kill a shard-0 replica host that is not the front-end's node,
        // so the drill models a plain remote-node death
        let victim = *router
            .placement
            .replicas_of(0)
            .iter()
            .find(|&&n| n != 0)
            .expect("3 distinct replicas include a non-origin node");
        router = router.with_schedule(
            FailureSchedule::parse(&format!("{victim}@0.0")).unwrap(),
        );
        let q = Query::BrightestN { n: 5, filter: SourceFilter::Any };
        let want = execute(&store, &q);
        let mut failovers_seen = 0;
        let mut now = 1e-6; // after the kill
        for _ in 0..200 {
            let (res, _) = router.execute(now, &q);
            assert_eq!(res.expect("two replicas survive"), want);
            failovers_seen = router.failover.n;
            now += 1e-4;
        }
        assert_eq!(router.failed, 0);
        assert!(failovers_seen >= 1, "the dead replica was never discovered");
        assert!(router.failover.mean() > 0.0);
        // discovery happens once per dead node, not once per query
        assert!(router.failover.n <= 6, "{} failovers", router.failover.n);
        assert_eq!(router.served_per_node[victim], 0, "dead node served traffic");
    }

    #[test]
    fn all_replicas_dead_fails_queries_and_revive_heals() {
        let store = test_store(500, 4, 3);
        let mut router = Router::new(Arc::clone(&store), 2, 2, RouterConfig::default())
            .with_schedule(FailureSchedule::parse("0@0.0:1.0,1@0.0:1.0").unwrap());
        let q = Query::BrightestN { n: 3, filter: SourceFilter::Any };
        let (res, _) = router.execute(0.5, &q);
        assert!(res.is_none(), "no surviving replica anywhere");
        assert_eq!(router.failed, 1);
        // after both revive, service resumes and answers are exact
        let (res2, _) = router.execute(1.5, &q);
        assert_eq!(res2.expect("revived"), execute(&store, &q));
    }

    #[test]
    fn sim_open_loop_reports_latency_and_node_loads() {
        let store = test_store(2000, 8, 13);
        let router = Router::new(Arc::clone(&store), 4, 2, RouterConfig::default());
        let engine = RouterEngine::new(router);
        let cfg = LoadGenConfig::scenario("uniform", 5).unwrap();
        let mut gen = LoadGen::new(cfg, store.width, store.height);
        let mut clock = SimClock::new();
        let drive = drive_open_loop(&engine, &mut clock, &mut gen, 2000.0, 0.5);
        let rep = engine.dist_report(&drive);
        assert!(rep.offered > 500, "offered {}", rep.offered);
        assert_eq!(rep.completed, rep.offered);
        assert_eq!(rep.failed, 0);
        assert!(rep.latency_all().n == rep.completed);
        assert!(rep.latency_all().p50() > 0.0);
        assert!(rep.sim_secs > 0.4);
        assert!(rep.served_per_node.iter().sum::<u64>() >= rep.completed);
        assert!(rep.bytes_moved > 0.0);
        assert!(rep.imbalance() >= 1.0);
    }

    #[test]
    fn hedged_subqueries_preserve_results_and_are_counted() {
        let store = test_store(1200, 8, 21);
        let mut router = Router::new(Arc::clone(&store), 4, 2, RouterConfig::default());
        let q = Query::BrightestN { n: 30, filter: SourceFilter::Any };
        let want = execute(&store, &q);
        // zero budget: every primary reply exceeds it, so a hedge fires
        // for every shard that has an alternate replica
        let (res, done) = router.execute_with(0.0, &q, Some(0.0), Consistency::CachedOk);
        assert_eq!(res.expect("no failures scheduled"), want);
        assert!(done > 0.0);
        assert!(router.hedges > 0, "zero budget must fire hedges");
        assert!(router.hedge_wins <= router.hedges);
        // without a budget nothing hedges
        let mut plain = Router::new(Arc::clone(&store), 4, 2, RouterConfig::default());
        let (res2, _) = plain.execute(0.0, &q);
        assert_eq!(res2.unwrap(), want);
        assert_eq!(plain.hedges, 0);
        assert_eq!(plain.hedge_cancels, 0);
    }

    /// Every hedge race cancels its loser, and the cancellation gives
    /// the loser's remaining service time back: total busy seconds are
    /// exactly the doubled per-shard service minus what was reclaimed.
    #[test]
    fn hedge_loser_is_cancelled_and_stops_consuming_service() {
        let store = test_store(1200, 8, 21);
        let q = Query::BrightestN { n: 30, filter: SourceFilter::Any };
        let mut plain = Router::new(Arc::clone(&store), 4, 2, RouterConfig::default());
        let (res0, _) = plain.execute(0.0, &q);
        assert!(res0.is_some());
        let plain_busy: f64 = plain.busy_per_node.iter().sum();
        let mut hedged = Router::new(Arc::clone(&store), 4, 2, RouterConfig::default());
        // zero budget: a hedge fires for every shard (2 distinct
        // replicas each, no failures, no replication lag)
        let (res, _) = hedged.execute_with(0.0, &q, Some(0.0), Consistency::CachedOk);
        assert_eq!(res.unwrap(), execute(&store, &q));
        assert!(hedged.hedges > 0);
        assert_eq!(
            hedged.hedge_cancels, hedged.hedges,
            "every hedge race resolves with exactly one cancelled loser"
        );
        assert!(
            hedged.hedge_cancel_saved_s > 0.0,
            "cancellation must reclaim service time"
        );
        assert!(hedged.busy_per_node.iter().all(|&b| b >= -1e-12));
        // service cost depends only on result rows, so the hedged run
        // charged exactly twice the plain run before reclamation
        let hedged_busy: f64 = hedged.busy_per_node.iter().sum();
        assert!(
            (hedged_busy + hedged.hedge_cancel_saved_s - 2.0 * plain_busy).abs() < 1e-9,
            "busy accounting drifted: {hedged_busy} + {} vs 2 * {plain_busy}",
            hedged.hedge_cancel_saved_s
        );
        assert!(hedged_busy < 2.0 * plain_busy, "no service was reclaimed");
    }

    /// Draining a node via a rendezvous target moves only the replicas
    /// that lived on it, serves every query issued mid-migration from
    /// the outgoing copies, and stops routing to the node afterwards.
    #[test]
    fn rebalance_migrates_minimal_ranges_and_serves_throughout() {
        let store = test_store(1500, 10, 5);
        let mut router = Router::new(Arc::clone(&store), 6, 2, RouterConfig::default());
        let before = router.placement.shard_nodes.clone();
        let members: Vec<usize> = (0..5).collect();
        let target =
            Placement::rendezvous_among(store.shards.len(), 6, &members, 2);
        let expected_moves: usize = before
            .iter()
            .map(|nodes| nodes.iter().filter(|&&n| n == 5).count())
            .sum();
        assert!(expected_moves > 0, "node 5 hosted nothing; grow the tier");
        let started = router.rebalance_to(0.0, &target);
        assert_eq!(started, expected_moves, "only replicas on the drained node move");
        assert_eq!(router.migrations as usize, started);
        assert!(router.migrated_bytes > 0.0);
        // a second rebalance to the same target while moves are in
        // flight initiates nothing (per-shard backstop)
        assert_eq!(router.rebalance_to(0.0, &target), 0);
        // mid-flight: the outgoing replicas still serve, answers exact
        let q = Query::BrightestN { n: 25, filter: SourceFilter::Any };
        let want = execute(&store, &q);
        let (res, _) = router.execute(1e-9, &q);
        assert_eq!(res.expect("served during migration"), want);
        // long after every transfer lands, the placement matches the
        // target and the drained node takes no new traffic
        let (res2, _) = router.execute(1e9, &q);
        assert_eq!(res2.expect("served after migration"), want);
        // moved slots swap in place, so compare replica *sets*
        for (s, (got, tgt)) in
            router.placement.shard_nodes.iter().zip(&target.shard_nodes).enumerate()
        {
            let mut a = got.clone();
            let mut b = tgt.clone();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b, "shard {s}: migrated replica set differs from target");
        }
        let served5 = router.served_per_node[5];
        for _ in 0..50 {
            let (r, _) = router.execute(1e9 + 1.0, &q);
            assert!(r.is_some());
        }
        assert_eq!(router.served_per_node[5], served5, "drained node kept serving");
        assert_eq!(router.failed, 0);
    }

    /// A tier constructed over a member subset places replicas only on
    /// members while accounting for the full capacity, and tracks the
    /// per-shard demand signal the control plane keys off.
    #[test]
    fn new_among_restricts_placement_to_members() {
        let store = test_store(800, 8, 9);
        let members = [0usize, 2, 4];
        let mut router = Router::new_among(
            Arc::clone(&store),
            6,
            &members,
            2,
            RouterConfig::default(),
        );
        assert_eq!(router.n_nodes(), 6);
        for nodes in &router.placement.shard_nodes {
            assert_eq!(nodes.len(), 2);
            for &n in nodes {
                assert!(members.contains(&n), "replica on non-member node {n}");
            }
        }
        let q = Query::BrightestN { n: 10, filter: SourceFilter::Any };
        let (res, _) = router.execute(0.0, &q);
        assert_eq!(res.expect("served"), execute(&store, &q));
        assert_eq!(router.served_per_node[1], 0);
        assert_eq!(router.served_per_node[5], 0);
        assert!(router.served_per_shard.iter().sum::<u64>() > 0);
    }

    /// One publish through a replicated router: Fresh reads observe the
    /// delta immediately (stalling on propagation if they must), while
    /// lag-tolerant reads served before propagation completes still see
    /// the pre-delta sky.
    #[test]
    fn fresh_reads_observe_a_publish_immediately_lagged_reads_need_not() {
        let store = test_store(900, 6, 33);
        let vs = Arc::new(VersionedStore::new(Arc::clone(&store)));
        let mut ing = Ingestor::new(Arc::clone(&vs));
        let mut router = Router::new(Arc::clone(&store), 5, 2, RouterConfig::default());
        let q = Query::BrightestN { n: 1, filter: SourceFilter::Any };
        let before = execute(&store, &q);
        // a new all-sky-brightest detection lands at t = 1.0
        let delta = ServedSource {
            id: 777_777,
            pos: (store.width * 0.5, store.height * 0.5),
            p_gal: 0.0,
            flux_r: 1e12,
            flux_logsd: 0.05,
            colors: [0.0; 4],
            converged: true,
        };
        let rep = ing.apply(&[delta]);
        router.publish(1.0, Arc::clone(&rep.published), &rep.touched);
        assert_eq!(router.epochs_published, 1);
        assert!(router.delta_bytes > 0.0, "delta shipping must be charged");
        let after = execute(&vs.load().store, &q);
        assert_ne!(before, after);
        // immediately after the publish instant, a fresh read returns
        // the new sky (read-your-writes), whatever the replica lag
        let (fresh, t_done) =
            router.execute_with(1.0 + 1e-9, &q, None, Consistency::Fresh);
        assert_eq!(fresh.expect("served"), after);
        assert!(t_done > 1.0);
        // a generously bounded read at the same instant may be served
        // by a lagging replica — and must then see the pre-delta sky
        let (lagged, _) =
            router.execute_with(1.0 + 1e-9, &q, None, Consistency::AtMost(10));
        let lagged = lagged.expect("served");
        assert!(
            lagged == before || lagged == after,
            "lag-tolerant read must be one of the two versions"
        );
        // once every node has applied the epoch, everyone serves the head
        let (late, _) = router.execute_with(10.0, &q, None, Consistency::CachedOk);
        assert_eq!(late.expect("served"), after);
    }

    /// AtMost(k) tolerates exactly k epochs of lag: with j unapplied
    /// publishes, bounds >= j never stall and bounds < j must refuse
    /// the lagging replicas (stalling until partial catch-up).
    #[test]
    fn at_most_bounds_replica_lag_exactly() {
        let store = test_store(700, 4, 41);
        let vs = Arc::new(VersionedStore::new(Arc::clone(&store)));
        let mut ing = Ingestor::new(Arc::clone(&vs));
        let mut router = Router::new(Arc::clone(&store), 4, 2, RouterConfig::default());
        // publish j = 3 epochs back-to-back at t = 1.0; none can have
        // been applied by 1.0 + epsilon (fabric latency is positive)
        let mut rng = Rng::new(5);
        for _ in 0..3 {
            let deltas: Vec<ServedSource> = (0..20)
                .map(|j| ServedSource {
                    id: 888_000 + router.epochs_published as usize * 100 + j,
                    pos: (
                        rng.uniform_in(0.0, store.width),
                        rng.uniform_in(0.0, store.height),
                    ),
                    p_gal: 0.4,
                    flux_r: 10.0,
                    flux_logsd: 0.2,
                    colors: [0.0; 4],
                    converged: true,
                })
                .collect();
            let rep = ing.apply(&deltas);
            router.publish(1.0, Arc::clone(&rep.published), &rep.touched);
        }
        assert_eq!(router.epochs_published, 3);
        let q = Query::BrightestN { n: 5, filter: SourceFilter::Any };
        let t = 1.0 + 1e-9;
        // lag 3 tolerated: no refusals, no stalls
        let refusals0 = router.stale_refusals;
        let (res, _) = router.execute_with(t, &q, None, Consistency::AtMost(3));
        assert!(res.is_some());
        assert_eq!(router.stale_refusals, refusals0, "lag <= k must not refuse");
        assert_eq!(router.stale_waits.n, 0);
        // lag bound 2 < 3: lagging replicas are refused and the read
        // stalls for (partial) catch-up, still completing correctly
        let (res2, t2) = router.execute_with(t, &q, None, Consistency::AtMost(2));
        assert!(res2.is_some());
        assert!(router.stale_refusals > refusals0, "lag > k must refuse replicas");
        assert!(router.stale_waits.n > 0, "bounded read must stall for catch-up");
        assert!(t2 > t);
        // and Fresh equals brute force over the head, with stalls
        let (res3, _) = router.execute_with(t, &q, None, Consistency::Fresh);
        assert_eq!(res3.expect("served"), execute(&vs.load().store, &q));
    }
}
