//! The front-end router: scatter-gather query planning over placed
//! shard replicas, load-balanced replica selection, and failover.
//!
//! Per query class the router plans the minimal shard set — cone/box
//! probes hit only ranges whose bounding boxes intersect, brightest-N
//! does per-replica top-k then a canonical merge, cross-match probes the
//! widened acceptance box — and dispatches each sub-query to one replica
//! chosen by the configured policy:
//!
//! * `random`  — uniform over surviving replicas,
//! * `rr`      — per-shard round-robin,
//! * `p2c`     — power-of-two-choices on per-replica in-flight counts
//!               (the classic "two random choices" result: sampling two
//!               and picking the less loaded collapses queue-length
//!               variance, which is exactly what the p99 tail is).
//!
//! On top of the balanced choice the router honors a per-request
//! *hedge budget* ([`Router::execute_with`], stamped by the engine
//! API's `Hedged` layer): when a replica's reply would land more than
//! the budget past its dispatch, the same sub-query is speculatively
//! issued to the best alternate replica and the earlier reply wins —
//! extra replica load and fabric bytes traded for a shorter p999 tail.
//!
//! Everything advances *simulated* time: service queues per node, and
//! remote request/response bytes ride the `ga::Fabric` NIC/bisection
//! model, so a 64-node serving tier runs on one host.

use std::sync::Arc;

use crate::ga::{Fabric, FabricConfig};
use crate::metrics::Stats;
use crate::prng::Rng;
use crate::serve::engine::drive::DriveReport;

use super::super::query::{
    merge_replies, Query, QueryResult, N_QUERY_CLASSES, QUERY_CLASSES,
};
use super::super::store::Store;
use super::failure::FailureSchedule;
use super::placement::Placement;
use super::remote::{CostModel, FabricShard, LocalShard, ShardClient, ShardReply};

/// Replica-selection policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Routing {
    Random,
    RoundRobin,
    PowerOfTwo,
}

impl Routing {
    pub fn parse(s: &str) -> Option<Routing> {
        match s {
            "random" => Some(Routing::Random),
            "rr" | "round-robin" => Some(Routing::RoundRobin),
            "p2c" | "power-of-two" => Some(Routing::PowerOfTwo),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Routing::Random => "random",
            Routing::RoundRobin => "rr",
            Routing::PowerOfTwo => "p2c",
        }
    }
}

#[derive(Clone, Debug)]
pub struct RouterConfig {
    pub routing: Routing,
    pub fabric: FabricConfig,
    pub cost: CostModel,
    /// time to conclude a replica is dead before retrying elsewhere, s
    pub timeout_detect: f64,
    pub seed: u64,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            routing: Routing::PowerOfTwo,
            fabric: FabricConfig::default(),
            cost: CostModel::default(),
            timeout_detect: 2e-3,
            seed: 42,
        }
    }
}

/// One boxed replica client per (shard, replica) slot.
type ShardClients = Vec<Vec<Box<dyn ShardClient>>>;

/// The distributed serving front-end (simulated time). Node 0 hosts the
/// router itself, so replicas placed there are served by [`LocalShard`]
/// and everything else by [`FabricShard`]. Killing node 0 models the
/// *shard-server process* on that host dying — the colocated front-end
/// process survives and reroutes, exactly like killing any other node.
pub struct Router {
    store: Arc<Store>,
    pub placement: Placement,
    cfg: RouterConfig,
    /// [shard][replica] — parallel to `placement.shard_nodes`
    clients: ShardClients,
    pub fabric: Fabric,
    rng: Rng,
    /// per-shard round-robin cursor
    rr: Vec<usize>,
    /// per-node serial-service availability, simulated seconds
    node_free: Vec<f64>,
    /// per-node completion times of outstanding sub-requests
    inflight: Vec<Vec<f64>>,
    /// ground truth liveness (written by the failure schedule)
    alive: Vec<bool>,
    /// the router's possibly-stale knowledge of dead nodes
    suspected: Vec<bool>,
    schedule: FailureSchedule,
    origin: usize,
    // accounting
    pub served_per_node: Vec<u64>,
    pub busy_per_node: Vec<f64>,
    /// extra latency of each failed-over sub-query (n = failover count)
    pub failover: Stats,
    /// queries lost because no replica of a needed range survived
    pub failed: u64,
    /// speculative second sub-queries issued past a hedge budget
    pub hedges: u64,
    /// hedges whose reply beat the primary replica's
    pub hedge_wins: u64,
    /// queries executed over this router's lifetime ([`Router::report`]
    /// uses it to reject reports over a reused router)
    pub queries: u64,
}

impl Router {
    pub fn new(store: Arc<Store>, n_nodes: usize, replicas: usize, cfg: RouterConfig) -> Router {
        let n_nodes = n_nodes.max(1);
        let placement = Placement::rendezvous(store.shards.len(), n_nodes, replicas);
        let origin = 0usize;
        let clients: ShardClients = placement
            .shard_nodes
            .iter()
            .enumerate()
            .map(|(s, nodes)| {
                nodes
                    .iter()
                    .map(|&node| -> Box<dyn ShardClient> {
                        if node == origin {
                            Box::new(LocalShard::new(
                                Arc::clone(&store),
                                s,
                                node,
                                cfg.cost.clone(),
                            ))
                        } else {
                            Box::new(FabricShard::new(
                                Arc::clone(&store),
                                s,
                                node,
                                cfg.cost.clone(),
                            ))
                        }
                    })
                    .collect()
            })
            .collect();
        let fabric = Fabric::new(cfg.fabric.clone(), n_nodes);
        let rng = Rng::new(cfg.seed ^ 0xd157);
        let n_shards = placement.n_shards();
        Router {
            store,
            placement,
            cfg,
            clients,
            fabric,
            rng,
            rr: vec![0; n_shards],
            node_free: vec![0.0; n_nodes],
            inflight: vec![Vec::new(); n_nodes],
            alive: vec![true; n_nodes],
            suspected: vec![false; n_nodes],
            schedule: FailureSchedule::default(),
            origin,
            served_per_node: vec![0; n_nodes],
            busy_per_node: vec![0.0; n_nodes],
            failover: Stats::new(),
            failed: 0,
            hedges: 0,
            hedge_wins: 0,
            queries: 0,
        }
    }

    /// Attach a kill/revive schedule (applied as simulated time passes).
    pub fn with_schedule(mut self, schedule: FailureSchedule) -> Router {
        self.schedule = schedule;
        self
    }

    pub fn routing(&self) -> Routing {
        self.cfg.routing
    }

    /// Simulated node count (including the front-end's node 0).
    pub fn n_nodes(&self) -> usize {
        self.node_free.len()
    }

    /// Shards a query must touch (indices into the store).
    fn plan(&self, q: &Query) -> Vec<usize> {
        let shards = &self.store.shards;
        match q {
            Query::Cone { center, radius, .. } => {
                let (bx0, by0) = (center.0 - radius, center.1 - radius);
                let (bx1, by1) = (center.0 + radius, center.1 + radius);
                (0..shards.len())
                    .filter(|&i| shards[i].intersects_box(bx0, by0, bx1, by1))
                    .collect()
            }
            Query::BoxSearch { x0, y0, x1, y1, .. } => (0..shards.len())
                .filter(|&i| shards[i].intersects_box(*x0, *y0, *x1, *y1))
                .collect(),
            Query::BrightestN { .. } => {
                (0..shards.len()).filter(|&i| !shards[i].sources.is_empty()).collect()
            }
            Query::CrossMatch { pos, radius } => {
                let probe = super::super::query::max_match_radius(*radius);
                let (bx0, by0) = (pos.0 - probe, pos.1 - probe);
                let (bx1, by1) = (pos.0 + probe, pos.1 + probe);
                (0..shards.len())
                    .filter(|&i| shards[i].intersects_box(bx0, by0, bx1, by1))
                    .collect()
            }
        }
    }

    /// Pick a replica index for `shard` among unsuspected replicas.
    fn pick_replica(&mut self, shard: usize) -> Option<usize> {
        let nodes = &self.placement.shard_nodes[shard];
        let cand: Vec<usize> =
            (0..nodes.len()).filter(|&r| !self.suspected[nodes[r]]).collect();
        match cand.len() {
            0 => None,
            1 => Some(cand[0]),
            k => match self.cfg.routing {
                Routing::Random => Some(cand[self.rng.below(k as u64) as usize]),
                Routing::RoundRobin => {
                    let r = cand[self.rr[shard] % k];
                    self.rr[shard] = self.rr[shard].wrapping_add(1);
                    Some(r)
                }
                Routing::PowerOfTwo => {
                    let i = self.rng.below(k as u64) as usize;
                    let mut j = self.rng.below(k as u64 - 1) as usize;
                    if j >= i {
                        j += 1;
                    }
                    let (a, b) = (cand[i], cand[j]);
                    let (na, nb) = (nodes[a], nodes[b]);
                    let (la, lb) = (self.inflight[na].len(), self.inflight[nb].len());
                    let pick_b = lb < la
                        || (lb == la && self.node_free[nb] < self.node_free[na]);
                    Some(if pick_b { b } else { a })
                }
            },
        }
    }

    /// Best alternate replica for a hedge: the unsuspected replica (not
    /// on `exclude_node`) with the fewest in-flight sub-requests, ties
    /// by earliest availability. Deliberately rng-free so hedging never
    /// perturbs the router's rng stream — random/rr primary choices
    /// replay exactly; p2c primaries can still drift because hedge
    /// dispatches feed the in-flight counts p2c reads.
    fn pick_hedge_replica(&self, shard: usize, exclude_node: usize) -> Option<usize> {
        let nodes = &self.placement.shard_nodes[shard];
        let mut best: Option<usize> = None;
        for (r, &n) in nodes.iter().enumerate() {
            if n == exclude_node || self.suspected[n] {
                continue;
            }
            best = match best {
                None => Some(r),
                Some(b) => {
                    let nb = nodes[b];
                    let better = self.inflight[n].len() < self.inflight[nb].len()
                        || (self.inflight[n].len() == self.inflight[nb].len()
                            && self.node_free[n] < self.node_free[nb]);
                    if better {
                        Some(r)
                    } else {
                        Some(b)
                    }
                }
            };
        }
        best
    }

    /// Speculatively re-issue `shard`'s sub-query to an alternate
    /// replica at `t_hedge` (the moment the budget expired). Both
    /// replicas hold the same range, so the replies are identical; the
    /// router keeps whichever lands first. Returns the observed reply
    /// time: `min(t_primary, hedge completion)`.
    fn hedge(
        &mut self,
        shard: usize,
        primary_node: usize,
        t_hedge: f64,
        q: &Query,
        t_primary: f64,
        rows: usize,
    ) -> f64 {
        let mut t_send = t_hedge;
        loop {
            let Some(r2) = self.pick_hedge_replica(shard, primary_node) else {
                return t_primary;
            };
            let node2 = self.clients[shard][r2].node();
            if !self.alive[node2] {
                // the hedge times out instead of replying: pay the
                // detection delay, remember the death, and retry on the
                // next-best alternate (each pass suspects one more dead
                // node, so this terminates)
                self.suspected[node2] = true;
                t_send += self.cfg.timeout_detect;
                continue;
            }
            let (reply2, t2) = self.clients[shard][r2].call(
                t_send,
                self.origin,
                q,
                &mut self.fabric,
                &mut self.node_free,
            );
            debug_assert_eq!(reply2.rows(), rows, "replicas of one shard must agree");
            self.inflight[node2].push(t2);
            self.served_per_node[node2] += 1;
            self.busy_per_node[node2] += self.cfg.cost.service_secs(reply2.rows());
            self.hedges += 1;
            return if t2 < t_primary {
                self.hedge_wins += 1;
                t2
            } else {
                t_primary
            };
        }
    }

    /// Execute one query arriving at simulated time `now`. Returns the
    /// merged result (`None` if some needed range lost all replicas) and
    /// the simulated completion time at the front-end.
    pub fn execute(&mut self, now: f64, q: &Query) -> (Option<QueryResult>, f64) {
        self.execute_with(now, q, None)
    }

    /// [`Router::execute`] with an optional per-request hedge budget in
    /// seconds: sub-queries whose primary reply would land more than
    /// the budget past dispatch are speculatively re-issued to an
    /// alternate replica (the engine API's `Hedged` layer stamps this).
    pub fn execute_with(
        &mut self,
        now: f64,
        q: &Query,
        hedge: Option<f64>,
    ) -> (Option<QueryResult>, f64) {
        self.queries += 1;
        self.schedule.apply(now, &mut self.alive, &mut self.suspected);
        for fl in &mut self.inflight {
            fl.retain(|&t| t > now);
        }
        let planned = self.plan(q);
        let mut replies: Vec<ShardReply> = Vec::with_capacity(planned.len());
        let mut done = now;
        for shard in planned {
            // scatter: dispatch this range's sub-query, failing over past
            // replicas the router discovers to be dead
            let mut t_send = now;
            let dispatched = loop {
                let Some(r) = self.pick_replica(shard) else { break None };
                // the client is authoritative for its own node id
                let node = self.clients[shard][r].node();
                if !self.alive[node] {
                    // timeout-based discovery: pay the detection delay,
                    // remember the death, retry on a surviving replica
                    self.suspected[node] = true;
                    t_send += self.cfg.timeout_detect;
                    continue;
                }
                let (reply, t) = self.clients[shard][r].call(
                    t_send,
                    self.origin,
                    q,
                    &mut self.fabric,
                    &mut self.node_free,
                );
                self.inflight[node].push(t);
                self.served_per_node[node] += 1;
                self.busy_per_node[node] += self.cfg.cost.service_secs(reply.rows());
                let t_reply = match hedge {
                    Some(budget) if t - t_send > budget => {
                        self.hedge(shard, node, t_send + budget, q, t, reply.rows())
                    }
                    _ => t,
                };
                break Some((reply, t_reply));
            };
            match dispatched {
                Some((reply, t)) => {
                    if t_send > now {
                        self.failover.push(t_send - now);
                    }
                    done = done.max(t);
                    replies.push(reply);
                }
                None => {
                    self.failed += 1;
                    return (None, t_send.max(done));
                }
            }
        }
        // the same merge the single-host engine is built from: the
        // distributed answer is byte-identical by construction
        (Some(merge_replies(q, replies)), done)
    }
}

/// Outcome of one simulated open-loop run against a [`Router`].
#[derive(Clone, Debug, Default)]
pub struct DistReport {
    pub offered: u64,
    pub completed: u64,
    pub failed: u64,
    /// length of the arrival window (offered rate = offered / this)
    pub arrival_secs: f64,
    /// simulated horizon: last arrival or completion, whichever is later
    pub sim_secs: f64,
    /// front-end latency (arrival -> merged result) per query class
    pub latency: [Stats; N_QUERY_CLASSES],
    pub served_per_node: Vec<u64>,
    pub busy_per_node: Vec<f64>,
    /// fabric traffic (remote request/response bytes only)
    pub bytes_moved: f64,
    pub transfers: u64,
    pub bytes_per_node: Vec<f64>,
    pub failover: Stats,
}

impl DistReport {
    pub fn latency_all(&self) -> Stats {
        Stats::merge_all(&self.latency)
    }

    /// Per-node load imbalance: max over mean of sub-requests served
    /// (1.0 = perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        let max = self.served_per_node.iter().copied().max().unwrap_or(0) as f64;
        let mean = self.served_per_node.iter().sum::<u64>() as f64
            / self.served_per_node.len().max(1) as f64;
        if mean <= 0.0 {
            0.0
        } else {
            max / mean
        }
    }

    /// Multi-line human summary: per-class quantiles, per-node load,
    /// fabric traffic, failover record.
    pub fn summary(&self) -> String {
        let all = self.latency_all();
        let aq = all.quantiles(&[0.50, 0.99]);
        let mut out = format!(
            "dist: {} completed / {} offered ({} failed) at {:.0} qps over {:.2}s (drained by {:.2} sim-s)\n  all      p50={:.3}ms p99={:.3}ms",
            self.completed,
            self.offered,
            self.failed,
            self.offered as f64 / self.arrival_secs.max(1e-9),
            self.arrival_secs,
            self.sim_secs,
            aq[0] * 1e3,
            aq[1] * 1e3,
        );
        for c in QUERY_CLASSES {
            let s = &self.latency[c.index()];
            if s.n == 0 {
                continue;
            }
            let q = s.quantiles(&[0.50, 0.99]);
            out.push_str(&format!(
                "\n  {:<8} n={} p50={:.3}ms p99={:.3}ms",
                c.name(),
                s.n,
                q[0] * 1e3,
                q[1] * 1e3
            ));
        }
        out.push_str(&format!(
            "\n  per-node sub-requests {:?} (imbalance {:.2})",
            self.served_per_node,
            self.imbalance()
        ));
        out.push_str(&format!(
            "\n  fabric: {:.2} MB in {} transfers",
            self.bytes_moved / 1e6,
            self.transfers
        ));
        if self.failover.n > 0 {
            out.push_str(&format!(
                "\n  failover: {} event(s), mean {:.3}ms, max {:.3}ms",
                self.failover.n,
                self.failover.mean() * 1e3,
                self.failover.max * 1e3
            ));
        }
        out
    }
}

impl Router {
    /// Assemble the distributed-tier report for a run driven through
    /// the engine API (`drive_open_loop` over a `RouterEngine`): the
    /// drive's disposition counters and latency joined with this
    /// router's cumulative per-node load, fabric traffic, and failover
    /// record.
    ///
    /// The router's counters are cumulative, so the report is only
    /// meaningful for a router that served exactly this drive; a reused
    /// router panics here instead of silently merging two runs.
    pub fn report(&self, drive: &DriveReport) -> DistReport {
        let reached_router =
            drive.offered.saturating_sub(drive.cache_hits + drive.shed + drive.queued);
        assert_eq!(
            self.queries, reached_router,
            "Router::report requires a freshly constructed router that served exactly this \
             drive ({} queries executed vs {} in the drive)",
            self.queries, reached_router
        );
        DistReport {
            offered: drive.offered,
            completed: drive.completed,
            failed: drive.failed,
            arrival_secs: drive.arrival_secs,
            sim_secs: drive.horizon.max(drive.arrival_secs),
            latency: drive.latency.clone(),
            served_per_node: self.served_per_node.clone(),
            busy_per_node: self.busy_per_node.clone(),
            bytes_moved: self.fabric.bytes_moved,
            transfers: self.fabric.transfers,
            bytes_per_node: self.fabric.node_bytes.clone(),
            failover: self.failover.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::{drive_open_loop, RouterEngine, SimClock};
    use crate::serve::loadgen::{LoadGen, LoadGenConfig};
    use crate::serve::query::{execute, SourceFilter};
    use crate::serve::snapshot;

    fn test_store(n: usize, shards: usize, seed: u64) -> Arc<Store> {
        let snap = snapshot::synthetic(n, seed);
        Arc::new(Store::build(snap.sources, snap.width, snap.height, shards))
    }

    #[test]
    fn router_matches_store_across_policies_and_placements() {
        let store = test_store(1500, 10, 5);
        let (w, h) = (store.width, store.height);
        for (nodes, replicas, routing) in [
            (1usize, 1usize, Routing::Random),
            (4, 2, Routing::RoundRobin),
            (6, 3, Routing::PowerOfTwo),
            (3, 9, Routing::PowerOfTwo), // replicas clamp to 3
        ] {
            let mut router = Router::new(
                Arc::clone(&store),
                nodes,
                replicas,
                RouterConfig { routing, ..Default::default() },
            );
            let mut rng = Rng::new(17);
            let mut now = 0.0;
            for i in 0..60 {
                let q = match i % 4 {
                    0 => Query::Cone {
                        center: (rng.uniform_in(0.0, w), rng.uniform_in(0.0, h)),
                        radius: rng.uniform_in(2.0, 200.0),
                        filter: SourceFilter::GalaxiesOnly,
                    },
                    1 => Query::BoxSearch {
                        x0: rng.uniform_in(0.0, w * 0.5),
                        y0: rng.uniform_in(0.0, h * 0.5),
                        x1: rng.uniform_in(w * 0.5, w),
                        y1: rng.uniform_in(h * 0.5, h),
                        filter: SourceFilter::Any,
                    },
                    2 => Query::BrightestN {
                        n: rng.below(80) as usize,
                        filter: SourceFilter::StarsOnly,
                    },
                    _ => Query::CrossMatch {
                        pos: (rng.uniform_in(0.0, w), rng.uniform_in(0.0, h)),
                        radius: rng.uniform_in(0.5, 6.0),
                    },
                };
                let (res, done) = router.execute(now, &q);
                assert!(done >= now);
                assert_eq!(
                    res.expect("no failures scheduled"),
                    execute(&store, &q),
                    "{routing:?} nodes={nodes} replicas={replicas} query {i}: {q:?}"
                );
                now += 1e-4;
            }
            assert_eq!(router.failed, 0);
            assert_eq!(router.failover.n, 0);
        }
    }

    #[test]
    fn remote_queries_move_bytes_local_single_node_does_not() {
        let store = test_store(800, 8, 9);
        let q = Query::BrightestN { n: 20, filter: SourceFilter::Any };
        // one node: everything is colocated with the front-end
        let mut local = Router::new(Arc::clone(&store), 1, 1, RouterConfig::default());
        let (r, _) = local.execute(0.0, &q);
        assert!(r.is_some());
        assert_eq!(local.fabric.bytes_moved, 0.0);
        // many nodes: most replicas are remote
        let mut dist = Router::new(Arc::clone(&store), 8, 2, RouterConfig::default());
        let (r2, _) = dist.execute(0.0, &q);
        assert_eq!(r2, r);
        assert!(dist.fabric.bytes_moved > 0.0);
        assert!(dist.fabric.transfers > 0);
    }

    #[test]
    fn failover_reroutes_and_records_latency() {
        let store = test_store(1000, 12, 7);
        let cfg = RouterConfig { routing: Routing::Random, ..Default::default() };
        let mut router = Router::new(Arc::clone(&store), 6, 3, cfg);
        // kill a shard-0 replica host that is not the front-end's node,
        // so the drill models a plain remote-node death
        let victim = *router
            .placement
            .replicas_of(0)
            .iter()
            .find(|&&n| n != 0)
            .expect("3 distinct replicas include a non-origin node");
        router = router.with_schedule(
            FailureSchedule::parse(&format!("{victim}@0.0")).unwrap(),
        );
        let q = Query::BrightestN { n: 5, filter: SourceFilter::Any };
        let want = execute(&store, &q);
        let mut failovers_seen = 0;
        let mut now = 1e-6; // after the kill
        for _ in 0..200 {
            let (res, _) = router.execute(now, &q);
            assert_eq!(res.expect("two replicas survive"), want);
            failovers_seen = router.failover.n;
            now += 1e-4;
        }
        assert_eq!(router.failed, 0);
        assert!(failovers_seen >= 1, "the dead replica was never discovered");
        assert!(router.failover.mean() > 0.0);
        // discovery happens once per dead node, not once per query
        assert!(router.failover.n <= 6, "{} failovers", router.failover.n);
        assert_eq!(router.served_per_node[victim], 0, "dead node served traffic");
    }

    #[test]
    fn all_replicas_dead_fails_queries_and_revive_heals() {
        let store = test_store(500, 4, 3);
        let mut router = Router::new(Arc::clone(&store), 2, 2, RouterConfig::default())
            .with_schedule(FailureSchedule::parse("0@0.0:1.0,1@0.0:1.0").unwrap());
        let q = Query::BrightestN { n: 3, filter: SourceFilter::Any };
        let (res, _) = router.execute(0.5, &q);
        assert!(res.is_none(), "no surviving replica anywhere");
        assert_eq!(router.failed, 1);
        // after both revive, service resumes and answers are exact
        let (res2, _) = router.execute(1.5, &q);
        assert_eq!(res2.expect("revived"), execute(&store, &q));
    }

    #[test]
    fn sim_open_loop_reports_latency_and_node_loads() {
        let store = test_store(2000, 8, 13);
        let router = Router::new(Arc::clone(&store), 4, 2, RouterConfig::default());
        let engine = RouterEngine::new(router);
        let cfg = LoadGenConfig::scenario("uniform", 5).unwrap();
        let mut gen = LoadGen::new(cfg, store.width, store.height);
        let mut clock = SimClock::new();
        let drive = drive_open_loop(&engine, &mut clock, &mut gen, 2000.0, 0.5);
        let rep = engine.dist_report(&drive);
        assert!(rep.offered > 500, "offered {}", rep.offered);
        assert_eq!(rep.completed, rep.offered);
        assert_eq!(rep.failed, 0);
        assert!(rep.latency_all().n == rep.completed);
        assert!(rep.latency_all().p50() > 0.0);
        assert!(rep.sim_secs > 0.4);
        assert!(rep.served_per_node.iter().sum::<u64>() >= rep.completed);
        assert!(rep.bytes_moved > 0.0);
        assert!(rep.imbalance() >= 1.0);
    }

    #[test]
    fn hedged_subqueries_preserve_results_and_are_counted() {
        let store = test_store(1200, 8, 21);
        let mut router = Router::new(Arc::clone(&store), 4, 2, RouterConfig::default());
        let q = Query::BrightestN { n: 30, filter: SourceFilter::Any };
        let want = execute(&store, &q);
        // zero budget: every primary reply exceeds it, so a hedge fires
        // for every shard that has an alternate replica
        let (res, done) = router.execute_with(0.0, &q, Some(0.0));
        assert_eq!(res.expect("no failures scheduled"), want);
        assert!(done > 0.0);
        assert!(router.hedges > 0, "zero budget must fire hedges");
        assert!(router.hedge_wins <= router.hedges);
        // without a budget nothing hedges
        let mut plain = Router::new(Arc::clone(&store), 4, 2, RouterConfig::default());
        let (res2, _) = plain.execute(0.0, &q);
        assert_eq!(res2.unwrap(), want);
        assert_eq!(plain.hedges, 0);
    }
}
