//! Shard clients: per-shard sub-query execution with costs in
//! simulated time.
//!
//! The distributed tier is modeled before it is built, exactly as
//! `cluster::sim` models inference: sub-queries execute for real (so
//! results are byte-exact), while their latency is charged to an
//! explicit cost model — per-request service time on the owning node
//! (nodes serve serially, so backlog queues in simulated time) plus,
//! for [`FabricShard`], request/response transfers through the same
//! [`ga::Fabric`](crate::ga::Fabric) NIC/bisection model the inference
//! side uses for global-array fetches.
//!
//! Clients carry no catalog data themselves: the router resolves which
//! epoch of the shard a replica node has applied (delta propagation
//! lags per node — see [`super::router`]) and hands the shard content
//! in per call. A client is just the *where* (node) and the *cost* of
//! asking.

use crate::ga::Fabric;

use super::super::query::Query;
use super::super::store::Shard;

// The per-shard execution and reply types live in `query` — one copy of
// the semantics shared by the single-host engine and this tier.
pub use super::super::query::{execute_on_shard, ShardReply};

/// Simulated-time costs of one shard request.
#[derive(Clone, Debug)]
pub struct CostModel {
    /// fixed service time per sub-query at the shard, seconds
    pub base_service: f64,
    /// added service time per result row, seconds
    pub per_row_service: f64,
    /// request message size, bytes
    pub req_bytes: f64,
    /// response envelope size, bytes
    pub envelope_bytes: f64,
    /// response payload per result row, bytes (~one `ServedSource`)
    pub row_bytes: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            base_service: 40e-6,
            per_row_service: 150e-9,
            req_bytes: 128.0,
            envelope_bytes: 64.0,
            row_bytes: 96.0,
        }
    }
}

impl CostModel {
    /// Service time of a reply with `rows` result rows.
    pub fn service_secs(&self, rows: usize) -> f64 {
        self.base_service + self.per_row_service * rows as f64
    }

    /// Response size of a reply with `rows` result rows.
    pub fn response_bytes(&self, rows: usize) -> f64 {
        self.envelope_bytes + self.row_bytes * rows as f64
    }

    /// Size of a delta shipment of `rows` upserts/tombstones to one
    /// replica (same envelope + per-row framing as a response).
    pub fn delta_bytes(&self, rows: usize) -> f64 {
        self.envelope_bytes + self.row_bytes * rows as f64
    }
}

/// One replica of one shard, addressable by the router. `call` executes
/// the sub-query against the shard content the replica's node has
/// applied (passed in by the router) and returns the reply plus its
/// simulated arrival time back at the origin node; `node_free` is the
/// per-node serial-service availability the replica queues on. `Send`
/// so a router full of boxed clients can sit behind the engine API's
/// shared-state wrappers.
pub trait ShardClient: Send {
    /// Node this replica lives on.
    fn node(&self) -> usize;

    /// Dispatch `q` at simulated time `now` from `origin` against this
    /// replica's `shard` content; transfer costs (if any) are charged
    /// to `fabric`.
    fn call(
        &self,
        now: f64,
        origin: usize,
        q: &Query,
        shard: &Shard,
        fabric: &mut Fabric,
        node_free: &mut [f64],
    ) -> (ShardReply, f64);
}

/// A replica colocated with the front-end: no network hop, but service
/// still queues on the owning node.
pub struct LocalShard {
    node: usize,
    cost: CostModel,
}

impl LocalShard {
    pub fn new(node: usize, cost: CostModel) -> LocalShard {
        LocalShard { node, cost }
    }
}

impl ShardClient for LocalShard {
    fn node(&self) -> usize {
        self.node
    }

    fn call(
        &self,
        now: f64,
        _origin: usize,
        q: &Query,
        shard: &Shard,
        _fabric: &mut Fabric,
        node_free: &mut [f64],
    ) -> (ShardReply, f64) {
        let reply = execute_on_shard(shard, q);
        let start = now.max(node_free[self.node]);
        let done = start + self.cost.service_secs(reply.rows());
        node_free[self.node] = done;
        (reply, done)
    }
}

/// A replica on a remote node: the request crosses the fabric, queues
/// on the remote node's serial service, and the response (sized by the
/// result rows) crosses back — all in `ga::Fabric` simulated time.
pub struct FabricShard {
    inner: LocalShard,
}

impl FabricShard {
    pub fn new(node: usize, cost: CostModel) -> FabricShard {
        FabricShard { inner: LocalShard::new(node, cost) }
    }
}

impl ShardClient for FabricShard {
    fn node(&self) -> usize {
        self.inner.node
    }

    fn call(
        &self,
        now: f64,
        origin: usize,
        q: &Query,
        shard: &Shard,
        fabric: &mut Fabric,
        node_free: &mut [f64],
    ) -> (ShardReply, f64) {
        let node = self.inner.node;
        let cost = &self.inner.cost;
        let t_req = fabric.get(now, cost.req_bytes, origin, node);
        let reply = execute_on_shard(shard, q);
        let start = t_req.max(node_free[node]);
        let svc_done = start + cost.service_secs(reply.rows());
        node_free[node] = svc_done;
        let done = fabric.get(svc_done, cost.response_bytes(reply.rows()), node, origin);
        (reply, done)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    use crate::ga::FabricConfig;
    use crate::serve::query::{execute, QueryResult, SourceFilter};
    use crate::serve::snapshot;
    use crate::serve::store::Store;

    fn test_store() -> Arc<Store> {
        let snap = snapshot::synthetic(600, 11);
        Arc::new(Store::build(snap.sources, snap.width, snap.height, 4))
    }

    #[test]
    fn per_shard_replies_merge_to_the_single_host_answer() {
        let store = test_store();
        let q = Query::Cone {
            center: (store.width * 0.5, store.height * 0.5),
            radius: 150.0,
            filter: SourceFilter::GalaxiesOnly,
        };
        let mut merged = Vec::new();
        for sh in &store.shards {
            match execute_on_shard(sh, &q) {
                ShardReply::Sources(v) => merged.extend(v),
                ShardReply::Match(_) => unreachable!(),
            }
        }
        merged.sort_by_key(|s| s.id);
        assert_eq!(execute(&store, &q), QueryResult::Sources(merged));
    }

    #[test]
    fn fabric_shard_is_slower_than_local_and_charges_bytes() {
        let store = test_store();
        let cost = CostModel::default();
        let local = LocalShard::new(0, cost.clone());
        let remote = FabricShard::new(1, cost);
        let q = Query::BrightestN { n: 50, filter: SourceFilter::Any };
        let shard = &store.shards[0];
        let mut fabric = Fabric::new(FabricConfig::default(), 2);
        let mut free = vec![0.0f64; 2];
        let (rl, tl) = local.call(0.0, 0, &q, shard, &mut fabric, &mut free);
        assert_eq!(fabric.transfers, 0, "local replica must not touch the fabric");
        let mut free2 = vec![0.0f64; 2];
        let (rr, tr) = remote.call(0.0, 0, &q, shard, &mut fabric, &mut free2);
        assert_eq!(rl, rr, "same shard, same reply");
        assert!(tr > tl, "remote {tr} must cost more than local {tl}");
        assert_eq!(fabric.transfers, 2, "request + response");
        assert!(fabric.bytes_moved > 128.0);
    }

    #[test]
    fn node_service_serializes_in_simulated_time() {
        let store = test_store();
        let cost = CostModel::default();
        let a = LocalShard::new(0, cost.clone());
        let b = LocalShard::new(0, cost);
        let q = Query::BrightestN { n: 10, filter: SourceFilter::Any };
        let mut fabric = Fabric::new(FabricConfig::default(), 1);
        let mut free = vec![0.0f64; 1];
        let (_, t1) = a.call(0.0, 0, &q, &store.shards[0], &mut fabric, &mut free);
        let (_, t2) = b.call(0.0, 0, &q, &store.shards[1], &mut fabric, &mut free);
        assert!(t2 > t1, "same-node requests must queue: {t1} {t2}");
    }
}
