//! Fault injection for the distributed serving tier: kill (and revive)
//! shard-server nodes at scheduled simulated times.
//!
//! The router discovers a dead node the way a real front-end does — by
//! timing out on it — then reroutes to surviving replicas and records
//! the failover latency. A revive models the health-checker readmitting
//! the node.

/// One scheduled liveness transition.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FailureEvent {
    /// simulated time, seconds
    pub at: f64,
    pub node: usize,
    /// true = revive, false = kill
    pub up: bool,
}

/// A time-ordered schedule of kill/revive events, consumed as simulated
/// time advances.
#[derive(Clone, Debug, Default)]
pub struct FailureSchedule {
    events: Vec<FailureEvent>,
    cursor: usize,
}

impl FailureSchedule {
    pub fn new(mut events: Vec<FailureEvent>) -> FailureSchedule {
        events.sort_by(|a, b| a.at.partial_cmp(&b.at).unwrap_or(std::cmp::Ordering::Equal));
        FailureSchedule { events, cursor: 0 }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Highest node id the schedule touches — callers validate it
    /// against their node count so a typo'd `--kill-node 7@1` on a
    /// 4-node tier errors instead of silently injecting nothing.
    pub fn max_node(&self) -> Option<usize> {
        self.events.iter().map(|e| e.node).max()
    }

    /// The time-ordered events. The tcp transport drives *real* child
    /// process kills from the same parsed schedule the simulated tier
    /// consumes through [`apply`](FailureSchedule::apply).
    pub fn events(&self) -> &[FailureEvent] {
        &self.events
    }

    /// True if any event is a revive — the tcp transport can kill a
    /// child process but not restart one, so it rejects these up front.
    pub fn has_revive(&self) -> bool {
        self.events.iter().any(|e| e.up)
    }

    /// Parse a CLI spec: comma-separated `NODE@T` (kill node NODE at
    /// simulated second T) or `NODE@T1:T2` (kill at T1, revive at T2).
    /// Examples: `3@0.5`, `0@1.0:2.0,4@1.5`.
    pub fn parse(spec: &str) -> Option<FailureSchedule> {
        let mut events = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (node_s, times) = part.split_once('@')?;
            let node: usize = node_s.trim().parse().ok()?;
            match times.split_once(':') {
                Some((t_kill, t_revive)) => {
                    let kill: f64 = t_kill.trim().parse().ok()?;
                    let revive: f64 = t_revive.trim().parse().ok()?;
                    if revive <= kill {
                        return None;
                    }
                    events.push(FailureEvent { at: kill, node, up: false });
                    events.push(FailureEvent { at: revive, node, up: true });
                }
                None => {
                    let kill: f64 = times.trim().parse().ok()?;
                    events.push(FailureEvent { at: kill, node, up: false });
                }
            }
        }
        if events.is_empty() {
            None
        } else {
            Some(FailureSchedule::new(events))
        }
    }

    /// Apply every event due at or before `now` to the liveness vector
    /// (nodes outside its range are ignored). Returns the events that
    /// fired. `suspected` is the router's stale-knowledge vector: a
    /// revive clears suspicion so traffic can return.
    pub fn apply(&mut self, now: f64, alive: &mut [bool], suspected: &mut [bool]) -> usize {
        let mut fired = 0;
        while self.cursor < self.events.len() && self.events[self.cursor].at <= now {
            let ev = self.events[self.cursor];
            self.cursor += 1;
            fired += 1;
            if ev.node < alive.len() {
                alive[ev.node] = ev.up;
                if ev.up {
                    suspected[ev.node] = false;
                }
            }
        }
        fired
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_kill_and_kill_revive_specs() {
        let s = FailureSchedule::parse("3@0.5").unwrap();
        assert_eq!(s.events, vec![FailureEvent { at: 0.5, node: 3, up: false }]);
        let s2 = FailureSchedule::parse("0@1.0:2.0,4@1.5").unwrap();
        assert_eq!(s2.events.len(), 3);
        // sorted by time
        assert_eq!(s2.events[0].at, 1.0);
        assert_eq!(s2.events[1].at, 1.5);
        assert_eq!(s2.events[2], FailureEvent { at: 2.0, node: 0, up: true });
        assert_eq!(s2.max_node(), Some(4));
        assert_eq!(FailureSchedule::default().max_node(), None);
        assert!(FailureSchedule::parse("").is_none());
        assert!(FailureSchedule::parse("x@1").is_none());
        assert!(FailureSchedule::parse("1@2:1").is_none(), "revive before kill");
    }

    #[test]
    fn apply_fires_due_events_in_order() {
        let mut s = FailureSchedule::parse("1@0.2:0.6").unwrap();
        let mut alive = vec![true; 3];
        let mut suspected = vec![false; 3];
        assert_eq!(s.apply(0.1, &mut alive, &mut suspected), 0);
        assert!(alive[1]);
        assert_eq!(s.apply(0.3, &mut alive, &mut suspected), 1);
        assert!(!alive[1]);
        suspected[1] = true; // router discovered the death
        assert_eq!(s.apply(1.0, &mut alive, &mut suspected), 1);
        assert!(alive[1]);
        assert!(!suspected[1], "revive must clear suspicion");
        // schedule exhausted
        assert_eq!(s.apply(9.0, &mut alive, &mut suspected), 0);
    }
}
