//! Range-to-node assignment with replication.
//!
//! The store's Hilbert-range shards are placed on simulated nodes by
//! rendezvous (highest-random-weight) hashing: every (shard, node) pair
//! gets a deterministic pseudo-random score, and a shard's R replicas
//! live on the R highest-scoring nodes. Rendezvous placement has the
//! property a growing serving tier needs: adding a node only pulls in
//! the ranges for which the new node now scores in the top R — every
//! replica that moves, moves *to the new node*, and everything else
//! stays put (no re-keying, no cascading shuffles).

/// splitmix64-style avalanche over the (shard, node) pair.
fn score(shard: u64, node: u64) -> u64 {
    let mut x = shard
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ node.wrapping_mul(0xD1B5_4A32_D192_ED03);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    x
}

/// A replicated assignment of shards to nodes.
#[derive(Clone, Debug)]
pub struct Placement {
    pub n_nodes: usize,
    /// replication factor actually used (clamped to `n_nodes`)
    pub replicas: usize,
    /// per shard: the replica node ids, rendezvous-score descending
    pub shard_nodes: Vec<Vec<usize>>,
}

impl Placement {
    /// Place `n_shards` ranges onto `n_nodes` nodes with `replicas`
    /// copies each (clamped to at least 1 and at most `n_nodes`).
    pub fn rendezvous(n_shards: usize, n_nodes: usize, replicas: usize) -> Placement {
        let n_nodes = n_nodes.max(1);
        let nodes: Vec<usize> = (0..n_nodes).collect();
        Placement::rendezvous_among(n_shards, n_nodes, &nodes, replicas)
    }

    /// Rendezvous placement over an explicit member set (node ids below
    /// `n_nodes`). This is how a node *removal* is expressed — rerank
    /// over the survivors — and rendezvous guarantees the mirror image
    /// of the growth property: only ranges that lived on the removed
    /// node move, each to the next-highest-scoring survivor.
    pub fn rendezvous_among(
        n_shards: usize,
        n_nodes: usize,
        nodes: &[usize],
        replicas: usize,
    ) -> Placement {
        let n_nodes = n_nodes.max(1);
        let replicas = replicas.clamp(1, nodes.len().max(1));
        let shard_nodes = (0..n_shards)
            .map(|s| {
                let mut scored: Vec<(u64, usize)> =
                    nodes.iter().map(|&n| (score(s as u64, n as u64), n)).collect();
                // score ties broken by node id so placement is total
                scored.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
                scored.truncate(replicas);
                scored.into_iter().map(|(_, n)| n).collect()
            })
            .collect();
        Placement { n_nodes, replicas, shard_nodes }
    }

    /// Rendezvous placement scored by an explicit *key* per range
    /// instead of the range's index. `keys = [0, 1, .., n)` reproduces
    /// [`Placement::rendezvous_among`] exactly.
    ///
    /// This is what makes compaction's rebalancing minimal: a range is
    /// identified by its `key_lo` (stable across re-splits — a split's
    /// lower half and a merge's surviving range keep theirs), so only
    /// ranges whose key changed get rescored. An index-keyed placement
    /// would reshuffle every range downstream of a split.
    pub fn rendezvous_keyed(
        keys: &[u64],
        n_nodes: usize,
        nodes: &[usize],
        replicas: usize,
    ) -> Placement {
        let n_nodes = n_nodes.max(1);
        let replicas = replicas.clamp(1, nodes.len().max(1));
        let shard_nodes = keys
            .iter()
            .map(|&k| {
                let mut scored: Vec<(u64, usize)> =
                    nodes.iter().map(|&n| (score(k, n as u64), n)).collect();
                scored.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
                scored.truncate(replicas);
                scored.into_iter().map(|(_, n)| n).collect()
            })
            .collect();
        Placement { n_nodes, replicas, shard_nodes }
    }

    pub fn n_shards(&self) -> usize {
        self.shard_nodes.len()
    }

    /// Replica node ids of one shard.
    pub fn replicas_of(&self, shard: usize) -> &[usize] {
        &self.shard_nodes[shard]
    }

    /// Number of shard replicas hosted by each node.
    pub fn counts_per_node(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_nodes];
        for nodes in &self.shard_nodes {
            for &n in nodes {
                counts[n] += 1;
            }
        }
        counts
    }

    /// Placement imbalance: max over mean of per-node replica counts
    /// (1.0 = perfectly even; 0.0 for a degenerate empty placement).
    pub fn imbalance(&self) -> f64 {
        let counts = self.counts_per_node();
        let max = counts.iter().copied().max().unwrap_or(0) as f64;
        let mean =
            counts.iter().sum::<usize>() as f64 / counts.len().max(1) as f64;
        if mean <= 0.0 {
            0.0
        } else {
            max / mean
        }
    }

    /// One-line description for logs.
    pub fn summary(&self) -> String {
        format!(
            "placement: {} shard(s) x{} replicas over {} node(s) (per-node {:?}, imbalance {:.2})",
            self.n_shards(),
            self.replicas,
            self.n_nodes,
            self.counts_per_node(),
            self.imbalance()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replicas_are_distinct_and_clamped() {
        let p = Placement::rendezvous(32, 5, 3);
        assert_eq!(p.n_shards(), 32);
        for s in 0..32 {
            let nodes = p.replicas_of(s);
            assert_eq!(nodes.len(), 3);
            for (i, &a) in nodes.iter().enumerate() {
                assert!(a < 5);
                for &b in &nodes[i + 1..] {
                    assert_ne!(a, b, "duplicate replica node for shard {s}");
                }
            }
        }
        // more replicas than nodes: clamp to n_nodes
        let p2 = Placement::rendezvous(8, 2, 5);
        assert_eq!(p2.replicas, 2);
        for s in 0..8 {
            assert_eq!(p2.replicas_of(s).len(), 2);
        }
    }

    #[test]
    fn placement_is_deterministic() {
        let a = Placement::rendezvous(64, 8, 3);
        let b = Placement::rendezvous(64, 8, 3);
        assert_eq!(a.shard_nodes, b.shard_nodes);
    }

    #[test]
    fn adding_a_node_only_moves_ranges_to_the_new_node() {
        // the rendezvous guarantee: growing n -> n+1 nodes, any replica
        // that appears in the new assignment but not the old one must be
        // the new node itself
        let (n_shards, replicas) = (256, 3);
        for n in [2usize, 4, 8, 15] {
            let old = Placement::rendezvous(n_shards, n, replicas);
            let new = Placement::rendezvous(n_shards, n + 1, replicas);
            let mut moved = 0usize;
            for s in 0..n_shards {
                let old_set: Vec<usize> = old.replicas_of(s).to_vec();
                for &node in new.replicas_of(s) {
                    if !old_set.contains(&node) {
                        assert_eq!(node, n, "shard {s} moved to a pre-existing node");
                        moved += 1;
                    }
                }
            }
            // and the expected movement is roughly R/(n+1) of all slots,
            // never a full reshuffle
            assert!(
                moved <= n_shards * replicas / 2,
                "n={n}: {moved} moved slots looks like a reshuffle"
            );
        }
    }

    #[test]
    fn removing_a_node_only_reassigns_ranges_that_lived_on_it() {
        // the mirror of the growth property: reranking over the
        // survivors must leave every shard that never touched the
        // removed node exactly where it was, and replace the removed
        // replica (where present) with exactly one survivor
        let (n_shards, replicas) = (256, 3);
        for n in [3usize, 5, 8, 12] {
            for removed in [0usize, 1, n - 1] {
                let full = Placement::rendezvous(n_shards, n, replicas);
                let survivors: Vec<usize> = (0..n).filter(|&x| x != removed).collect();
                let shrunk =
                    Placement::rendezvous_among(n_shards, n, &survivors, replicas);
                for s in 0..n_shards {
                    let old = full.replicas_of(s);
                    let new = shrunk.replicas_of(s);
                    if !old.contains(&removed) {
                        assert_eq!(old, new, "n={n} removed={removed} shard {s} moved");
                        continue;
                    }
                    // survivors keep their replicas; exactly one new
                    // node backfills the lost copy
                    for &node in old.iter().filter(|&&x| x != removed) {
                        assert!(new.contains(&node), "n={n} shard {s} lost survivor {node}");
                    }
                    let gained: Vec<usize> = new
                        .iter()
                        .copied()
                        .filter(|node| !old.contains(node))
                        .collect();
                    assert_eq!(gained.len(), 1, "n={n} shard {s}: gained {gained:?}");
                    assert_ne!(gained[0], removed);
                }
            }
        }
    }

    #[test]
    fn keyed_rendezvous_generalizes_indexed_rendezvous() {
        let nodes: Vec<usize> = (0..6).collect();
        let keys: Vec<u64> = (0..48).collect();
        let by_index = Placement::rendezvous_among(48, 6, &nodes, 2);
        let by_key = Placement::rendezvous_keyed(&keys, 6, &nodes, 2);
        assert_eq!(by_index.shard_nodes, by_key.shard_nodes);
    }

    #[test]
    fn keyed_rendezvous_moves_only_rekeyed_ranges() {
        // the compaction contract: ranges keeping their key keep their
        // replica set, regardless of how neighbors split or merge
        let nodes: Vec<usize> = (0..5).collect();
        let before: Vec<u64> = vec![10, 200, 3000, 40_000, 500_000, 6_000_000];
        // "split" range 1 (new upper half keyed 900) and "merge" 4+5
        // (survivor keeps 500_000): indices shift, three keys survive
        let after: Vec<u64> = vec![10, 200, 900, 3000, 40_000, 500_000];
        let pa = Placement::rendezvous_keyed(&before, 5, &nodes, 2);
        let pb = Placement::rendezvous_keyed(&after, 5, &nodes, 2);
        for (&k, sa) in before.iter().zip(&pa.shard_nodes) {
            if let Some(j) = after.iter().position(|&x| x == k) {
                assert_eq!(sa, &pb.shard_nodes[j], "range keyed {k} moved without re-keying");
            }
        }
    }

    #[test]
    fn load_spreads_over_nodes() {
        let p = Placement::rendezvous(256, 8, 2);
        let counts = p.counts_per_node();
        assert_eq!(counts.iter().sum::<usize>(), 256 * 2);
        assert!(counts.iter().all(|&c| c > 0), "an idle node: {counts:?}");
        assert!(p.imbalance() < 2.0, "imbalance {}", p.imbalance());
    }
}
