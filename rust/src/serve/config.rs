//! `serve-bench`'s typed configuration: every flag parsed and
//! cross-validated in one place.
//!
//! The bench grew one tier at a time, and so did its flag parsing —
//! the contradiction matrix (which flags belong to which tier, which
//! flags require which) was smeared through `cmd_serve_bench`.
//! [`ServeConfig::from_cli`] centralizes it: parse once, validate every
//! cross-flag rule with an error that names both sides, and hand the
//! drivers a typed struct instead of a bag of strings. The conflict
//! pairs are pinned by unit tests here, so a new flag that silently
//! breaks an old rule fails in `cargo test`, not in a user's terminal.
//!
//! This is also where the control plane's flags live
//! (`docs/CONTROL.md`):
//!
//! * `--rebalance MS` — run a [`crate::serve::control::Controller`]
//!   with a decision window of `MS` milliseconds (distributed tiers,
//!   sim and tcp);
//! * `--autoscale MIN..MAX` — let the controller grow/retire membership
//!   inside the band (simulated tier only: real shard-server processes
//!   cannot be spawned on demand mid-run);
//! * `--priority-mix L:N:H` — stamp each generated request's
//!   [`crate::serve::engine::Priority`] from these weights;
//! * `--load-curve PERIOD:PEAK` — swell the offered rate by a
//!   raised-cosine curve, the diurnal shape an autoscaler reacts to.

use crate::cli::Cli;
use crate::serve::control::ControlConfig;
use crate::serve::engine::LayerSpec;
use crate::serve::loadgen::LoadGenConfig;
use crate::serve::sched::{SchedConfig, SchedKind};

macro_rules! fail {
    ($($t:tt)*) => { return Err(format!($($t)*)) };
}

/// `"MIN..MAX"` as a pair of counts.
fn parse_band(raw: &str) -> Option<(usize, usize)> {
    let (lo, hi) = raw.split_once("..")?;
    Some((lo.parse().ok()?, hi.parse().ok()?))
}

/// `"A:B"` as a pair of floats.
fn parse_pair(raw: &str) -> Option<(f64, f64)> {
    let (a, b) = raw.split_once(':')?;
    Some((a.parse().ok()?, b.parse().ok()?))
}

/// Everything `serve-bench` needs to know, parsed and cross-validated.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// `--transport tcp` (real shard-server processes, wall clock)
    pub tcp: bool,
    /// `--dist-nodes N` (0 = single-host tier)
    pub dist_nodes: usize,
    /// `--replicas R` (distributed tiers; parsed here so the autoscale
    /// band can be validated against it)
    pub replicas: usize,
    /// `--threads N` (single-host worker pool)
    pub threads: usize,
    pub shards: usize,
    pub qps: f64,
    pub secs: f64,
    pub mix: String,
    pub seed: u64,
    pub n_sources: usize,
    pub sched: SchedConfig,
    pub burst: usize,
    /// the middleware layer stack (admission bound, cache, hedging)
    pub spec: LayerSpec,
    /// `--rebalance MS` as seconds (0 = controller off)
    pub rebalance_s: f64,
    /// `--autoscale MIN..MAX` membership band (requires `--rebalance`)
    pub autoscale: Option<(usize, usize)>,
    /// `--priority-mix L:N:H` draw weights
    pub priority_mix: Option<[f64; 3]>,
    /// `--load-curve PERIOD:PEAK` as `(period_s, peak)`
    pub rate_curve: Option<(f64, f64)>,
}

impl ServeConfig {
    /// Parse and cross-validate the full `serve-bench` flag set. Every
    /// rule produces an error naming the flags in conflict and what to
    /// change; the first violated rule wins (matching the historical
    /// in-line validation order).
    pub fn from_cli(cli: &Cli) -> Result<ServeConfig, String> {
        // --threads sizes the single-host worker pool; --dist-nodes
        // replaces that pool with the simulated multi-node tier. Naming
        // both is a contradiction we refuse rather than guess about
        // (--dist-nodes 0 keeps its historical meaning: tier off).
        let transport = cli.flag_str("transport", "sim");
        if !matches!(transport, "sim" | "tcp") {
            fail!("bad --transport {transport:?}: want sim|tcp");
        }
        let tcp = transport == "tcp";
        let dist_nodes = cli.flag_count("dist-nodes", 0, 0)?;
        let dist = dist_nodes > 0;
        if tcp && !dist {
            fail!(
                "--transport tcp spawns real shard-server processes; say how many with \
                 --dist-nodes N (N >= 1)"
            );
        }
        if tcp {
            for key in ["routing", "hedge-ms", "hedge-budget"] {
                if cli.flag(key).is_some() {
                    fail!(
                        "--{key} configures the simulated fabric tier; the tcp transport \
                         measures real sockets and does not take it"
                    );
                }
            }
        }
        if dist && cli.flag("threads").is_some() {
            fail!(
                "--threads and --dist-nodes contradict: --threads sizes the single-host \
                 worker pool, --dist-nodes replaces it with the simulated multi-node tier. \
                 Pass exactly one of them (plain serve-bench = single-host)."
            );
        }
        if !dist {
            for key in ["replicas", "routing", "kill-node", "hedge-ms", "hedge-budget"] {
                if cli.flag(key).is_some() {
                    fail!("--{key} only applies to the distributed tier; add --dist-nodes N");
                }
            }
            for key in ["trace-sample", "slow-ms"] {
                if cli.flag(key).is_some() {
                    fail!(
                        "--{key} samples per-request span traces, which live on the \
                         distributed tiers; add --dist-nodes N (the single-host tier still \
                         supports --obs-dump)"
                    );
                }
            }
        } else {
            if cli.flag("queue-depth").is_some() {
                fail!(
                    "--queue-depth only applies to the single-host tier (the simulated tier \
                     models backlog as latency, not sheds); drop it or drop --dist-nodes"
                );
            }
            for key in ["sched", "batch"] {
                if cli.flag(key).is_some() {
                    fail!(
                        "--{key} configures the single-host worker pool's request scheduler; \
                         the simulated tier has no worker pool. Drop it or drop --dist-nodes."
                    );
                }
            }
        }
        if cli.flag("ingest-batch").is_some() && cli.flag("ingest-qps").is_none() {
            fail!("--ingest-batch sizes ingestion publishes; add --ingest-qps R to enable them");
        }
        if cli.flag("hedge-budget").is_some() && cli.flag("hedge-ms").is_none() {
            fail!("--hedge-budget caps the hedge layer; add --hedge-ms B to enable hedging");
        }
        // durability flag matrix: the WAL logs ingestion publishes, so
        // it needs an ingest stream; the simulated tier has nothing
        // real to fsync; compaction rides the single-host ingest loop
        if cli.flag("wal-dir").is_some() && cli.flag("ingest-qps").is_none() {
            fail!("--wal-dir logs ingestion publishes; add --ingest-qps R to generate them");
        }
        if cli.flag("wal-dir").is_some() && dist && !tcp {
            fail!(
                "--wal-dir appends and fsyncs a real on-disk log; the simulated fabric tier \
                 has nothing durable to protect. Use the single-host tier or --transport tcp."
            );
        }
        if cli.flag("checkpoint-every").is_some() && cli.flag("wal-dir").is_none() {
            fail!("--checkpoint-every sets the WAL checkpoint cadence; add --wal-dir DIR");
        }
        if cli.flag("compact-threshold").is_some() && dist {
            fail!(
                "--compact-threshold runs the single-host Hilbert-range compactor; \
                 distributed compaction is not wired yet. Drop --dist-nodes."
            );
        }
        if cli.flag("compact-threshold").is_some() && cli.flag("ingest-qps").is_none() {
            fail!(
                "--compact-threshold watches shard skew produced by live ingestion; \
                 add --ingest-qps R"
            );
        }
        if cli.flag("pipeline").is_some() && !tcp {
            fail!(
                "--pipeline sets per-connection request pipelining on real sockets; \
                 add --transport tcp"
            );
        }

        // counts are validated, not silently clamped: `--threads 0` (or
        // a negative / non-numeric value the old parser defaulted away)
        // is a misconfiguration the user should hear about
        let threads = cli.flag_count("threads", 4, 1)?;
        let shards = cli.flag_count("shards", 8, 1)?;
        let replicas = cli.flag_count("replicas", 2, 1)?;
        let qps = cli.flag_parse("qps", 2000.0f64);
        let secs = cli.flag_parse("secs", 3.0f64).max(0.1);
        let mix = cli.flag_str("mix", "uniform").to_string();
        let seed = cli.flag_u64("seed", 42);
        let n_sources = cli.flag_count("sources", 5000, 1)?;
        let sched_s = cli.flag_str("sched", "condvar");
        let Some(sched_kind) = SchedKind::parse(sched_s) else {
            fail!("bad --sched {sched_s:?}: want condvar|steal");
        };
        let sched = SchedConfig { kind: sched_kind, batch: cli.flag_count("batch", 1, 1)? };
        let burst = cli.flag_count("burst", 1, 1)?;
        let mut spec = LayerSpec {
            admit_depth: cli.flag_usize("queue-depth", 1024),
            cache_entries: cli.flag_usize("cache", 512),
            hedge_budget: cli.flag_parse("hedge-ms", 0.0f64).max(0.0) * 1e-3,
            hedge_cap: cli.flag_parse("hedge-budget", 0.05f64).max(0.0),
            ..Default::default()
        };

        // --- the control plane (docs/CONTROL.md) ---
        let rebalance_s = match cli.flag("rebalance") {
            None => 0.0,
            Some(raw) => {
                if !dist {
                    fail!(
                        "--rebalance runs the distributed control plane's decision loop; \
                         add --dist-nodes N"
                    );
                }
                match raw.parse::<f64>() {
                    Ok(ms) if ms.is_finite() && ms > 0.0 => ms * 1e-3,
                    _ => fail!(
                        "--rebalance is the controller's decision window in milliseconds \
                         and must be positive, got {raw:?}"
                    ),
                }
            }
        };
        let autoscale = match cli.flag("autoscale") {
            None => None,
            Some(raw) => {
                if cli.flag("rebalance").is_none() {
                    fail!(
                        "--autoscale scales membership from the controller's decision loop; \
                         add --rebalance MS to run one"
                    );
                }
                if tcp {
                    fail!(
                        "--autoscale grows and retires modeled nodes mid-run; real \
                         shard-server processes cannot be spawned on demand. Drop \
                         --transport tcp (the tcp tier still takes --rebalance)."
                    );
                }
                let Some((lo, hi)) = parse_band(raw) else {
                    fail!("bad --autoscale {raw:?}: want MIN..MAX (e.g. 2..6)");
                };
                if lo < 1 || hi < lo {
                    fail!("bad --autoscale {raw:?}: want 1 <= MIN <= MAX");
                }
                if lo < replicas {
                    fail!(
                        "--autoscale floor {lo} is below --replicas {replicas}: every shard \
                         needs that many distinct members even at the floor"
                    );
                }
                if dist_nodes < lo || dist_nodes > hi {
                    fail!(
                        "--autoscale {lo}..{hi} must bracket --dist-nodes {dist_nodes}: the \
                         band scales the starting membership"
                    );
                }
                Some((lo, hi))
            }
        };
        let priority_mix = match cli.flag("priority-mix") {
            None => None,
            Some(raw) => {
                let parts: Vec<f64> =
                    raw.split(':').filter_map(|p| p.parse::<f64>().ok()).collect();
                let ok = parts.len() == 3
                    && raw.split(':').count() == 3
                    && parts.iter().all(|w| w.is_finite() && *w >= 0.0)
                    && parts.iter().sum::<f64>() > 0.0;
                if !ok {
                    fail!(
                        "bad --priority-mix {raw:?}: want three non-negative weights \
                         LOW:NORMAL:HIGH with a positive sum, e.g. 6:3:1"
                    );
                }
                Some([parts[0], parts[1], parts[2]])
            }
        };
        // a mixed-priority stream is what graded admission exists to
        // triage: shed the low-priority expensive classes first instead
        // of uniformly at the depth (see engine::admit_fraction)
        spec.graded_admission = priority_mix.is_some();
        let rate_curve = match cli.flag("load-curve") {
            None => None,
            Some(raw) => {
                let Some((period, peak)) = parse_pair(raw) else {
                    fail!(
                        "bad --load-curve {raw:?}: want PERIOD_S:PEAK \
                         (e.g. 4:3 = a 4-second period swelling to 3x the base rate)"
                    );
                };
                if !(period.is_finite() && period > 0.0 && peak.is_finite() && peak >= 1.0) {
                    fail!(
                        "bad --load-curve {raw:?}: PERIOD_S must be positive and PEAK \
                         at least 1.0"
                    );
                }
                Some((period, peak))
            }
        };

        Ok(ServeConfig {
            tcp,
            dist_nodes,
            replicas,
            threads,
            shards,
            qps,
            secs,
            mix,
            seed,
            n_sources,
            sched,
            burst,
            spec,
            rebalance_s,
            autoscale,
            priority_mix,
            rate_curve,
        })
    }

    /// Any distributed tier selected (`--dist-nodes N` with N > 0).
    pub fn dist(&self) -> bool {
        self.dist_nodes > 0
    }

    /// Node capacity the tier is constructed with: the autoscale
    /// ceiling when a band is set (headroom allocated up front,
    /// placement confined to the starting members), else the node
    /// count itself.
    pub fn capacity(&self) -> usize {
        self.autoscale.map(|(_, hi)| hi).unwrap_or(self.dist_nodes).max(1)
    }

    /// The controller to run, when `--rebalance` asked for one.
    pub fn controller_config(&self) -> Option<ControlConfig> {
        if self.rebalance_s <= 0.0 {
            return None;
        }
        Some(ControlConfig {
            period_s: self.rebalance_s,
            autoscale: self.autoscale,
            ..Default::default()
        })
    }

    /// Overlay the load-shape flags onto a scenario-derived generator
    /// config (flags win; absent flags leave the scenario's values).
    pub fn apply_to_loadgen(&self, gen: &mut LoadGenConfig) {
        gen.burst = self.burst;
        if let Some(mix) = self.priority_mix {
            gen.priority_mix = Some(mix);
        }
        if let Some(curve) = self.rate_curve {
            gen.rate_curve = Some(curve);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli(s: &str) -> Cli {
        Cli::parse(s.split_whitespace().map(String::from))
    }

    fn err(s: &str) -> String {
        ServeConfig::from_cli(&cli(s)).expect_err("flag set should be rejected")
    }

    fn ok(s: &str) -> ServeConfig {
        match ServeConfig::from_cli(&cli(s)) {
            Ok(c) => c,
            Err(e) => panic!("flag set {s:?} should parse, got: {e}"),
        }
    }

    #[test]
    fn defaults_parse_to_the_single_host_tier() {
        let c = ok("serve-bench");
        assert!(!c.tcp && !c.dist());
        assert_eq!((c.threads, c.shards, c.burst), (4, 8, 1));
        assert_eq!(c.spec.admit_depth, 1024);
        assert_eq!(c.spec.cache_entries, 512);
        assert!(!c.spec.graded_admission, "graded admission rides --priority-mix");
        assert_eq!(c.rebalance_s, 0.0);
        assert!(c.autoscale.is_none() && c.priority_mix.is_none() && c.rate_curve.is_none());
        assert!(c.controller_config().is_none());
    }

    #[test]
    fn transport_must_be_sim_or_tcp_and_tcp_needs_nodes() {
        assert!(err("serve-bench --transport quic").contains("--transport"));
        let e = err("serve-bench --transport tcp");
        assert!(e.contains("--dist-nodes"), "{e}");
    }

    #[test]
    fn tcp_rejects_each_sim_only_flag() {
        for pair in ["--routing p2c", "--hedge-ms 1", "--hedge-budget 0.1"] {
            let e = err(&format!("serve-bench --transport tcp --dist-nodes 2 {pair}"));
            let flag = pair.split_whitespace().next().unwrap();
            assert!(e.contains(flag) && e.contains("tcp"), "{pair}: {e}");
        }
    }

    #[test]
    fn threads_and_dist_nodes_contradict() {
        let e = err("serve-bench --threads 4 --dist-nodes 4");
        assert!(e.contains("--threads") && e.contains("--dist-nodes"), "{e}");
    }

    #[test]
    fn single_host_rejects_each_dist_only_flag() {
        for pair in [
            "--replicas 2",
            "--routing p2c",
            "--kill-node 1@0.5",
            "--hedge-ms 1",
            "--hedge-budget 0.1",
            "--trace-sample 10",
            "--slow-ms 5",
        ] {
            let e = err(&format!("serve-bench {pair}"));
            let flag = pair.split_whitespace().next().unwrap();
            assert!(e.contains(flag) && e.contains("--dist-nodes"), "{pair}: {e}");
        }
    }

    #[test]
    fn dist_rejects_each_single_host_flag() {
        for pair in ["--queue-depth 64", "--sched steal", "--batch 8"] {
            let e = err(&format!("serve-bench --dist-nodes 4 {pair}"));
            let flag = pair.split_whitespace().next().unwrap();
            assert!(e.contains(flag), "{pair}: {e}");
        }
    }

    #[test]
    fn dependent_flags_name_their_prerequisite() {
        for (flags, want) in [
            ("--ingest-batch 16", "--ingest-qps"),
            ("--hedge-ms 1 --hedge-budget 0.1 --dist-nodes 2", ""), // valid: both present
            ("--checkpoint-every 4", "--wal-dir"),
            ("--pipeline 4", "--transport tcp"),
            ("--wal-dir d", "--ingest-qps"),
            ("--compact-threshold 1.5", "--ingest-qps"),
        ] {
            let line = format!("serve-bench {flags}");
            if want.is_empty() {
                ok(&line);
            } else {
                let e = err(&line);
                assert!(e.contains(want), "{flags}: {e}");
            }
        }
        // a hedge cap without a hedge budget is the orphan
        let e = err("serve-bench --dist-nodes 2 --hedge-budget 0.1");
        assert!(e.contains("--hedge-ms"), "{e}");
        // the WAL is refused on the simulated fabric tier specifically
        let e = err("serve-bench --dist-nodes 2 --ingest-qps 10 --wal-dir d");
        assert!(e.contains("simulated"), "{e}");
        ok("serve-bench --transport tcp --dist-nodes 2 --ingest-qps 10 --wal-dir d");
        // distributed compaction is not wired
        let e = err("serve-bench --dist-nodes 2 --ingest-qps 10 --compact-threshold 1.5");
        assert!(e.contains("--compact-threshold"), "{e}");
    }

    #[test]
    fn rebalance_requires_the_distributed_tier_and_a_positive_window() {
        let e = err("serve-bench --rebalance 250");
        assert!(e.contains("--rebalance") && e.contains("--dist-nodes"), "{e}");
        for bad in ["0", "-5", "x"] {
            let e = err(&format!("serve-bench --dist-nodes 4 --rebalance {bad}"));
            assert!(e.contains("--rebalance") && e.contains("positive"), "{bad}: {e}");
        }
        let c = ok("serve-bench --dist-nodes 4 --rebalance 250");
        assert!((c.rebalance_s - 0.25).abs() < 1e-12);
        let ctl = c.controller_config().expect("controller requested");
        assert!((ctl.period_s - 0.25).abs() < 1e-12);
        assert!(ctl.autoscale.is_none());
        // the tcp tier takes --rebalance too (routing-only migration)
        ok("serve-bench --transport tcp --dist-nodes 3 --rebalance 250");
    }

    #[test]
    fn autoscale_requires_rebalance_and_the_simulated_tier() {
        let e = err("serve-bench --dist-nodes 4 --autoscale 2..6");
        assert!(e.contains("--rebalance"), "{e}");
        let e = err(
            "serve-bench --transport tcp --dist-nodes 4 --rebalance 250 --autoscale 2..6",
        );
        assert!(e.contains("--transport tcp"), "{e}");
    }

    #[test]
    fn autoscale_band_is_validated_against_replicas_and_nodes() {
        for bad in ["2", "2..", "..4", "4..2", "0..4", "a..b"] {
            let e = err(&format!("serve-bench --dist-nodes 4 --rebalance 250 --autoscale {bad}"));
            assert!(e.contains("--autoscale"), "{bad}: {e}");
        }
        // the floor must hold --replicas distinct members
        let e = err("serve-bench --dist-nodes 4 --replicas 3 --rebalance 250 --autoscale 2..6");
        assert!(e.contains("--replicas"), "{e}");
        // the band must bracket the starting membership
        for nodes in [1, 7] {
            let e = err(&format!(
                "serve-bench --dist-nodes {nodes} --rebalance 250 --autoscale 2..6"
            ));
            assert!(e.contains("bracket"), "{nodes}: {e}");
        }
        let c = ok("serve-bench --dist-nodes 4 --rebalance 250 --autoscale 2..6");
        assert_eq!(c.autoscale, Some((2, 6)));
        assert_eq!(c.capacity(), 6, "capacity is the band ceiling");
        assert_eq!(c.controller_config().unwrap().autoscale, Some((2, 6)));
        let plain = ok("serve-bench --dist-nodes 4");
        assert_eq!(plain.capacity(), 4, "no band: capacity is the node count");
    }

    #[test]
    fn priority_mix_parses_three_weights_or_rejects() {
        for bad in ["1:2", "1:2:3:4", "1:x:3", "-1:2:3", "0:0:0"] {
            let e = err(&format!("serve-bench --priority-mix {bad}"));
            assert!(e.contains("--priority-mix"), "{bad}: {e}");
        }
        let c = ok("serve-bench --priority-mix 6:3:1");
        assert_eq!(c.priority_mix, Some([6.0, 3.0, 1.0]));
        assert!(c.spec.graded_admission, "--priority-mix turns on graded admission");
        let mut gen = LoadGenConfig::default();
        c.apply_to_loadgen(&mut gen);
        assert_eq!(gen.priority_mix, Some([6.0, 3.0, 1.0]));
    }

    #[test]
    fn load_curve_parses_period_and_peak_or_rejects() {
        for bad in ["4", "0:3", "4:0.5", "x:3", "4:y"] {
            let e = err(&format!("serve-bench --load-curve {bad}"));
            assert!(e.contains("--load-curve"), "{bad}: {e}");
        }
        let c = ok("serve-bench --load-curve 4:3");
        assert_eq!(c.rate_curve, Some((4.0, 3.0)));
        let mut gen = LoadGenConfig::default();
        c.apply_to_loadgen(&mut gen);
        assert_eq!(gen.rate_curve, Some((4.0, 3.0)));
    }

    #[test]
    fn loadgen_overlay_leaves_scenario_values_when_flags_are_absent() {
        let c = ok("serve-bench --burst 4");
        let mut gen = LoadGenConfig {
            priority_mix: Some([1.0, 1.0, 1.0]),
            rate_curve: Some((9.0, 2.0)),
            ..Default::default()
        };
        c.apply_to_loadgen(&mut gen);
        assert_eq!(gen.burst, 4);
        assert_eq!(gen.priority_mix, Some([1.0, 1.0, 1.0]), "absent flag leaves the preset");
        assert_eq!(gen.rate_curve, Some((9.0, 2.0)));
    }

    #[test]
    fn full_control_plane_line_parses() {
        let c = ok(
            "serve-bench --dist-nodes 3 --replicas 2 --rebalance 100 --autoscale 2..8 \
             --priority-mix 2:5:3 --load-curve 2:4 --mix moving --qps 9000 --secs 2",
        );
        assert!(c.dist() && !c.tcp);
        assert_eq!(c.dist_nodes, 3);
        assert_eq!(c.capacity(), 8);
        assert_eq!(c.mix, "moving");
        let ctl = c.controller_config().unwrap();
        assert!((ctl.period_s - 0.1).abs() < 1e-12);
        assert_eq!(ctl.autoscale, Some((2, 8)));
    }
}
