//! Hilbert-range compaction: detect sustained ingestion skew in
//! per-shard row counts and re-split the hot key ranges.
//!
//! Everything here is a deterministic function of `(store, threshold)`
//! — the WAL logs a compaction as just those two values and replay
//! re-derives the identical re-split.
//!
//! The shard *count* is conserved: every split of a hot range is paid
//! for by absorbing an empty shard or merging the coldest adjacent
//! pair. Keeping the count stable keeps `shard_epochs`, placement
//! tables, and every consumer sized the same across a compaction.
//! Untouched shards stay `Arc`-shared with the prior epoch (asserted
//! in tests), so a compaction costs only the rows it actually moves.
//!
//! Placement identity is a range's `key_lo`, not its index:
//! [`crate::serve::dist::Placement::rendezvous_keyed`] scores nodes
//! per key. A split's lower half and a merge's surviving range keep
//! their `key_lo` — and therefore their replica set — so rendezvous
//! rebalancing moves only the re-split ranges (the minimal-movement
//! property test pins this).

use std::sync::Arc;

use super::super::store::{ServedSource, Shard, Store};

/// Row-count skew: max over non-empty shards divided by their mean.
/// `0.0` when fewer than two shards are non-empty (nothing to split
/// against).
pub fn skew(store: &Store) -> f64 {
    let rows: Vec<usize> =
        store.shards.iter().map(|s| s.sources.len()).filter(|&n| n > 0).collect();
    if rows.len() < 2 {
        return 0.0;
    }
    let mean = rows.iter().sum::<usize>() as f64 / rows.len() as f64;
    *rows.iter().max().unwrap() as f64 / mean
}

/// Sustained-skew detector: fires when [`skew`] exceeds `threshold`
/// for `sustain` consecutive observations (one per publish), so a
/// single skewed batch does not trigger a re-split.
#[derive(Clone, Debug)]
pub struct Compactor {
    pub threshold: f64,
    pub sustain: u32,
    streak: u32,
}

impl Compactor {
    pub fn new(threshold: f64, sustain: u32) -> Compactor {
        Compactor { threshold, sustain: sustain.max(1), streak: 0 }
    }

    /// Observe the store after a publish; `true` means compact now.
    /// The streak resets after firing and whenever skew drops back
    /// under the threshold.
    pub fn observe(&mut self, store: &Store) -> bool {
        if skew(store) > self.threshold {
            self.streak += 1;
        } else {
            self.streak = 0;
        }
        if self.streak >= self.sustain {
            self.streak = 0;
            true
        } else {
            false
        }
    }
}

/// What a compaction publish did (returned by
/// [`crate::serve::Ingestor::compact`]).
#[derive(Clone, Debug)]
pub struct CompactionReport {
    /// the epoch the re-split was published as
    pub epoch: u64,
    pub splits: usize,
    pub merges: usize,
    /// empty shards absorbed to pay for splits
    pub absorbed: usize,
    /// rows whose shard assignment was rewritten
    pub rows_resharded: usize,
    pub skew_before: f64,
    pub skew_after: f64,
}

/// The planned new shard list plus accounting.
pub struct Resplit {
    pub shards: Vec<Arc<Shard>>,
    /// per new shard: was it rebuilt (vs `Arc`-shared from the old store)?
    pub rebuilt: Vec<bool>,
    /// rows living in rebuilt ranges — the rows whose shard assignment
    /// (and possibly placement) changed
    pub rows_resharded: usize,
    /// hot ranges split / cold pairs merged / empty shards absorbed
    pub splits: usize,
    pub merges: usize,
    pub absorbed: usize,
}

/// Deterministically re-split hot Hilbert ranges. Returns `None` when
/// nothing qualifies: no shard exceeds `threshold` x the mean row
/// count, no hot shard is splittable (a single-key run cannot be cut),
/// or no empty shard / cold adjacent pair can pay for a split.
///
/// Hot shards are processed hottest-first; each split cuts at the
/// median row, nudged forward so identical-key runs are never divided
/// (the invariant `Store::build` maintains).
pub fn resplit_hot(store: &Store, threshold: f64) -> Option<Resplit> {
    let n = store.shards.len();
    if n < 2 {
        return None;
    }
    let rows: Vec<usize> = store.shards.iter().map(|s| s.sources.len()).collect();
    let nonempty: Vec<usize> = rows.iter().copied().filter(|&r| r > 0).collect();
    if nonempty.len() < 2 {
        return None;
    }
    let mean = nonempty.iter().sum::<usize>() as f64 / nonempty.len() as f64;

    // a shard is splittable when it spans at least two distinct keys
    let splittable = |i: usize| {
        let sh = &store.shards[i];
        sh.sources.len() >= 2
            && store.sky_key(sh.sources[0].pos)
                != store.sky_key(sh.sources[sh.sources.len() - 1].pos)
    };
    let mut hot: Vec<usize> = (0..n)
        .filter(|&i| rows[i] as f64 > threshold * mean && splittable(i))
        .collect();
    if hot.is_empty() {
        return None;
    }
    // hottest first; index ascending breaks ties deterministically
    hot.sort_by_key(|&i| (usize::MAX - rows[i], i));
    let hot_set: Vec<bool> = {
        let mut v = vec![false; n];
        for &i in &hot {
            v[i] = true;
        }
        v
    };

    // budget: each split must absorb an empty shard or merge a cold pair
    let empties: Vec<usize> = (0..n).filter(|&i| rows[i] == 0).collect();
    let mut merge_pairs: Vec<(usize, usize)> = (0..n - 1)
        .filter(|&i| !hot_set[i] && !hot_set[i + 1] && rows[i] > 0 && rows[i + 1] > 0)
        .map(|i| (i, i + 1))
        .collect();
    // coldest combined pair first; leftmost breaks ties
    merge_pairs.sort_by_key(|&(i, j)| (rows[i] + rows[j], i));
    // greedily keep disjoint pairs
    let mut taken = vec![false; n];
    merge_pairs.retain(|&(i, j)| {
        if taken[i] || taken[j] {
            false
        } else {
            taken[i] = true;
            taken[j] = true;
            true
        }
    });

    let splits = hot.len().min(empties.len() + merge_pairs.len());
    if splits == 0 {
        return None;
    }
    hot.truncate(splits);
    let absorbed = splits.min(empties.len());
    let merges = splits - absorbed;
    merge_pairs.truncate(merges);

    #[derive(Clone, Copy, PartialEq)]
    enum Plan {
        Keep,
        Split,
        /// first of a merged pair (absorbs its right neighbor)
        MergeLeft,
        /// dropped: absorbed into the left neighbor or as an empty
        Drop,
    }
    let mut plan = vec![Plan::Keep; n];
    for &i in &hot {
        plan[i] = Plan::Split;
    }
    for &(i, j) in &merge_pairs {
        plan[i] = Plan::MergeLeft;
        plan[j] = Plan::Drop;
    }
    for &i in empties.iter().take(absorbed) {
        plan[i] = Plan::Drop;
    }

    let rebuild = |mut sources: Vec<ServedSource>, fallback: (u64, u64)| {
        sources.sort_by_cached_key(|s| (store.sky_key(s.pos), s.id));
        let (lo, hi) = if sources.is_empty() {
            fallback
        } else {
            (
                store.sky_key(sources[0].pos),
                store.sky_key(sources[sources.len() - 1].pos),
            )
        };
        Arc::new(Shard::build(sources, lo, hi))
    };

    let mut shards = Vec::with_capacity(n);
    let mut rebuilt = Vec::with_capacity(n);
    let mut rows_resharded = 0usize;
    for (i, sh) in store.shards.iter().enumerate() {
        match plan[i] {
            Plan::Drop => {}
            Plan::Keep => {
                shards.push(Arc::clone(sh));
                rebuilt.push(false);
            }
            Plan::MergeLeft => {
                let right = &store.shards[i + 1];
                let mut sources = sh.sources.clone();
                sources.extend(right.sources.iter().cloned());
                rows_resharded += sources.len();
                // the merged range keeps the left key_lo: its replica
                // set under keyed rendezvous is unchanged
                shards.push(rebuild(sources, (sh.key_lo, right.key_hi)));
                rebuilt.push(true);
            }
            Plan::Split => {
                let srcs = &sh.sources;
                let keys: Vec<u64> = srcs.iter().map(|s| store.sky_key(s.pos)).collect();
                // cut at the median, nudged past any identical-key run
                // (forward first, backward if the run reaches the end)
                let mut cut = srcs.len() / 2;
                while cut < srcs.len() && keys[cut] == keys[cut - 1] {
                    cut += 1;
                }
                if cut == srcs.len() {
                    cut = srcs.len() / 2;
                    while cut > 0 && keys[cut] == keys[cut - 1] {
                        cut -= 1;
                    }
                }
                if cut == 0 || cut == srcs.len() {
                    // one giant key run after all: cannot split — keep
                    shards.push(Arc::clone(sh));
                    rebuilt.push(false);
                    continue;
                }
                rows_resharded += srcs.len();
                // lower half keeps key_lo (placement unchanged); the
                // upper half is the new range that moves
                shards.push(rebuild(srcs[..cut].to_vec(), (sh.key_lo, sh.key_lo)));
                rebuilt.push(true);
                shards.push(rebuild(srcs[cut..].to_vec(), (sh.key_hi, sh.key_hi)));
                rebuilt.push(true);
            }
        }
    }
    // a degenerate split (unsplittable key run discovered late) can
    // leave the count short of n; give up rather than resize consumers
    if shards.len() != n {
        return None;
    }
    Some(Resplit { shards, rebuilt, rows_resharded, splits, merges, absorbed })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn skewed_store(n: usize, shards: usize) -> Store {
        // cluster 70% of sources into one corner so one shard runs hot
        let mut sources = Vec::with_capacity(n);
        let mut state = 0x9E37_79B9u64;
        let mut next = || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state >> 11) as f64 / (1u64 << 53) as f64
        };
        for id in 0..n {
            let (x, y) = if next() < 0.7 {
                (next() * 10.0, next() * 10.0)
            } else {
                (next() * 100.0, next() * 100.0)
            };
            sources.push(ServedSource {
                id,
                pos: (x, y),
                p_gal: 0.5,
                flux_r: 100.0 + id as f64,
                flux_logsd: 0.1,
                colors: [0.0; 4],
                converged: true,
            });
        }
        Store::build(sources, 100.0, 100.0, shards)
    }

    #[test]
    fn skew_is_zero_for_balanced_and_tiny_stores() {
        let snap = crate::serve::snapshot::synthetic(400, 9);
        let store = Store::build(snap.sources, snap.width, snap.height, 4);
        assert!(skew(&store) < 1.5, "uniform synthetic stays near 1.0");
        let one = Store::build(store.all_sources(), store.width, store.height, 1);
        assert_eq!(skew(&one), 0.0);
    }

    #[test]
    fn compactor_requires_sustained_skew() {
        let store = skewed_store(600, 4);
        assert!(skew(&store) > 1.3, "fixture must actually be skewed");
        let mut c = Compactor::new(1.3, 3);
        assert!(!c.observe(&store));
        assert!(!c.observe(&store));
        assert!(c.observe(&store), "third consecutive observation fires");
        assert!(!c.observe(&store), "the streak resets after firing");
    }

    #[test]
    fn resplit_conserves_count_rows_and_shares_cold_shards() {
        let store = skewed_store(900, 6);
        let before = skew(&store);
        let re = resplit_hot(&store, 1.2).expect("skewed store must re-split");
        assert_eq!(re.shards.len(), store.shards.len(), "shard count is conserved");
        let total_before: usize = store.shards.iter().map(|s| s.sources.len()).sum();
        let total_after: usize = re.shards.iter().map(|s| s.sources.len()).sum();
        assert_eq!(total_before, total_after, "no row is lost or duplicated");
        let after = Store {
            shards: re.shards.clone(),
            width: store.width,
            height: store.height,
        };
        assert!(skew(&after) < before, "re-splitting must reduce skew ({before:.2} -> {:.2})", skew(&after));
        // every shard not rebuilt is Arc-shared with the old store
        let shared = re
            .shards
            .iter()
            .zip(&re.rebuilt)
            .filter(|(_, &r)| !r)
            .filter(|(sh, _)| store.shards.iter().any(|old| Arc::ptr_eq(old, sh)))
            .count();
        assert_eq!(shared, re.rebuilt.iter().filter(|&&r| !r).count());
        // rows are still sorted by (key, id) within each shard
        for sh in &re.shards {
            let keys: Vec<(u64, usize)> =
                sh.sources.iter().map(|s| (after.sky_key(s.pos), s.id)).collect();
            let mut sorted = keys.clone();
            sorted.sort();
            assert_eq!(keys, sorted);
        }
        assert_eq!(
            re.rows_resharded,
            re.shards
                .iter()
                .zip(&re.rebuilt)
                .filter(|(_, &r)| r)
                .map(|(s, _)| s.sources.len())
                .sum::<usize>()
        );
    }

    #[test]
    fn balanced_store_does_not_resplit() {
        let snap = crate::serve::snapshot::synthetic(800, 17);
        let store = Store::build(snap.sources, snap.width, snap.height, 8);
        assert!(resplit_hot(&store, 2.0).is_none());
    }

    #[test]
    fn resplit_is_deterministic() {
        let store = skewed_store(700, 5);
        let a = resplit_hot(&store, 1.2).expect("resplit");
        let b = resplit_hot(&store, 1.2).expect("resplit");
        for (x, y) in a.shards.iter().zip(&b.shards) {
            assert_eq!(x.key_lo, y.key_lo);
            assert_eq!(x.key_hi, y.key_hi);
            assert_eq!(x.sources, y.sources);
        }
    }
}
