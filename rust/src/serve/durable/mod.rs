//! Durable publish log: WAL + periodic checkpoints + crash recovery +
//! Hilbert-range compaction.
//!
//! The serving tier's store is epoch-stamped copy-on-write
//! ([`crate::serve::ingest`]): every publish installs a new immutable
//! [`EpochStore`]. This module makes those publishes survive a crash:
//!
//! * **WAL** ([`wal`]): before an epoch becomes visible,
//!   [`crate::serve::VersionedStore::publish_logged`] appends a
//!   CRC-framed record of its delta rows and `fsync`s it — under the
//!   same lock that flips the head pointer, so the log order *is* the
//!   publish order and an acked epoch is a durable epoch.
//! * **Checkpoints** ([`checkpoint`]): every `checkpoint_every` epochs
//!   the head is materialized as one jsonlite snapshot per shard plus
//!   an atomically-renamed manifest; only shards touched since the
//!   previous checkpoint rewrite. The WAL is then cut over to a fresh
//!   segment and old files are garbage-collected.
//! * **Recovery** ([`DurableLog::recover`]): load the checkpoint,
//!   replay the WAL tail through a real [`Ingestor`] (so replay
//!   exercises the exact production publish path), truncate any torn
//!   tail a `kill -9` left behind. The two phases are timed separately
//!   — the RTO split `celeste recover-bench` reports.
//! * **Compaction** ([`compact`]): sustained row-count skew re-splits
//!   hot key ranges, logged as a `(threshold)` record and re-derived
//!   deterministically on replay.
//!
//! Byte parity is the contract throughout: WAL payloads use the wire
//! codec (f64s as IEEE-754 bits), checkpoints use the lossless
//! snapshot codec, and [`catalog_checksum`] hashes the wire encoding
//! of the id-sorted catalog so two processes can compare entire
//! catalogs with one u64.

pub mod compact;
mod checkpoint;
mod wal;

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::metrics::Stopwatch;

use super::ingest::{EpochStore, Ingestor, VersionedStore};
use super::net::wire;
use super::obs::Registry;
use super::store::{ServedSource, Store};

pub use compact::{resplit_hot, skew, CompactionReport, Compactor, Resplit};
pub use wal::WalRecord;

/// What a publish wants logged. Borrowed: the WAL encodes straight
/// from the ingestor's delta buffer, no copy.
pub enum WalOp<'a> {
    /// Last-write-wins delta rows of the epoch being published.
    Publish { rows: &'a [ServedSource] },
    /// The epoch re-split shard ranges at this skew threshold; replay
    /// re-derives the identical re-split from the prior epoch's store.
    Compact { threshold: f64 },
}

/// FNV-1a 64 over the wire encoding of the id-sorted rows: the
/// catalog-wide byte-parity check. Two stores with equal checksums
/// hold bit-identical rows (every f64 hashed as its IEEE-754 bits),
/// regardless of how either is sharded.
pub fn catalog_checksum(rows: &[ServedSource]) -> u64 {
    let mut sorted = rows.to_vec();
    sorted.sort_by_key(|s| s.id);
    fnv1a(&wire::encode_sources(&sorted))
}

/// [`catalog_checksum`] of a store's flat view.
pub fn store_checksum(store: &Store) -> u64 {
    catalog_checksum(&store.all_sources())
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// How a recovery went: what was loaded, what was replayed, how long
/// each phase took (the RTO split), and what the catalog looks like at
/// the recovered epoch.
#[derive(Clone, Debug)]
pub struct RecoveryReport {
    pub checkpoint_epoch: u64,
    pub recovered_epoch: u64,
    pub records_replayed: usize,
    /// bytes of torn tail truncated (0 on a clean shutdown)
    pub truncated_bytes: u64,
    pub checkpoint_load_s: f64,
    pub replay_s: f64,
    /// catalog size and checksum at the recovered epoch
    pub rows: usize,
    pub checksum: u64,
}

/// A recovered store: head at the last durably published epoch, with
/// the log re-attached so the next publish appends where the old
/// process left off.
pub struct Recovered {
    pub versioned: Arc<VersionedStore>,
    pub log: Arc<DurableLog>,
    pub report: RecoveryReport,
}

struct LogState {
    file: File,
    manifest: checkpoint::Manifest,
    last_epoch: u64,
}

/// The durable publish log over one `--wal-dir`.
///
/// Thread safety: `append` is only ever called under the
/// [`VersionedStore`] head lock (see `publish_logged`), which also
/// serializes checkpoints; the internal mutex exists so metrics
/// scrapes never race an append.
pub struct DurableLog {
    dir: PathBuf,
    checkpoint_every: u64,
    state: Mutex<LogState>,
    obs: Registry,
}

impl DurableLog {
    /// Does `dir` hold a recoverable log (a checkpoint manifest)?
    pub fn exists(dir: &Path) -> bool {
        dir.join(checkpoint::MANIFEST_FILE).exists()
    }

    /// Create a fresh log in `dir` and write checkpoint 0 of `initial`
    /// immediately — the directory is self-contained from the first
    /// byte, so a restart needs `--wal-dir` and nothing else.
    /// `checkpoint_every = 0` disables periodic checkpoints (the WAL
    /// then grows until a manual [`DurableLog::checkpoint_now`]).
    pub fn create(dir: &Path, checkpoint_every: u64, initial: &EpochStore) -> Result<DurableLog> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating --wal-dir {}", dir.display()))?;
        if Self::exists(dir) {
            bail!(
                "--wal-dir {} already holds a checkpoint; recover from it instead of re-creating",
                dir.display()
            );
        }
        let checksum = store_checksum(&initial.store);
        let manifest = checkpoint::write_checkpoint(dir, initial, checksum, None)?;
        let file = OpenOptions::new()
            .append(true)
            .create(true)
            .open(checkpoint::wal_path(dir, manifest.epoch))?;
        file.sync_all()?;
        checkpoint::sync_dir(dir)?;
        let obs = Registry::new();
        obs.counter("wal_checkpoints").inc();
        Ok(DurableLog {
            dir: dir.to_path_buf(),
            checkpoint_every,
            state: Mutex::new(LogState { file, manifest, last_epoch: initial.epoch }),
            obs,
        })
    }

    /// Recover from `dir`: checkpoint-load + WAL tail-replay, with the
    /// torn tail (if any) truncated. Replay drives a real [`Ingestor`]
    /// so the recovered epochs are built by the same code that built
    /// them originally — recovery parity is production parity.
    pub fn recover(dir: &Path, checkpoint_every: u64) -> Result<Recovered> {
        let sw = Stopwatch::start();
        let manifest = checkpoint::load_manifest(dir)?
            .ok_or_else(|| anyhow!("no checkpoint manifest in {}", dir.display()))?;
        let head = checkpoint::load_checkpoint(dir, &manifest)?;
        let checkpoint_load_s = sw.elapsed_secs();

        let sw = Stopwatch::start();
        let wal_path = checkpoint::wal_path(dir, manifest.epoch);
        let scan = match File::open(&wal_path) {
            Ok(mut f) => wal::scan_segment(&mut f)?,
            // crash after the manifest rename, before the new segment:
            // the checkpoint alone is the recovered state
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                wal::WalScan { records: Vec::new(), valid_bytes: 0, torn: false }
            }
            Err(e) => return Err(e.into()),
        };
        let mut truncated_bytes = 0u64;
        if scan.torn {
            let f = OpenOptions::new().write(true).open(&wal_path)?;
            truncated_bytes = f.metadata()?.len().saturating_sub(scan.valid_bytes);
            f.set_len(scan.valid_bytes)?;
            f.sync_all()?;
        }
        let checkpoint_epoch = manifest.epoch;
        let versioned = Arc::new(VersionedStore::from_head(head));
        let mut ingestor = Ingestor::new(Arc::clone(&versioned));
        let mut records_replayed = 0usize;
        for rec in &scan.records {
            let want = versioned.epoch() + 1;
            if rec.epoch() != want {
                bail!(
                    "WAL replay gap in {}: expected epoch {want}, record says {}",
                    dir.display(),
                    rec.epoch()
                );
            }
            match rec {
                WalRecord::Publish { rows, .. } => {
                    ingestor.apply(rows);
                }
                WalRecord::Compact { threshold, .. } => {
                    ingestor.compact(*threshold).ok_or_else(|| {
                        anyhow!(
                            "WAL replay: compact record at epoch {want} did not re-derive \
                             (threshold {threshold})"
                        )
                    })?;
                }
            }
            records_replayed += 1;
        }
        let replay_s = sw.elapsed_secs();

        let recovered = versioned.load();
        let flat = recovered.store.all_sources();
        let report = RecoveryReport {
            checkpoint_epoch,
            recovered_epoch: recovered.epoch,
            records_replayed,
            truncated_bytes,
            checkpoint_load_s,
            replay_s,
            rows: flat.len(),
            checksum: catalog_checksum(&flat),
        };
        let file = OpenOptions::new().append(true).create(true).open(&wal_path)?;
        let obs = Registry::new();
        obs.gauge_set("recovered_epoch", recovered.epoch as f64);
        obs.gauge_set("recovery_checkpoint_load_ms", checkpoint_load_s * 1e3);
        obs.gauge_set("recovery_replay_ms", replay_s * 1e3);
        obs.counter("wal_replayed_records").add(records_replayed as u64);
        let log = Arc::new(DurableLog {
            dir: dir.to_path_buf(),
            checkpoint_every,
            state: Mutex::new(LogState {
                file,
                manifest,
                last_epoch: recovered.epoch,
            }),
            obs,
        });
        versioned.attach_wal(Arc::clone(&log));
        Ok(Recovered { versioned, log, report })
    }

    /// Append one publish record and `fsync` it. Called under the
    /// [`VersionedStore`] head lock *before* the pointer flips: when
    /// this returns, the epoch is durable, so the caller may ack it.
    /// Triggers a checkpoint every `checkpoint_every` epochs.
    pub(crate) fn append(&self, next: &EpochStore, op: &WalOp) -> Result<()> {
        let rec = match op {
            WalOp::Publish { rows } => WalRecord::Publish { epoch: next.epoch, rows: rows.to_vec() },
            WalOp::Compact { threshold } => {
                WalRecord::Compact { epoch: next.epoch, threshold: *threshold }
            }
        };
        let bytes = wal::encode_record(&rec);
        let mut st = self.state.lock().unwrap();
        assert_eq!(
            next.epoch,
            st.last_epoch + 1,
            "WAL appends must be contiguous (the head lock serializes publishes)"
        );
        st.file.write_all(&bytes)?;
        let sw = Stopwatch::start();
        st.file.sync_data()?;
        self.obs.histogram("wal_fsync_s").record(sw.elapsed_secs());
        self.obs.counter("wal_appends").inc();
        self.obs.counter("wal_bytes").add(bytes.len() as u64);
        st.last_epoch = next.epoch;
        if self.checkpoint_every > 0 && next.epoch - st.manifest.epoch >= self.checkpoint_every {
            self.checkpoint_locked(&mut st, next)?;
        }
        Ok(())
    }

    /// Force a checkpoint of `head` now (tests, shutdown hooks).
    pub fn checkpoint_now(&self, head: &EpochStore) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        assert_eq!(head.epoch, st.last_epoch, "checkpoint must capture the logged head");
        self.checkpoint_locked(&mut st, head)
    }

    fn checkpoint_locked(&self, st: &mut LogState, head: &EpochStore) -> Result<()> {
        let checksum = store_checksum(&head.store);
        let manifest = checkpoint::write_checkpoint(&self.dir, head, checksum, Some(&st.manifest))?;
        // cut over to a fresh segment, then drop files only the old
        // manifest referenced — a crash anywhere in between recovers
        // from whichever manifest is on disk, both of which are intact
        let file = OpenOptions::new()
            .append(true)
            .create(true)
            .open(checkpoint::wal_path(&self.dir, manifest.epoch))?;
        file.sync_all()?;
        checkpoint::sync_dir(&self.dir)?;
        checkpoint::gc(&self.dir, &manifest)?;
        st.file = file;
        st.manifest = manifest;
        self.obs.counter("wal_checkpoints").inc();
        Ok(())
    }

    /// The log's own metrics registry (`wal_appends`, `wal_bytes`,
    /// `wal_checkpoints`, the `wal_fsync_s` histogram, and after a
    /// recovery the `recovered_epoch` / `recovery_*_ms` gauges). Merge
    /// its snapshot into a scrape with [`super::obs::Snapshot::merge_all`].
    pub fn obs(&self) -> &Registry {
        &self.obs
    }

    /// Epoch of the last record durably on disk.
    pub fn last_epoch(&self) -> u64 {
        self.state.lock().unwrap().last_epoch
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::ingest::{DriftConfig, DriftGen};
    use crate::serve::snapshot;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("celeste-durable-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn seed(n: usize, shards: usize, s: u64) -> Arc<VersionedStore> {
        let snap = snapshot::synthetic(n, s);
        let store = Arc::new(Store::build(snap.sources, snap.width, snap.height, shards));
        Arc::new(VersionedStore::new(store))
    }

    /// Publish through a WAL-attached store, drop everything, recover:
    /// the recovered catalog is byte-identical to the last-write-wins
    /// mirror at the recovered epoch.
    #[test]
    fn wal_recovery_is_byte_identical_to_the_mirror() {
        let dir = tmpdir("roundtrip");
        let vs = seed(500, 6, 11);
        let head0 = vs.load();
        let log = Arc::new(DurableLog::create(&dir, 4, &head0).expect("create"));
        vs.attach_wal(Arc::clone(&log));
        let mut ing = Ingestor::new(Arc::clone(&vs));
        let mut drift = DriftGen::new(
            &head0.store.all_sources(),
            head0.store.width,
            head0.store.height,
            DriftConfig { batch: 24, seed: 3, ..Default::default() },
        );
        for _ in 0..10 {
            ing.apply(&drift.next_batch());
        }
        let want = drift.mirror_sorted();
        let want_sum = catalog_checksum(&want);
        drop((ing, vs, log));

        let rec = DurableLog::recover(&dir, 4).expect("recover");
        assert_eq!(rec.report.recovered_epoch, 10);
        assert_eq!(rec.report.checkpoint_epoch, 8, "checkpoint every 4 epochs");
        assert_eq!(rec.report.records_replayed, 2, "only the tail replays");
        assert_eq!(rec.report.truncated_bytes, 0, "clean shutdown has no tear");
        let got = rec.versioned.load().store.all_sources();
        assert_eq!(got, want, "recovered rows are byte-identical to the mirror");
        assert_eq!(rec.report.checksum, want_sum);
        assert!(rec.report.checkpoint_load_s >= 0.0 && rec.report.replay_s >= 0.0);

        // the recovered log accepts the next publish where the old one
        // stopped — and a second recovery sees it
        let mut ing = Ingestor::new(Arc::clone(&rec.versioned));
        let rep = ing.apply(&drift.next_batch());
        assert_eq!(rep.epoch, 11);
        drop((ing, rec));
        let rec2 = DurableLog::recover(&dir, 4).expect("re-recover");
        assert_eq!(rec2.report.recovered_epoch, 11);
        assert_eq!(rec2.versioned.load().store.all_sources(), drift.mirror_sorted());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A torn tail (partial record, as kill -9 mid-append leaves) is
    /// truncated; recovery lands on the last *complete* epoch.
    #[test]
    fn torn_tail_is_truncated_to_the_last_durable_epoch() {
        let dir = tmpdir("torn");
        let vs = seed(300, 4, 7);
        let head0 = vs.load();
        let log = Arc::new(DurableLog::create(&dir, 0, &head0).expect("create"));
        vs.attach_wal(Arc::clone(&log));
        let mut ing = Ingestor::new(Arc::clone(&vs));
        let mut drift = DriftGen::new(
            &head0.store.all_sources(),
            head0.store.width,
            head0.store.height,
            DriftConfig { batch: 10, seed: 9, ..Default::default() },
        );
        for _ in 0..3 {
            ing.apply(&drift.next_batch());
        }
        let mirror_at_3 = drift.mirror_sorted();
        drop((ing, vs, log));
        // shear 7 bytes off the tail: epoch 3's record is now torn
        let wal = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .find(|e| e.file_name().to_string_lossy().starts_with("wal-e"))
            .expect("segment")
            .path();
        let len = std::fs::metadata(&wal).unwrap().len();
        let f = OpenOptions::new().write(true).open(&wal).unwrap();
        f.set_len(len - 7).unwrap();
        drop(f);

        let rec = DurableLog::recover(&dir, 0).expect("recover");
        assert_eq!(rec.report.recovered_epoch, 2, "epoch 3 was torn away");
        assert!(rec.report.truncated_bytes > 0, "the tear was truncated");
        assert_ne!(
            rec.versioned.load().store.all_sources(),
            mirror_at_3,
            "epoch 3 must not half-apply"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Incremental checkpoints: shards untouched since the previous
    /// checkpoint keep their file (same name, not rewritten).
    #[test]
    fn untouched_shards_are_not_rewritten_by_a_checkpoint() {
        let dir = tmpdir("incr");
        let vs = seed(600, 8, 23);
        let head0 = vs.load();
        let log = Arc::new(DurableLog::create(&dir, 0, &head0).expect("create"));
        vs.attach_wal(Arc::clone(&log));
        let mut ing = Ingestor::new(Arc::clone(&vs));
        // touch exactly one shard: update one existing row in place
        let one = head0.store.shards.iter().find(|s| !s.sources.is_empty()).unwrap().sources[0]
            .clone();
        let rep = ing.apply(&[ServedSource { flux_r: one.flux_r * 2.0, ..one }]);
        assert_eq!(rep.touched.len(), 1, "one shard touched");
        let head1 = vs.load();
        log.checkpoint_now(&head1).expect("checkpoint");

        let m = checkpoint::load_manifest(&dir).unwrap().unwrap();
        assert_eq!(m.epoch, 1);
        let rewritten: Vec<usize> = m
            .shards
            .iter()
            .enumerate()
            .filter(|(_, s)| s.epoch == 1)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(rewritten, vec![rep.touched[0].0], "only the touched shard re-stamped");
        // recovery from the incremental checkpoint is exact, no replay
        drop((ing, vs, log));
        let rec = DurableLog::recover(&dir, 0).expect("recover");
        assert_eq!(rec.report.records_replayed, 0);
        assert_eq!(rec.report.recovered_epoch, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A compaction epoch replays from its `(threshold)` record: the
    /// recovered store re-derives the identical re-split.
    #[test]
    fn compaction_replays_deterministically_from_the_log() {
        let dir = tmpdir("compact-replay");
        // skewed seed: most rows in one corner
        let mut sources = snapshot::synthetic(400, 5).sources;
        for (i, s) in sources.iter_mut().enumerate() {
            if i % 4 != 0 {
                s.pos = (s.pos.0 * 0.08, s.pos.1 * 0.08);
            }
        }
        let store = Arc::new(Store::build(sources, 100.0, 100.0, 4));
        let vs = Arc::new(VersionedStore::new(store));
        let head0 = vs.load();
        let log = Arc::new(DurableLog::create(&dir, 0, &head0).expect("create"));
        vs.attach_wal(Arc::clone(&log));
        let mut ing = Ingestor::new(Arc::clone(&vs));
        let rep = ing.compact(1.2).expect("skewed store compacts");
        assert_eq!(rep.epoch, 1);
        let head = vs.load();
        let want: Vec<(u64, u64, usize)> = head
            .store
            .shards
            .iter()
            .map(|s| (s.key_lo, s.key_hi, s.sources.len()))
            .collect();
        drop((ing, vs, log));

        let rec = DurableLog::recover(&dir, 0).expect("recover");
        assert_eq!(rec.report.recovered_epoch, 1);
        let got: Vec<(u64, u64, usize)> = rec
            .versioned
            .load()
            .store
            .shards
            .iter()
            .map(|s| (s.key_lo, s.key_hi, s.sources.len()))
            .collect();
        assert_eq!(got, want, "replayed re-split matches the original layout exactly");
        std::fs::remove_dir_all(&dir).ok();
    }
}
