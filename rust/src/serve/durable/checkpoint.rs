//! Checkpoints: a manifest plus one jsonlite snapshot file per shard,
//! so a checkpoint after a publish rewrites only the Hilbert ranges
//! that publish touched.
//!
//! Directory layout (all inside the `--wal-dir`):
//!
//! ```text
//! MANIFEST.json            epoch, extent, per-shard file + range + stamp
//! shard-0003-e00012.json   shard 3 as of the epoch that last mutated it
//! wal-e000000000012.log    records after the manifest's epoch
//! ```
//!
//! Shard files are named by `(index, shard_epoch)` — a shard untouched
//! since the previous checkpoint keeps its file byte-for-byte, and the
//! old manifest stays valid while a new checkpoint is in flight. The
//! manifest itself is replaced atomically (tmp + fsync + rename +
//! directory sync), so a crash at any point leaves either the old or
//! the new checkpoint fully intact, never a mix.
//!
//! All u64s that can exceed 2^53 (epochs, Hilbert keys) are stored as
//! decimal strings: jsonlite numbers are f64 and would round them.

use std::collections::BTreeSet;
use std::fs::{self, File};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::jsonlite::{self, Value};

use super::super::ingest::EpochStore;
use super::super::snapshot;
use super::super::store::{Shard, Store};

pub(crate) const MANIFEST_FORMAT: &str = "celeste-wal-manifest-v1";
pub(crate) const MANIFEST_FILE: &str = "MANIFEST.json";

#[derive(Clone, Debug, PartialEq)]
pub(crate) struct ManifestShard {
    pub file: String,
    pub key_lo: u64,
    pub key_hi: u64,
    /// the epoch that last mutated this shard (its cache stamp)
    pub epoch: u64,
    pub rows: usize,
}

#[derive(Clone, Debug, PartialEq)]
pub(crate) struct Manifest {
    pub epoch: u64,
    pub width: f64,
    pub height: f64,
    pub shards: Vec<ManifestShard>,
    /// catalog checksum at `epoch` (FNV-1a over the wire encoding of
    /// the id-sorted rows) — verified on load
    pub checksum: u64,
}

impl Manifest {
    /// Name of the WAL segment holding records after this checkpoint.
    pub fn wal_file(&self) -> String {
        wal_file_for(self.epoch)
    }
}

pub(crate) fn wal_file_for(epoch: u64) -> String {
    format!("wal-e{epoch:012}.log")
}

fn shard_file_for(idx: usize, stamp: u64) -> String {
    format!("shard-{idx:04}-e{stamp:05}.json")
}

fn u64_str_field(v: &Value, key: &str) -> Result<u64> {
    v.get(key)
        .and_then(Value::as_str)
        .ok_or_else(|| anyhow!("manifest missing string field {key:?}"))?
        .parse::<u64>()
        .map_err(|e| anyhow!("manifest field {key:?}: {e}"))
}

fn f64_field(v: &Value, key: &str) -> Result<f64> {
    v.get(key)
        .and_then(Value::as_f64)
        .ok_or_else(|| anyhow!("manifest missing numeric field {key:?}"))
}

fn manifest_to_json(m: &Manifest) -> String {
    let shards: Vec<Value> = m
        .shards
        .iter()
        .map(|s| {
            let mut o = std::collections::BTreeMap::new();
            o.insert("file".to_string(), Value::Str(s.file.clone()));
            o.insert("key_lo".to_string(), Value::Str(s.key_lo.to_string()));
            o.insert("key_hi".to_string(), Value::Str(s.key_hi.to_string()));
            o.insert("epoch".to_string(), Value::Str(s.epoch.to_string()));
            o.insert("rows".to_string(), Value::Num(s.rows as f64));
            Value::Obj(o)
        })
        .collect();
    let mut o = std::collections::BTreeMap::new();
    o.insert("format".to_string(), Value::Str(MANIFEST_FORMAT.to_string()));
    o.insert("epoch".to_string(), Value::Str(m.epoch.to_string()));
    o.insert("width".to_string(), Value::Num(m.width));
    o.insert("height".to_string(), Value::Num(m.height));
    o.insert("shards".to_string(), Value::Arr(shards));
    o.insert("checksum".to_string(), Value::Str(format!("{:016x}", m.checksum)));
    jsonlite::to_string(&Value::Obj(o))
}

fn manifest_from_json(text: &str) -> Result<Manifest> {
    let v = jsonlite::parse(text).map_err(|e| anyhow!("manifest parse: {e}"))?;
    match v.get("format").and_then(Value::as_str) {
        Some(MANIFEST_FORMAT) => {}
        other => bail!("unsupported manifest format {other:?} (want {MANIFEST_FORMAT})"),
    }
    let shards = v
        .get("shards")
        .and_then(Value::as_arr)
        .ok_or_else(|| anyhow!("manifest missing shards"))?
        .iter()
        .map(|s| {
            Ok(ManifestShard {
                file: s
                    .get("file")
                    .and_then(Value::as_str)
                    .ok_or_else(|| anyhow!("manifest shard missing file"))?
                    .to_string(),
                key_lo: u64_str_field(s, "key_lo")?,
                key_hi: u64_str_field(s, "key_hi")?,
                epoch: u64_str_field(s, "epoch")?,
                rows: f64_field(s, "rows")? as usize,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let checksum = u64::from_str_radix(
        v.get("checksum")
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow!("manifest missing checksum"))?,
        16,
    )
    .map_err(|e| anyhow!("manifest checksum: {e}"))?;
    Ok(Manifest {
        epoch: u64_str_field(&v, "epoch")?,
        width: f64_field(&v, "width")?,
        height: f64_field(&v, "height")?,
        shards,
        checksum,
    })
}

/// Write `text` to `dir/name` atomically: tmp file, fsync, rename,
/// directory sync. After this returns the file is durably either the
/// old content or the new, never a torn mix.
fn write_atomic(dir: &Path, name: &str, text: &str) -> Result<()> {
    let tmp = dir.join(format!("{name}.tmp"));
    {
        let mut f = File::create(&tmp)?;
        std::io::Write::write_all(&mut f, text.as_bytes())?;
        f.sync_all()?;
    }
    fs::rename(&tmp, dir.join(name))?;
    sync_dir(dir)?;
    Ok(())
}

pub(crate) fn sync_dir(dir: &Path) -> Result<()> {
    // On unix, renames become durable when the directory itself is
    // synced; elsewhere File::open on a directory may fail — best
    // effort there.
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_all();
    }
    Ok(())
}

/// Write a checkpoint of `head`, rewriting only shards whose stamp
/// changed since `prev` (or all of them when `prev` is `None`).
/// Returns the new manifest; stale shard files and WAL segments are
/// *not* removed here — the caller garbage-collects after it has cut
/// over to the new segment.
pub(crate) fn write_checkpoint(
    dir: &Path,
    head: &EpochStore,
    checksum: u64,
    prev: Option<&Manifest>,
) -> Result<Manifest> {
    let store = &head.store;
    let mut shards = Vec::with_capacity(store.shards.len());
    for (i, shard) in store.shards.iter().enumerate() {
        let stamp = head.shard_epochs[i];
        let reusable = prev.and_then(|p| p.shards.get(i)).filter(|ps| {
            ps.epoch == stamp
                && ps.key_lo == shard.key_lo
                && ps.key_hi == shard.key_hi
                && ps.rows == shard.sources.len()
        });
        let file = match reusable {
            Some(ps) => ps.file.clone(),
            None => {
                let name = shard_file_for(i, stamp);
                write_atomic(
                    dir,
                    &name,
                    &snapshot::to_json(&shard.sources, store.width, store.height),
                )?;
                name
            }
        };
        shards.push(ManifestShard {
            file,
            key_lo: shard.key_lo,
            key_hi: shard.key_hi,
            epoch: stamp,
            rows: shard.sources.len(),
        });
    }
    let manifest = Manifest {
        epoch: head.epoch,
        width: store.width,
        height: store.height,
        shards,
        checksum,
    };
    write_atomic(dir, MANIFEST_FILE, &manifest_to_json(&manifest))?;
    Ok(manifest)
}

/// Load the manifest, or `None` when the directory holds no checkpoint
/// yet (fresh `--wal-dir`).
pub(crate) fn load_manifest(dir: &Path) -> Result<Option<Manifest>> {
    let path = dir.join(MANIFEST_FILE);
    if !path.exists() {
        return Ok(None);
    }
    manifest_from_json(&fs::read_to_string(&path)?).map(Some)
}

/// Rebuild the checkpointed `EpochStore` from a manifest: every shard
/// file parsed, each shard re-indexed over its recorded key range.
pub(crate) fn load_checkpoint(dir: &Path, m: &Manifest) -> Result<Arc<EpochStore>> {
    let mut shards = Vec::with_capacity(m.shards.len());
    let mut shard_epochs = Vec::with_capacity(m.shards.len());
    for (i, ms) in m.shards.iter().enumerate() {
        let snap = snapshot::load(&dir.join(&ms.file))
            .map_err(|e| anyhow!("checkpoint shard {i} ({}): {e}", ms.file))?;
        if snap.sources.len() != ms.rows {
            bail!(
                "checkpoint shard {i} ({}): {} rows on disk, manifest says {}",
                ms.file,
                snap.sources.len(),
                ms.rows
            );
        }
        shards.push(Arc::new(Shard::build(snap.sources, ms.key_lo, ms.key_hi)));
        shard_epochs.push(ms.epoch);
    }
    let store = Arc::new(Store { shards, width: m.width, height: m.height });
    let got = super::store_checksum(&store);
    if got != m.checksum {
        bail!(
            "checkpoint checksum mismatch: manifest says {:016x}, shard files hash to {got:016x}",
            m.checksum
        );
    }
    Ok(Arc::new(EpochStore { epoch: m.epoch, shard_epochs, store }))
}

/// Remove shard files and WAL segments the manifest no longer
/// references. Safe to call any time after the manifest rename: the
/// live manifest never points at a deleted file.
pub(crate) fn gc(dir: &Path, live: &Manifest) -> Result<()> {
    let keep: BTreeSet<&str> = live.shards.iter().map(|s| s.file.as_str()).collect();
    let live_wal = live.wal_file();
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        let stale_shard = name.starts_with("shard-")
            && name.ends_with(".json")
            && !keep.contains(name.as_ref());
        let stale_wal =
            name.starts_with("wal-e") && name.ends_with(".log") && name != live_wal;
        let stale_tmp = name.ends_with(".tmp");
        if stale_shard || stale_wal || stale_tmp {
            let _ = fs::remove_file(entry.path());
        }
    }
    Ok(())
}

pub(crate) fn wal_path(dir: &Path, epoch: u64) -> PathBuf {
    dir.join(wal_file_for(epoch))
}
