//! WAL record framing: length-prefixed, CRC-framed records with the
//! `wire.rs` codec discipline — a fixed header validated field by
//! field *before* any payload allocation, every f64 stored as its
//! IEEE-754 bits so replay is byte-exact.
//!
//! Record layout (little-endian, 24-byte header):
//!
//! ```text
//! magic   u32   0xCE57_106A
//! version u8    1
//! rtype   u8    1 = Publish (epoch delta rows), 2 = Compact
//! reserved u16  0
//! epoch   u64   the epoch this record publishes
//! len     u32   payload length in bytes (capped)
//! crc     u32   CRC-32 (IEEE) of the payload bytes
//! payload len bytes
//! ```
//!
//! A `Publish` payload is the wire codec's count-prefixed row batch
//! ([`wire::encode_sources`]) — byte-identical to the `Publish` frame
//! that shipped the same epoch over TCP. A `Compact` payload is the
//! skew threshold as f64 bits: compaction is a deterministic function
//! of (store, threshold), so replay re-derives the re-split instead of
//! logging the whole post-compaction layout.
//!
//! Torn-tail policy: a process killed mid-append leaves a partial or
//! corrupt record at the end of the segment. The first anomaly —
//! short read, bad magic, CRC mismatch, undecodable payload — ends the
//! scan; the caller truncates the segment at the last good offset and
//! recovery proceeds from there. Everything *before* the tear was
//! fsynced before its publish was acked, so nothing acked is lost.

use std::io::{self, Read};

use super::super::net::wire;
use super::super::store::ServedSource;

pub(crate) const WAL_MAGIC: u32 = 0xCE57_106A;
pub(crate) const WAL_VERSION: u8 = 1;
const REC_PUBLISH: u8 = 1;
const REC_COMPACT: u8 = 2;
pub(crate) const WAL_HEADER_LEN: usize = 24;
/// Same payload bound as the wire protocol: a corrupt length field
/// must not drive a huge allocation.
const MAX_RECORD_PAYLOAD: usize = 64 << 20;

/// One durable log record, decoded.
#[derive(Clone, Debug, PartialEq)]
pub enum WalRecord {
    /// The rows that changed in `epoch` (last-write-wins deltas — the
    /// same rows [`crate::serve::IngestReport::deltas`] carries).
    Publish { epoch: u64, rows: Vec<ServedSource> },
    /// Epoch `epoch` re-split the Hilbert key ranges; replay re-runs
    /// the deterministic re-split at the logged threshold.
    Compact { epoch: u64, threshold: f64 },
}

impl WalRecord {
    pub fn epoch(&self) -> u64 {
        match self {
            WalRecord::Publish { epoch, .. } | WalRecord::Compact { epoch, .. } => *epoch,
        }
    }
}

/// CRC-32 (IEEE 802.3, reflected, poly 0xEDB88320) over `bytes`.
/// Hand-rolled byte-at-a-time table: the WAL's cost is dominated by
/// `fsync`, not the checksum, and the container bakes in no CRC crate.
pub(crate) fn crc32(bytes: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Encode one record: header + payload, ready to append.
pub(crate) fn encode_record(rec: &WalRecord) -> Vec<u8> {
    let (rtype, epoch, payload) = match rec {
        WalRecord::Publish { epoch, rows } => (REC_PUBLISH, *epoch, wire::encode_sources(rows)),
        WalRecord::Compact { epoch, threshold } => {
            (REC_COMPACT, *epoch, threshold.to_bits().to_le_bytes().to_vec())
        }
    };
    let mut out = Vec::with_capacity(WAL_HEADER_LEN + payload.len());
    out.extend_from_slice(&WAL_MAGIC.to_le_bytes());
    out.push(WAL_VERSION);
    out.push(rtype);
    out.extend_from_slice(&0u16.to_le_bytes());
    out.extend_from_slice(&epoch.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(&payload).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Result of scanning a segment: the records that decoded cleanly, the
/// byte offset they end at, and whether the scan stopped at a tear
/// (anything after `valid_bytes` is garbage to truncate).
pub(crate) struct WalScan {
    pub records: Vec<WalRecord>,
    pub valid_bytes: u64,
    pub torn: bool,
}

/// Scan a segment from the start, stopping at the first anomaly.
/// I/O errors other than clean EOF propagate; a tear is *data*, not an
/// error, and is reported in the scan.
pub(crate) fn scan_segment(r: &mut impl Read) -> io::Result<WalScan> {
    let mut records = Vec::new();
    let mut valid_bytes = 0u64;
    let mut header = [0u8; WAL_HEADER_LEN];
    loop {
        match read_exact_or_eof(r, &mut header)? {
            ReadOutcome::Eof => {
                return Ok(WalScan { records, valid_bytes, torn: false });
            }
            ReadOutcome::Short => {
                return Ok(WalScan { records, valid_bytes, torn: true });
            }
            ReadOutcome::Full => {}
        }
        // validate every header field before allocating the payload
        let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
        let version = header[4];
        let rtype = header[5];
        let len = u32::from_le_bytes(header[16..20].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(header[20..24].try_into().unwrap());
        if magic != WAL_MAGIC
            || version != WAL_VERSION
            || !(REC_PUBLISH..=REC_COMPACT).contains(&rtype)
            || len > MAX_RECORD_PAYLOAD
        {
            return Ok(WalScan { records, valid_bytes, torn: true });
        }
        let mut payload = vec![0u8; len];
        match read_exact_or_eof(r, &mut payload)? {
            ReadOutcome::Full => {}
            _ => return Ok(WalScan { records, valid_bytes, torn: true }),
        }
        if crc32(&payload) != crc {
            return Ok(WalScan { records, valid_bytes, torn: true });
        }
        let epoch = u64::from_le_bytes(header[8..16].try_into().unwrap());
        let rec = match rtype {
            REC_PUBLISH => match wire::decode_sources(&payload) {
                Ok(rows) => WalRecord::Publish { epoch, rows },
                Err(_) => return Ok(WalScan { records, valid_bytes, torn: true }),
            },
            _ => {
                if payload.len() != 8 {
                    return Ok(WalScan { records, valid_bytes, torn: true });
                }
                let bits = u64::from_le_bytes(payload[..8].try_into().unwrap());
                WalRecord::Compact { epoch, threshold: f64::from_bits(bits) }
            }
        };
        records.push(rec);
        valid_bytes += (WAL_HEADER_LEN + len) as u64;
    }
}

enum ReadOutcome {
    Full,
    /// clean EOF at a record boundary
    Eof,
    /// EOF mid-buffer: a torn write
    Short,
}

fn read_exact_or_eof(r: &mut impl Read, buf: &mut [u8]) -> io::Result<ReadOutcome> {
    let mut filled = 0usize;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                return Ok(if filled == 0 { ReadOutcome::Eof } else { ReadOutcome::Short });
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(ReadOutcome::Full)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(id: usize) -> ServedSource {
        ServedSource {
            id,
            pos: (id as f64 * 0.5, 1.0 + id as f64),
            p_gal: 0.25,
            flux_r: 1000.0 + id as f64,
            flux_logsd: 0.1,
            colors: [0.1, -0.2, 0.3, f64::MIN_POSITIVE],
            converged: id % 2 == 0,
        }
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // the canonical IEEE check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn records_round_trip_byte_exactly() {
        let recs = vec![
            WalRecord::Publish { epoch: 1, rows: vec![row(3), row(7)] },
            WalRecord::Compact { epoch: 2, threshold: 2.5 },
            WalRecord::Publish { epoch: 3, rows: Vec::new() },
        ];
        let mut buf = Vec::new();
        for r in &recs {
            buf.extend_from_slice(&encode_record(r));
        }
        let scan = scan_segment(&mut &buf[..]).expect("scan");
        assert!(!scan.torn);
        assert_eq!(scan.valid_bytes, buf.len() as u64);
        assert_eq!(scan.records, recs);
    }

    #[test]
    fn torn_tail_keeps_the_good_prefix() {
        let good = encode_record(&WalRecord::Publish { epoch: 1, rows: vec![row(1)] });
        let second = encode_record(&WalRecord::Publish { epoch: 2, rows: vec![row(2)] });
        // cut the second record mid-payload, as a kill -9 mid-write does
        let mut buf = good.clone();
        buf.extend_from_slice(&second[..second.len() - 5]);
        let scan = scan_segment(&mut &buf[..]).expect("scan");
        assert!(scan.torn);
        assert_eq!(scan.valid_bytes, good.len() as u64);
        assert_eq!(scan.records.len(), 1);
        assert_eq!(scan.records[0].epoch(), 1);
    }

    #[test]
    fn corrupt_crc_and_bad_magic_end_the_scan() {
        let good = encode_record(&WalRecord::Publish { epoch: 1, rows: vec![row(1)] });
        let mut flipped = encode_record(&WalRecord::Publish { epoch: 2, rows: vec![row(2)] });
        let last = flipped.len() - 1;
        flipped[last] ^= 0x40; // payload bit flip: CRC must catch it
        let mut buf = good.clone();
        buf.extend_from_slice(&flipped);
        let scan = scan_segment(&mut &buf[..]).expect("scan");
        assert!(scan.torn);
        assert_eq!(scan.records.len(), 1);

        let mut garbage = good;
        garbage.extend_from_slice(b"not a wal record at all........");
        let scan = scan_segment(&mut &garbage[..]).expect("scan");
        assert!(scan.torn);
        assert_eq!(scan.records.len(), 1);
    }

    #[test]
    fn oversized_length_field_does_not_allocate() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&WAL_MAGIC.to_le_bytes());
        buf.push(WAL_VERSION);
        buf.push(1);
        buf.extend_from_slice(&0u16.to_le_bytes());
        buf.extend_from_slice(&1u64.to_le_bytes());
        buf.extend_from_slice(&u32::MAX.to_le_bytes()); // 4 GiB "payload"
        buf.extend_from_slice(&0u32.to_le_bytes());
        let scan = scan_segment(&mut &buf[..]).expect("scan");
        assert!(scan.torn, "a hostile length is a tear, not an allocation");
        assert!(scan.records.is_empty());
    }
}
