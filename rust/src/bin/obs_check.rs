//! obs_check — the CI gate over a `serve-bench --obs-dump` file.
//!
//! Parses the jsonlite dump (`docs/OBSERVABILITY.md` documents the
//! schema) and asserts the telemetry pipeline actually worked end to
//! end, rather than silently degrading to empty metrics:
//!
//! * the schema tag is the one this build writes;
//! * with `--expect-net`, real frames crossed the wire
//!   (`net_frames > 0`) and at least one per-server snapshot was
//!   scraped;
//! * with `--expect-stale`, the deliberate stale-epoch probe was
//!   refused and counted on *both* sides of the connection
//!   (`net_stale_refusals` client-side, `stale_refusals` on a server);
//! * with `--min-traces N`, at least `N` sampled traces survived, at
//!   least one of them a *complete cross-process span tree*: client
//!   spans carrying encode + decode, server spans carrying
//!   shard_execute, joined by a non-zero trace id;
//! * every trace's client spans sum to its end-to-end latency within
//!   5% — the partition-by-construction invariant the unit tests pin,
//!   re-checked here on a real multi-process run.
//!
//! Exit 0 when every asserted condition holds, 1 otherwise (each
//! failure on stderr).

use anyhow::{bail, Result};

use celeste::jsonlite::{self, Value};

/// The dump schema this checker understands (must match
/// `serve::obs::write_dump`).
const SCHEMA: &str = "celeste-obs-dump-v1";

/// Client span sums must reproduce end-to-end latency within this
/// fraction (the acceptance-criteria tolerance).
const SPAN_SUM_TOL: f64 = 0.05;

/// Sub-millisecond requests are dominated by clock granularity; skip
/// the span-sum check below this total rather than fail on noise.
const SPAN_SUM_MIN_MS: f64 = 0.05;

fn counter(snapshot: &Value, name: &str) -> f64 {
    snapshot
        .get("counters")
        .and_then(|c| c.get(name))
        .and_then(Value::as_f64)
        .unwrap_or(0.0)
}

fn span_sum_ms(spans: &Value) -> f64 {
    spans
        .as_obj()
        .map(|m| m.values().filter_map(Value::as_f64).sum())
        .unwrap_or(0.0)
}

fn has_span(spans: &Value, stage: &str) -> bool {
    spans.get(stage).and_then(Value::as_f64).is_some_and(|v| v > 0.0)
}

/// A complete cross-process span tree: the client side attributed wire
/// encode and decode, the server side attributed shard execution, and
/// the two halves are joined by a real (non-zero) trace id.
fn is_complete_tree(trace: &Value) -> bool {
    let id_ok = trace.get("trace_id").and_then(Value::as_f64).is_some_and(|id| id > 0.0);
    let client = trace.get("client_spans_ms");
    let server = trace.get("server_spans_ms");
    match (client, server) {
        (Some(c), Some(s)) => {
            id_ok
                && has_span(c, "encode")
                && has_span(c, "decode")
                && has_span(s, "shard_execute")
        }
        _ => false,
    }
}

fn check_traces(dump: &Value, min_traces: usize, failures: &mut Vec<String>) {
    let traces = match dump.get("traces").and_then(Value::as_arr) {
        Some(t) => t,
        None => {
            failures.push("dump has no `traces` array".to_string());
            return;
        }
    };
    if traces.len() < min_traces {
        failures.push(format!(
            "wanted at least {min_traces} sampled trace(s), dump has {}",
            traces.len()
        ));
    }
    if min_traces > 0 && !traces.iter().any(is_complete_tree) {
        failures.push(
            "no complete cross-process span tree: want one trace with client \
             encode+decode spans, server shard_execute spans, and a non-zero \
             trace id"
                .to_string(),
        );
    }
    for trace in traces {
        let id = trace.get("trace_id").and_then(Value::as_f64).unwrap_or(0.0);
        let total_ms = trace.get("total_ms").and_then(Value::as_f64).unwrap_or(0.0);
        if total_ms < SPAN_SUM_MIN_MS {
            continue;
        }
        let sum_ms = trace.get("client_spans_ms").map(span_sum_ms).unwrap_or(0.0);
        let err = (sum_ms - total_ms).abs() / total_ms;
        if err > SPAN_SUM_TOL {
            failures.push(format!(
                "trace {id:.0}: client spans sum to {sum_ms:.3}ms but end-to-end \
                 latency is {total_ms:.3}ms ({:.1}% apart, tolerance {:.0}%)",
                err * 100.0,
                SPAN_SUM_TOL * 100.0
            ));
        }
    }
}

fn main() -> Result<()> {
    let mut dump_path: Option<String> = None;
    let mut expect_net = false;
    let mut expect_stale = false;
    let mut min_traces = 0usize;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--dump" => match args.next() {
                Some(v) => dump_path = Some(v),
                None => bail!("--dump needs a file path"),
            },
            "--expect-net" => expect_net = true,
            "--expect-stale" => expect_stale = true,
            "--min-traces" => match args.next().map(|v| v.parse::<usize>()) {
                Some(Ok(n)) => min_traces = n,
                _ => bail!("--min-traces needs a non-negative integer"),
            },
            other => bail!(
                "unknown argument {other:?} \
                 (want --dump FILE [--expect-net] [--expect-stale] [--min-traces N])"
            ),
        }
    }
    let Some(dump_path) = dump_path else {
        bail!("usage: obs_check --dump FILE [--expect-net] [--expect-stale] [--min-traces N]");
    };

    let text = match std::fs::read_to_string(&dump_path) {
        Ok(t) => t,
        Err(e) => bail!("cannot read {dump_path}: {e}"),
    };
    let dump = match jsonlite::parse(&text) {
        Ok(v) => v,
        Err(e) => bail!("cannot parse {dump_path}: {e}"),
    };

    let mut failures: Vec<String> = Vec::new();

    match dump.get("schema").and_then(Value::as_str) {
        Some(SCHEMA) => {}
        got => failures.push(format!("dump schema is {got:?}, want {SCHEMA:?}")),
    }

    let metrics = dump.get("metrics");
    let Some(metrics) = metrics else {
        for f in &failures {
            eprintln!("obs_check FAIL: {f}");
        }
        bail!("dump has no `metrics` object");
    };
    let servers = dump.get("servers").and_then(Value::as_arr).unwrap_or(&[]);

    if expect_net {
        let frames = counter(metrics, "net_frames");
        if frames <= 0.0 {
            failures.push(format!(
                "net_frames is {frames:.0}; a tcp run must move at least one frame"
            ));
        }
        if servers.is_empty() {
            failures.push("no scraped server snapshots in a tcp dump".to_string());
        }
    }
    if expect_stale {
        let client_side = counter(metrics, "net_stale_refusals");
        if client_side <= 0.0 {
            failures.push(
                "net_stale_refusals is 0 client-side; the stale probe did not register"
                    .to_string(),
            );
        }
        if !servers.iter().any(|s| counter(s, "stale_refusals") > 0.0) {
            failures.push(
                "no server snapshot counted a stale_refusal; the probe's refusal \
                 was not attributed server-side"
                    .to_string(),
            );
        }
    }
    check_traces(&dump, min_traces, &mut failures);

    let n_traces = dump.get("traces").and_then(Value::as_arr).map_or(0, <[Value]>::len);
    println!(
        "obs_check: {dump_path}: {} server snapshot(s), {} trace(s), \
         net_frames={:.0}, stale_refusals={:.0}",
        servers.len(),
        n_traces,
        counter(metrics, "net_frames"),
        counter(metrics, "net_stale_refusals"),
    );

    if failures.is_empty() {
        println!("obs_check: OK");
        Ok(())
    } else {
        for f in &failures {
            eprintln!("obs_check FAIL: {f}");
        }
        bail!("{} obs gate failure(s)", failures.len());
    }
}
