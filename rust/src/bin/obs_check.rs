//! obs_check — the CI gate over a `serve-bench --obs-dump` file.
//!
//! Parses the jsonlite dump (`docs/OBSERVABILITY.md` documents the
//! schema) and asserts the telemetry pipeline actually worked end to
//! end, rather than silently degrading to empty metrics:
//!
//! * the schema tag is the one this build writes;
//! * with `--expect-net`, real frames crossed the wire
//!   (`net_frames > 0`) and at least one per-server snapshot was
//!   scraped;
//! * with `--expect-stale`, the deliberate stale-epoch probe was
//!   refused and counted on *both* sides of the connection
//!   (`net_stale_refusals` client-side, `stale_refusals` on a server);
//! * with `--min-traces N`, at least `N` sampled traces survived, at
//!   least one of them a *complete cross-process span tree*: client
//!   spans carrying encode + decode, server spans carrying
//!   shard_execute, joined by a non-zero trace id;
//! * every trace's client spans sum to its end-to-end latency within
//!   5% — the partition-by-construction invariant the unit tests pin,
//!   re-checked here on a real multi-process run;
//! * with `--timeline`, the continuous-telemetry section exists and
//!   every row (each node and the cluster fold) *conserves*: evicted
//!   counter deltas + the per-window deltas sum exactly to the row's
//!   final counters — a windowed rollup that loses or invents events
//!   fails here;
//! * with `--min-windows N` / `--nodes N`, the cluster timeline closed
//!   at least `N` non-empty windows and exactly `N` node rows exist;
//! * with `--killed NAME`, that node's row gapped and its health
//!   verdict flipped to unhealthy — and *no other* node gained a gap
//!   (the kill was attributed precisely);
//! * with `--expect-recovered`, the killed node restarted: a
//!   `recovered` window, `restarts >= 1`, and a flip back to healthy;
//! * with `--expect-recovery`, the dump carries the WAL recovery
//!   gauges (`recovered_epoch`, `recovery_replay_ms`) somewhere — the
//!   recover-bench / restarted-server visibility gate;
//! * with `--expect-migrations`, the control plane's decision log is
//!   present (`control` section, v3) and recorded at least one
//!   rebalance decision — the moving-hotspot smoke's proof that the
//!   controller actually acted, not just ran.
//!
//! Exit 0 when every asserted condition holds, 1 otherwise (each
//! failure on stderr).

use anyhow::{bail, Result};

use celeste::jsonlite::{self, Value};

/// The dump schema this checker understands (must match
/// `serve::obs::write_dump`).
const SCHEMA: &str = "celeste-obs-dump-v3";

/// Client span sums must reproduce end-to-end latency within this
/// fraction (the acceptance-criteria tolerance).
const SPAN_SUM_TOL: f64 = 0.05;

/// Sub-millisecond requests are dominated by clock granularity; skip
/// the span-sum check below this total rather than fail on noise.
const SPAN_SUM_MIN_MS: f64 = 0.05;

fn counter(snapshot: &Value, name: &str) -> f64 {
    snapshot
        .get("counters")
        .and_then(|c| c.get(name))
        .and_then(Value::as_f64)
        .unwrap_or(0.0)
}

fn gauge(snapshot: &Value, name: &str) -> Option<f64> {
    snapshot.get("gauges").and_then(|g| g.get(name)).and_then(Value::as_f64)
}

/// Sum an object of numeric counters into `acc`.
fn accumulate(acc: &mut std::collections::BTreeMap<String, u64>, obj: Option<&Value>) {
    if let Some(map) = obj.and_then(Value::as_obj) {
        for (k, v) in map {
            if let Some(n) = v.as_f64() {
                *acc.entry(k.clone()).or_insert(0) += n as u64;
            }
        }
    }
}

/// The windowed-rollup conservation invariant on one timeline row:
/// evicted deltas + every window's deltas == the row's final counters,
/// key for key, exactly.
fn check_conservation(row: &Value, failures: &mut Vec<String>) {
    let name = row.get("node").and_then(Value::as_str).unwrap_or("?");
    let mut total = std::collections::BTreeMap::new();
    accumulate(&mut total, row.get("evicted"));
    if let Some(windows) = row.get("windows").and_then(Value::as_arr) {
        for w in windows {
            accumulate(&mut total, w.get("counters"));
        }
    }
    let mut fin = std::collections::BTreeMap::new();
    accumulate(&mut fin, row.get("final"));
    if total != fin {
        let diff: Vec<String> = fin
            .keys()
            .chain(total.keys())
            .filter(|k| total.get(*k) != fin.get(*k))
            .map(|k| {
                format!(
                    "{k}: windows+evicted {} vs final {}",
                    total.get(k).copied().unwrap_or(0),
                    fin.get(k).copied().unwrap_or(0)
                )
            })
            .collect();
        failures.push(format!(
            "timeline row {name:?} does not conserve: {}",
            diff.join(", ")
        ));
    }
}

/// A window that carries actual telemetry (not a gap, not empty).
fn window_is_live(w: &Value) -> bool {
    let gapped = w.get("gapped").and_then(Value::as_bool).unwrap_or(false);
    let has = |k: &str| w.get(k).and_then(Value::as_obj).is_some_and(|m| !m.is_empty());
    !gapped && (has("counters") || has("gauges") || has("hists"))
}

fn node_row<'a>(timeline: &'a Value, name: &str) -> Option<&'a Value> {
    timeline
        .get("nodes")
        .and_then(Value::as_arr)?
        .iter()
        .find(|r| r.get("node").and_then(Value::as_str) == Some(name))
}

/// Health transitions for `node` that landed on verdict `to`.
fn health_flips(timeline: &Value, node: &str, to: &str) -> usize {
    timeline
        .get("health")
        .and_then(Value::as_arr)
        .map(|h| {
            h.iter()
                .filter(|t| t.get("node").and_then(Value::as_str) == Some(node))
                .filter(|t| t.get("to").and_then(Value::as_str) == Some(to))
                .count()
        })
        .unwrap_or(0)
}

fn check_timeline(
    dump: &Value,
    min_windows: usize,
    nodes: Option<usize>,
    killed: Option<&str>,
    expect_recovered: bool,
    failures: &mut Vec<String>,
) {
    let Some(timeline) = dump.get("timeline") else {
        failures.push(
            "dump has no `timeline` section; run serve-bench with --collect-ms N".to_string(),
        );
        return;
    };
    let rows = timeline.get("nodes").and_then(Value::as_arr).unwrap_or(&[]);
    if rows.is_empty() {
        failures.push("timeline has no node rows".to_string());
    }
    if let Some(n) = nodes {
        if rows.len() != n {
            failures.push(format!("timeline has {} node row(s), want {n}", rows.len()));
        }
    }
    for row in rows {
        check_conservation(row, failures);
    }
    match timeline.get("cluster") {
        Some(cluster) => {
            check_conservation(cluster, failures);
            let live = cluster
                .get("windows")
                .and_then(Value::as_arr)
                .map(|ws| ws.iter().filter(|w| window_is_live(w)).count())
                .unwrap_or(0);
            if live < min_windows {
                failures.push(format!(
                    "cluster timeline has {live} non-empty window(s), want at least \
                     {min_windows}"
                ));
            }
        }
        None => failures.push("timeline has no cluster row".to_string()),
    }
    if let Some(victim) = killed {
        match node_row(timeline, victim) {
            Some(row) => {
                let gaps = row.get("gaps").and_then(Value::as_f64).unwrap_or(0.0);
                if gaps <= 0.0 {
                    failures.push(format!(
                        "killed node {victim:?} shows no gapped windows; its death was \
                         invisible to the collector"
                    ));
                }
                if health_flips(timeline, victim, "unhealthy") == 0 {
                    failures.push(format!(
                        "killed node {victim:?} never flipped to unhealthy"
                    ));
                }
            }
            None => failures.push(format!("timeline has no row for killed node {victim:?}")),
        }
        for row in rows {
            let name = row.get("node").and_then(Value::as_str).unwrap_or("?");
            if name == victim {
                continue;
            }
            let gaps = row.get("gaps").and_then(Value::as_f64).unwrap_or(0.0);
            if gaps > 0.0 {
                failures.push(format!(
                    "node {name:?} gained {gaps:.0} gap(s) but only {victim:?} was killed; \
                     the kill was misattributed"
                ));
            }
        }
        if expect_recovered {
            if let Some(row) = node_row(timeline, victim) {
                let restarts = row.get("restarts").and_then(Value::as_f64).unwrap_or(0.0);
                let has_recovered_window = row
                    .get("windows")
                    .and_then(Value::as_arr)
                    .is_some_and(|ws| {
                        ws.iter().any(|w| {
                            w.get("recovered").and_then(Value::as_bool).unwrap_or(false)
                        })
                    });
                if restarts <= 0.0 || !has_recovered_window {
                    failures.push(format!(
                        "killed node {victim:?} shows no recovered window \
                         (restarts={restarts:.0}); the restart drill did not fold back in"
                    ));
                }
                if health_flips(timeline, victim, "healthy") == 0 {
                    failures.push(format!(
                        "killed node {victim:?} never flipped back to healthy after recovery"
                    ));
                }
            }
        }
    } else if expect_recovered {
        failures.push("--expect-recovered needs --killed NODE to name the victim".to_string());
    }
}

/// The recover-bench / restarted-server gate: the WAL recovery gauges
/// must be reachable somewhere in the dump (front-end metrics or a
/// scraped server snapshot).
fn check_recovery_gauges(dump: &Value, failures: &mut Vec<String>) {
    let metrics = dump.get("metrics");
    let servers = dump.get("servers").and_then(Value::as_arr).unwrap_or(&[]);
    let snapshots: Vec<&Value> = metrics.into_iter().chain(servers.iter()).collect();
    for g in ["recovered_epoch", "recovery_replay_ms"] {
        if !snapshots.iter().any(|s| gauge(s, g).is_some()) {
            failures.push(format!(
                "no snapshot in the dump carries the {g} gauge; the recovery registry \
                 is not reachable from --obs-dump"
            ));
        }
    }
}

/// The control-plane gate: the dump's `control` section must exist
/// (the run passed --rebalance) and its decision log must hold at
/// least one rebalance whose event record names the hot node — a
/// controller that ran but never acted fails here.
fn check_control(dump: &Value, failures: &mut Vec<String>) {
    let Some(control) = dump.get("control") else {
        failures.push(
            "dump has no `control` section; run serve-bench with --rebalance MS".to_string(),
        );
        return;
    };
    let rebalances =
        control.get("rebalances").and_then(Value::as_f64).unwrap_or(0.0);
    if rebalances < 1.0 {
        failures.push(format!(
            "control log shows {rebalances:.0} rebalance decision(s); the moving hotspot \
             should have triggered at least one"
        ));
    }
    let decisions = control.get("decisions").and_then(Value::as_arr).unwrap_or(&[]);
    if decisions.is_empty() {
        failures.push("control section has an empty `decisions` array".to_string());
        return;
    }
    let named = decisions.iter().any(|d| {
        d.get("event").and_then(Value::as_str) == Some("rebalance")
            && d.get("hot_node").and_then(Value::as_f64).is_some()
    });
    if !named {
        failures.push(
            "no rebalance decision names its hot_node; the trigger measurement was \
             not recorded"
                .to_string(),
        );
    }
}

fn span_sum_ms(spans: &Value) -> f64 {
    spans
        .as_obj()
        .map(|m| m.values().filter_map(Value::as_f64).sum())
        .unwrap_or(0.0)
}

fn has_span(spans: &Value, stage: &str) -> bool {
    spans.get(stage).and_then(Value::as_f64).is_some_and(|v| v > 0.0)
}

/// A complete cross-process span tree: the client side attributed wire
/// encode and decode, the server side attributed shard execution, and
/// the two halves are joined by a real (non-zero) trace id.
fn is_complete_tree(trace: &Value) -> bool {
    let id_ok = trace.get("trace_id").and_then(Value::as_f64).is_some_and(|id| id > 0.0);
    let client = trace.get("client_spans_ms");
    let server = trace.get("server_spans_ms");
    match (client, server) {
        (Some(c), Some(s)) => {
            id_ok
                && has_span(c, "encode")
                && has_span(c, "decode")
                && has_span(s, "shard_execute")
        }
        _ => false,
    }
}

fn check_traces(dump: &Value, min_traces: usize, failures: &mut Vec<String>) {
    let traces = match dump.get("traces").and_then(Value::as_arr) {
        Some(t) => t,
        None => {
            failures.push("dump has no `traces` array".to_string());
            return;
        }
    };
    if traces.len() < min_traces {
        failures.push(format!(
            "wanted at least {min_traces} sampled trace(s), dump has {}",
            traces.len()
        ));
    }
    if min_traces > 0 && !traces.iter().any(is_complete_tree) {
        failures.push(
            "no complete cross-process span tree: want one trace with client \
             encode+decode spans, server shard_execute spans, and a non-zero \
             trace id"
                .to_string(),
        );
    }
    for trace in traces {
        let id = trace.get("trace_id").and_then(Value::as_f64).unwrap_or(0.0);
        let total_ms = trace.get("total_ms").and_then(Value::as_f64).unwrap_or(0.0);
        if total_ms < SPAN_SUM_MIN_MS {
            continue;
        }
        let sum_ms = trace.get("client_spans_ms").map(span_sum_ms).unwrap_or(0.0);
        let err = (sum_ms - total_ms).abs() / total_ms;
        if err > SPAN_SUM_TOL {
            failures.push(format!(
                "trace {id:.0}: client spans sum to {sum_ms:.3}ms but end-to-end \
                 latency is {total_ms:.3}ms ({:.1}% apart, tolerance {:.0}%)",
                err * 100.0,
                SPAN_SUM_TOL * 100.0
            ));
        }
    }
}

fn main() -> Result<()> {
    let mut dump_path: Option<String> = None;
    let mut expect_net = false;
    let mut expect_stale = false;
    let mut min_traces = 0usize;
    let mut timeline = false;
    let mut min_windows = 0usize;
    let mut nodes: Option<usize> = None;
    let mut killed: Option<String> = None;
    let mut expect_recovered = false;
    let mut expect_recovery = false;
    let mut expect_migrations = false;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--dump" => match args.next() {
                Some(v) => dump_path = Some(v),
                None => bail!("--dump needs a file path"),
            },
            "--expect-net" => expect_net = true,
            "--expect-stale" => expect_stale = true,
            "--min-traces" => match args.next().map(|v| v.parse::<usize>()) {
                Some(Ok(n)) => min_traces = n,
                _ => bail!("--min-traces needs a non-negative integer"),
            },
            "--timeline" => timeline = true,
            "--min-windows" => match args.next().map(|v| v.parse::<usize>()) {
                Some(Ok(n)) => min_windows = n,
                _ => bail!("--min-windows needs a non-negative integer"),
            },
            "--nodes" => match args.next().map(|v| v.parse::<usize>()) {
                Some(Ok(n)) => nodes = Some(n),
                _ => bail!("--nodes needs a non-negative integer"),
            },
            "--killed" => match args.next() {
                Some(v) => killed = Some(v),
                None => bail!("--killed needs a node name"),
            },
            "--expect-recovered" => expect_recovered = true,
            "--expect-recovery" => expect_recovery = true,
            "--expect-migrations" => expect_migrations = true,
            other => bail!(
                "unknown argument {other:?} \
                 (want --dump FILE [--expect-net] [--expect-stale] [--min-traces N] \
                 [--timeline] [--min-windows N] [--nodes N] [--killed NODE] \
                 [--expect-recovered] [--expect-recovery] [--expect-migrations])"
            ),
        }
    }
    let Some(dump_path) = dump_path else {
        bail!(
            "usage: obs_check --dump FILE [--expect-net] [--expect-stale] [--min-traces N] \
             [--timeline] [--min-windows N] [--nodes N] [--killed NODE] \
             [--expect-recovered] [--expect-recovery] [--expect-migrations]"
        );
    };

    let text = match std::fs::read_to_string(&dump_path) {
        Ok(t) => t,
        Err(e) => bail!("cannot read {dump_path}: {e}"),
    };
    let dump = match jsonlite::parse(&text) {
        Ok(v) => v,
        Err(e) => bail!("cannot parse {dump_path}: {e}"),
    };

    let mut failures: Vec<String> = Vec::new();

    match dump.get("schema").and_then(Value::as_str) {
        Some(SCHEMA) => {}
        got => failures.push(format!("dump schema is {got:?}, want {SCHEMA:?}")),
    }

    let metrics = dump.get("metrics");
    let Some(metrics) = metrics else {
        for f in &failures {
            eprintln!("obs_check FAIL: {f}");
        }
        bail!("dump has no `metrics` object");
    };
    let servers = dump.get("servers").and_then(Value::as_arr).unwrap_or(&[]);

    if expect_net {
        let frames = counter(metrics, "net_frames");
        if frames <= 0.0 {
            failures.push(format!(
                "net_frames is {frames:.0}; a tcp run must move at least one frame"
            ));
        }
        if servers.is_empty() {
            failures.push("no scraped server snapshots in a tcp dump".to_string());
        }
    }
    if expect_stale {
        let client_side = counter(metrics, "net_stale_refusals");
        if client_side <= 0.0 {
            failures.push(
                "net_stale_refusals is 0 client-side; the stale probe did not register"
                    .to_string(),
            );
        }
        if !servers.iter().any(|s| counter(s, "stale_refusals") > 0.0) {
            failures.push(
                "no server snapshot counted a stale_refusal; the probe's refusal \
                 was not attributed server-side"
                    .to_string(),
            );
        }
    }
    check_traces(&dump, min_traces, &mut failures);
    if timeline || min_windows > 0 || nodes.is_some() || killed.is_some() || expect_recovered {
        check_timeline(
            &dump,
            min_windows,
            nodes,
            killed.as_deref(),
            expect_recovered,
            &mut failures,
        );
    }
    if expect_recovery {
        check_recovery_gauges(&dump, &mut failures);
    }
    if expect_migrations {
        check_control(&dump, &mut failures);
    }

    let n_traces = dump.get("traces").and_then(Value::as_arr).map_or(0, <[Value]>::len);
    let n_windows = dump
        .get("timeline")
        .and_then(|t| t.get("cluster"))
        .and_then(|c| c.get("windows"))
        .and_then(Value::as_arr)
        .map_or(0, <[Value]>::len);
    println!(
        "obs_check: {dump_path}: {} server snapshot(s), {} trace(s), {} cluster window(s), \
         net_frames={:.0}, stale_refusals={:.0}",
        servers.len(),
        n_traces,
        n_windows,
        counter(metrics, "net_frames"),
        counter(metrics, "net_stale_refusals"),
    );

    if failures.is_empty() {
        println!("obs_check: OK");
        Ok(())
    } else {
        for f in &failures {
            eprintln!("obs_check FAIL: {f}");
        }
        bail!("{} obs gate failure(s)", failures.len());
    }
}
